//! TILE&PACK exploration (paper Alg. 1 / Fig. 12b) + packing ablations.
//!
//! Regenerates the MobileNetV2 mapping, then quantifies what the paper's
//! choices cost: rotation on/off, bin size, and width-multiplier scaling
//! (how many crossbars would a 0.5× or 1.4× MobileNetV2 need?).
//!
//! Run with:  cargo run --release --example tilepack_explore

use imcc::net::mobilenetv2::mobilenet_v2;
use imcc::tilepack::{pack, tile_network, Packing};
use imcc::util::table::{f, Table};

fn main() {
    let net = mobilenet_v2(224);
    let tiles = tile_network(&net, 256);

    // ---- the paper's mapping --------------------------------------------
    let p = pack(&tiles, 256, false);
    let mut utils = p.utilizations();
    utils.sort_by(|a, b| b.partial_cmp(a).unwrap());
    println!(
        "MobileNetV2: {} tiles, {} devices -> {} crossbars (paper: 34), \
         lower bound {}",
        tiles.len(),
        p.total_devices(),
        p.n_bins(),
        Packing::area_lower_bound(&tiles, 256),
    );
    for (i, u) in utils.iter().enumerate() {
        println!("  bin {i:>2}: {:>5.1}%", u * 100.0);
    }

    // ---- ablation: rotation ----------------------------------------------
    let rot = pack(&tiles, 256, true);
    println!(
        "\nrotation ablation: {} bins without, {} with 90° tile rotation",
        p.n_bins(),
        rot.n_bins()
    );

    // ---- ablation: crossbar size ------------------------------------------
    let mut t = Table::new(
        "crossbar-size ablation (same network)",
        &["array", "tiles", "bins", "total devices", "waste %"],
    );
    for s in [128usize, 256, 512] {
        let tl = tile_network(&net, s);
        let pk = pack(&tl, s, false);
        let capacity = pk.n_bins() * s * s;
        let waste = 100.0 * (1.0 - pk.total_devices() as f64 / capacity as f64);
        t.row([
            format!("{s}x{s}"),
            tl.len().to_string(),
            pk.n_bins().to_string(),
            pk.total_devices().to_string(),
            f(waste, 1),
        ]);
    }
    t.print();

    // ---- ablation: width multiplier ----------------------------------------
    println!("\nwidth-multiplier scaling (input 224, array 256x256):");
    for res in [96usize, 160, 224] {
        let n = mobilenet_v2(res);
        let tl = tile_network(&n, 256);
        let pk = pack(&tl, 256, false);
        println!(
            "  {res:>3}px input: {:>3} crossbars ({} conv weights)",
            pk.n_bins(),
            tl.iter().map(|x| x.devices()).sum::<usize>()
        );
    }
    println!("(weights are resolution-independent; the bin count is too — the\n sweep demonstrates the packer is shape-stable, not a paper figure)");
}
