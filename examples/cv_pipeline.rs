//! Heterogeneous CV pipeline (paper §VII / Fig. 13 discussion).
//!
//! The paper argues its SW+IMA+DIG.ACC model extends beyond a single DNN to
//! "modern computer vision pipelines" that mix AI with classic linear
//! algebra — PCA, FFT, filtering, inverse kinematics — which fixed-function
//! IMC architectures cannot host at all. This example quantifies that
//! claim: a drone-style perception pipeline
//!
//!     FIR pre-filter → MobileNetV2 (IMA + DW accel) → PCA on the
//!     1280-d features → 6-DOF inverse kinematics
//!
//! where every non-DNN stage runs on the programmable cores.
//!
//! Run with:  cargo run --release --example cv_pipeline

use imcc::arch::{PowerModel, SystemConfig};
use imcc::coordinator::{run_network, Strategy};
use imcc::cores::DspKernels;
use imcc::net::mobilenetv2::mobilenet_v2;
use imcc::util::units;

fn main() {
    let cfg = SystemConfig::scaled_up(33);
    let pm = PowerModel::paper();
    let dsp = DspKernels::new(&cfg);
    let net = mobilenet_v2(224);

    let fir = dsp.fir(224 * 224, 16);
    let dnn = run_network(&net, Strategy::ImaDw, &cfg, &pm);
    let pca = dsp.pca_project(1280, 64);
    let ik = dsp.inverse_kinematics(6, 20);

    let stages: [(&str, u64, f64); 4] = [
        ("FIR 16-tap pre-filter (cores)", fir.cycles, fir.energy.total_j(&pm, &cfg)),
        ("MobileNetV2 (IMA + DW accel)", dnn.cycles, dnn.energy_j),
        ("PCA 1280→64 (cores)", pca.cycles, pca.energy.total_j(&pm, &cfg)),
        ("IK 6-DOF ×20 iters (cores)", ik.cycles, ik.energy.total_j(&pm, &cfg)),
    ];
    let total_cy: u64 = stages.iter().map(|s| s.1).sum();
    let total_j: f64 = stages.iter().map(|s| s.2).sum();

    println!("heterogeneous CV pipeline on the 33-crossbar cluster @500 MHz:\n");
    for (name, cy, j) in &stages {
        println!(
            "  {:<32} {:>10} cy  {:>10}  {:>10}  ({:.1}%)",
            name,
            cy,
            units::fmt_time(*cy as f64 * cfg.freq.cycle_ns() * 1e-9),
            units::fmt_energy(*j),
            100.0 * *cy as f64 / total_cy as f64
        );
    }
    println!(
        "\n  pipeline total: {} / {} per frame → {:.0} fps",
        units::fmt_time(total_cy as f64 * cfg.freq.cycle_ns() * 1e-9),
        units::fmt_energy(total_j),
        1.0 / (total_cy as f64 * cfg.freq.cycle_ns() * 1e-9)
    );
    println!(
        "\nreading: the classic-DSP glue costs {:.1}% of the frame — programmable\n\
         cores make the pipeline possible (IMA+DIG.ACC-only systems cannot run\n\
         it at all, Fig. 13) at negligible performance cost.",
        100.0 * (total_cy - dnn.cycles) as f64 / total_cy as f64
    );
}
