//! END-TO-END DRIVER: full MobileNetV2 inference, functionally executed
//! through the AOT PJRT artifacts *and* accounted by the cycle/energy model —
//! proving the three layers compose (DESIGN.md "End-to-end validation").
//!
//! What happens here:
//!   1. TILE&PACK maps all MobileNetV2 conv weights onto 256×256 crossbars
//!      (Alg. 1 — the paper needs 34; we measure our packing).
//!   2. The crossbars are "programmed" (weight tiles uploaded once).
//!   3. A real 224×224×3 int8 input runs through the network: every MVM job,
//!      dw-engine tile and residual chunk executes inside a PJRT executable
//!      lowered from the Pallas kernels. The result must be bit-exact
//!      against the JAX golden logits (same seed, same numeric contract).
//!   4. The same job stream is costed by the simulator → the paper's
//!      headline 10.1 ms / 482 µJ / 99 inf/s (Fig. 12, Table I row).
//!
//! Run with:  make artifacts && cargo run --release --example mobilenet_e2e
//! Results are recorded in EXPERIMENTS.md.

use imcc::arch::{PowerModel, SystemConfig};
use imcc::coordinator::{run_network, Strategy};
use imcc::runtime::{functional, Manifest, Runtime};
use imcc::tilepack::{pack, tile_network};
use imcc::util::units;

fn main() -> imcc::util::error::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());

    // ---- 1. TILE&PACK --------------------------------------------------
    let manifest = Manifest::load(&dir, false)?;
    let net = manifest.to_network();
    let tiles = tile_network(&net, 256);
    let packing = pack(&tiles, 256, false);
    println!(
        "[tilepack] {} weight tiles -> {} crossbars (paper: 34); median utilization {:.0}%",
        tiles.len(),
        packing.n_bins(),
        {
            let mut u = packing.utilizations();
            u.sort_by(|a, b| a.partial_cmp(b).unwrap());
            u[u.len() / 2] * 100.0
        }
    );

    // ---- 2+3. functional inference via PJRT artifacts -------------------
    let mut rt = Runtime::load(&dir)?;
    functional::program_network(&mut rt, &manifest, 0.0)?;
    println!(
        "[program] {} crossbar tiles programmed (once, off the request path)",
        rt.programmed_tiles()
    );
    let res = functional::run_inference(&rt, &manifest)?;
    imcc::ensure!(res.all_match(), "layer checksum divergence");
    imcc::ensure!(res.logits == manifest.golden_logits, "logits mismatch");
    println!(
        "[functional] {} layers bit-exact vs JAX golden; argmax {} == golden {}; \
         {} backend job calls in {:.2}s host wall",
        res.checksums.len(),
        res.argmax,
        manifest.golden_argmax,
        res.backend_calls,
        res.wall.as_secs_f64()
    );

    // ---- 4. simulated latency/energy on the scaled-up cluster -----------
    let cfg = SystemConfig::scaled_up(packing.n_bins());
    let pm = PowerModel::paper();
    let rep = run_network(&net, Strategy::ImaDw, &cfg, &pm);
    println!(
        "[simulated] {} | {} | {:.0} inf/s   (paper: 10.1 ms, 482 µJ, 99 inf/s)",
        units::fmt_time(rep.time_s),
        units::fmt_energy(rep.energy_j),
        rep.inferences_per_s()
    );

    // per-engine share
    for (engine, cy) in rep.engine_breakdown() {
        println!(
            "            {:?}: {:.1}% of cycles",
            engine,
            100.0 * cy as f64 / rep.cycles as f64
        );
    }
    Ok(())
}
