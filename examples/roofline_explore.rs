//! Roofline exploration (paper Fig. 7) + extensions the paper doesn't show.
//!
//! Regenerates the three panels, then extends the study with an execution-
//! model × frequency × bus-width grid on the *real* Bottleneck layer (the
//! paper only sweeps synthetic point-wise layers).
//!
//! Run with:  cargo run --release --example roofline_explore

use imcc::arch::{ExecModel, FreqPoint, PowerModel, SystemConfig};
use imcc::coordinator::{run_network, Strategy};
use imcc::net::bottleneck::bottleneck;
use imcc::report::fig7_roofline;
use imcc::util::table::{f, Table};

fn main() {
    // ---- the paper's figure ----------------------------------------------
    fig7_roofline::generate().print();

    // ---- extension: the same sweep on a real heterogeneous layer ---------
    let pm = PowerModel::paper();
    let net = bottleneck();
    let mut t = Table::new(
        "extension — Bottleneck (IMA+DW) across operating points",
        &["freq", "exec model", "bus", "cycles", "GOPS"],
    );
    for freq in [FreqPoint::HIGH, FreqPoint::LOW] {
        for exec in [ExecModel::Sequential, ExecModel::Pipelined] {
            for bus in [32usize, 64, 128, 256] {
                let cfg = SystemConfig::paper()
                    .with_freq(freq)
                    .with_exec(exec)
                    .with_bus_bits(bus);
                let r = run_network(&net, Strategy::ImaDw, &cfg, &pm);
                t.row([
                    format!("{} MHz", freq.freq_mhz),
                    format!("{exec:?}"),
                    format!("{bus}b"),
                    r.cycles.to_string(),
                    f(r.gops(), 1),
                ]);
            }
        }
    }
    t.print();
    println!(
        "\nreading: on the heterogeneous Bottleneck the pipelined/sequential gap and \
         the bus-width knee match the synthetic roofline — 128-bit + pipelined is \
         where the real workload stops being interface-bound too."
    );
}
