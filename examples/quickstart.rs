//! Quickstart: the library in ~40 lines.
//!
//! Builds the paper's case-study Bottleneck, runs it under the four
//! computation mappings on the simulated heterogeneous cluster, and prints
//! the Fig. 9 story: the IMA alone cannot beat Amdahl — the depth-wise
//! accelerator can.
//!
//!     cargo run --release --example quickstart

use imcc::arch::{PowerModel, SystemConfig};
use imcc::coordinator::{run_network, Strategy};
use imcc::net::bottleneck::bottleneck;

fn main() {
    // The publication configuration: 8 cores + IMA + DW engine,
    // 500 MHz @ 0.8 V, 128-bit IMA data interface, pipelined execution.
    let cfg = SystemConfig::paper();
    let pm = PowerModel::paper();

    // A MobileNetV2-style Bottleneck: pw-expand → 3×3 dw → pw-project (+res).
    let net = bottleneck();
    println!(
        "workload: {} ({} layers, {:.1} MMAC)\n",
        net.name,
        net.layers.len(),
        net.total_macs() as f64 / 1e6
    );

    let baseline = run_network(&net, Strategy::Cores, &cfg, &pm);
    println!(
        "{:<12} {:>10} cycles  {:>7.1} GOPS  {:>6.3} TOPS/W",
        "CORES",
        baseline.cycles,
        baseline.gops(),
        baseline.tops_per_w()
    );

    for s in [
        Strategy::ImaOnly { c_job: 16 },
        Strategy::Hybrid,
        Strategy::ImaDw,
    ] {
        let r = run_network(&net, s, &cfg, &pm);
        println!(
            "{:<12} {:>10} cycles  {:>7.1} GOPS  {:>6.3} TOPS/W  ({:.1}x CORES)",
            s.label(),
            r.cycles,
            r.gops(),
            r.tops_per_w(),
            baseline.cycles as f64 / r.cycles as f64
        );
    }

    println!("\npaper (Fig. 9): IMA_cjob16 2.27x | HYBRID 4.6x | IMA+DW 11.5x over CORES");
}
