//! Bottleneck case study (paper §V-C): timing *and* numerics.
//!
//! * Simulated: Fig. 9 (perf / energy-eff / area-eff of the five mappings)
//!   and Fig. 10 (Amdahl breakdown).
//! * Functional: the fused L2 Bottleneck artifact (Pallas crossbar jobs +
//!   dw-engine tiles + residual, lowered as ONE HLO module) runs on real
//!   data and is checked bit-exactly against the JAX golden output.
//!
//! Run with:  make artifacts && cargo run --release --example bottleneck_study

use imcc::arch::{PowerModel, SystemConfig};
use imcc::report::{fig10_breakdown, fig9_bottleneck};
use imcc::runtime::golden;
use imcc::runtime::Runtime;

fn main() -> imcc::util::error::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let cfg = SystemConfig::paper();
    let pm = PowerModel::paper();

    // ---- simulated: Figs. 9 & 10 ----------------------------------------
    fig9_bottleneck::generate(&cfg, &pm).print();
    println!();
    fig10_breakdown::generate(&cfg, &pm).print();

    // ---- functional: the fused L2 artifact vs golden ---------------------
    let rt = Runtime::load(&dir)?;
    let x = golden::load_i8(&format!("{dir}/golden/bottleneck_x.bin"))?;
    let w1 = golden::load_i8(&format!("{dir}/golden/bottleneck_w1.bin"))?;
    let wd = golden::load_i8(&format!("{dir}/golden/bottleneck_wd.bin"))?;
    let w2 = golden::load_i8(&format!("{dir}/golden/bottleneck_w2.bin"))?;
    let shifts_raw = golden::load_i32(&format!("{dir}/golden/bottleneck_shifts.bin"))?;
    let want = golden::load_i8(&format!("{dir}/golden/bottleneck_y.bin"))?;

    let t0 = std::time::Instant::now();
    let got = rt.bottleneck(&x, &w1, &wd, &w2, &[shifts_raw[0], shifts_raw[1], shifts_raw[2]])?;
    let dt = t0.elapsed();

    match golden::first_mismatch(&got, &want) {
        None => println!(
            "\n[functional] fused Bottleneck artifact: {} outputs bit-exact vs JAX \
             golden (checksum {}), {:.1} ms on the native backend",
            got.len(),
            golden::checksum_i8(&got),
            dt.as_secs_f64() * 1e3
        ),
        Some(i) => imcc::bail!(
            "fused Bottleneck diverges at element {i}: {} vs {}",
            got[i],
            want[i]
        ),
    }
    Ok(())
}
