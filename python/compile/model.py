"""L2: the paper's compute graph in JAX, calling the L1 Pallas kernels.

Two roles:

1. `bottleneck_fused` — the case-study Bottleneck block (paper §V-C) as a
   single JAX function composed of Pallas crossbar jobs + depth-wise engine
   tiles + the residual kernel. `aot.py` lowers it to one HLO artifact; it is
   the L2 showcase exercised by `examples/bottleneck_study.rs`.

2. `run_network` — the *golden* integer inference of any `netspec` network
   (pure jnp via ref oracles, vectorized, fast). It fixes per-layer shifts and
   produces the golden activations/logits the Rust functional runtime must
   reproduce bit-exactly.

Numeric semantics are identical between the two paths and with Rust by
construction (everything funnels through `qnn.py` / the contract in
DESIGN.md §4).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from . import netspec, qnn
from .kernels import ancillary, dw_conv, imc_mvm, ref

XBAR = imc_mvm.XBAR_ROWS


# --------------------------------------------------------------------------
# Synthetic quantized weights (seeded — the paper's evaluation is perf/energy,
# not accuracy; see DESIGN.md §3).
# --------------------------------------------------------------------------


def synth_weights(layers: List[netspec.Layer], seed: int) -> Dict[int, np.ndarray]:
    """Deterministic int4 weights per layer, in the serialized layout."""
    rng = np.random.default_rng(seed)
    out = {}
    for idx, l in enumerate(layers):
        if l.n_weights == 0:
            continue
        w = rng.integers(qnn.INT4_MIN, qnn.INT4_MAX + 1, size=l.weight_shape)
        out[idx] = w.astype(np.int8)
    return out


def synth_input(layer0: netspec.Layer, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed + 0x5EED)
    return rng.integers(-128, 128, size=(layer0.hin, layer0.win, layer0.cin)).astype(
        np.int8
    )


# --------------------------------------------------------------------------
# Golden inference (pure jnp, also selects per-layer shifts).
# --------------------------------------------------------------------------


def _auto_shift(acc: jnp.ndarray) -> int:
    """Smallest shift that keeps |round_shift(acc, s)| within int8.

    Guarantees the golden path never clips (except rounding at the boundary),
    so ADC-in-artifact vs raw+digital-requant are interchangeable.
    """
    maxabs = int(jnp.max(jnp.abs(acc)))
    s = 0
    while ((maxabs + ((1 << s) >> 1)) >> s) > qnn.INT8_MAX:
        s += 1
    return s


def run_network(
    layers: List[netspec.Layer],
    weights: Dict[int, np.ndarray],
    x: np.ndarray,
    shifts: Optional[List[int]] = None,
) -> Tuple[np.ndarray, List[int], List[int]]:
    """Integer inference. Returns (logits_i32, per-layer shifts, checksums).

    When ``shifts`` is None they are chosen per layer (_auto_shift) and
    returned for the manifest; pass them back in to re-run deterministically.
    """
    acts: List[jnp.ndarray] = []  # per-layer int8 outputs (for residuals)
    cur = jnp.asarray(x, jnp.int8)
    out_shifts: List[int] = []
    checksums: List[int] = []
    logits = None

    for idx, l in enumerate(layers):
        if l.kind == "conv":
            w = jnp.asarray(weights[idx])
            cols = ref.im2col(cur, k=l.k, stride=l.stride, pad=l.pad)
            acc = cols.astype(jnp.int32) @ w.astype(jnp.int32)
            s = shifts[idx] if shifts is not None else _auto_shift(acc)
            y = qnn.requantize(acc, s, int(l.relu)).reshape(l.hout, l.wout, l.cout)
        elif l.kind == "dw":
            w = jnp.asarray(weights[idx])
            xp = jnp.pad(cur, ((l.pad, l.pad), (l.pad, l.pad), (0, 0)))
            xi = xp.astype(jnp.int32)
            wi = w.astype(jnp.int32)
            acc = jnp.zeros((l.hout, l.wout, l.cout), jnp.int32)
            for ki in range(3):
                for kj in range(3):
                    sl = xi[
                        ki : ki + (l.hout - 1) * l.stride + 1 : l.stride,
                        kj : kj + (l.wout - 1) * l.stride + 1 : l.stride,
                        :,
                    ]
                    acc = acc + sl * wi[ki, kj][None, None, :]
            s = shifts[idx] if shifts is not None else _auto_shift(acc)
            y = qnn.requantize(acc, s, int(l.relu))
        elif l.kind == "add":
            src = acts[l.residual_from]
            s = 0
            y = qnn.saturating_add_i8(cur, src)
        elif l.kind == "pool":
            s = 0
            y = ref.avgpool_ref(cur)[None, None, :]
        elif l.kind == "fc":
            w = jnp.asarray(weights[idx])
            acc = cur.reshape(1, -1).astype(jnp.int32) @ w.astype(jnp.int32)
            s = 0  # logits stay int32
            logits = acc.reshape(-1)
            y = logits  # terminal
        else:
            raise ValueError(l.kind)

        out_shifts.append(s)
        checksums.append(qnn.checksum_i64(y))
        if l.kind != "fc":
            acts.append(y)
            cur = y

    assert logits is not None, "network must end with an fc layer"
    return np.asarray(logits), out_shifts, checksums


# --------------------------------------------------------------------------
# Fused case-study Bottleneck built from the Pallas kernels (the artifact).
# --------------------------------------------------------------------------


def bottleneck_fused(x, w_exp, w_dw, w_proj, shifts):
    """The paper's Bottleneck as crossbar jobs + DW engine tiles + residual.

    x       [16, 16, 128] i8
    w_exp   [128, 768]    i8   (pw expand,  IMA: 1 row tile x 3 col tiles)
    w_dw    [3, 3, 768]   i8   (depth-wise, digital accelerator: 48 blocks)
    w_proj  [768, 128]    i8   (pw project, IMA: 3 row tiles, digital accum)
    shifts  [3]           i32
    returns [16, 16, 128] i8 (with the residual connection applied)
    """
    hw = netspec.BOTTLENECK_HW
    cc = netspec.BOTTLENECK_C
    hid = netspec.BOTTLENECK_HID
    px = hw * hw

    one = jnp.ones((1,), jnp.int32)
    zero = jnp.zeros((1,), jnp.int32)

    # pw expand on the IMA: rows = 128 <= 256 -> ADC-fused jobs.
    x2d = x.reshape(px, cc)
    h1 = imc_mvm.mvm_tiled(x2d, w_exp, shifts[0:1], one)
    h1 = h1.reshape(hw, hw, hid)

    # depth-wise on the digital accelerator (HWC in/out, no marshaling).
    h1p = jnp.pad(h1, ((1, 1), (1, 1), (0, 0)))
    h2 = dw_conv.dw3x3_layer(h1p, w_dw, shifts[1:2], one, stride=1)

    # pw project: rows = 768 -> 3 row tiles of raw partials + digital requant.
    h2d = h2.reshape(px, hid)
    n_row_tiles = hid // XBAR
    acc = jnp.zeros((px, cc), jnp.int32)
    for rt in range(n_row_tiles):
        xt = h2d[:, rt * XBAR : (rt + 1) * XBAR]
        wt = w_proj[rt * XBAR : (rt + 1) * XBAR, :]
        wt = jnp.pad(wt, ((0, 0), (0, XBAR - cc)))
        # issue the raw jobs in 16-pixel chunks like the coordinator does
        for pc in range(px // imc_mvm.PIXELS_PER_CALL):
            lo = pc * imc_mvm.PIXELS_PER_CALL
            hi = lo + imc_mvm.PIXELS_PER_CALL
            part = imc_mvm.imc_mvm_raw(xt[lo:hi], wt)
            acc = acc.at[lo:hi].add(part[:, :cc])
    y = qnn.requantize(acc, shifts[2], zero[0])

    # residual on the cores.
    flat_y = y.reshape(-1)
    flat_x = x.reshape(-1)
    out = jnp.zeros_like(flat_y)
    chunk = ancillary.RESIDUAL_CHUNK
    for c0 in range(0, flat_y.size, chunk):
        out = out.at[c0 : c0 + chunk].set(
            ancillary.residual_add(flat_y[c0 : c0 + chunk], flat_x[c0 : c0 + chunk])
        )
    return out.reshape(hw, hw, cc)


def bottleneck_ref(x, w_exp, w_dw, w_proj, shifts):
    """Oracle for `bottleneck_fused` (pure jnp)."""
    hw, cc, hid = netspec.BOTTLENECK_HW, netspec.BOTTLENECK_C, netspec.BOTTLENECK_HID
    x = jnp.asarray(x)
    x2d = x.reshape(hw * hw, cc)
    h1 = ref.imc_mvm_ref(x2d, jnp.asarray(w_exp), int(shifts[0]), 1)
    h1 = h1.reshape(hw, hw, hid)
    h1p = jnp.pad(h1, ((1, 1), (1, 1), (0, 0)))
    h2 = ref.dw3x3_ref(h1p, jnp.asarray(w_dw), int(shifts[1]), 1, stride=1)
    acc = h2.reshape(hw * hw, hid).astype(jnp.int32) @ jnp.asarray(w_proj).astype(
        jnp.int32
    )
    y = qnn.requantize(acc, int(shifts[2]), 0).reshape(hw, hw, cc)
    return qnn.saturating_add_i8(y, x)
