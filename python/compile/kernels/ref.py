"""Pure-jnp oracles for every L1 kernel — the correctness ground truth.

No Pallas here: these are straight-line jnp implementations of the numeric
contract (qnn.py). pytest/hypothesis sweeps assert the Pallas kernels match
these bit-exactly under interpret mode.
"""

from __future__ import annotations

import jax.numpy as jnp

from .. import qnn


def imc_mvm_ref(x, w, shift, relu):
    """x [P,R] i8, w [R,C] i8 -> i8 [P,C]; ADC requant fused."""
    acc = x.astype(jnp.int32) @ w.astype(jnp.int32)
    return qnn.requantize(acc, shift, relu)


def imc_mvm_raw_ref(x, w):
    """x [P,R] i8, w [R,C] i8 -> i32 [P,C] raw partials."""
    return x.astype(jnp.int32) @ w.astype(jnp.int32)


def requant_ref(acc, shift, relu):
    return qnn.requantize(acc, shift, relu)


def residual_ref(a, b):
    return qnn.saturating_add_i8(a, b)


def dw3x3_ref(x, w, shift, relu, *, stride=1):
    """Depth-wise 3x3 over an HWC tensor.

    x [Hin, Win, C] i8 (already padded), w [3, 3, C] i8.
    Output [ (Hin-3)//stride + 1, (Win-3)//stride + 1, C ] i8.
    """
    hin, win, c = x.shape
    hout = (hin - 3) // stride + 1
    wout = (win - 3) // stride + 1
    xi = x.astype(jnp.int32)
    wi = w.astype(jnp.int32)
    acc = jnp.zeros((hout, wout, c), jnp.int32)
    for ki in range(3):
        for kj in range(3):
            sl = xi[
                ki : ki + (hout - 1) * stride + 1 : stride,
                kj : kj + (wout - 1) * stride + 1 : stride,
                :,
            ]
            acc = acc + sl * wi[ki, kj][None, None, :]
    return qnn.requantize(acc, shift, relu)


def conv2d_ref(x, w, shift, relu, *, k, stride, pad):
    """Standard conv via explicit im2col (the streamer's "virtual IM2COL").

    x [H, W, Cin] i8; w [k*k*Cin, Cout] i8 in crossbar layout, i.e. row index
    r = (ki*k + kj)*Cin + ci (must match `rust/src/runtime/functional.rs`).
    """
    cols = im2col(x, k=k, stride=stride, pad=pad)  # [Npx, k*k*Cin]
    acc = cols.astype(jnp.int32) @ w.astype(jnp.int32)
    h, wdt, cin = x.shape
    hout = (h + 2 * pad - k) // stride + 1
    wout = (wdt + 2 * pad - k) // stride + 1
    y = qnn.requantize(acc, shift, relu)
    return y.reshape(hout, wout, -1)


def im2col(x, *, k, stride, pad):
    """HWC im2col with the crossbar row ordering r = (ki*k + kj)*Cin + ci."""
    h, w, cin = x.shape
    xp = jnp.pad(x, ((pad, pad), (pad, pad), (0, 0)))
    hout = (h + 2 * pad - k) // stride + 1
    wout = (w + 2 * pad - k) // stride + 1
    patches = []
    for ki in range(k):
        for kj in range(k):
            sl = xp[
                ki : ki + (hout - 1) * stride + 1 : stride,
                kj : kj + (wout - 1) * stride + 1 : stride,
                :,
            ]
            patches.append(sl.reshape(hout * wout, cin))
    return jnp.concatenate(patches, axis=1)


def avgpool_ref(x):
    """Global average pool, integer semantics shared with Rust:
    q = floor((sum + area//2) / area), clipped to int8."""
    h, w, c = x.shape
    area = h * w
    s = x.astype(jnp.int32).sum(axis=(0, 1)) + area // 2
    q = jnp.floor_divide(s, area)
    return jnp.clip(q, qnn.INT8_MIN, qnn.INT8_MAX).astype(jnp.int8)
