"""L1 Pallas kernels: the cluster cores' ancillary operations.

The paper offloads MVMs to the IMA and depth-wise layers to the digital
accelerator; the 8 RISC-V cores keep the "glue" compute: requantization of
digitally-accumulated partials (row-split layers) and residual connections.
These are small, bandwidth-bound kernels; they exist as artifacts so the Rust
request path never computes tensor math outside PJRT executables.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import qnn

RESIDUAL_CHUNK = 4096
REQUANT_ROWS = 16
REQUANT_COLS = 256


def _requant_kernel(acc_ref, shift_ref, relu_ref, y_ref):
    y_ref[...] = qnn.requantize(acc_ref[...], shift_ref[0], relu_ref[0])


@jax.jit
def requant(acc, shift, relu):
    """Digital requantization of summed int32 partials.

    acc [P, 256] i32 (P = 16 or 128 for the batched variant),
    shift/relu [1] i32 -> y [P, 256] i8.
    """
    return pl.pallas_call(
        _requant_kernel,
        out_shape=jax.ShapeDtypeStruct(acc.shape, jnp.int8),
        interpret=True,
    )(acc, shift, relu)


def _residual_kernel(a_ref, b_ref, y_ref):
    y_ref[...] = qnn.saturating_add_i8(a_ref[...], b_ref[...])


@jax.jit
def residual_add(a, b):
    """int8 saturating residual add over a fixed 4096-element chunk."""
    return pl.pallas_call(
        _residual_kernel,
        out_shape=jax.ShapeDtypeStruct((RESIDUAL_CHUNK,), jnp.int8),
        interpret=True,
    )(a, b)
