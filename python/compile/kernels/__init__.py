# L1: Pallas kernels (imc_mvm, dw_conv, ancillary) + pure-jnp oracles (ref).
