"""L1 Pallas kernel: the depth-wise digital accelerator datapath.

Models the paper's weight-stationary 3x3 depth-wise engine (Fig. 4/5): a
16-channel block is processed over a spatial tile with the window buffer
sliding vertically; the MAC network accumulates in int32 and the ancillary
blocks (ReLU, shift & clip) bring the result back to int8. Data is HWC, the
same layout the IMA uses — no marshaling between engines.

The engine's native granularity becomes the Pallas block:
  * stride 1: x [18, 18, 16] i8 (16x16 outputs + 1-pixel halo), w [3, 3, 16];
  * stride 2: x [33, 33, 16] i8 (16x16 outputs, halo included);
  * y [16, 16, 16] i8.

The Rust coordinator tiles any layer spatially/channel-wise onto these fixed
tiles with zero padding (`rust/src/runtime/functional.rs`), mirroring how the
hardware streams 16-channel blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import qnn

TILE = 16  # output tile side
CH_BLOCK = 16  # channels per engine block
K = 3


def _dw_kernel(stride, x_ref, w_ref, shift_ref, relu_ref, y_ref):
    x = x_ref[...].astype(jnp.int32)  # [Hin, Win, 16]
    w = w_ref[...].astype(jnp.int32)  # [3, 3, 16]
    acc = jnp.zeros((TILE, TILE, CH_BLOCK), jnp.int32)
    # The 3x3 window as 9 shifted HW slices — the window-buffer dataflow
    # (LD/MAC/ST) collapses to 9 strided MACs per output tile.
    for ki in range(K):
        for kj in range(K):
            sl = jax.lax.slice(
                x,
                (ki, kj, 0),
                (ki + (TILE - 1) * stride + 1, kj + (TILE - 1) * stride + 1, CH_BLOCK),
                (stride, stride, 1),
            )
            acc = acc + sl * w[ki, kj][None, None, :]
    y_ref[...] = qnn.requantize(acc, shift_ref[0], relu_ref[0])


@functools.partial(jax.jit, static_argnames=("stride",))
def dw3x3_tile(x, w, shift, relu, *, stride=1):
    """One depth-wise engine tile. ``x`` [(TILE-1)*stride + 3]^2 x 16 i8,
    ``w`` [3,3,16] i8, shift/relu [1] i32 -> y [16,16,16] i8."""
    hin = (TILE - 1) * stride + K
    assert x.shape == (hin, hin, CH_BLOCK), x.shape
    return pl.pallas_call(
        functools.partial(_dw_kernel, stride),
        out_shape=jax.ShapeDtypeStruct((TILE, TILE, CH_BLOCK), jnp.int8),
        interpret=True,
    )(x, w, shift, relu)


def dw3x3_layer(x, w, shift, relu, *, stride=1):
    """A whole depth-wise layer as a grid of engine tiles (used by the fused
    Bottleneck artifact). ``x`` [H+2, W+2, C] i8 pre-padded, ``w`` [3,3,C].

    C must be a multiple of 16 and the output spatial dims multiples of 16 —
    the general (ragged) case is handled host-side by the Rust coordinator.
    """
    hp, wp, c = x.shape
    hout = (hp - K) // stride + 1
    wout = (wp - K) // stride + 1
    assert c % CH_BLOCK == 0 and hout % TILE == 0 and wout % TILE == 0
    hin_t = (TILE - 1) * stride + K

    grid = (hout // TILE, wout // TILE, c // CH_BLOCK)

    def x_index(i, j, b):
        # Element offsets: overlapping halo tiles. BlockSpec indices are in
        # units of the block shape, so express via pl.BlockSpec with
        # element-indexed mapping through a gather-free slice: use
        # `pl.BlockSpec(block_shape, index_map)` where index_map returns
        # block indices — overlapping windows need unit "blocks", so instead
        # we pass the whole array and slice inside the kernel.
        raise NotImplementedError

    # Overlapping (halo) blocks cannot be expressed as disjoint BlockSpecs;
    # keep x whole in the kernel and slice per grid step.
    def kernel(x_ref, w_ref, shift_ref, relu_ref, y_ref):
        i = pl.program_id(0)
        j = pl.program_id(1)
        xt = jax.lax.dynamic_slice(
            x_ref[...],
            (i * TILE * stride, j * TILE * stride, 0),
            (hin_t, hin_t, CH_BLOCK),
        ).astype(jnp.int32)
        w_ = w_ref[...].astype(jnp.int32)
        acc = jnp.zeros((TILE, TILE, CH_BLOCK), jnp.int32)
        for ki in range(K):
            for kj in range(K):
                sl = jax.lax.slice(
                    xt,
                    (ki, kj, 0),
                    (
                        ki + (TILE - 1) * stride + 1,
                        kj + (TILE - 1) * stride + 1,
                        CH_BLOCK,
                    ),
                    (stride, stride, 1),
                )
                acc = acc + sl * w_[ki, kj][None, None, :]
        y_ref[...] = qnn.requantize(acc, shift_ref[0], relu_ref[0])

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((hp, wp, CH_BLOCK), lambda i, j, b: (0, 0, b)),
            pl.BlockSpec((K, K, CH_BLOCK), lambda i, j, b: (0, 0, b)),
            pl.BlockSpec((1,), lambda i, j, b: (0,)),
            pl.BlockSpec((1,), lambda i, j, b: (0,)),
        ],
        out_specs=pl.BlockSpec((TILE, TILE, CH_BLOCK), lambda i, j, b: (i, j, b)),
        out_shape=jax.ShapeDtypeStruct((hout, wout, c), jnp.int8),
        interpret=True,
    )(x, w, shift, relu)
