"""L1 Pallas kernel: the PCM crossbar matrix-vector-multiply job.

One *job* on the paper's IMA computes, for a batch of output pixels, the dot
product of an (up to) 256-element int8 input slice against a 256x256 crossbar
of int4 conductances, with the bit-line ADCs performing the requantization to
int8 (HERMES core, Khaddam-Aljameh et al. 2021). Here the job is a Pallas
block:

  * ``x``   [P, 256]  int8 — P = `PIXELS_PER_CALL` output pixels' im2col rows
                        (the HWPE streamer's "virtual IM2COL");
  * ``w``   [256, 256] int8 in [-8, 7] — the programmed crossbar;
  * ``acc`` analog bit-line integration, modeled as an exact int32 dot
            (a Gaussian conductance-noise study perturbs ``w`` host-side);
  * ``y``   [P, 256] int8 — ADC output: round-shift, optional ReLU, clip.

Hardware adaptation (DESIGN.md §2): the 256-wide crossbar job is shaped for
the MXU — a single [16,256]x[256,256] int8 dot with a fused epilogue; the
BlockSpec HBM->VMEM staging plays the role of the TCDM->DAC-buffer streamer.
VMEM footprint per job ~= 90 kB. ``interpret=True`` everywhere: the CPU PJRT
plugin cannot execute Mosaic custom calls.

Two variants:
  * ``imc_mvm``      — ADC inside (single-row-tile layers);
  * ``imc_mvm_raw``  — int32 partials out (row-split layers accumulate
                        digitally on the cluster cores, see DESIGN.md §4).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import qnn

XBAR_ROWS = 256
XBAR_COLS = 256
PIXELS_PER_CALL = 16


def _bitline_dot(x_i8, w_i8):
    """The analog bit-line integration: one 256-deep dot per (pixel, column).

    Carried in f32 — bit-exact, because every partial sum is bounded by
    256 · 127 · 8 = 260,096 < 2²⁴ (f32 integers are exact below 2²⁴), and it
    maps on the fast XLA GEMM path instead of the slow integer dot
    (EXPERIMENTS.md §Perf, L1 iteration 1). On a real TPU the same dot maps
    on the MXU at int8/bf16 rate.
    """
    acc = jax.lax.dot_general(
        x_i8.astype(jnp.float32),
        w_i8.astype(jnp.float32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return acc.astype(jnp.int32)


def _mvm_kernel(x_ref, w_ref, shift_ref, relu_ref, y_ref):
    """Crossbar job with the ADC epilogue fused in."""
    acc = _bitline_dot(x_ref[...], w_ref[...])
    y_ref[...] = qnn.requantize(acc, shift_ref[0], relu_ref[0])


def _mvm_raw_kernel(x_ref, w_ref, acc_ref):
    """Crossbar job in raw-partial mode (int32 out, no ADC quantization)."""
    acc_ref[...] = _bitline_dot(x_ref[...], w_ref[...])


@functools.partial(jax.jit, static_argnames=("pixels",))
def imc_mvm(x, w, shift, relu, *, pixels=PIXELS_PER_CALL):
    """ADC-quantizing crossbar job. Shapes: x [P,256] i8, w [256,256] i8,
    shift/relu [1] i32 -> y [P,256] i8."""
    return pl.pallas_call(
        _mvm_kernel,
        out_shape=jax.ShapeDtypeStruct((pixels, XBAR_COLS), jnp.int8),
        interpret=True,
    )(x, w, shift, relu)


@functools.partial(jax.jit, static_argnames=("pixels",))
def imc_mvm_raw(x, w, *, pixels=PIXELS_PER_CALL):
    """Raw-partial crossbar job. x [P,256] i8, w [256,256] i8 -> acc [P,256] i32."""
    return pl.pallas_call(
        _mvm_raw_kernel,
        out_shape=jax.ShapeDtypeStruct((pixels, XBAR_COLS), jnp.int32),
        interpret=True,
    )(x, w)


def mvm_tiled(x2d, w2d, shift, relu, *, col_tile=XBAR_COLS):
    """A whole linear layer as a grid of crossbar jobs (used by the fused
    Bottleneck artifact, L2). ``x2d`` [P, R<=256] i8, ``w2d`` [R, C] i8.

    Rows are padded to 256 (zero devices contribute no current); columns are
    split over ``ceil(C / 256)`` crossbar column tiles via the Pallas grid —
    exactly the job stream the Rust coordinator issues.
    """
    p, r = x2d.shape
    rw, c = w2d.shape
    assert r == rw and r <= XBAR_ROWS, (r, rw)
    x_pad = jnp.pad(x2d, ((0, 0), (0, XBAR_ROWS - r)))
    n_col_tiles = -(-c // col_tile)
    w_pad = jnp.pad(w2d, ((0, XBAR_ROWS - r), (0, n_col_tiles * col_tile - c)))

    grid = (n_col_tiles,)
    y = pl.pallas_call(
        _mvm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((p, XBAR_ROWS), lambda j: (0, 0)),
            pl.BlockSpec((XBAR_ROWS, col_tile), lambda j: (0, j)),
            pl.BlockSpec((1,), lambda j: (0,)),
            pl.BlockSpec((1,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((p, col_tile), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((p, n_col_tiles * col_tile), jnp.int8),
        interpret=True,
    )(x_pad, w_pad, shift, relu)
    return y[:, :c]
