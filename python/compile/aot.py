"""AOT compile path: lower every kernel/model to HLO *text* artifacts.

Python runs ONCE (``make artifacts``); the Rust coordinator is self-contained
afterwards. Interchange format is HLO text, NOT ``.serialize()`` — jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's xla_extension
0.5.1 rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Outputs (under ``artifacts/``):
  * ``*.hlo.txt``            — one per PJRT executable (see ARTIFACTS below);
  * ``weights.bin``          — concatenated per-layer int8/int4 weights;
  * ``manifest.json``        — full MobileNetV2 layer list + shifts + golden
                               checksums + weight offsets (single source of
                               truth replayed by Rust);
  * ``manifest_tiny.json`` / ``weights_tiny.bin`` — scaled-down net for fast
                               integration tests;
  * ``golden/*.bin``         — golden inputs/outputs (bottleneck I/O, logits).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, netspec, qnn
from .kernels import ancillary, dw_conv, imc_mvm

SEED = 20220717  # arXiv date of the paper's final version; fully arbitrary


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


I8, I32 = jnp.int8, jnp.int32
P = imc_mvm.PIXELS_PER_CALL
XB = imc_mvm.XBAR_ROWS
T = dw_conv.TILE
CB = dw_conv.CH_BLOCK


def artifact_specs():
    """name -> (fn, example_arg_specs). Shapes are the runtime ABI."""
    return {
        "imc_mvm": (
            lambda x, w, s, r: (imc_mvm.imc_mvm(x, w, s, r),),
            [
                _spec((P, XB), I8),
                _spec((XB, XB), I8),
                _spec((1,), I32),
                _spec((1,), I32),
            ],
        ),
        "imc_mvm_raw": (
            lambda x, w: (imc_mvm.imc_mvm_raw(x, w),),
            [_spec((P, XB), I8), _spec((XB, XB), I8)],
        ),
        # 128-pixel batched variants: same jobs, amortized per-call overhead
        # for large layers (EXPERIMENTS.md §Perf, L3 iteration 2)
        "imc_mvm_b128": (
            lambda x, w, s, r: (imc_mvm.imc_mvm(x, w, s, r, pixels=8 * P),),
            [
                _spec((8 * P, XB), I8),
                _spec((XB, XB), I8),
                _spec((1,), I32),
                _spec((1,), I32),
            ],
        ),
        "imc_mvm_raw_b128": (
            lambda x, w: (imc_mvm.imc_mvm_raw(x, w, pixels=8 * P),),
            [_spec((8 * P, XB), I8), _spec((XB, XB), I8)],
        ),
        "requant": (
            lambda a, s, r: (ancillary.requant(a, s, r),),
            [_spec((P, XB), I32), _spec((1,), I32), _spec((1,), I32)],
        ),
        "requant_b128": (
            lambda a, s, r: (ancillary.requant(a, s, r),),
            [_spec((8 * P, XB), I32), _spec((1,), I32), _spec((1,), I32)],
        ),
        "residual": (
            lambda a, b: (ancillary.residual_add(a, b),),
            [
                _spec((ancillary.RESIDUAL_CHUNK,), I8),
                _spec((ancillary.RESIDUAL_CHUNK,), I8),
            ],
        ),
        "dw3x3_s1": (
            lambda x, w, s, r: (dw_conv.dw3x3_tile(x, w, s, r, stride=1),),
            [
                _spec((T + 2, T + 2, CB), I8),
                _spec((3, 3, CB), I8),
                _spec((1,), I32),
                _spec((1,), I32),
            ],
        ),
        "dw3x3_s2": (
            lambda x, w, s, r: (dw_conv.dw3x3_tile(x, w, s, r, stride=2),),
            [
                _spec((2 * T + 1, 2 * T + 1, CB), I8),
                _spec((3, 3, CB), I8),
                _spec((1,), I32),
                _spec((1,), I32),
            ],
        ),
        "bottleneck": (
            lambda x, w1, wd, w2, s: (model.bottleneck_fused(x, w1, wd, w2, s),),
            [
                _spec((16, 16, netspec.BOTTLENECK_C), I8),
                _spec((netspec.BOTTLENECK_C, netspec.BOTTLENECK_HID), I8),
                _spec((3, 3, netspec.BOTTLENECK_HID), I8),
                _spec((netspec.BOTTLENECK_HID, netspec.BOTTLENECK_C), I8),
                _spec((3,), I32),
            ],
        ),
    }


def emit_artifacts(outdir: str) -> None:
    specs = artifact_specs()
    for name, (fn, args) in specs.items():
        t0 = time.time()
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"  {name}.hlo.txt  ({len(text) / 1024:.0f} kB, {time.time() - t0:.1f}s)")


def build_golden(outdir: str, layers, tag: str, weights_name: str, manifest_name: str):
    """Synthesize weights, run golden inference, write manifest + binaries."""
    weights = model.synth_weights(layers, SEED)
    x = model.synth_input(layers[0], SEED)

    logits, shifts, checksums = model.run_network(layers, weights, x)

    # serialize weights and fill layer records
    blobs = []
    offset = 0
    for idx, l in enumerate(layers):
        l.shift = shifts[idx]
        l.out_checksum = checksums[idx]
        if idx in weights:
            raw = weights[idx].tobytes()
            l.weight_offset = offset
            l.weight_len = len(raw)
            offset += len(raw)
            blobs.append(raw)
    with open(os.path.join(outdir, weights_name), "wb") as f:
        f.write(b"".join(blobs))

    gold = os.path.join(outdir, "golden")
    os.makedirs(gold, exist_ok=True)
    x.tofile(os.path.join(gold, f"{tag}_input.bin"))
    logits.astype(np.int32).tofile(os.path.join(gold, f"{tag}_logits.bin"))

    manifest = {
        "version": 1,
        "seed": SEED,
        "network": tag,
        "input": {
            "shape": [layers[0].hin, layers[0].win, layers[0].cin],
            "file": f"golden/{tag}_input.bin",
        },
        "logits": {
            "file": f"golden/{tag}_logits.bin",
            "len": int(logits.size),
            "argmax": int(np.argmax(logits)),
            "checksum": qnn.checksum_i64(logits),
        },
        "weights_file": weights_name,
        "total_macs": netspec.total_macs(layers),
        "layers": netspec.to_manifest_dict(layers),
    }
    with open(os.path.join(outdir, manifest_name), "w") as f:
        json.dump(manifest, f, indent=1)
    print(
        f"  {manifest_name}: {len(layers)} layers, "
        f"{netspec.total_macs(layers) / 1e6:.1f} MMAC, argmax={np.argmax(logits)}"
    )


def build_bottleneck_golden(outdir: str):
    """Golden I/O for the fused bottleneck artifact (bit-exact vs ref)."""
    rng = np.random.default_rng(SEED + 7)
    cc, hid = netspec.BOTTLENECK_C, netspec.BOTTLENECK_HID
    x = rng.integers(-128, 128, size=(16, 16, cc)).astype(np.int8)
    w1 = rng.integers(-8, 8, size=(cc, hid)).astype(np.int8)
    wd = rng.integers(-8, 8, size=(3, 3, hid)).astype(np.int8)
    w2 = rng.integers(-8, 8, size=(hid, cc)).astype(np.int8)
    # representative shifts (expand/dw/proj) — chosen like _auto_shift would
    shifts = np.array([9, 9, 10], dtype=np.int32)
    y = np.asarray(model.bottleneck_ref(x, w1, wd, w2, shifts))

    gold = os.path.join(outdir, "golden")
    os.makedirs(gold, exist_ok=True)
    x.tofile(os.path.join(gold, "bottleneck_x.bin"))
    w1.tofile(os.path.join(gold, "bottleneck_w1.bin"))
    wd.tofile(os.path.join(gold, "bottleneck_wd.bin"))
    w2.tofile(os.path.join(gold, "bottleneck_w2.bin"))
    shifts.tofile(os.path.join(gold, "bottleneck_shifts.bin"))
    y.tofile(os.path.join(gold, "bottleneck_y.bin"))
    print(f"  bottleneck golden: checksum={qnn.checksum_i64(y)}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument(
        "--skip-mnv2",
        action="store_true",
        help="skip the full-size MobileNetV2 golden (slowest step)",
    )
    args = ap.parse_args()
    outdir = args.out
    os.makedirs(outdir, exist_ok=True)

    print("[aot] lowering kernels to HLO text")
    emit_artifacts(outdir)
    print("[aot] golden: fused bottleneck")
    build_bottleneck_golden(outdir)
    print("[aot] golden: tiny network")
    build_golden(
        outdir, netspec.tiny_mobilenet(), "tiny", "weights_tiny.bin", "manifest_tiny.json"
    )
    if not args.skip_mnv2:
        print("[aot] golden: MobileNetV2 224x224 (full)")
        build_golden(
            outdir, netspec.mobilenet_v2(), "mnv2", "weights.bin", "manifest.json"
        )
    print("[aot] done")


if __name__ == "__main__":
    main()
