"""Network specification — the single source of truth for layer shapes.

`aot.py` serializes this spec into `artifacts/manifest.json`; the Rust
coordinator replays inference from the manifest, so Python and Rust can never
disagree on shapes/strides/shifts. `rust/src/net/mobilenetv2.rs` builds the
same network independently for the *timing* model and an integration test
cross-checks the two.

Layer kinds:
  * ``conv``  — standard KxK convolution, mapped on the IMA via virtual
                im2col (rows = K*K*Cin, cols = Cout);
  * ``dw``    — 3x3 depth-wise, mapped on the dedicated digital accelerator;
  * ``add``   — int8 saturating residual add with the output of a previous
                layer (``residual_from``);
  * ``pool``  — global average pool (cores);
  * ``fc``    — fully connected (IMA, rows = Cin, cols = Cout).

Point-wise convolutions are ``conv`` with k=1.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional


@dataclasses.dataclass
class Layer:
    name: str
    kind: str  # conv | dw | add | pool | fc
    hin: int
    win: int
    cin: int
    cout: int
    k: int = 1
    stride: int = 1
    pad: int = 0
    relu: bool = False
    residual_from: Optional[int] = None  # layer index whose output is added
    # Filled during golden generation:
    shift: int = 0
    weight_offset: int = 0
    weight_len: int = 0
    out_checksum: int = 0

    @property
    def hout(self) -> int:
        if self.kind in ("add",):
            return self.hin
        if self.kind in ("pool", "fc"):
            return 1
        return (self.hin + 2 * self.pad - self.k) // self.stride + 1

    @property
    def wout(self) -> int:
        if self.kind in ("add",):
            return self.win
        if self.kind in ("pool", "fc"):
            return 1
        return (self.win + 2 * self.pad - self.k) // self.stride + 1

    @property
    def weight_shape(self):
        """Weight tensor shape in the serialized layout."""
        if self.kind in ("conv", "fc"):
            return (self.k * self.k * self.cin, self.cout)  # crossbar layout
        if self.kind == "dw":
            return (3, 3, self.cin)
        return ()

    @property
    def n_weights(self) -> int:
        s = self.weight_shape
        n = 1
        for d in s:
            n *= d
        return n if s else 0

    @property
    def macs(self) -> int:
        if self.kind in ("conv", "fc"):
            return self.hout * self.wout * self.k * self.k * self.cin * self.cout
        if self.kind == "dw":
            return self.hout * self.wout * 9 * self.cout
        return 0


# MobileNetV2 inverted-residual settings (t = expansion, c = out channels,
# n = repeats, s = first-block stride), Sandler et al. 2018, width 1.0.
MNV2_BLOCKS = [
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
]


def mobilenet_v2(resolution: int = 224, width: float = 1.0) -> List[Layer]:
    """Full MobileNetV2 as a flat layer list with explicit residual edges."""

    def c(ch: int) -> int:
        scaled = int(round(ch * width / 8.0) * 8)
        return max(8, scaled) if width != 1.0 else ch

    layers: List[Layer] = []
    h = w = resolution
    cin = 3

    def out_idx() -> int:
        return len(layers) - 1

    # conv1: 3x3 s2
    layers.append(
        Layer("conv1", "conv", h, w, cin, c(32), k=3, stride=2, pad=1, relu=True)
    )
    h, w, cin = layers[-1].hout, layers[-1].wout, c(32)

    for bi, (t, ch, n, s) in enumerate(MNV2_BLOCKS):
        cout = c(ch)
        for i in range(n):
            stride = s if i == 0 else 1
            prefix = f"bneck{bi + 1}_{i}"
            block_in_idx = out_idx()
            hid = cin * t
            if t != 1:
                layers.append(
                    Layer(f"{prefix}_exp", "conv", h, w, cin, hid, k=1, relu=True)
                )
            layers.append(
                Layer(
                    f"{prefix}_dw",
                    "dw",
                    h,
                    w,
                    hid,
                    hid,
                    k=3,
                    stride=stride,
                    pad=1,
                    relu=True,
                )
            )
            h, w = layers[-1].hout, layers[-1].wout
            layers.append(Layer(f"{prefix}_proj", "conv", h, w, hid, cout, k=1))
            if stride == 1 and cin == cout:
                layers.append(
                    Layer(
                        f"{prefix}_add",
                        "add",
                        h,
                        w,
                        cout,
                        cout,
                        residual_from=block_in_idx,
                    )
                )
            cin = cout

    layers.append(Layer("conv_last", "conv", h, w, cin, c(1280), k=1, relu=True))
    cin = c(1280)
    layers.append(Layer("pool", "pool", h, w, cin, cin))
    layers.append(Layer("fc", "fc", 1, 1, cin, 1000))
    return layers


# Case-study Bottleneck (paper Fig. 8 reconstruction, DESIGN.md §5):
# Cin = Cout = 128, expansion 6 (hidden 768), 16x16, stride 1, residual.
BOTTLENECK_C = 128
BOTTLENECK_HID = 768
BOTTLENECK_HW = 16


def bottleneck_case_study() -> List[Layer]:
    hw, cc, hid = BOTTLENECK_HW, BOTTLENECK_C, BOTTLENECK_HID
    return [
        Layer("bneck_exp", "conv", hw, hw, cc, hid, k=1, relu=True),
        Layer("bneck_dw", "dw", hw, hw, hid, hid, k=3, stride=1, pad=1, relu=True),
        Layer("bneck_proj", "conv", hw, hw, hid, cc, k=1),
        Layer("bneck_add", "add", hw, hw, cc, cc, residual_from=-1),
    ]


def tiny_mobilenet(resolution: int = 32) -> List[Layer]:
    """A scaled-down MobileNetV2-style net for fast integration tests."""
    layers = [
        Layer("conv1", "conv", resolution, resolution, 3, 16, k=3, stride=2, pad=1, relu=True)
    ]
    h = layers[-1].hout
    layers.append(Layer("b1_exp", "conv", h, h, 16, 96, k=1, relu=True))
    layers.append(Layer("b1_dw", "dw", h, h, 96, 96, k=3, stride=1, pad=1, relu=True))
    layers.append(Layer("b1_proj", "conv", h, h, 96, 16, k=1))
    layers.append(Layer("b1_add", "add", h, h, 16, 16, residual_from=0))
    layers.append(Layer("b2_exp", "conv", h, h, 16, 96, k=1, relu=True))
    layers.append(
        Layer("b2_dw", "dw", h, h, 96, 96, k=3, stride=2, pad=1, relu=True)
    )
    h2 = layers[-1].hout
    layers.append(Layer("b2_proj", "conv", h2, h2, 96, 24, k=1))
    layers.append(Layer("conv_last", "conv", h2, h2, 24, 64, k=1, relu=True))
    layers.append(Layer("pool", "pool", h2, h2, 64, 64))
    layers.append(Layer("fc", "fc", 1, 1, 64, 10))
    return layers


def total_macs(layers: List[Layer]) -> int:
    return sum(l.macs for l in layers)


def to_manifest_dict(layers: List[Layer]) -> list:
    out = []
    for idx, l in enumerate(layers):
        out.append(
            {
                "id": idx,
                "name": l.name,
                "kind": l.kind,
                "hin": l.hin,
                "win": l.win,
                "cin": l.cin,
                "cout": l.cout,
                "k": l.k,
                "stride": l.stride,
                "pad": l.pad,
                "relu": int(l.relu),
                "residual_from": -1 if l.residual_from is None else l.residual_from,
                "shift": l.shift,
                "weight_offset": l.weight_offset,
                "weight_len": l.weight_len,
                "out_checksum": l.out_checksum,
                "hout": l.hout,
                "wout": l.wout,
                "macs": l.macs,
            }
        )
    return out
