"""Shared quantized-arithmetic primitives (the repo-wide numeric contract).

These functions define the bit-exact semantics shared by:
  * the Pallas kernels (L1, `kernels/`),
  * the JAX model (L2, `model.py`),
  * the Rust coordinator's functional runtime (L3, `rust/src/runtime/`).

Contract (see DESIGN.md §4):
  * activations int8, weights int4 (stored int8 in [-8, 7]), accumulators int32;
  * ADC/requant: ``y = clip(round_shift(acc, s), -128, 127)`` with
    ``round_shift(a, s) = (a + (1 << (s-1))) >> s`` for ``s > 0`` (arithmetic
    shift, round-half-up), identity at ``s = 0``;
  * optional ReLU before the clip;
  * residual connections are int8 saturating adds.

Anything that changes here must change in `rust/src/runtime/functional.rs`
and in the kernels, or the golden-vector integration tests will fail.
"""

from __future__ import annotations

import jax.numpy as jnp

INT8_MIN = -128
INT8_MAX = 127
INT4_MIN = -8
INT4_MAX = 7


def round_shift(acc, shift):
    """Round-half-up arithmetic right shift of an int32 accumulator.

    ``shift`` may be a Python int or a traced int32 scalar. ``shift == 0`` is
    the identity (no rounding term).
    """
    acc = acc.astype(jnp.int32)
    shift = jnp.asarray(shift, dtype=jnp.int32)
    rnd = jnp.where(
        shift > 0,
        jnp.left_shift(jnp.int32(1), jnp.maximum(shift - 1, 0)),
        jnp.int32(0),
    )
    return jnp.right_shift(acc + rnd, shift)


def requantize(acc, shift, relu):
    """ADC output stage: round-shift, optional ReLU, clip to int8.

    ``relu`` may be a Python bool/int or a traced int32 scalar (!= 0 = on).
    Returns int8.
    """
    y = round_shift(acc, shift)
    relu = jnp.asarray(relu, dtype=jnp.int32)
    y = jnp.where(relu != 0, jnp.maximum(y, 0), y)
    return jnp.clip(y, INT8_MIN, INT8_MAX).astype(jnp.int8)


def saturating_add_i8(a, b):
    """int8 + int8 -> int8 with saturation (the residual connection)."""
    s = a.astype(jnp.int32) + b.astype(jnp.int32)
    return jnp.clip(s, INT8_MIN, INT8_MAX).astype(jnp.int8)


def clip_int4(w):
    """Clamp weights to the signed 4-bit range the PCM devices store."""
    return jnp.clip(w, INT4_MIN, INT4_MAX).astype(jnp.int8)


def checksum_i64(x) -> int:
    """Order-independent checksum used to pinpoint layer divergence from Rust.

    Must match `rust/src/runtime/golden.rs::checksum`: sum of elements as i64
    plus 31 * element count.
    """
    import numpy as np

    arr = np.asarray(x).astype(np.int64)
    return int(arr.sum() + 31 * arr.size)
