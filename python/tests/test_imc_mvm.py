"""Pallas crossbar kernel vs pure-jnp oracle — the core L1 correctness signal."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import imc_mvm, ref

P = imc_mvm.PIXELS_PER_CALL
XB = imc_mvm.XBAR_ROWS


def _rand(rng, shape, lo, hi):
    return rng.integers(lo, hi, size=shape).astype(np.int8)


def _args(seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (P, XB), -128, 128)
    w = _rand(rng, (XB, XB), -8, 8)
    return jnp.asarray(x), jnp.asarray(w)


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("shift", [0, 4, 9, 12])
@pytest.mark.parametrize("relu", [0, 1])
def test_imc_mvm_matches_ref(seed, shift, relu):
    x, w = _args(seed)
    s = jnp.array([shift], jnp.int32)
    r = jnp.array([relu], jnp.int32)
    got = imc_mvm.imc_mvm(x, w, s, r)
    want = ref.imc_mvm_ref(x, w, shift, relu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("seed", [0, 3])
def test_imc_mvm_raw_matches_ref(seed):
    x, w = _args(seed)
    got = imc_mvm.imc_mvm_raw(x, w)
    want = ref.imc_mvm_raw_ref(x, w)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_zero_padding_rows_are_inert():
    """Rows beyond the layer's K^2*Cin must not change the output — the
    contract the Rust tiler relies on when padding to 256."""
    rng = np.random.default_rng(42)
    rows = 100
    x_small = _rand(rng, (P, rows), -128, 128)
    w_small = _rand(rng, (rows, XB), -8, 8)
    x = np.zeros((P, XB), np.int8)
    x[:, :rows] = x_small
    w = np.zeros((XB, XB), np.int8)
    w[:rows, :] = w_small
    # garbage in padded *weight* rows must be masked by zero activations
    w[rows:, :] = _rand(rng, (XB - rows, XB), -8, 8)
    s = jnp.array([7], jnp.int32)
    r = jnp.array([0], jnp.int32)
    got = imc_mvm.imc_mvm(jnp.asarray(x), jnp.asarray(w), s, r)
    want = ref.imc_mvm_ref(jnp.asarray(x_small), jnp.asarray(w_small), 7, 0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(
    seed=st.integers(0, 2**31 - 1),
    rows=st.integers(1, XB),
    cols=st.integers(1, XB),
    shift=st.integers(0, 16),
    relu=st.integers(0, 1),
)
@settings(max_examples=25, deadline=None)
def test_mvm_tiled_arbitrary_shapes(seed, rows, cols, shift, relu):
    """mvm_tiled (the L2 building block) over ragged row/col sizes."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(_rand(rng, (P, rows), -128, 128))
    w = jnp.asarray(_rand(rng, (rows, cols), -8, 8))
    got = imc_mvm.mvm_tiled(
        x, w, jnp.array([shift], jnp.int32), jnp.array([relu], jnp.int32)
    )
    want = ref.imc_mvm_ref(x, w, shift, relu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_raw_plus_requant_equals_fused():
    """Row-split contract: raw partial sums + digital requant == fused ADC."""
    from compile.kernels import ancillary

    rng = np.random.default_rng(7)
    x = _rand(rng, (P, XB), -128, 128)
    w = _rand(rng, (XB, XB), -8, 8)
    s = jnp.array([9], jnp.int32)
    r = jnp.array([1], jnp.int32)
    fused = imc_mvm.imc_mvm(jnp.asarray(x), jnp.asarray(w), s, r)
    raw = imc_mvm.imc_mvm_raw(jnp.asarray(x), jnp.asarray(w))
    requant = ancillary.requant(raw, s, r)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(requant))


@pytest.mark.parametrize("pixels", [16, 128])
def test_batched_pixel_variants_match_ref(pixels):
    """The 16- and 128-pixel job variants are the same math (§Perf L3-2)."""
    rng = np.random.default_rng(99)
    x = jnp.asarray(rng.integers(-128, 128, size=(pixels, XB)).astype(np.int8))
    w = jnp.asarray(rng.integers(-8, 8, size=(XB, XB)).astype(np.int8))
    s = jnp.array([9], jnp.int32)
    r = jnp.array([1], jnp.int32)
    got = imc_mvm.imc_mvm(x, w, s, r, pixels=pixels)
    want = ref.imc_mvm_ref(x, w, 9, 1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    raw = imc_mvm.imc_mvm_raw(x, w, pixels=pixels)
    np.testing.assert_array_equal(np.asarray(raw), np.asarray(ref.imc_mvm_raw_ref(x, w)))


def test_f32_carrier_is_exact_at_worst_case():
    """§Perf L1-1 safety proof, executed: the worst-case bit-line sum
    (256 rows of ±127×∓8) is below 2^24, so the f32-carrier dot is exact."""
    x = jnp.full((16, XB), -128, jnp.int8)
    w = jnp.full((XB, XB), -8, jnp.int8)
    raw = np.asarray(imc_mvm.imc_mvm_raw(x, w))
    assert (raw == 128 * 8 * 256).all()  # 262144 < 2**24
    assert abs(raw[0, 0]) < 2**24
