"""AOT path tests: every artifact lowers to parseable HLO text with the
shapes the Rust runtime hard-codes (the ABI contract of client.rs)."""

import re

import jax
import pytest

from compile import aot


@pytest.fixture(scope="module")
def specs():
    return aot.artifact_specs()


def test_all_artifacts_present(specs):
    assert set(specs) == {
        "imc_mvm",
        "imc_mvm_raw",
        "imc_mvm_b128",
        "imc_mvm_raw_b128",
        "requant",
        "requant_b128",
        "residual",
        "dw3x3_s1",
        "dw3x3_s2",
        "bottleneck",
    }


@pytest.mark.parametrize(
    "name", ["imc_mvm", "imc_mvm_raw", "requant", "residual", "dw3x3_s1", "dw3x3_s2"]
)
def test_artifact_lowers_to_hlo_text(specs, name):
    fn, args = specs[name]
    lowered = jax.jit(fn).lower(*args)
    text = aot.to_hlo_text(lowered)
    # HLO text, with a tuple-returning entry (the Rust loader calls
    # to_tuple1) and no serialized-proto artifacts
    assert text.startswith("HloModule"), text[:40]
    assert "ENTRY" in text
    root_tuple = re.search(r"ROOT .* tuple\(", text)
    assert root_tuple, "entry must return a tuple (return_tuple=True)"


def test_mvm_abi_shapes(specs):
    fn, args = specs["imc_mvm"]
    text = aot.to_hlo_text(jax.jit(fn).lower(*args))
    # the exact parameter shapes rust/src/runtime/client.rs relies on
    assert "s8[16,256]" in text
    assert "s8[256,256]" in text
    assert "s32[1]" in text


def test_dw_abi_shapes(specs):
    fn, args = specs["dw3x3_s1"]
    text = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert "s8[18,18,16]" in text
    assert "s8[16,16,16]" in text
    fn2, args2 = specs["dw3x3_s2"]
    text2 = aot.to_hlo_text(jax.jit(fn2).lower(*args2))
    assert "s8[33,33,16]" in text2


def test_no_custom_calls_in_artifacts(specs):
    """interpret=True must lower Pallas to plain HLO — a Mosaic custom-call
    would be unloadable by the CPU PJRT client."""
    for name, (fn, args) in specs.items():
        if name == "bottleneck":
            continue  # covered implicitly; lowering it twice is slow
        text = aot.to_hlo_text(jax.jit(fn).lower(*args))
        assert "custom-call" not in text, f"{name} contains a custom-call"
