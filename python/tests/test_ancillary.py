"""Ancillary Pallas kernels (requant, residual) vs oracles."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ancillary, ref

P, C = ancillary.REQUANT_ROWS, ancillary.REQUANT_COLS


@given(seed=st.integers(0, 2**31 - 1), shift=st.integers(0, 16), relu=st.integers(0, 1))
@settings(max_examples=30, deadline=None)
def test_requant_matches_ref(seed, shift, relu):
    rng = np.random.default_rng(seed)
    acc = rng.integers(-(2**20), 2**20, size=(P, C)).astype(np.int32)
    got = ancillary.requant(
        jnp.asarray(acc), jnp.array([shift], jnp.int32), jnp.array([relu], jnp.int32)
    )
    want = ref.requant_ref(jnp.asarray(acc), shift, relu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_requant_saturation_corners():
    acc = np.zeros((P, C), np.int32)
    acc[0, 0] = 2**30
    acc[0, 1] = -(2**30)
    got = np.asarray(
        ancillary.requant(
            jnp.asarray(acc), jnp.array([0], jnp.int32), jnp.array([0], jnp.int32)
        )
    )
    assert got[0, 0] == 127 and got[0, 1] == -128


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_residual_matches_ref(seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(-128, 128, size=ancillary.RESIDUAL_CHUNK).astype(np.int8)
    b = rng.integers(-128, 128, size=ancillary.RESIDUAL_CHUNK).astype(np.int8)
    got = ancillary.residual_add(jnp.asarray(a), jnp.asarray(b))
    want = ref.residual_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_residual_saturates():
    a = np.full(ancillary.RESIDUAL_CHUNK, 127, np.int8)
    b = np.full(ancillary.RESIDUAL_CHUNK, 127, np.int8)
    got = np.asarray(ancillary.residual_add(jnp.asarray(a), jnp.asarray(b)))
    assert (got == 127).all()
    got2 = np.asarray(
        ancillary.residual_add(jnp.asarray(-a - 1), jnp.asarray(-b - 1))
    )
    assert (got2 == -128).all()
