"""L2 model tests: netspec shape algebra, golden runner, fused bottleneck."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, netspec


def test_mobilenetv2_shapes():
    layers = netspec.mobilenet_v2()
    # canonical MobileNetV2 anatomy
    assert layers[0].name == "conv1" and layers[0].hout == 112
    assert layers[-1].kind == "fc" and layers[-1].cout == 1000
    assert layers[-2].kind == "pool"
    assert layers[-3].cout == 1280
    # 17 inverted-residual blocks, 10 residual adds
    adds = [l for l in layers if l.kind == "add"]
    assert len(adds) == 10
    dws = [l for l in layers if l.kind == "dw"]
    assert len(dws) == 17
    # final spatial resolution before pooling is 7x7
    assert layers[-3].hout == 7
    # parameter count of conv+fc weights ~ 2.2M (width 1.0, incl. classifier)
    n_weights = sum(l.n_weights for l in layers)
    assert 3.0e6 < n_weights < 3.6e6  # incl. dw + fc(1.28M)


def test_mobilenetv2_macs():
    layers = netspec.mobilenet_v2()
    macs = netspec.total_macs(layers)
    # canonical MobileNetV2 = ~300M MACs + 1.28M fc
    assert 280e6 < macs < 330e6


def test_residual_links_are_consistent():
    layers = netspec.mobilenet_v2()
    for idx, l in enumerate(layers):
        if l.kind == "add":
            src = layers[l.residual_from]
            assert src.hout == l.hin and src.wout == l.win
            assert (src.cout if src.kind != "add" else src.cin) == l.cin


def test_tiny_network_runs_and_is_deterministic():
    layers = netspec.tiny_mobilenet()
    weights = model.synth_weights(layers, 123)
    x = model.synth_input(layers[0], 123)
    logits1, shifts, sums = model.run_network(layers, weights, x)
    logits2, _, sums2 = model.run_network(layers, weights, x, shifts=shifts)
    np.testing.assert_array_equal(logits1, logits2)
    assert sums == sums2
    assert logits1.dtype == np.int32 and logits1.size == 10


def test_auto_shift_never_clips():
    layers = netspec.tiny_mobilenet()
    weights = model.synth_weights(layers, 9)
    x = model.synth_input(layers[0], 9)
    _, shifts, _ = model.run_network(layers, weights, x)
    assert all(s >= 0 for s in shifts)
    assert max(shifts) < 24


def test_bottleneck_fused_matches_ref():
    """The fused L2 artifact graph (Pallas kernels) vs the pure-jnp oracle."""
    rng = np.random.default_rng(5)
    cc, hid = netspec.BOTTLENECK_C, netspec.BOTTLENECK_HID
    x = rng.integers(-128, 128, size=(16, 16, cc)).astype(np.int8)
    w1 = rng.integers(-8, 8, size=(cc, hid)).astype(np.int8)
    wd = rng.integers(-8, 8, size=(3, 3, hid)).astype(np.int8)
    w2 = rng.integers(-8, 8, size=(hid, cc)).astype(np.int8)
    shifts = jnp.array([9, 9, 10], jnp.int32)
    got = model.bottleneck_fused(
        jnp.asarray(x), jnp.asarray(w1), jnp.asarray(wd), jnp.asarray(w2), shifts
    )
    want = model.bottleneck_ref(x, w1, wd, w2, np.array([9, 9, 10]))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_case_study_bottleneck_matches_paper_occupancy():
    """DESIGN.md §5: the reconstructed bottleneck must reproduce the paper's
    +25 % (cjob8) / +54 % (cjob16) crossbar-device increases."""
    cc, hid = netspec.BOTTLENECK_C, netspec.BOTTLENECK_HID
    weights = 2 * cc * hid + 9 * hid
    dw_dense = 9 * hid  # true dw weights
    for cjob, expect in [(8, 0.25), (16, 0.54)]:
        dw_devices = 9 * hid * cjob
        increase = (dw_devices - dw_dense) / weights
        # Fig. 8 is not machine-readable; +-4 pp reproduces the quoted
        # +25 % / +54 % as closely as any MobileNetV2-style config can.
        assert abs(increase - expect) < 0.04, (cjob, increase)
