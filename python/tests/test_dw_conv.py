"""Depth-wise engine kernel vs oracle (strides 1 and 2, tiles and layers)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import dw_conv, ref

T = dw_conv.TILE
CB = dw_conv.CH_BLOCK


def _tile_args(seed, stride):
    rng = np.random.default_rng(seed)
    hin = (T - 1) * stride + 3
    x = rng.integers(-128, 128, size=(hin, hin, CB)).astype(np.int8)
    w = rng.integers(-8, 8, size=(3, 3, CB)).astype(np.int8)
    return jnp.asarray(x), jnp.asarray(w)


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("seed", [0, 1])
@pytest.mark.parametrize("shift,relu", [(0, 0), (6, 1), (9, 0)])
def test_dw_tile_matches_ref(stride, seed, shift, relu):
    x, w = _tile_args(seed, stride)
    got = dw_conv.dw3x3_tile(
        x, w, jnp.array([shift], jnp.int32), jnp.array([relu], jnp.int32), stride=stride
    )
    want = ref.dw3x3_ref(x, w, shift, relu, stride=stride)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("stride,hw,c", [(1, 32, 32), (2, 64, 16), (1, 16, 48)])
def test_dw_layer_matches_ref(stride, hw, c):
    rng = np.random.default_rng(hw * 7 + c)
    x = rng.integers(-128, 128, size=(hw + 2, hw + 2, c)).astype(np.int8)
    w = rng.integers(-8, 8, size=(3, 3, c)).astype(np.int8)
    s = jnp.array([7], jnp.int32)
    r = jnp.array([1], jnp.int32)
    got = dw_conv.dw3x3_layer(jnp.asarray(x), jnp.asarray(w), s, r, stride=stride)
    want = ref.dw3x3_ref(jnp.asarray(x), jnp.asarray(w), 7, 1, stride=stride)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(seed=st.integers(0, 2**31 - 1), stride=st.sampled_from([1, 2]))
@settings(max_examples=15, deadline=None)
def test_dw_tile_random_sweep(seed, stride):
    x, w = _tile_args(seed, stride)
    s = seed % 12
    got = dw_conv.dw3x3_tile(
        x, w, jnp.array([s], jnp.int32), jnp.array([1], jnp.int32), stride=stride
    )
    want = ref.dw3x3_ref(x, w, s, 1, stride=stride)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_dw_channel_independence():
    """Depth-wise contract: each output channel depends only on its own
    input channel (what makes IMA mapping so wasteful, paper Fig. 8)."""
    rng = np.random.default_rng(3)
    x1 = rng.integers(-128, 128, size=(T + 2, T + 2, CB)).astype(np.int8)
    w = rng.integers(-8, 8, size=(3, 3, CB)).astype(np.int8)
    x2 = x1.copy()
    x2[:, :, 1:] = rng.integers(-128, 128, size=(T + 2, T + 2, CB - 1))
    s = jnp.array([5], jnp.int32)
    r = jnp.array([0], jnp.int32)
    y1 = np.asarray(dw_conv.dw3x3_tile(jnp.asarray(x1), jnp.asarray(w), s, r))
    y2 = np.asarray(dw_conv.dw3x3_tile(jnp.asarray(x2), jnp.asarray(w), s, r))
    np.testing.assert_array_equal(y1[:, :, 0], y2[:, :, 0])
