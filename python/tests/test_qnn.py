"""Unit tests for the shared quantization contract (qnn.py)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import qnn


def test_round_shift_zero_is_identity():
    a = jnp.array([-5, 0, 7, 1000, -1000], jnp.int32)
    assert (qnn.round_shift(a, 0) == a).all()


def test_round_shift_rounds_half_up():
    # (3 + 2) >> 2 = 1 ; (2 + 2) >> 2 = 1 ; (1 + 2) >> 2 = 0
    a = jnp.array([3, 2, 1, -2, -3, -1], jnp.int32)
    got = qnn.round_shift(a, 2)
    # round-half-up on the shifted value: 3/4 -> 1, 2/4 -> 1, 1/4 -> 0,
    # -2/4 -> 0, -3/4 -> 0 (since -3+2=-1 >> 2 = -1? arithmetic: -1>>2 = -1)
    expect = [(v + 2) >> 2 for v in [3, 2, 1, -2, -3, -1]]
    assert got.tolist() == expect


@given(
    st.lists(st.integers(-(2**28), 2**28), min_size=1, max_size=64),
    st.integers(0, 20),
)
@settings(max_examples=200, deadline=None)
def test_round_shift_matches_python_model(vals, s):
    a = jnp.array(vals, jnp.int32)
    got = qnn.round_shift(a, s).tolist()
    if s == 0:
        expect = vals
    else:
        expect = [(v + (1 << (s - 1))) >> s for v in vals]
    assert got == expect


@given(
    st.lists(st.integers(-(2**28), 2**28), min_size=1, max_size=64),
    st.integers(0, 20),
    st.booleans(),
)
@settings(max_examples=100, deadline=None)
def test_requantize_range_and_relu(vals, s, relu):
    a = jnp.array(vals, jnp.int32)
    y = np.asarray(qnn.requantize(a, s, int(relu)))
    assert y.dtype == np.int8
    assert y.min() >= (0 if relu else -128)
    assert y.max() <= 127


@given(
    st.lists(st.integers(-128, 127), min_size=1, max_size=128),
    st.lists(st.integers(-128, 127), min_size=1, max_size=128),
)
@settings(max_examples=100, deadline=None)
def test_saturating_add(a_vals, b_vals):
    n = min(len(a_vals), len(b_vals))
    a = jnp.array(a_vals[:n], jnp.int8)
    b = jnp.array(b_vals[:n], jnp.int8)
    y = np.asarray(qnn.saturating_add_i8(a, b))
    for i in range(n):
        s = a_vals[i] + b_vals[i]
        assert y[i] == max(-128, min(127, s))


def test_clip_int4_range():
    w = jnp.arange(-20, 20, dtype=jnp.int32)
    c = np.asarray(qnn.clip_int4(w))
    assert c.min() == -8 and c.max() == 7


def test_checksum_matches_rust_formula():
    x = np.array([1, -2, 3], dtype=np.int32)
    assert qnn.checksum_i64(x) == (1 - 2 + 3) + 31 * 3
