//! Cycle/energy costs of the PULP-NN-style software kernels.
//!
//! Every kernel is a parallel section: the work splits into chunks (one
//! per [`PAR_GRAIN_MACS`] MACs or [`PAR_GRAIN_ELEMS`] elements) and
//! engages `min(n_cores, chunks)` cores. Layers big enough to fill the
//! cluster — everything in the paper's workloads — engage all eight and
//! cost exactly what the original 8-core model charged; a tiny ancillary
//! section engages fewer, which the batch scheduler turns into a shorter
//! per-core resource prefix so other tenants' sections can share the
//! complex (see `coordinator::timeline`).

use crate::arch::{EnergyAccount, SystemConfig};
use crate::net::{Layer, LayerKind};
use crate::sim::event_unit::EventUnit;

/// MACs per parallel work chunk (conv/dw/fc kernels).
pub const PAR_GRAIN_MACS: usize = 4096;
/// Elements per parallel work chunk (ancillary element-wise kernels).
pub const PAR_GRAIN_ELEMS: usize = 512;

#[derive(Clone, Debug, Default)]
pub struct CoresCost {
    pub cycles: u64,
    pub energy: EnergyAccount,
    /// Cores the parallel section engages (0 for a zero-cost section).
    pub cores: usize,
}

pub struct SwKernels<'a> {
    pub cfg: &'a SystemConfig,
    pub eu: EventUnit,
    /// Cores available (8 in the cluster; 1 models the MCU baselines).
    pub n_cores: usize,
}

impl<'a> SwKernels<'a> {
    pub fn new(cfg: &'a SystemConfig) -> Self {
        SwKernels {
            cfg,
            eu: EventUnit::paper(),
            n_cores: cfg.n_cores,
        }
    }

    pub fn with_cores(mut self, n: usize) -> Self {
        self.n_cores = n;
        self
    }

    /// Cores a section of `chunks` work chunks engages.
    fn engaged(&self, chunks: usize) -> usize {
        chunks.clamp(1, self.n_cores)
    }

    /// Scale an 8-core throughput rate to `n` cores (linear with a mild
    /// parallel-efficiency knee below 8 — PULP-NN scales ~0.95/core).
    fn scale_rate(&self, rate_8core: f64, n_cores: usize) -> f64 {
        let n = n_cores as f64;
        if n_cores >= 8 {
            rate_8core * (n / 8.0)
        } else {
            rate_8core * (n / 8.0) * (1.0 + 0.05 * (8.0 - n) / 8.0)
        }
    }

    fn cost(&self, k: usize, cycles: u64, tcdm_duty: f64) -> CoresCost {
        let mut e = EnergyAccount::default();
        let wall = cycles + self.eu.parallel_section_overhead_cy(k, k);
        e.wall_cy = wall;
        e.core_active_cy = wall * k as u64;
        e.core_idle_cy = wall * (self.cfg.n_cores.saturating_sub(k)) as u64;
        e.tcdm_duty_millicycles = (wall as f64 * tcdm_duty * 1000.0) as u64;
        CoresCost { cycles: wall, energy: e, cores: k }
    }

    /// Element-wise section of `elems` at `rate_8core` elems/cycle.
    fn elemwise(&self, elems: usize, rate_8core: f64, tcdm_duty: f64) -> CoresCost {
        let k = self.engaged(elems.div_ceil(PAR_GRAIN_ELEMS));
        let rate = self.scale_rate(rate_8core, k);
        self.cost(k, (elems as f64 / rate).ceil() as u64, tcdm_duty)
    }

    /// A whole layer in software (the CORES baseline of Fig. 9).
    pub fn layer_cost(&self, l: &Layer) -> CoresCost {
        match l.kind {
            LayerKind::Conv | LayerKind::Fc => {
                let k = self.engaged((l.macs() as usize).div_ceil(PAR_GRAIN_MACS));
                let rate = self.scale_rate(self.cfg.sw_pw_macs_per_cycle, k);
                self.cost(k, (l.macs() as f64 / rate).ceil() as u64, 0.5)
            }
            LayerKind::Dw => {
                let k = self.engaged((l.macs() as usize).div_ceil(PAR_GRAIN_MACS));
                let rate = if k == 1 {
                    self.cfg.sw_dw_macs_per_cycle_1core
                } else {
                    self.scale_rate(self.cfg.sw_dw_macs_per_cycle, k)
                };
                self.cost(k, (l.macs() as f64 / rate).ceil() as u64, 0.6)
            }
            LayerKind::Add => self.residual(l.out_pixels() * l.cout),
            LayerKind::Pool => self.pool(l.hin * l.win * l.cin),
        }
    }

    /// Residual connection: int8 saturating add of `elems` elements.
    pub fn residual(&self, elems: usize) -> CoresCost {
        self.elemwise(elems, self.cfg.sw_residual_elems_per_cycle, 0.8)
    }

    /// Digital accumulation of `n_partials` int32 partial tensors of
    /// `elems` elements (row-split IMA layers): (n-1) adds per element.
    pub fn accumulate_partials(&self, elems: usize, n_partials: usize) -> CoresCost {
        if n_partials <= 1 {
            return CoresCost::default();
        }
        let adds = elems * (n_partials - 1);
        self.elemwise(adds, self.cfg.sw_accum_elems_per_cycle, 0.9)
    }

    /// Requantization (shift-round-clip int32→int8) of `elems` elements.
    pub fn requant(&self, elems: usize) -> CoresCost {
        self.elemwise(elems, self.cfg.sw_requant_elems_per_cycle, 0.7)
    }

    /// HWC↔CHW marshaling of `elems` elements (HYBRID mapping, §V-C).
    pub fn marshal(&self, elems: usize) -> CoresCost {
        self.elemwise(elems, self.cfg.sw_marshal_elems_per_cycle, 0.9)
    }

    /// Global average pooling over `elems` inputs.
    pub fn pool(&self, elems: usize) -> CoresCost {
        self.elemwise(elems, self.cfg.sw_pool_elems_per_cycle, 0.6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::bottleneck::bottleneck;
    use crate::net::Layer;

    fn sw(cfg: &SystemConfig) -> SwKernels<'_> {
        SwKernels::new(cfg)
    }

    #[test]
    fn pw_layer_rate() {
        let cfg = SystemConfig::paper();
        let l = Layer::conv("pw", 16, 16, 128, 768);
        let c = sw(&cfg).layer_cost(&l);
        let rate = l.macs() as f64 / c.cycles as f64;
        assert!((rate - 15.5).abs() < 0.5, "{rate}");
        assert_eq!(c.cores, 8, "a full-size layer engages the cluster");
    }

    #[test]
    fn dw_software_is_the_bottleneck() {
        // paper §IV-C: dw in software is slow (the accelerator's raison
        // d'être) — per-MAC it is ~5× slower than pw
        let cfg = SystemConfig::paper();
        let net = bottleneck();
        let pw = sw(&cfg).layer_cost(&net.layers[0]);
        let dw = sw(&cfg).layer_cost(&net.layers[1]);
        let pw_per_mac = pw.cycles as f64 / net.layers[0].macs() as f64;
        let dw_per_mac = dw.cycles as f64 / net.layers[1].macs() as f64;
        assert!(dw_per_mac / pw_per_mac > 4.0);
    }

    #[test]
    fn single_core_dw_matches_26x_claim_base() {
        let cfg = SystemConfig::paper();
        let l = Layer::dw("d", 16, 16, 768, 1);
        let c = sw(&cfg).with_cores(1).layer_cost(&l);
        let rate = l.macs() as f64 / c.cycles as f64;
        assert!((rate - 1.14).abs() < 0.05, "{rate}");
        assert_eq!(c.cores, 1);
    }

    #[test]
    fn whole_bottleneck_in_software() {
        // the CORES bar of Fig. 9: ~3.5–4 M cycles for the case-study block
        let cfg = SystemConfig::paper();
        let net = bottleneck();
        let total: u64 = net.layers.iter().map(|l| sw(&cfg).layer_cost(l).cycles).sum();
        assert!((3_000_000..4_500_000).contains(&total), "{total}");
    }

    #[test]
    fn ancillary_costs_scale_linearly() {
        let cfg = SystemConfig::paper();
        let s = sw(&cfg);
        let r1 = s.residual(10_000).cycles;
        let r2 = s.residual(20_000).cycles;
        assert!((r2 as f64 / r1 as f64 - 2.0).abs() < 0.1);
        assert_eq!(s.accumulate_partials(1000, 1).cycles, 0);
        assert!(s.accumulate_partials(1000, 3).cycles > s.accumulate_partials(1000, 2).cycles);
    }

    #[test]
    fn fewer_cores_cost_more_cycles() {
        let cfg = SystemConfig::paper();
        let l = Layer::conv("pw", 16, 16, 128, 128);
        let c8 = sw(&cfg).layer_cost(&l).cycles;
        let c2 = sw(&cfg).with_cores(2).layer_cost(&l).cycles;
        assert!(c2 > 3 * c8);
    }

    #[test]
    fn tiny_sections_engage_fewer_cores() {
        let cfg = SystemConfig::paper();
        let s = sw(&cfg);
        // one chunk of work: a single core
        assert_eq!(s.residual(64).cores, 1);
        // four chunks: four cores
        assert_eq!(s.residual(4 * PAR_GRAIN_ELEMS).cores, 4);
        // everything at or past eight chunks engages the whole cluster
        assert_eq!(s.residual(8 * PAR_GRAIN_ELEMS).cores, 8);
        assert_eq!(s.residual(100 * PAR_GRAIN_ELEMS).cores, 8);
        // the paper workloads' smallest ancillary section still fills it:
        // MobileNetV2's 7×7×160 residual add
        assert_eq!(s.residual(7 * 7 * 160).cores, 8);
    }
}
