//! Classic DSP kernels on the programmable cores (paper §VII / Fig. 13
//! discussion): modern embedded CV pipelines couple DNN inference with
//! PCA, FFT, filtering or inverse kinematics — exactly the workloads that
//! fixed-function IMC systems ([7], [31]) cannot host and that justify the
//! SW+IMA+DIG.ACC computing model. Cycle models follow the XpulpV2 DSP
//! throughput conventions of `arch::params` (fixed-point, 8 cores).

use crate::arch::{EnergyAccount, SystemConfig};
use crate::sim::event_unit::EventUnit;

use super::kernels::CoresCost;

pub struct DspKernels<'a> {
    pub cfg: &'a SystemConfig,
    eu: EventUnit,
}

impl<'a> DspKernels<'a> {
    pub fn new(cfg: &'a SystemConfig) -> Self {
        DspKernels {
            cfg,
            eu: EventUnit::paper(),
        }
    }

    fn cost(&self, cycles: u64, duty: f64) -> CoresCost {
        let wall = cycles + 2 * self.eu.barrier_cy;
        let mut e = EnergyAccount::default();
        e.wall_cy = wall;
        e.core_active_cy = wall * self.cfg.n_cores as u64;
        e.tcdm_duty_millicycles = (wall as f64 * duty * 1000.0) as u64;
        CoresCost { cycles: wall, energy: e, cores: self.cfg.n_cores }
    }

    /// Radix-2 complex FFT of `n` points (fixed-point): 5·n·log2(n) MAC-ish
    /// ops at the XpulpV2 sdotp rate, parallel across butterflies.
    pub fn fft(&self, n: usize) -> CoresCost {
        assert!(n.is_power_of_two());
        let ops = 5 * n as u64 * (n as u64).ilog2() as u64;
        let rate = self.cfg.sw_pw_macs_per_cycle; // complex MAC ≈ dotp unit
        self.cost((ops as f64 / rate).ceil() as u64, 0.6)
    }

    /// FIR filter: `taps`-tap convolution over `n` samples.
    pub fn fir(&self, n: usize, taps: usize) -> CoresCost {
        let macs = (n * taps) as u64;
        self.cost((macs as f64 / self.cfg.sw_pw_macs_per_cycle).ceil() as u64, 0.5)
    }

    /// PCA projection of a `dim`-vector onto `comps` components (a small
    /// dense MVM — could also go to the IMA, but weights would evict DNN
    /// tiles; the cores run it "for free").
    pub fn pca_project(&self, dim: usize, comps: usize) -> CoresCost {
        let macs = (dim * comps) as u64;
        self.cost((macs as f64 / self.cfg.sw_pw_macs_per_cycle).ceil() as u64, 0.5)
    }

    /// Damped-least-squares inverse-kinematics iteration for a `joints`-DOF
    /// chain: Jacobian build + 3 small MVMs per iteration.
    pub fn inverse_kinematics(&self, joints: usize, iters: usize) -> CoresCost {
        let per_iter = (3 * joints * joints + 9 * joints) as u64;
        let macs = per_iter * iters as u64;
        self.cost((macs as f64 / self.cfg.sw_pw_macs_per_cycle).ceil() as u64, 0.4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dsp(cfg: &SystemConfig) -> DspKernels<'_> {
        DspKernels::new(cfg)
    }

    #[test]
    fn fft_scales_n_log_n() {
        let cfg = SystemConfig::paper();
        let d = dsp(&cfg);
        let c1k = d.fft(1024).cycles as f64;
        let c4k = d.fft(4096).cycles as f64;
        // 4096·12 / 1024·10 = 4.8×
        assert!((c4k / c1k - 4.8).abs() < 0.3, "{}", c4k / c1k);
    }

    #[test]
    fn dsp_stages_are_small_next_to_inference() {
        // the §VII argument: classic DSP glue is cheap on the cluster cores
        // compared to the 10 ms DNN — flexibility costs ~nothing
        let cfg = SystemConfig::paper();
        let d = dsp(&cfg);
        let pipeline_cy = d.fir(224 * 224, 16).cycles
            + d.fft(1024).cycles
            + d.pca_project(1280, 64).cycles
            + d.inverse_kinematics(6, 20).cycles;
        let inference_cy = 5_400_000u64; // measured MNv2 e2e
        assert!(pipeline_cy * 10 < inference_cy, "{pipeline_cy}");
    }

    #[test]
    #[should_panic]
    fn fft_requires_power_of_two() {
        let cfg = SystemConfig::paper();
        dsp(&cfg).fft(1000);
    }
}
