//! Software execution on the 8 RISC-V cores (paper §III-B; PULP-NN [36]).
//!
//! The paper reports core performance as aggregate MAC/cycle figures for the
//! XpulpV2 DSP kernels (sdotp-based 8-bit convolutions); this module turns
//! layer shapes into cycle/energy costs using those calibrated rates, plus
//! the ancillary operations the cores keep in every mapping: residual adds,
//! partial-sum accumulation and requantization for row-split IMA layers,
//! HWC↔CHW marshaling (HYBRID only), pooling and the classifier.

pub mod dsp;
pub mod kernels;

pub use dsp::DspKernels;
pub use kernels::{CoresCost, SwKernels, PAR_GRAIN_ELEMS, PAR_GRAIN_MACS};
