//! System configuration: every knob the paper sweeps, every calibrated
//! constant, with the paper/section each number comes from.
//!
//! Calibration philosophy (DESIGN.md §5): constants marked *paper* are quoted
//! directly from the manuscript; constants marked *calibrated* are not
//! published and were fitted so the simulator lands on the paper's reported
//! aggregate numbers (958 GOPS peak, Fig. 9 ratios, 10.1 ms / 482 µJ e2e).
//! `report::experiments` re-checks the targets on every run.

/// Operating point (paper §V-B investigates two).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FreqPoint {
    pub freq_mhz: f64,
    pub vdd: f64,
}

impl FreqPoint {
    /// Maximum frequency at nominal voltage (paper: 500 MHz @ 0.8 V).
    pub const HIGH: FreqPoint = FreqPoint {
        freq_mhz: 500.0,
        vdd: 0.80,
    };
    /// Low-voltage point (paper: 250 MHz @ 0.65 V).
    pub const LOW: FreqPoint = FreqPoint {
        freq_mhz: 250.0,
        vdd: 0.65,
    };

    pub fn freq_hz(&self) -> f64 {
        self.freq_mhz * 1e6
    }

    pub fn cycle_ns(&self) -> f64 {
        1e3 / self.freq_mhz
    }

    /// Dynamic-power scaling factor vs the HIGH point: `f/f0 * (V/V0)^2`
    /// (classical scaling, same rule the paper uses for the IMA macro).
    pub fn power_factor(&self) -> f64 {
        (self.freq_mhz / FreqPoint::HIGH.freq_mhz)
            * (self.vdd / FreqPoint::HIGH.vdd).powi(2)
    }
}

/// IMA execution model (paper §IV-B, Fig. 3b).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecModel {
    /// STREAM-IN → COMPUTE → STREAM-OUT strictly in sequence.
    Sequential,
    /// The three phases of consecutive jobs overlap (extra pipeline
    /// registers: +40 % digital area, +5 % of the whole subsystem).
    Pipelined,
}

/// Full system configuration. `SystemConfig::paper()` is the publication
/// configuration (500 MHz, 128-bit IMA bus, pipelined IMA).
#[derive(Clone, Debug)]
pub struct SystemConfig {
    // ---- cluster (paper §III-B) ----------------------------------------
    /// RISC-V cores in the cluster (RV32IMC + XpulpV2). *paper*
    pub n_cores: usize,
    /// Shared L1 TCDM size in kB. *paper*
    pub tcdm_kb: usize,
    /// Word-interleaved TCDM banks. *paper*
    pub tcdm_banks: usize,
    /// Operating point.
    pub freq: FreqPoint,

    // ---- IMA subsystem (paper §IV-B, §V-B) ------------------------------
    /// Crossbar rows (word-lines). *paper* (HERMES: 256)
    pub xbar_rows: usize,
    /// Crossbar columns (bit-lines). *paper*
    pub xbar_cols: usize,
    /// Fixed analog MVM latency in ns, independent of cluster clock. *paper*
    pub ima_mvm_ns: f64,
    /// IMA subsystem data-interface width in bits (swept 32..512 in Fig. 7;
    /// optimal = 128). *paper*
    pub ima_bus_bits: usize,
    /// Execution model for back-to-back jobs.
    pub ima_exec: ExecModel,
    /// Number of crossbars muxed into the IMA subsystem (1 for §V; the
    /// scaled-up §VI system instantiates `tilepack` output, 34 for MNv2).
    pub n_crossbars: usize,

    /// Streamer address-generator setup cycles folded into each stream
    /// phase (FIFO fill + re-aligner latency). *calibrated*
    pub streamer_setup_cy: u64,
    /// Per-job trigger/handshake cycles in pipelined mode. *calibrated*
    pub ima_trigger_cy: u64,
    /// Per-job issue overhead spent by the controlling core advancing the
    /// pipelined job queue (register-file strides update, event wait).
    /// *calibrated* against Fig. 9's IMA+DW/CORES ratio.
    pub ima_job_issue_cy: u64,
    /// One-off per-layer configuration written by a core over the control
    /// interface (regfile programming + ACQUIRE/TRIGGER). *calibrated*
    pub ima_layer_cfg_cy: u64,
    /// Depth-wise-on-IMA jobs cannot be hardware-pipelined: the diagonal
    /// job blocks need per-job source-stride reconfiguration by the cores
    /// (paper Fig. 8 discussion). Extra per-job cycles. *calibrated*
    /// against the IMA_cjob8/IMA_cjob16 bars of Fig. 9.
    pub ima_dw_job_cfg_cy: u64,

    /// PCM programming: per-row program-and-verify time as a multiple of
    /// the MVM latency (paper §VI: 20–30×; we take the middle).
    pub pcm_program_row_factor: f64,

    // ---- depth-wise accelerator (paper §IV-C) ---------------------------
    /// Channels per engine block. *paper*
    pub dw_ch_block: usize,
    /// Average steady-state throughput in MAC/cycle. *paper* (29.7)
    pub dw_macs_per_cycle: f64,
    /// Weight preload + window-buffer prime per (column, 16-ch block).
    /// *calibrated* (keeps the average at ~29.7 on real layers)
    pub dw_setup_cy: u64,

    // ---- software kernel throughput on the 8 cores (PULP-NN, [36]) -----
    /// 8-core MAC/cycle on point-wise / standard convolutions. *paper [36]*
    pub sw_pw_macs_per_cycle: f64,
    /// 8-core MAC/cycle on depth-wise convolutions — dw kernels are
    /// marshaling-bound and scale poorly (the paper's motivation for the
    /// dedicated accelerator). *calibrated* against HYBRID in Fig. 9.
    pub sw_dw_macs_per_cycle: f64,
    /// Single-core dw MAC/cycle (paper: the accelerator's 29.7 is "26×
    /// over a pure software implementation" → 1.14).
    pub sw_dw_macs_per_cycle_1core: f64,
    /// 8-core int8 elements/cycle on the residual add. *calibrated*
    pub sw_residual_elems_per_cycle: f64,
    /// 8-core int32 partial-sum accumulation elements/cycle (row-split
    /// layers). *calibrated*
    pub sw_accum_elems_per_cycle: f64,
    /// 8-core requantization (shift-round-clip) elements/cycle. *calibrated*
    pub sw_requant_elems_per_cycle: f64,
    /// 8-core HWC↔CHW marshaling elements/cycle (HYBRID mapping only).
    /// *calibrated*
    pub sw_marshal_elems_per_cycle: f64,
    /// 8-core global-average-pool elements/cycle. *calibrated*
    pub sw_pool_elems_per_cycle: f64,
}

impl SystemConfig {
    /// The publication configuration (Fig. 9: 500 MHz, 0.8 V, 128-bit bus,
    /// pipelined IMA, single crossbar).
    pub fn paper() -> Self {
        SystemConfig {
            n_cores: 8,
            tcdm_kb: 512,
            tcdm_banks: 32,
            freq: FreqPoint::HIGH,

            xbar_rows: 256,
            xbar_cols: 256,
            ima_mvm_ns: 130.0,
            ima_bus_bits: 128,
            ima_exec: ExecModel::Pipelined,
            n_crossbars: 1,

            streamer_setup_cy: 1,
            ima_trigger_cy: 1,
            ima_job_issue_cy: 30,
            ima_layer_cfg_cy: 200,
            ima_dw_job_cfg_cy: 50,

            pcm_program_row_factor: 25.0,

            dw_ch_block: 16,
            dw_macs_per_cycle: 29.7,
            dw_setup_cy: 10,

            sw_pw_macs_per_cycle: 15.5,
            sw_dw_macs_per_cycle: 3.0,
            sw_dw_macs_per_cycle_1core: 1.14,
            sw_residual_elems_per_cycle: 3.0,
            sw_accum_elems_per_cycle: 1.2,
            sw_requant_elems_per_cycle: 1.0,
            sw_marshal_elems_per_cycle: 2.7,
            sw_pool_elems_per_cycle: 6.0,
        }
    }

    /// The scaled-up §VI system: same cluster, `n` crossbars in the IMA
    /// subsystem (statically muxed, one active at a time).
    pub fn scaled_up(n_crossbars: usize) -> Self {
        SystemConfig {
            n_crossbars,
            ..Self::paper()
        }
    }

    pub fn with_freq(mut self, freq: FreqPoint) -> Self {
        self.freq = freq;
        self
    }

    pub fn with_bus_bits(mut self, bits: usize) -> Self {
        self.ima_bus_bits = bits;
        self
    }

    pub fn with_exec(mut self, exec: ExecModel) -> Self {
        self.ima_exec = exec;
        self
    }

    /// IMA data-interface bytes per cycle.
    pub fn bus_bytes(&self) -> usize {
        self.ima_bus_bits / 8
    }

    /// Analog MVM latency in cluster cycles at the current operating point
    /// (the analog core's latency is clock-independent, paper §V-B).
    pub fn ima_compute_cy(&self) -> u64 {
        (self.ima_mvm_ns / self.freq.cycle_ns()).ceil() as u64
    }

    /// Theoretical crossbar peak in ops/s (paper: 1.008 TOPS).
    pub fn ima_peak_ops_per_s(&self) -> f64 {
        (self.xbar_rows * self.xbar_cols * 2) as f64 / (self.ima_mvm_ns * 1e-9)
    }

    /// Total crossbar device capacity of the IMA subsystem.
    pub fn xbar_capacity(&self) -> usize {
        self.xbar_rows * self.xbar_cols * self.n_crossbars
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_config_constants() {
        let c = SystemConfig::paper();
        assert_eq!(c.n_cores, 8);
        assert_eq!(c.tcdm_kb, 512);
        assert_eq!(c.tcdm_banks, 32);
        assert_eq!(c.xbar_rows, 256);
        assert_eq!(c.bus_bytes(), 16);
    }

    #[test]
    fn ima_peak_is_1008_gops() {
        let c = SystemConfig::paper();
        let peak = c.ima_peak_ops_per_s() / 1e9;
        assert!((peak - 1008.2).abs() < 1.0, "{peak}");
    }

    #[test]
    fn compute_latency_scales_with_clock() {
        let hi = SystemConfig::paper();
        let lo = SystemConfig::paper().with_freq(FreqPoint::LOW);
        assert_eq!(hi.ima_compute_cy(), 65); // 130 ns @ 2 ns/cy
        assert_eq!(lo.ima_compute_cy(), 33); // 130 ns @ 4 ns/cy
    }

    #[test]
    fn power_factor_low_point() {
        let f = FreqPoint::LOW.power_factor();
        assert!((f - 0.33).abs() < 0.01, "{f}");
        assert_eq!(FreqPoint::HIGH.power_factor(), 1.0);
    }

    #[test]
    fn scaled_up_capacity() {
        let c = SystemConfig::scaled_up(34);
        assert_eq!(c.xbar_capacity(), 34 * 65536);
    }
}
