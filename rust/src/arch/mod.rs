//! Architecture models: technology scaling, system parameters, area, power.
//!
//! The paper's silicon results (GF 22FDX place&route + PrimeTime power +
//! HERMES-core measurements) enter the reproduction exclusively through the
//! constants and analytical models in this module — see DESIGN.md §3 for the
//! substitution argument and §5 for every calibration target.

pub mod area;
pub mod params;
pub mod power;
pub mod technology;

pub use area::AreaModel;
pub use params::{ExecModel, FreqPoint, SystemConfig};
pub use power::{EnergyAccount, PowerModel};
