//! Technology scaling rules (paper §V-A).
//!
//! The IMA macro is measured silicon in 14 nm (HERMES core,
//! Khaddam-Aljameh et al. 2021: 256×256 PCM, 130 ns MVM, 10.5 TOPS/W,
//! 1.59 TOPS/mm²); the paper integrates it in 22 nm by scaling power as
//! `a · b²` (a = dimensional scaling, b = voltage scaling), area by the
//! dimensional scaling, and keeping latency constant. This module encodes
//! exactly that arithmetic so the derivation of every 22 nm IMA constant is
//! executable, not folklore.

/// HERMES-core published numbers at 14 nm.
pub mod hermes14 {
    /// MVM latency (ns) — assumed constant across nodes (paper §V-A).
    pub const MVM_NS: f64 = 130.0;
    /// Peak efficiency on 8b×4b MVMs (TOPS/W).
    pub const TOPS_PER_W: f64 = 10.5;
    /// Performance density (TOPS/mm²).
    pub const TOPS_PER_MM2: f64 = 1.59;
    /// Array geometry.
    pub const ROWS: usize = 256;
    pub const COLS: usize = 256;

    /// Peak throughput of one macro: 256·256·2 ops / 130 ns ≈ 1.008 TOPS.
    pub fn peak_tops() -> f64 {
        (ROWS * COLS * 2) as f64 / MVM_NS / 1e3
    }

    /// Implied macro power at peak (W): peak / efficiency ≈ 96 mW.
    pub fn power_w() -> f64 {
        peak_tops() / TOPS_PER_W
    }

    /// Implied macro area (mm²): peak / density ≈ 0.63 mm².
    pub fn area_mm2() -> f64 {
        peak_tops() / TOPS_PER_MM2
    }
}

/// Scaling of the analog macro from 14 nm to the cluster's 22 nm node.
pub struct ImaScaling {
    /// Dimensional scaling factor a = 22/14.
    pub dim: f64,
    /// Voltage scaling factor b (paper scales under constant frequency;
    /// the macro supply is kept — b = 1.0 reproduces the paper's ~150 mW).
    pub volt: f64,
}

impl Default for ImaScaling {
    fn default() -> Self {
        ImaScaling {
            dim: 22.0 / 14.0,
            volt: 1.0,
        }
    }
}

impl ImaScaling {
    /// Power scales by `a · b²` (paper §V-A).
    pub fn power_w(&self) -> f64 {
        hermes14::power_w() * self.dim * self.volt * self.volt
    }

    /// Area follows dimensional scaling (`a²` for planar area).
    pub fn area_mm2(&self) -> f64 {
        hermes14::area_mm2() * self.dim * self.dim
    }

    /// Latency is assumed constant (paper §V-A).
    pub fn mvm_ns(&self) -> f64 {
        hermes14::MVM_NS
    }

    /// Energy of one full-array MVM job at 22 nm (J).
    pub fn mvm_energy_j(&self) -> f64 {
        self.power_w() * self.mvm_ns() * 1e-9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hermes_implied_numbers() {
        assert!((hermes14::peak_tops() - 1.008).abs() < 0.001);
        let p = hermes14::power_w();
        assert!((p - 0.096).abs() < 0.001, "{p}");
        let a = hermes14::area_mm2();
        assert!((a - 0.634).abs() < 0.01, "{a}");
    }

    #[test]
    fn scaled_macro_matches_paper_aggregates() {
        let s = ImaScaling::default();
        // ~151 mW at 22 nm → with the cluster on top, the paper's measured
        // peak system efficiency of 6.39 TOPS/W at 958 GOPS implies ~150 mW.
        let p = s.power_w();
        assert!((0.140..0.160).contains(&p), "{p}");
        // area ≈ 1.56 mm²?? — no: the paper quotes 0.83 mm² for the IMA
        // *sub-system*; HERMES' 0.63 mm² contains periphery counted
        // separately there. Dimensional scaling alone would give ~1.57 mm²
        // for the full macro; the paper's floorplan allocates 0.83 mm² to
        // the IMA (analog + digital), i.e. assumes only the array core
        // scales. We keep the paper's quoted 0.83 in `area.rs` and expose
        // this scaling as the upper bound.
        assert!(s.area_mm2() > 0.83);
    }

    #[test]
    fn mvm_energy_magnitude() {
        let e = ImaScaling::default().mvm_energy_j();
        // ~19.6 nJ per full-array job
        assert!((15e-9..25e-9).contains(&e), "{e}");
    }
}
