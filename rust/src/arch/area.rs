//! Area model — reproduces the Fig. 6(b) breakdown of the 2.5 mm² cluster
//! and the §VI scaled-up system area (~30 mm² with 34 crossbars).

use super::params::SystemConfig;

/// Component areas in mm² at GF 22FDX (paper Fig. 6b: ~1/3 IMA, ~1/3 TCDM,
/// 1/3 rest; DW accelerator = 2.1 %; total 2.5 mm²).
#[derive(Clone, Debug)]
pub struct AreaModel {
    pub ima_subsystem: f64,
    pub tcdm: f64,
    pub cores: f64,
    pub icache: f64,
    pub interconnect: f64,
    pub dw_accel: f64,
    pub dma: f64,
    pub periph: f64,
}

impl AreaModel {
    /// The single-crossbar publication floorplan.
    pub fn paper() -> Self {
        AreaModel {
            ima_subsystem: 0.83,
            tcdm: 0.83,
            cores: 0.33,
            icache: 0.12,
            interconnect: 0.09,
            dw_accel: 0.0525, // 2.1 % of 2.5 mm²
            dma: 0.05,
            periph: 0.1975,
        }
    }

    /// Scale to a configuration: crossbar count multiplies the IMA macro
    /// area; TCDM scales linearly with capacity; the interconnect grows
    /// linearly with the IMA bus width (paper §V-B: "interconnect area
    /// scales linearly with the bit-width of the system bus").
    pub fn for_config(cfg: &SystemConfig) -> Self {
        let base = Self::paper();
        let ima_digital = 0.10; // streamer/controller/buffers share
        let ima_analog = base.ima_subsystem - ima_digital;
        AreaModel {
            ima_subsystem: ima_digital + ima_analog * cfg.n_crossbars as f64,
            tcdm: base.tcdm * cfg.tcdm_kb as f64 / 512.0,
            interconnect: base.interconnect * (cfg.ima_bus_bits as f64 / 128.0).max(0.5),
            cores: base.cores * cfg.n_cores as f64 / 8.0,
            ..base
        }
    }

    pub fn total(&self) -> f64 {
        self.ima_subsystem
            + self.tcdm
            + self.cores
            + self.icache
            + self.interconnect
            + self.dw_accel
            + self.dma
            + self.periph
    }

    /// (label, mm², % of total) rows for the Fig. 6b report.
    pub fn breakdown(&self) -> Vec<(&'static str, f64, f64)> {
        let t = self.total();
        vec![
            ("IMA subsystem", self.ima_subsystem, 100.0 * self.ima_subsystem / t),
            ("TCDM (L1)", self.tcdm, 100.0 * self.tcdm / t),
            ("RISC-V cores", self.cores, 100.0 * self.cores / t),
            ("I$ hierarchy", self.icache, 100.0 * self.icache / t),
            ("Interconnect", self.interconnect, 100.0 * self.interconnect / t),
            ("DW accelerator", self.dw_accel, 100.0 * self.dw_accel / t),
            ("DMA", self.dma, 100.0 * self.dma / t),
            ("Peripherals", self.periph, 100.0 * self.periph / t),
        ]
    }

    /// Effective PCM-array area charged to a workload that uses
    /// `devices_used` crossbar cells (the paper's "area utilization
    /// efficiency" in Fig. 9c charges only the arrays the Bottleneck maps,
    /// padding included).
    pub fn effective_pcm_mm2(&self, cfg: &SystemConfig, devices_used: usize) -> f64 {
        let per_xbar_analog = (Self::paper().ima_subsystem - 0.10).max(1e-9);
        let cells = (cfg.xbar_rows * cfg.xbar_cols) as f64;
        per_xbar_analog * devices_used as f64 / cells
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_total_is_2_5mm2() {
        let a = AreaModel::paper();
        assert!((a.total() - 2.5).abs() < 1e-9, "{}", a.total());
    }

    #[test]
    fn thirds_rule_and_dw_share() {
        let a = AreaModel::paper();
        let t = a.total();
        assert!((a.ima_subsystem / t - 0.333).abs() < 0.01);
        assert!((a.tcdm / t - 0.333).abs() < 0.01);
        assert!((a.dw_accel / t - 0.021).abs() < 0.001);
    }

    #[test]
    fn scaled_up_34_crossbars_is_about_30mm2() {
        let cfg = SystemConfig::scaled_up(34);
        let a = AreaModel::for_config(&cfg);
        // paper §VI: "minimum area of ~30 mm², since the area of the single
        // IMA is 0.83 mm²"
        assert!((26.0..32.0).contains(&a.total()), "{}", a.total());
    }

    #[test]
    fn effective_pcm_area_scales_with_devices() {
        let cfg = SystemConfig::paper();
        let a = AreaModel::paper();
        let full = a.effective_pcm_mm2(&cfg, 65536);
        let half = a.effective_pcm_mm2(&cfg, 32768);
        assert!((full - 0.73).abs() < 1e-9);
        assert!((half * 2.0 - full).abs() < 1e-12);
    }
}
