//! Power/energy model — component activity × per-component power, with the
//! paper's V/f scaling. All powers are quoted at the HIGH point (0.8 V,
//! 500 MHz) and scaled by `FreqPoint::power_factor()`; the analog IMA macro
//! keeps its own supply (constant power across cluster operating points).
//!
//! Calibration targets: peak system efficiency 6.39 TOPS/W at 958 GOPS
//! (→ ~150 mW total during peak MVM streaming), Fig. 9b ratios, and the
//! end-to-end 482 µJ.

use super::params::SystemConfig;
use super::technology::ImaScaling;

/// Per-component active/idle power at (0.8 V, 500 MHz), in watts.
#[derive(Clone, Debug)]
pub struct PowerModel {
    /// One RISC-V core, executing DSP kernels. *calibrated* (Vega-class)
    pub core_active_w: f64,
    /// One clock-gated core (event-unit sleep). *calibrated*
    pub core_idle_w: f64,
    /// TCDM at full port utilization (scaled by access duty). *calibrated*
    pub tcdm_active_w: f64,
    /// Always-on cluster infrastructure: I$, LIC, event unit. *calibrated*
    pub infra_w: f64,
    /// Depth-wise accelerator streaming+computing. *calibrated*
    pub dw_active_w: f64,
    pub dw_idle_w: f64,
    /// IMA digital wrapper (streamer, buffers, FSM) while streaming.
    /// *calibrated*
    pub ima_digital_active_w: f64,
    pub ima_digital_idle_w: f64,
    /// Analog macro power during the 130 ns MVM at full array utilization
    /// (scaled 14→22 nm from HERMES by a·b², §V-A). *derived*
    pub ima_analog_w: f64,
    /// Fraction of the analog job energy that is utilization-independent
    /// (ADC/DAC + word-line drivers). *calibrated*
    pub ima_analog_fixed_frac: f64,
}

impl PowerModel {
    pub fn paper() -> Self {
        PowerModel {
            core_active_w: 7.5e-3,
            core_idle_w: 0.4e-3,
            tcdm_active_w: 16.0e-3,
            infra_w: 8.0e-3,
            dw_active_w: 9.0e-3,
            dw_idle_w: 0.15e-3,
            ima_digital_active_w: 10.0e-3,
            ima_digital_idle_w: 0.25e-3,
            ima_analog_w: ImaScaling::default().power_w(), // ≈151 mW
            ima_analog_fixed_frac: 0.30,
        }
    }

    /// Identity fingerprint (FNV-1a over the field bits) — the cache key
    /// ingredient that keeps batch results computed under different power
    /// models from ever aliasing (`coordinator::plan_cache`).
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for f in [
            self.core_active_w,
            self.core_idle_w,
            self.tcdm_active_w,
            self.infra_w,
            self.dw_active_w,
            self.dw_idle_w,
            self.ima_digital_active_w,
            self.ima_digital_idle_w,
            self.ima_analog_w,
            self.ima_analog_fixed_frac,
        ] {
            for b in f.to_bits().to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x1000_0000_01b3);
            }
        }
        h
    }

    /// Energy of one analog MVM job using `rows_used` word-lines and
    /// `cols_used` bit-lines (J). Unused bit-lines (and their ADCs) are
    /// clock/power-gated — HERMES has per-column ADCs — so energy scales
    /// with the active columns; within an active column the fixed share
    /// (ADC conversion, drivers) is utilization-independent and the rest
    /// scales with the driven rows. Latency is the constant 130 ns.
    pub fn ima_job_energy_j(&self, cfg: &SystemConfig, rows_used: usize, cols_used: usize) -> f64 {
        let row_frac = rows_used as f64 / cfg.xbar_rows as f64;
        let col_frac = cols_used as f64 / cfg.xbar_cols as f64;
        let scale = col_frac
            * (self.ima_analog_fixed_frac + (1.0 - self.ima_analog_fixed_frac) * row_frac);
        self.ima_analog_w * cfg.ima_mvm_ns * 1e-9 * scale
    }
}

/// Integrated energy over a simulated interval: per-component busy cycles
/// accumulated by the engines, converted to joules at the end.
#[derive(Clone, Debug, Default)]
pub struct EnergyAccount {
    /// core-cycles spent computing (sum over cores).
    pub core_active_cy: u64,
    /// core-cycles spent clock-gated.
    pub core_idle_cy: u64,
    /// cycles with TCDM ports busy, weighted by port duty (×1000 fixed point).
    pub tcdm_duty_millicycles: u64,
    /// wall cycles of the measured interval (infra is always on).
    pub wall_cy: u64,
    pub dw_active_cy: u64,
    pub ima_digital_active_cy: u64,
    /// analog job energy already in joules (utilization-dependent).
    pub ima_analog_j: f64,
}

impl EnergyAccount {
    pub fn add(&mut self, other: &EnergyAccount) {
        self.core_active_cy += other.core_active_cy;
        self.core_idle_cy += other.core_idle_cy;
        self.tcdm_duty_millicycles += other.tcdm_duty_millicycles;
        self.wall_cy += other.wall_cy;
        self.dw_active_cy += other.dw_active_cy;
        self.ima_digital_active_cy += other.ima_digital_active_cy;
        self.ima_analog_j += other.ima_analog_j;
    }

    /// Total joules at the configured operating point.
    pub fn total_j(&self, pm: &PowerModel, cfg: &SystemConfig) -> f64 {
        let cy_s = cfg.freq.cycle_ns() * 1e-9;
        let pf = cfg.freq.power_factor();
        let digital = pf
            * cy_s
            * (self.core_active_cy as f64 * pm.core_active_w
                + self.core_idle_cy as f64 * pm.core_idle_w
                + self.tcdm_duty_millicycles as f64 / 1000.0 * pm.tcdm_active_w
                + self.wall_cy as f64 * pm.infra_w
                + self.dw_active_cy as f64 * pm.dw_active_w
                + self.ima_digital_active_cy as f64 * pm.ima_digital_active_w);
        // idle leakage of gated engines over the remaining wall time
        let idle = pf
            * cy_s
            * ((self.wall_cy.saturating_sub(self.dw_active_cy)) as f64 * pm.dw_idle_w
                + (self.wall_cy.saturating_sub(self.ima_digital_active_cy)) as f64
                    * pm.ima_digital_idle_w);
        digital + idle + self.ima_analog_j
    }

    /// Convenience: record `n_cores` active and the rest idle for `cy`.
    pub fn cores_busy(&mut self, cfg: &SystemConfig, n_active: usize, cy: u64) {
        self.core_active_cy += cy * n_active as u64;
        self.core_idle_cy += cy * (cfg.n_cores - n_active) as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::params::FreqPoint;

    #[test]
    fn peak_streaming_power_near_150mw() {
        // Pipelined full-utilization MVM streaming at 250 MHz: analog duty
        // ~130/140 ns, digital wrapper + TCDM active, cores gated.
        let cfg = SystemConfig::paper().with_freq(FreqPoint::LOW);
        let pm = PowerModel::paper();
        let mut acc = EnergyAccount::default();
        let jobs = 10_000u64;
        let job_cy = 35u64; // steady-state pipelined job at 250 MHz
        acc.wall_cy = jobs * job_cy;
        acc.ima_digital_active_cy = acc.wall_cy;
        acc.tcdm_duty_millicycles = acc.wall_cy * 900; // streams nearly saturate
        acc.core_idle_cy = acc.wall_cy * 8;
        acc.ima_analog_j = jobs as f64 * pm.ima_job_energy_j(&cfg, 256, 256);
        let t = acc.wall_cy as f64 * cfg.freq.cycle_ns() * 1e-9;
        let p = acc.total_j(&pm, &cfg) / t;
        assert!((0.120..0.180).contains(&p), "peak power {p} W");
    }

    #[test]
    fn analog_job_energy_scales_with_utilization() {
        let cfg = SystemConfig::paper();
        let pm = PowerModel::paper();
        let full = pm.ima_job_energy_j(&cfg, 256, 256);
        let empty = pm.ima_job_energy_j(&cfg, 0, 256);
        assert!(full > empty);
        assert!((empty / full - pm.ima_analog_fixed_frac).abs() < 1e-9);
        // full-array job ≈ 19.6 nJ
        assert!((15e-9..25e-9).contains(&full), "{full}");
    }

    #[test]
    fn cores_only_power_magnitude() {
        // 8 cores crunching PULP-NN kernels ≈ 90 mW at 0.8 V/500 MHz
        let cfg = SystemConfig::paper();
        let pm = PowerModel::paper();
        let mut acc = EnergyAccount::default();
        acc.wall_cy = 1_000_000;
        acc.cores_busy(&cfg, 8, 1_000_000);
        acc.tcdm_duty_millicycles = acc.wall_cy * 500;
        let t = acc.wall_cy as f64 * cfg.freq.cycle_ns() * 1e-9;
        let p = acc.total_j(&pm, &cfg) / t;
        assert!((0.070..0.110).contains(&p), "{p}");
    }

    #[test]
    fn low_voltage_point_cuts_energy_per_cycle_by_v_squared() {
        // P ∝ f·V² and t_cy ∝ 1/f, so energy *per cycle* ∝ V² only.
        let hi = SystemConfig::paper();
        let lo = SystemConfig::paper().with_freq(FreqPoint::LOW);
        let pm = PowerModel::paper();
        let mut acc = EnergyAccount::default();
        acc.wall_cy = 1000;
        acc.cores_busy(&hi, 8, 1000);
        let e_hi = acc.total_j(&pm, &hi);
        let e_lo = acc.total_j(&pm, &lo);
        let v_sq = (FreqPoint::LOW.vdd / FreqPoint::HIGH.vdd).powi(2);
        assert!((e_lo / e_hi - v_sq).abs() < 1e-6, "{}", e_lo / e_hi);
    }
}
