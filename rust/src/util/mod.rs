//! Infrastructure substrates built in-tree (the offline environment provides
//! no criterion/serde/clap/proptest — see DESIGN.md §9).

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod prop;
pub mod rng;
pub mod table;
pub mod units;
