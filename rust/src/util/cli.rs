//! Tiny argv parser (clap is unavailable offline).
//!
//! Supports `subcommand --flag --key value --key=value positional` shapes —
//! all the `imcc` binary needs.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `std::env::args().skip(1)`-style iterators.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                    && !Self::is_boolean_flag(rest)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Flags that never take a value even when followed by a positional.
    /// `--json`, `--trace`, and `--trace-limit` stay OFF this list on
    /// purpose: the first two take an optional filename (bare use falls
    /// through to the flag path below, picking the default name) and the
    /// limit always takes a count.
    fn is_boolean_flag(name: &str) -> bool {
        matches!(
            name,
            "help"
                | "breakdown"
                | "peak"
                | "verbose"
                | "quiet"
                | "rotate"
                | "tiny"
                | "sequential"
                | "no-pipeline"
                | "sweep"
                | "overlap"
                | "no-overlap"
                | "backfill"
                | "no-backfill"
                | "stream-weights"
                | "prune"
                | "no-prune"
                | "autoscale"
                | "no-autoscale"
                | "no-admission"
                | "gap-skip"
                | "no-gap-skip"
        )
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.opt(name) {
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!("warning: could not parse --{name} {v}; using default");
                std::process::exit(2)
            }),
            None => default,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn parses_subcommand_and_options() {
        let a = argv("roofline --freq-mhz 250 --bus=128 --peak");
        assert_eq!(a.subcommand.as_deref(), Some("roofline"));
        assert_eq!(a.opt("freq-mhz"), Some("250"));
        assert_eq!(a.opt("bus"), Some("128"));
        assert!(a.flag("peak"));
    }

    #[test]
    fn boolean_flags_do_not_swallow_positionals() {
        let a = argv("e2e --breakdown manifest.json");
        assert!(a.flag("breakdown"));
        assert_eq!(a.positional, vec!["manifest.json"]);
    }

    #[test]
    fn opt_parse_default() {
        let a = argv("x");
        assert_eq!(a.opt_parse("missing", 42u32), 42);
    }

    #[test]
    fn serve_flags_parse() {
        let a = argv("serve --models mobilenetv2,bottleneck --rate 120 --policy wrr --sweep");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.opt("models"), Some("mobilenetv2,bottleneck"));
        assert_eq!(a.opt_parse("rate", 0.0f64), 120.0);
        assert_eq!(a.opt("policy"), Some("wrr"));
        assert!(a.flag("sweep"));
    }

    #[test]
    fn scaleup_flags_parse() {
        let a = argv("scaleup --arrays 8 --batch 4 --no-pipeline");
        assert_eq!(a.subcommand.as_deref(), Some("scaleup"));
        assert_eq!(a.opt_parse("arrays", 0usize), 8);
        assert_eq!(a.opt_parse("batch", 0usize), 4);
        assert!(a.flag("no-pipeline"));
    }

    #[test]
    fn overlap_and_json_flags_parse() {
        // boolean overlap flags never swallow a following token; --json
        // doubles as a flag (default filename) or a keyed option
        let a = argv("serve --no-overlap --stream-weights --json out.json");
        assert!(a.flag("no-overlap"));
        assert!(a.flag("stream-weights"));
        assert_eq!(a.opt("json"), Some("out.json"));
        // the backfill switches are boolean too: a following token stays
        // positional (or feeds --json), never becomes the flag's "value"
        let c = argv("serve --no-backfill --json out.json");
        assert!(c.flag("no-backfill"));
        assert_eq!(c.opt("json"), Some("out.json"));
        // --no-prune is boolean too: the pruning smoke passes it right
        // before --json FILE
        let d = argv("serve --no-prune --json out.json");
        assert!(d.flag("no-prune"));
        assert_eq!(d.opt("json"), Some("out.json"));
        let b = argv("scaleup --stream-weights positional --json");
        assert!(b.flag("stream-weights"));
        assert_eq!(b.positional, vec!["positional"]);
        assert!(b.flag("json"));
        assert_eq!(b.opt("json"), None);
    }

    #[test]
    fn trace_flags_parse() {
        // --trace mirrors --json: keyed with a filename, or bare (default
        // name) when followed by another --flag or nothing
        let a = argv("serve --trace trace.json --trace-limit 5000 --json out.json");
        assert_eq!(a.opt("trace"), Some("trace.json"));
        assert_eq!(a.opt_parse("trace-limit", 0usize), 5000);
        assert_eq!(a.opt("json"), Some("out.json"));
        let b = argv("serve --no-overlap --trace --json out.json");
        assert!(b.flag("trace"));
        assert_eq!(b.opt("trace"), None);
        assert_eq!(b.opt("json"), Some("out.json"));
        let c = argv("serve --sweep --trace");
        assert!(c.flag("sweep"));
        assert!(c.flag("trace"));
    }

    #[test]
    fn fleet_fault_flags_parse() {
        // --faults and --fault-seed are valued options; the fault spec is
        // one comma-joined token so the parser never splits it
        let a = argv(
            "serve --nodes 3 --faults crash@node1:5e6..8e6,drain@node2:1e7 --json out.json",
        );
        assert_eq!(a.opt_parse("nodes", 1usize), 3);
        assert_eq!(a.opt("faults"), Some("crash@node1:5e6..8e6,drain@node2:1e7"));
        assert_eq!(a.opt("json"), Some("out.json"));
        let b = argv("serve --nodes 4 --fault-seed 0xfeed --router replica --autoscale");
        assert_eq!(b.opt("fault-seed"), Some("0xfeed"));
        assert_eq!(b.opt("router"), Some("replica"));
        assert!(b.flag("autoscale"));
        // `--faults=SPEC` keyed form works too
        let c = argv("serve --nodes 2 --faults=update@node0:1e6..2e6");
        assert_eq!(c.opt("faults"), Some("update@node0:1e6..2e6"));
    }

    #[test]
    fn admission_and_autoscale_flags_parse() {
        // --slo-p95 takes a value; the controller switches are boolean and
        // never swallow the token after them
        let a = argv("serve --slo-p95 4000000 --autoscale --json out.json");
        assert_eq!(a.opt_parse("slo-p95", 0u64), 4_000_000);
        assert!(a.flag("autoscale"));
        assert_eq!(a.opt("json"), Some("out.json"));
        let b = argv("serve --no-admission --no-autoscale --json out.json");
        assert!(b.flag("no-admission"));
        assert!(b.flag("no-autoscale"));
        assert_eq!(b.opt("json"), Some("out.json"));
    }

    #[test]
    fn fleet_flags_parse() {
        // the fleet trio are all valued options: --nodes and --router
        // must consume their tokens, and --node-arrays keeps its comma
        // list intact for the caller to split
        let a = argv("serve --nodes 4 --router least-loaded --node-arrays 64,32,12,64 --json out.json");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.opt_parse("nodes", 1usize), 4);
        assert_eq!(a.opt("router"), Some("least-loaded"));
        assert_eq!(a.opt("node-arrays"), Some("64,32,12,64"));
        assert_eq!(a.opt("json"), Some("out.json"));
        assert!(a.positional.is_empty());
        // omitted --nodes falls back to the single-cluster default
        let b = argv("serve --rate 100");
        assert_eq!(b.opt_parse("nodes", 1usize), 1);
        assert_eq!(b.opt("router"), None);
    }

    #[test]
    fn event_queue_takes_a_value_and_gap_skip_does_not() {
        // --event-queue is a valued option (not on the boolean list), so
        // it must consume the mode word, not leave it as a positional
        let a = argv("serve --event-queue heap --no-gap-skip --rate 100");
        assert_eq!(a.opt("event-queue"), Some("heap"));
        assert!(a.flag("no-gap-skip"));
        assert!(a.positional.is_empty());
        assert_eq!(a.opt("rate"), Some("100"));
        // the boolean gap-skip switches never swallow a following word
        let b = argv("serve --gap-skip positional --no-gap-skip");
        assert!(b.flag("gap-skip") && b.flag("no-gap-skip"));
        assert_eq!(b.positional, vec!["positional".to_string()]);
    }
}
