//! Property-test harness (proptest is unavailable offline).
//!
//! `check(name, cases, f)` runs `f` against `cases` seeded RNGs; on failure
//! it panics with the exact seed so `check_seed` reproduces the case. No
//! shrinking — generators should be written to produce small cases often
//! (pass small bounds first).

use super::rng::SplitMix64;

pub const DEFAULT_CASES: usize = 256;

/// Run `f(rng)` for `cases` deterministic seeds derived from `name`.
pub fn check<F: Fn(&mut SplitMix64)>(name: &str, cases: usize, f: F) {
    let base = fnv1a(name.as_bytes());
    for i in 0..cases {
        let seed = base ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = SplitMix64::new(seed);
            f(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property `{name}` failed at case {i} (seed {seed:#x}): {msg}");
        }
    }
}

/// Re-run a single failing case by seed.
pub fn check_seed<F: Fn(&mut SplitMix64)>(seed: u64, f: F) {
    let mut rng = SplitMix64::new(seed);
    f(&mut rng);
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("add_commutes", 64, |rng| {
            let a = rng.range_i64(-1000, 1000);
            let b = rng.range_i64(-1000, 1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn reports_seed_on_failure() {
        let r = std::panic::catch_unwind(|| {
            check("always_fails", 4, |_rng| {
                panic!("boom");
            });
        });
        let payload = r.unwrap_err();
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("seed"), "{msg}");
    }

    #[test]
    fn deterministic_across_runs() {
        let mut trace1 = Vec::new();
        check("trace", 8, |rng| {
            let _ = rng.next_u64(); // exercise
        });
        // seeds derive only from the name: same name -> same seeds
        let base1 = fnv1a(b"trace");
        let base2 = fnv1a(b"trace");
        assert_eq!(base1, base2);
        trace1.push(base1);
    }
}
