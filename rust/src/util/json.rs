//! Minimal JSON: a recursive-descent parser + a writer.
//!
//! Used for `artifacts/manifest.json` (the Python→Rust network contract) and
//! for machine-readable experiment reports. Supports the full JSON grammar
//! except `\u` surrogate pairs outside the BMP (not needed by the manifest).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ---- accessors ------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; panics with a useful message (manifest
    /// files are trusted build products — fail loudly on contract drift).
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("manifest missing key `{key}`"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    // ---- parser ---------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            b: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- writer ---------------------------------------------------------

    pub fn write(&self, out: &mut String, indent: usize) {
        self.write_at(out, indent, 0);
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 1);
        s
    }

    fn write_at(&self, out: &mut String, indent: usize, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write_at(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    val.write_at(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}

/// Convenience object builder: `obj([("a", 1.into()), ...])`.
pub fn obj<I: IntoIterator<Item = (&'static str, Json)>>(items: I) -> Json {
    Json::Obj(
        items
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

fn newline(out: &mut String, indent: usize, depth: usize) {
    if indent > 0 {
        out.push('\n');
        for _ in 0..indent * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(
                                char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.req("a").as_arr().unwrap().len(), 3);
        assert_eq!(
            j.req("a").as_arr().unwrap()[2].req("b").as_str(),
            Some("x")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1 2]").is_err());
        assert!(Json::parse("\"open").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"layers": [{"id": 0, "name": "conv1", "relu": 1}], "seed": 7}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string_pretty();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn roundtrip_escapes_and_unicode() {
        let j = Json::Str("tab\t quote\" bäck\\".to_string());
        let out = j.to_string_pretty();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn negative_and_large_ints_roundtrip_exactly() {
        // checksums are i64-ish; verify no precision loss in our range
        let j = Json::parse("[-9007199254740991, 9007199254740991]").unwrap();
        let v = j.as_arr().unwrap();
        assert_eq!(v[0].as_i64(), Some(-9007199254740991));
        assert_eq!(v[1].as_i64(), Some(9007199254740991));
    }
}
