//! Deterministic PRNG (SplitMix64) — seeds are printed by every randomized
//! test/bench so failures reproduce exactly. No external `rand` available.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes; the same
/// generator seeds the property-test harness (`util::prop`).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` (Lemire's method, unbiased enough for tests).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// int8 activation value (full range).
    #[inline]
    pub fn next_i8(&mut self) -> i8 {
        self.range_i64(-128, 127) as i8
    }

    /// int4 weight value (PCM conductance range).
    #[inline]
    pub fn next_i4(&mut self) -> i8 {
        self.range_i64(-8, 7) as i8
    }

    pub fn fill_i8(&mut self, buf: &mut [i8]) {
        for v in buf {
            *v = self.next_i8();
        }
    }

    pub fn fill_i4(&mut self, buf: &mut [i8]) {
        for v in buf {
            *v = self.next_i4();
        }
    }

    /// Standard normal via Box-Muller (for the PCM conductance-noise study).
    pub fn next_gauss(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn i4_range() {
        let mut r = SplitMix64::new(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..5000 {
            let v = r.next_i4();
            assert!((-8..=7).contains(&v));
            seen_lo |= v == -8;
            seen_hi |= v == 7;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = SplitMix64::new(3);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn gauss_moments() {
        let mut r = SplitMix64::new(11);
        let n = 20_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.next_gauss();
            sum += g;
            sq += g * g;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
