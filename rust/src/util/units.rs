//! Unit formatting/conversion helpers used by every report.

/// Cycles at `freq_hz` → seconds.
pub fn cycles_to_s(cycles: u64, freq_hz: f64) -> f64 {
    cycles as f64 / freq_hz
}

/// Operations (MAC = 2 ops, the paper's convention) over seconds → GOPS.
pub fn gops(ops: u64, seconds: f64) -> f64 {
    if seconds <= 0.0 {
        return 0.0;
    }
    ops as f64 / seconds / 1e9
}

/// ops / joule → TOPS/W.
pub fn tops_per_w(ops: u64, joules: f64) -> f64 {
    if joules <= 0.0 {
        return 0.0;
    }
    ops as f64 / joules / 1e12
}

pub fn fmt_si(v: f64, unit: &str) -> String {
    let (scale, prefix) = if v == 0.0 {
        (1.0, "")
    } else {
        let a = v.abs();
        if a >= 1e12 {
            (1e12, "T")
        } else if a >= 1e9 {
            (1e9, "G")
        } else if a >= 1e6 {
            (1e6, "M")
        } else if a >= 1e3 {
            (1e3, "k")
        } else if a >= 1.0 {
            (1.0, "")
        } else if a >= 1e-3 {
            (1e-3, "m")
        } else if a >= 1e-6 {
            (1e-6, "µ")
        } else if a >= 1e-9 {
            (1e-9, "n")
        } else {
            (1e-12, "p")
        }
    };
    format!("{:.3} {}{}", v / scale, prefix, unit)
}

pub fn fmt_time(seconds: f64) -> String {
    fmt_si(seconds, "s")
}

pub fn fmt_energy(joules: f64) -> String {
    fmt_si(joules, "J")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gops_basic() {
        assert!((gops(1_000_000_000, 1.0) - 1.0).abs() < 1e-12);
        assert_eq!(gops(100, 0.0), 0.0);
    }

    #[test]
    fn ima_peak_sanity() {
        // the paper's compute roof: 256*256*2 ops in 130 ns = 1.008 TOPS
        let ops = 256 * 256 * 2u64;
        let g = gops(ops, 130e-9);
        assert!((g - 1008.2).abs() < 1.0, "{g}");
    }

    #[test]
    fn si_formatting() {
        assert_eq!(fmt_si(1.5e9, "OPS"), "1.500 GOPS");
        assert_eq!(fmt_si(482e-6, "J"), "482.000 µJ");
        assert_eq!(fmt_si(0.0101, "s"), "10.100 ms");
    }

    #[test]
    fn tops_per_w_basic() {
        // 958 GOPS at 150 mW = 6.39 TOPS/W
        let e = tops_per_w(958_000_000_000, 0.150);
        assert!((e - 6.39).abs() < 0.01, "{e}");
    }
}
