//! ASCII table rendering for figure/table reports (paper-style rows).

#[derive(Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row<I: IntoIterator<Item = String>>(&mut self, cells: I) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table `{}`",
            self.title
        );
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(display_width(c));
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s.push('\n');
            s
        };
        out.push_str(&sep);
        out.push('|');
        for (i, h) in self.header.iter().enumerate() {
            out.push_str(&format!(" {:<w$} |", h, w = widths[i]));
        }
        out.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push('|');
            for (i, c) in row.iter().enumerate() {
                let pad = widths[i].saturating_sub(display_width(c));
                out.push_str(&format!(" {}{} |", c, " ".repeat(pad)));
            }
            out.push('\n');
        }
        out.push_str(&sep);
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }
}

/// Character count (not bytes) so µ/× align correctly.
fn display_width(s: &str) -> usize {
    s.chars().count()
}

/// Quick one-line f64 cell.
pub fn f(v: f64, prec: usize) -> String {
    format!("{:.*}", prec, v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "GOPS"]);
        t.row(["CORES".to_string(), "10.9".to_string()]);
        t.row(["IMA+DW".to_string(), "125.3".to_string()]);
        let s = t.render();
        assert!(s.contains("| CORES "));
        assert!(s.contains("| IMA+DW "));
        let lines: Vec<&str> = s.lines().collect();
        let w = lines[1].len();
        for l in &lines[1..] {
            assert_eq!(l.chars().count(), lines[1].chars().count(), "{w} {l}");
        }
    }

    #[test]
    #[should_panic]
    fn rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(["only-one".to_string()]);
    }

    #[test]
    fn unicode_width() {
        assert_eq!(display_width("µJ"), 2);
        assert_eq!(display_width("2.5×"), 4);
    }
}
