//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Used by every `[[bench]]` target (`harness = false`): warms up, runs N
//! timed iterations, reports min/median/mean/p95. Deterministic workloads +
//! median keep the numbers stable enough for the §Perf before/after log.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10}/iter  median {:>12}  mean {:>12}  p95 {:>12}  ({} iters)",
            self.name,
            fmt_ns(self.min_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p95_ns),
            self.iters
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{:.0} ns", ns)
    }
}

/// Run `f` repeatedly for at least `min_iters` iterations and ~`budget_ms`.
/// `f` must return something observable to defeat dead-code elimination.
pub fn bench<T, F: FnMut() -> T>(name: &str, min_iters: usize, budget_ms: u64, mut f: F) -> BenchResult {
    // warmup
    for _ in 0..2 {
        std::hint::black_box(f());
    }
    let mut samples_ns: Vec<f64> = Vec::new();
    let start = Instant::now();
    loop {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples_ns.push(t0.elapsed().as_nanos() as f64);
        let enough_iters = samples_ns.len() >= min_iters;
        let out_of_budget = start.elapsed().as_millis() as u64 >= budget_ms;
        if enough_iters && (out_of_budget || samples_ns.len() >= 10_000) {
            break;
        }
        if out_of_budget && samples_ns.len() >= 3 {
            break;
        }
    }
    samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples_ns.len();
    let median = samples_ns[n / 2];
    let mean = samples_ns.iter().sum::<f64>() / n as f64;
    let p95 = samples_ns[((n as f64 * 0.95) as usize).min(n - 1)];
    let res = BenchResult {
        name: name.to_string(),
        iters: n,
        min_ns: samples_ns[0],
        median_ns: median,
        mean_ns: mean,
        p95_ns: p95,
    };
    println!("{}", res.report());
    res
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let r = bench("noop_sum", 10, 5, || (0..100u64).sum::<u64>());
        assert!(r.iters >= 10);
        assert!(r.min_ns <= r.median_ns);
        assert!(r.median_ns <= r.p95_ns + 1.0);
    }
}
