//! Minimal error plumbing (`anyhow`/`thiserror` are unavailable offline).
//!
//! Provides the three things the runtime layer needs: a string-carrying
//! [`Error`] convertible from `io::Error`, a [`Context`] extension trait for
//! `Result`/`Option` mirroring `anyhow::Context`, and the `bail!`/`ensure!`
//! macros (exported at the crate root).

use std::fmt;

/// A flat, message-carrying error. Context wrapping concatenates messages
/// (`outer: inner`) instead of keeping a source chain — enough for a CLI
/// whose errors terminate in `eprintln!`.
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e)
    }
}

impl From<String> for Error {
    fn from(msg: String) -> Error {
        Error { msg }
    }
}

impl From<&str> for Error {
    fn from(msg: &str) -> Error {
        Error { msg: msg.to_string() }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context`-shaped extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<D: fmt::Display>(self, msg: D) -> Result<T>;
    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<D: fmt::Display>(self, msg: D) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{msg}: {e}")))
    }

    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<D: fmt::Display>(self, msg: D) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }

    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Early-return with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)).into())
    };
}

/// `bail!` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        bail_helper()
    }

    fn bail_helper() -> Result<u32> {
        crate::bail!("boom {}", 42);
    }

    #[test]
    fn bail_formats() {
        assert_eq!(fails().unwrap_err().to_string(), "boom 42");
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: u32) -> Result<u32> {
            crate::ensure!(x < 10, "too big: {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert_eq!(check(30).unwrap_err().to_string(), "too big: 30");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<u32, String> = Err("inner".into());
        assert_eq!(r.context("outer").unwrap_err().to_string(), "outer: inner");
        let o: Option<u32> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
        let ok: Option<u32> = Some(7);
        assert_eq!(ok.with_context(|| "unused").unwrap(), 7);
    }

    #[test]
    fn io_error_converts() {
        fn read() -> Result<Vec<u8>> {
            Ok(std::fs::read("/nonexistent/imcc-error-test")?)
        }
        assert!(read().is_err());
    }
}
