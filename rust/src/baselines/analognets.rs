//! Zhou et al. [7] "AnalogNets" baseline (IMA+DIG.ACC): a 1024×512 PCM
//! array (same HERMES prototype family) + fixed-function activation/pooling
//! logic and an IM2COL block — **no programmable cores**.
//!
//! Table I row is quoted from the publication; MobileNetV2 is architecturally
//! undeployable: a single array cannot host the weights (no reprogramming at
//! inference time) and residual connections have no engine to run on.

use super::{Baseline, BaselineRow};
use crate::net::mobilenetv2::mobilenet_v2;
use crate::net::LayerKind;

#[derive(Default)]
pub struct AnalogNets;

impl AnalogNets {
    /// Why MobileNetV2 cannot be deployed (paper §VII): returns the list of
    /// blocking reasons, empty if deployable.
    pub fn mnv2_blockers(&self) -> Vec<String> {
        let mut blockers = Vec::new();
        let net = mobilenet_v2(224);
        let conv_devices: usize = net
            .layers
            .iter()
            .filter(|l| l.kind == LayerKind::Conv)
            .map(|l| l.n_weights())
            .sum();
        let capacity = 1024 * 512;
        if conv_devices > capacity {
            blockers.push(format!(
                "weights need {conv_devices} devices, single array holds {capacity} \
                 (no inference-time reprogramming of PCM)"
            ));
        }
        let has_residuals = net.layers.iter().any(|l| l.kind == LayerKind::Add);
        if has_residuals {
            blockers.push(
                "residual connections require a programmable engine; only \
                 fixed activation/pooling logic is available"
                    .into(),
            );
        }
        blockers
    }
}

impl Baseline for AnalogNets {
    fn row(&self) -> BaselineRow {
        BaselineRow {
            name: "AnalogNets [7]",
            tech_nm: 14,
            area_mm2: 3.2,
            cores: "None",
            analog_imc: "1x PCM",
            array_rows: Some(1024),
            array_cols: Some(512),
            digital_acc: "ReLU, activ., im2col",
            peak_tops: 2.0,
            peak_tops_precision: "8b-4b",
            peak_tops_per_w: 13.5,
            mnv2_inf_per_s: None,
            mnv2_energy_mj: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mnv2_is_not_deployable() {
        let b = AnalogNets;
        let blockers = b.mnv2_blockers();
        assert_eq!(blockers.len(), 2, "{blockers:?}");
        assert!(b.row().mnv2_inf_per_s.is_none());
    }

    #[test]
    fn higher_peak_than_this_work_single_array() {
        // paper §VII: their bigger array (1024×512 vs 256×256) peaks higher
        // on raw MVMs — the comparison point is end-to-end flexibility
        assert!(AnalogNets.row().peak_tops > 0.958);
    }
}
