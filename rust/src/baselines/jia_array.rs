//! Jia et al. [31] baseline: 4×4 array of charge-based IMC cores with SIMD
//! near-memory digital accelerators and a NoC — but no standalone
//! programmable processor (host control via off-chip FPGA/MCU).
//!
//! Table I row quoted from the publication; MobileNetV2 marked n/a for the
//! same flexibility reasons as [7] (paper §VII: "not viable to map
//! heterogeneous workloads such as the MobileNetV2, due to the absence of a
//! programmable processor").

use super::{Baseline, BaselineRow};

#[derive(Default)]
pub struct JiaArray;

impl Baseline for JiaArray {
    fn row(&self) -> BaselineRow {
        BaselineRow {
            name: "Jia [31]",
            tech_nm: 16,
            area_mm2: 25.0,
            cores: "None",
            analog_imc: "16x charge",
            array_rows: Some(1152),
            array_cols: Some(256),
            digital_acc: "Activ., scaling, pooling",
            peak_tops: 3.0,
            peak_tops_precision: "8b-8b",
            peak_tops_per_w: 30.0,
            mnv2_inf_per_s: None,
            mnv2_energy_mj: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_matches_table1() {
        let r = JiaArray.row();
        assert_eq!(r.tech_nm, 16);
        assert_eq!(r.peak_tops, 3.0);
        assert!(r.mnv2_inf_per_s.is_none());
    }
}
