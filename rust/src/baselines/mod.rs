//! State-of-the-art baseline models (paper §VII, Table I, Fig. 13).
//!
//! Four architecture classes are compared against this work:
//!
//! * [`vega`]      — Vega [9]: the same PULP cluster generation without
//!   analog IMC or the dw accelerator (fully digital, + HWCE std-conv
//!   engine). MobileNetV2 runs in software at the low-voltage point.
//! * [`jia_mcu`]   — Jia et al. [6] (IMA+MCU): a charge-based IMC array
//!   loosely coupled to one tiny RISC-V core; point-wise on the array,
//!   everything else on the single core (the paper's footnote-2 method).
//! * [`analognets`]— Zhou et al. [7] (IMA+DIG.ACC): PCM array + fixed
//!   activation/pooling logic, *no programmable cores* — cannot run
//!   MobileNetV2 (n/a in Table I, "not deployable" in Fig. 13).
//! * [`jia_array`] — Jia et al. [31]: 16-core charge-based IMC with SIMD
//!   near-memory digital — no standalone programmable processor either.
//!
//! Each model implements [`Baseline`] so Table I / Fig. 13 render uniformly.

pub mod analognets;
pub mod jia_array;
pub mod jia_mcu;
pub mod vega;

/// A Table-I row.
#[derive(Clone, Debug)]
pub struct BaselineRow {
    pub name: &'static str,
    pub tech_nm: u32,
    pub area_mm2: f64,
    pub cores: &'static str,
    pub analog_imc: &'static str,
    pub array_rows: Option<u32>,
    pub array_cols: Option<u32>,
    pub digital_acc: &'static str,
    pub peak_tops: f64,
    pub peak_tops_precision: &'static str,
    pub peak_tops_per_w: f64,
    /// MobileNetV2 end-to-end: None = cannot deploy the network.
    pub mnv2_inf_per_s: Option<f64>,
    pub mnv2_energy_mj: Option<f64>,
}

pub trait Baseline {
    fn row(&self) -> BaselineRow;
}

pub use analognets::AnalogNets;
pub use jia_array::JiaArray;
pub use jia_mcu::JiaMcu;
pub use vega::Vega;

/// All Table-I baselines in paper column order.
pub fn all_baselines() -> Vec<Box<dyn Baseline>> {
    vec![
        Box::new(Vega::default()),
        Box::new(AnalogNets::default()),
        Box::new(JiaArray::default()),
        Box::new(JiaMcu::default()),
    ]
}
