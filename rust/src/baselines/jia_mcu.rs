//! Jia et al. [6] baseline (IMA+MCU): a 2304×256 charge-based CIM array
//! integrated with one tiny RV32IMC core over a low-bandwidth system bus.
//!
//! The paper's footnote-2 estimation method, reproduced: point-wise latency
//! from the array's peak 8b×4b MVM throughput (0.068 TOPS, scaled from the
//! published 1b×1b numbers); depth-wise + residual latency from our cluster
//! measurements scaled by ~10× (ISA) and ~7× (no 8-core parallelism) —
//! i.e. the single tiny core runs dw at ~1/70 of our 8-core rate.

use crate::arch::{PowerModel, SystemConfig};
use crate::cores::SwKernels;
use crate::net::{mobilenetv2::mobilenet_v2, LayerKind};

use super::{Baseline, BaselineRow};

pub struct JiaMcu {
    /// Peak MVM throughput at 8b×4b (TOPS), footnote 1 of Table I.
    pub mvm_peak_tops: f64,
    /// Their core is ~10× slower per-core than an XpulpV2 core [34].
    pub isa_factor: f64,
    /// MCU clock for the software part (their prototype: 65 nm, ~100 MHz).
    pub mcu_freq_hz: f64,
}

impl Default for JiaMcu {
    fn default() -> Self {
        JiaMcu {
            mvm_peak_tops: 0.068,
            isa_factor: 10.0,
            mcu_freq_hz: 100e6,
        }
    }
}

impl JiaMcu {
    /// Modeled MobileNetV2 inference time (s).
    pub fn mnv2_time_s(&self) -> f64 {
        let cfg = SystemConfig::paper();
        let pm = PowerModel::paper();
        let _ = pm;
        let net = mobilenet_v2(224);
        let sw1 = SwKernels::new(&cfg).with_cores(1);
        let mut t = 0.0f64;
        for l in &net.layers {
            match l.kind {
                LayerKind::Conv | LayerKind::Fc => {
                    // on the CIM array at its peak MVM rate
                    t += 2.0 * l.macs() as f64 / (self.mvm_peak_tops * 1e12);
                }
                _ => {
                    // dw/residual/pool on the single tiny core
                    let cy = sw1.layer_cost(l).cycles as f64 * self.isa_factor;
                    t += cy / self.mcu_freq_hz;
                }
            }
        }
        t
    }
}

impl Baseline for JiaMcu {
    fn row(&self) -> BaselineRow {
        let t = self.mnv2_time_s();
        BaselineRow {
            name: "Jia [6] (IMA+MCU)",
            tech_nm: 65,
            area_mm2: 13.5,
            cores: "1 RV32IMC",
            analog_imc: "1x charge",
            array_rows: Some(2304),
            array_cols: Some(256),
            digital_acc: "Activ., scaling, pooling",
            peak_tops: 0.068,
            peak_tops_precision: "8b-4b",
            peak_tops_per_w: 12.5,
            mnv2_inf_per_s: Some(1.0 / t),
            mnv2_energy_mj: None, // the paper also reports n/a
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mnv2_near_quarter_inference_per_second() {
        // paper Table I footnote 2: 0.23 inf/s
        let t = JiaMcu::default().mnv2_time_s();
        let inf_s = 1.0 / t;
        assert!((0.1..0.6).contains(&inf_s), "{inf_s} inf/s (paper: 0.23)");
    }

    #[test]
    fn single_core_dominates_the_time() {
        // the architectural point: the tiny core, not the CIM array, is the
        // bottleneck (two orders of magnitude vs this work)
        let b = JiaMcu::default();
        let net = mobilenet_v2(224);
        let mvm_time: f64 = net
            .layers
            .iter()
            .filter(|l| matches!(l.kind, LayerKind::Conv | LayerKind::Fc))
            .map(|l| 2.0 * l.macs() as f64 / (b.mvm_peak_tops * 1e12))
            .sum();
        assert!(mvm_time < 0.2 * b.mnv2_time_s());
    }
}
