//! Vega [9] baseline: ten-core fully-digital PULP SoC (22 nm), HWCE standard-
//! convolution accelerator, no analog IMC, no dw engine.
//!
//! The MobileNetV2 numbers are *modeled*, not quoted: we run the same
//! software cost model as our CORES strategy at Vega's efficient operating
//! point, give the HWCE a 3× boost on standard (non-pw, non-dw) convolutions,
//! and apply Vega's published energy/cycle. The paper's Table I quotes
//! 10 inf/s and 1.19 mJ — the model must land near both.

use crate::arch::{FreqPoint, PowerModel, SystemConfig};
use crate::coordinator::{run_network, Strategy};
use crate::net::mobilenetv2::mobilenet_v2;

use super::{Baseline, BaselineRow};

pub struct Vega {
    /// Vega runs MobileNetV2 at its energy-efficient point.
    pub freq: FreqPoint,
    /// HWCE speedup on standard convolutions (k > 1, non-dw).
    pub hwce_boost: f64,
    /// Vega's cluster is heavily energy-optimized vs our model cluster:
    /// measured 22 nm silicon reaches ~0.61 TOPS/W on 8-bit ML workloads;
    /// this factor rescales our cluster's energy/cycle to Vega's.
    pub energy_scale: f64,
}

impl Default for Vega {
    fn default() -> Self {
        Vega {
            freq: FreqPoint::LOW,
            hwce_boost: 3.0,
            energy_scale: 0.45,
        }
    }
}

impl Vega {
    /// Modeled MobileNetV2 end-to-end (inf/s, mJ).
    pub fn mnv2(&self) -> (f64, f64) {
        let cfg = SystemConfig::paper().with_freq(self.freq);
        let pm = PowerModel::paper();
        let net = mobilenet_v2(224);
        let rep = run_network(&net, Strategy::Cores, &cfg, &pm);
        // HWCE accelerates the k>1 standard convs (conv1 only in MNv2)
        let mut cycles = 0u64;
        for (l, lr) in net.layers.iter().zip(&rep.layers) {
            let boosted = matches!(l.kind, crate::net::LayerKind::Conv) && l.k > 1;
            cycles += if boosted {
                (lr.cycles as f64 / self.hwce_boost) as u64
            } else {
                lr.cycles
            };
        }
        let t = cycles as f64 * cfg.freq.cycle_ns() * 1e-9;
        let e = rep.energy_j * self.energy_scale;
        (1.0 / t, e * 1e3)
    }
}

impl Baseline for Vega {
    fn row(&self) -> BaselineRow {
        let (inf_s, mj) = self.mnv2();
        BaselineRow {
            name: "Vega [9]",
            tech_nm: 22,
            area_mm2: 12.0,
            cores: "9x RV32IMCF Xpulp",
            analog_imc: "None",
            array_rows: None,
            array_cols: None,
            digital_acc: "HWCE (std conv)",
            peak_tops: 0.032,
            peak_tops_precision: "ML 8b",
            peak_tops_per_w: 0.61,
            mnv2_inf_per_s: Some(inf_s),
            mnv2_energy_mj: Some(mj),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mnv2_near_10_inf_per_s() {
        // paper Table I: 10 inf/s
        let (inf_s, _) = Vega::default().mnv2();
        assert!((7.0..15.0).contains(&inf_s), "{inf_s} inf/s (paper: 10)");
    }

    #[test]
    fn mnv2_energy_near_1_19_mj() {
        let (_, mj) = Vega::default().mnv2();
        assert!((0.8..1.8).contains(&mj), "{mj} mJ (paper: 1.19)");
    }
}
