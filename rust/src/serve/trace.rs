//! Deterministic execution tracing for the serving simulator: structured
//! events, per-request latency decomposition, and Chrome `trace_event`
//! export.
//!
//! # Event model
//!
//! The event loop feeds a [`TraceRecorder`] at exactly the points where
//! simulated time is spent or a control decision lands:
//!
//! * **Batch spans** ([`BatchSpan`], one per dispatch) carry the full
//!   lifecycle of a formed batch — head arrival, the tenant's previous
//!   dispatch, the batch-window close, the migration floor, the dispatch
//!   instant, completion, which resource the gap search last advanced the
//!   start past ([`BatchSpan::blocker`]), and whether the tenant is
//!   staged.
//! * **Occupancy intervals** replay, verbatim, the intervals
//!   [`ResourceTimeline::commit`] records for the batch's
//!   [`ReservationProfile`] (via
//!   [`ReservationProfile::committed_spans`]), relocated to pool-absolute
//!   resource ids — so the traced per-resource tracks merge to *exactly*
//!   the committed timeline, by construction. Autoscale migrations replay
//!   their reprogramming profile the same way (marked with batch id 0;
//!   real batches are numbered from 1 by event-loop step).
//! * **Instant events**: admission rejections (with the predictor's
//!   verdict), lazy deadline drops, and autoscale decisions.
//!
//! # Latency decomposition
//!
//! [`decompose`] splits one request's end-to-end latency into five
//! telescoping, non-negative phases that sum to it *exactly*: queue wait
//! (arrival → the tenant's previous dispatch, head-of-line blocking),
//! batching wait (→ window close), migration stall (→ the autoscale
//! `not_before` floor), resource stall (→ dispatch; attributed to the
//! blocking resource, or to the whole pool in `--no-overlap` mode), and
//! service (→ completion). The decomposition is *always on* — it is a
//! handful of clamps per request, recorded into
//! [`LatencyBreakdown`](super::metrics::LatencyBreakdown) — so the serve
//! JSON is bit-identical whether or not a trace is being captured.
//!
//! # Zero-overhead contract
//!
//! [`TraceRecorder::Off`] is a unit variant: every recording method is an
//! inlined no-op behind a single discriminant test, the hot path
//! allocates nothing, and dispatch tables plus all [`ServeCounters`]
//! (`super::ServeCounters`) are pinned bit-identical with tracing on or
//! off by `tests/trace_regression.rs` and the CI trace smoke. With the
//! recorder on, events append to a bounded ring: past `limit` the oldest
//! events are dropped and counted in `truncated_events` — a visible
//! counter, never a silent cap.
//!
//! # Viewing a trace (Perfetto how-to)
//!
//! `imcc serve --trace out.json` writes Chrome `trace_event` JSON. Open
//! <https://ui.perfetto.dev> (or `chrome://tracing`) and load the file:
//! each tenant is one *process* (pid = tenant index + 1) whose first
//! track holds the batch lifecycle phases (window/migration/stall/
//! service), the second the control instants (rejections, drops, scale
//! events), and one further track per pool resource the tenant occupied
//! (core0..7, dw_acc, ima_mux, dma, pcm_prog, each array). Timestamps
//! are microseconds of simulated time; batch/blocker metadata rides in
//! each slice's `args`.

use std::collections::{BTreeMap, VecDeque};

use crate::coordinator::timeline::{res_label, IntervalSet, ResMap, ResourceTimeline};
use crate::coordinator::ReservationProfile;
use crate::util::json::{obj, Json};

use super::autoscale::ScaleEvent;
use super::ServeReport;

/// Pseudo resource id for "the whole pool": resource stalls in
/// `--no-overlap` mode (where batches serialize on one opaque server)
/// are attributed here, since no single timeline resource is to blame.
pub const RES_POOL: usize = usize::MAX;

/// [`res_label`] extended with the pool sentinel.
pub fn stall_label(res: usize) -> String {
    if res == RES_POOL {
        "pool".into()
    } else {
        res_label(res)
    }
}

/// Default event cap (per run) before the ring starts dropping oldest
/// events: ~1M events, far above any shipped scenario.
pub const DEFAULT_TRACE_LIMIT: usize = 1 << 20;

/// One request's latency, split into five phases that sum exactly to
/// end-to-end (completion − arrival). All cycles.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RequestPhases {
    /// Arrival → the tenant's previous dispatch (head-of-line wait).
    pub queue_wait: u64,
    /// → the batch window's close (filling or timing out).
    pub batch_wait: u64,
    /// → the autoscale migration floor (`not_before`).
    pub migration_stall: u64,
    /// → dispatch: ready but resources busy.
    pub resource_stall: u64,
    /// Dispatch → batch completion.
    pub service: u64,
}

impl RequestPhases {
    /// Sum of all phases — exactly the end-to-end latency.
    pub fn total(&self) -> u64 {
        self.queue_wait + self.batch_wait + self.migration_stall + self.resource_stall
            + self.service
    }
}

/// Split one admitted request's latency into phases. `a` is its arrival,
/// `prev_dispatch` the tenant's previous dispatch instant (0 before the
/// first), `close` the batch window's close, `not_before` the migration
/// floor, `t` the dispatch instant, `end` the batch completion. Each
/// boundary is clamped into the window left by the previous one, so the
/// phases are non-negative and telescope to `end - a` no matter how the
/// instants interleave (a request arriving after the window closed, a
/// floor already in the past, …). Requires `a ≤ t ≤ end` — which the
/// dispatcher guarantees for every admitted request.
pub fn decompose(
    a: u64,
    prev_dispatch: u64,
    close: u64,
    not_before: u64,
    t: u64,
    end: u64,
) -> RequestPhases {
    let c1 = close.clamp(a, t);
    let w = prev_dispatch.clamp(a, c1);
    let c2 = not_before.clamp(c1, t);
    RequestPhases {
        queue_wait: w - a,
        batch_wait: c1 - w,
        migration_stall: c2 - c1,
        resource_stall: t - c2,
        service: end - t,
    }
}

/// One dispatched batch's lifecycle (all instants in absolute cycles).
#[derive(Clone, Copy, Debug)]
pub struct BatchSpan {
    pub tenant: usize,
    /// Event-loop step that dispatched it (1-based; 0 marks autoscale
    /// migration occupancy, which has no batch).
    pub batch: u64,
    /// Requests admitted.
    pub size: usize,
    /// Arrival of the batch's oldest request.
    pub head_arrival: u64,
    /// The tenant's previous dispatch (0 before the first).
    pub prev_dispatch: u64,
    /// When the batch window closed (head + max-wait, or the max-batch'th
    /// arrival — clamped to the dispatch instant).
    pub window_close: u64,
    /// Migration floor active at dispatch (0 = none).
    pub not_before: u64,
    pub dispatch: u64,
    pub end: u64,
    /// Pool-absolute resource the gap search last advanced the start
    /// past; `None` = the profile fit at its floor, [`RES_POOL`] = the
    /// serialized single-server clock.
    pub blocker: Option<usize>,
    /// The tenant runs staged passes (weights reprogrammed per pass).
    pub staged: bool,
}

/// One recorded event. Events are appended in simulation order, which is
/// deterministic under a fixed seed — the exported bytes are too.
#[derive(Clone, Debug)]
pub enum TraceEvent {
    /// A dispatched batch's lifecycle span.
    Batch(BatchSpan),
    /// One committed busy interval on one pool resource (absolute
    /// cycles, pool-absolute id) — replayed from the committed profile.
    Occupancy {
        tenant: usize,
        batch: u64,
        res: usize,
        start: u64,
        end: u64,
    },
    /// Admission refused an arrival at the front door.
    Reject {
        tenant: usize,
        t: u64,
        arrival: u64,
        depth: usize,
        predicted_cy: u64,
    },
    /// Lazy deadline expiry dropped `count` queued requests at `t`.
    Drops { tenant: usize, t: u64, count: u64 },
    /// The autoscaler applied a resize.
    Scale(ScaleEvent),
    /// A node-level fault instant on this node (crash, drain, update,
    /// recover, rejoin, arrayfail) — rendered on a node-scoped control
    /// track (pid 0) since it belongs to no single tenant.
    Fault { t: u64, label: &'static str },
    /// A failover hand-off landing on this node (`rejoin` false: the
    /// stream fled a dead/draining `peer`) or a parked stream returning
    /// at a staged rejoin (`rejoin` true; `peer` is the node itself).
    Failover {
        tenant: usize,
        t: u64,
        peer: usize,
        moved: usize,
        rejoin: bool,
    },
}

/// The live recording state behind [`TraceRecorder::On`]: a bounded ring
/// of events plus the end-of-run timeline snapshot.
#[derive(Clone, Debug)]
pub struct TraceBuffer {
    events: VecDeque<TraceEvent>,
    limit: usize,
    truncated: u64,
    final_intervals: Vec<(usize, Vec<(u64, u64)>)>,
}

impl TraceBuffer {
    fn push(&mut self, ev: TraceEvent) {
        if self.events.len() >= self.limit {
            self.events.pop_front();
            self.truncated += 1;
        }
        self.events.push_back(ev);
    }
}

/// The recorder handed through the event loop. [`TraceRecorder::Off`] is
/// the default everywhere (sweeps, the library entry points, benches):
/// every method below is a no-op behind one discriminant test and the
/// simulation allocates nothing for tracing.
#[derive(Clone, Debug, Default)]
pub enum TraceRecorder {
    #[default]
    Off,
    On(Box<TraceBuffer>),
}

impl TraceRecorder {
    /// A live recorder capped at `limit` events (oldest dropped past it,
    /// counted — never silently).
    pub fn on(limit: usize) -> TraceRecorder {
        TraceRecorder::On(Box::new(TraceBuffer {
            events: VecDeque::new(),
            limit: limit.max(1),
            truncated: 0,
            final_intervals: Vec::new(),
        }))
    }

    #[inline]
    pub fn is_on(&self) -> bool {
        matches!(self, TraceRecorder::On(_))
    }

    #[inline]
    pub fn batch(&mut self, span: BatchSpan) {
        if let TraceRecorder::On(b) = self {
            b.push(TraceEvent::Batch(span));
        }
    }

    /// Replay the intervals `commit(t, prof, map)` records — every merged
    /// busy interval in backfill mode, the first-use→last-release envelope
    /// otherwise — as occupancy events in pool-absolute, absolute-time
    /// coordinates. Empty intervals are skipped, exactly as `commit`
    /// skips them.
    #[inline]
    pub fn occupancy(
        &mut self,
        tenant: usize,
        batch: u64,
        t: u64,
        prof: &ReservationProfile,
        map: ResMap,
        backfill: bool,
    ) {
        if let TraceRecorder::On(b) = self {
            for (res, a0, b0) in prof.committed_spans(backfill) {
                if a0 < b0 {
                    b.push(TraceEvent::Occupancy {
                        tenant,
                        batch,
                        res: map.map(res),
                        start: t + a0,
                        end: t + b0,
                    });
                }
            }
        }
    }

    #[inline]
    pub fn reject(&mut self, tenant: usize, t: u64, arrival: u64, depth: usize, predicted_cy: u64) {
        if let TraceRecorder::On(b) = self {
            b.push(TraceEvent::Reject {
                tenant,
                t,
                arrival,
                depth,
                predicted_cy,
            });
        }
    }

    #[inline]
    pub fn drops(&mut self, tenant: usize, t: u64, count: u64) {
        if let TraceRecorder::On(b) = self {
            b.push(TraceEvent::Drops { tenant, t, count });
        }
    }

    #[inline]
    pub fn scale(&mut self, ev: ScaleEvent) {
        if let TraceRecorder::On(b) = self {
            b.push(TraceEvent::Scale(ev));
        }
    }

    #[inline]
    pub fn fault(&mut self, t: u64, label: &'static str) {
        if let TraceRecorder::On(b) = self {
            b.push(TraceEvent::Fault { t, label });
        }
    }

    #[inline]
    pub fn failover(&mut self, tenant: usize, t: u64, peer: usize, moved: usize, rejoin: bool) {
        if let TraceRecorder::On(b) = self {
            b.push(TraceEvent::Failover {
                tenant,
                t,
                peer,
                moved,
                rejoin,
            });
        }
    }

    /// Snapshot the committed per-resource interval sets at end of run —
    /// the ground truth the traced occupancy events must merge to
    /// (`tests/trace_regression.rs` pins the conservation).
    pub fn capture_timeline(&mut self, timeline: &ResourceTimeline) {
        if let TraceRecorder::On(b) = self {
            b.final_intervals = timeline
                .committed_intervals()
                .map(|(r, iv)| (r, iv.to_vec()))
                .collect();
        }
    }

    /// Consume the recorder into the finished trace (`None` when off).
    pub fn finish(self) -> Option<ServeTrace> {
        match self {
            TraceRecorder::Off => None,
            TraceRecorder::On(b) => Some(ServeTrace {
                events: b.events.into(),
                limit: b.limit,
                truncated_events: b.truncated,
                final_intervals: b.final_intervals,
            }),
        }
    }
}

/// A finished recording: the event stream in simulation order, the cap it
/// ran under, how many events the cap dropped (0 = complete), and the
/// end-of-run committed timeline snapshot.
#[derive(Clone, Debug)]
pub struct ServeTrace {
    pub events: Vec<TraceEvent>,
    pub limit: usize,
    pub truncated_events: u64,
    /// `(pool-absolute resource, merged committed intervals)`, ascending.
    pub final_intervals: Vec<(usize, Vec<(u64, u64)>)>,
}

impl ServeTrace {
    /// Merge every recorded occupancy event per resource — with no
    /// truncation and pruning off this equals [`Self::final_intervals`]
    /// exactly (span conservation).
    pub fn merged_occupancy(&self) -> BTreeMap<usize, IntervalSet> {
        let mut merged: BTreeMap<usize, IntervalSet> = BTreeMap::new();
        for ev in &self.events {
            if let TraceEvent::Occupancy { res, start, end, .. } = *ev {
                merged.entry(res).or_default().insert(start, end);
            }
        }
        merged
    }

    #[allow(clippy::type_complexity)]
    fn counts(&self) -> (u64, u64, u64, u64, u64, u64, u64) {
        let (mut batches, mut occ, mut rejects, mut drops, mut scales) = (0, 0, 0, 0, 0);
        let (mut faults, mut failovers) = (0, 0);
        for ev in &self.events {
            match ev {
                TraceEvent::Batch(_) => batches += 1,
                TraceEvent::Occupancy { .. } => occ += 1,
                TraceEvent::Reject { .. } => rejects += 1,
                TraceEvent::Drops { .. } => drops += 1,
                TraceEvent::Scale(_) => scales += 1,
                TraceEvent::Fault { .. } => faults += 1,
                TraceEvent::Failover { .. } => failovers += 1,
            }
        }
        (batches, occ, rejects, drops, scales, faults, failovers)
    }

    /// The compact summary the CLI prints next to the export path. The
    /// fault/failover tallies only appear when a fault plan produced
    /// some — a no-fault trace summary is byte-identical to earlier
    /// releases.
    pub fn render_summary(&self) -> String {
        let (batches, occ, rejects, drops, scales, faults, failovers) = self.counts();
        let chaos = if faults + failovers > 0 {
            format!(", {faults} fault marks, {failovers} failovers")
        } else {
            String::new()
        };
        format!(
            "trace: {} events ({} batch spans, {} occupancy intervals, {} rejects, \
             {} drop batches, {} scale events{}), limit {}, truncated {}\n",
            self.events.len(),
            batches,
            occ,
            rejects,
            drops,
            scales,
            chaos,
            self.limit,
            self.truncated_events,
        )
    }
}

/// Microseconds of simulated time for a cycle count (Chrome traces use
/// µs timestamps; `displayTimeUnit` renders them as ms).
fn us(cy: u64, cycle_ns: f64) -> f64 {
    cy as f64 * cycle_ns * 1e-3
}

fn pid_of(tenant: usize) -> i64 {
    tenant as i64 + 1
}

/// The node-scoped process fault instants render under (tenant pids
/// start at 1, so 0 is free).
const PID_NODE: i64 = 0;

/// Batch-lifecycle track.
const TID_LIFE: i64 = 1;
/// Control instants (rejects, drops, scale events).
const TID_CTRL: i64 = 2;
/// Resource `res` renders on thread `TID_RES0 + res`.
const TID_RES0: i64 = 3;

fn complete_event(
    name: &str,
    cat: &'static str,
    pid: i64,
    tid: i64,
    ts_cy: u64,
    dur_cy: u64,
    cycle_ns: f64,
    args: Json,
) -> Json {
    obj([
        ("name", name.into()),
        ("cat", cat.into()),
        ("ph", "X".into()),
        ("pid", pid.into()),
        ("tid", tid.into()),
        ("ts", us(ts_cy, cycle_ns).into()),
        ("dur", us(dur_cy, cycle_ns).into()),
        ("args", args),
    ])
}

fn instant_event(
    name: &'static str,
    pid: i64,
    tid: i64,
    ts_cy: u64,
    cycle_ns: f64,
    args: Json,
) -> Json {
    obj([
        ("name", name.into()),
        ("cat", "control".into()),
        ("ph", "i".into()),
        ("s", "t".into()), // thread-scoped instant
        ("pid", pid.into()),
        ("tid", tid.into()),
        ("ts", us(ts_cy, cycle_ns).into()),
        ("args", args),
    ])
}

fn metadata_event(name: &'static str, pid: i64, tid: Option<i64>, label: String) -> Json {
    let mut fields = vec![
        ("name", name.into()),
        ("ph", "M".into()),
        ("pid", pid.into()),
        ("args", obj([("name", label.into())])),
    ];
    if let Some(tid) = tid {
        fields.push(("tid", tid.into()));
    }
    obj(fields)
}

/// Render a finished trace as Chrome `trace_event` JSON (the
/// `{"traceEvents": [...]}` object form): one *process* per tenant, the
/// lifecycle/control/per-resource *threads* described in the module docs,
/// metadata events naming them all. Deterministic bytes: events are
/// emitted in recorded order behind sorted-key objects.
pub fn chrome_trace(rep: &ServeReport, tr: &ServeTrace) -> Json {
    // name every (pid, tid) pair actually used, so Perfetto shows model
    // names and resource labels instead of bare ids
    let mut tids: BTreeMap<(i64, i64), String> = BTreeMap::new();
    for ev in &tr.events {
        match ev {
            TraceEvent::Batch(s) => {
                tids.insert((pid_of(s.tenant), TID_LIFE), "batches".into());
            }
            TraceEvent::Occupancy { tenant, res, .. } => {
                tids.insert((pid_of(*tenant), TID_RES0 + *res as i64), res_label(*res));
            }
            TraceEvent::Reject { tenant, .. } | TraceEvent::Drops { tenant, .. } => {
                tids.insert((pid_of(*tenant), TID_CTRL), "control".into());
            }
            TraceEvent::Scale(ev) => {
                tids.insert((pid_of(ev.tenant), TID_CTRL), "control".into());
            }
            TraceEvent::Fault { .. } => {
                tids.insert((PID_NODE, TID_CTRL), "faults".into());
            }
            TraceEvent::Failover { tenant, .. } => {
                tids.insert((pid_of(*tenant), TID_CTRL), "control".into());
            }
        }
    }
    let mut events: Vec<Json> = Vec::with_capacity(tr.events.len() + tids.len() + rep.tenants.len());
    // the node-scoped fault track gets its own process — only when a
    // fault plan actually marked this node, so no-fault exports are
    // byte-identical to earlier releases
    if tids.contains_key(&(PID_NODE, TID_CTRL)) {
        events.push(metadata_event("process_name", PID_NODE, None, "node".into()));
    }
    for (i, s) in rep.tenants.iter().enumerate() {
        events.push(metadata_event(
            "process_name",
            pid_of(i),
            None,
            s.name.to_string(),
        ));
    }
    for (&(pid, tid), label) in &tids {
        events.push(metadata_event("thread_name", pid, Some(tid), label.clone()));
    }
    let cyns = rep.cycle_ns;
    for ev in &tr.events {
        match ev {
            TraceEvent::Batch(s) => {
                let pid = pid_of(s.tenant);
                let c1 = s.window_close.clamp(s.head_arrival, s.dispatch);
                let c2 = s.not_before.clamp(c1, s.dispatch);
                let args = obj([
                    ("batch", (s.batch as f64).into()),
                    ("size", s.size.into()),
                    (
                        "blocker",
                        match s.blocker {
                            Some(r) => stall_label(r).into(),
                            None => Json::Null,
                        },
                    ),
                    ("staged", s.staged.into()),
                ]);
                // zero-width phases are omitted; service always renders so
                // every batch is visible even when it dispatched instantly
                if c1 > s.head_arrival {
                    events.push(complete_event(
                        "window", "batch", pid, TID_LIFE, s.head_arrival, c1 - s.head_arrival,
                        cyns, args.clone(),
                    ));
                }
                if c2 > c1 {
                    events.push(complete_event(
                        "migration", "batch", pid, TID_LIFE, c1, c2 - c1, cyns, args.clone(),
                    ));
                }
                if s.dispatch > c2 {
                    events.push(complete_event(
                        "stall", "batch", pid, TID_LIFE, c2, s.dispatch - c2, cyns, args.clone(),
                    ));
                }
                events.push(complete_event(
                    "service", "batch", pid, TID_LIFE, s.dispatch, s.end - s.dispatch, cyns, args,
                ));
            }
            TraceEvent::Occupancy { tenant, batch, res, start, end } => {
                events.push(complete_event(
                    &res_label(*res),
                    "occupancy",
                    pid_of(*tenant),
                    TID_RES0 + *res as i64,
                    *start,
                    end - start,
                    cyns,
                    obj([("batch", (*batch as f64).into())]),
                ));
            }
            TraceEvent::Reject { tenant, t, arrival, depth, predicted_cy } => {
                events.push(instant_event(
                    "reject",
                    pid_of(*tenant),
                    TID_CTRL,
                    *t,
                    cyns,
                    obj([
                        ("arrival_cy", (*arrival as f64).into()),
                        ("depth", (*depth).into()),
                        ("predicted_cy", (*predicted_cy as f64).into()),
                    ]),
                ));
            }
            TraceEvent::Drops { tenant, t, count } => {
                events.push(instant_event(
                    "drop",
                    pid_of(*tenant),
                    TID_CTRL,
                    *t,
                    cyns,
                    obj([("count", (*count as f64).into())]),
                ));
            }
            TraceEvent::Scale(ev) => {
                events.push(instant_event(
                    ev.kind.label(),
                    pid_of(ev.tenant),
                    TID_CTRL,
                    ev.t,
                    cyns,
                    obj([
                        ("from_arrays", ev.from_arrays.into()),
                        ("to_arrays", ev.to_arrays.into()),
                        ("program_cycles", (ev.program_cycles as f64).into()),
                        ("blocked_cycles", (ev.blocked_cycles as f64).into()),
                        ("streamed", ev.streamed.into()),
                    ]),
                ));
            }
            TraceEvent::Fault { t, label } => {
                events.push(instant_event(label, PID_NODE, TID_CTRL, *t, cyns, obj([])));
            }
            TraceEvent::Failover {
                tenant,
                t,
                peer,
                moved,
                rejoin,
            } => {
                events.push(instant_event(
                    if *rejoin { "rejoin" } else { "failover" },
                    pid_of(*tenant),
                    TID_CTRL,
                    *t,
                    cyns,
                    obj([
                        ("moved", (*moved).into()),
                        ("peer_node", (*peer).into()),
                    ]),
                ));
            }
        }
    }
    obj([
        ("displayTimeUnit", "ms".into()),
        ("event_limit", tr.limit.into()),
        ("seed", format!("{:#x}", rep.seed).into()),
        ("truncated_events", (tr.truncated_events as f64).into()),
        ("traceEvents", Json::Arr(events)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decompose_telescopes_for_any_instant_interleaving() {
        // every phase boundary ordering, including degenerate ones
        let pts = [0u64, 3, 5, 8, 10];
        for &a in &pts {
            for &prev in &pts {
                for &close in &pts {
                    for &nb in &pts {
                        for &t in &pts {
                            if t < a {
                                continue; // dispatch precedes arrival: impossible
                            }
                            let end = t + 7;
                            let ph = decompose(a, prev, close, nb, t, end);
                            assert_eq!(ph.total(), end - a, "a={a} prev={prev} close={close} nb={nb} t={t}");
                            assert_eq!(ph.service, 7);
                        }
                    }
                }
            }
        }
        // the canonical well-ordered case lands each phase exactly
        let ph = decompose(0, 2, 5, 7, 10, 30);
        assert_eq!(
            ph,
            RequestPhases {
                queue_wait: 2,
                batch_wait: 3,
                migration_stall: 2,
                resource_stall: 3,
                service: 20,
            }
        );
    }

    #[test]
    fn off_recorder_records_nothing_and_finishes_none() {
        let mut rec = TraceRecorder::Off;
        assert!(!rec.is_on());
        rec.reject(0, 10, 5, 3, 99);
        rec.drops(0, 10, 2);
        assert!(rec.finish().is_none());
    }

    #[test]
    fn truncation_drops_oldest_and_counts() {
        let mut rec = TraceRecorder::on(2);
        for i in 0..5u64 {
            rec.drops(0, i, 1);
        }
        let tr = rec.finish().unwrap();
        assert_eq!(tr.events.len(), 2);
        assert_eq!(tr.truncated_events, 3);
        // the survivors are the *newest* events
        match (&tr.events[0], &tr.events[1]) {
            (TraceEvent::Drops { t: t0, .. }, TraceEvent::Drops { t: t1, .. }) => {
                assert_eq!((*t0, *t1), (3, 4));
            }
            other => panic!("unexpected events {other:?}"),
        }
    }

    #[test]
    fn stall_labels_cover_pool_and_resources() {
        assert_eq!(stall_label(RES_POOL), "pool");
        assert_eq!(stall_label(0), "core0");
        assert_eq!(stall_label(crate::coordinator::timeline::RES_DWACC), "dw_acc");
    }

    #[test]
    fn merged_occupancy_merges_adjacent_intervals() {
        let mut rec = TraceRecorder::on(DEFAULT_TRACE_LIMIT);
        if let TraceRecorder::On(b) = &mut rec {
            for (s, e) in [(0u64, 5u64), (5, 9), (12, 14)] {
                b.push(TraceEvent::Occupancy { tenant: 0, batch: 1, res: 3, start: s, end: e });
            }
        }
        let tr = rec.finish().unwrap();
        let merged = tr.merged_occupancy();
        assert_eq!(merged[&3].to_vec(), &[(0, 9), (12, 14)]);
    }
}
