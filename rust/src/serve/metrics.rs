//! Serving metrics: fixed-bin logarithmic latency histograms and per-model
//! serving statistics.
//!
//! Percentiles come from a fixed-size log2 histogram (8 linear sub-bins per
//! power of two, the HdrHistogram idea shrunk to one page): recording is
//! O(1) with no allocation on the serving path, quantiles resolve to the
//! lower bound of the owning bin (≤ 12.5 % relative error — far below the
//! run-to-run variation any real deployment sees), and because bins are
//! integers the reported p50/p95/p99 are *bit-identical* across runs with
//! the same seed, which the determinism tests pin.
//!
//! Reported percentiles are *auditable*: [`LogHistogram::quantile_bounds`]
//! exposes the `[lo, hi)` bounds of the bin a quantile resolved to (the
//! serve JSON carries them as `latency_bins`), so a consumer can verify
//! that every reported p50/p95/p99 lies inside its own bin instead of
//! trusting the floor convention blindly.
//!
//! [`LatencyBreakdown`] carries the per-request latency decomposition the
//! event loop derives at every dispatch (see `serve::trace::decompose`):
//! one histogram per phase — queue wait, batching wait, migration stall,
//! resource stall, service — whose per-request components sum *exactly* to
//! the end-to-end latency, so the phase `sum()`s conserve against the
//! latency histogram's total cycle count.

use std::rc::Rc;

use super::trace::RequestPhases;

/// Linear sub-bins per octave: 2^3 = 8.
const SUB_BITS: u32 = 3;
const SUB: usize = 1 << SUB_BITS;
/// Octaves 3..=63 carry 8 sub-bins each; values 0..=7 get exact bins.
const BINS: usize = SUB * 62;

/// Fixed-footprint log-scale histogram over `u64` values (cycles).
#[derive(Clone, Debug)]
pub struct LogHistogram {
    counts: Vec<u64>,
    n: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram::new()
    }
}

impl LogHistogram {
    pub fn new() -> LogHistogram {
        LogHistogram {
            counts: vec![0; BINS],
            n: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    fn bin_of(v: u64) -> usize {
        if v < SUB as u64 {
            return v as usize; // exact bins for 0..=7
        }
        let msb = 63 - v.leading_zeros(); // ≥ SUB_BITS here
        let sub = ((v >> (msb - SUB_BITS)) & (SUB as u64 - 1)) as usize;
        (msb as usize - 2) * SUB + sub
    }

    /// Lower bound of bin `b` — the value a quantile query reports.
    fn bin_floor(b: usize) -> u64 {
        if b < SUB {
            return b as u64;
        }
        let msb = (b / SUB + 2) as u32;
        let sub = (b % SUB) as u64;
        (SUB as u64 + sub) << (msb - SUB_BITS)
    }

    pub fn record(&mut self, v: u64) {
        self.counts[Self::bin_of(v)] += 1;
        self.n += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Exact revocation of one previously recorded value — the crash
    /// path un-counts in-flight requests a dying node never finished.
    /// Bins, count, and sum return to their prior state bit-exactly;
    /// `min`/`max` stay high-water marks (a revoked extreme is not
    /// forgotten), which can only widen the reported envelope — the
    /// percentiles themselves are recomputed from the exact bins.
    pub fn remove(&mut self, v: u64) {
        let b = Self::bin_of(v);
        debug_assert!(self.counts[b] > 0, "removing {v} that was never recorded");
        self.counts[b] -= 1;
        self.n -= 1;
        self.sum -= v as u128;
    }

    /// Fold another histogram into this one, bin-wise — the fleet's
    /// aggregate percentiles merge per-node histograms without
    /// re-binning. Bins are globally fixed, so the merge reports exactly
    /// what one histogram over the union of samples would.
    pub fn merge(&mut self, other: &LogHistogram) {
        for (c, &o) in self.counts.iter_mut().zip(other.counts.iter()) {
            *c += o;
        }
        self.n += other.n;
        self.sum += other.sum;
        if other.n > 0 {
            self.min = self.min.min(other.min);
            self.max = self.max.max(other.max);
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn min(&self) -> u64 {
        if self.n == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum as f64 / self.n as f64
        }
    }

    /// Quantile `q` in [0, 1]: the lower bound of the bin holding the
    /// ⌈q·n⌉-th smallest sample (0 when empty).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.n == 0 {
            return 0;
        }
        let target = ((q * self.n as f64).ceil() as u64).clamp(1, self.n);
        let mut seen = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::bin_floor(b);
            }
        }
        self.max
    }

    /// The serving table's (p50, p95, p99).
    pub fn percentiles(&self) -> (u64, u64, u64) {
        (self.quantile(0.50), self.quantile(0.95), self.quantile(0.99))
    }

    /// Exact total of all recorded values (no binning error).
    pub fn sum(&self) -> u128 {
        self.sum
    }

    /// The bin a value lands in — public so audits can cross-check a
    /// reported percentile against [`Self::bin_bounds`].
    pub fn bin_index(v: u64) -> usize {
        Self::bin_of(v)
    }

    /// Half-open value range `[lo, hi)` covered by bin `b`. The last
    /// bin's true upper edge is 2^64, which does not fit in a `u64`, so
    /// it saturates to `u64::MAX`.
    pub fn bin_bounds(b: usize) -> (u64, u64) {
        let lo = Self::bin_floor(b);
        let hi = if b + 1 >= BINS {
            u64::MAX
        } else {
            Self::bin_floor(b + 1)
        };
        (lo, hi)
    }

    /// The `[lo, hi)` bounds of the bin quantile `q` resolves to:
    /// `quantile(q)` reports exactly `lo`, and the sample it stands for
    /// is `< hi`. Surfaced in the serve JSON as `latency_bins` so the
    /// reported percentiles are auditable. `(0, 0)` when empty.
    pub fn quantile_bounds(&self, q: f64) -> (u64, u64) {
        if self.n == 0 {
            return (0, 0);
        }
        let target = ((q * self.n as f64).ceil() as u64).clamp(1, self.n);
        let mut seen = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::bin_bounds(b);
            }
        }
        (self.max, u64::MAX)
    }
}

/// Per-phase latency decomposition for one tenant: five histograms whose
/// per-request components telescope exactly to the end-to-end latency
/// (`serve::trace::decompose` guarantees the sum). Always on — deriving
/// the phases is a handful of clamps per dispatched request — so the
/// serve JSON carries the same breakdown whether or not tracing is.
#[derive(Clone, Debug, Default)]
pub struct LatencyBreakdown {
    /// Arrival → the batch window opening (head-of-line wait behind the
    /// tenant's previous dispatch).
    pub queue_wait: LogHistogram,
    /// Waiting for the batch window to fill or time out.
    pub batch_wait: LogHistogram,
    /// Held back by an in-flight autoscale migration (`not_before`).
    pub migration_stall: LogHistogram,
    /// Ready but resources busy — attributed to the blocking resource
    /// in [`StallShare`].
    pub resource_stall: LogHistogram,
    /// Dispatch → batch completion.
    pub service: LogHistogram,
}

impl LatencyBreakdown {
    pub fn record(&mut self, ph: &RequestPhases) {
        self.queue_wait.record(ph.queue_wait);
        self.batch_wait.record(ph.batch_wait);
        self.migration_stall.record(ph.migration_stall);
        self.resource_stall.record(ph.resource_stall);
        self.service.record(ph.service);
    }

    /// Exact revocation of one recorded decomposition (crash-revoked
    /// in-flight work) — phase-wise [`LogHistogram::remove`], so the
    /// components-sum-to-latency conservation law survives the crash.
    pub fn remove(&mut self, ph: &RequestPhases) {
        self.queue_wait.remove(ph.queue_wait);
        self.batch_wait.remove(ph.batch_wait);
        self.migration_stall.remove(ph.migration_stall);
        self.resource_stall.remove(ph.resource_stall);
        self.service.remove(ph.service);
    }

    /// Bin-wise merge of another breakdown (fleet aggregation) —
    /// phase-wise [`LogHistogram::merge`], so conservation against the
    /// merged latency histogram survives the fold.
    pub fn merge(&mut self, other: &LatencyBreakdown) {
        self.queue_wait.merge(&other.queue_wait);
        self.batch_wait.merge(&other.batch_wait);
        self.migration_stall.merge(&other.migration_stall);
        self.resource_stall.merge(&other.resource_stall);
        self.service.merge(&other.service);
    }

    /// Phase name → histogram, in decomposition order.
    pub fn phases(&self) -> [(&'static str, &LogHistogram); 5] {
        [
            ("queue_wait", &self.queue_wait),
            ("batch_wait", &self.batch_wait),
            ("migration_stall", &self.migration_stall),
            ("resource_stall", &self.resource_stall),
            ("service", &self.service),
        ]
    }

    /// Total cycles across all phases — equals the end-to-end latency
    /// histogram's `sum()` exactly (the conservation law
    /// `tests/trace_regression.rs` pins).
    pub fn components_sum(&self) -> u128 {
        self.phases().iter().map(|(_, h)| h.sum()).sum()
    }
}

/// One resource's share of all resource-stall cycles: when a dispatch
/// was delayed past its floor by a busy resource, the stalled cycles of
/// every request in the batch are charged to the resource the gap
/// search last advanced past
/// (`ResourceTimeline::earliest_start_blocked`), or to the whole pool
/// (`serve::trace::RES_POOL`) in `--no-overlap` mode.
#[derive(Clone, Debug)]
pub struct StallShare {
    pub name: Rc<str>,
    /// Pool-absolute resource id (`trace::RES_POOL` when serialized).
    pub res: usize,
    pub stalled_cycles: u64,
}

/// One pool resource's share of a serving run — the per-resource
/// utilization breakdown (the core-complex aggregate, each core0..7 row,
/// DW accelerator, IMA mux, DMA port, PCM programming port, the array
/// aggregate, the busiest array). `units` is how many physical units the
/// entry aggregates: utilization = busy / (units × makespan). Names are
/// shared `Rc<str>`s so cloning stats/report structs is a pointer bump,
/// not a string copy.
#[derive(Clone, Debug)]
pub struct ResourceUtil {
    pub name: Rc<str>,
    pub busy_cycles: u64,
    pub units: u64,
}

impl ResourceUtil {
    pub fn new(name: &str, busy_cycles: u64, units: u64) -> ResourceUtil {
        ResourceUtil {
            name: Rc::from(name),
            busy_cycles,
            units,
        }
    }
}

/// Deterministic performance counters of one serving run: the event-loop
/// work plus the timeline's gap-search/occupancy counters
/// (`coordinator::timeline::TimelineStats`). Counter-based perf pins are
/// reproducible under a fixed seed — unlike wall clock, they cannot flake
/// — and the pruned-vs-unpruned comparisons in the regression suite and
/// the CI smoke are stated entirely in these.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeCounters {
    /// Event-loop dispatch steps (batches committed).
    pub steps: u64,
    /// Candidate validations (heap pops that re-ran the gap search).
    pub validations: u64,
    /// Gap-search probe work (binary-search halving steps).
    pub probes: u64,
    /// Interval nodes live in the timeline when the run drained.
    pub live_intervals: u64,
    /// High-water mark of live interval nodes.
    pub peak_live_intervals: u64,
    /// Interval nodes folded into the pruning watermark.
    pub pruned_intervals: u64,
    /// Final pruning watermark (0 when pruning is off).
    pub watermark: u64,
    /// Next-event queue insertions. Like the two counters below, a pure
    /// function of the pop sequence — which both queue kinds realize
    /// identically — so the value is the same under
    /// `--event-queue heap|calendar` (pinned by `tests/prop_evq.rs`).
    pub evq_pushes: u64,
    /// Next-event queue extractions (equals `validations`' pops plus
    /// the final drain; kept separately so the queue can be gated
    /// without reference to the validation path).
    pub evq_pops: u64,
    /// Pops whose stored lower-bound instant had gone stale (lazy
    /// revalidation moved the dispatch later) — the churn measure the
    /// calendar queue is designed to tolerate.
    pub evq_stale: u64,
}

/// Per-model serving outcome, accumulated by the event loop.
#[derive(Clone, Debug)]
pub struct TenantStats {
    pub name: Rc<str>,
    /// Arrays this tenant's weights occupy (its pool slice).
    pub arrays: usize,
    /// Passes per request (1 = weights resident in the slice).
    pub n_passes: usize,
    /// Device occupancy within the tenant's slice, in [0, 1].
    pub occupancy: f64,
    pub arrivals: u64,
    pub served: u64,
    pub dropped: u64,
    /// Requests refused at the front door by admission control (never
    /// queued, so never served or dropped). 0 with admission off.
    pub rejected: u64,
    /// Latency budget admission enforced for this tenant (cycles; 0 =
    /// no budget — config echo for the JSON baseline).
    pub slo_p95_cy: u64,
    pub batches: u64,
    /// End-to-end request latency (arrival → batch completion), cycles.
    pub latency: LogHistogram,
    /// Where that latency went, phase by phase (components sum to
    /// `latency`'s total exactly).
    pub breakdown: LatencyBreakdown,
    /// Deepest backlog observed for this tenant: sampled at *every*
    /// event-loop step (each dispatch instant, for all tenants) and
    /// additionally at this tenant's own dispatch-candidate instants
    /// before expired requests are dropped — so it is never below
    /// [`peak_queue_at_dispatch`](Self::peak_queue_at_dispatch).
    pub peak_queue: usize,
    /// The PR 3 instrument, retained for comparison: backlog sampled only
    /// at this tenant's own dispatch-candidate instants (pre-drop).
    /// `tests/peak_queue_regression.rs` pins its relation to the
    /// every-event sample and to the pool-wide simultaneous backlog.
    pub peak_queue_at_dispatch: usize,
    /// Cycles this tenant's batches held their resources (sum of batch
    /// makespans — overlapped batches each count in full).
    pub busy_cycles: u64,
    /// Energy of all served batches (work + reprogramming), joules.
    pub energy_j: f64,
}

impl TenantStats {
    pub fn new(name: &str, arrays: usize, n_passes: usize, occupancy: f64) -> TenantStats {
        TenantStats {
            name: Rc::from(name),
            arrays,
            n_passes,
            occupancy,
            arrivals: 0,
            served: 0,
            dropped: 0,
            rejected: 0,
            slo_p95_cy: 0,
            batches: 0,
            latency: LogHistogram::new(),
            breakdown: LatencyBreakdown::default(),
            peak_queue: 0,
            peak_queue_at_dispatch: 0,
            busy_cycles: 0,
            energy_j: 0.0,
        }
    }

    /// Mean formed batch size (0 when nothing dispatched).
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.served as f64 / self.batches as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_bins_below_eight() {
        for v in 0..8u64 {
            let mut h = LogHistogram::new();
            h.record(v);
            assert_eq!(h.quantile(1.0), v);
        }
    }

    #[test]
    fn bins_are_monotone_and_floor_is_consistent() {
        let mut prev = 0usize;
        for v in [
            1u64, 7, 8, 9, 15, 16, 31, 100, 1000, 65_535, 1 << 20, (1 << 40) + 12345,
            u64::MAX,
        ] {
            let b = LogHistogram::bin_of(v);
            assert!(b >= prev, "bin({v}) = {b} < {prev}");
            assert!(b < BINS);
            assert!(LogHistogram::bin_floor(b) <= v, "floor of bin({v})");
            prev = b;
        }
        // the floor of a value's bin never exceeds the value, and the next
        // bin's floor exceeds it: the bin brackets the value
        for v in [8u64, 100, 12_345, 1 << 30] {
            let b = LogHistogram::bin_of(v);
            assert!(LogHistogram::bin_floor(b + 1) > v);
        }
    }

    #[test]
    fn quantiles_on_uniform_ramp() {
        let mut h = LogHistogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        let (p50, p95, p99) = h.percentiles();
        // ≤ 12.5 % relative error, always from below
        assert!(p50 <= 500 && p50 as f64 >= 500.0 * 0.875, "{p50}");
        assert!(p95 <= 950 && p95 as f64 >= 950.0 * 0.875, "{p95}");
        assert!(p99 <= 990 && p99 as f64 >= 990.0 * 0.875, "{p99}");
        assert!(p50 <= p95 && p95 <= p99);
        assert_eq!(h.count(), 1000);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), 1000);
        assert!((h.mean() - 500.5).abs() < 1e-9);
    }

    #[test]
    fn percentiles_lie_within_their_bin_bounds() {
        let mut h = LogHistogram::new();
        let mut x = 1u64;
        for i in 0..4096u64 {
            x = x
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            h.record((x >> 33) % (1u64 << (i % 48 + 4)));
        }
        for q in [0.0, 0.25, 0.50, 0.90, 0.95, 0.99, 1.0] {
            let v = h.quantile(q);
            let (lo, hi) = h.quantile_bounds(q);
            assert_eq!(v, lo, "quantile({q}) must report its bin's floor");
            assert!(lo < hi, "degenerate bin [{lo},{hi}) at q={q}");
            // the bounds are exactly the owning bin's bounds
            assert_eq!(LogHistogram::bin_bounds(LogHistogram::bin_index(v)), (lo, hi));
        }
        // last-bin upper edge saturates instead of overflowing 2^64
        assert_eq!(LogHistogram::bin_index(u64::MAX), BINS - 1);
        assert_eq!(LogHistogram::bin_bounds(BINS - 1).1, u64::MAX);
        // empty histogram: bounds degenerate to (0, 0), matching quantile = 0
        assert_eq!(LogHistogram::new().quantile_bounds(0.95), (0, 0));
    }

    #[test]
    fn breakdown_components_sum_to_latency() {
        let mut bd = LatencyBreakdown::default();
        let mut lat = LogHistogram::new();
        for (a, prev, close, nb, t, e) in [
            (0u64, 2u64, 5u64, 7u64, 10u64, 30u64), // all five phases non-zero
            (7, 4, 9, 12, 12, 40),                  // no resource stall
            (15, 4, 9, 12, 20, 40),                 // late arrival: a past close and nb
        ] {
            let ph = crate::serve::trace::decompose(a, prev, close, nb, t, e);
            assert_eq!(ph.total(), e - a, "phases must telescope to latency");
            bd.record(&ph);
            lat.record(e - a);
        }
        assert_eq!(bd.components_sum(), lat.sum());
        assert_eq!(bd.phases().len(), 5);
    }

    #[test]
    fn merge_matches_recording_the_union() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        let mut u = LogHistogram::new();
        for v in [3u64, 9, 100, 6_000] {
            a.record(v);
            u.record(v);
        }
        for v in [0u64, 17, 950, 1 << 30] {
            b.record(v);
            u.record(v);
        }
        a.merge(&b);
        assert_eq!(a.percentiles(), u.percentiles());
        assert_eq!(a.count(), u.count());
        assert_eq!(a.sum(), u.sum());
        assert_eq!(a.min(), u.min());
        assert_eq!(a.max(), u.max());
        // merging an empty histogram is a no-op — min must not regress
        // toward the empty histogram's u64::MAX sentinel
        a.merge(&LogHistogram::new());
        assert_eq!(a.min(), u.min());
        assert_eq!(a.percentiles(), u.percentiles());
    }

    #[test]
    fn remove_is_an_exact_inverse_of_record() {
        let mut h = LogHistogram::new();
        let base = LogHistogram::new();
        for v in [0u64, 7, 8, 100, 12_345, 1 << 30] {
            h.record(v);
        }
        for v in [1 << 30, 12_345, 100, 8, 7, 0u64] {
            h.remove(v);
        }
        assert_eq!(h.count(), base.count());
        assert_eq!(h.sum(), base.sum());
        assert_eq!(h.percentiles(), base.percentiles());
        // interleaved: the survivors' percentiles are exactly what
        // recording only the survivors would report
        let mut mixed = LogHistogram::new();
        let mut survivors = LogHistogram::new();
        for v in [5u64, 50, 500, 5_000] {
            mixed.record(v);
            survivors.record(v);
        }
        for v in [9u64, 90, 900] {
            mixed.record(v);
        }
        for v in [9u64, 90, 900] {
            mixed.remove(v);
        }
        assert_eq!(mixed.percentiles(), survivors.percentiles());
        assert_eq!(mixed.count(), survivors.count());
        assert_eq!(mixed.sum(), survivors.sum());
    }

    #[test]
    fn empty_histogram_reports_zeros() {
        let h = LogHistogram::new();
        assert_eq!(h.percentiles(), (0, 0, 0));
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.mean(), 0.0);
        assert!(h.is_empty());
    }
}
