//! Serving metrics: fixed-bin logarithmic latency histograms and per-model
//! serving statistics.
//!
//! Percentiles come from a fixed-size log2 histogram (8 linear sub-bins per
//! power of two, the HdrHistogram idea shrunk to one page): recording is
//! O(1) with no allocation on the serving path, quantiles resolve to the
//! lower bound of the owning bin (≤ 12.5 % relative error — far below the
//! run-to-run variation any real deployment sees), and because bins are
//! integers the reported p50/p95/p99 are *bit-identical* across runs with
//! the same seed, which the determinism tests pin.

use std::rc::Rc;

/// Linear sub-bins per octave: 2^3 = 8.
const SUB_BITS: u32 = 3;
const SUB: usize = 1 << SUB_BITS;
/// Octaves 3..=63 carry 8 sub-bins each; values 0..=7 get exact bins.
const BINS: usize = SUB * 62;

/// Fixed-footprint log-scale histogram over `u64` values (cycles).
#[derive(Clone, Debug)]
pub struct LogHistogram {
    counts: Vec<u64>,
    n: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram::new()
    }
}

impl LogHistogram {
    pub fn new() -> LogHistogram {
        LogHistogram {
            counts: vec![0; BINS],
            n: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    fn bin_of(v: u64) -> usize {
        if v < SUB as u64 {
            return v as usize; // exact bins for 0..=7
        }
        let msb = 63 - v.leading_zeros(); // ≥ SUB_BITS here
        let sub = ((v >> (msb - SUB_BITS)) & (SUB as u64 - 1)) as usize;
        (msb as usize - 2) * SUB + sub
    }

    /// Lower bound of bin `b` — the value a quantile query reports.
    fn bin_floor(b: usize) -> u64 {
        if b < SUB {
            return b as u64;
        }
        let msb = (b / SUB + 2) as u32;
        let sub = (b % SUB) as u64;
        (SUB as u64 + sub) << (msb - SUB_BITS)
    }

    pub fn record(&mut self, v: u64) {
        self.counts[Self::bin_of(v)] += 1;
        self.n += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn min(&self) -> u64 {
        if self.n == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum as f64 / self.n as f64
        }
    }

    /// Quantile `q` in [0, 1]: the lower bound of the bin holding the
    /// ⌈q·n⌉-th smallest sample (0 when empty).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.n == 0 {
            return 0;
        }
        let target = ((q * self.n as f64).ceil() as u64).clamp(1, self.n);
        let mut seen = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::bin_floor(b);
            }
        }
        self.max
    }

    /// The serving table's (p50, p95, p99).
    pub fn percentiles(&self) -> (u64, u64, u64) {
        (self.quantile(0.50), self.quantile(0.95), self.quantile(0.99))
    }
}

/// One pool resource's share of a serving run — the per-resource
/// utilization breakdown (the core-complex aggregate, each core0..7 row,
/// DW accelerator, IMA mux, DMA port, PCM programming port, the array
/// aggregate, the busiest array). `units` is how many physical units the
/// entry aggregates: utilization = busy / (units × makespan). Names are
/// shared `Rc<str>`s so cloning stats/report structs is a pointer bump,
/// not a string copy.
#[derive(Clone, Debug)]
pub struct ResourceUtil {
    pub name: Rc<str>,
    pub busy_cycles: u64,
    pub units: u64,
}

impl ResourceUtil {
    pub fn new(name: &str, busy_cycles: u64, units: u64) -> ResourceUtil {
        ResourceUtil {
            name: Rc::from(name),
            busy_cycles,
            units,
        }
    }
}

/// Deterministic performance counters of one serving run: the event-loop
/// work plus the timeline's gap-search/occupancy counters
/// (`coordinator::timeline::TimelineStats`). Counter-based perf pins are
/// reproducible under a fixed seed — unlike wall clock, they cannot flake
/// — and the pruned-vs-unpruned comparisons in the regression suite and
/// the CI smoke are stated entirely in these.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeCounters {
    /// Event-loop dispatch steps (batches committed).
    pub steps: u64,
    /// Candidate validations (heap pops that re-ran the gap search).
    pub validations: u64,
    /// Gap-search probe work (binary-search halving steps).
    pub probes: u64,
    /// Interval nodes live in the timeline when the run drained.
    pub live_intervals: u64,
    /// High-water mark of live interval nodes.
    pub peak_live_intervals: u64,
    /// Interval nodes folded into the pruning watermark.
    pub pruned_intervals: u64,
    /// Final pruning watermark (0 when pruning is off).
    pub watermark: u64,
}

/// Per-model serving outcome, accumulated by the event loop.
#[derive(Clone, Debug)]
pub struct TenantStats {
    pub name: Rc<str>,
    /// Arrays this tenant's weights occupy (its pool slice).
    pub arrays: usize,
    /// Passes per request (1 = weights resident in the slice).
    pub n_passes: usize,
    /// Device occupancy within the tenant's slice, in [0, 1].
    pub occupancy: f64,
    pub arrivals: u64,
    pub served: u64,
    pub dropped: u64,
    /// Requests refused at the front door by admission control (never
    /// queued, so never served or dropped). 0 with admission off.
    pub rejected: u64,
    /// Latency budget admission enforced for this tenant (cycles; 0 =
    /// no budget — config echo for the JSON baseline).
    pub slo_p95_cy: u64,
    pub batches: u64,
    /// End-to-end request latency (arrival → batch completion), cycles.
    pub latency: LogHistogram,
    /// Deepest backlog observed for this tenant: sampled at *every*
    /// event-loop step (each dispatch instant, for all tenants) and
    /// additionally at this tenant's own dispatch-candidate instants
    /// before expired requests are dropped — so it is never below
    /// [`peak_queue_at_dispatch`](Self::peak_queue_at_dispatch).
    pub peak_queue: usize,
    /// The PR 3 instrument, retained for comparison: backlog sampled only
    /// at this tenant's own dispatch-candidate instants (pre-drop).
    /// `tests/peak_queue_regression.rs` pins its relation to the
    /// every-event sample and to the pool-wide simultaneous backlog.
    pub peak_queue_at_dispatch: usize,
    /// Cycles this tenant's batches held their resources (sum of batch
    /// makespans — overlapped batches each count in full).
    pub busy_cycles: u64,
    /// Energy of all served batches (work + reprogramming), joules.
    pub energy_j: f64,
}

impl TenantStats {
    pub fn new(name: &str, arrays: usize, n_passes: usize, occupancy: f64) -> TenantStats {
        TenantStats {
            name: Rc::from(name),
            arrays,
            n_passes,
            occupancy,
            arrivals: 0,
            served: 0,
            dropped: 0,
            rejected: 0,
            slo_p95_cy: 0,
            batches: 0,
            latency: LogHistogram::new(),
            peak_queue: 0,
            peak_queue_at_dispatch: 0,
            busy_cycles: 0,
            energy_j: 0.0,
        }
    }

    /// Mean formed batch size (0 when nothing dispatched).
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.served as f64 / self.batches as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_bins_below_eight() {
        for v in 0..8u64 {
            let mut h = LogHistogram::new();
            h.record(v);
            assert_eq!(h.quantile(1.0), v);
        }
    }

    #[test]
    fn bins_are_monotone_and_floor_is_consistent() {
        let mut prev = 0usize;
        for v in [
            1u64, 7, 8, 9, 15, 16, 31, 100, 1000, 65_535, 1 << 20, (1 << 40) + 12345,
            u64::MAX,
        ] {
            let b = LogHistogram::bin_of(v);
            assert!(b >= prev, "bin({v}) = {b} < {prev}");
            assert!(b < BINS);
            assert!(LogHistogram::bin_floor(b) <= v, "floor of bin({v})");
            prev = b;
        }
        // the floor of a value's bin never exceeds the value, and the next
        // bin's floor exceeds it: the bin brackets the value
        for v in [8u64, 100, 12_345, 1 << 30] {
            let b = LogHistogram::bin_of(v);
            assert!(LogHistogram::bin_floor(b + 1) > v);
        }
    }

    #[test]
    fn quantiles_on_uniform_ramp() {
        let mut h = LogHistogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        let (p50, p95, p99) = h.percentiles();
        // ≤ 12.5 % relative error, always from below
        assert!(p50 <= 500 && p50 as f64 >= 500.0 * 0.875, "{p50}");
        assert!(p95 <= 950 && p95 as f64 >= 950.0 * 0.875, "{p95}");
        assert!(p99 <= 990 && p99 as f64 >= 990.0 * 0.875, "{p99}");
        assert!(p50 <= p95 && p95 <= p99);
        assert_eq!(h.count(), 1000);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), 1000);
        assert!((h.mean() - 500.5).abs() < 1e-9);
    }

    #[test]
    fn empty_histogram_reports_zeros() {
        let h = LogHistogram::new();
        assert_eq!(h.percentiles(), (0, 0, 0));
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.mean(), 0.0);
        assert!(h.is_empty());
    }
}
