//! Deterministic fault plans for the serving fleet: a seeded schedule
//! of node-level fault events the fleet loop injects at exact cycle
//! instants, so chaos runs are as bit-reproducible as healthy ones.
//!
//! ## Grammar (`imcc serve --faults SPEC`)
//!
//! A plan is a comma-separated list of events, each
//! `kind@nodeN:T[..T2][xF]` with instants in cycles (integers or
//! scientific notation, `5e6`):
//!
//! - `crash@node1:5e6..8e6` — hard crash at `T`: in-flight batches are
//!   **lost** (their ledger entries revoked exactly), the queued stream
//!   fails over to survivors, and the node rejoins at `T2` after PCM
//!   reprogramming (omit `..T2` and it never comes back).
//! - `drain@node2:1e7[..T2]` — graceful drain at `T`: in-flight work
//!   completes, the queued stream hands off, the node rejoins at `T2`
//!   (reprogrammed) or stays out.
//! - `update@node0:5e6..9e6` — a rolling **model update** step: drain
//!   semantics with the rejoin mandatory (the node reprograms its PCM
//!   arrays with the new weights before taking traffic again).
//! - `degrade@node1:2e6..6e6x1.5` — service on the node is stretched by
//!   factor `F ≥ 1` while `T ≤ t < T2` (a thermally or drift-degraded
//!   node that still answers, just slower).
//! - `arrayfail@node2:3e6[xK]` — `K` PCM arrays (default 1) fail
//!   permanently at `T`: every resident tenant reprograms around the
//!   dead arrays and service stretches by `n/(n-K)` from then on (the
//!   first-order cost of losing `K`-way parallel capacity).
//!
//! [`FaultPlan::seeded`] generates randomized crash/recover plans from
//! `--fault-seed` (node 0 is the survivor anchor and is never faulted,
//! so failover always has a live target), and
//! [`FaultPlan::rolling_update`] composes drain→reprogram→rejoin into a
//! staggered rolling update across the whole fleet.
//!
//! Down-spans of one node must not overlap (a crash cannot hit a node
//! that is already down); [`FaultPlan::validate`] rejects such plans
//! up front, along with out-of-range node ids and array-fail counts
//! that would leave a node with no arrays.

use crate::util::rng::SplitMix64;

/// What happens to a node at its fault instant. See the module docs
/// for the exact semantics of each kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Hard crash: in-flight lost, queue fails over, optional staged
    /// rejoin (PCM reprogramming first) at `recover_at`.
    Crash { recover_at: Option<u64> },
    /// Graceful drain: in-flight completes, queue fails over. With
    /// `rejoin_at` the node reprograms and rejoins; `update` marks the
    /// drain as a rolling-model-update step (rejoin mandatory).
    Drain { rejoin_at: Option<u64>, update: bool },
    /// Service stretched by `percent`/100 (> 100) while `t ≤ now < until`.
    Degrade { until: u64, percent: u64 },
    /// `arrays` PCM arrays fail permanently: resident tenants reprogram
    /// and service stretches by `n/(n-arrays)` from `t` on.
    ArrayFail { arrays: usize },
}

/// One scheduled fault: `kind` strikes `node` at cycle `t`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    pub node: usize,
    pub t: u64,
    pub kind: FaultKind,
}

/// A deterministic fault schedule. Empty plans are the no-fault path:
/// the fleet loop takes exactly the healthy code paths and its output
/// is bit-identical to a run with no plan at all.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// The empty plan — the healthy fleet.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub(crate) fn sorted(mut self) -> FaultPlan {
        // stable schedule order: instant, then node, then kind order as
        // written (sort_by_key is stable, so same-(t, node) events keep
        // their spec order)
        self.events.sort_by_key(|e| (e.t, e.node));
        self
    }

    /// Parse the `--faults` grammar (see the module docs). Events come
    /// back sorted by (instant, node).
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut events = Vec::new();
        for (i, raw) in spec.split(',').enumerate() {
            let ev = raw.trim();
            if ev.is_empty() {
                return Err(format!("fault event {} is empty in `{spec}`", i + 1));
            }
            events.push(parse_event(ev)?);
        }
        Ok(FaultPlan { events }.sorted())
    }

    /// A seeded random crash/recover plan: each node other than node 0
    /// (the survivor anchor — failover always has a live target) draws
    /// exponentially spaced crashes with mean `mtbf_cy` over
    /// `[0, horizon_cy)`, each down for `mtbf_cy/8 .. 3·mtbf_cy/8`
    /// cycles. A pure function of `(seed, nodes, horizon_cy, mtbf_cy)`.
    pub fn seeded(seed: u64, nodes: usize, horizon_cy: u64, mtbf_cy: u64) -> FaultPlan {
        let mtbf = mtbf_cy.max(1);
        let mut events = Vec::new();
        for node in 1..nodes {
            let mut rng = SplitMix64::new(
                seed ^ (node as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            );
            let mut t = exp_draw(&mut rng, mtbf);
            while t < horizon_cy {
                let down = mtbf / 8 + rng.below((mtbf / 4).max(1));
                let recover = t + down.max(1);
                events.push(FaultEvent {
                    node,
                    t,
                    kind: FaultKind::Crash {
                        recover_at: Some(recover),
                    },
                });
                t = recover + exp_draw(&mut rng, mtbf).max(1);
            }
        }
        FaultPlan { events }.sorted()
    }

    /// A rolling model update across the whole fleet: node by node,
    /// drain → reprogram → rejoin, staggered so at most one node is
    /// ever out. Node `i` drains at `start_cy + i·(down_cy + down_cy/4
    /// + 1)` and rejoins `down_cy` later.
    pub fn rolling_update(nodes: usize, start_cy: u64, down_cy: u64) -> FaultPlan {
        let down = down_cy.max(1);
        let stride = down + down / 4 + 1;
        let events = (0..nodes)
            .map(|node| {
                let t = start_cy + node as u64 * stride;
                FaultEvent {
                    node,
                    t,
                    kind: FaultKind::Drain {
                        rejoin_at: Some(t + down),
                        update: true,
                    },
                }
            })
            .collect();
        FaultPlan { events }.sorted()
    }

    /// Static plan checks against a concrete fleet: node ids in range,
    /// recover/rejoin strictly after the fault, no overlapping
    /// down-spans on one node, and array failures that leave every node
    /// at least one array.
    pub fn validate(&self, nodes: usize, node_arrays: &[usize]) -> Result<(), String> {
        let mut down_spans: Vec<Vec<(u64, u64)>> = vec![Vec::new(); nodes];
        let mut lost_arrays: Vec<usize> = vec![0; nodes];
        for e in &self.events {
            if e.node >= nodes {
                return Err(format!(
                    "fault targets node{} but the fleet has {nodes} nodes (node0..node{})",
                    e.node,
                    nodes - 1
                ));
            }
            match e.kind {
                FaultKind::Crash { recover_at } => {
                    let until = match recover_at {
                        Some(r) if r <= e.t => {
                            return Err(format!(
                                "crash@node{}: recovery {r} is not after the crash at {}",
                                e.node, e.t
                            ));
                        }
                        Some(r) => r,
                        None => u64::MAX,
                    };
                    down_spans[e.node].push((e.t, until));
                }
                FaultKind::Drain { rejoin_at, update } => {
                    let label = if update { "update" } else { "drain" };
                    let until = match rejoin_at {
                        Some(r) if r <= e.t => {
                            return Err(format!(
                                "{label}@node{}: rejoin {r} is not after the drain at {}",
                                e.node, e.t
                            ));
                        }
                        Some(r) => r,
                        None => u64::MAX,
                    };
                    down_spans[e.node].push((e.t, until));
                }
                FaultKind::Degrade { until, percent } => {
                    if until <= e.t {
                        return Err(format!(
                            "degrade@node{}: window end {until} is not after {}",
                            e.node, e.t
                        ));
                    }
                    if percent <= 100 {
                        return Err(format!(
                            "degrade@node{}: factor must exceed 1.0",
                            e.node
                        ));
                    }
                }
                FaultKind::ArrayFail { arrays } => {
                    if arrays == 0 {
                        return Err(format!("arrayfail@node{}: 0 arrays failed", e.node));
                    }
                    lost_arrays[e.node] += arrays;
                }
            }
        }
        for (node, spans) in down_spans.iter_mut().enumerate() {
            spans.sort_unstable();
            for w in spans.windows(2) {
                if w[1].0 < w[0].1 {
                    return Err(format!(
                        "node{node} goes down at {} while already down since {} \
                         (down-spans must not overlap)",
                        w[1].0, w[0].0
                    ));
                }
            }
        }
        for (node, &lost) in lost_arrays.iter().enumerate() {
            if lost > 0 {
                let na = node_arrays.get(node).copied().unwrap_or(0);
                if lost >= na {
                    return Err(format!(
                        "arrayfail leaves node{node} {lost} arrays short of its {na}",
                    ));
                }
            }
        }
        Ok(())
    }

    /// Compact human echo of the plan, schedule order.
    pub fn describe(&self) -> String {
        self.events
            .iter()
            .map(|e| match e.kind {
                FaultKind::Crash { recover_at: Some(r) } => {
                    format!("crash@node{}:{}..{r}", e.node, e.t)
                }
                FaultKind::Crash { recover_at: None } => format!("crash@node{}:{}", e.node, e.t),
                FaultKind::Drain { rejoin_at, update } => {
                    let k = if update { "update" } else { "drain" };
                    match rejoin_at {
                        Some(r) => format!("{k}@node{}:{}..{r}", e.node, e.t),
                        None => format!("{k}@node{}:{}", e.node, e.t),
                    }
                }
                FaultKind::Degrade { until, percent } => format!(
                    "degrade@node{}:{}..{until}x{}",
                    e.node,
                    e.t,
                    percent as f64 / 100.0
                ),
                FaultKind::ArrayFail { arrays } => {
                    format!("arrayfail@node{}:{}x{arrays}", e.node, e.t)
                }
            })
            .collect::<Vec<_>>()
            .join(",")
    }
}

/// Mean-`mtbf` exponential gap, drawn deterministically.
fn exp_draw(rng: &mut SplitMix64, mtbf: u64) -> u64 {
    let u = rng.next_f64();
    (-(1.0 - u).ln() * mtbf as f64) as u64
}

/// A cycle instant: plain integer or scientific notation (`5e6`).
fn parse_cy(s: &str, ev: &str) -> Result<u64, String> {
    if let Ok(v) = s.parse::<u64>() {
        return Ok(v);
    }
    match s.parse::<f64>() {
        Ok(v) if v.is_finite() && v >= 0.0 && v <= u64::MAX as f64 => Ok(v as u64),
        _ => Err(format!("bad cycle instant `{s}` in fault event `{ev}`")),
    }
}

fn parse_event(ev: &str) -> Result<FaultEvent, String> {
    let (kind, rest) = ev
        .split_once('@')
        .ok_or_else(|| format!("fault event `{ev}` has no `@` (kind@nodeN:T)"))?;
    let (node_s, time_s) = rest
        .split_once(':')
        .ok_or_else(|| format!("fault event `{ev}` has no `:` (kind@nodeN:T)"))?;
    let node: usize = node_s
        .strip_prefix("node")
        .and_then(|d| d.parse().ok())
        .ok_or_else(|| format!("bad node `{node_s}` in fault event `{ev}` (nodeN)"))?;

    // split off an `xF` suffix, then an optional `..T2` range
    let (times, factor) = match time_s.split_once('x') {
        Some((ts, fs)) => (ts, Some(fs)),
        None => (time_s, None),
    };
    let (t, until) = match times.split_once("..") {
        Some((a, b)) => (parse_cy(a, ev)?, Some(parse_cy(b, ev)?)),
        None => (parse_cy(times, ev)?, None),
    };

    let no_factor = |k: &str| -> Result<(), String> {
        match factor {
            Some(_) => Err(format!("`{k}` takes no xF factor in fault event `{ev}`")),
            None => Ok(()),
        }
    };
    let kind = match kind.trim() {
        "crash" => {
            no_factor("crash")?;
            FaultKind::Crash { recover_at: until }
        }
        "drain" => {
            no_factor("drain")?;
            FaultKind::Drain {
                rejoin_at: until,
                update: false,
            }
        }
        "update" => {
            no_factor("update")?;
            let rejoin = until.ok_or_else(|| {
                format!("`update` needs a rejoin instant (update@nodeN:T..T2) in `{ev}`")
            })?;
            FaultKind::Drain {
                rejoin_at: Some(rejoin),
                update: true,
            }
        }
        "degrade" => {
            let until = until.ok_or_else(|| {
                format!("`degrade` needs a window (degrade@nodeN:T..T2xF) in `{ev}`")
            })?;
            let fs = factor.ok_or_else(|| {
                format!("`degrade` needs a factor (degrade@nodeN:T..T2xF) in `{ev}`")
            })?;
            let f: f64 = fs
                .parse()
                .ok()
                .filter(|f: &f64| f.is_finite() && *f > 1.0 && *f <= 1000.0)
                .ok_or_else(|| {
                    format!("bad degrade factor `{fs}` in `{ev}` (1.0 < F ≤ 1000)")
                })?;
            FaultKind::Degrade {
                until,
                percent: (f * 100.0).round() as u64,
            }
        }
        "arrayfail" => {
            if until.is_some() {
                return Err(format!(
                    "`arrayfail` takes one instant (arrayfail@nodeN:T[xK]) in `{ev}`"
                ));
            }
            let arrays = match factor {
                Some(fs) => fs
                    .parse::<usize>()
                    .ok()
                    .filter(|&k| k >= 1)
                    .ok_or_else(|| {
                        format!("bad array-fail count `{fs}` in `{ev}` (integer ≥ 1)")
                    })?,
                None => 1,
            };
            FaultKind::ArrayFail { arrays }
        }
        other => {
            return Err(format!(
                "unknown fault kind `{other}` in `{ev}` (crash|drain|update|degrade|arrayfail)"
            ));
        }
    };
    Ok(FaultEvent { node, t, kind })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grammar_round_trips() {
        let plan = FaultPlan::parse(
            "crash@node1:5e6..8e6,drain@node2:1e7,update@node0:2e6..3e6,\
             degrade@node1:9e6..12e6x1.5,arrayfail@node2:4e6x4",
        )
        .unwrap();
        assert_eq!(plan.events.len(), 5);
        // sorted by (t, node)
        assert!(plan.events.windows(2).all(|w| (w[0].t, w[0].node) <= (w[1].t, w[1].node)));
        assert!(plan.events.contains(&FaultEvent {
            node: 1,
            t: 5_000_000,
            kind: FaultKind::Crash {
                recover_at: Some(8_000_000)
            },
        }));
        assert!(plan.events.contains(&FaultEvent {
            node: 0,
            t: 2_000_000,
            kind: FaultKind::Drain {
                rejoin_at: Some(3_000_000),
                update: true
            },
        }));
        assert!(plan.events.contains(&FaultEvent {
            node: 1,
            t: 9_000_000,
            kind: FaultKind::Degrade {
                until: 12_000_000,
                percent: 150
            },
        }));
        assert!(plan.events.contains(&FaultEvent {
            node: 2,
            t: 4_000_000,
            kind: FaultKind::ArrayFail { arrays: 4 },
        }));
        // parse(describe(plan)) is the identity on the sorted plan
        assert_eq!(FaultPlan::parse(&plan.describe()).unwrap(), plan);
    }

    #[test]
    fn grammar_rejects_malformed_events() {
        for bad in [
            "",                             // empty event
            "crash@node1",                  // no instant
            "crashnode1:5e6",               // no @
            "crash@n1:5e6",                 // bad node
            "crash@node1:abc",              // bad instant
            "crash@node1:5e6x2",            // crash takes no factor
            "explode@node1:5e6",            // unknown kind
            "update@node1:5e6",             // update needs a rejoin
            "degrade@node1:5e6..6e6",       // degrade needs a factor
            "degrade@node1:5e6x1.5",        // degrade needs a window
            "degrade@node1:5e6..6e6x0.5",   // factor must exceed 1
            "arrayfail@node1:5e6..6e6",     // arrayfail takes one instant
            "arrayfail@node1:5e6x0",        // zero arrays
            "crash@node1:5e6,,drain@node2:6e6", // empty middle event
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "`{bad}` must not parse");
        }
    }

    #[test]
    fn validate_rejects_impossible_plans() {
        let nodes = 3;
        let arrays = [64usize, 32, 12];
        let ok = FaultPlan::parse("crash@node1:5e6..8e6,crash@node1:9e6").unwrap();
        assert!(ok.validate(nodes, &arrays).is_ok());
        for (spec, why) in [
            ("crash@node7:5e6", "node out of range"),
            ("crash@node1:5e6..5e6", "recovery not after crash"),
            ("drain@node1:5e6..4e6", "rejoin before drain"),
            ("crash@node1:5e6..9e6,crash@node1:7e6", "overlapping down-spans"),
            ("crash@node1:5e6,crash@node1:9e6", "second crash while down forever"),
            ("arrayfail@node2:5e6x12", "node2 loses all 12 arrays"),
            ("arrayfail@node2:5e6x6,arrayfail@node2:7e6x6", "cumulative array loss"),
        ] {
            let plan = FaultPlan::parse(spec).unwrap();
            assert!(plan.validate(nodes, &arrays).is_err(), "{why}: `{spec}`");
        }
    }

    #[test]
    fn seeded_plans_are_deterministic_and_spare_node_zero() {
        let a = FaultPlan::seeded(7, 4, 50_000_000, 10_000_000);
        let b = FaultPlan::seeded(7, 4, 50_000_000, 10_000_000);
        assert_eq!(a, b, "a pure function of the seed");
        assert!(!a.is_empty(), "a 5×MTBF horizon should draw some crashes");
        assert!(a.events.iter().all(|e| e.node != 0), "node 0 is the anchor");
        assert!(a
            .events
            .iter()
            .all(|e| matches!(e.kind, FaultKind::Crash { recover_at: Some(_) })));
        assert!(a.validate(4, &[64, 64, 64, 64]).is_ok());
        let c = FaultPlan::seeded(8, 4, 50_000_000, 10_000_000);
        assert_ne!(a, c, "different seeds draw different plans");
    }

    #[test]
    fn rolling_update_staggers_without_overlap() {
        let plan = FaultPlan::rolling_update(4, 1_000_000, 2_000_000);
        assert_eq!(plan.events.len(), 4);
        assert!(plan.validate(4, &[64, 64, 64, 64]).is_ok());
        // one node out at a time: each rejoin lands before the next drain
        for w in plan.events.windows(2) {
            let FaultKind::Drain {
                rejoin_at: Some(r), update: true,
            } = w[0].kind
            else {
                panic!("rolling update is made of update steps");
            };
            assert!(r < w[1].t, "node {} rejoins before node {} drains", w[0].node, w[1].node);
        }
        // every node is updated exactly once
        let mut nodes: Vec<usize> = plan.events.iter().map(|e| e.node).collect();
        nodes.sort_unstable();
        assert_eq!(nodes, vec![0, 1, 2, 3]);
    }
}
