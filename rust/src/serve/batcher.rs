//! Dynamic batching: per-tenant arrival queues behind a max-batch /
//! max-wait admission window.
//!
//! A batch becomes dispatchable the moment the window *fills* (`max_batch`
//! requests are waiting) or the oldest pending request has waited
//! `max_wait_cy` — whichever comes first. That is the standard serving
//! trade: a wide window buys pipelining throughput from
//! `scheduler::run_batched`, the wait bound caps the latency a lone
//! request can be held hostage for. `max_batch = 1, max_wait = 0`
//! degenerates to strict one-by-one serving, which the equivalence tests
//! pin against the sequential baseline.
//!
//! Queues are open-loop: arrivals are precomputed by `serve::traffic`, so
//! a queue knows not only who is waiting *now* but when the window will
//! fill — which is what lets the event loop jump straight to the next
//! dispatch instant instead of ticking cycles.

/// Admission window knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchWindow {
    /// Largest batch a single dispatch may form (≥ 1).
    pub max_batch: usize,
    /// Longest the oldest pending request may wait before the window
    /// closes regardless of fill (cycles; 0 = dispatch immediately).
    pub max_wait_cy: u64,
}

impl Default for BatchWindow {
    fn default() -> Self {
        BatchWindow {
            max_batch: 8,
            // 200 µs at 500 MHz — a fraction of one MobileNetV2 inference
            max_wait_cy: 100_000,
        }
    }
}

/// One tenant's open-loop arrival queue. `next` marks the first request
/// not yet served (or dropped); everything before it is history.
/// `screened` marks how far admission control has looked: requests before
/// it were accepted at the front door (rejected ones are removed from
/// `arrivals` outright, so they never count toward depth, drops, or
/// batches). With admission off `screened` stays 0 and nothing changes.
#[derive(Clone, Debug)]
pub struct TenantQueue {
    arrivals: Vec<u64>,
    next: usize,
    screened: usize,
}

impl TenantQueue {
    /// `arrivals` must be sorted ascending (as `traffic::arrivals` emits).
    pub fn new(arrivals: Vec<u64>) -> TenantQueue {
        debug_assert!(arrivals.windows(2).all(|w| w[0] <= w[1]));
        TenantQueue {
            arrivals,
            next: 0,
            screened: 0,
        }
    }

    pub fn total_arrivals(&self) -> usize {
        self.arrivals.len()
    }

    /// Requests not yet served or dropped (including future arrivals).
    pub fn outstanding(&self) -> usize {
        self.arrivals.len() - self.next
    }

    /// Arrival cycle of the oldest pending request.
    pub fn head_arrival(&self) -> Option<u64> {
        self.arrivals.get(self.next).copied()
    }

    /// Backlog visible at time `t`: arrived but not yet served/dropped.
    pub fn depth_at(&self, t: u64) -> usize {
        self.arrivals[self.next..]
            .iter()
            .take_while(|&&a| a <= t)
            .count()
    }

    /// Earliest cycle at which this queue's admission window closes: the
    /// window fills, or the head request exhausts its wait budget. `None`
    /// when nothing is outstanding.
    pub fn ready_at(&self, w: &BatchWindow) -> Option<u64> {
        let rem = &self.arrivals[self.next..];
        let head = *rem.first()?;
        let timeout = head.saturating_add(w.max_wait_cy);
        match rem.get(w.max_batch.saturating_sub(1)) {
            Some(&fill) => Some(fill.min(timeout)),
            // the window can never fill again — the wait bound closes it
            None => Some(timeout),
        }
    }

    /// When the window now being dispatched at `t` actually closed:
    /// [`Self::ready_at`], capped at the dispatch instant itself (a batch
    /// can never close after it dispatches — and when deeper backlog let
    /// the dispatcher form a larger batch than the head window, `t` *is*
    /// the close). Feeds the `batch_wait` phase of the latency
    /// decomposition; call before [`Self::admit`] consumes the window.
    pub fn window_close_at(&self, w: &BatchWindow, t: u64) -> u64 {
        self.ready_at(w).map_or(t, |r| r.min(t))
    }

    /// Pop up to `max_batch` requests that have arrived by `t`; returns
    /// their arrival cycles (≥ 1 entry whenever `ready_at ≤ t`).
    pub fn admit(&mut self, t: u64, max_batch: usize) -> Vec<u64> {
        let mut out = Vec::new();
        while out.len() < max_batch {
            match self.arrivals.get(self.next) {
                Some(&a) if a <= t => {
                    out.push(a);
                    self.next += 1;
                }
                _ => break,
            }
        }
        out
    }

    /// Run admission control over every arrival that has landed by `t`
    /// and has not been screened yet, oldest first. The predicate sees
    /// `(arrival_cycle, queue_depth_ahead)` — the number of already
    /// accepted requests still pending when this one reaches the front
    /// door — and returns `true` to accept. Refused requests are removed
    /// from the queue entirely (they were never admitted, so they cannot
    /// later be dropped or served). Returns how many were refused. Each
    /// arrival is screened exactly once, so accept/reject decisions are
    /// final — `ready_at` can only move later, never earlier, preserving
    /// the event heap's lower-bound invariant.
    pub fn screen_arrivals(&mut self, t: u64, mut accept: impl FnMut(u64, usize) -> bool) -> u64 {
        self.screened = self.screened.max(self.next);
        let mut rejected = 0;
        while let Some(&a) = self.arrivals.get(self.screened) {
            if a > t {
                break;
            }
            if accept(a, self.screened - self.next) {
                self.screened += 1;
            } else {
                self.arrivals.remove(self.screened);
                rejected += 1;
            }
        }
        rejected
    }

    /// Abandon pending requests whose `deadline_cy` wait budget had
    /// already expired at time `t`; returns how many were dropped.
    pub fn drop_expired(&mut self, t: u64, deadline_cy: u64) -> u64 {
        let mut dropped = 0;
        while let Some(&a) = self.arrivals.get(self.next) {
            if a.saturating_add(deadline_cy) < t {
                self.next += 1;
                dropped += 1;
            } else {
                break;
            }
        }
        dropped
    }

    /// Remove and return every not-yet-served arrival — the pending
    /// backlog and the still-future stream alike — for the fleet's
    /// cross-node migration hand-off. The served/dropped history stays
    /// behind (so this queue's ledger remains auditable) and the screen
    /// cursor is clamped, keeping every remaining index in bounds.
    pub fn take_pending(&mut self) -> Vec<u64> {
        let out = self.arrivals.split_off(self.next);
        self.screened = self.screened.min(self.arrivals.len());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn window(max_batch: usize, max_wait_cy: u64) -> BatchWindow {
        BatchWindow {
            max_batch,
            max_wait_cy,
        }
    }

    #[test]
    fn take_pending_hands_off_everything_unserved() {
        let mut q = TenantQueue::new(vec![100, 150, 200, 900, 1500]);
        // serve the first two, then hand the rest to another node
        assert_eq!(q.admit(300, 2), vec![100, 150]);
        assert_eq!(q.take_pending(), vec![200, 900, 1500]);
        assert_eq!(q.outstanding(), 0);
        assert_eq!(q.head_arrival(), None);
        assert_eq!(q.ready_at(&window(1, 0)), None);
        // the served history stays behind for the ledger
        assert_eq!(q.total_arrivals(), 2);
        // the emptied queue still screens and admits safely
        assert_eq!(q.screen_arrivals(5000, |_, _| true), 0);
        assert_eq!(q.admit(5000, 4), Vec::<u64>::new());
        // a second take is empty, not a panic
        assert_eq!(q.take_pending(), Vec::<u64>::new());
    }

    #[test]
    fn window_close_caps_at_dispatch_and_tracks_ready() {
        let q = TenantQueue::new(vec![100, 150, 200, 900]);
        let w = window(4, 1000);
        assert_eq!(q.ready_at(&w), Some(900)); // 4th arrival fills it
        // dispatched late: the close stays where the window filled
        assert_eq!(q.window_close_at(&w, 2000), 900);
        // dispatched the instant it filled
        assert_eq!(q.window_close_at(&w, 900), 900);
        // a deeper-backlog batch dispatched before the head window closed:
        // the dispatch instant is the close
        assert_eq!(q.window_close_at(&w, 400), 400);
        // drained queue: degenerate close at the dispatch instant
        let empty = TenantQueue::new(vec![]);
        assert_eq!(empty.window_close_at(&w, 500), 500);
    }

    #[test]
    fn window_fills_before_timeout() {
        let q = TenantQueue::new(vec![100, 150, 200, 900]);
        // 3-wide window fills when the third request lands at 200
        assert_eq!(q.ready_at(&window(3, 10_000)), Some(200));
        // 1-wide window is ready the instant the head arrived
        assert_eq!(q.ready_at(&window(1, 10_000)), Some(100));
    }

    #[test]
    fn timeout_closes_a_starved_window() {
        let q = TenantQueue::new(vec![100, 150]);
        // window of 8 can never fill: head's wait budget closes it
        assert_eq!(q.ready_at(&window(8, 500)), Some(600));
        assert_eq!(q.ready_at(&window(8, 0)), Some(100));
    }

    #[test]
    fn admit_respects_time_and_cap() {
        let mut q = TenantQueue::new(vec![100, 150, 200, 900]);
        assert_eq!(q.admit(250, 8), vec![100, 150, 200]);
        assert_eq!(q.outstanding(), 1);
        assert_eq!(q.admit(250, 8), Vec::<u64>::new());
        assert_eq!(q.admit(900, 8), vec![900]);
        assert_eq!(q.outstanding(), 0);
        assert_eq!(q.head_arrival(), None);
    }

    #[test]
    fn admit_caps_at_max_batch() {
        let mut q = TenantQueue::new(vec![0, 0, 0, 0, 0]);
        assert_eq!(q.admit(0, 2).len(), 2);
        assert_eq!(q.admit(0, 2).len(), 2);
        assert_eq!(q.admit(0, 2).len(), 1);
    }

    #[test]
    fn depth_counts_only_arrived_pending() {
        let mut q = TenantQueue::new(vec![100, 150, 200, 900]);
        assert_eq!(q.depth_at(50), 0);
        assert_eq!(q.depth_at(160), 2);
        q.admit(160, 1);
        assert_eq!(q.depth_at(160), 1);
    }

    #[test]
    fn screening_refuses_and_forgets() {
        let mut q = TenantQueue::new(vec![100, 150, 200, 900]);
        // refuse anything arriving when ≥ 2 accepted requests are ahead
        let r = q.screen_arrivals(300, |_, depth| depth < 2);
        assert_eq!(r, 1); // 200 saw [100, 150] ahead → refused
        assert_eq!(q.outstanding(), 3);
        assert_eq!(q.depth_at(300), 2);
        // already-screened arrivals are never re-screened
        let r = q.screen_arrivals(300, |_, _| false);
        assert_eq!(r, 0);
        // the late arrival gets screened once it lands
        let r = q.screen_arrivals(900, |a, depth| {
            assert_eq!((a, depth), (900, 2));
            true
        });
        assert_eq!(r, 0);
        assert_eq!(q.admit(900, 8), vec![100, 150, 900]);
    }

    #[test]
    fn screening_tracks_serves_and_drops() {
        let mut q = TenantQueue::new(vec![0, 10, 20]);
        assert_eq!(q.screen_arrivals(5, |_, _| true), 0);
        q.admit(5, 8); // serves 0; next passes ahead of nothing
        assert_eq!(q.screen_arrivals(25, |_, depth| depth == 0), 1); // 10 ok, 20 sees 10 ahead
        assert_eq!(q.head_arrival(), Some(10));
        assert_eq!(q.outstanding(), 1);
    }

    #[test]
    fn expired_requests_drop() {
        let mut q = TenantQueue::new(vec![100, 150, 800]);
        // at t=700 with a 500-cycle budget, 100 has waited 600 > 500;
        // 150 has waited exactly 550 > 500; 800 hasn't arrived
        assert_eq!(q.drop_expired(700, 500), 2);
        assert_eq!(q.head_arrival(), Some(800));
        // budget 0 never drops a request the instant it arrives
        let mut q = TenantQueue::new(vec![700]);
        assert_eq!(q.drop_expired(700, 0), 0);
    }
}
