//! Event-driven multi-model serving: the traffic layer of the scaled-up
//! system.
//!
//! PR 1's batch engine answers the closed-loop question "how fast is a
//! batch of B"; this subsystem answers the production question the ROADMAP
//! asks — *what latency does a user see at a given offered load?* It is a
//! deterministic discrete-event simulator over the same cycle-accurate
//! models, composed of four pieces:
//!
//! * [`traffic`] — seeded open-loop arrival processes per model (Poisson,
//!   MMPP-2 bursts, replayable traces) built on `util::rng`; open-loop
//!   because closed-loop measurement hides queueing delay entirely;
//! * [`tenancy`] — several networks resident in one `ImaArrayPool`: the
//!   pool is carved into disjoint per-tenant array slices through the
//!   shared LRU `coordinator::plan_cache`, and an [`tenancy::Arbiter`]
//!   (FIFO, weighted round-robin, shortest-job-first on planned cycles)
//!   breaks ties when several tenants are dispatchable at one instant;
//! * [`batcher`] — dynamic batching behind a max-batch/max-wait admission
//!   window; formed batches execute through
//!   [`scheduler::run_batched`](crate::coordinator::scheduler::run_batched),
//!   so every cost (pipelining, PCM reprogramming for staged tenants,
//!   cut-boundary DMA) is exactly the batch engine's;
//! * [`metrics`] — per-model latency percentiles from a fixed-bin log
//!   histogram (p50/p95/p99 bit-identical under a fixed seed), queue
//!   depth, per-resource utilization, and drop statistics.
//!
//! Dispatch is *per-resource* and interval-precise: every batch carries a
//! [`ReservationProfile`](crate::coordinator::ReservationProfile) (the
//! merged busy intervals of every core,
//! accelerator, mux, DMA/programming port and array it occupies), and the
//! simulator keeps one [`ResourceTimeline`] of committed busy-interval
//! sets across the pool. The default **backfilling** arbiter dispatches a
//! tenant's batch at the earliest instant every busy interval of its
//! profile fits — including inside idle gaps of batches already committed
//! — so two tenants on disjoint array slices genuinely overlap, small
//! core sections of different tenants share the (per-core, affinity-
//! rotated) complex, and contended shared engines still serialize
//! correctly. [`ServeConfig::backfill`]` = false` (`--no-backfill`) falls
//! back to the conservative PR 3 envelope reservation bit-identically —
//! the regression suite pins that, and that the backfilled makespan never
//! exceeds the envelope one. A staged tenant's PCM reprogramming charges
//! its own array timelines, not a global clock, and with
//! [`ServeConfig::stream_weights`] the reprogramming of pass k+1 streams
//! under pass k's compute tail. `overlap: false` restores the PR 2 model —
//! the whole pool is one opaque server and batches serialize on it,
//! bit-identical to the serialized baseline the regression tests pin.
//!
//! The event loop is exact, not ticked: a binary-heap next-event queue
//! keyed by (dispatch instant, tenant id) jumps the clock from one
//! dispatch to the next. Stored instants are lower bounds, revalidated
//! lazily on pop, so a dispatch costs O(log n_tenants) instead of a
//! linear scan per event. With one model, a 1-wide window, and overlap
//! off, the whole apparatus collapses to back-to-back sequential serving,
//! bit-identical to the scheduler's sequential baseline — the regression
//! tests pin that, and the seeded-trace determinism of the percentile
//! tables.
//!
//! Long horizons stay flat: before each event the loop threads the
//! minimum over its tenants' next admission instants into
//! [`ResourceTimeline::prune_before`] as a **watermark**, folding
//! committed busy intervals that can never conflict again — so the gap
//! search walks the live window, not the whole serving history.
//! `--no-prune` ([`ServeConfig::prune`]` = false`) keeps everything, and
//! the dispatch table is bit-identical either way (pinned by
//! `tests/prop_prune.rs` and the CI pruning smoke). The hot path is
//! allocation-lean: batch costs and their reservation profiles are
//! interned in the shared plan cache (`PlanCache::get_or_batch`), claim
//! scratch is reused across events, and the run's work is counted
//! deterministically in [`ServeCounters`] (event-loop steps, candidate
//! validations, gap-search probe steps, live/pruned interval nodes) so
//! perf regressions pin on counters instead of wall clock — `imcc
//! bench-timeline` writes both as the machine-readable baseline.

pub mod batcher;
pub mod metrics;
pub mod tenancy;
pub mod traffic;

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::rc::Rc;

use crate::arch::{PowerModel, SystemConfig};
use crate::coordinator::timeline::{
    res_label, IntervalSet, ResMap, ResourceTimeline, N_CORES, RES_ARRAY0, RES_CORE0, RES_DMA,
    RES_DWACC, RES_IMA_MUX, RES_PROG,
};
use crate::coordinator::{BatchConfig, BatchReport, PlanCache, Strategy};
use crate::net::bottleneck::bottleneck;
use crate::net::mobilenetv2::mobilenet_v2;
use crate::net::Network;
use crate::util::json::{obj, Json};
use crate::util::table::{f, Table};

pub use batcher::{BatchWindow, TenantQueue};
pub use metrics::{LogHistogram, ResourceUtil, ServeCounters, TenantStats};
pub use tenancy::{place_tenants, Arbiter, Claim, Policy, Tenancy, Tenant};
pub use traffic::TrafficModel;

/// Default traffic seed, shared by the library default, the CLI, and the
/// serving report so "default" means one thing everywhere.
pub const DEFAULT_SEED: u64 = 0xC0FF_EE00;

/// Human label of a dispatch discipline — shared by the serve table and
/// the serving-report sweep so the two can never drift: `serialized`
/// (PR 2 single server), `overlapped` (PR 3 envelopes), or `backfilled`
/// (interval gaps).
pub fn dispatch_label(overlap: bool, backfill: bool) -> &'static str {
    if !overlap {
        "serialized"
    } else if backfill {
        "backfilled"
    } else {
        "overlapped"
    }
}

/// One model's serving contract: its network, arrival process, and WRR
/// weight.
#[derive(Clone, Debug)]
pub struct ModelTraffic {
    pub net: Network,
    pub traffic: TrafficModel,
    /// Weighted-round-robin share (≥ 1; ignored by FIFO/SJF).
    pub weight: u64,
}

/// Serving-simulation knobs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Crossbar arrays in the shared pool.
    pub n_arrays: usize,
    pub policy: Policy,
    pub window: BatchWindow,
    /// Request pipelining inside each dispatched batch.
    pub pipeline: bool,
    /// Charge staged-pass boundary DMA (see `scheduler`).
    pub charge_dma: bool,
    /// Per-resource dispatch: overlap batches whose reservation profiles
    /// are disjoint. Off = the PR 2 model (one opaque pool server).
    pub overlap: bool,
    /// Backfill batches into idle gaps of committed reservations (busy
    /// interval sets, plus per-tenant core-affinity rotation). Off = the
    /// conservative PR 3 envelope reservation, bit-identical
    /// (`--no-backfill`). Per timeline state the backfilled start is
    /// never later than the envelope one; the end-to-end makespan
    /// conservation is pinned empirically by the regression/property
    /// suites and the CI smoke on the shipped scenarios.
    pub backfill: bool,
    /// Stream staged PCM reprogramming under the previous pass's compute
    /// tail (see `scheduler::BatchConfig::stream_weights`).
    pub stream_weights: bool,
    /// Fold committed timeline intervals behind the oldest possible
    /// future dispatch into a watermark (`--no-prune` disables). Pruning
    /// is invisible to the dispatch table — only the gap-search work and
    /// live-interval footprint shrink (both counted in
    /// [`ServeCounters`]).
    pub prune: bool,
    /// Master seed; per-model arrival seeds derive from it.
    pub seed: u64,
    /// Open-loop arrival horizon in seconds (the sim then drains).
    pub duration_s: f64,
    /// Abandon requests that waited longer than this before dispatch
    /// (cycles; 0 disables deadlines).
    pub deadline_cy: u64,
    /// Allow 90° tile rotation during placement.
    pub rotate: bool,
    pub strategy: Strategy,
    /// LRU bound for the internal plan cache.
    pub plan_cache_cap: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            n_arrays: 64,
            policy: Policy::Fifo,
            window: BatchWindow::default(),
            pipeline: true,
            charge_dma: true,
            overlap: true,
            backfill: true,
            stream_weights: false,
            prune: true,
            seed: DEFAULT_SEED,
            duration_s: 0.25,
            deadline_cy: 0,
            rotate: false,
            strategy: Strategy::ImaDw,
            plan_cache_cap: 32,
        }
    }
}

/// Outcome of one serving simulation.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub policy: Policy,
    pub seed: u64,
    pub n_arrays: usize,
    /// Per-resource dispatch was enabled (config echo).
    pub overlap: bool,
    /// Backfilling dispatch was enabled (config echo).
    pub backfill: bool,
    /// Streamed staged reprogramming was enabled (config echo).
    pub stream_weights: bool,
    /// Watermark pruning was enabled (config echo). Never affects the
    /// dispatch table — [`render_table`](Self::render_table) is
    /// bit-identical with it on or off.
    pub prune: bool,
    /// Arrival horizon, cycles.
    pub duration_cycles: u64,
    /// Completion of the last batch (≥ duration while draining).
    pub makespan_cycles: u64,
    /// Cycles at least one batch was in flight (the *union* of batch
    /// spans — overlapped batches do not double-count, so this never
    /// exceeds the makespan; without overlap it is the plain sum).
    pub busy_cycles: u64,
    pub cycle_ns: f64,
    /// Deepest pool-wide simultaneous backlog (sum of every tenant's
    /// pending queue) observed at any event-loop step — the quantity
    /// per-tenant peaks cannot reconstruct (aligned bursts add up,
    /// disjoint bursts do not).
    pub peak_backlog: u64,
    pub tenants: Vec<TenantStats>,
    /// Busy cycles per pool resource (the core-complex aggregate, each
    /// core, DW accelerator, IMA mux, DMA port, PCM programming port, the
    /// array aggregate, and the busiest single array).
    pub resource_busy: Vec<ResourceUtil>,
    /// Deterministic perf counters of the run (event-loop steps,
    /// validations, gap-search probes, live/pruned interval nodes) —
    /// reported in the JSON baseline, never in the dispatch table.
    pub counters: ServeCounters,
}

impl ServeReport {
    /// Fraction of the makespan at least one batch was in flight.
    pub fn utilization(&self) -> f64 {
        if self.makespan_cycles == 0 {
            0.0
        } else {
            self.busy_cycles as f64 / self.makespan_cycles as f64
        }
    }

    /// Utilization of one resource entry: busy cycles over `units`
    /// physical units times the makespan.
    pub fn resource_utilization(&self, r: &ResourceUtil) -> f64 {
        let denom = r.units as f64 * self.makespan_cycles as f64;
        if denom == 0.0 {
            0.0
        } else {
            r.busy_cycles as f64 / denom
        }
    }

    pub fn total_served(&self) -> u64 {
        self.tenants.iter().map(|t| t.served).sum()
    }

    pub fn total_dropped(&self) -> u64 {
        self.tenants.iter().map(|t| t.dropped).sum()
    }

    /// Aggregate served throughput over the makespan, inferences/s.
    pub fn inferences_per_s(&self) -> f64 {
        let makespan_s = self.makespan_cycles as f64 * self.cycle_ns * 1e-9;
        if makespan_s > 0.0 {
            self.total_served() as f64 / makespan_s
        } else {
            0.0
        }
    }

    fn ms(&self, cy: u64) -> f64 {
        cy as f64 * self.cycle_ns * 1e-6
    }

    /// The per-model latency table the CLI prints; bit-identical across
    /// runs with the same seed (the determinism tests compare this
    /// string). A per-resource utilization line follows the table.
    pub fn render_table(&self) -> String {
        let dispatch = dispatch_label(self.overlap, self.backfill);
        let title = format!(
            "serving — {} policy, {} arrays, seed {:#x}, {} dispatch, pool util {:.0}%",
            self.policy.label(),
            self.n_arrays,
            self.seed,
            dispatch,
            self.utilization() * 100.0
        );
        let mut t = Table::new(
            &title,
            &[
                "model", "arrays", "passes", "occ", "arrivals", "served", "dropped", "batches",
                "mean B", "p50 ms", "p95 ms", "p99 ms", "peak q",
            ],
        );
        for s in &self.tenants {
            let (p50, p95, p99) = s.latency.percentiles();
            t.row([
                s.name.to_string(),
                s.arrays.to_string(),
                s.n_passes.to_string(),
                format!("{:.0}%", s.occupancy * 100.0),
                s.arrivals.to_string(),
                s.served.to_string(),
                s.dropped.to_string(),
                s.batches.to_string(),
                f(s.mean_batch(), 1),
                f(self.ms(p50), 3),
                f(self.ms(p95), 3),
                f(self.ms(p99), 3),
                s.peak_queue.to_string(),
            ]);
        }
        let mut out = t.render();
        let util: Vec<String> = self
            .resource_busy
            .iter()
            .map(|r| format!("{} {:.0}%", r.name, self.resource_utilization(r) * 100.0))
            .collect();
        out.push_str(&format!("per-resource utilization: {}\n", util.join(", ")));
        out.push_str(&format!("peak simultaneous backlog: {} requests\n", self.peak_backlog));
        out
    }

    /// Machine-readable summary (the `BENCH_serve.json` payload): config
    /// echo, aggregate throughput, per-tenant percentiles, per-resource
    /// utilization.
    pub fn to_json(&self) -> Json {
        let tenants: Vec<Json> = self
            .tenants
            .iter()
            .map(|s| {
                let (p50, p95, p99) = s.latency.percentiles();
                obj([
                    ("model", s.name.as_ref().into()),
                    ("arrays", s.arrays.into()),
                    ("passes", s.n_passes.into()),
                    ("arrivals", (s.arrivals as f64).into()),
                    ("served", (s.served as f64).into()),
                    ("dropped", (s.dropped as f64).into()),
                    ("batches", (s.batches as f64).into()),
                    ("mean_batch", s.mean_batch().into()),
                    ("p50_ms", self.ms(p50).into()),
                    ("p95_ms", self.ms(p95).into()),
                    ("p99_ms", self.ms(p99).into()),
                    ("peak_queue", s.peak_queue.into()),
                    ("peak_queue_at_dispatch", s.peak_queue_at_dispatch.into()),
                ])
            })
            .collect();
        let resources: Vec<Json> = self
            .resource_busy
            .iter()
            .map(|r| {
                obj([
                    ("name", r.name.as_ref().into()),
                    ("busy_cycles", (r.busy_cycles as f64).into()),
                    ("units", (r.units as f64).into()),
                    ("utilization", self.resource_utilization(r).into()),
                ])
            })
            .collect();
        let c = &self.counters;
        let counters = obj([
            ("steps", (c.steps as f64).into()),
            ("validations", (c.validations as f64).into()),
            ("probes", (c.probes as f64).into()),
            ("live_intervals", (c.live_intervals as f64).into()),
            ("peak_live_intervals", (c.peak_live_intervals as f64).into()),
            ("pruned_intervals", (c.pruned_intervals as f64).into()),
            ("watermark", (c.watermark as f64).into()),
        ]);
        obj([
            ("policy", self.policy.label().into()),
            ("seed", format!("{:#x}", self.seed).into()),
            ("arrays", self.n_arrays.into()),
            ("overlap", self.overlap.into()),
            ("backfill", self.backfill.into()),
            ("stream_weights", self.stream_weights.into()),
            ("prune", self.prune.into()),
            ("duration_cycles", (self.duration_cycles as f64).into()),
            ("makespan_cycles", (self.makespan_cycles as f64).into()),
            ("busy_cycles", (self.busy_cycles as f64).into()),
            ("peak_backlog", (self.peak_backlog as f64).into()),
            ("pool_utilization", self.utilization().into()),
            ("inf_per_s", self.inferences_per_s().into()),
            ("served", (self.total_served() as f64).into()),
            ("dropped", (self.total_dropped() as f64).into()),
            ("counters", counters),
            ("tenants", Json::Arr(tenants)),
            ("resources", Json::Arr(resources)),
        ])
    }
}

/// Networks the CLI can serve by name.
pub fn model_by_name(name: &str) -> Result<Network, String> {
    match name.trim().to_ascii_lowercase().as_str() {
        "mobilenetv2" | "mnv2" | "mobilenet" => Ok(mobilenet_v2(224)),
        "bottleneck" | "bn" => Ok(bottleneck()),
        other => Err(format!("unknown model `{other}` (mobilenetv2|bottleneck)")),
    }
}

/// The canonical two-model mix — MobileNetV2 plus the Bottleneck case
/// study under equal-rate Poisson traffic, equal WRR weight. Shared by
/// the serving report, the benches, and the regression tests so they all
/// measure the same tenancy.
pub fn mnv2_bottleneck_pair(rate_per_s: f64) -> Vec<ModelTraffic> {
    vec![
        ModelTraffic {
            net: mobilenet_v2(224),
            traffic: TrafficModel::Poisson { rate_per_s },
            weight: 1,
        },
        ModelTraffic {
            net: bottleneck(),
            traffic: TrafficModel::Poisson { rate_per_s },
            weight: 1,
        },
    ]
}

/// `n` bottleneck tenants with distinct names under equal Poisson load —
/// the multi-tenant fleet the serve bench and `imcc bench-timeline` both
/// measure, so their numbers describe the same tenancy.
pub fn bottleneck_fleet(n: usize, rate_per_s: f64) -> Vec<ModelTraffic> {
    (0..n)
        .map(|i| {
            let mut net = bottleneck();
            net.name = format!("bn-{i}");
            ModelTraffic {
                net,
                traffic: TrafficModel::Poisson { rate_per_s },
                weight: 1,
            }
        })
        .collect()
}

/// Shared simulation context: the placed tenants, the plan cache the
/// batch reports (cycles, energy, reservation profile) are interned in —
/// repeated (tenant, batch-size) points share one allocation, within this
/// run and across sweep points reusing the cache — and a thin per-run
/// memo in front of it so the event loop's repeated lookups are one
/// small-key hash, not a full cache-key rebuild per validation.
struct SimCtx<'a> {
    models: &'a [ModelTraffic],
    tenancy: &'a Tenancy,
    cfg: &'a SystemConfig,
    pm: &'a PowerModel,
    scfg: &'a ServeConfig,
    cache: &'a mut PlanCache,
    memo: HashMap<(usize, usize), Rc<BatchReport>>,
}

impl SimCtx<'_> {
    fn batch_cost(&mut self, tenant: usize, batch: usize) -> Rc<BatchReport> {
        if let Some(rep) = self.memo.get(&(tenant, batch)) {
            return Rc::clone(rep);
        }
        let rep = self.cache.get_or_batch(
            &self.models[tenant].net,
            self.scfg.strategy,
            self.cfg,
            self.pm,
            &self.tenancy.tenants[tenant].plan,
            BatchConfig {
                batch,
                pipeline: self.scfg.pipeline,
                charge_dma: self.scfg.charge_dma,
                stream_weights: self.scfg.stream_weights,
            },
        );
        self.memo.insert((tenant, batch), Rc::clone(&rep));
        rep
    }
}

/// Validate one tenant's next dispatch: the earliest instant its batch can
/// start given its queue and (in overlap mode) the pool timeline, plus the
/// batch it would form there. Expired requests are dropped lazily at the
/// would-be dispatch instant (charged to `st`). `None` once the queue is
/// drained.
#[allow(clippy::too_many_arguments)]
fn validate_candidate(
    q: &mut TenantQueue,
    st: &mut TenantStats,
    tenant: usize,
    ctx: &mut SimCtx<'_>,
    timeline: &ResourceTimeline,
    pool_free: u64,
    rmap: ResMap,
) -> Option<(u64, usize, u64)> {
    let scfg = ctx.scfg;
    loop {
        let r = q.ready_at(&scfg.window)?;
        // fixed point: waiting for resources may let more arrivals join
        // the window, which may change the profile, which may move the
        // instant — batch size normally only grows, so this converges in
        // a round or two
        let mut b = q.depth_at(r).min(scfg.window.max_batch).max(1);
        let mut td;
        let mut rounds = 0usize;
        loop {
            let cost = ctx.batch_cost(tenant, b);
            td = if scfg.overlap {
                timeline.earliest_start(&cost.profile, rmap, r)
            } else {
                r.max(pool_free)
            };
            let b2 = q.depth_at(td).min(scfg.window.max_batch).max(1);
            if b2 == b {
                break;
            }
            rounds += 1;
            if rounds > scfg.window.max_batch {
                // cycle guard: a staged profile's intervals move with the
                // batch size, so under backfilling a bigger batch can fit
                // an *earlier* gap and the fixed point may oscillate.
                // Shrink strictly until the size is admissible at its own
                // dispatch instant — the dispatcher admits exactly the
                // validated size, so the committed profile is always the
                // one checked here.
                if b2 > b {
                    break; // enough arrivals by td to admit exactly b
                }
            }
            b = b2;
        }
        // backlog snapshot at the candidate instant, taken before lazy
        // drops so expired-but-still-queued requests count toward the
        // peak a client would have observed; the every-event sample in
        // the main loop augments this, never undercuts it
        let depth = q.depth_at(td);
        st.peak_queue = st.peak_queue.max(depth);
        st.peak_queue_at_dispatch = st.peak_queue_at_dispatch.max(depth);
        // lazy abandonment: clients that waited past their deadline are
        // gone by the time this tenant would dispatch
        if scfg.deadline_cy > 0 {
            let d = q.drop_expired(td, scfg.deadline_cy);
            if d > 0 {
                st.dropped += d;
                continue; // window state changed — recompute
            }
        }
        let cycles = ctx.batch_cost(tenant, b).cycles;
        return Some((td, b, cycles));
    }
}

/// Run the serving simulation to completion (arrival horizon + drain)
/// with a private plan cache.
pub fn simulate(
    models: &[ModelTraffic],
    scfg: &ServeConfig,
    pm: &PowerModel,
) -> Result<ServeReport, String> {
    let mut cache = PlanCache::with_capacity(scfg.plan_cache_cap);
    simulate_with_cache(models, scfg, pm, &mut cache)
}

/// [`simulate`] against a caller-owned plan cache: sweeps re-running the
/// same (network, pool) points skip re-placement entirely.
pub fn simulate_with_cache(
    models: &[ModelTraffic],
    scfg: &ServeConfig,
    pm: &PowerModel,
    cache: &mut PlanCache,
) -> Result<ServeReport, String> {
    if models.is_empty() {
        return Err("no models to serve".into());
    }
    if scfg.window.max_batch == 0 {
        return Err("admission window must admit ≥ 1 request (max_batch ≥ 1)".into());
    }
    let cfg = SystemConfig::scaled_up(scfg.n_arrays);
    let cycle_ns = cfg.freq.cycle_ns();
    let duration_cy = (scfg.duration_s * 1e9 / cycle_ns) as u64;

    // borrow the networks — placement only reads them, no clones
    let nets: Vec<&Network> = models.iter().map(|m| &m.net).collect();
    let tenancy = place_tenants(&nets, cfg.xbar_rows, scfg.n_arrays, scfg.rotate, cache)?;

    // seeded, per-model arrival streams
    let mut queues: Vec<TenantQueue> = Vec::with_capacity(models.len());
    let mut stats: Vec<TenantStats> = Vec::with_capacity(models.len());
    for (i, (m, ten)) in models.iter().zip(tenancy.tenants.iter()).enumerate() {
        let seed_i = scfg
            .seed
            .wrapping_add((i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let arr = traffic::arrivals(&m.traffic, seed_i, duration_cy, cycle_ns);
        let mut st = TenantStats::new(&ten.name, ten.arrays, ten.n_passes(), ten.occupancy);
        st.arrivals = arr.len() as u64;
        queues.push(TenantQueue::new(arr));
        stats.push(st);
    }
    let weights: Vec<u64> = models.iter().map(|m| m.weight).collect();
    let mut arbiter = Arbiter::new(scfg.policy, &weights);
    let mut ctx = SimCtx {
        models,
        tenancy: &tenancy,
        cfg: &cfg,
        pm,
        scfg,
        cache,
        memo: HashMap::new(),
    };

    // core-affinity rotation is a backfill refinement: the envelope
    // arbiter keeps affinity 0 so `--no-backfill` reproduces the PR 3
    // fused-complex dispatch bit-identically
    let rmaps: Vec<ResMap> = tenancy
        .tenants
        .iter()
        .map(|ten| ResMap {
            array_base: ten.array_base,
            core_base: if scfg.backfill && scfg.overlap {
                ten.core_base
            } else {
                0
            },
        })
        .collect();
    let mut timeline = ResourceTimeline::with_resources(scfg.backfill, RES_ARRAY0 + scfg.n_arrays);
    let mut pool_free: u64 = 0; // serialized-mode single-server clock
    // union of batch spans — an interval set, because a backfilled batch
    // validated later may legitimately start in an idle gap *before* an
    // earlier-dispatched batch (that is the point of backfilling; every
    // start still respects its requests' arrivals and the resource
    // timeline)
    let mut inflight = IntervalSet::new();
    let mut makespan: u64 = 0;
    let mut peak_backlog: u64 = 0;

    // next-event queue keyed by (dispatch instant, tenant id); stored
    // instants are lower bounds (queues only fill, resources only get
    // busier), revalidated lazily on pop — ties break deterministically
    // toward the lower tenant id via the arbiter below
    let mut heap: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
    for (i, q) in queues.iter().enumerate() {
        if let Some(r) = q.ready_at(&scfg.window) {
            heap.push(Reverse((r, i)));
        }
    }

    // event-loop work counters (deterministic under a fixed seed)
    let mut steps: u64 = 0;
    let mut validations: u64 = 0;
    // claim scratch, reused across events — the loop allocates nothing
    // once the memoized batch costs are warm
    let mut claims: Vec<Claim> = Vec::new();
    let mut claim_batches: Vec<usize> = Vec::new();

    loop {
        // watermark pruning: no future dispatch can probe before the
        // earliest next admission instant across tenants (`ready_at` is
        // nondecreasing per queue), so committed intervals wholly before
        // it can never conflict again — fold them away
        if scfg.prune {
            if let Some(w) = queues.iter().filter_map(|q| q.ready_at(&scfg.window)).min() {
                timeline.prune_before(w);
            }
        }
        // pop-and-validate until every remaining stored key exceeds the
        // best validated instant: `claims` then holds exactly the tenants
        // dispatchable at `t_min`
        claims.clear();
        claim_batches.clear();
        let mut t_min: Option<u64> = None;
        while let Some(&Reverse((t_est, i))) = heap.peek() {
            if t_min.is_some_and(|tm| t_est > tm) {
                break;
            }
            heap.pop();
            validations += 1;
            let Some((td, b, cycles)) = validate_candidate(
                &mut queues[i],
                &mut stats[i],
                i,
                &mut ctx,
                &timeline,
                pool_free,
                rmaps[i],
            ) else {
                continue; // queue drained (e.g. emptied by drops)
            };
            let claim = Claim {
                tenant: i,
                head_arrival: queues[i].head_arrival().unwrap_or(u64::MAX),
                planned_cycles: cycles,
            };
            match t_min {
                Some(tm) if td > tm => heap.push(Reverse((td, i))),
                Some(tm) if td == tm => {
                    claims.push(claim);
                    claim_batches.push(b);
                }
                _ => {
                    // strictly earlier: everything validated so far goes
                    // back at its (still valid) validated instant
                    if let Some(tm_old) = t_min {
                        for c in claims.drain(..) {
                            heap.push(Reverse((tm_old, c.tenant)));
                        }
                        claim_batches.clear();
                    }
                    t_min = Some(td);
                    claims.push(claim);
                    claim_batches.push(b);
                }
            }
        }
        let Some(t) = t_min else { break };
        debug_assert!(!claims.is_empty());
        steps += 1;

        // every-event backlog sampling (pre-admission): each tenant's
        // pending depth at this dispatch instant, and the pool-wide
        // simultaneous backlog no per-tenant instrument can reconstruct
        let mut backlog: usize = 0;
        for (i, q) in queues.iter().enumerate() {
            let d = q.depth_at(t);
            stats[i].peak_queue = stats[i].peak_queue.max(d);
            backlog += d;
        }
        peak_backlog = peak_backlog.max(backlog as u64);

        let pick_tenant = arbiter.pick(&claims);
        // losers stay candidates at the same instant (still lower bounds)
        for c in &claims {
            if c.tenant != pick_tenant {
                heap.push(Reverse((t, c.tenant)));
            }
        }
        let pick_ix = claims.iter().position(|c| c.tenant == pick_tenant).unwrap();
        let b_claim = claim_batches[pick_ix];

        // admit exactly the validated batch: the timeline was checked
        // against profile(b_claim), and validation guarantees at least
        // b_claim arrivals are pending at `t`
        let admitted = queues[pick_tenant].admit(t, b_claim);
        let bsz = admitted.len();
        debug_assert!(bsz >= 1);
        debug_assert_eq!(bsz, b_claim);
        let cost = ctx.batch_cost(pick_tenant, bsz);
        let end = t + cost.cycles;
        timeline.commit(t, &cost.profile, rmaps[pick_tenant]);
        pool_free = pool_free.max(end);
        makespan = makespan.max(end);
        // pool-busy union: overlapped spans do not double-count
        inflight.insert(t, end);

        let st = &mut stats[pick_tenant];
        st.batches += 1;
        st.served += bsz as u64;
        st.busy_cycles += cost.cycles;
        st.energy_j += cost.energy_j;
        for a in &admitted {
            st.latency.record(end - a);
        }
        if let Some(r) = queues[pick_tenant].ready_at(&scfg.window) {
            heap.push(Reverse((r.max(t), pick_tenant)));
        }
    }

    // per-resource utilization breakdown from the committed timelines:
    // the core-complex aggregate (8 units), each core's own row, then the
    // shared engines
    let cores_busy: u64 = (0..N_CORES).map(|c| timeline.busy_cycles(RES_CORE0 + c)).sum();
    let mut resource_busy = vec![ResourceUtil::new("cores", cores_busy, N_CORES as u64)];
    for c in 0..N_CORES {
        resource_busy.push(ResourceUtil::new(
            &res_label(RES_CORE0 + c),
            timeline.busy_cycles(RES_CORE0 + c),
            1,
        ));
    }
    resource_busy.extend([
        ResourceUtil::new("dw_acc", timeline.busy_cycles(RES_DWACC), 1),
        ResourceUtil::new("ima_mux", timeline.busy_cycles(RES_IMA_MUX), 1),
        ResourceUtil::new("dma", timeline.busy_cycles(RES_DMA), 1),
        ResourceUtil::new("pcm_prog", timeline.busy_cycles(RES_PROG), 1),
    ]);
    let mut arrays_total = 0u64;
    let mut array_peak = (0u64, RES_ARRAY0);
    for (res, busy) in timeline.busy_per_resource() {
        if res >= RES_ARRAY0 {
            arrays_total += busy;
            if busy > array_peak.0 {
                array_peak = (busy, res);
            }
        }
    }
    resource_busy.push(ResourceUtil::new("arrays", arrays_total, scfg.n_arrays as u64));
    resource_busy.push(ResourceUtil::new(&res_label(array_peak.1), array_peak.0, 1));

    let tl_stats = timeline.stats();
    let counters = ServeCounters {
        steps,
        validations,
        probes: tl_stats.probes,
        live_intervals: tl_stats.live_nodes,
        peak_live_intervals: tl_stats.peak_live_nodes,
        pruned_intervals: tl_stats.pruned_nodes,
        watermark: tl_stats.watermark,
    };

    Ok(ServeReport {
        policy: scfg.policy,
        seed: scfg.seed,
        n_arrays: scfg.n_arrays,
        overlap: scfg.overlap,
        backfill: scfg.backfill,
        stream_weights: scfg.stream_weights,
        prune: scfg.prune,
        duration_cycles: duration_cy,
        makespan_cycles: makespan,
        busy_cycles: inflight.total(),
        cycle_ns,
        peak_backlog,
        tenants: stats,
        resource_busy,
        counters,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_models_serve_under_poisson() {
        let pm = PowerModel::paper();
        let scfg = ServeConfig {
            duration_s: 0.1,
            ..ServeConfig::default()
        };
        let rep = simulate(&mnv2_bottleneck_pair(200.0), &scfg, &pm).unwrap();
        assert_eq!(rep.tenants.len(), 2);
        for t in &rep.tenants {
            assert_eq!(t.n_passes, 1, "{} must be resident in 64 arrays", t.name);
            assert!(t.served > 0, "{} served nothing", t.name);
            assert_eq!(t.served + t.dropped, t.arrivals);
        }
        assert!(rep.utilization() > 0.0 && rep.utilization() <= 1.0);
        assert!(rep.makespan_cycles >= rep.busy_cycles);
        // every request completes no earlier than it arrives
        for t in &rep.tenants {
            assert!(t.latency.count() == t.served);
        }
        // the breakdown names every shared resource and no resource is
        // busier than the run is long
        assert!(rep.resource_busy.iter().any(|r| r.name.as_ref() == "cores"));
        for r in &rep.resource_busy {
            let u = rep.resource_utilization(r);
            assert!((0.0..=1.0).contains(&u), "{} at {u}", r.name);
        }
    }

    #[test]
    fn drain_completes_every_arrival_without_deadlines() {
        let pm = PowerModel::paper();
        let scfg = ServeConfig {
            duration_s: 0.02,
            ..ServeConfig::default()
        };
        // heavy overload: arrivals far outpace the pool, but with no
        // deadline the drain still serves every single one
        let rep = simulate(&mnv2_bottleneck_pair(5_000.0), &scfg, &pm).unwrap();
        for t in &rep.tenants {
            assert_eq!(t.served, t.arrivals, "{}", t.name);
            assert_eq!(t.dropped, 0);
        }
        assert!(rep.makespan_cycles > rep.duration_cycles, "drained past horizon");
    }

    #[test]
    fn deadlines_shed_load_under_overload() {
        let pm = PowerModel::paper();
        let scfg = ServeConfig {
            duration_s: 0.02,
            deadline_cy: 2_000_000, // 4 ms at 500 MHz
            ..ServeConfig::default()
        };
        let rep = simulate(&mnv2_bottleneck_pair(5_000.0), &scfg, &pm).unwrap();
        assert!(rep.total_dropped() > 0, "overload must shed");
        for t in &rep.tenants {
            assert_eq!(t.served + t.dropped, t.arrivals);
            // survivors waited at most deadline before dispatch, so their
            // latency is bounded by deadline + the largest batch service
            let worst_batch = rep.makespan_cycles; // loose but sufficient
            assert!(t.latency.max() <= scfg.deadline_cy + worst_batch);
        }
    }

    #[test]
    fn overlap_never_slows_serving_down() {
        // identical t=0 backlogs form identical batches in both modes, so
        // the overlapped makespan is provably ≤ the serialized sum
        let pm = PowerModel::paper();
        let models: Vec<ModelTraffic> = mnv2_bottleneck_pair(0.0)
            .into_iter()
            .map(|mut m| {
                m.traffic = TrafficModel::Trace {
                    arrivals_cy: vec![0; 12],
                };
                m
            })
            .collect();
        let base = ServeConfig {
            window: BatchWindow {
                max_batch: 4,
                max_wait_cy: 0,
            },
            duration_s: 0.02,
            ..ServeConfig::default()
        };
        let on = simulate(&models, &base, &pm).unwrap();
        let off = simulate(
            &models,
            &ServeConfig {
                overlap: false,
                ..base
            },
            &pm,
        )
        .unwrap();
        assert_eq!(on.total_served(), 24);
        assert_eq!(off.total_served(), 24);
        assert!(on.makespan_cycles <= off.makespan_cycles);
        assert!(on.busy_cycles <= on.makespan_cycles);
    }

    #[test]
    fn serve_json_has_the_bench_fields() {
        let pm = PowerModel::paper();
        let scfg = ServeConfig {
            duration_s: 0.05,
            ..ServeConfig::default()
        };
        let rep = simulate(&mnv2_bottleneck_pair(400.0), &scfg, &pm).unwrap();
        let j = rep.to_json();
        assert!(j.req("inf_per_s").as_f64().unwrap() > 0.0);
        assert_eq!(j.req("overlap"), &Json::Bool(true));
        assert_eq!(j.req("backfill"), &Json::Bool(true));
        assert_eq!(j.req("prune"), &Json::Bool(true));
        assert!(j.req("peak_backlog").as_f64().unwrap() >= 0.0);
        // the deterministic perf counters ride along for the baselines
        let c = j.req("counters");
        assert!(c.req("steps").as_f64().unwrap() > 0.0);
        assert!(c.req("probes").as_f64().unwrap() > 0.0);
        assert!(c.req("pruned_intervals").as_f64().unwrap() > 0.0);
        assert!(
            c.req("peak_live_intervals").as_f64().unwrap()
                >= c.req("live_intervals").as_f64().unwrap()
        );
        assert_eq!(j.req("tenants").as_arr().unwrap().len(), 2);
        let res = j.req("resources").as_arr().unwrap();
        assert!(res.iter().any(|r| r.req("name").as_str() == Some("cores")));
        // the per-core rows ride along with the aggregate
        for c in 0..8 {
            let name = format!("core{c}");
            assert!(res.iter().any(|r| r.req("name").as_str() == Some(name.as_str())));
        }
        for r in res {
            let u = r.req("utilization").as_f64().unwrap();
            assert!((0.0..=1.0).contains(&u));
        }
        // the JSON round-trips through the writer
        let text = j.to_string_pretty();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn model_by_name_roundtrip() {
        assert!(model_by_name("mobilenetv2").is_ok());
        assert!(model_by_name("MNV2").is_ok());
        assert!(model_by_name("bottleneck").is_ok());
        assert!(model_by_name("resnet50").is_err());
    }
}
