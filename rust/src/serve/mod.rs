//! Event-driven multi-model serving: the traffic layer of the scaled-up
//! system.
//!
//! PR 1's batch engine answers the closed-loop question "how fast is a
//! batch of B"; this subsystem answers the production question the ROADMAP
//! asks — *what latency does a user see at a given offered load?* It is a
//! deterministic discrete-event simulator over the same cycle-accurate
//! models, composed of four pieces:
//!
//! * [`traffic`] — seeded open-loop arrival processes per model (Poisson,
//!   MMPP-2 bursts, replayable traces) built on `util::rng`; open-loop
//!   because closed-loop measurement hides queueing delay entirely;
//! * [`tenancy`] — several networks resident in one `ImaArrayPool`: the
//!   pool is carved into disjoint per-tenant array slices through the
//!   shared LRU `coordinator::plan_cache`, and an [`tenancy::Arbiter`]
//!   (FIFO, weighted round-robin, shortest-job-first on planned cycles)
//!   picks which tenant dispatches when several have batches ready;
//! * [`batcher`] — dynamic batching behind a max-batch/max-wait admission
//!   window; formed batches execute through
//!   [`scheduler::run_batched`](crate::coordinator::scheduler::run_batched),
//!   so every cost (pipelining, PCM reprogramming for staged tenants,
//!   cut-boundary DMA) is exactly the batch engine's;
//! * [`metrics`] — per-model latency percentiles from a fixed-bin log
//!   histogram (p50/p95/p99 bit-identical under a fixed seed), queue
//!   depth, pool utilization, and drop statistics.
//!
//! The event loop is exact, not ticked: queues know when their admission
//! window closes (arrivals are precomputed), so the clock jumps from one
//! dispatch instant to the next. Batches serialize on the pool — cores,
//! DW accelerator, and the IMA mux are shared single resources — so one
//! batch is in flight at a time; within a batch, `run_batched` pipelines
//! requests over the tenant's arrays as before. With one model and a
//! 1-wide window the whole apparatus collapses to back-to-back sequential
//! serving, bit-identical to the scheduler's sequential baseline — the
//! regression tests pin that, and the seeded-trace determinism of the
//! percentile tables.

pub mod batcher;
pub mod metrics;
pub mod tenancy;
pub mod traffic;

use std::collections::HashMap;

use crate::arch::{PowerModel, SystemConfig};
use crate::coordinator::{run_batched, BatchConfig, PlanCache, Strategy};
use crate::net::bottleneck::bottleneck;
use crate::net::mobilenetv2::mobilenet_v2;
use crate::net::Network;
use crate::util::table::{f, Table};

pub use batcher::{BatchWindow, TenantQueue};
pub use metrics::{LogHistogram, TenantStats};
pub use tenancy::{place_tenants, Arbiter, Claim, Policy, Tenancy, Tenant};
pub use traffic::TrafficModel;

/// Default traffic seed, shared by the library default, the CLI, and the
/// serving report so "default" means one thing everywhere.
pub const DEFAULT_SEED: u64 = 0xC0FF_EE00;

/// One model's serving contract: its network, arrival process, and WRR
/// weight.
#[derive(Clone, Debug)]
pub struct ModelTraffic {
    pub net: Network,
    pub traffic: TrafficModel,
    /// Weighted-round-robin share (≥ 1; ignored by FIFO/SJF).
    pub weight: u64,
}

/// Serving-simulation knobs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Crossbar arrays in the shared pool.
    pub n_arrays: usize,
    pub policy: Policy,
    pub window: BatchWindow,
    /// Request pipelining inside each dispatched batch.
    pub pipeline: bool,
    /// Charge staged-pass boundary DMA (see `scheduler`).
    pub charge_dma: bool,
    /// Master seed; per-model arrival seeds derive from it.
    pub seed: u64,
    /// Open-loop arrival horizon in seconds (the sim then drains).
    pub duration_s: f64,
    /// Abandon requests that waited longer than this before dispatch
    /// (cycles; 0 disables deadlines).
    pub deadline_cy: u64,
    /// Allow 90° tile rotation during placement.
    pub rotate: bool,
    pub strategy: Strategy,
    /// LRU bound for the internal plan cache.
    pub plan_cache_cap: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            n_arrays: 64,
            policy: Policy::Fifo,
            window: BatchWindow::default(),
            pipeline: true,
            charge_dma: true,
            seed: DEFAULT_SEED,
            duration_s: 0.25,
            deadline_cy: 0,
            rotate: false,
            strategy: Strategy::ImaDw,
            plan_cache_cap: 32,
        }
    }
}

/// Outcome of one serving simulation.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub policy: Policy,
    pub seed: u64,
    pub n_arrays: usize,
    /// Arrival horizon, cycles.
    pub duration_cycles: u64,
    /// Completion of the last batch (≥ duration while draining).
    pub makespan_cycles: u64,
    /// Cycles the pool was executing a batch.
    pub busy_cycles: u64,
    pub cycle_ns: f64,
    pub tenants: Vec<TenantStats>,
}

impl ServeReport {
    /// Fraction of the makespan the pool was busy.
    pub fn utilization(&self) -> f64 {
        if self.makespan_cycles == 0 {
            0.0
        } else {
            self.busy_cycles as f64 / self.makespan_cycles as f64
        }
    }

    pub fn total_served(&self) -> u64 {
        self.tenants.iter().map(|t| t.served).sum()
    }

    pub fn total_dropped(&self) -> u64 {
        self.tenants.iter().map(|t| t.dropped).sum()
    }

    fn ms(&self, cy: u64) -> f64 {
        cy as f64 * self.cycle_ns * 1e-6
    }

    /// The per-model latency table the CLI prints; bit-identical across
    /// runs with the same seed (the determinism tests compare this
    /// string).
    pub fn render_table(&self) -> String {
        let title = format!(
            "serving — {} policy, {} arrays, seed {:#x}, pool util {:.0}%",
            self.policy.label(),
            self.n_arrays,
            self.seed,
            self.utilization() * 100.0
        );
        let mut t = Table::new(
            &title,
            &[
                "model", "arrays", "passes", "occ", "arrivals", "served", "dropped", "batches",
                "mean B", "p50 ms", "p95 ms", "p99 ms", "peak q",
            ],
        );
        for s in &self.tenants {
            let (p50, p95, p99) = s.latency.percentiles();
            t.row([
                s.name.clone(),
                s.arrays.to_string(),
                s.n_passes.to_string(),
                format!("{:.0}%", s.occupancy * 100.0),
                s.arrivals.to_string(),
                s.served.to_string(),
                s.dropped.to_string(),
                s.batches.to_string(),
                f(s.mean_batch(), 1),
                f(self.ms(p50), 3),
                f(self.ms(p95), 3),
                f(self.ms(p99), 3),
                s.peak_queue.to_string(),
            ]);
        }
        t.render()
    }
}

/// Networks the CLI can serve by name.
pub fn model_by_name(name: &str) -> Result<Network, String> {
    match name.trim().to_ascii_lowercase().as_str() {
        "mobilenetv2" | "mnv2" | "mobilenet" => Ok(mobilenet_v2(224)),
        "bottleneck" | "bn" => Ok(bottleneck()),
        other => Err(format!("unknown model `{other}` (mobilenetv2|bottleneck)")),
    }
}

/// The canonical two-model mix — MobileNetV2 plus the Bottleneck case
/// study under equal-rate Poisson traffic, equal WRR weight. Shared by
/// the serving report, the benches, and the regression tests so they all
/// measure the same tenancy.
pub fn mnv2_bottleneck_pair(rate_per_s: f64) -> Vec<ModelTraffic> {
    vec![
        ModelTraffic {
            net: mobilenet_v2(224),
            traffic: TrafficModel::Poisson { rate_per_s },
            weight: 1,
        },
        ModelTraffic {
            net: bottleneck(),
            traffic: TrafficModel::Poisson { rate_per_s },
            weight: 1,
        },
    ]
}

/// Shared simulation context: the placed tenants plus a memo of batch
/// costs — requests are identical, so (tenant, batch size) fully
/// determines the scheduler's outcome.
struct SimCtx<'a> {
    models: &'a [ModelTraffic],
    tenancy: &'a Tenancy,
    cfg: &'a SystemConfig,
    pm: &'a PowerModel,
    scfg: &'a ServeConfig,
    memo: HashMap<(usize, usize), (u64, f64)>,
}

impl SimCtx<'_> {
    /// (cycles, energy) of dispatching `batch` requests of `tenant`.
    fn batch_cost(&mut self, tenant: usize, batch: usize) -> (u64, f64) {
        // shared refs are Copy: lift them out so the closure does not
        // capture `self` alongside the `memo` borrow
        let (models, tenancy) = (self.models, self.tenancy);
        let (cfg, pm, scfg) = (self.cfg, self.pm, self.scfg);
        *self.memo.entry((tenant, batch)).or_insert_with(|| {
            let rep = run_batched(
                &models[tenant].net,
                scfg.strategy,
                cfg,
                pm,
                &tenancy.tenants[tenant].plan,
                BatchConfig {
                    batch,
                    pipeline: scfg.pipeline,
                    charge_dma: scfg.charge_dma,
                },
            );
            (rep.cycles, rep.energy_j)
        })
    }
}

/// Run the serving simulation to completion (arrival horizon + drain)
/// with a private plan cache.
pub fn simulate(
    models: &[ModelTraffic],
    scfg: &ServeConfig,
    pm: &PowerModel,
) -> Result<ServeReport, String> {
    let mut cache = PlanCache::with_capacity(scfg.plan_cache_cap);
    simulate_with_cache(models, scfg, pm, &mut cache)
}

/// [`simulate`] against a caller-owned plan cache: sweeps re-running the
/// same (network, pool) points skip re-placement entirely.
pub fn simulate_with_cache(
    models: &[ModelTraffic],
    scfg: &ServeConfig,
    pm: &PowerModel,
    cache: &mut PlanCache,
) -> Result<ServeReport, String> {
    if models.is_empty() {
        return Err("no models to serve".into());
    }
    if scfg.window.max_batch == 0 {
        return Err("admission window must admit ≥ 1 request (max_batch ≥ 1)".into());
    }
    let cfg = SystemConfig::scaled_up(scfg.n_arrays);
    let cycle_ns = cfg.freq.cycle_ns();
    let duration_cy = (scfg.duration_s * 1e9 / cycle_ns) as u64;

    let nets: Vec<Network> = models.iter().map(|m| m.net.clone()).collect();
    let tenancy = place_tenants(&nets, cfg.xbar_rows, scfg.n_arrays, scfg.rotate, cache)?;

    // seeded, per-model arrival streams
    let mut queues: Vec<TenantQueue> = Vec::with_capacity(models.len());
    let mut stats: Vec<TenantStats> = Vec::with_capacity(models.len());
    for (i, (m, ten)) in models.iter().zip(tenancy.tenants.iter()).enumerate() {
        let seed_i = scfg
            .seed
            .wrapping_add((i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let arr = traffic::arrivals(&m.traffic, seed_i, duration_cy, cycle_ns);
        let mut st = TenantStats::new(&ten.name, ten.arrays, ten.n_passes(), ten.occupancy);
        st.arrivals = arr.len() as u64;
        queues.push(TenantQueue::new(arr));
        stats.push(st);
    }
    let weights: Vec<u64> = models.iter().map(|m| m.weight).collect();
    let mut arbiter = Arbiter::new(scfg.policy, &weights);
    let mut ctx = SimCtx {
        models,
        tenancy: &tenancy,
        cfg: &cfg,
        pm,
        scfg,
        memo: HashMap::new(),
    };

    let mut pool_free: u64 = 0;
    let mut busy: u64 = 0;
    let mut makespan: u64 = 0;

    loop {
        // jump the clock to the earliest dispatch instant
        let mut t_min: Option<u64> = None;
        for q in &queues {
            if let Some(r) = q.ready_at(&scfg.window) {
                let td = r.max(pool_free);
                t_min = Some(t_min.map_or(td, |m: u64| m.min(td)));
            }
        }
        let Some(t) = t_min else { break };

        // lazy abandonment: clients that waited past their deadline are
        // gone by the time the pool would have picked them up
        if scfg.deadline_cy > 0 {
            let mut dropped = 0;
            for (i, q) in queues.iter_mut().enumerate() {
                let d = q.drop_expired(t, scfg.deadline_cy);
                stats[i].dropped += d;
                dropped += d;
            }
            if dropped > 0 {
                continue; // window states changed — recompute the instant
            }
        }

        // backlog snapshot at the decision instant
        for (i, q) in queues.iter().enumerate() {
            stats[i].peak_queue = stats[i].peak_queue.max(q.depth_at(t));
        }

        // claims of every tenant dispatchable exactly at t
        let mut claims: Vec<Claim> = Vec::new();
        for (i, q) in queues.iter().enumerate() {
            if let Some(r) = q.ready_at(&scfg.window) {
                if r.max(pool_free) == t {
                    let b = q.depth_at(t).min(scfg.window.max_batch);
                    let (cycles, _) = ctx.batch_cost(i, b);
                    claims.push(Claim {
                        tenant: i,
                        head_arrival: q.head_arrival().unwrap_or(u64::MAX),
                        planned_cycles: cycles,
                    });
                }
            }
        }
        assert!(!claims.is_empty(), "an instant with no dispatchable tenant");

        let pick = arbiter.pick(&claims);
        let admitted = queues[pick].admit(t, scfg.window.max_batch);
        let b = admitted.len();
        debug_assert!(b >= 1);
        let (cycles, energy_j) = ctx.batch_cost(pick, b);
        let end = t + cycles;
        pool_free = end;
        busy += cycles;
        makespan = makespan.max(end);

        let st = &mut stats[pick];
        st.batches += 1;
        st.served += b as u64;
        st.busy_cycles += cycles;
        st.energy_j += energy_j;
        for a in &admitted {
            st.latency.record(end - a);
        }
    }

    Ok(ServeReport {
        policy: scfg.policy,
        seed: scfg.seed,
        n_arrays: scfg.n_arrays,
        duration_cycles: duration_cy,
        makespan_cycles: makespan,
        busy_cycles: busy,
        cycle_ns,
        tenants: stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_models_serve_under_poisson() {
        let pm = PowerModel::paper();
        let scfg = ServeConfig {
            duration_s: 0.1,
            ..ServeConfig::default()
        };
        let rep = simulate(&mnv2_bottleneck_pair(200.0), &scfg, &pm).unwrap();
        assert_eq!(rep.tenants.len(), 2);
        for t in &rep.tenants {
            assert_eq!(t.n_passes, 1, "{} must be resident in 64 arrays", t.name);
            assert!(t.served > 0, "{} served nothing", t.name);
            assert_eq!(t.served + t.dropped, t.arrivals);
        }
        assert!(rep.utilization() > 0.0 && rep.utilization() <= 1.0);
        assert!(rep.makespan_cycles >= rep.busy_cycles);
        // every request completes no earlier than it arrives
        for t in &rep.tenants {
            assert!(t.latency.count() == t.served);
        }
    }

    #[test]
    fn drain_completes_every_arrival_without_deadlines() {
        let pm = PowerModel::paper();
        let scfg = ServeConfig {
            duration_s: 0.02,
            ..ServeConfig::default()
        };
        // heavy overload: arrivals far outpace the pool, but with no
        // deadline the drain still serves every single one
        let rep = simulate(&mnv2_bottleneck_pair(5_000.0), &scfg, &pm).unwrap();
        for t in &rep.tenants {
            assert_eq!(t.served, t.arrivals, "{}", t.name);
            assert_eq!(t.dropped, 0);
        }
        assert!(rep.makespan_cycles > rep.duration_cycles, "drained past horizon");
    }

    #[test]
    fn deadlines_shed_load_under_overload() {
        let pm = PowerModel::paper();
        let scfg = ServeConfig {
            duration_s: 0.02,
            deadline_cy: 2_000_000, // 4 ms at 500 MHz
            ..ServeConfig::default()
        };
        let rep = simulate(&mnv2_bottleneck_pair(5_000.0), &scfg, &pm).unwrap();
        assert!(rep.total_dropped() > 0, "overload must shed");
        for t in &rep.tenants {
            assert_eq!(t.served + t.dropped, t.arrivals);
            // survivors waited at most deadline before dispatch, so their
            // latency is bounded by deadline + the largest batch service
            let worst_batch = rep.busy_cycles; // loose but sufficient
            assert!(t.latency.max() <= scfg.deadline_cy + worst_batch);
        }
    }

    #[test]
    fn model_by_name_roundtrip() {
        assert!(model_by_name("mobilenetv2").is_ok());
        assert!(model_by_name("MNV2").is_ok());
        assert!(model_by_name("bottleneck").is_ok());
        assert!(model_by_name("resnet50").is_err());
    }
}
