//! Event-driven multi-model serving: the traffic layer of the scaled-up
//! system.
//!
//! PR 1's batch engine answers the closed-loop question "how fast is a
//! batch of B"; this subsystem answers the production question the ROADMAP
//! asks — *what latency does a user see at a given offered load?* It is a
//! deterministic discrete-event simulator over the same cycle-accurate
//! models, composed of a data plane (traffic, tenancy, batching, metrics)
//! and a control plane (admission, autoscaling) on top of it:
//!
//! * [`traffic`] — seeded open-loop arrival processes per model (Poisson,
//!   MMPP-2 bursts, replayable traces) built on `util::rng`; open-loop
//!   because closed-loop measurement hides queueing delay entirely;
//! * [`tenancy`] — several networks resident in one `ImaArrayPool`: the
//!   pool is carved into disjoint per-tenant array slices through the
//!   shared LRU `coordinator::plan_cache`, and an [`tenancy::Arbiter`]
//!   (FIFO, weighted round-robin, shortest-job-first on planned cycles)
//!   breaks ties when several tenants are dispatchable at one instant;
//! * [`batcher`] — dynamic batching behind a max-batch/max-wait admission
//!   window; formed batches execute through
//!   [`scheduler::run_batched`](crate::coordinator::scheduler::run_batched),
//!   so every cost (pipelining, PCM reprogramming for staged tenants,
//!   cut-boundary DMA) is exactly the batch engine's;
//! * [`metrics`] — per-model latency percentiles from a fixed-bin log
//!   histogram (p50/p95/p99 bit-identical under a fixed seed), queue
//!   depth, per-resource utilization, and drop/refusal statistics;
//! * [`admission`] — reject-on-arrival admission control against a
//!   per-tenant p95 latency budget (`--slo-p95`, cycles): every arrival
//!   faces a predictor built from the per-event queue sample, a
//!   worst-case drain bound over the tenant's service ceiling, and the
//!   online p95 of its completed requests — and is refused at the front
//!   door instead of aging in the queue toward a lazy deadline drop;
//! * [`autoscale`] — an online pool-resizing controller (`--autoscale`):
//!   backlog sustained across a hysteresis window grows a tenant's
//!   disjoint array slice out of the pool's free run (sustained idleness
//!   shrinks it, returning the tail for co-tenants to claim), re-planning
//!   through the shared plan cache and charging the PCM reprogramming of
//!   the moved arrays on the pool timeline — streamed under the
//!   `--stream-weights` overlap path, blocking the tenant's next dispatch
//!   otherwise.
//!
//! Dispatch is *per-resource* and interval-precise: every batch carries a
//! [`ReservationProfile`](crate::coordinator::ReservationProfile) (the
//! merged busy intervals of every core,
//! accelerator, mux, DMA/programming port and array it occupies), and the
//! simulator keeps one [`ResourceTimeline`] of committed busy-interval
//! sets across the pool. The default **backfilling** arbiter dispatches a
//! tenant's batch at the earliest instant every busy interval of its
//! profile fits — including inside idle gaps of batches already committed
//! — so two tenants on disjoint array slices genuinely overlap, small
//! core sections of different tenants share the (per-core, affinity-
//! rotated) complex, and contended shared engines still serialize
//! correctly. [`ServeConfig::backfill`]` = false` (`--no-backfill`) falls
//! back to the conservative PR 3 envelope reservation bit-identically —
//! the regression suite pins that, and that the backfilled makespan never
//! exceeds the envelope one. A staged tenant's PCM reprogramming charges
//! its own array timelines, not a global clock, and with
//! [`ServeConfig::stream_weights`] the reprogramming of pass k+1 streams
//! under pass k's compute tail. `overlap: false` restores the PR 2 model —
//! the whole pool is one opaque server and batches serialize on it,
//! bit-identical to the serialized baseline the regression tests pin.
//!
//! The event loop is exact, not ticked: a next-event queue keyed by
//! (dispatch instant, tenant id) jumps the clock from one dispatch to
//! the next. Stored instants are lower bounds (queues only fill,
//! resources only get busier), revalidated lazily on pop — so the queue
//! sees heavy churn: most pops push the same tenant straight back at a
//! later instant. The structure behind that contract is the [`evq`]
//! module's [`evq::EventQueue`]: a bucketed **calendar queue** by
//! default (extraction scans forward from the last extracted minimum,
//! which under the churn above almost always terminates in its first
//! occupied bucket), or the PR 3 binary heap under `--event-queue heap`
//! ([`ServeConfig::event_queue`]). Both realize the identical total
//! order on (instant, tenant), so dispatch tables, serve JSON, and
//! trace bytes are bit-identical across the two — `tests/prop_evq.rs`
//! and the CI event-queue smoke pin that — and the queue's own work
//! rides in [`ServeCounters`] as `evq_pushes`/`evq_pops`/`evq_stale`
//! (all mode-independent functions of the shared pop sequence; the
//! mode-*dependent* structural step counts appear only in `imcc
//! bench-timeline`'s heap-vs-calendar section). With one model, a
//! 1-wide window, and overlap off, the whole apparatus collapses to
//! back-to-back sequential serving, bit-identical to the scheduler's
//! sequential baseline — the regression tests pin that, and the
//! seeded-trace determinism of the percentile tables.
//!
//! Long horizons stay flat: before each event the loop threads the
//! minimum over its tenants' next admission instants into
//! [`ResourceTimeline::prune_before`] as a **watermark**, folding
//! committed busy intervals that can never conflict again — so the gap
//! search walks the live window, not the whole serving history.
//! `--no-prune` ([`ServeConfig::prune`]` = false`) keeps everything, and
//! the dispatch table is bit-identical either way (pinned by
//! `tests/prop_prune.rs` and the CI pruning smoke). Within that live
//! window the gap search additionally takes the timeline's **gap-skip
//! fast paths** (append-at-tail and no-usable-gap — see
//! `coordinator/timeline.rs`; `--no-gap-skip` /
//! [`ServeConfig::gap_skip`]` = false` disables them): dispatch
//! decisions are identical either way, only the `probes` counter drops.
//! The hot path is allocation-lean: batch costs and their reservation
//! profiles are interned in the shared plan cache
//! (`PlanCache::get_or_batch`), claim scratch is reused across events,
//! and the run's work is counted deterministically in [`ServeCounters`]
//! (event-loop steps, candidate validations, gap-search probe steps,
//! live/pruned interval nodes, event-queue traffic) so perf regressions
//! pin on counters instead of wall clock — `imcc bench-timeline` writes
//! both as the machine-readable baseline.
//!
//! Both controllers are strictly additive: with the budget unset (or
//! `--no-admission`) and `--no-autoscale` the loop takes exactly the
//! uncontrolled code paths and the dispatch table is bit-identical to the
//! uncontrolled baseline — `tests/prop_admission.rs` pins that, arrival
//! conservation (served + dropped + rejected = offered), and the SLO
//! conformance property; `tests/autoscale_regression.rs` pins the seeded
//! decision traces, the migration price, and the stale-pressure age-out.
//!
//! Every served request's latency is **decomposed** at its dispatch into
//! five telescoping phases that sum to it exactly ([`trace::decompose`]):
//! *queue wait* (arrival → the tenant's previous dispatch: head-of-line
//! blocking behind the batch in front), *batching wait* (→ the batch
//! window's close: filling or timing out), *migration stall* (→ the
//! autoscale `not_before` floor), *resource stall* (→ dispatch: the batch
//! was formed but its reservation profile did not fit the committed
//! timeline — charged to the resource the gap search last advanced the
//! start past, or to the whole pool in `--no-overlap` mode), and
//! *service* (→ completion). Each boundary is clamped into the window
//! the previous one leaves, so out-of-order instants (a request arriving
//! after its window closed, a floor already in the past) fold into the
//! neighboring phase instead of going negative. The decomposition is
//! always on — per-tenant phase percentiles ([`LatencyBreakdown`]) and
//! the pool-wide stall attribution ([`StallShare`]) ride in
//! [`ServeReport`] whether or not a trace is captured — while the
//! [`trace`] module's event recorder (batch lifecycles, per-resource
//! occupancy replayed from the committed profiles, admission/drop/scale
//! instants, Chrome `trace_event` export for Perfetto) is strictly
//! opt-in: [`TraceRecorder::Off`] is a no-op on the hot path, and
//! `tests/trace_regression.rs` pins traced and untraced runs
//! bit-identical on dispatch tables and counters.
//!
//! **Fleet sharding** ([`fleet`]) lifts all of the above from one cluster
//! to N: the single-cluster setup / event-loop body / report tail are
//! factored into [`NodeSim`] (pure code motion — `imcc serve --nodes 1`
//! is pinned bit-identical to the pre-fleet output on dispatch tables,
//! serve JSON, and trace bytes by `tests/fleet_regression.rs`), and the
//! fleet front-end routes tenants to heterogeneous nodes (per-node array
//! counts, timelines, and event queues) under one deterministic global
//! loop with consistent-hash, least-loaded, and replica router policies,
//! plus cross-node migration priced by the same PCM-reprogramming model
//! as [`apply_scale`].
//!
//! **Fault injection and self-healing** ([`faults`]) makes the fleet
//! survivable: `imcc serve --nodes N --faults SPEC` injects a
//! deterministic schedule of node faults — the grammar is
//! `kind@nodeN:T[..T2][xF]` per event, comma-separated, e.g.
//! `crash@node1:5e6..8e6,drain@node2:1e7` (kinds: `crash` with
//! optional recovery, graceful `drain`, `update` = a rolling-model-
//! update drain with mandatory rejoin, `degrade` slowdown windows,
//! permanent `arrayfail` capacity loss) — and `--fault-seed S` draws a
//! randomized crash/recover plan. The self-healing control plane lives
//! in the fleet loop: when a node dies its queued streams fail over to
//! survivors chosen by router re-resolution (a survivor-only hash ring
//! keyed by the *original* node ids, least-loaded reassignment, or a
//! replica water-fill over the live nodes), each hand-off re-priced
//! with the same PR 6 migration model (PCM reprogramming on the
//! destination's `RES_PROG` chained after its array timelines, plus
//! the per-request DMA hand-off) — that is the **failover pricing
//! model**: failover is a migration the tenant did not ask for.
//! Recovery is a staged rejoin: the node's PCM arrays reprogram
//! *before* it takes traffic (its parked post-recovery stream returns
//! through the same priced `migrate_in`). A crash loses the batches in
//! flight: their ledger entries are revoked exactly (histogram bins
//! are exact, so revocation is too) and the requests counted in the
//! fleet's `lost_in_crash`, extending arrival conservation to
//! `served + dropped + rejected + lost_in_crash == offered arrivals`
//! with every retried (failed-over) request accounted exactly once.
//! With no fault plan the loop takes exactly the healthy code paths —
//! tables, serve JSON, and trace bytes are pinned bit-identical to the
//! pre-fault release by `tests/fault_regression.rs`.

pub mod admission;
pub mod autoscale;
pub mod batcher;
pub mod evq;
pub mod faults;
pub mod fleet;
pub mod metrics;
pub mod tenancy;
pub mod trace;
pub mod traffic;

use std::collections::{BTreeMap, HashMap};
use std::rc::Rc;

use crate::arch::{PowerModel, SystemConfig};
use crate::coordinator::timeline::{
    res_label, IntervalSet, ProfileBuilder, ResMap, ResourceTimeline, N_CORES, RES_ARRAY0,
    RES_CORE0, RES_DMA, RES_DWACC, RES_IMA_MUX, RES_PROG,
};
use crate::coordinator::{BatchConfig, BatchReport, PlanCache, Strategy};
use crate::ima::ImaArrayPool;
use crate::net::bottleneck::bottleneck;
use crate::net::mobilenetv2::mobilenet_v2;
use crate::net::Network;
use crate::util::json::{obj, Json};
use crate::util::table::{f, Table};

pub use admission::AdmissionControl;
pub use autoscale::{AutoscaleConfig, Autoscaler, Pressure, ScaleDecision, ScaleEvent, ScaleKind};
pub use batcher::{BatchWindow, TenantQueue};
pub use evq::{EventQueue, EventQueueKind, EvqCounters};
pub use faults::{FaultEvent, FaultKind, FaultPlan};
pub use fleet::{
    parse_node_arrays, simulate_fleet, simulate_fleet_traced, FailoverRecord, FaultRecord,
    FleetConfig, FleetFaultOutcome, FleetMigration, FleetMigrationConfig, FleetReport, NodeReport,
    ReplicaScale, RouterPolicy,
};
pub use metrics::{
    LatencyBreakdown, LogHistogram, ResourceUtil, ServeCounters, StallShare, TenantStats,
};
pub use tenancy::{place_tenants, Arbiter, Claim, Policy, Tenancy, Tenant};
pub use trace::{ServeTrace, TraceRecorder};
pub use traffic::TrafficModel;

/// Default traffic seed, shared by the library default, the CLI, and the
/// serving report so "default" means one thing everywhere.
pub const DEFAULT_SEED: u64 = 0xC0FF_EE00;

/// Human label of a dispatch discipline — shared by the serve table and
/// the serving-report sweep so the two can never drift: `serialized`
/// (PR 2 single server), `overlapped` (PR 3 envelopes), or `backfilled`
/// (interval gaps).
pub fn dispatch_label(overlap: bool, backfill: bool) -> &'static str {
    if !overlap {
        "serialized"
    } else if backfill {
        "backfilled"
    } else {
        "overlapped"
    }
}

/// One model's serving contract: its network, arrival process, and WRR
/// weight.
#[derive(Clone, Debug)]
pub struct ModelTraffic {
    pub net: Network,
    pub traffic: TrafficModel,
    /// Weighted-round-robin share (≥ 1; ignored by FIFO/SJF).
    pub weight: u64,
}

/// Serving-simulation knobs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Crossbar arrays in the shared pool.
    pub n_arrays: usize,
    pub policy: Policy,
    pub window: BatchWindow,
    /// Request pipelining inside each dispatched batch.
    pub pipeline: bool,
    /// Charge staged-pass boundary DMA (see `scheduler`).
    pub charge_dma: bool,
    /// Per-resource dispatch: overlap batches whose reservation profiles
    /// are disjoint. Off = the PR 2 model (one opaque pool server).
    pub overlap: bool,
    /// Backfill batches into idle gaps of committed reservations (busy
    /// interval sets, plus per-tenant core-affinity rotation). Off = the
    /// conservative PR 3 envelope reservation, bit-identical
    /// (`--no-backfill`). Per timeline state the backfilled start is
    /// never later than the envelope one; the end-to-end makespan
    /// conservation is pinned empirically by the regression/property
    /// suites and the CI smoke on the shipped scenarios.
    pub backfill: bool,
    /// Stream staged PCM reprogramming under the previous pass's compute
    /// tail (see `scheduler::BatchConfig::stream_weights`).
    pub stream_weights: bool,
    /// Fold committed timeline intervals behind the oldest possible
    /// future dispatch into a watermark (`--no-prune` disables). Pruning
    /// is invisible to the dispatch table — only the gap-search work and
    /// live-interval footprint shrink (both counted in
    /// [`ServeCounters`]).
    pub prune: bool,
    /// Next-event queue structure (`--event-queue heap|calendar`).
    /// Both realize the same total order — dispatch tables, serve JSON,
    /// and trace bytes are bit-identical either way.
    pub event_queue: EventQueueKind,
    /// Gap-search fast paths in the timeline (`--no-gap-skip`
    /// disables). Dispatch decisions are identical either way — only
    /// the `probes` counter drops with them on.
    pub gap_skip: bool,
    /// Master seed; per-model arrival seeds derive from it.
    pub seed: u64,
    /// Open-loop arrival horizon in seconds (the sim then drains).
    pub duration_s: f64,
    /// Abandon requests that waited longer than this before dispatch
    /// (cycles; 0 disables deadlines).
    pub deadline_cy: u64,
    /// Refuse arrivals at the front door whenever the predicted
    /// completion latency blows this p95 budget (cycles; 0 disables
    /// admission control entirely).
    pub slo_p95_cy: u64,
    /// Master switch for front-door admission (`--no-admission` keeps
    /// the budget as a config echo but never refuses a request).
    pub admission: bool,
    /// Online pool-resizing controller (`--autoscale`): grow/shrink
    /// tenant slices on sustained pressure, charging migrations.
    pub autoscale: bool,
    /// Hysteresis thresholds and windows of the resizing controller.
    pub autoscale_cfg: AutoscaleConfig,
    /// Arrays held back from the initial carve, claimable only by the
    /// resizing controller (0 = carve the whole pool).
    pub headroom: usize,
    /// Allow 90° tile rotation during placement.
    pub rotate: bool,
    pub strategy: Strategy,
    /// LRU bound for the internal plan cache.
    pub plan_cache_cap: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            n_arrays: 64,
            policy: Policy::Fifo,
            window: BatchWindow::default(),
            pipeline: true,
            charge_dma: true,
            overlap: true,
            backfill: true,
            stream_weights: false,
            prune: true,
            event_queue: EventQueueKind::default(),
            gap_skip: true,
            seed: DEFAULT_SEED,
            duration_s: 0.25,
            deadline_cy: 0,
            slo_p95_cy: 0,
            admission: true,
            autoscale: false,
            autoscale_cfg: AutoscaleConfig::default(),
            headroom: 0,
            rotate: false,
            strategy: Strategy::ImaDw,
            plan_cache_cap: 32,
        }
    }
}

/// Outcome of one serving simulation.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub policy: Policy,
    pub seed: u64,
    pub n_arrays: usize,
    /// Per-resource dispatch was enabled (config echo).
    pub overlap: bool,
    /// Backfilling dispatch was enabled (config echo).
    pub backfill: bool,
    /// Streamed staged reprogramming was enabled (config echo).
    pub stream_weights: bool,
    /// Watermark pruning was enabled (config echo). Never affects the
    /// dispatch table — [`render_table`](Self::render_table) is
    /// bit-identical with it on or off.
    pub prune: bool,
    /// Gap-skip fast paths were enabled (config echo). Like `prune`,
    /// never affects the dispatch table — only `counters.probes`.
    pub gap_skip: bool,
    /// Which next-event structure ran the loop. Deliberately *not* in
    /// [`to_json`](Self::to_json): serve JSON is pinned bit-identical
    /// across `--event-queue heap|calendar`, so a mode echo would be
    /// the one field breaking the equality the CI smoke asserts.
    pub event_queue: EventQueueKind,
    /// Structural work the queue performed (heap: sift-depth proxy;
    /// calendar: bucket/entry scan steps). The only mode-*dependent*
    /// tally, so it stays out of serve JSON too — `imcc bench-timeline`
    /// reports it per mode in the heap-vs-calendar section.
    pub evq_steps: u64,
    /// p95 latency budget handed to admission control (cycles; config
    /// echo, 0 = no budget).
    pub slo_p95_cy: u64,
    /// Front-door admission control was active (budget set and not
    /// switched off).
    pub admission: bool,
    /// The online pool-resizing controller was active (config echo).
    pub autoscale: bool,
    /// Arrival horizon, cycles.
    pub duration_cycles: u64,
    /// Completion of the last batch (≥ duration while draining).
    pub makespan_cycles: u64,
    /// Cycles at least one batch was in flight (the *union* of batch
    /// spans — overlapped batches do not double-count, so this never
    /// exceeds the makespan; without overlap it is the plain sum).
    pub busy_cycles: u64,
    pub cycle_ns: f64,
    /// Deepest pool-wide simultaneous backlog (sum of every tenant's
    /// pending queue) observed at any event-loop step — the quantity
    /// per-tenant peaks cannot reconstruct (aligned bursts add up,
    /// disjoint bursts do not).
    pub peak_backlog: u64,
    pub tenants: Vec<TenantStats>,
    /// Every resize the autoscaler applied, in event order (empty with
    /// the controller off). Deterministic under the seed.
    pub scale_events: Vec<ScaleEvent>,
    /// Busy cycles per pool resource (the core-complex aggregate, each
    /// core, DW accelerator, IMA mux, DMA port, PCM programming port, the
    /// array aggregate, and the busiest single array).
    pub resource_busy: Vec<ResourceUtil>,
    /// Resource-stall attribution: total stalled request-cycles charged
    /// to each blocking resource (ascending id, the `--no-overlap` pool
    /// sentinel last; empty when nothing ever stalled). Sums to the
    /// tenants' `breakdown.resource_stall` totals.
    pub stall_by_resource: Vec<StallShare>,
    /// Deterministic perf counters of the run (event-loop steps,
    /// validations, gap-search probes, live/pruned interval nodes) —
    /// reported in the JSON baseline, never in the dispatch table.
    pub counters: ServeCounters,
}

impl ServeReport {
    /// Fraction of the makespan at least one batch was in flight.
    pub fn utilization(&self) -> f64 {
        if self.makespan_cycles == 0 {
            0.0
        } else {
            self.busy_cycles as f64 / self.makespan_cycles as f64
        }
    }

    /// Utilization of one resource entry: busy cycles over `units`
    /// physical units times the makespan.
    pub fn resource_utilization(&self, r: &ResourceUtil) -> f64 {
        let denom = r.units as f64 * self.makespan_cycles as f64;
        if denom == 0.0 {
            0.0
        } else {
            r.busy_cycles as f64 / denom
        }
    }

    pub fn total_served(&self) -> u64 {
        self.tenants.iter().map(|t| t.served).sum()
    }

    pub fn total_dropped(&self) -> u64 {
        self.tenants.iter().map(|t| t.dropped).sum()
    }

    /// Requests refused at the front door by admission control.
    pub fn total_rejected(&self) -> u64 {
        self.tenants.iter().map(|t| t.rejected).sum()
    }

    /// Aggregate served throughput over the makespan, inferences/s.
    pub fn inferences_per_s(&self) -> f64 {
        let makespan_s = self.makespan_cycles as f64 * self.cycle_ns * 1e-9;
        if makespan_s > 0.0 {
            self.total_served() as f64 / makespan_s
        } else {
            0.0
        }
    }

    fn ms(&self, cy: u64) -> f64 {
        cy as f64 * self.cycle_ns * 1e-6
    }

    /// The per-model latency table the CLI prints; bit-identical across
    /// runs with the same seed (the determinism tests compare this
    /// string). A per-resource utilization line follows the table.
    pub fn render_table(&self) -> String {
        let dispatch = dispatch_label(self.overlap, self.backfill);
        let title = format!(
            "serving — {} policy, {} arrays, seed {:#x}, {} dispatch, pool util {:.0}%",
            self.policy.label(),
            self.n_arrays,
            self.seed,
            dispatch,
            self.utilization() * 100.0
        );
        let mut t = Table::new(
            &title,
            &[
                "model", "arrays", "passes", "occ", "arrivals", "served", "dropped", "rejected",
                "batches", "mean B", "p50 ms", "p95 ms", "p99 ms", "peak q",
            ],
        );
        for s in &self.tenants {
            let (p50, p95, p99) = s.latency.percentiles();
            t.row([
                s.name.to_string(),
                s.arrays.to_string(),
                s.n_passes.to_string(),
                format!("{:.0}%", s.occupancy * 100.0),
                s.arrivals.to_string(),
                s.served.to_string(),
                s.dropped.to_string(),
                s.rejected.to_string(),
                s.batches.to_string(),
                f(s.mean_batch(), 1),
                f(self.ms(p50), 3),
                f(self.ms(p95), 3),
                f(self.ms(p99), 3),
                s.peak_queue.to_string(),
            ]);
        }
        let mut out = t.render();
        let util: Vec<String> = self
            .resource_busy
            .iter()
            .map(|r| format!("{} {:.0}%", r.name, self.resource_utilization(r) * 100.0))
            .collect();
        out.push_str(&format!("per-resource utilization: {}\n", util.join(", ")));
        out.push_str(&format!("peak simultaneous backlog: {} requests\n", self.peak_backlog));
        if self.autoscale {
            out.push_str(&format!("scale events: {}\n", self.scale_events.len()));
            for ev in &self.scale_events {
                out.push_str(&format!(
                    "  {} {} @{}: [{}, {}) -> [{}, {}) arrays, {} prog cy, {} blocked{}\n",
                    ev.kind.label(),
                    self.tenants[ev.tenant].name,
                    ev.t,
                    ev.from_base,
                    ev.from_base + ev.from_arrays,
                    ev.to_base,
                    ev.to_base + ev.to_arrays,
                    ev.program_cycles,
                    ev.blocked_cycles,
                    if ev.streamed { " (streamed)" } else { "" },
                ));
            }
        }
        out
    }

    /// The per-tenant latency-decomposition table (phase percentiles and
    /// each phase's share of total latency cycles), followed by the
    /// resource-stall attribution line. Printed by the CLI below the
    /// serving table; kept out of [`render_table`](Self::render_table) so
    /// the pruned-vs-unpruned and traced-vs-untraced comparisons of that
    /// string stay exactly as before.
    pub fn render_breakdown(&self) -> String {
        let mut t = Table::new(
            "latency decomposition — phases sum to end-to-end latency",
            &["model", "phase", "p50 ms", "p95 ms", "p99 ms", "mean ms", "share"],
        );
        for s in &self.tenants {
            let total = s.latency.sum();
            for (name, h) in s.breakdown.phases() {
                let (p50, p95, p99) = h.percentiles();
                let share = if total == 0 {
                    0.0
                } else {
                    h.sum() as f64 / total as f64
                };
                t.row([
                    s.name.to_string(),
                    name.to_string(),
                    f(self.ms(p50), 3),
                    f(self.ms(p95), 3),
                    f(self.ms(p99), 3),
                    f(h.mean() * self.cycle_ns * 1e-6, 3),
                    format!("{:.1}%", share * 100.0),
                ]);
            }
        }
        let mut out = t.render();
        if !self.stall_by_resource.is_empty() {
            let shares: Vec<String> = self
                .stall_by_resource
                .iter()
                .map(|r| format!("{} {:.3} ms", r.name, self.ms(r.stalled_cycles)))
                .collect();
            out.push_str(&format!("resource-stall attribution: {}\n", shares.join(", ")));
        }
        out
    }

    /// Machine-readable summary (the `BENCH_serve.json` payload): config
    /// echo, aggregate throughput, per-tenant percentiles, per-resource
    /// utilization.
    pub fn to_json(&self) -> Json {
        let tenants: Vec<Json> = self
            .tenants
            .iter()
            .map(|s| {
                let (p50, p95, p99) = s.latency.percentiles();
                // per-phase decomposition: percentiles plus the exact
                // cycle totals, which sum to total_cycles
                let mut phases: Vec<(&'static str, Json)> = s
                    .breakdown
                    .phases()
                    .iter()
                    .map(|(n, h)| {
                        let (q50, q95, q99) = h.percentiles();
                        (
                            *n,
                            obj([
                                ("p50_ms", self.ms(q50).into()),
                                ("p95_ms", self.ms(q95).into()),
                                ("p99_ms", self.ms(q99).into()),
                                ("mean_ms", (h.mean() * self.cycle_ns * 1e-6).into()),
                                ("sum_cycles", (h.sum() as f64).into()),
                            ]),
                        )
                    })
                    .collect();
                phases.push(("total_cycles", (s.latency.sum() as f64).into()));
                // the [lo, hi) bin bounds each reported percentile
                // resolved to, so the floor convention is auditable
                let bin = |q: f64| {
                    let (lo, hi) = s.latency.quantile_bounds(q);
                    Json::Arr(vec![(lo as f64).into(), (hi as f64).into()])
                };
                obj([
                    ("latency_breakdown", obj(phases)),
                    (
                        "latency_bins",
                        obj([("p50_cy", bin(0.50)), ("p95_cy", bin(0.95)), ("p99_cy", bin(0.99))]),
                    ),
                    ("model", s.name.as_ref().into()),
                    ("arrays", s.arrays.into()),
                    ("passes", s.n_passes.into()),
                    ("arrivals", (s.arrivals as f64).into()),
                    ("served", (s.served as f64).into()),
                    ("dropped", (s.dropped as f64).into()),
                    ("rejected", (s.rejected as f64).into()),
                    ("slo_p95", (s.slo_p95_cy as f64).into()),
                    ("batches", (s.batches as f64).into()),
                    ("mean_batch", s.mean_batch().into()),
                    ("p50_ms", self.ms(p50).into()),
                    ("p95_ms", self.ms(p95).into()),
                    ("p99_ms", self.ms(p99).into()),
                    ("peak_queue", s.peak_queue.into()),
                    ("peak_queue_at_dispatch", s.peak_queue_at_dispatch.into()),
                ])
            })
            .collect();
        let resources: Vec<Json> = self
            .resource_busy
            .iter()
            .map(|r| {
                obj([
                    ("name", r.name.as_ref().into()),
                    ("busy_cycles", (r.busy_cycles as f64).into()),
                    ("units", (r.units as f64).into()),
                    ("utilization", self.resource_utilization(r).into()),
                ])
            })
            .collect();
        let events: Vec<Json> = self
            .scale_events
            .iter()
            .map(|ev| {
                obj([
                    ("tenant", self.tenants[ev.tenant].name.as_ref().into()),
                    ("t_cycles", (ev.t as f64).into()),
                    ("kind", ev.kind.label().into()),
                    ("from_base", ev.from_base.into()),
                    ("from_arrays", ev.from_arrays.into()),
                    ("to_base", ev.to_base.into()),
                    ("to_arrays", ev.to_arrays.into()),
                    ("program_cycles", (ev.program_cycles as f64).into()),
                    ("blocked_cycles", (ev.blocked_cycles as f64).into()),
                    ("streamed", ev.streamed.into()),
                ])
            })
            .collect();
        let stalls: Vec<Json> = self
            .stall_by_resource
            .iter()
            .map(|r| {
                obj([
                    ("name", r.name.as_ref().into()),
                    ("stalled_cycles", (r.stalled_cycles as f64).into()),
                ])
            })
            .collect();
        let c = &self.counters;
        let counters = obj([
            ("steps", (c.steps as f64).into()),
            ("validations", (c.validations as f64).into()),
            ("probes", (c.probes as f64).into()),
            ("live_intervals", (c.live_intervals as f64).into()),
            ("peak_live_intervals", (c.peak_live_intervals as f64).into()),
            ("pruned_intervals", (c.pruned_intervals as f64).into()),
            ("watermark", (c.watermark as f64).into()),
            ("evq_pushes", (c.evq_pushes as f64).into()),
            ("evq_pops", (c.evq_pops as f64).into()),
            ("evq_stale", (c.evq_stale as f64).into()),
        ]);
        obj([
            ("policy", self.policy.label().into()),
            ("seed", format!("{:#x}", self.seed).into()),
            ("arrays", self.n_arrays.into()),
            ("overlap", self.overlap.into()),
            ("backfill", self.backfill.into()),
            ("stream_weights", self.stream_weights.into()),
            ("prune", self.prune.into()),
            ("gap_skip", self.gap_skip.into()),
            ("slo_p95_cy", (self.slo_p95_cy as f64).into()),
            ("admission", self.admission.into()),
            ("autoscale", self.autoscale.into()),
            ("duration_cycles", (self.duration_cycles as f64).into()),
            ("makespan_cycles", (self.makespan_cycles as f64).into()),
            ("busy_cycles", (self.busy_cycles as f64).into()),
            ("peak_backlog", (self.peak_backlog as f64).into()),
            ("pool_utilization", self.utilization().into()),
            ("inf_per_s", self.inferences_per_s().into()),
            ("served", (self.total_served() as f64).into()),
            ("dropped", (self.total_dropped() as f64).into()),
            ("rejected", (self.total_rejected() as f64).into()),
            ("scale_events", Json::Arr(events)),
            ("counters", counters),
            ("stall_by_resource", Json::Arr(stalls)),
            ("tenants", Json::Arr(tenants)),
            ("resources", Json::Arr(resources)),
        ])
    }
}

/// Networks the CLI can serve by name.
pub fn model_by_name(name: &str) -> Result<Network, String> {
    match name.trim().to_ascii_lowercase().as_str() {
        "mobilenetv2" | "mnv2" | "mobilenet" => Ok(mobilenet_v2(224)),
        "bottleneck" | "bn" => Ok(bottleneck()),
        other => Err(format!("unknown model `{other}` (mobilenetv2|bottleneck)")),
    }
}

/// The canonical two-model mix — MobileNetV2 plus the Bottleneck case
/// study under equal-rate Poisson traffic, equal WRR weight. Shared by
/// the serving report, the benches, and the regression tests so they all
/// measure the same tenancy.
pub fn mnv2_bottleneck_pair(rate_per_s: f64) -> Vec<ModelTraffic> {
    vec![
        ModelTraffic {
            net: mobilenet_v2(224),
            traffic: TrafficModel::Poisson { rate_per_s },
            weight: 1,
        },
        ModelTraffic {
            net: bottleneck(),
            traffic: TrafficModel::Poisson { rate_per_s },
            weight: 1,
        },
    ]
}

/// `n` bottleneck tenants with distinct names under equal Poisson load —
/// the multi-tenant fleet the serve bench and `imcc bench-timeline` both
/// measure, so their numbers describe the same tenancy.
pub fn bottleneck_fleet(n: usize, rate_per_s: f64) -> Vec<ModelTraffic> {
    (0..n)
        .map(|i| {
            let mut net = bottleneck();
            net.name = format!("bn-{i}");
            ModelTraffic {
                net,
                traffic: TrafficModel::Poisson { rate_per_s },
                weight: 1,
            }
        })
        .collect()
}

/// Shared simulation context: the placed tenants, the plan cache the
/// batch reports (cycles, energy, reservation profile) are interned in —
/// repeated (tenant, batch-size) points share one allocation, within this
/// run and across sweep points reusing the cache — and a thin per-run
/// memo in front of it so the event loop's repeated lookups are one
/// small-key hash, not a full cache-key rebuild per validation.
struct SimCtx<'a> {
    models: &'a [ModelTraffic],
    /// Owned, not borrowed: the autoscaler rewrites a tenant's slice and
    /// plan mid-run.
    tenancy: Tenancy,
    cfg: &'a SystemConfig,
    pm: &'a PowerModel,
    scfg: &'a ServeConfig,
    cache: &'a mut PlanCache,
    memo: HashMap<(usize, usize), Rc<BatchReport>>,
}

impl SimCtx<'_> {
    fn batch_cost(&mut self, tenant: usize, batch: usize) -> Rc<BatchReport> {
        if let Some(rep) = self.memo.get(&(tenant, batch)) {
            return Rc::clone(rep);
        }
        let rep = self.cache.get_or_batch(
            &self.models[tenant].net,
            self.scfg.strategy,
            self.cfg,
            self.pm,
            &self.tenancy.tenants[tenant].plan,
            BatchConfig {
                batch,
                pipeline: self.scfg.pipeline,
                charge_dma: self.scfg.charge_dma,
                stream_weights: self.scfg.stream_weights,
            },
        );
        self.memo.insert((tenant, batch), Rc::clone(&rep));
        rep
    }
}

/// Validate one tenant's next dispatch: the earliest instant its batch can
/// start given its queue and (in overlap mode) the pool timeline, plus the
/// batch it would form there and the resource that pushed the start past
/// its floor (`None` = fit at the floor; [`trace::RES_POOL`] = the
/// serialized single-server clock). Expired requests are dropped lazily at
/// the would-be dispatch instant (charged to `st`); with admission control
/// on, unscreened arrivals face the front-door gate first and refusals are
/// charged to `st.rejected`. Refusals and drops are also recorded on
/// `rec` (a no-op when tracing is off). `not_before` floors this tenant's
/// dispatch (a blocking migration's tail); 0 = no floor. `None` once the
/// queue is drained.
#[allow(clippy::too_many_arguments)]
fn validate_candidate(
    q: &mut TenantQueue,
    st: &mut TenantStats,
    tenant: usize,
    ctx: &mut SimCtx<'_>,
    timeline: &ResourceTimeline,
    pool_free: u64,
    rmap: ResMap,
    not_before: u64,
    mut admission: Option<&mut AdmissionControl>,
    rec: &mut TraceRecorder,
) -> Option<(u64, usize, u64, Option<usize>)> {
    let scfg = ctx.scfg;
    loop {
        let r = q.ready_at(&scfg.window)?;
        // front-door screening at the admission instant: every arrival
        // landed by `r` faces the predictor before it may join a window
        if let Some(ac) = admission.as_deref_mut() {
            let rej = q.screen_arrivals(r, |a, depth| {
                let ok = ac.admit(tenant, depth);
                if !ok {
                    rec.reject(tenant, r, a, depth, ac.predicted(tenant, depth));
                }
                ok
            });
            if rej > 0 {
                st.rejected += rej;
                continue; // window state changed — recompute
            }
        }
        // a migration floor delays the dispatch, never the window math
        let floor = r.max(not_before);
        // fixed point: waiting for resources may let more arrivals join
        // the window, which may change the profile, which may move the
        // instant — batch size normally only grows, so this converges in
        // a round or two
        let mut b = q.depth_at(floor).min(scfg.window.max_batch).max(1);
        let mut td;
        let mut blocker;
        let mut rounds = 0usize;
        loop {
            let cost = ctx.batch_cost(tenant, b);
            (td, blocker) = if scfg.overlap {
                timeline.earliest_start_blocked(&cost.profile, rmap, floor)
            } else {
                let start = floor.max(pool_free);
                (start, (start > floor).then_some(trace::RES_POOL))
            };
            let b2 = q.depth_at(td).min(scfg.window.max_batch).max(1);
            if b2 == b {
                break;
            }
            rounds += 1;
            if rounds > scfg.window.max_batch {
                // cycle guard: a staged profile's intervals move with the
                // batch size, so under backfilling a bigger batch can fit
                // an *earlier* gap and the fixed point may oscillate.
                // Shrink strictly until the size is admissible at its own
                // dispatch instant — the dispatcher admits exactly the
                // validated size, so the committed profile is always the
                // one checked here.
                if b2 > b {
                    break; // enough arrivals by td to admit exactly b
                }
            }
            b = b2;
        }
        // late arrivals that landed while the batch waited for resources
        // face the same gate before they may join at the dispatch instant
        if let Some(ac) = admission.as_deref_mut() {
            let rej = q.screen_arrivals(td, |a, depth| {
                let ok = ac.admit(tenant, depth);
                if !ok {
                    rec.reject(tenant, td, a, depth, ac.predicted(tenant, depth));
                }
                ok
            });
            if rej > 0 {
                st.rejected += rej;
                continue;
            }
        }
        // backlog snapshot at the candidate instant, taken before lazy
        // drops so expired-but-still-queued requests count toward the
        // peak a client would have observed; the every-event sample in
        // the main loop augments this, never undercuts it
        let depth = q.depth_at(td);
        st.peak_queue = st.peak_queue.max(depth);
        st.peak_queue_at_dispatch = st.peak_queue_at_dispatch.max(depth);
        // lazy abandonment: clients that waited past their deadline are
        // gone by the time this tenant would dispatch
        if scfg.deadline_cy > 0 {
            let d = q.drop_expired(td, scfg.deadline_cy);
            if d > 0 {
                st.dropped += d;
                rec.drops(tenant, td, d);
                continue; // window state changed — recompute
            }
        }
        let cycles = ctx.batch_cost(tenant, b).cycles;
        return Some((td, b, cycles, blocker));
    }
}

/// Actuate one autoscale decision at instant `t`: re-plan the tenant's
/// network into the new slice through the shared plan cache, charge the
/// PCM reprogramming of the moved arrays on the pool timeline (chained on
/// the programming port, landing on the destination array timelines),
/// floor the tenant's next dispatch when the migration blocks, and trace
/// the event. Every abort path restores the free map untouched — the
/// controller simply retries while the pressure persists. Grows free the
/// old slice before searching, so in-place growth coalesces with
/// neighboring free arrays and a co-tenant's shrink return is claimable;
/// a plan that would not actually spread into more arrays than it already
/// holds is kept where it is (growing a resident tenant buys nothing).
#[allow(clippy::too_many_arguments)]
fn apply_scale(
    decision: ScaleDecision,
    tenant: usize,
    t: u64,
    ctx: &mut SimCtx<'_>,
    auto: &mut Autoscaler,
    timeline: &mut ResourceTimeline,
    rmaps: &mut [ResMap],
    stats: &mut [TenantStats],
    not_before: &mut [u64],
    admission: Option<&mut AdmissionControl>,
    rec: &mut TraceRecorder,
) {
    let scfg = ctx.scfg;
    let (old_base, old_arrays) = {
        let ten = &ctx.tenancy.tenants[tenant];
        (ten.array_base, ten.arrays)
    };
    auto.release(old_base, old_arrays);
    let (new_base, trial, kind) = match decision {
        ScaleDecision::Grow { target } => {
            let Some((base, len)) = auto.find_run(old_arrays + 1, target) else {
                auto.reserve(old_base, old_arrays);
                return; // no free run wide enough — retry later
            };
            (base, len, ScaleKind::Grow)
        }
        ScaleDecision::Shrink { target } => (old_base, target, ScaleKind::Shrink),
    };
    let s = ctx.cfg.xbar_rows;
    let plan = match ctx
        .cache
        .get_or_place(&ctx.models[tenant].net, s, trial, scfg.rotate)
    {
        Ok(p) => p,
        Err(_) => {
            // a single layer outgrows the trial slice — keep the old one
            auto.reserve(old_base, old_arrays);
            return;
        }
    };
    let used = plan.passes.iter().map(|p| p.arrays_used).max().unwrap_or(0);
    if used == 0 || (kind == ScaleKind::Grow && used <= old_arrays) {
        auto.reserve(old_base, old_arrays);
        return;
    }
    auto.reserve(new_base, used);

    // migration price: PCM reprogramming of every array the new plan's
    // first pass touches, serialized on the programming port and charged
    // to the destination array timelines after whatever already holds them
    let pool = ImaArrayPool::new(ctx.cfg, ctx.pm);
    let by_array = pool.program_cycles_by_array(&plan.passes[0]);
    let program_cycles: u64 = by_array.values().sum();
    let mut pb = ProfileBuilder::new();
    let mut prog_free = timeline.free_at(RES_PROG).saturating_sub(t);
    let mut end_max = 0u64;
    for (&a, &cy) in &by_array {
        let res = RES_ARRAY0 + new_base + a;
        let start = prog_free.max(timeline.free_at(res).saturating_sub(t));
        let fin = start + cy;
        pb.occupy(RES_PROG, start, fin);
        pb.occupy(res, start, fin);
        prog_free = fin;
        end_max = end_max.max(fin);
    }
    let prog_profile = pb.build(end_max);
    let identity = ResMap {
        array_base: 0,
        core_base: 0,
    };
    timeline.commit(t, &prog_profile, identity);
    // migration occupancy rides the trace under batch id 0, so traced
    // occupancy still merges to the committed timeline with autoscale on
    rec.occupancy(tenant, 0, t, &prog_profile, identity, scfg.backfill);
    // a blocking migration floors the tenant's next dispatch past the
    // reprogramming tail; with --stream-weights it rides the overlap
    // path and only the destination array timelines carry the cost
    let blocked_cycles = if scfg.stream_weights { 0 } else { end_max };
    not_before[tenant] = not_before[tenant].max(t + blocked_cycles);

    // swap the slice in: tenant record, stats echo, resource map, the
    // per-run cost memo, and the admission predictor's service ceiling
    let slice_devices = used * s * s;
    let occupancy = if slice_devices == 0 {
        0.0
    } else {
        plan.passes
            .iter()
            .map(|p| p.devices_used() as f64 / slice_devices as f64)
            .fold(0.0, f64::max)
    };
    let n_passes = plan.passes.len();
    {
        let ten = &mut ctx.tenancy.tenants[tenant];
        ten.array_base = new_base;
        ten.arrays = used;
        ten.plan = Rc::clone(&plan);
        ten.occupancy = occupancy;
    }
    stats[tenant].arrays = used;
    stats[tenant].n_passes = n_passes;
    stats[tenant].occupancy = occupancy;
    stats[tenant].energy_j += pool.program_energy_j(&plan.passes[0]);
    rmaps[tenant].array_base = new_base;
    ctx.memo.retain(|&(tn, _), _| tn != tenant);
    if let Some(ac) = admission {
        let svc = (1..=scfg.window.max_batch)
            .map(|b| ctx.batch_cost(tenant, b).cycles)
            .max()
            .unwrap_or(0);
        ac.set_svc_max(tenant, svc);
    }
    let ev = ScaleEvent {
        tenant,
        t,
        kind,
        from_base: old_base,
        from_arrays: old_arrays,
        to_base: new_base,
        to_arrays: used,
        program_cycles,
        blocked_cycles,
        streamed: scfg.stream_weights,
    };
    rec.scale(ev);
    auto.committed(ev);
}

/// Run the serving simulation to completion (arrival horizon + drain)
/// with a private plan cache.
pub fn simulate(
    models: &[ModelTraffic],
    scfg: &ServeConfig,
    pm: &PowerModel,
) -> Result<ServeReport, String> {
    let mut cache = PlanCache::with_capacity(scfg.plan_cache_cap);
    simulate_with_cache(models, scfg, pm, &mut cache)
}

/// [`simulate`] against a caller-owned plan cache: sweeps re-running the
/// same (network, pool) points skip re-placement entirely.
pub fn simulate_with_cache(
    models: &[ModelTraffic],
    scfg: &ServeConfig,
    pm: &PowerModel,
    cache: &mut PlanCache,
) -> Result<ServeReport, String> {
    simulate_traced(models, scfg, pm, cache, &mut TraceRecorder::Off)
}

/// [`simulate_with_cache`] with an execution-trace recorder. Pass
/// [`TraceRecorder::Off`] (what every other entry point does) for a
/// recorder that is a no-op on the hot path; a live recorder observes the
/// run without perturbing it — the report, dispatch table, and counters
/// are bit-identical either way (`tests/trace_regression.rs`). Consume
/// the recorder with [`TraceRecorder::finish`] afterwards.
pub fn simulate_traced(
    models: &[ModelTraffic],
    scfg: &ServeConfig,
    pm: &PowerModel,
    cache: &mut PlanCache,
    rec: &mut TraceRecorder,
) -> Result<ServeReport, String> {
    let cfg = SystemConfig::scaled_up(scfg.n_arrays);
    let mut node = NodeSim::new(models, scfg, pm, &cfg, cache)?;
    while node.step(rec).is_some() {}
    Ok(node.into_report(rec))
}

/// One cluster's complete in-flight simulation state: the setup, the
/// event-loop body, and the report tail of [`simulate_traced`], factored
/// apart so the [`fleet`] front-end can hold N of them and interleave
/// their steps under one global clock. A single-cluster run is exactly
/// [`new`](Self::new) + [`step`](Self::step) to exhaustion +
/// [`into_report`](Self::into_report) — the factoring is pure code
/// motion, and `--nodes 1` stays bit-identical to the pre-fleet output
/// on dispatch tables, serve JSON, and trace bytes (pinned by
/// `tests/fleet_regression.rs`).
pub(crate) struct NodeSim<'a> {
    ctx: SimCtx<'a>,
    queues: Vec<TenantQueue>,
    stats: Vec<TenantStats>,
    arbiter: Arbiter,
    rmaps: Vec<ResMap>,
    auto: Option<Autoscaler>,
    not_before: Vec<u64>,
    prev_dispatch: Vec<u64>,
    stall_by_res: BTreeMap<usize, u64>,
    admission: Option<AdmissionControl>,
    admission_on: bool,
    timeline: ResourceTimeline,
    pool_free: u64,
    inflight: IntervalSet,
    makespan: u64,
    peak_backlog: u64,
    evq: EventQueue,
    steps: u64,
    validations: u64,
    claims: Vec<Claim>,
    claim_batches: Vec<usize>,
    claim_blockers: Vec<Option<usize>>,
    duration_cy: u64,
    cycle_ns: f64,
    /// False while the node is crashed or drained: the fleet loop sees
    /// no events from a dead node. Always true outside fault mode.
    alive: bool,
    /// Service-stretch spans `(from, until, percent > 100)` from
    /// `degrade`/`arrayfail` fault events; empty outside fault mode, and
    /// the empty check is the only cost the healthy path pays.
    degrade: Vec<(u64, u64, u64)>,
    /// Record dispatched-but-unfinished batches so a crash can revoke
    /// them exactly. Only armed (by the fleet) for nodes a fault plan
    /// can crash — off, `step` allocates nothing for it.
    track_inflight: bool,
    open_batches: Vec<OpenBatch>,
}

/// One dispatched batch still in flight — everything `crash` needs to
/// revoke its ledger entries bit-exactly (see [`NodeSim::crash`]).
struct OpenBatch {
    tenant: usize,
    dispatch: u64,
    end: u64,
    window_close: u64,
    not_before: u64,
    prev_dispatch: u64,
    blocker: Option<usize>,
    svc_cycles: u64,
    arrivals: Vec<u64>,
}

impl<'a> NodeSim<'a> {
    /// Place the tenants, seed the arrival streams, and arm the event
    /// queue — everything up to (but not including) the first event-loop
    /// step. `cfg` must be the system config for `scfg.n_arrays` arrays
    /// (the fleet passes per-node heterogeneous configs).
    pub(crate) fn new(
        models: &'a [ModelTraffic],
        scfg: &'a ServeConfig,
        pm: &'a PowerModel,
        cfg: &'a SystemConfig,
        cache: &'a mut PlanCache,
    ) -> Result<NodeSim<'a>, String> {
        if models.is_empty() {
            return Err("no models to serve".into());
        }
        if scfg.window.max_batch == 0 {
            return Err("admission window must admit ≥ 1 request (max_batch ≥ 1)".into());
        }
        let cycle_ns = cfg.freq.cycle_ns();
        let duration_cy = (scfg.duration_s * 1e9 / cycle_ns) as u64;

        if scfg.headroom >= scfg.n_arrays {
            return Err(format!(
                "headroom {} leaves no arrays to carve (pool has {})",
                scfg.headroom, scfg.n_arrays
            ));
        }
        let admission_on = scfg.slo_p95_cy > 0 && scfg.admission;

        // borrow the networks — placement only reads them, no clones; held-
        // back headroom arrays stay free for the resizing controller
        let nets: Vec<&Network> = models.iter().map(|m| &m.net).collect();
        let tenancy = place_tenants(
            &nets,
            cfg.xbar_rows,
            scfg.n_arrays - scfg.headroom,
            scfg.rotate,
            cache,
        )?;

        // seeded, per-model arrival streams
        let mut queues: Vec<TenantQueue> = Vec::with_capacity(models.len());
        let mut stats: Vec<TenantStats> = Vec::with_capacity(models.len());
        for (i, (m, ten)) in models.iter().zip(tenancy.tenants.iter()).enumerate() {
            let seed_i = scfg
                .seed
                .wrapping_add((i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let arr = traffic::arrivals(&m.traffic, seed_i, duration_cy, cycle_ns);
            let mut st = TenantStats::new(&ten.name, ten.arrays, ten.n_passes(), ten.occupancy);
            st.arrivals = arr.len() as u64;
            if admission_on {
                st.slo_p95_cy = scfg.slo_p95_cy;
            }
            queues.push(TenantQueue::new(arr));
            stats.push(st);
        }
        let weights: Vec<u64> = models.iter().map(|m| m.weight).collect();
        let arbiter = Arbiter::new(scfg.policy, &weights);

        // core-affinity rotation is a backfill refinement: the envelope
        // arbiter keeps affinity 0 so `--no-backfill` reproduces the PR 3
        // fused-complex dispatch bit-identically; the autoscaler rewrites a
        // tenant's array base when it relocates a slice
        let rmaps: Vec<ResMap> = tenancy
            .tenants
            .iter()
            .map(|ten| ResMap {
                array_base: ten.array_base,
                core_base: if scfg.backfill && scfg.overlap {
                    ten.core_base
                } else {
                    0
                },
            })
            .collect();
        // the resizing controller and the per-tenant migration floors — both
        // inert (and the floors all 0) with autoscale off
        let auto: Option<Autoscaler> = if scfg.autoscale {
            let slices: Vec<(usize, usize)> = tenancy
                .tenants
                .iter()
                .map(|ten| (ten.array_base, ten.arrays))
                .collect();
            Some(Autoscaler::new(scfg.autoscale_cfg, scfg.n_arrays, &slices))
        } else {
            None
        };
        let not_before: Vec<u64> = vec![0; models.len()];
        // per-tenant previous dispatch instant and the pool-wide stall
        // attribution — the always-on halves of the decomposition state
        let prev_dispatch: Vec<u64> = vec![0; models.len()];
        let stall_by_res: BTreeMap<usize, u64> = BTreeMap::new();

        let mut ctx = SimCtx {
            models,
            tenancy,
            cfg,
            pm,
            scfg,
            cache,
            memo: HashMap::new(),
        };
        // the admission gate prices every tenant's service ceiling up front
        // (warming the cost memo changes nothing the dispatcher observes)
        let admission: Option<AdmissionControl> = if admission_on {
            let svc_max: Vec<u64> = (0..models.len())
                .map(|ti| {
                    (1..=scfg.window.max_batch)
                        .map(|b| ctx.batch_cost(ti, b).cycles)
                        .max()
                        .unwrap_or(0)
                })
                .collect();
            Some(AdmissionControl::new(scfg.slo_p95_cy, &scfg.window, svc_max))
        } else {
            None
        };
        let mut timeline =
            ResourceTimeline::with_resources(scfg.backfill, RES_ARRAY0 + scfg.n_arrays);
        timeline.set_gap_skip(scfg.gap_skip);

        // next-event queue keyed by (dispatch instant, tenant id); stored
        // instants are lower bounds (queues only fill, resources only get
        // busier), revalidated lazily on pop — ties break deterministically
        // toward the lower tenant id via the arbiter in `step`. Calendar
        // and heap realize the same order; see `evq`.
        let mut evq = EventQueue::new(scfg.event_queue);
        for (i, q) in queues.iter().enumerate() {
            if let Some(r) = q.ready_at(&scfg.window) {
                evq.push(r, i);
            }
        }

        Ok(NodeSim {
            ctx,
            queues,
            stats,
            arbiter,
            rmaps,
            auto,
            not_before,
            prev_dispatch,
            stall_by_res,
            admission,
            admission_on,
            timeline,
            pool_free: 0, // serialized-mode single-server clock
            // union of batch spans — an interval set, because a backfilled
            // batch validated later may legitimately start in an idle gap
            // *before* an earlier-dispatched batch (that is the point of
            // backfilling; every start still respects its requests'
            // arrivals and the resource timeline)
            inflight: IntervalSet::new(),
            makespan: 0,
            peak_backlog: 0,
            evq,
            // event-loop work counters (deterministic under a fixed seed)
            steps: 0,
            validations: 0,
            // claim scratch, reused across events — the loop allocates
            // nothing once the memoized batch costs are warm
            claims: Vec::new(),
            claim_batches: Vec::new(),
            claim_blockers: Vec::new(),
            duration_cy,
            cycle_ns,
            alive: true,
            degrade: Vec::new(),
            track_inflight: false,
            open_batches: Vec::new(),
        })
    }

    /// Arm the fault machinery for this node: in-flight tracking when a
    /// crash can strike it, and the degrade/arrayfail service-stretch
    /// spans. The fleet calls this once, before the first step; a node
    /// left unarmed runs the exact healthy code paths.
    pub(crate) fn set_fault_mode(&mut self, track_inflight: bool, degrade: Vec<(u64, u64, u64)>) {
        self.track_inflight = track_inflight;
        self.degrade = degrade;
    }

    /// Service cycles after the degrade spans covering dispatch instant
    /// `t` stretch them (identity when no span covers `t`).
    fn stretched(&self, t: u64, cycles: u64) -> u64 {
        let mut cy = cycles;
        for &(from, until, percent) in &self.degrade {
            if t >= from && t < until {
                cy = cy.saturating_mul(percent) / 100;
            }
        }
        cy
    }

    /// Hard crash at instant `t`: every in-flight batch is lost — its
    /// served/arrival/latency/breakdown/stall ledger entries are revoked
    /// exactly (the histograms' bins are exact, so removal is too; the
    /// busy-interval union and committed timeline keep the spans, since
    /// the node genuinely burned them before dying) — and every queued
    /// stream is taken for failover. Returns `(lost, pending)` where
    /// `pending` is `(local tenant, taken stream)` for non-empty queues;
    /// the lost requests leave this node's arrival ledger and land in
    /// the fleet's `lost_in_crash`.
    pub(crate) fn crash(&mut self, t: u64) -> (u64, Vec<(usize, Vec<u64>)>) {
        let mut lost = 0u64;
        let open = std::mem::take(&mut self.open_batches);
        for ob in open {
            if ob.end <= t {
                continue; // completed before the crash
            }
            let st = &mut self.stats[ob.tenant];
            let n = ob.arrivals.len() as u64;
            st.served -= n;
            st.arrivals -= n;
            st.batches -= 1;
            st.busy_cycles -= ob.svc_cycles;
            for &a in &ob.arrivals {
                st.latency.remove(ob.end - a);
                let ph = trace::decompose(
                    a,
                    ob.prev_dispatch,
                    ob.window_close,
                    ob.not_before,
                    ob.dispatch,
                    ob.end,
                );
                st.breakdown.remove(&ph);
                if ph.resource_stall > 0 {
                    let key = ob.blocker.unwrap_or(trace::RES_POOL);
                    let e = self
                        .stall_by_res
                        .get_mut(&key)
                        .expect("revoking a stall never recorded");
                    *e -= ph.resource_stall;
                }
            }
            lost += n;
        }
        self.stall_by_res.retain(|_, v| *v > 0);
        let pending = self.take_all_pending();
        self.alive = false;
        (lost, pending)
    }

    /// Graceful drain at a fault instant: in-flight batches complete
    /// (nothing is revoked or lost), queued streams are taken for
    /// failover, and the node stops producing events until revived.
    pub(crate) fn drain_now(&mut self) -> Vec<(usize, Vec<u64>)> {
        self.open_batches.clear();
        let pending = self.take_all_pending();
        self.alive = false;
        pending
    }

    fn take_all_pending(&mut self) -> Vec<(usize, Vec<u64>)> {
        let mut pending = Vec::new();
        for ix in 0..self.queues.len() {
            let moved = self.migrate_out(ix);
            if !moved.is_empty() {
                pending.push((ix, moved));
            }
        }
        pending
    }

    /// Staged-rejoin step 1: the node is live again and produces events
    /// (step 2 is the fleet pushing the parked streams back through the
    /// priced `migrate_in`, which reprograms before traffic flows).
    pub(crate) fn revive(&mut self, t: u64) {
        self.alive = true;
        for i in 0..self.queues.len() {
            if let Some(r) = self.queues[i].ready_at(&self.ctx.scfg.window) {
                self.evq.push(r.max(t), i);
            }
        }
    }

    /// Reprogram tenant `ix`'s resident arrays in place (an `arrayfail`
    /// remap, or a rejoin with nothing parked): the full PCM price with
    /// no hand-off and no queue splice. Returns
    /// `(program_cycles, blocked_cycles)`.
    pub(crate) fn reprogram(
        &mut self,
        ix: usize,
        t: u64,
        rec: &mut TraceRecorder,
    ) -> (u64, u64) {
        let (program_cycles, total) = self.charge_program(ix, t, 0, rec);
        let blocked_cycles = if self.ctx.scfg.stream_weights { 0 } else { total };
        self.not_before[ix] = self.not_before[ix].max(t + blocked_cycles);
        (program_cycles, blocked_cycles)
    }

    /// This tenant's pending depth at `t` — the replica autoscaler's
    /// per-node pressure signal for the heavy tenant.
    pub(crate) fn tenant_backlog_at(&self, ix: usize, t: u64) -> usize {
        self.queues[ix].depth_at(t)
    }

    /// The earliest stored event instant, or `None` once the node has
    /// drained. Stored instants are lower bounds, so this bounds the
    /// node's next dispatch from below — the fleet loop always steps
    /// whichever node holds the globally smallest one (ties toward the
    /// lower node id). Peeking only perturbs the calendar's
    /// mode-dependent structural `steps` tally, which deliberately stays
    /// out of serve JSON.
    pub(crate) fn next_event(&mut self) -> Option<u64> {
        if !self.alive {
            return None; // crashed or drained: no events until revived
        }
        self.evq.peek().map(|(t, _)| t)
    }

    /// Pool-wide pending backlog at instant `t` (arrived, not yet
    /// served or dropped) — the fleet's online load signal for
    /// least-loaded migration decisions.
    pub(crate) fn backlog_at(&self, t: u64) -> usize {
        self.queues.iter().map(|q| q.depth_at(t)).sum()
    }

    /// Hand tenant `ix`'s entire pending arrival stream to the fleet for
    /// re-routing; the offered-load ledger follows the requests, so
    /// arrival conservation holds per node, not just fleet-wide.
    pub(crate) fn migrate_out(&mut self, ix: usize) -> Vec<u64> {
        let moved = self.queues[ix].take_pending();
        self.stats[ix].arrivals -= moved.len() as u64;
        moved
    }

    /// Splice a migrated arrival stream into tenant `ix` at instant `t`,
    /// charging the same migration price [`apply_scale`] charges an
    /// in-pool slice move: PCM reprogramming of every array the tenant's
    /// resident plan (first pass) touches, serialized on this node's
    /// programming port and chained after whatever already holds the
    /// destination arrays — plus the trace hand-off, charged on the DMA
    /// port after the reprogramming tail. With `--stream-weights` the
    /// price rides the overlap path and the tenant's dispatch floor
    /// stays at `t`; otherwise the floor moves past the full tail.
    /// Returns `(program_cycles, handoff_cycles, blocked_cycles)`.
    pub(crate) fn migrate_in(
        &mut self,
        ix: usize,
        mut arrivals: Vec<u64>,
        t: u64,
        handoff_cy_per_req: u64,
        rec: &mut TraceRecorder,
    ) -> (u64, u64, u64) {
        let scfg = self.ctx.scfg;
        let handoff_cycles = arrivals.len() as u64 * handoff_cy_per_req;
        let (program_cycles, total) = self.charge_program(ix, t, handoff_cycles, rec);
        let blocked_cycles = if scfg.stream_weights { 0 } else { total };
        self.not_before[ix] = self.not_before[ix].max(t + blocked_cycles);
        self.stats[ix].arrivals += arrivals.len() as u64;
        // splice: whatever this copy still had pending (normally nothing —
        // migration targets hold standby copies) merges with the handed-off
        // stream, sorted so the queue invariant holds
        let mut merged = self.queues[ix].take_pending();
        merged.append(&mut arrivals);
        merged.sort_unstable();
        self.queues[ix] = TenantQueue::new(merged);
        if let Some(r) = self.queues[ix].ready_at(&scfg.window) {
            self.evq.push(r.max(t), ix);
        }
        (program_cycles, handoff_cycles, blocked_cycles)
    }

    /// The shared PCM-reprogramming price ([`migrate_in`](Self::migrate_in)
    /// and [`reprogram`](Self::reprogram)): program every array the
    /// tenant's resident plan (first pass) touches, serialized on this
    /// node's programming port and chained after whatever already holds
    /// the destination arrays, then the optional DMA hand-off after the
    /// reprogramming tail. Commits the profile, records its trace
    /// occupancy, and charges the programming energy. Returns
    /// `(program_cycles, total_tail_cycles)`.
    fn charge_program(
        &mut self,
        ix: usize,
        t: u64,
        handoff_cycles: u64,
        rec: &mut TraceRecorder,
    ) -> (u64, u64) {
        let scfg = self.ctx.scfg;
        let (plan, array_base) = {
            let ten = &self.ctx.tenancy.tenants[ix];
            (Rc::clone(&ten.plan), ten.array_base)
        };
        let pool = ImaArrayPool::new(self.ctx.cfg, self.ctx.pm);
        let by_array = pool.program_cycles_by_array(&plan.passes[0]);
        let program_cycles: u64 = by_array.values().sum();
        let mut pb = ProfileBuilder::new();
        let mut prog_free = self.timeline.free_at(RES_PROG).saturating_sub(t);
        let mut end_max = 0u64;
        for (&a, &cy) in &by_array {
            let res = RES_ARRAY0 + array_base + a;
            let start = prog_free.max(self.timeline.free_at(res).saturating_sub(t));
            let fin = start + cy;
            pb.occupy(RES_PROG, start, fin);
            pb.occupy(res, start, fin);
            prog_free = fin;
            end_max = end_max.max(fin);
        }
        let mut total = end_max;
        if handoff_cycles > 0 {
            let dma = end_max.max(self.timeline.free_at(RES_DMA).saturating_sub(t));
            pb.occupy(RES_DMA, dma, dma + handoff_cycles);
            total = dma + handoff_cycles;
        }
        let prog_profile = pb.build(total);
        let identity = ResMap {
            array_base: 0,
            core_base: 0,
        };
        self.timeline.commit(t, &prog_profile, identity);
        // migration occupancy rides the trace under batch id 0, exactly
        // like an autoscale move, so traced occupancy still merges to the
        // committed timeline
        rec.occupancy(ix, 0, t, &prog_profile, identity, scfg.backfill);
        self.stats[ix].energy_j += pool.program_energy_j(&plan.passes[0]);
        (program_cycles, total)
    }

    /// One event-loop iteration: prune, pop-and-validate the claim set,
    /// arbitrate, dispatch one batch, and run the autoscale pass.
    /// Returns the dispatch instant, or `None` when the node has drained
    /// and nothing was dispatched.
    pub(crate) fn step(&mut self, rec: &mut TraceRecorder) -> Option<u64> {
        let scfg = self.ctx.scfg;
        // watermark pruning: no future dispatch can probe before the
        // earliest next admission instant across tenants (`ready_at` is
        // nondecreasing per queue), so committed intervals wholly before
        // it can never conflict again — fold them away
        if scfg.prune {
            if let Some(w) = self
                .queues
                .iter()
                .filter_map(|q| q.ready_at(&scfg.window))
                .min()
            {
                self.timeline.prune_before(w);
            }
        }
        // pop-and-validate until every remaining stored key exceeds the
        // best validated instant: `claims` then holds exactly the tenants
        // dispatchable at `t_min`
        self.claims.clear();
        self.claim_batches.clear();
        self.claim_blockers.clear();
        let mut t_min: Option<u64> = None;
        while let Some((t_est, i)) = self.evq.peek() {
            if t_min.is_some_and(|tm| t_est > tm) {
                break;
            }
            self.evq.pop();
            self.validations += 1;
            let Some((td, b, cycles, blocker)) = validate_candidate(
                &mut self.queues[i],
                &mut self.stats[i],
                i,
                &mut self.ctx,
                &self.timeline,
                self.pool_free,
                self.rmaps[i],
                self.not_before[i],
                self.admission.as_mut(),
                rec,
            ) else {
                continue; // queue drained (e.g. emptied by drops)
            };
            if td > t_est {
                // the stored lower bound had gone stale — the churn
                // tally the calendar queue is built to absorb
                self.evq.mark_stale();
            }
            let claim = Claim {
                tenant: i,
                head_arrival: self.queues[i].head_arrival().unwrap_or(u64::MAX),
                planned_cycles: cycles,
            };
            match t_min {
                Some(tm) if td > tm => self.evq.push(td, i),
                Some(tm) if td == tm => {
                    self.claims.push(claim);
                    self.claim_batches.push(b);
                    self.claim_blockers.push(blocker);
                }
                _ => {
                    // strictly earlier: everything validated so far goes
                    // back at its (still valid) validated instant
                    if let Some(tm_old) = t_min {
                        for c in self.claims.drain(..) {
                            self.evq.push(tm_old, c.tenant);
                        }
                        self.claim_batches.clear();
                        self.claim_blockers.clear();
                    }
                    t_min = Some(td);
                    self.claims.push(claim);
                    self.claim_batches.push(b);
                    self.claim_blockers.push(blocker);
                }
            }
        }
        let t = t_min?;
        debug_assert!(!self.claims.is_empty());
        self.steps += 1;

        // every-event backlog sampling (pre-admission): each tenant's
        // pending depth at this dispatch instant, and the pool-wide
        // simultaneous backlog no per-tenant instrument can reconstruct
        let mut backlog: usize = 0;
        for (i, q) in self.queues.iter().enumerate() {
            let d = q.depth_at(t);
            self.stats[i].peak_queue = self.stats[i].peak_queue.max(d);
            backlog += d;
            // the same samples feed the resizing controller's pressure
            // windows (aged out at the horizon before any decision)
            if let Some(a) = self.auto.as_mut() {
                a.record(i, t, d);
            }
        }
        self.peak_backlog = self.peak_backlog.max(backlog as u64);

        let pick_tenant = self.arbiter.pick(&self.claims);
        // losers stay candidates at the same instant (still lower bounds)
        for c in &self.claims {
            if c.tenant != pick_tenant {
                self.evq.push(t, c.tenant);
            }
        }
        let pick_ix = self
            .claims
            .iter()
            .position(|c| c.tenant == pick_tenant)
            .unwrap();
        let b_claim = self.claim_batches[pick_ix];
        let blocker = self.claim_blockers[pick_ix];

        // decomposition boundaries, snapshotted before `admit` advances
        // the queue: the window close, the migration floor, and this
        // tenant's previous dispatch
        let close = self.queues[pick_tenant].window_close_at(&scfg.window, t);
        let nb = self.not_before[pick_tenant];
        let prev = self.prev_dispatch[pick_tenant];

        // admit exactly the validated batch: the timeline was checked
        // against profile(b_claim), and validation guarantees at least
        // b_claim arrivals are pending at `t`
        let admitted = self.queues[pick_tenant].admit(t, b_claim);
        let bsz = admitted.len();
        debug_assert!(bsz >= 1);
        debug_assert_eq!(bsz, b_claim);
        let cost = self.ctx.batch_cost(pick_tenant, bsz);
        // degraded-node slowdown: the service tail stretches, the claim
        // (and so SJF ordering and timeline shape) stays at base cost —
        // a first-order model of a node running hot or short of arrays
        let svc = self.stretched(t, cost.cycles);
        let end = t + svc;
        self.timeline.commit(t, &cost.profile, self.rmaps[pick_tenant]);
        self.pool_free = self.pool_free.max(end);
        self.makespan = self.makespan.max(end);
        // pool-busy union: overlapped spans do not double-count
        self.inflight.insert(t, end);

        let st = &mut self.stats[pick_tenant];
        st.batches += 1;
        st.served += bsz as u64;
        st.busy_cycles += svc;
        st.energy_j += cost.energy_j;
        for a in &admitted {
            st.latency.record(end - a);
            let ph = trace::decompose(*a, prev, close, nb, t, end);
            st.breakdown.record(&ph);
            if ph.resource_stall > 0 {
                *self
                    .stall_by_res
                    .entry(blocker.unwrap_or(trace::RES_POOL))
                    .or_insert(0) += ph.resource_stall;
            }
        }
        self.prev_dispatch[pick_tenant] = t;
        if self.track_inflight {
            // keep only batches still open so a later crash revokes
            // exactly the work that would finish after it
            self.open_batches.retain(|ob| ob.end > t);
            self.open_batches.push(OpenBatch {
                tenant: pick_tenant,
                dispatch: t,
                end,
                window_close: close,
                not_before: nb,
                prev_dispatch: prev,
                blocker,
                svc_cycles: svc,
                arrivals: admitted.clone(),
            });
        }
        if rec.is_on() {
            rec.batch(trace::BatchSpan {
                tenant: pick_tenant,
                batch: self.steps,
                size: bsz,
                head_arrival: admitted[0],
                prev_dispatch: prev,
                window_close: close,
                not_before: nb,
                dispatch: t,
                end,
                blocker,
                staged: cost.staged(),
            });
            rec.occupancy(
                pick_tenant,
                self.steps,
                t,
                &cost.profile,
                self.rmaps[pick_tenant],
                scfg.backfill,
            );
        }
        // close the admission predictor's loop with the same latencies
        // the percentile table is built from
        if let Some(ac) = self.admission.as_mut() {
            for a in &admitted {
                ac.observe(pick_tenant, end - a);
            }
        }
        if let Some(r) = self.queues[pick_tenant].ready_at(&scfg.window) {
            self.evq.push(r.max(t), pick_tenant);
        }

        // controller pass, tenant-id order (deterministic): stored heap
        // instants stay safe — a re-plan only changes future validations,
        // which recompute from scratch on pop, and the migration floor
        // only moves dispatches later
        if let Some(auto_ref) = self.auto.as_mut() {
            for ti in 0..self.queues.len() {
                let cur = self.ctx.tenancy.tenants[ti].arrays;
                if let Some(d) = auto_ref.decide(ti, t, cur) {
                    apply_scale(
                        d,
                        ti,
                        t,
                        &mut self.ctx,
                        auto_ref,
                        &mut self.timeline,
                        &mut self.rmaps,
                        &mut self.stats,
                        &mut self.not_before,
                        self.admission.as_mut(),
                        rec,
                    );
                }
            }
        }
        Some(t)
    }

    /// Fold the drained state into a [`ServeReport`]: the per-resource
    /// utilization breakdown, the stall attribution, and the
    /// deterministic counters.
    pub(crate) fn into_report(self, rec: &mut TraceRecorder) -> ServeReport {
        let scfg = self.ctx.scfg;
        // the conservation ground truth for the trace: the committed
        // interval sets as they stand at end of run
        rec.capture_timeline(&self.timeline);

        // per-resource utilization breakdown from the committed timelines:
        // the core-complex aggregate (8 units), each core's own row, then
        // the shared engines
        let cores_busy: u64 = (0..N_CORES)
            .map(|c| self.timeline.busy_cycles(RES_CORE0 + c))
            .sum();
        let mut resource_busy = vec![ResourceUtil::new("cores", cores_busy, N_CORES as u64)];
        for c in 0..N_CORES {
            resource_busy.push(ResourceUtil::new(
                &res_label(RES_CORE0 + c),
                self.timeline.busy_cycles(RES_CORE0 + c),
                1,
            ));
        }
        resource_busy.extend([
            ResourceUtil::new("dw_acc", self.timeline.busy_cycles(RES_DWACC), 1),
            ResourceUtil::new("ima_mux", self.timeline.busy_cycles(RES_IMA_MUX), 1),
            ResourceUtil::new("dma", self.timeline.busy_cycles(RES_DMA), 1),
            ResourceUtil::new("pcm_prog", self.timeline.busy_cycles(RES_PROG), 1),
        ]);
        let mut arrays_total = 0u64;
        let mut array_peak = (0u64, RES_ARRAY0);
        for (res, busy) in self.timeline.busy_per_resource() {
            if res >= RES_ARRAY0 {
                arrays_total += busy;
                if busy > array_peak.0 {
                    array_peak = (busy, res);
                }
            }
        }
        resource_busy.push(ResourceUtil::new("arrays", arrays_total, scfg.n_arrays as u64));
        resource_busy.push(ResourceUtil::new(&res_label(array_peak.1), array_peak.0, 1));

        // ascending resource id; the serialized-pool sentinel (usize::MAX)
        // sorts last by construction
        let stall_by_resource: Vec<StallShare> = self
            .stall_by_res
            .iter()
            .map(|(&res, &cy)| StallShare {
                name: Rc::from(trace::stall_label(res).as_str()),
                res,
                stalled_cycles: cy,
            })
            .collect();

        let tl_stats = self.timeline.stats();
        let eq = self.evq.counters();
        let counters = ServeCounters {
            steps: self.steps,
            validations: self.validations,
            probes: tl_stats.probes,
            live_intervals: tl_stats.live_nodes,
            peak_live_intervals: tl_stats.peak_live_nodes,
            pruned_intervals: tl_stats.pruned_nodes,
            watermark: tl_stats.watermark,
            evq_pushes: eq.pushes,
            evq_pops: eq.pops,
            evq_stale: eq.stale,
        };

        ServeReport {
            policy: scfg.policy,
            seed: scfg.seed,
            n_arrays: scfg.n_arrays,
            overlap: scfg.overlap,
            backfill: scfg.backfill,
            stream_weights: scfg.stream_weights,
            prune: scfg.prune,
            gap_skip: scfg.gap_skip,
            event_queue: self.evq.kind(),
            evq_steps: eq.steps,
            slo_p95_cy: scfg.slo_p95_cy,
            admission: self.admission_on,
            autoscale: scfg.autoscale,
            duration_cycles: self.duration_cy,
            makespan_cycles: self.makespan,
            busy_cycles: self.inflight.total(),
            cycle_ns: self.cycle_ns,
            peak_backlog: self.peak_backlog,
            tenants: self.stats,
            scale_events: self.auto.map(|a| a.events).unwrap_or_default(),
            resource_busy,
            stall_by_resource,
            counters,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_models_serve_under_poisson() {
        let pm = PowerModel::paper();
        let scfg = ServeConfig {
            duration_s: 0.1,
            ..ServeConfig::default()
        };
        let rep = simulate(&mnv2_bottleneck_pair(200.0), &scfg, &pm).unwrap();
        assert_eq!(rep.tenants.len(), 2);
        for t in &rep.tenants {
            assert_eq!(t.n_passes, 1, "{} must be resident in 64 arrays", t.name);
            assert!(t.served > 0, "{} served nothing", t.name);
            assert_eq!(t.served + t.dropped, t.arrivals);
        }
        assert!(rep.utilization() > 0.0 && rep.utilization() <= 1.0);
        assert!(rep.makespan_cycles >= rep.busy_cycles);
        // every request completes no earlier than it arrives
        for t in &rep.tenants {
            assert!(t.latency.count() == t.served);
        }
        // the breakdown names every shared resource and no resource is
        // busier than the run is long
        assert!(rep.resource_busy.iter().any(|r| r.name.as_ref() == "cores"));
        for r in &rep.resource_busy {
            let u = rep.resource_utilization(r);
            assert!((0.0..=1.0).contains(&u), "{} at {u}", r.name);
        }
    }

    #[test]
    fn drain_completes_every_arrival_without_deadlines() {
        let pm = PowerModel::paper();
        let scfg = ServeConfig {
            duration_s: 0.02,
            ..ServeConfig::default()
        };
        // heavy overload: arrivals far outpace the pool, but with no
        // deadline the drain still serves every single one
        let rep = simulate(&mnv2_bottleneck_pair(5_000.0), &scfg, &pm).unwrap();
        for t in &rep.tenants {
            assert_eq!(t.served, t.arrivals, "{}", t.name);
            assert_eq!(t.dropped, 0);
        }
        assert!(rep.makespan_cycles > rep.duration_cycles, "drained past horizon");
    }

    #[test]
    fn deadlines_shed_load_under_overload() {
        let pm = PowerModel::paper();
        let scfg = ServeConfig {
            duration_s: 0.02,
            deadline_cy: 2_000_000, // 4 ms at 500 MHz
            ..ServeConfig::default()
        };
        let rep = simulate(&mnv2_bottleneck_pair(5_000.0), &scfg, &pm).unwrap();
        assert!(rep.total_dropped() > 0, "overload must shed");
        for t in &rep.tenants {
            assert_eq!(t.served + t.dropped, t.arrivals);
            // survivors waited at most deadline before dispatch, so their
            // latency is bounded by deadline + the largest batch service
            let worst_batch = rep.makespan_cycles; // loose but sufficient
            assert!(t.latency.max() <= scfg.deadline_cy + worst_batch);
        }
    }

    #[test]
    fn overlap_never_slows_serving_down() {
        // identical t=0 backlogs form identical batches in both modes, so
        // the overlapped makespan is provably ≤ the serialized sum
        let pm = PowerModel::paper();
        let models: Vec<ModelTraffic> = mnv2_bottleneck_pair(0.0)
            .into_iter()
            .map(|mut m| {
                m.traffic = TrafficModel::Trace {
                    arrivals_cy: vec![0; 12],
                };
                m
            })
            .collect();
        let base = ServeConfig {
            window: BatchWindow {
                max_batch: 4,
                max_wait_cy: 0,
            },
            duration_s: 0.02,
            ..ServeConfig::default()
        };
        let on = simulate(&models, &base, &pm).unwrap();
        let off = simulate(
            &models,
            &ServeConfig {
                overlap: false,
                ..base
            },
            &pm,
        )
        .unwrap();
        assert_eq!(on.total_served(), 24);
        assert_eq!(off.total_served(), 24);
        assert!(on.makespan_cycles <= off.makespan_cycles);
        assert!(on.busy_cycles <= on.makespan_cycles);
    }

    #[test]
    fn serve_json_has_the_bench_fields() {
        let pm = PowerModel::paper();
        let scfg = ServeConfig {
            duration_s: 0.05,
            ..ServeConfig::default()
        };
        let rep = simulate(&mnv2_bottleneck_pair(400.0), &scfg, &pm).unwrap();
        let j = rep.to_json();
        assert!(j.req("inf_per_s").as_f64().unwrap() > 0.0);
        assert_eq!(j.req("overlap"), &Json::Bool(true));
        assert_eq!(j.req("backfill"), &Json::Bool(true));
        assert_eq!(j.req("prune"), &Json::Bool(true));
        assert!(j.req("peak_backlog").as_f64().unwrap() >= 0.0);
        // the deterministic perf counters ride along for the baselines
        let c = j.req("counters");
        assert!(c.req("steps").as_f64().unwrap() > 0.0);
        assert!(c.req("probes").as_f64().unwrap() > 0.0);
        assert!(c.req("pruned_intervals").as_f64().unwrap() > 0.0);
        assert!(
            c.req("peak_live_intervals").as_f64().unwrap()
                >= c.req("live_intervals").as_f64().unwrap()
        );
        assert_eq!(j.req("tenants").as_arr().unwrap().len(), 2);
        let res = j.req("resources").as_arr().unwrap();
        assert!(res.iter().any(|r| r.req("name").as_str() == Some("cores")));
        // the per-core rows ride along with the aggregate
        for c in 0..8 {
            let name = format!("core{c}");
            assert!(res.iter().any(|r| r.req("name").as_str() == Some(name.as_str())));
        }
        for r in res {
            let u = r.req("utilization").as_f64().unwrap();
            assert!((0.0..=1.0).contains(&u));
        }
        // the JSON round-trips through the writer
        let text = j.to_string_pretty();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn model_by_name_roundtrip() {
        assert!(model_by_name("mobilenetv2").is_ok());
        assert!(model_by_name("MNV2").is_ok());
        assert!(model_by_name("bottleneck").is_ok());
        assert!(model_by_name("resnet50").is_err());
    }
}
