//! Reject-on-arrival admission control against a per-tenant latency
//! budget.
//!
//! Deadlines (`--deadline-ms`) shed load *lazily*: a request queues, ages
//! past its budget, and is only discovered dead at the tenant's next
//! dispatch instant — the client waited the whole time for nothing.
//! Admission control refuses the request at the front door instead, the
//! moment it arrives, whenever the latency it would see is predicted to
//! blow the tenant's p95 budget (`--slo-p95`, cycles). Refused requests
//! never enter the queue, so they cannot inflate anyone else's wait.
//!
//! The predictor combines the two pressure signals the simulator already
//! has with one new one:
//!
//! * the **per-event queue sample** — the depth `d` of accepted requests
//!   still pending ahead of the arrival (the same quantity
//!   `TenantStats::peak_queue` tracks the maximum of);
//! * a **worst-case drain bound** from the tenant's own service ceiling
//!   `svc_max` (its costliest admissible batch): everything ahead drains
//!   in `ceil((d+1)/max_batch)` full-window batches, each preceded by at
//!   most the window's wait cap and followed by at most one in-flight
//!   batch remainder — so a request admitted at depth `d` completes
//!   within `max_wait + (ceil((d+1)/w) + 1) · svc_max` cycles of its
//!   arrival on an uncontended slice (`tests/prop_admission.rs` pins
//!   that the per-tenant p95 stays within budget wherever the
//!   uncontrolled run blew it);
//! * the **online p95 estimate** — a [`LogHistogram`] over latencies of
//!   this tenant's *completed* requests, fed back by the event loop. On a
//!   contended pool the analytic bound is optimistic (another tenant may
//!   hold shared engines); the observed p95 closes that loop: once the
//!   tail degrades past the bound, it takes over as the prediction.
//!
//! A request is admitted iff `max(observed_p95, bound(d)) ≤ budget`.
//! Everything is a deterministic function of simulator state — no wall
//! clock — so admission decisions replay bit-identically under a seed.

use super::batcher::BatchWindow;
use super::metrics::LogHistogram;

/// Per-tenant admission state: the service ceiling and the online
/// latency histogram the predictor reads.
struct TenantSlo {
    /// Cycles of this tenant's costliest admissible batch (max over
    /// batch sizes `1..=max_batch` of the planned batch cycles).
    svc_max: u64,
    /// Latencies of completed requests (arrival → batch completion).
    hist: LogHistogram,
}

/// Front-door admission gate for every tenant of one serving run.
pub struct AdmissionControl {
    /// p95 latency budget, cycles (> 0; 0 would admit nothing).
    budget: u64,
    w_max: u64,
    max_wait_cy: u64,
    tenants: Vec<TenantSlo>,
}

impl AdmissionControl {
    /// `svc_max[i]` is tenant `i`'s service ceiling — the planned cycles
    /// of its costliest admissible batch.
    pub fn new(budget: u64, window: &BatchWindow, svc_max: Vec<u64>) -> AdmissionControl {
        AdmissionControl {
            budget,
            w_max: window.max_batch.max(1) as u64,
            max_wait_cy: window.max_wait_cy,
            tenants: svc_max
                .into_iter()
                .map(|s| TenantSlo {
                    svc_max: s,
                    hist: LogHistogram::new(),
                })
                .collect(),
        }
    }

    /// Worst-case completion latency of a request entering behind `depth`
    /// accepted requests: window wait, full-window drain of everything up
    /// to and including it, plus one in-flight batch remainder.
    fn bound(&self, tenant: usize, depth: usize) -> u64 {
        let s = self.tenants[tenant].svc_max;
        let batches = (depth as u64 + 1).div_ceil(self.w_max);
        self.max_wait_cy
            .saturating_add((batches + 1).saturating_mul(s))
    }

    /// The latency the predictor expects this arrival to see: the larger
    /// of the analytic drain bound and the observed p95 tail.
    pub fn predicted(&self, tenant: usize, depth: usize) -> u64 {
        self.observed_p95(tenant).max(self.bound(tenant, depth))
    }

    /// Admit iff the predicted latency fits the budget.
    pub fn admit(&self, tenant: usize, depth: usize) -> bool {
        self.predicted(tenant, depth) <= self.budget
    }

    /// The p95 budget the gate enforces (cycles) — the threshold every
    /// traced rejection's `predicted_cy` exceeded (the trace tests check
    /// exactly that).
    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// Re-price a tenant's service ceiling after the autoscaler
    /// re-planned its slice. The observed histogram is kept: the tail is
    /// a property of the workload the tenant already saw, and a stale
    /// high tail decays as post-resize completions land on top of it.
    pub fn set_svc_max(&mut self, tenant: usize, svc_max: u64) {
        self.tenants[tenant].svc_max = svc_max;
    }

    /// Feed back one completed request's latency (the same value the
    /// serving table's percentiles are built from).
    pub fn observe(&mut self, tenant: usize, latency_cy: u64) {
        self.tenants[tenant].hist.record(latency_cy);
    }

    /// Online p95 estimate over completed requests (0 before the first
    /// completion).
    pub fn observed_p95(&self, tenant: usize) -> u64 {
        self.tenants[tenant].hist.quantile(0.95)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn window(max_batch: usize, max_wait_cy: u64) -> BatchWindow {
        BatchWindow {
            max_batch,
            max_wait_cy,
        }
    }

    #[test]
    fn empty_queue_admits_within_budget() {
        // depth 0, w=8: bound = wait + 2·svc
        let ac = AdmissionControl::new(2_100, &window(8, 100), vec![1_000]);
        assert_eq!(ac.predicted(0, 0), 2_100);
        assert!(ac.admit(0, 0));
        let tight = AdmissionControl::new(2_099, &window(8, 100), vec![1_000]);
        assert!(!tight.admit(0, 0));
    }

    #[test]
    fn depth_raises_the_prediction_by_full_windows() {
        let ac = AdmissionControl::new(u64::MAX, &window(4, 0), vec![100]);
        // depths 0..=3 ride the first batch, 4..=7 the second, ...
        assert_eq!(ac.predicted(0, 0), 200);
        assert_eq!(ac.predicted(0, 3), 200);
        assert_eq!(ac.predicted(0, 4), 300);
        assert_eq!(ac.predicted(0, 8), 400);
    }

    #[test]
    fn observed_tail_takes_over_when_worse() {
        let mut ac = AdmissionControl::new(1_000, &window(8, 0), vec![100]);
        assert!(ac.admit(0, 0)); // bound 200 ≤ 1000
        for _ in 0..100 {
            ac.observe(0, 5_000);
        }
        // the online p95 (a bin floor ≤ 5000, ≥ 4096) now dominates
        assert!(ac.observed_p95(0) > 1_000);
        assert_eq!(ac.predicted(0, 0), ac.observed_p95(0));
        assert!(!ac.admit(0, 0));
    }

    #[test]
    fn tenants_are_independent() {
        let mut ac = AdmissionControl::new(10_000, &window(8, 0), vec![100, 4_000]);
        ac.observe(0, 60_000);
        assert!(!ac.admit(0, 0), "tenant 0's tail blows its budget");
        assert!(ac.admit(1, 0), "tenant 1 is unaffected");
        // the heavy tenant's own svc ceiling prices its drain
        assert!(ac.predicted(1, 8) > ac.predicted(1, 0));
    }
}
