//! Next-event queue for the serving event loop.
//!
//! The loop keys pending tenants by `(dispatch instant, tenant id)` and
//! repeatedly extracts the minimum; stored instants are *lower bounds*
//! (queues only fill, resources only get busier) revalidated lazily on
//! pop, so the structure sees heavy churn: most pops immediately push
//! the same tenant back at a later instant. Two interchangeable
//! implementations realize the same total order:
//!
//! - [`EventQueueKind::Heap`] — the PR 3 `BinaryHeap<Reverse<..>>`,
//!   kept as the pinned off-switch (`--event-queue heap`);
//! - [`EventQueueKind::Calendar`] — a Brown-style calendar queue:
//!   events hash into `buckets` of width `2^wbits` cycles by their day
//!   `(t >> wbits) & mask`, and extraction scans at most one "year"
//!   (every bucket, one day each) forward from the last extracted
//!   minimum before falling back to a direct scan. Under the lazy
//!   revalidation churn above, pushes land at or just past the cursor,
//!   so the scan almost always terminates in its first occupied bucket.
//!
//! Both implementations order events by the full `(t, tenant)` tuple —
//! ties break toward the lower tenant id — so their pop sequences are
//! identical event by event, and everything downstream (dispatch
//! tables, serve JSON, trace bytes) is bit-identical across
//! `--event-queue heap|calendar`; `tests/prop_evq.rs` pins this. The
//! [`EvqCounters`] work tallies `pushes`/`pops`/`stale` are pure
//! functions of that shared pop sequence (mode-independent, exported in
//! serve JSON); only `steps` — the structural work each implementation
//! performs — differs by mode, and it is reported solely in
//! `bench-timeline`'s heap-vs-calendar section, never in serve JSON.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Which next-event structure the serving loop runs on.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EventQueueKind {
    /// Bucketed calendar queue (the default).
    #[default]
    Calendar,
    /// Binary heap — the pre-calendar behavior, pinned bit-identical.
    Heap,
}

impl EventQueueKind {
    /// Parse a `--event-queue` value.
    pub fn parse(s: &str) -> Option<EventQueueKind> {
        match s {
            "calendar" => Some(EventQueueKind::Calendar),
            "heap" => Some(EventQueueKind::Heap),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            EventQueueKind::Calendar => "calendar",
            EventQueueKind::Heap => "heap",
        }
    }
}

/// Deterministic event-queue work tallies. `pushes`, `pops`, and
/// `stale` (pops whose lower-bound instant had drifted behind the
/// revalidated dispatch instant) are functions of the pop sequence and
/// therefore identical across queue kinds; `steps` counts structural
/// work (heap: sift-depth proxy, calendar: buckets and entries
/// examined) and is the only mode-dependent field.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EvqCounters {
    pub pushes: u64,
    pub pops: u64,
    pub stale: u64,
    pub steps: u64,
}

/// `ceil(log2(n)) + 1` — the deterministic sift-depth proxy the heap
/// mode charges per push/pop (mirrors the timeline's probe unit: a pure
/// function of the occupancy, never of layout or allocation).
fn sift_steps(n: usize) -> u64 {
    (usize::BITS - n.leading_zeros()) as u64
}

const MIN_BUCKETS: usize = 16;
const DEFAULT_WBITS: u32 = 12;

/// Brown-style calendar queue over `(t, id)` events; see the module doc
/// for the ordering contract it shares with the heap.
#[derive(Clone, Debug)]
struct CalendarQueue {
    /// `buckets[(t >> wbits) & mask]` holds the events of day
    /// `t >> wbits`, unordered (extraction selects the min).
    buckets: Vec<Vec<(u64, usize)>>,
    /// Bucket width is `2^wbits` cycles.
    wbits: u32,
    len: usize,
    /// Lower bound on every stored key — the scan cursor. Monotone in
    /// steady state (pops raise it to each extracted minimum); a push
    /// below it lowers it again, so correctness never rests on the
    /// caller's push discipline.
    last_min: u64,
    /// Cached peek result, invalidated by push/pop.
    cached: Option<(u64, usize)>,
    steps: u64,
}

impl CalendarQueue {
    fn new() -> CalendarQueue {
        CalendarQueue {
            buckets: vec![Vec::new(); MIN_BUCKETS],
            wbits: DEFAULT_WBITS,
            len: 0,
            last_min: 0,
            cached: None,
            steps: 0,
        }
    }

    fn bucket_of(&self, t: u64) -> usize {
        ((t >> self.wbits) as usize) & (self.buckets.len() - 1)
    }

    fn push(&mut self, t: u64, id: usize) {
        if t < self.last_min {
            self.last_min = t;
        }
        let b = self.bucket_of(t);
        self.buckets[b].push((t, id));
        self.len += 1;
        self.steps += 1;
        // keep the cache only if the newcomer cannot beat it
        if self.cached.is_some_and(|m| (t, id) < m) {
            self.cached = Some((t, id));
        }
        if self.len > 2 * self.buckets.len() {
            self.resize(self.buckets.len() * 2);
        }
    }

    /// Locate the current minimum `(t, id)` without removing it.
    fn peek(&mut self) -> Option<(u64, usize)> {
        if self.len == 0 {
            return None;
        }
        if let Some(m) = self.cached {
            return Some(m);
        }
        let n = self.buckets.len();
        let start_day = self.last_min >> self.wbits;
        // one year forward from the cursor: day k lives in bucket
        // (start_day + k) & mask, and only entries of exactly that day
        // belong to this lap (later laps of the same bucket wait)
        for k in 0..n as u64 {
            let day = start_day + k;
            let b = (day as usize) & (n - 1);
            self.steps += 1;
            let mut best: Option<(u64, usize)> = None;
            for &(t, id) in &self.buckets[b] {
                self.steps += 1;
                if t >> self.wbits == day && best.is_none_or(|m| (t, id) < m) {
                    best = Some((t, id));
                }
            }
            if best.is_some() {
                self.cached = best;
                return best;
            }
        }
        // nothing within a year of the cursor: direct scan (rare — only
        // after a drain leaves one far-future event)
        let mut best: Option<(u64, usize)> = None;
        for bucket in &self.buckets {
            for &(t, id) in bucket {
                self.steps += 1;
                if best.is_none_or(|m| (t, id) < m) {
                    best = Some((t, id));
                }
            }
        }
        self.cached = best;
        best
    }

    fn pop(&mut self) -> Option<(u64, usize)> {
        let m = self.peek()?;
        let b = self.bucket_of(m.0);
        // swap_remove is order-safe: the minimum is selected by value,
        // never by position
        let ix = self.buckets[b].iter().position(|&e| e == m).unwrap();
        self.buckets[b].swap_remove(ix);
        self.len -= 1;
        self.steps += 1;
        self.last_min = m.0;
        self.cached = None;
        if self.buckets.len() > MIN_BUCKETS && self.len < self.buckets.len() / 4 {
            self.resize(self.buckets.len() / 2);
        }
        Some(m)
    }

    /// Deterministic rebuild at `n` buckets (a power of two), re-deriving
    /// the bucket width from the live key span so each bucket holds ~1
    /// event — a pure function of the stored multiset.
    fn resize(&mut self, n: usize) {
        let events: Vec<(u64, usize)> = self.buckets.iter().flatten().copied().collect();
        self.steps += events.len() as u64;
        if let (Some(lo), Some(hi)) =
            (events.iter().map(|e| e.0).min(), events.iter().map(|e| e.0).max())
        {
            // a zero key span (single event, or every event at one
            // instant) carries no spacing information: re-deriving from
            // it would collapse the bucket width to 2 cycles and every
            // later push would pile into a handful of buckets. Keep the
            // current width instead — any live span re-derives normally.
            if hi > lo {
                let spacing = (hi - lo) / (events.len() as u64) + 1;
                self.wbits = 64 - spacing.leading_zeros();
            }
        }
        self.buckets = vec![Vec::new(); n];
        for (t, id) in events {
            let b = self.bucket_of(t);
            self.buckets[b].push((t, id));
        }
        self.cached = None;
    }
}

/// The serving loop's next-event queue; see [`EventQueueKind`] for the
/// two interchangeable implementations.
#[derive(Clone, Debug)]
pub struct EventQueue {
    imp: Impl,
    counters: EvqCounters,
}

#[derive(Clone, Debug)]
enum Impl {
    Heap(BinaryHeap<Reverse<(u64, usize)>>),
    Calendar(CalendarQueue),
}

impl EventQueue {
    pub fn new(kind: EventQueueKind) -> EventQueue {
        let imp = match kind {
            EventQueueKind::Heap => Impl::Heap(BinaryHeap::new()),
            EventQueueKind::Calendar => Impl::Calendar(CalendarQueue::new()),
        };
        EventQueue { imp, counters: EvqCounters::default() }
    }

    pub fn kind(&self) -> EventQueueKind {
        match &self.imp {
            Impl::Heap(_) => EventQueueKind::Heap,
            Impl::Calendar(_) => EventQueueKind::Calendar,
        }
    }

    pub fn push(&mut self, t: u64, id: usize) {
        self.counters.pushes += 1;
        match &mut self.imp {
            Impl::Heap(heap) => {
                heap.push(Reverse((t, id)));
                self.counters.steps += sift_steps(heap.len());
            }
            Impl::Calendar(cal) => cal.push(t, id),
        }
    }

    pub fn peek(&mut self) -> Option<(u64, usize)> {
        match &mut self.imp {
            Impl::Heap(heap) => heap.peek().map(|&Reverse(e)| e),
            Impl::Calendar(cal) => cal.peek(),
        }
    }

    pub fn pop(&mut self) -> Option<(u64, usize)> {
        let e = match &mut self.imp {
            Impl::Heap(heap) => {
                let e = heap.pop().map(|Reverse(e)| e);
                if e.is_some() {
                    self.counters.steps += sift_steps(heap.len() + 1);
                }
                e
            }
            Impl::Calendar(cal) => cal.pop(),
        };
        if e.is_some() {
            self.counters.pops += 1;
        }
        e
    }

    /// Record that the event just popped carried a stale lower bound
    /// (revalidation moved its dispatch instant later). Mode-independent:
    /// staleness is a property of the pop sequence, not the structure.
    pub fn mark_stale(&mut self) {
        self.counters.stale += 1;
    }

    pub fn counters(&self) -> EvqCounters {
        let mut c = self.counters;
        if let Impl::Calendar(cal) = &self.imp {
            c.steps = cal.steps;
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic pseudo-random stream (splitmix-style) — no
    /// dependence on process state, so the sequences are reproducible.
    struct Rng(u64);
    impl Rng {
        fn next(&mut self) -> u64 {
            self.0 = self.0.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = self.0;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        }
    }

    fn drain(q: &mut EventQueue) -> Vec<(u64, usize)> {
        let mut out = Vec::new();
        while let Some(e) = q.pop() {
            out.push(e);
        }
        out
    }

    #[test]
    fn parse_and_label_round_trip() {
        for k in [EventQueueKind::Calendar, EventQueueKind::Heap] {
            assert_eq!(EventQueueKind::parse(k.label()), Some(k));
        }
        assert_eq!(EventQueueKind::parse("fifo"), None);
        assert_eq!(EventQueueKind::default(), EventQueueKind::Calendar);
    }

    #[test]
    fn calendar_matches_heap_on_random_churn() {
        // the serving access pattern: pop the min, re-push the same id a
        // (pseudo-random) bit later, occasionally push fresh ids — the
        // two structures must agree on every pop
        let mut rng = Rng(42);
        let mut cal = EventQueue::new(EventQueueKind::Calendar);
        let mut heap = EventQueue::new(EventQueueKind::Heap);
        for id in 0..8usize {
            let t = rng.next() % 10_000;
            cal.push(t, id);
            heap.push(t, id);
        }
        for round in 0..5_000u64 {
            let a = cal.pop();
            let b = heap.pop();
            assert_eq!(a, b, "pop diverged at round {round}");
            let (t, id) = a.unwrap();
            // lazy revalidation: usually later, sometimes much later
            // (drains the year window), sometimes at the same instant
            let bump = match rng.next() % 10 {
                0 => 0,
                9 => 1 << 20,
                _ => rng.next() % 5_000,
            };
            cal.push(t + bump, id);
            heap.push(t + bump, id);
        }
        // mode-independent tallies agree; structural steps differ freely
        let (cc, hc) = (cal.counters(), heap.counters());
        assert_eq!((cc.pushes, cc.pops, cc.stale), (hc.pushes, hc.pops, hc.stale));
        let mut a = drain(&mut cal);
        let b = drain(&mut heap);
        assert_eq!(a, b, "drain order diverged");
        a.sort();
        assert_eq!(a, b, "drain must come out fully sorted");
    }

    #[test]
    fn ties_break_toward_the_lower_id() {
        for kind in [EventQueueKind::Calendar, EventQueueKind::Heap] {
            let mut q = EventQueue::new(kind);
            q.push(100, 3);
            q.push(100, 1);
            q.push(100, 2);
            q.push(50, 7);
            assert_eq!(q.peek(), Some((50, 7)));
            assert_eq!(
                drain(&mut q),
                vec![(50, 7), (100, 1), (100, 2), (100, 3)],
                "{}",
                kind.label()
            );
        }
    }

    #[test]
    fn far_future_events_survive_the_year_fallback() {
        // one event far beyond a year of empty buckets exercises the
        // direct-scan fallback; interleaved near events keep the cursor
        // honest
        let mut q = EventQueue::new(EventQueueKind::Calendar);
        q.push(u64::MAX / 2, 0);
        assert_eq!(q.peek(), Some((u64::MAX / 2, 0)));
        q.push(10, 1);
        assert_eq!(q.pop(), Some((10, 1)));
        assert_eq!(q.pop(), Some((u64::MAX / 2, 0)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn resize_preserves_content_and_order() {
        let mut rng = Rng(7);
        let mut q = EventQueue::new(EventQueueKind::Calendar);
        let mut expect: Vec<(u64, usize)> = (0..200usize)
            .map(|id| {
                let t = rng.next() % 1_000_000;
                q.push(t, id);
                (t, id)
            })
            .collect();
        expect.sort();
        assert_eq!(drain(&mut q), expect, "growth + shrink resizes must not lose events");
    }

    #[test]
    fn resize_with_all_events_at_one_instant_keeps_a_sane_width() {
        // push enough same-instant events to force a growth resize
        // (len > 2 * buckets): the zero key span must not collapse the
        // bucket width, and later spread-out pushes must still pop in
        // order without degenerate bucket behavior
        let mut q = EventQueue::new(EventQueueKind::Calendar);
        let n = 2 * MIN_BUCKETS + 1; // crosses the growth threshold
        for id in 0..n {
            q.push(5_000, id);
        }
        let mut expect: Vec<(u64, usize)> = (0..n).map(|id| (5_000, id)).collect();
        // events pushed after the degenerate resize land in sane buckets
        for id in n..n + 64 {
            let t = 10_000 + (id as u64) * 4_096;
            q.push(t, id);
            expect.push((t, id));
        }
        expect.sort();
        assert_eq!(drain(&mut q), expect);
    }

    #[test]
    fn resize_with_a_single_live_event_keeps_a_sane_width() {
        // grow past the threshold, then drain to one event so the next
        // shrink resize sees a single-key (zero-span) population
        let mut q = EventQueue::new(EventQueueKind::Calendar);
        let n = 2 * MIN_BUCKETS + 1;
        for id in 0..n {
            q.push(id as u64 * 100, id);
        }
        for _ in 0..n - 1 {
            let _ = q.pop();
        }
        // the shrink resize has fired by now; the surviving far event
        // and fresh pushes must still come out fully ordered
        let survivor = ((n - 1) as u64 * 100, n - 1);
        q.push(1 << 30, n);
        q.push(survivor.0 + 1, n + 1);
        assert_eq!(
            drain(&mut q),
            vec![survivor, (survivor.0 + 1, n + 1), (1 << 30, n)]
        );
    }

    #[test]
    fn wbits_survive_a_zero_span_resize() {
        // white-box: a resize over a zero key span must keep the prior
        // width rather than re-deriving a degenerate one
        let mut cal = CalendarQueue::new();
        let before = cal.wbits;
        for id in 0..64 {
            cal.push(1 << 20, id);
        }
        assert_eq!(cal.wbits, before, "zero span must not touch wbits");
        // a live span still re-derives: spread the keys and force a rebuild
        for id in 64..256 {
            cal.push((id as u64) << 24, id);
        }
        assert_ne!(cal.wbits, 1, "live span re-derivation must not degenerate");
    }

    #[test]
    fn push_below_the_cursor_interleaved_with_stale_pops_matches_heap() {
        // adversarial churn for the last_min cursor: pops raise it, then
        // a push strictly below it (an "earlier than any lower bound"
        // event, which the serving loop produces when a strictly-earlier
        // claim re-pushes prior claims) must still pop first, in both
        // modes, with stale marks sprinkled in
        let mut rng = Rng(0xDEAD_BEEF);
        let mut cal = EventQueue::new(EventQueueKind::Calendar);
        let mut heap = EventQueue::new(EventQueueKind::Heap);
        let mut next_id = 0usize;
        for _ in 0..16 {
            let t = 1_000_000 + rng.next() % 1_000_000;
            cal.push(t, next_id);
            heap.push(t, next_id);
            next_id += 1;
        }
        for round in 0..4_000u64 {
            match rng.next() % 8 {
                // push far below the cursor
                0 => {
                    let t = rng.next() % 1_000;
                    cal.push(t, next_id);
                    heap.push(t, next_id);
                    next_id += 1;
                }
                // stale pop: re-push the same id later
                1 | 2 => {
                    let a = cal.pop();
                    assert_eq!(a, heap.pop(), "stale-pop diverged at round {round}");
                    if let Some((t, id)) = a {
                        cal.mark_stale();
                        heap.mark_stale();
                        let t2 = t + 1 + rng.next() % 100_000;
                        cal.push(t2, id);
                        heap.push(t2, id);
                    }
                }
                // plain pop
                3 | 4 => {
                    assert_eq!(cal.pop(), heap.pop(), "pop diverged at round {round}");
                }
                // push near the cursor
                _ => {
                    let base = cal.peek().map_or(0, |(t, _)| t);
                    let t = base + rng.next() % 50_000;
                    cal.push(t, next_id);
                    heap.push(t, next_id);
                    next_id += 1;
                }
            }
            assert_eq!(cal.peek(), heap.peek(), "peek diverged at round {round}");
        }
        let (cc, hc) = (cal.counters(), heap.counters());
        assert_eq!((cc.pushes, cc.pops, cc.stale), (hc.pushes, hc.pops, hc.stale));
        assert_eq!(drain(&mut cal), drain(&mut heap), "drain order diverged");
    }

    #[test]
    fn counters_track_pushes_pops_and_stale() {
        let mut q = EventQueue::new(EventQueueKind::Calendar);
        q.push(1, 0);
        q.push(2, 1);
        let _ = q.pop();
        q.mark_stale();
        let c = q.counters();
        assert_eq!((c.pushes, c.pops, c.stale), (2, 1, 1));
        assert!(c.steps > 0);
    }
}
