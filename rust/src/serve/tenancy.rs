//! Multi-model residency in one IMA pool, plus the arbitration policies
//! that pick which tenant's batch dispatches next.
//!
//! Placement carves the pool into disjoint per-tenant array slices, first
//! come first carved: each network TILE&PACKs into the arrays the earlier
//! tenants left over (through the shared [`PlanCache`], so a tenant whose
//! geometry and slice repeat across sweeps never re-packs). A tenant whose
//! slice holds all its weights is *resident* — its requests never touch PCM
//! programming; an oversubscribed tenant falls back to staged serving
//! inside its own slice and pays reprogramming + boundary DMA per batch,
//! exactly as `coordinator::scheduler` charges it.
//!
//! The carve is the *initial* layout, not a lifetime contract: under
//! `--autoscale` the resizing controller in [`super::autoscale`] rewrites
//! a [`Tenant`]'s `array_base`/`arrays`/`plan` mid-run — growing a
//! pressured tenant into the pool's free run (arrays held back by
//! `ServeConfig::headroom` or returned by a co-tenant's shrink) and
//! re-planning through the same shared cache, with the PCM reprogramming
//! of the moved arrays charged on the pool timeline. Slices stay disjoint
//! at every instant; only their boundaries move.
//!
//! Under fleet sharding ([`super::fleet`]) every node runs its own
//! independent carve of its own pool: [`place_tenants`] is called once
//! per node over that node's roster (owned tenants plus any standby
//! replica of the fleet's heaviest tenant), so a tenant resident on a
//! big node can legitimately be staged on a small one — that asymmetry
//! is exactly what load-aware routing exploits.
//!
//! Cross-tenant timing: dispatch is per-resource and interval-precise.
//! Every batch carries a reservation profile of merged busy `[start, end)`
//! intervals over the pool's explicit resources — each array of the
//! tenant's slice, each of the eight cores, the DW accelerator, the IMA
//! mux, and the L2/DMA and PCM-programming ports (see
//! `coordinator::timeline`). The backfilling arbiter (default) places a
//! batch at the earliest instant its intervals fit, including inside idle
//! gaps of batches already committed; `backfill: false` falls back to the
//! conservative first-use→last-release envelope reservation, and
//! `overlap: false` restores the one-batch-in-flight pool of PR 2.
//!
//! Core affinity: each tenant also gets a `core_base` — a rotation of the
//! per-core resources `core0..7`. A big parallel section still engages
//! all eight cores (rotation is then a no-op permutation), but small
//! residual/ancillary sections of different tenants land on disjoint
//! physical cores and genuinely share the complex, the way disjoint array
//! slices already overlap. The envelope arbiter ignores the rotation so
//! `--no-backfill` stays bit-identical to the PR 3 fused-complex model.
//!
//! The arbiter below only breaks ties between tenants dispatchable at the
//! same instant.

use std::borrow::Borrow;
use std::rc::Rc;

use crate::coordinator::timeline::N_CORES;
use crate::coordinator::PlanCache;
use crate::net::Network;
use crate::tilepack::StagedPlacement;

/// One model resident (or staged) in its slice of the pool.
#[derive(Clone, Debug)]
pub struct Tenant {
    pub name: String,
    /// First pool array of this tenant's slice.
    pub array_base: usize,
    /// Arrays in the slice (max over passes for staged tenants).
    pub arrays: usize,
    /// Core-affinity rotation: this tenant's logical core `c` runs on
    /// physical core `(core_base + c) % 8`. Only the backfilling arbiter
    /// applies it (see the module docs).
    pub core_base: usize,
    pub plan: Rc<StagedPlacement>,
    /// Device occupancy within the slice, in [0, 1].
    pub occupancy: f64,
}

impl Tenant {
    pub fn resident(&self) -> bool {
        self.plan.is_resident()
    }

    pub fn n_passes(&self) -> usize {
        self.plan.n_passes()
    }
}

/// The whole pool, carved.
#[derive(Clone, Debug)]
pub struct Tenancy {
    pub n_arrays: usize,
    pub tenants: Vec<Tenant>,
}

impl Tenancy {
    /// Arrays carved out across all tenants.
    pub fn arrays_used(&self) -> usize {
        self.tenants.iter().map(|t| t.arrays).sum()
    }
}

/// Carve `n_arrays` among `nets` in order. Every tenant must at least fit
/// staged in what is left — a single layer larger than the remaining slice
/// is an error (the pool is simply too small for that mix). Generic over
/// owned and borrowed networks so callers (the serving loop) can pass
/// `&[&Network]` without cloning every model.
pub fn place_tenants<N: Borrow<Network>>(
    nets: &[N],
    s: usize,
    n_arrays: usize,
    rotate: bool,
    cache: &mut PlanCache,
) -> Result<Tenancy, String> {
    let mut tenants = Vec::with_capacity(nets.len());
    let mut base = 0usize;
    // spread core affinities evenly: 2 tenants → bases 0 and 4, 4 tenants
    // → 0/2/4/6, ≥ 8 tenants wrap
    let core_stride = N_CORES / nets.len().clamp(1, N_CORES);
    for (ti, net) in nets.iter().enumerate() {
        let net = net.borrow();
        if base >= n_arrays {
            return Err(format!(
                "no arrays left for `{}`: {base} of {n_arrays} already carved",
                net.name
            ));
        }
        let remaining = n_arrays - base;
        let plan = cache
            .get_or_place(net, s, remaining, rotate)
            .map_err(|e| format!("placing `{}` in {remaining} arrays: {e}", net.name))?;
        let arrays = plan.passes.iter().map(|p| p.arrays_used).max().unwrap_or(0);
        let slice_devices = arrays * s * s;
        let occupancy = if slice_devices == 0 {
            0.0
        } else {
            // staged tenants reuse the slice pass after pass: occupancy is
            // the fullest pass
            plan.passes
                .iter()
                .map(|p| p.devices_used() as f64 / slice_devices as f64)
                .fold(0.0, f64::max)
        };
        tenants.push(Tenant {
            name: net.name.clone(),
            array_base: base,
            arrays,
            core_base: (ti * core_stride) % N_CORES,
            plan,
            occupancy,
        });
        base += arrays;
    }
    Ok(Tenancy { n_arrays, tenants })
}

/// Arbitration policy between tenants with dispatchable batches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Oldest waiting head-of-queue request first.
    Fifo,
    /// Weighted round-robin over tenants (weights from the model specs).
    Wrr,
    /// Shortest planned batch (in scheduler cycles) first. Maximizes
    /// throughput, starves heavy models under overload — the report shows
    /// both.
    Sjf,
}

impl Policy {
    pub fn parse(s: &str) -> Result<Policy, String> {
        match s.to_ascii_lowercase().as_str() {
            "fifo" => Ok(Policy::Fifo),
            "wrr" => Ok(Policy::Wrr),
            "sjf" => Ok(Policy::Sjf),
            other => Err(format!("unknown policy `{other}` (fifo|wrr|sjf)")),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Policy::Fifo => "FIFO",
            Policy::Wrr => "WRR",
            Policy::Sjf => "SJF",
        }
    }
}

/// One tenant's claim at an arbitration point.
#[derive(Clone, Copy, Debug)]
pub struct Claim {
    pub tenant: usize,
    /// Arrival cycle of its oldest pending request.
    pub head_arrival: u64,
    /// Planned cycles of the batch it would dispatch.
    pub planned_cycles: u64,
}

/// Deterministic arbiter. WRR keeps rotating state; FIFO/SJF are
/// stateless. All ties break toward the lower tenant id.
pub struct Arbiter {
    policy: Policy,
    weights: Vec<u64>,
    /// WRR cursor: tenant whose turn it is, and how much of its weight
    /// this turn has consumed.
    wrr_tenant: usize,
    wrr_spent: u64,
}

impl Arbiter {
    pub fn new(policy: Policy, weights: &[u64]) -> Arbiter {
        assert!(!weights.is_empty());
        Arbiter {
            policy,
            weights: weights.iter().map(|&w| w.max(1)).collect(),
            wrr_tenant: 0,
            wrr_spent: 0,
        }
    }

    /// Pick one claim. `claims` must be non-empty; ids must be < the
    /// weight-vector length.
    pub fn pick(&mut self, claims: &[Claim]) -> usize {
        assert!(!claims.is_empty());
        match self.policy {
            Policy::Fifo => {
                claims
                    .iter()
                    .min_by_key(|c| (c.head_arrival, c.tenant))
                    .unwrap()
                    .tenant
            }
            Policy::Sjf => {
                claims
                    .iter()
                    .min_by_key(|c| (c.planned_cycles, c.tenant))
                    .unwrap()
                    .tenant
            }
            Policy::Wrr => {
                let n = self.weights.len();
                for _ in 0..n {
                    let t = self.wrr_tenant;
                    if claims.iter().any(|c| c.tenant == t) {
                        self.wrr_spent += 1;
                        if self.wrr_spent >= self.weights[t] {
                            self.wrr_tenant = (t + 1) % n;
                            self.wrr_spent = 0;
                        }
                        return t;
                    }
                    // absent tenants forfeit the rest of their turn
                    self.wrr_tenant = (t + 1) % n;
                    self.wrr_spent = 0;
                }
                unreachable!("non-empty claims always yield a pick");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::bottleneck::bottleneck;
    use crate::net::mobilenetv2::mobilenet_v2;

    #[test]
    fn two_models_resident_in_disjoint_slices() {
        let mut cache = PlanCache::new();
        let nets = vec![mobilenet_v2(224), bottleneck()];
        let t = place_tenants(&nets, 256, 64, false, &mut cache).unwrap();
        assert_eq!(t.tenants.len(), 2);
        let (a, b) = (&t.tenants[0], &t.tenants[1]);
        assert!(a.resident() && b.resident());
        // disjoint, in-bounds slices
        assert_eq!(b.array_base, a.array_base + a.arrays);
        assert!(t.arrays_used() <= 64);
        assert!(a.occupancy > 0.0 && a.occupancy <= 1.0);
        assert!(b.occupancy > 0.0 && b.occupancy <= 1.0);
    }

    #[test]
    fn core_affinity_spreads_across_tenants() {
        let mut cache = PlanCache::new();
        let nets = vec![mobilenet_v2(224), bottleneck()];
        let t = place_tenants(&nets, 256, 64, false, &mut cache).unwrap();
        assert_eq!(t.tenants[0].core_base, 0);
        assert_eq!(t.tenants[1].core_base, 4);
        // a lone tenant keeps affinity 0
        let mut cache = PlanCache::new();
        let t1 = place_tenants(&[bottleneck()], 256, 8, false, &mut cache).unwrap();
        assert_eq!(t1.tenants[0].core_base, 0);
    }

    #[test]
    fn second_tenant_stages_when_squeezed() {
        let mut cache = PlanCache::new();
        // bottleneck carves a few arrays; 12 arrays leave too little for
        // MobileNetV2 resident → staged in its slice
        let nets = vec![bottleneck(), mobilenet_v2(224)];
        let t = place_tenants(&nets, 256, 12, false, &mut cache).unwrap();
        assert!(t.tenants[0].resident());
        assert!(!t.tenants[1].resident());
        assert!(t.tenants[1].n_passes() > 1);
        assert!(t.arrays_used() <= 12);
    }

    #[test]
    fn pool_exhaustion_is_an_error() {
        let mut cache = PlanCache::new();
        let nets = vec![bottleneck(), bottleneck(), bottleneck()];
        // bottleneck needs ~4 arrays; 4 total leaves zero for tenant 2
        let r = place_tenants(&nets, 256, 4, false, &mut cache);
        assert!(r.is_err(), "{r:?}");
    }

    #[test]
    fn fifo_picks_oldest_head() {
        let mut arb = Arbiter::new(Policy::Fifo, &[1, 1]);
        let pick = arb.pick(&[
            Claim { tenant: 0, head_arrival: 100, planned_cycles: 5 },
            Claim { tenant: 1, head_arrival: 50, planned_cycles: 500 },
        ]);
        assert_eq!(pick, 1);
    }

    #[test]
    fn sjf_picks_shortest_batch() {
        let mut arb = Arbiter::new(Policy::Sjf, &[1, 1]);
        let pick = arb.pick(&[
            Claim { tenant: 0, head_arrival: 100, planned_cycles: 5 },
            Claim { tenant: 1, head_arrival: 50, planned_cycles: 500 },
        ]);
        assert_eq!(pick, 0);
    }

    #[test]
    fn wrr_alternates_and_respects_weights() {
        let both = [
            Claim { tenant: 0, head_arrival: 0, planned_cycles: 1 },
            Claim { tenant: 1, head_arrival: 0, planned_cycles: 1 },
        ];
        let mut arb = Arbiter::new(Policy::Wrr, &[2, 1]);
        let picks: Vec<usize> = (0..6).map(|_| arb.pick(&both)).collect();
        assert_eq!(picks, vec![0, 0, 1, 0, 0, 1]);
        // a tenant with nothing pending forfeits its turn
        let only1 = [Claim { tenant: 1, head_arrival: 0, planned_cycles: 1 }];
        let mut arb = Arbiter::new(Policy::Wrr, &[2, 1]);
        assert_eq!(arb.pick(&only1), 1);
        assert_eq!(arb.pick(&both), 0, "turn passed back to tenant 0");
    }
}
