//! Open-loop traffic generators: seeded arrival processes per model.
//!
//! Arrivals are generated *open-loop* — the client population does not slow
//! down when the pool saturates — which is the only way latency percentiles
//! mean anything (closed-loop "batch of B" measurements hide queueing
//! entirely, the coordinated-omission trap). Three processes:
//!
//! * [`TrafficModel::Poisson`] — memoryless arrivals at a fixed rate, the
//!   classic serving baseline;
//! * [`TrafficModel::Bursty`] — a two-state Markov-modulated Poisson
//!   process (MMPP-2): dwell in a hot state at `burst ×` the base rate,
//!   then a cold state at `rate / burst`, exponential dwell times — the
//!   open/closed-tab traffic real deployments see;
//! * [`TrafficModel::Trace`] — replay an explicit arrival-cycle list
//!   (regression tests and production trace replay).
//!
//! Everything derives from [`SplitMix64`], so a (model, seed) pair yields
//! the same arrival vector on every run — the serving determinism tests
//! pin this.

use crate::util::rng::SplitMix64;

/// An arrival process, parameterized in wall-clock terms.
#[derive(Clone, Debug)]
pub enum TrafficModel {
    /// Memoryless arrivals at `rate_per_s`.
    Poisson { rate_per_s: f64 },
    /// MMPP-2: alternate hot and cold states (hot rate = `burst` × cold
    /// rate) with exponential `dwell_s` dwell, normalized so the
    /// time-averaged rate equals `rate_per_s` — same offered load as
    /// `Poisson`, different clumping.
    Bursty {
        rate_per_s: f64,
        burst: f64,
        dwell_s: f64,
    },
    /// Replay explicit arrival times (cycles from simulation start);
    /// need not be sorted — generation sorts a copy.
    Trace { arrivals_cy: Vec<u64> },
}

impl TrafficModel {
    pub fn label(&self) -> String {
        match self {
            TrafficModel::Poisson { rate_per_s } => format!("poisson({rate_per_s:.0}/s)"),
            TrafficModel::Bursty {
                rate_per_s, burst, ..
            } => format!("bursty({rate_per_s:.0}/s x{burst:.1})"),
            TrafficModel::Trace { arrivals_cy } => format!("trace({} reqs)", arrivals_cy.len()),
        }
    }
}

/// Exponential variate with the given rate (events per cycle).
fn exp_cy(rng: &mut SplitMix64, rate_per_cy: f64) -> f64 {
    -(1.0 - rng.next_f64()).ln() / rate_per_cy
}

/// Generate the sorted arrival cycles of `model` over `[0, duration_cy)`.
/// `cycle_ns` converts the wall-clock rates into cycle terms; the result
/// depends only on (model, seed, duration, cycle_ns).
pub fn arrivals(
    model: &TrafficModel,
    seed: u64,
    duration_cy: u64,
    cycle_ns: f64,
) -> Vec<u64> {
    let cy_per_s = 1e9 / cycle_ns;
    let mut rng = SplitMix64::new(seed);
    let mut out = Vec::new();
    match model {
        TrafficModel::Poisson { rate_per_s } => {
            if *rate_per_s <= 0.0 {
                return out;
            }
            let rate_per_cy = rate_per_s / cy_per_s;
            let mut t = 0.0f64;
            loop {
                t += exp_cy(&mut rng, rate_per_cy);
                if t >= duration_cy as f64 {
                    break;
                }
                out.push(t as u64);
            }
        }
        TrafficModel::Bursty {
            rate_per_s,
            burst,
            dwell_s,
        } => {
            if *rate_per_s <= 0.0 {
                return out;
            }
            let burst = burst.max(1.0);
            let dwell_cy = (dwell_s * cy_per_s).max(1.0);
            // equal expected dwell in each state: hot + cold average to
            // exactly `rate_per_s` while their ratio stays `burst`
            let hot_rate = 2.0 * burst / (burst + 1.0) * rate_per_s;
            let cold_rate = 2.0 / (burst + 1.0) * rate_per_s;
            let mut hot = rng.below(2) == 1;
            let mut t = 0.0f64;
            // exponential dwell; memorylessness lets the arrival clock
            // resample cleanly at every state switch
            let mut t_switch = exp_cy(&mut rng, 1.0 / dwell_cy);
            loop {
                let rate_per_cy = if hot { hot_rate } else { cold_rate } / cy_per_s;
                let next = t + exp_cy(&mut rng, rate_per_cy);
                if next >= t_switch {
                    t = t_switch;
                    t_switch += exp_cy(&mut rng, 1.0 / dwell_cy);
                    hot = !hot;
                    if t >= duration_cy as f64 {
                        break;
                    }
                    continue;
                }
                t = next;
                if t >= duration_cy as f64 {
                    break;
                }
                out.push(t as u64);
            }
        }
        TrafficModel::Trace { arrivals_cy } => {
            out = arrivals_cy
                .iter()
                .copied()
                .filter(|&a| a < duration_cy)
                .collect();
            out.sort_unstable();
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const CYCLE_NS: f64 = 2.0; // 500 MHz

    #[test]
    fn poisson_is_seed_deterministic() {
        let m = TrafficModel::Poisson { rate_per_s: 1000.0 };
        let a = arrivals(&m, 42, 5_000_000, CYCLE_NS);
        let b = arrivals(&m, 42, 5_000_000, CYCLE_NS);
        assert_eq!(a, b);
        let c = arrivals(&m, 43, 5_000_000, CYCLE_NS);
        assert_ne!(a, c);
    }

    #[test]
    fn poisson_rate_is_roughly_respected() {
        // 10 ms at 500 MHz = 5 M cycles; 10 k/s → ~100 arrivals
        let m = TrafficModel::Poisson { rate_per_s: 10_000.0 };
        let a = arrivals(&m, 7, 5_000_000, CYCLE_NS);
        assert!((60..=140).contains(&a.len()), "{}", a.len());
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "sorted");
        assert!(a.iter().all(|&t| t < 5_000_000));
    }

    #[test]
    fn bursty_offered_load_matches_poisson() {
        let p = TrafficModel::Poisson { rate_per_s: 10_000.0 };
        let b = TrafficModel::Bursty {
            rate_per_s: 10_000.0,
            burst: 4.0,
            dwell_s: 0.001,
        };
        let ap = arrivals(&p, 11, 25_000_000, CYCLE_NS);
        let ab = arrivals(&b, 11, 25_000_000, CYCLE_NS);
        // normalized MMPP-2: same time-averaged rate as Poisson (~500
        // arrivals over 50 ms), only the clumping differs — pin a loose
        // envelope (bursty counts have much higher variance) + sortedness
        assert!(!ab.is_empty());
        assert!(ab.len() > ap.len() / 3, "{} vs {}", ab.len(), ap.len());
        assert!(ab.len() < ap.len() * 3, "{} vs {}", ab.len(), ap.len());
        assert!(ab.windows(2).all(|w| w[0] <= w[1]), "sorted");
    }

    #[test]
    fn trace_replays_sorted_and_clipped() {
        let m = TrafficModel::Trace {
            arrivals_cy: vec![50, 10, 99, 100, 200],
        };
        assert_eq!(arrivals(&m, 0, 100, CYCLE_NS), vec![10, 50, 99]);
    }

    #[test]
    fn zero_rate_yields_no_arrivals() {
        let m = TrafficModel::Poisson { rate_per_s: 0.0 };
        assert!(arrivals(&m, 1, 1_000_000, CYCLE_NS).is_empty());
    }
}
