//! Online pool-resizing: grow or shrink a tenant's array slice when its
//! queue pressure stays across a hysteresis threshold for a full window.
//!
//! The serving loop samples every tenant's backlog at every event step
//! (the same per-event queue samples `TenantStats::peak_queue` maxes
//! over). This module turns those samples into scaling decisions:
//!
//! * **pressure windows** — a per-tenant sample deque over the last
//!   hysteresis window. A condition is *sustained* only when every
//!   retained sample meets the threshold **and** the evidence spans at
//!   least `window_cy` cycles — one spike never scales anything, and a
//!   freshly scaled tenant starts from a clean slate;
//! * **staleness** — samples land only at event-loop steps, so a tenant
//!   idle since its last dispatch would keep "reporting" its final
//!   backlog forever. [`Pressure`] therefore ages out samples older than
//!   twice the window at the event horizon *before* any sustained check
//!   reads them; without the age-out, one ancient sample both fakes the
//!   window-spanning coverage and freezes a dead backlog into the
//!   controller's view (the premature-grow regression in
//!   `tests/autoscale_regression.rs` pins the fix);
//! * **slice accounting** — a pool-wide free map of arrays not carved by
//!   any tenant. Grows free the tenant's old slice first and then take
//!   the lowest-base free run that fits (so in-place growth happens
//!   whenever the neighboring arrays are free, relocation otherwise, and
//!   arrays returned by a co-tenant's shrink coalesce and are claimable);
//!   shrinks stay at the tenant's base and return the tail;
//! * **decision trace** — every applied resize is a [`ScaleEvent`]
//!   carrying the migration price: the PCM reprogramming cycles of the
//!   moved arrays (exactly `ImaArrayPool::program_cycles_by_array` of the
//!   new plan's first pass) and how long the tenant's dispatches were
//!   blocked behind it (0-extra when the migration streams under the
//!   `--stream-weights` overlap path).
//!
//! Everything here is a pure function of seeded simulator state — no wall
//! clock — so a decision trace replays bit-identically under its seed and
//! moves only when the seed does.
//!
//! The fleet's cross-node migration controller ([`super::fleet`]) reuses
//! two pieces of this module verbatim: [`Pressure`] windows drive its
//! hot-spot detector (sampling per-node backlogs instead of per-tenant
//! ones), and a migration's price is the same apply-scale model —
//! `ImaArrayPool::program_cycles_by_array` of the destination placement's
//! first pass, charged on the destination node's timeline. In-node
//! autoscaling and cross-node migration both rewrite array ownership, so
//! in-node `--autoscale` is restricted to single-node (`--nodes 1`)
//! runs. On a multi-node `--router replica` fleet the same flag (and
//! this module's `Pressure` hysteresis) instead drives *fleet-level*
//! replica scaling: `serve::fleet` grows and shrinks the heavy tenant's
//! active replica set on sustained backlog pressure, re-water-filling
//! the pending stream at the migration price on every resize.

use std::collections::VecDeque;

/// Hysteresis thresholds and windows of the resizing controller.
#[derive(Clone, Copy, Debug)]
pub struct AutoscaleConfig {
    /// Sustained backlog ≥ this → grow the tenant's slice.
    pub hi_depth: usize,
    /// Sustained backlog ≤ this → shrink the tenant's slice.
    pub lo_depth: usize,
    /// Cycles a condition must hold before the controller acts.
    pub window_cy: u64,
    /// Cycles a tenant must wait between its own scale events.
    pub cooldown_cy: u64,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            hi_depth: 16,
            lo_depth: 0,
            // 2 ms / 6 ms at 500 MHz
            window_cy: 1_000_000,
            cooldown_cy: 3_000_000,
        }
    }
}

/// Per-tenant sliding pressure windows over the event-step depth samples.
pub struct Pressure {
    window_cy: u64,
    samples: Vec<VecDeque<(u64, usize)>>,
}

impl Pressure {
    pub fn new(n_tenants: usize, window_cy: u64) -> Pressure {
        Pressure {
            window_cy: window_cy.max(1),
            samples: vec![VecDeque::new(); n_tenants],
        }
    }

    /// Record one event-step sample (`t` nondecreasing per tenant).
    pub fn record(&mut self, tenant: usize, t: u64, depth: usize) {
        self.samples[tenant].push_back((t, depth));
    }

    /// The stale-pressure fix: drop samples older than twice the window
    /// at the event horizon `t`. A sample that old describes a backlog
    /// the tenant may long since have drained (samples only land at
    /// event steps); left in place it would both pass for coverage and
    /// pin its dead depth into every sustained check.
    pub fn age_out(&mut self, tenant: usize, t: u64) {
        let horizon = t.saturating_sub(2 * self.window_cy);
        let q = &mut self.samples[tenant];
        while q.front().is_some_and(|&(ts, _)| ts < horizon) {
            q.pop_front();
        }
    }

    /// Forget everything (after a scale event: fresh evidence required).
    pub fn clear(&mut self, tenant: usize) {
        self.samples[tenant].clear();
    }

    /// Retained sample count (regression tests watch the age-out).
    pub fn len(&self, tenant: usize) -> usize {
        self.samples[tenant].len()
    }

    fn sustained(&mut self, tenant: usize, t: u64, pred: impl Fn(usize) -> bool) -> bool {
        self.age_out(tenant, t);
        let q = &self.samples[tenant];
        let Some(&(first_ts, _)) = q.front() else {
            return false;
        };
        // coverage: the retained evidence must span a full window
        first_ts.saturating_add(self.window_cy) <= t && q.iter().all(|&(_, d)| pred(d))
    }

    /// Backlog ≥ `hi` for a full window ending at `t`.
    pub fn sustained_hi(&mut self, tenant: usize, t: u64, hi: usize) -> bool {
        self.sustained(tenant, t, |d| d >= hi)
    }

    /// Backlog ≤ `lo` for a full window ending at `t`.
    pub fn sustained_lo(&mut self, tenant: usize, t: u64, lo: usize) -> bool {
        self.sustained(tenant, t, |d| d <= lo)
    }
}

/// Grow or shrink, as recorded in the decision trace.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleKind {
    Grow,
    Shrink,
}

impl ScaleKind {
    pub fn label(&self) -> &'static str {
        match self {
            ScaleKind::Grow => "grow",
            ScaleKind::Shrink => "shrink",
        }
    }
}

/// One applied resize: the slice move plus its migration price. `Copy`,
/// deliberately: the execution trace records the same value the
/// controller commits ([`serve::trace`](super::trace) renders it as an
/// instant event on the tenant's control track).
#[derive(Clone, Copy, Debug)]
pub struct ScaleEvent {
    pub tenant: usize,
    /// Event-loop instant the resize was applied (cycles).
    pub t: u64,
    pub kind: ScaleKind,
    pub from_base: usize,
    pub from_arrays: usize,
    pub to_base: usize,
    pub to_arrays: usize,
    /// PCM reprogramming charged for the moved arrays (the new plan's
    /// first-pass `program_cycles_by_array` total).
    pub program_cycles: u64,
    /// How long the tenant's own dispatches were floored behind the
    /// migration (0 when the reprogramming streams under compute).
    pub blocked_cycles: u64,
    /// Migration rode the `--stream-weights` overlap path.
    pub streamed: bool,
}

/// What the controller wants for a tenant at this instant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleDecision {
    Grow { target: usize },
    Shrink { target: usize },
}

/// The resizing controller: pressure windows + the pool free map +
/// per-tenant cooldowns + the decision trace.
pub struct Autoscaler {
    pub cfg: AutoscaleConfig,
    pressure: Pressure,
    /// `free[a]` — pool array `a` is carved by no tenant.
    free: Vec<bool>,
    cooldown_until: Vec<u64>,
    pub events: Vec<ScaleEvent>,
}

impl Autoscaler {
    /// `slices` are the initially carved `(array_base, arrays)` spans.
    pub fn new(cfg: AutoscaleConfig, n_arrays: usize, slices: &[(usize, usize)]) -> Autoscaler {
        let mut free = vec![true; n_arrays];
        for &(base, len) in slices {
            for f in &mut free[base..base + len] {
                debug_assert!(*f, "initial slices overlap");
                *f = false;
            }
        }
        Autoscaler {
            cfg,
            pressure: Pressure::new(slices.len(), cfg.window_cy),
            free,
            cooldown_until: vec![0; slices.len()],
            events: Vec::new(),
        }
    }

    /// Feed one event-step backlog sample.
    pub fn record(&mut self, tenant: usize, t: u64, depth: usize) {
        self.pressure.record(tenant, t, depth);
    }

    pub fn pressure_mut(&mut self) -> &mut Pressure {
        &mut self.pressure
    }

    /// Evaluate one tenant's hysteresis state at instant `t`. Growing
    /// takes priority; a tenant in cooldown (or with nothing sustained)
    /// gets `None`. Pure read apart from sample aging.
    pub fn decide(&mut self, tenant: usize, t: u64, cur_arrays: usize) -> Option<ScaleDecision> {
        if t < self.cooldown_until[tenant] {
            return None;
        }
        let step = (cur_arrays / 2).max(1);
        if self.pressure.sustained_hi(tenant, t, self.cfg.hi_depth) {
            return Some(ScaleDecision::Grow {
                target: cur_arrays + step,
            });
        }
        if cur_arrays > 1 && self.pressure.sustained_lo(tenant, t, self.cfg.lo_depth) {
            return Some(ScaleDecision::Shrink {
                target: cur_arrays - step,
            });
        }
        None
    }

    /// Return a slice to the free map.
    pub fn release(&mut self, base: usize, len: usize) {
        for f in &mut self.free[base..base + len] {
            debug_assert!(!*f, "double free of a pool array");
            *f = true;
        }
    }

    /// Carve a slice out of the free map.
    pub fn reserve(&mut self, base: usize, len: usize) {
        for f in &mut self.free[base..base + len] {
            debug_assert!(*f, "reserving a carved pool array");
            *f = false;
        }
    }

    /// Lowest-base maximal free run of length ≥ `min_len`, clipped to
    /// `want`. Does not reserve — callers reserve what the re-placed
    /// plan actually uses.
    pub fn find_run(&self, min_len: usize, want: usize) -> Option<(usize, usize)> {
        let mut a = 0;
        while a < self.free.len() {
            if self.free[a] {
                let mut end = a;
                while end < self.free.len() && self.free[end] {
                    end += 1;
                }
                let len = end - a;
                if len >= min_len {
                    return Some((a, len.min(want)));
                }
                a = end;
            } else {
                a += 1;
            }
        }
        None
    }

    /// Free arrays currently carved by nobody.
    pub fn free_arrays(&self) -> usize {
        self.free.iter().filter(|&&f| f).count()
    }

    /// Record an applied resize: trace it, clear the tenant's samples
    /// (fresh evidence required) and start its cooldown.
    pub fn committed(&mut self, ev: ScaleEvent) {
        self.pressure.clear(ev.tenant);
        self.cooldown_until[ev.tenant] = ev.t.saturating_add(self.cfg.cooldown_cy);
        self.events.push(ev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(hi: usize, lo: usize, window: u64, cooldown: u64) -> AutoscaleConfig {
        AutoscaleConfig {
            hi_depth: hi,
            lo_depth: lo,
            window_cy: window,
            cooldown_cy: cooldown,
        }
    }

    #[test]
    fn sustained_needs_a_full_window_of_evidence() {
        let mut p = Pressure::new(1, 1_000);
        p.record(0, 5_000, 20);
        // one fresh sample: no coverage yet
        assert!(!p.sustained_hi(0, 5_000, 10));
        p.record(0, 5_400, 25);
        p.record(0, 6_100, 30);
        // evidence now spans ≥ window (5_000 + 1_000 ≤ 6_100)
        assert!(p.sustained_hi(0, 6_100, 10));
        // one low sample inside the window breaks the streak
        p.record(0, 6_200, 3);
        assert!(!p.sustained_hi(0, 6_200, 10));
    }

    #[test]
    fn stale_samples_age_out_at_the_horizon() {
        // the latent bug this pins: a tenant idle since its last dispatch
        // keeps its old backlog on record; without aging, that ancient
        // sample fakes window coverage and a single fresh burst sample
        // "sustains" immediately
        let mut p = Pressure::new(1, 1_000_000);
        p.record(0, 0, 50); // ancient high-water sample
        p.record(0, 10_000_000, 60); // burst begins much later
        assert_eq!(p.len(0), 2);
        // aged at the horizon: the ancient sample is gone, coverage fails,
        // nothing fires on the first burst event
        assert!(!p.sustained_hi(0, 10_000_000, 10));
        assert_eq!(p.len(0), 1, "ancient sample aged out");
        // the burst must genuinely span the window before firing
        p.record(0, 10_400_000, 55);
        assert!(!p.sustained_hi(0, 10_400_000, 10));
        p.record(0, 11_100_000, 70);
        assert!(p.sustained_hi(0, 11_100_000, 10));
    }

    #[test]
    fn decide_honors_hysteresis_and_cooldown() {
        let mut a = Autoscaler::new(cfg(10, 0, 1_000, 100_000), 8, &[(0, 4)]);
        for t in [0u64, 400, 1_100] {
            a.record(0, t, 20);
        }
        assert_eq!(a.decide(0, 1_100, 4), Some(ScaleDecision::Grow { target: 6 }));
        // an applied event clears the evidence and starts the cooldown
        a.committed(ScaleEvent {
            tenant: 0,
            t: 1_100,
            kind: ScaleKind::Grow,
            from_base: 0,
            from_arrays: 4,
            to_base: 0,
            to_arrays: 6,
            program_cycles: 10,
            blocked_cycles: 10,
            streamed: false,
        });
        a.record(0, 1_200, 20);
        a.record(0, 2_300, 20);
        assert_eq!(a.decide(0, 2_300, 6), None, "cooldown holds");
        assert_eq!(a.events.len(), 1);
    }

    #[test]
    fn shrink_fires_on_sustained_idle_but_never_below_one() {
        let mut a = Autoscaler::new(cfg(10, 0, 1_000, 0), 8, &[(0, 4), (4, 1)]);
        for t in [0u64, 500, 1_200] {
            a.record(0, t, 0);
            a.record(1, t, 0);
        }
        assert_eq!(a.decide(0, 1_200, 4), Some(ScaleDecision::Shrink { target: 2 }));
        assert_eq!(a.decide(1, 1_200, 1), None, "one array is the floor");
    }

    #[test]
    fn free_runs_coalesce_and_first_fit_allocates() {
        let mut a = Autoscaler::new(cfg(10, 0, 1, 0), 12, &[(0, 4), (4, 3)]);
        assert_eq!(a.free_arrays(), 5);
        assert_eq!(a.find_run(4, 6), Some((7, 5)));
        assert_eq!(a.find_run(6, 6), None);
        // tenant 1 shrinks: its tail returns and coalesces with the pool
        // tail into one run a co-tenant can claim
        a.release(5, 2);
        assert_eq!(a.find_run(6, 9), Some((5, 7)));
        a.reserve(5, 6);
        assert_eq!(a.free_arrays(), 1);
        assert_eq!(a.find_run(1, 4), Some((11, 1)));
    }
}
