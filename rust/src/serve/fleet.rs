//! Fleet-scale sharding: N heterogeneous IMC clusters behind a routing
//! front-end, under one deterministic event loop.
//!
//! Each node is a complete single-cluster simulator — its own array
//! count ([`FleetConfig::node_arrays`]), `ResourceTimeline` pool, plan
//! cache, and `EventQueue` — embodied by `serve::NodeSim`, the factored
//! setup/step/report of `serve::simulate_traced`. The fleet loop holds N
//! of them and repeatedly steps **the node whose earliest stored event
//! instant is globally smallest, ties toward the lower node id**. That
//! is the whole ordering contract, and it is weaker than it looks:
//! stored instants are lower bounds, so a node may dispatch *later* than
//! another node's pending event. This is harmless — nodes share no
//! resources, so each node's dispatch table is a function of its own
//! routed arrival stream alone and is invariant under interleaving. The
//! global order only pins *when* the migration controller samples
//! backlogs, which makes migrations (and therefore everything) a pure
//! function of the seed: two runs with the same seed and flags produce
//! byte-identical fleet reports, per-node tables, and traces.
//!
//! ## Router policies
//!
//! Routing is per *tenant* (a model and its arrival stream), decided up
//! front from the globally generated seeded streams — the same
//! `seed + (i+1)·φ` per-tenant seeds as a single-cluster run, so the
//! offered load is identical no matter how it is sharded:
//!
//! - **`hash`** — consistent hashing: FNV-1a over 32 virtual points per
//!   node; a tenant lives on the first ring point at or after its name's
//!   hash. Stateless and minimally disruptive as nodes come and go, but
//!   load-blind: a hot tenant pins its whole stream to one node.
//! - **`least-loaded`** — offered-load-aware placement (heaviest tenant
//!   first, each to the node minimizing projected load per array), plus
//!   an *online* migration controller: the heaviest tenant holds standby
//!   replicas on every node, and when its owner's backlog sustains above
//!   `hot_factor × coldest + hot_margin` over a pressure window
//!   (`serve::autoscale::Pressure`, the PR 6 hysteresis machinery), its
//!   pending stream migrates to the coldest node for the migration price
//!   below.
//! - **`replica`** — the heaviest tenant is resident on *every* node and
//!   its stream is split per-arrival to the node with the earliest
//!   projected finish (a virtual-finish-time water-fill over probed
//!   single-request service cycles); all other tenants route by the hash
//!   ring. With `--autoscale` the split is *online* instead: the stream
//!   starts on the ring owner alone and the fleet controller grows or
//!   shrinks the **active replica set** on sustained heavy-tenant
//!   backlog pressure (the same `Pressure` hysteresis, thresholds from
//!   `ServeConfig::autoscale_cfg`), re-water-filling the pending stream
//!   over the new active set at the migration price on every resize
//!   ([`FleetReport::replica_scales`]).
//!
//! ## Fault injection and self-healing
//!
//! [`FleetConfig::faults`] carries a [`FaultPlan`](super::faults) — a
//! seeded, deterministic schedule of crash / drain / degrade /
//! array-failure events (`imcc serve --faults SPEC`, grammar in
//! `serve::faults`). The fleet loop interleaves the plan with node
//! events: a fault due at or before the globally smallest stored node
//! instant applies first (ties: the fault wins), so the whole chaos
//! timeline stays a pure function of the seed. Self-healing is layered
//! at the loop:
//!
//! - **crash** — the node's in-flight batches are revoked exactly and
//!   counted `lost_in_crash`; its queued streams fail over to survivors
//!   through the router re-resolution below, each re-spliced at the full
//!   migration price. With a scheduled recovery, arrivals past the
//!   recovery instant are *parked* at the fleet and returned to the home
//!   node when it rejoins (PCM reprogramming before traffic — a staged
//!   rejoin).
//! - **drain / update** — graceful: in-flight completes, queued streams
//!   fail over, the node stops. An `update` rejoin additionally
//!   reprograms every resident tenant (the rolling-model-update step);
//!   [`FaultPlan::rolling_update`](super::faults::FaultPlan::rolling_update)
//!   staggers one per node so at most one node is ever out.
//! - **router re-resolution** — hash fleets rebuild the ring over
//!   survivors only, keyed by the *original* node ids, so a recovered
//!   node slots back into exactly its old arcs; least-loaded fleets
//!   re-assign by capacity-weighted backlog argmin; replica fleets
//!   re-water-fill the heavy stream over surviving replicas. When a
//!   plan is armed, every node holds a standby copy of every tenant so
//!   any survivor is a valid failover target (this changes placement,
//!   so bit-identity to the healthy fleet is only promised for an
//!   *empty* plan, not a never-firing one).
//! - **accounting** — failed-over and parked-returned requests are
//!   `retried` (each exactly once); crash-revoked requests leave the
//!   dead node's ledger and land in `lost_in_crash`, so per-node
//!   conservation (`served + dropped + rejected == arrivals`) still
//!   holds verbatim and fleet-wide the law extends to
//!   `served + dropped + rejected + lost_in_crash == offered`.
//!   Per-node downtime (clamped to the arrival horizon) folds into
//!   [`FleetFaultOutcome::availability`].
//!
//! ## Migration cost accounting
//!
//! A cross-node move charges exactly what the PR 6 autoscaler's
//! `apply_scale` charges an in-pool slice move — PCM reprogramming of
//! every array the tenant's resident plan (first pass) touches,
//! serialized on the *destination's* `RES_PROG` port and chained after
//! whatever already occupies the destination arrays — **plus** a trace
//! hand-off charge on the destination's DMA port
//! ([`FleetMigrationConfig::handoff_cy_per_req`] per moved request),
//! since the pending stream's state has to cross nodes. Programming
//! energy lands on the tenant's destination-node ledger. With
//! `--stream-weights` the whole tail rides the overlap path and the
//! tenant's dispatch floor stays put; otherwise the floor moves past it
//! (`blocked_cycles`). Every migration is reported in
//! [`FleetReport::migrations`] with its independently recomputable
//! price — `tests/fleet_regression.rs` re-derives `program_cycles` from
//! the placement and `ImaArrayPool::program_cycles_by_array`. Failover
//! and rejoin hand-offs are priced identically (a migration the tenant
//! did not ask for); a rejoin's hand-off charge is zero, since the
//! parked stream never left the fleet controller.
//!
//! `--nodes 1` (any router) degenerates to a single node owning every
//! tenant in global order with its original streams, no standby copies
//! and no migration controller — pinned bit-identical to the pre-fleet
//! single-cluster path on dispatch tables, serve JSON, and trace bytes.

use std::collections::BTreeMap;

use crate::arch::{PowerModel, SystemConfig};
use crate::coordinator::{BatchConfig, PlanCache};
use crate::net::Network;
use crate::util::json::{obj, Json};
use crate::util::table::{f, Table};

use super::autoscale::Pressure;
use super::faults::{FaultKind, FaultPlan};
use super::metrics::LogHistogram;
use super::tenancy::place_tenants;
use super::trace::TraceRecorder;
use super::{traffic, ModelTraffic, NodeSim, ServeConfig, ServeReport};

/// Virtual ring points per node — enough that a 4-node ring's arcs are
/// reasonably even without making ring construction measurable.
const VNODES: usize = 32;

/// How the front-end assigns tenants (and their arrival streams) to
/// nodes. See the module docs for the semantics of each policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouterPolicy {
    /// Consistent hashing by tenant name over a virtual-node ring.
    Hash,
    /// Offered-load-aware placement plus online hot-spot migration.
    LeastLoaded,
    /// Heaviest tenant replicated on all nodes, stream split
    /// per-arrival; everything else hash-routed.
    Replica,
}

impl RouterPolicy {
    pub fn parse(s: &str) -> Result<RouterPolicy, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "hash" | "consistent-hash" => Ok(RouterPolicy::Hash),
            "least-loaded" | "ll" => Ok(RouterPolicy::LeastLoaded),
            "replica" => Ok(RouterPolicy::Replica),
            other => Err(format!(
                "unknown router `{other}` (hash|least-loaded|replica)"
            )),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            RouterPolicy::Hash => "hash",
            RouterPolicy::LeastLoaded => "least-loaded",
            RouterPolicy::Replica => "replica",
        }
    }
}

/// Knobs of the least-loaded router's online migration controller. The
/// pressure window/cooldown defaults mirror `AutoscaleConfig` so the
/// two controllers breathe at the same rate.
#[derive(Clone, Copy, Debug)]
pub struct FleetMigrationConfig {
    /// Migrate when `owner backlog ≥ hot_factor × coldest + hot_margin`…
    pub hot_factor: u64,
    /// …with the additive margin keeping tiny backlogs from thrashing.
    pub hot_margin: u64,
    /// The imbalance must sustain for a full window (cycles).
    pub window_cy: u64,
    /// Minimum spacing between migrations (cycles).
    pub cooldown_cy: u64,
    /// Hand-off DMA charge per moved pending request (cycles).
    pub handoff_cy_per_req: u64,
}

impl Default for FleetMigrationConfig {
    fn default() -> Self {
        FleetMigrationConfig {
            hot_factor: 2,
            hot_margin: 8,
            window_cy: 1_000_000,
            cooldown_cy: 3_000_000,
            handoff_cy_per_req: 512,
        }
    }
}

/// Fleet topology and routing configuration; per-node serving knobs
/// (policy, window, seed, …) come from the shared [`ServeConfig`].
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Number of nodes (≥ 1).
    pub nodes: usize,
    pub router: RouterPolicy,
    /// Per-node array counts (heterogeneous fleet). Empty = every node
    /// gets the shared `ServeConfig::n_arrays`.
    pub node_arrays: Vec<usize>,
    pub migration: FleetMigrationConfig,
    /// Deterministic fault schedule (`--faults` / `--fault-seed`).
    /// Empty = the healthy fleet, bit-identical to a run with no plan.
    pub faults: FaultPlan,
}

impl FleetConfig {
    pub fn new(nodes: usize, router: RouterPolicy) -> FleetConfig {
        FleetConfig {
            nodes,
            router,
            node_arrays: Vec::new(),
            migration: FleetMigrationConfig::default(),
            faults: FaultPlan::none(),
        }
    }
}

/// Parse a `--node-arrays A,B,..` list against the `--nodes` count,
/// naming the offending entry (1-based) or the disagreeing lengths.
pub fn parse_node_arrays(s: &str, nodes: usize) -> Result<Vec<usize>, String> {
    let entries: Vec<&str> = s.split(',').collect();
    if entries.len() != nodes {
        return Err(format!(
            "--node-arrays lists {} array counts but --nodes says {nodes} — the lists disagree",
            entries.len()
        ));
    }
    let mut out = Vec::with_capacity(entries.len());
    for (ix, e) in entries.iter().enumerate() {
        match e.trim().parse::<usize>() {
            Ok(v) if v >= 1 => out.push(v),
            _ => {
                return Err(format!(
                    "--node-arrays entry {} of {} (`{}`) is not an array count (integer ≥ 1)",
                    ix + 1,
                    entries.len(),
                    e.trim()
                ))
            }
        }
    }
    Ok(out)
}

/// One executed cross-node migration, with its independently
/// recomputable price (see the module docs).
#[derive(Clone, Debug)]
pub struct FleetMigration {
    pub tenant: String,
    pub from_node: usize,
    pub to_node: usize,
    /// Fleet-clock instant the move was decided and charged (cycles).
    pub t: u64,
    /// Pending requests handed off.
    pub moved: usize,
    /// PCM reprogramming on the destination (sum over touched arrays).
    pub program_cycles: u64,
    /// DMA hand-off charge (`moved × handoff_cy_per_req`).
    pub handoff_cycles: u64,
    /// How far past `t` the tenant's dispatch floor moved (0 when the
    /// price streamed under compute).
    pub blocked_cycles: u64,
    pub streamed: bool,
}

/// One fault-plan event as it fired (fleet clock, node, kind label).
#[derive(Clone, Debug)]
pub struct FaultRecord {
    pub t: u64,
    pub node: usize,
    pub label: &'static str,
}

/// One failover hand-off (or parked-stream rejoin) with its migration
/// price — the chaos counterpart of [`FleetMigration`].
#[derive(Clone, Debug)]
pub struct FailoverRecord {
    pub tenant: String,
    pub from_node: usize,
    pub to_node: usize,
    pub t: u64,
    /// Requests re-spliced (each counts once toward `retried`).
    pub moved: usize,
    pub program_cycles: u64,
    pub handoff_cycles: u64,
    pub blocked_cycles: u64,
    /// `true` for a parked stream returning to its recovered home node
    /// (`from_node == to_node`), `false` for a survivor hand-off.
    pub rejoin: bool,
}

/// One fleet-level replica-set resize (the `--autoscale` + `--router
/// replica` controller): the active set grew onto / shrank off `node`,
/// re-water-filling `moved` pending heavy requests.
#[derive(Clone, Debug)]
pub struct ReplicaScale {
    pub t: u64,
    pub grow: bool,
    pub node: usize,
    /// Pending heavy requests re-spliced across the new active set.
    pub moved: usize,
    /// Active replicas after the resize.
    pub active_after: usize,
}

/// The chaos ledger of a faulted run: every fault as it fired, every
/// failover with its price, the conservation tallies, and per-node
/// downtime. Present in [`FleetReport`] only when a plan was armed, so
/// healthy runs stay byte-identical.
#[derive(Clone, Debug)]
pub struct FleetFaultOutcome {
    pub events: Vec<FaultRecord>,
    pub failovers: Vec<FailoverRecord>,
    /// Requests re-spliced by failover or rejoin, each exactly once.
    pub retried: u64,
    /// Requests revoked in-flight by crashes, plus queued requests with
    /// no surviving node to fail over to.
    pub lost_in_crash: u64,
    /// Per-node down cycles, clamped to `[0, horizon_cy]`.
    pub downtime_cy: Vec<u64>,
    /// Per-node PCM arrays permanently failed (`arrayfail` events).
    pub arrays_lost: Vec<usize>,
    /// The arrival horizon the availability ratio is taken over.
    pub horizon_cy: u64,
}

impl FleetFaultOutcome {
    /// `1 − Σ downtime / (nodes × horizon)`: the fraction of node-time
    /// the fleet had live. Strictly below 1.0 whenever any node spent
    /// down-time inside the horizon.
    pub fn availability(&self) -> f64 {
        let n = self.downtime_cy.len();
        if n == 0 || self.horizon_cy == 0 {
            return 1.0;
        }
        let down: u64 = self.downtime_cy.iter().sum();
        1.0 - down as f64 / (n as f64 * self.horizon_cy as f64)
    }

    pub fn to_json(&self) -> Json {
        let events: Vec<Json> = self
            .events
            .iter()
            .map(|e| {
                obj([
                    ("t_cycles", (e.t as f64).into()),
                    ("node", e.node.into()),
                    ("label", e.label.into()),
                ])
            })
            .collect();
        let failovers: Vec<Json> = self
            .failovers
            .iter()
            .map(|m| {
                obj([
                    ("tenant", m.tenant.as_str().into()),
                    ("from_node", m.from_node.into()),
                    ("to_node", m.to_node.into()),
                    ("t_cycles", (m.t as f64).into()),
                    ("moved", m.moved.into()),
                    ("program_cycles", (m.program_cycles as f64).into()),
                    ("handoff_cycles", (m.handoff_cycles as f64).into()),
                    ("blocked_cycles", (m.blocked_cycles as f64).into()),
                    ("rejoin", m.rejoin.into()),
                ])
            })
            .collect();
        obj([
            ("events", Json::Arr(events)),
            ("failovers", Json::Arr(failovers)),
            ("retried", (self.retried as f64).into()),
            ("lost_in_crash", (self.lost_in_crash as f64).into()),
            (
                "downtime_cy",
                Json::Arr(self.downtime_cy.iter().map(|&d| (d as f64).into()).collect()),
            ),
            (
                "arrays_lost",
                Json::Arr(self.arrays_lost.iter().map(|&d| d.into()).collect()),
            ),
            ("availability", self.availability().into()),
            ("horizon_cy", (self.horizon_cy as f64).into()),
        ])
    }
}

/// One node's slice of the fleet: its id, pool size, and complete
/// single-cluster [`ServeReport`].
#[derive(Clone, Debug)]
pub struct NodeReport {
    pub node: usize,
    pub arrays: usize,
    pub report: ServeReport,
}

/// The fleet run's outcome: per-node reports plus the migration log.
/// Aggregates (arrival conservation, merged latency percentiles) are
/// derived, never stored, so they cannot drift from the per-node truth.
#[derive(Clone, Debug)]
pub struct FleetReport {
    pub router: RouterPolicy,
    pub nodes_n: usize,
    pub seed: u64,
    pub cycle_ns: f64,
    pub nodes: Vec<NodeReport>,
    pub migrations: Vec<FleetMigration>,
    /// Fleet-level replica resizes (`--autoscale --router replica`);
    /// empty (and absent from JSON) otherwise.
    pub replica_scales: Vec<ReplicaScale>,
    /// The chaos ledger — `Some` exactly when a fault plan was armed,
    /// so healthy tables and JSON stay byte-identical.
    pub faults: Option<FleetFaultOutcome>,
}

impl FleetReport {
    /// Offered load summed over every node's tenant ledger. Migration
    /// moves a request's ledger entry with it, so this equals the
    /// globally generated arrival count exactly — less
    /// `lost_in_crash` when faults revoked or stranded requests.
    pub fn total_arrivals(&self) -> u64 {
        self.nodes
            .iter()
            .flat_map(|n| n.report.tenants.iter())
            .map(|t| t.arrivals)
            .sum()
    }

    pub fn total_served(&self) -> u64 {
        self.nodes.iter().map(|n| n.report.total_served()).sum()
    }

    pub fn total_dropped(&self) -> u64 {
        self.nodes.iter().map(|n| n.report.total_dropped()).sum()
    }

    pub fn total_rejected(&self) -> u64 {
        self.nodes.iter().map(|n| n.report.total_rejected()).sum()
    }

    /// Fleet makespan: the last node to drain.
    pub fn makespan_cycles(&self) -> u64 {
        self.nodes
            .iter()
            .map(|n| n.report.makespan_cycles)
            .max()
            .unwrap_or(0)
    }

    /// End-to-end latency over *all* served requests fleet-wide: the
    /// per-tenant histograms merged bin-wise ([`LogHistogram::merge`]),
    /// exactly what one histogram over the union would report.
    pub fn merged_latency(&self) -> LogHistogram {
        let mut h = LogHistogram::new();
        for n in &self.nodes {
            for t in &n.report.tenants {
                h.merge(&t.latency);
            }
        }
        h
    }

    /// Fleet throughput over the fleet makespan, inferences/s.
    pub fn inferences_per_s(&self) -> f64 {
        let makespan_s = self.makespan_cycles() as f64 * self.cycle_ns * 1e-9;
        if makespan_s > 0.0 {
            self.total_served() as f64 / makespan_s
        } else {
            0.0
        }
    }

    fn ms(&self, cy: u64) -> f64 {
        cy as f64 * self.cycle_ns * 1e-6
    }

    /// The fleet summary table the CLI prints above the per-node
    /// tables: one row per node plus the fleet totals and the migration
    /// log. Byte-identical across runs with the same seed.
    pub fn render_table(&self) -> String {
        let merged = self.merged_latency();
        let (p50, p95, p99) = merged.percentiles();
        let title = format!(
            "fleet — {} nodes, {} router, seed {:#x}, p50/p95/p99 {}/{}/{} ms",
            self.nodes_n,
            self.router.label(),
            self.seed,
            f(self.ms(p50), 3),
            f(self.ms(p95), 3),
            f(self.ms(p99), 3),
        );
        let mut t = Table::new(
            &title,
            &[
                "node", "arrays", "tenants", "arrivals", "served", "dropped", "rejected",
                "p95 ms", "util",
            ],
        );
        for nr in &self.nodes {
            let mut h = LogHistogram::new();
            for ten in &nr.report.tenants {
                h.merge(&ten.latency);
            }
            let node_arrivals: u64 = nr.report.tenants.iter().map(|s| s.arrivals).sum();
            t.row([
                nr.node.to_string(),
                nr.arrays.to_string(),
                nr.report.tenants.len().to_string(),
                node_arrivals.to_string(),
                nr.report.total_served().to_string(),
                nr.report.total_dropped().to_string(),
                nr.report.total_rejected().to_string(),
                f(self.ms(h.quantile(0.95)), 3),
                format!("{:.0}%", nr.report.utilization() * 100.0),
            ]);
        }
        let mut out = t.render();
        out.push_str(&format!(
            "fleet totals: {} arrivals, {} served, {} dropped, {} rejected, {:.1} inf/s\n",
            self.total_arrivals(),
            self.total_served(),
            self.total_dropped(),
            self.total_rejected(),
            self.inferences_per_s(),
        ));
        if !self.migrations.is_empty() {
            out.push_str(&format!("migrations: {}\n", self.migrations.len()));
            for m in &self.migrations {
                out.push_str(&format!(
                    "  {} node{} -> node{} @{}: {} reqs, {} prog cy, {} handoff cy, {} blocked{}\n",
                    m.tenant,
                    m.from_node,
                    m.to_node,
                    m.t,
                    m.moved,
                    m.program_cycles,
                    m.handoff_cycles,
                    m.blocked_cycles,
                    if m.streamed { " (streamed)" } else { "" },
                ));
            }
        }
        if !self.replica_scales.is_empty() {
            out.push_str(&format!(
                "replica scale events: {}\n",
                self.replica_scales.len()
            ));
            for s in &self.replica_scales {
                out.push_str(&format!(
                    "  {} node{} @{}: {} pending re-filled, {} active\n",
                    if s.grow { "grow" } else { "shrink" },
                    s.node,
                    s.t,
                    s.moved,
                    s.active_after,
                ));
            }
        }
        if let Some(fo) = &self.faults {
            out.push_str(&format!(
                "faults: {} events, {} failovers, {} retried, {} lost in crash, \
                 availability {:.4}\n",
                fo.events.len(),
                fo.failovers.len(),
                fo.retried,
                fo.lost_in_crash,
                fo.availability(),
            ));
            for e in &fo.events {
                out.push_str(&format!("  {} node{} @{}\n", e.label, e.node, e.t));
            }
            for fv in &fo.failovers {
                out.push_str(&format!(
                    "  {} {} node{} -> node{} @{}: {} reqs, {} prog cy, {} handoff cy, \
                     {} blocked\n",
                    if fv.rejoin { "rejoin" } else { "failover" },
                    fv.tenant,
                    fv.from_node,
                    fv.to_node,
                    fv.t,
                    fv.moved,
                    fv.program_cycles,
                    fv.handoff_cycles,
                    fv.blocked_cycles,
                ));
            }
            let down: Vec<String> = fo
                .downtime_cy
                .iter()
                .enumerate()
                .map(|(ix, &d)| format!("node{ix} {d}"))
                .collect();
            out.push_str(&format!("downtime cy: {}\n", down.join(", ")));
        }
        out
    }

    /// Machine-readable fleet report: the aggregates, the migration
    /// log, and every node's full single-cluster JSON under `nodes[]`.
    /// The `faults` and `replica_scales` keys appear only when their
    /// machinery ran, keeping healthy output byte-identical.
    pub fn to_json(&self) -> Json {
        let merged = self.merged_latency();
        let (p50, p95, p99) = merged.percentiles();
        let migrations: Vec<Json> = self
            .migrations
            .iter()
            .map(|m| {
                obj([
                    ("tenant", m.tenant.as_str().into()),
                    ("from_node", m.from_node.into()),
                    ("to_node", m.to_node.into()),
                    ("t_cycles", (m.t as f64).into()),
                    ("moved", m.moved.into()),
                    ("program_cycles", (m.program_cycles as f64).into()),
                    ("handoff_cycles", (m.handoff_cycles as f64).into()),
                    ("blocked_cycles", (m.blocked_cycles as f64).into()),
                    ("streamed", m.streamed.into()),
                ])
            })
            .collect();
        let nodes: Vec<Json> = self
            .nodes
            .iter()
            .map(|nr| {
                obj([
                    ("node", nr.node.into()),
                    ("arrays", nr.arrays.into()),
                    ("report", nr.report.to_json()),
                ])
            })
            .collect();
        let mut root = obj([
            ("router", self.router.label().into()),
            ("nodes_n", self.nodes_n.into()),
            ("seed", format!("{:#x}", self.seed).into()),
            (
                "fleet",
                obj([
                    ("arrivals", (self.total_arrivals() as f64).into()),
                    ("served", (self.total_served() as f64).into()),
                    ("dropped", (self.total_dropped() as f64).into()),
                    ("rejected", (self.total_rejected() as f64).into()),
                    ("p50_ms", self.ms(p50).into()),
                    ("p95_ms", self.ms(p95).into()),
                    ("p99_ms", self.ms(p99).into()),
                    ("makespan_cycles", (self.makespan_cycles() as f64).into()),
                    ("inf_per_s", self.inferences_per_s().into()),
                    ("migrations", Json::Arr(migrations)),
                ]),
            ),
            ("nodes", Json::Arr(nodes)),
        ]);
        if let Json::Obj(m) = &mut root {
            if !self.replica_scales.is_empty() {
                let scales: Vec<Json> = self
                    .replica_scales
                    .iter()
                    .map(|s| {
                        obj([
                            ("t_cycles", (s.t as f64).into()),
                            ("kind", if s.grow { "grow" } else { "shrink" }.into()),
                            ("node", s.node.into()),
                            ("moved", s.moved.into()),
                            ("active_after", s.active_after.into()),
                        ])
                    })
                    .collect();
                m.insert("replica_scales".to_string(), Json::Arr(scales));
            }
            if let Some(fo) = &self.faults {
                m.insert("faults".to_string(), fo.to_json());
            }
        }
        root
    }
}

/// FNV-1a 64-bit — the same hash `Network::fingerprint` uses, hand
/// rolled here over a string key.
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in s.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_0001_b3);
    }
    h
}

/// The consistent-hash ring over an explicit node-id set: `VNODES`
/// points per node keyed `node{id}#{v}` — by the *original* id, so a
/// survivor ring after a failure holds exactly the full ring's points
/// minus the dead node's, and re-adding the node restores the original
/// assignment bit-for-bit. Sorted by (hash, node) so collisions
/// (astronomically unlikely) still order deterministically.
fn hash_ring_of(ids: &[usize]) -> Vec<(u64, usize)> {
    let mut pts: Vec<(u64, usize)> = ids
        .iter()
        .flat_map(|&ix| (0..VNODES).map(move |v| (fnv1a(&format!("node{ix}#{v}")), ix)))
        .collect();
    pts.sort_unstable();
    pts
}

/// The full-fleet ring: every node id in `0..n`.
fn hash_ring(n: usize) -> Vec<(u64, usize)> {
    let ids: Vec<usize> = (0..n).collect();
    hash_ring_of(&ids)
}

/// Ring lookup: the first point at or clockwise of the name's hash
/// (wrapping to the ring's first point).
fn ring_assign(pts: &[(u64, usize)], name: &str) -> usize {
    let h = fnv1a(name);
    let ix = pts.partition_point(|&(ph, _)| ph < h);
    if ix == pts.len() {
        pts[0].1
    } else {
        pts[ix].1
    }
}

/// Offered-load-aware placement: tenants in descending arrival count
/// (ties toward the lower tenant index), each to the node minimizing
/// projected load per array — `(load + w) / cap` compared by
/// cross-multiplication so the decision is exact integer arithmetic
/// (strict inequality keeps the lower node id on ties).
fn least_loaded_assign(arrival_counts: &[usize], caps: &[usize]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..arrival_counts.len()).collect();
    order.sort_by(|&a, &b| {
        arrival_counts[b]
            .cmp(&arrival_counts[a])
            .then(a.cmp(&b))
    });
    let mut load = vec![0u64; caps.len()];
    let mut owner = vec![0usize; arrival_counts.len()];
    for ti in order {
        let w = arrival_counts[ti] as u64;
        let mut best = 0usize;
        for cand in 1..caps.len() {
            if (load[cand] + w) as u128 * caps[best] as u128
                < (load[best] + w) as u128 * caps[cand] as u128
            {
                best = cand;
            }
        }
        load[best] += w;
        owner[ti] = best;
    }
    owner
}

/// What the fleet loop does when a compiled fault fires.
enum FaultAction {
    Crash { recover_at: Option<u64> },
    Drain { rejoin_at: Option<u64>, update: bool },
    Rejoin { label: &'static str, reprogram_all: bool },
    Degrade,
    ArrayFail { arrays: usize },
}

/// One loop-ready fault instant (rejoins split out of their
/// crash/drain events so the schedule is a flat sorted list).
struct CompiledFault {
    t: u64,
    node: usize,
    action: FaultAction,
}

/// Lower a validated [`FaultPlan`] into the flat schedule the loop
/// consumes, plus the per-node arming data: which nodes need in-flight
/// tracking (a crash can strike them) and the service-stretch spans
/// (degrade windows; array failures as permanent spans whose factor
/// composes multiplicatively to `original/remaining`).
#[allow(clippy::type_complexity)]
fn compile_faults(
    plan: &FaultPlan,
    node_arrays: &[usize],
) -> Result<(Vec<CompiledFault>, Vec<bool>, Vec<Vec<(u64, u64, u64)>>), String> {
    let n = node_arrays.len();
    plan.validate(n, node_arrays)?;
    let mut events: Vec<CompiledFault> = Vec::new();
    let mut track = vec![false; n];
    let mut spans: Vec<Vec<(u64, u64, u64)>> = vec![Vec::new(); n];
    let mut remaining: Vec<u64> = node_arrays.iter().map(|&a| a as u64).collect();
    for ev in &plan.clone().sorted().events {
        match ev.kind {
            FaultKind::Crash { recover_at } => {
                track[ev.node] = true;
                events.push(CompiledFault {
                    t: ev.t,
                    node: ev.node,
                    action: FaultAction::Crash { recover_at },
                });
                if let Some(tr) = recover_at {
                    events.push(CompiledFault {
                        t: tr,
                        node: ev.node,
                        action: FaultAction::Rejoin {
                            label: "recover",
                            reprogram_all: false,
                        },
                    });
                }
            }
            FaultKind::Drain { rejoin_at, update } => {
                events.push(CompiledFault {
                    t: ev.t,
                    node: ev.node,
                    action: FaultAction::Drain { rejoin_at, update },
                });
                if let Some(tr) = rejoin_at {
                    events.push(CompiledFault {
                        t: tr,
                        node: ev.node,
                        action: FaultAction::Rejoin {
                            label: "rejoin",
                            reprogram_all: update,
                        },
                    });
                }
            }
            FaultKind::Degrade { until, percent } => {
                spans[ev.node].push((ev.t, until, percent));
                events.push(CompiledFault {
                    t: ev.t,
                    node: ev.node,
                    action: FaultAction::Degrade,
                });
            }
            FaultKind::ArrayFail { arrays } => {
                let left = remaining[ev.node] - arrays as u64; // validate: ≥ 1
                // compose with any earlier arrayfail span so the product
                // of active factors is original/remaining (rounded up)
                let percent = (remaining[ev.node] * 100).div_ceil(left);
                spans[ev.node].push((ev.t, u64::MAX, percent));
                remaining[ev.node] = left;
                events.push(CompiledFault {
                    t: ev.t,
                    node: ev.node,
                    action: FaultAction::ArrayFail { arrays },
                });
            }
        }
    }
    // a recover at `tr` and another fault at the same (t, node) must
    // apply in down-span order; the stable sort keeps the push order,
    // which emitted the earlier event's rejoin first
    events.sort_by_key(|e| (e.t, e.node));
    Ok((events, track, spans))
}

/// Pick the failover targets for one taken stream and re-splice it at
/// the migration price. Returns the primary (first) target so the
/// least-loaded migration controller can follow its heavy tenant.
#[allow(clippy::too_many_arguments)]
fn failover_stream(
    gi: usize,
    from: usize,
    t: u64,
    stream: Vec<u64>,
    router: RouterPolicy,
    heavy: usize,
    svc: &[u64],
    models: &[ModelTraffic],
    node_arrays: &[usize],
    rosters: &[Vec<usize>],
    alive: &[bool],
    active: &mut [bool],
    fleet_auto: bool,
    handoff_cy_per_req: u64,
    sims: &mut [NodeSim],
    recs: &mut [TraceRecorder],
    retried: &mut u64,
    lost: &mut u64,
    failovers: &mut Vec<FailoverRecord>,
) -> Option<usize> {
    let n = sims.len();
    let alive_ids: Vec<usize> = (0..n).filter(|&k| alive[k]).collect();
    if alive_ids.is_empty() {
        // nowhere to go: the stream already left the dead node's ledger
        *lost += stream.len() as u64;
        return None;
    }
    let mut shares: Vec<(usize, Vec<u64>)> = Vec::new();
    if router == RouterPolicy::Replica && gi == heavy && n > 1 {
        // water-fill over surviving replicas (the active set when the
        // fleet autoscaler runs; activate the fastest survivor if the
        // whole active set died)
        let pool: Vec<usize> = if fleet_auto {
            let act: Vec<usize> = alive_ids.iter().copied().filter(|&k| active[k]).collect();
            if act.is_empty() {
                let k = *alive_ids.iter().min_by_key(|&&k| (svc[k], k)).unwrap();
                active[k] = true;
                vec![k]
            } else {
                act
            }
        } else {
            alive_ids.clone()
        };
        let mut busy = vec![t; n];
        let mut per: Vec<Vec<u64>> = vec![Vec::new(); n];
        for &a in &stream {
            let mut best = pool[0];
            for &cand in &pool[1..] {
                if busy[cand].max(a) + svc[cand] < busy[best].max(a) + svc[best] {
                    best = cand;
                }
            }
            busy[best] = busy[best].max(a) + svc[best];
            per[best].push(a);
        }
        for (k, share) in per.iter_mut().enumerate() {
            if !share.is_empty() {
                shares.push((k, std::mem::take(share)));
            }
        }
    } else if router == RouterPolicy::LeastLoaded {
        // capacity-weighted argmin over survivors, exact integer compare
        let w = stream.len() as u64;
        let mut best = alive_ids[0];
        let mut best_b = sims[best].backlog_at(t) as u64;
        for &cand in &alive_ids[1..] {
            let cb = sims[cand].backlog_at(t) as u64;
            if (cb + w) as u128 * node_arrays[best] as u128
                < (best_b + w) as u128 * node_arrays[cand] as u128
            {
                best = cand;
                best_b = cb;
            }
        }
        shares.push((best, stream));
    } else {
        // hash router, and the replica router's ring-routed tenants:
        // rebuild the ring over survivors only (original ids — see
        // `hash_ring_of`)
        let ring = hash_ring_of(&alive_ids);
        let k = ring_assign(&ring, &models[gi].net.name);
        shares.push((k, stream));
    }
    let primary = shares.first().map(|&(k, _)| k);
    for (k, share) in shares {
        let local = rosters[k]
            .iter()
            .position(|&g| g == gi)
            .expect("chaos rosters hold every tenant on every node");
        let moved_n = share.len();
        let (pc, hc, bc) = sims[k].migrate_in(local, share, t, handoff_cy_per_req, &mut recs[k]);
        *retried += moved_n as u64;
        recs[k].failover(local, t, from, moved_n, false);
        failovers.push(FailoverRecord {
            tenant: models[gi].net.name.clone(),
            from_node: from,
            to_node: k,
            t,
            moved: moved_n,
            program_cycles: pc,
            handoff_cycles: hc,
            blocked_cycles: bc,
            rejoin: false,
        });
    }
    primary
}

/// [`simulate_fleet_traced`] with tracing off on every node.
pub fn simulate_fleet(
    models: &[ModelTraffic],
    scfg: &ServeConfig,
    fcfg: &FleetConfig,
    pm: &PowerModel,
) -> Result<FleetReport, String> {
    let mut recs: Vec<TraceRecorder> = (0..fcfg.nodes).map(|_| TraceRecorder::Off).collect();
    simulate_fleet_traced(models, scfg, fcfg, pm, &mut recs)
}

/// Run the fleet to completion: route the globally generated arrival
/// streams to nodes, step the per-node simulators under the global
/// min-event order (see the module docs), interleave the fault plan
/// with its self-healing control plane, and run the migration (least-
/// loaded) or replica-autoscale (replica + `--autoscale`) controller.
/// `recs` holds one trace recorder per node ([`TraceRecorder::Off`] for
/// no trace); per-node traces are as bit-identical to untraced runs as
/// single-cluster ones.
pub fn simulate_fleet_traced(
    models: &[ModelTraffic],
    scfg: &ServeConfig,
    fcfg: &FleetConfig,
    pm: &PowerModel,
    recs: &mut [TraceRecorder],
) -> Result<FleetReport, String> {
    let n = fcfg.nodes;
    if n == 0 {
        return Err("a fleet needs at least one node".into());
    }
    if models.is_empty() {
        return Err("no models to serve".into());
    }
    if recs.len() != n {
        return Err(format!("{} trace recorders for {n} nodes", recs.len()));
    }
    let fleet_auto = scfg.autoscale && n > 1;
    if fleet_auto && fcfg.router != RouterPolicy::Replica {
        return Err(
            "fleet-wide autoscaling grows and shrinks replicas of the heavy tenant, \
             so --autoscale with --nodes N needs --router replica; in-node autoscaling \
             (hash/least-loaded fleets) is limited to --nodes 1"
                .into(),
        );
    }
    if !fcfg.node_arrays.is_empty() && fcfg.node_arrays.len() != n {
        return Err(format!(
            "--node-arrays lists {} nodes, --nodes says {n}",
            fcfg.node_arrays.len()
        ));
    }
    let node_arrays: Vec<usize> = if fcfg.node_arrays.is_empty() {
        vec![scfg.n_arrays; n]
    } else {
        fcfg.node_arrays.clone()
    };
    for (ix, &na) in node_arrays.iter().enumerate() {
        if na == 0 {
            return Err(format!("node {ix} has no arrays"));
        }
        if scfg.headroom >= na {
            return Err(format!(
                "headroom {} leaves node {ix} no arrays to carve (node has {na})",
                scfg.headroom
            ));
        }
    }
    let chaos = !fcfg.faults.is_empty();
    let (fault_events, track_inflight, degrade_spans) = if chaos {
        compile_faults(&fcfg.faults, &node_arrays)?
    } else {
        (Vec::new(), vec![false; n], vec![Vec::new(); n])
    };

    // the globally generated seeded streams — identical offered load to
    // a single-cluster run, however it is sharded (the per-tenant seed
    // depends only on the global tenant index; cycle_ns is frequency-
    // derived and frequency does not vary with the array count)
    let cfg_global = SystemConfig::scaled_up(scfg.n_arrays);
    let cycle_ns = cfg_global.freq.cycle_ns();
    let duration_cy = (scfg.duration_s * 1e9 / cycle_ns) as u64;
    let arrivals: Vec<Vec<u64>> = models
        .iter()
        .enumerate()
        .map(|(i, m)| {
            let seed_i = scfg
                .seed
                .wrapping_add((i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            traffic::arrivals(&m.traffic, seed_i, duration_cy, cycle_ns)
        })
        .collect();
    // the heaviest tenant by offered load (first on ties) — the one the
    // replica and migration machinery revolves around
    let mut heavy = 0usize;
    for (i, a) in arrivals.iter().enumerate() {
        if a.len() > arrivals[heavy].len() {
            heavy = i;
        }
    }

    // --- route: one owner per tenant ---------------------------------
    let ring = hash_ring(n);
    let owner_of: Vec<usize> = match fcfg.router {
        RouterPolicy::Hash | RouterPolicy::Replica => models
            .iter()
            .map(|m| ring_assign(&ring, &m.net.name))
            .collect(),
        RouterPolicy::LeastLoaded => {
            let counts: Vec<usize> = arrivals.iter().map(|a| a.len()).collect();
            least_loaded_assign(&counts, &node_arrays)
        }
    };

    // per-node rosters, ascending global tenant index; the heavy tenant
    // gets standby copies wherever the migration controller (least-
    // loaded) or the per-arrival splitter (replica) may need it, and a
    // node with no resident tenant gets a standby copy so its pool is
    // still a valid (if idle) placement
    let mut rosters: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (gi, &ow) in owner_of.iter().enumerate() {
        rosters[ow].push(gi);
    }
    let everywhere = n > 1
        && (fcfg.router == RouterPolicy::LeastLoaded || fcfg.router == RouterPolicy::Replica);
    for r in rosters.iter_mut() {
        if everywhere && !r.contains(&heavy) {
            r.push(heavy);
            r.sort_unstable();
        }
        if r.is_empty() {
            r.push(heavy);
        }
    }
    // an armed fault plan replicates every tenant everywhere (full
    // standby) so any survivor is a valid failover target; this changes
    // placement, which is why bit-identity is only promised for an
    // *empty* plan
    if chaos && n > 1 {
        for r in rosters.iter_mut() {
            for gi in 0..models.len() {
                if !r.contains(&gi) {
                    r.push(gi);
                }
            }
            r.sort_unstable();
        }
    }

    // --- per-node configs ---------------------------------------------
    // fleet-level replica autoscaling supersedes the in-node resizer
    let scfgs: Vec<ServeConfig> = node_arrays
        .iter()
        .map(|&na| ServeConfig {
            n_arrays: na,
            autoscale: scfg.autoscale && !fleet_auto,
            ..scfg.clone()
        })
        .collect();
    let cfgs: Vec<SystemConfig> = node_arrays
        .iter()
        .map(|&na| SystemConfig::scaled_up(na))
        .collect();
    let mut caches: Vec<PlanCache> = (0..n)
        .map(|_| PlanCache::with_capacity(scfg.plan_cache_cap))
        .collect();

    // --- replica split of the heavy stream ----------------------------
    // probe each node's single-request service cycles for the heavy
    // tenant; placement and batch cost are interned in the node's plan
    // cache, so the probe warms exactly what NodeSim::new recomputes and
    // never perturbs the node's own run
    let mut svc = vec![0u64; n];
    let mut split: Vec<Vec<u64>> = vec![Vec::new(); n];
    if fcfg.router == RouterPolicy::Replica && n > 1 {
        for ix in 0..n {
            let nets: Vec<&Network> = rosters[ix].iter().map(|&gi| &models[gi].net).collect();
            let tenancy = place_tenants(
                &nets,
                cfgs[ix].xbar_rows,
                node_arrays[ix] - scfg.headroom,
                scfg.rotate,
                &mut caches[ix],
            )?;
            let local = rosters[ix].iter().position(|&gi| gi == heavy).unwrap();
            let rep = caches[ix].get_or_batch(
                &models[heavy].net,
                scfg.strategy,
                &cfgs[ix],
                pm,
                &tenancy.tenants[local].plan,
                BatchConfig {
                    batch: 1,
                    pipeline: scfg.pipeline,
                    charge_dma: scfg.charge_dma,
                    stream_weights: scfg.stream_weights,
                },
            );
            svc[ix] = rep.cycles;
        }
        if fleet_auto {
            // online split: everything starts on the ring owner and the
            // fleet controller grows the active set from there
            split[owner_of[heavy]] = arrivals[heavy].clone();
        } else {
            // earliest-projected-finish water-fill, arrival order, ties
            // to the lower node id
            let mut busy = vec![0u64; n];
            for &a in &arrivals[heavy] {
                let mut best = 0usize;
                for cand in 1..n {
                    if busy[cand].max(a) + svc[cand] < busy[best].max(a) + svc[best] {
                        best = cand;
                    }
                }
                busy[best] = busy[best].max(a) + svc[best];
                split[best].push(a);
            }
        }
    }

    // --- per-node model lists: routed streams as replayable traces ----
    let replica_split = fcfg.router == RouterPolicy::Replica && n > 1;
    let node_models: Vec<Vec<ModelTraffic>> = rosters
        .iter()
        .enumerate()
        .map(|(ix, roster)| {
            roster
                .iter()
                .map(|&gi| {
                    let stream = if gi == heavy && replica_split {
                        split[ix].clone()
                    } else if owner_of[gi] == ix {
                        arrivals[gi].clone()
                    } else {
                        Vec::new() // standby copy: resident, no stream
                    };
                    ModelTraffic {
                        net: models[gi].net.clone(),
                        traffic: traffic::TrafficModel::Trace {
                            arrivals_cy: stream,
                        },
                        weight: models[gi].weight,
                    }
                })
                .collect()
        })
        .collect();

    // --- build the node simulators ------------------------------------
    let mut sims: Vec<NodeSim> = Vec::with_capacity(n);
    for (((m, sc), cf), ca) in node_models
        .iter()
        .zip(scfgs.iter())
        .zip(cfgs.iter())
        .zip(caches.iter_mut())
    {
        sims.push(NodeSim::new(m, sc, pm, cf, ca)?);
    }
    if chaos {
        for (ix, sim) in sims.iter_mut().enumerate() {
            sim.set_fault_mode(track_inflight[ix], degrade_spans[ix].clone());
        }
    }

    // --- the global event loop ----------------------------------------
    let mig = &fcfg.migration;
    let migrate_on = n > 1 && fcfg.router == RouterPolicy::LeastLoaded;
    let mut pressure = Pressure::new(1, mig.window_cy);
    let mut owner = owner_of[heavy];
    let mut cooldown_until = 0u64;
    let mut migrations: Vec<FleetMigration> = Vec::new();
    // fleet replica-autoscale state (replica router + --autoscale)
    let acfg = scfg.autoscale_cfg;
    let mut active = vec![false; n];
    if fleet_auto {
        active[owner_of[heavy]] = true;
    }
    let mut apressure = Pressure::new(1, acfg.window_cy);
    let mut acooldown = 0u64;
    let mut replica_scales: Vec<ReplicaScale> = Vec::new();
    let heavy_local: Vec<Option<usize>> = rosters
        .iter()
        .map(|r| r.iter().position(|&g| g == heavy))
        .collect();
    // chaos state
    let mut alive = vec![true; n];
    let mut down_since: Vec<Option<u64>> = vec![None; n];
    let mut downtime = vec![0u64; n];
    let mut arrays_lost = vec![0usize; n];
    let mut parked: BTreeMap<(usize, usize), Vec<u64>> = BTreeMap::new();
    let mut lost = 0u64;
    let mut retried = 0u64;
    let mut fault_log: Vec<FaultRecord> = Vec::new();
    let mut failovers: Vec<FailoverRecord> = Vec::new();
    let mut fi = 0usize;
    loop {
        let mut next: Option<(u64, usize)> = None;
        for (j, s) in sims.iter_mut().enumerate() {
            if let Some(t) = s.next_event() {
                if next.map_or(true, |(bt, _)| t < bt) {
                    next = Some((t, j));
                }
            }
        }
        // a fault due at or before the earliest stored node instant
        // applies first (ties: the fault wins); stored instants are
        // lower bounds, so a node may already have dispatched past the
        // fault instant — crash revocation covers exactly that window
        if fi < fault_events.len() && next.map_or(true, |(bt, _)| fault_events[fi].t <= bt) {
            let ft = fault_events[fi].t;
            let d = fault_events[fi].node;
            match fault_events[fi].action {
                FaultAction::Crash { recover_at } => {
                    recs[d].fault(ft, "crash");
                    fault_log.push(FaultRecord {
                        t: ft,
                        node: d,
                        label: "crash",
                    });
                    let (lost_d, pending) = sims[d].crash(ft);
                    lost += lost_d;
                    alive[d] = false;
                    down_since[d] = Some(ft);
                    if fleet_auto {
                        active[d] = false;
                    }
                    let mut heavy_target: Option<usize> = None;
                    for (local_ix, stream) in pending {
                        let gi = rosters[d][local_ix];
                        let (go, park): (Vec<u64>, Vec<u64>) = match recover_at {
                            // arrivals past the recovery instant wait for
                            // the staged rejoin instead of failing over
                            Some(tr) => stream.into_iter().partition(|&a| a < tr),
                            None => (stream, Vec::new()),
                        };
                        if !park.is_empty() {
                            parked.entry((d, gi)).or_default().extend(park);
                        }
                        if !go.is_empty() {
                            let target = failover_stream(
                                gi,
                                d,
                                ft,
                                go,
                                fcfg.router,
                                heavy,
                                &svc,
                                models,
                                &node_arrays,
                                &rosters,
                                &alive,
                                &mut active,
                                fleet_auto,
                                mig.handoff_cy_per_req,
                                &mut sims,
                                recs,
                                &mut retried,
                                &mut lost,
                                &mut failovers,
                            );
                            if gi == heavy {
                                heavy_target = target;
                            }
                        }
                    }
                    if migrate_on && !alive[owner] {
                        owner = heavy_target
                            .or_else(|| least_loaded_survivor(&alive, &node_arrays, &sims, ft))
                            .unwrap_or(owner);
                    }
                }
                FaultAction::Drain { rejoin_at, update } => {
                    let label = if update { "update" } else { "drain" };
                    recs[d].fault(ft, label);
                    fault_log.push(FaultRecord {
                        t: ft,
                        node: d,
                        label,
                    });
                    let pending = sims[d].drain_now();
                    alive[d] = false;
                    down_since[d] = Some(ft);
                    if fleet_auto {
                        active[d] = false;
                    }
                    let mut heavy_target: Option<usize> = None;
                    for (local_ix, stream) in pending {
                        let gi = rosters[d][local_ix];
                        let (go, park): (Vec<u64>, Vec<u64>) = match rejoin_at {
                            Some(tr) => stream.into_iter().partition(|&a| a < tr),
                            None => (stream, Vec::new()),
                        };
                        if !park.is_empty() {
                            parked.entry((d, gi)).or_default().extend(park);
                        }
                        if !go.is_empty() {
                            let target = failover_stream(
                                gi,
                                d,
                                ft,
                                go,
                                fcfg.router,
                                heavy,
                                &svc,
                                models,
                                &node_arrays,
                                &rosters,
                                &alive,
                                &mut active,
                                fleet_auto,
                                mig.handoff_cy_per_req,
                                &mut sims,
                                recs,
                                &mut retried,
                                &mut lost,
                                &mut failovers,
                            );
                            if gi == heavy {
                                heavy_target = target;
                            }
                        }
                    }
                    if migrate_on && !alive[owner] {
                        owner = heavy_target
                            .or_else(|| least_loaded_survivor(&alive, &node_arrays, &sims, ft))
                            .unwrap_or(owner);
                    }
                }
                FaultAction::Rejoin {
                    label,
                    reprogram_all,
                } => {
                    if let Some(s) = down_since[d].take() {
                        downtime[d] += ft.min(duration_cy).saturating_sub(s.min(duration_cy));
                    }
                    alive[d] = true;
                    sims[d].revive(ft);
                    recs[d].fault(ft, label);
                    fault_log.push(FaultRecord {
                        t: ft,
                        node: d,
                        label,
                    });
                    // staged rejoin: every returning stream reprograms
                    // (priced through migrate_in, hand-off free — the
                    // parked stream never left the fleet controller)
                    // before the node takes traffic
                    let mut returned = vec![false; rosters[d].len()];
                    for (local_ix, &gi) in rosters[d].iter().enumerate() {
                        if let Some(stream) = parked.remove(&(d, gi)) {
                            let moved_n = stream.len();
                            let (pc, _hc, bc) =
                                sims[d].migrate_in(local_ix, stream, ft, 0, &mut recs[d]);
                            retried += moved_n as u64;
                            recs[d].failover(local_ix, ft, d, moved_n, true);
                            failovers.push(FailoverRecord {
                                tenant: models[gi].net.name.clone(),
                                from_node: d,
                                to_node: d,
                                t: ft,
                                moved: moved_n,
                                program_cycles: pc,
                                handoff_cycles: 0,
                                blocked_cycles: bc,
                                rejoin: true,
                            });
                            returned[local_ix] = true;
                            if migrate_on && gi == heavy {
                                owner = d;
                            }
                        }
                    }
                    if reprogram_all {
                        // rolling model update: the new weights land on
                        // every resident tenant, traffic or not
                        for (local_ix, &ret) in returned.iter().enumerate() {
                            if !ret {
                                sims[d].reprogram(local_ix, ft, &mut recs[d]);
                            }
                        }
                    }
                }
                FaultAction::Degrade => {
                    // the span itself was pre-armed on the node; this
                    // just drops the mark at its timeline position
                    recs[d].fault(ft, "degrade");
                    fault_log.push(FaultRecord {
                        t: ft,
                        node: d,
                        label: "degrade",
                    });
                }
                FaultAction::ArrayFail { arrays } => {
                    recs[d].fault(ft, "arrayfail");
                    fault_log.push(FaultRecord {
                        t: ft,
                        node: d,
                        label: "arrayfail",
                    });
                    // every resident tenant remaps onto the surviving
                    // arrays: the full PCM price, no hand-off; the
                    // permanent service stretch was pre-armed
                    for local_ix in 0..rosters[d].len() {
                        sims[d].reprogram(local_ix, ft, &mut recs[d]);
                    }
                    arrays_lost[d] += arrays;
                }
            }
            fi += 1;
            continue;
        }
        let Some((_, j)) = next else { break };
        let stepped = sims[j].step(&mut recs[j]);
        let Some(t) = stepped else { continue };
        if migrate_on && alive[owner] {
            // hot-spot detector: the heavy tenant's owner vs the coldest
            // other live node, sampled at every fleet dispatch
            let hot = sims[owner].backlog_at(t) as u64;
            let mut cold = (u64::MAX, usize::MAX);
            for (k, s) in sims.iter().enumerate() {
                if k != owner && alive[k] {
                    let b = s.backlog_at(t) as u64;
                    if (b, k) < cold {
                        cold = (b, k);
                    }
                }
            }
            let (cold_b, cold_n) = cold;
            if cold_n < n
                && hot >= mig.hot_factor.saturating_mul(cold_b).saturating_add(mig.hot_margin)
            {
                pressure.record(0, t, 1);
            } else {
                pressure.clear(0);
            }
            pressure.age_out(0, t);
            if cold_n < n && t >= cooldown_until && pressure.sustained_hi(0, t, 1) {
                pressure.clear(0);
                cooldown_until = t + mig.cooldown_cy;
                let local_from = rosters[owner].iter().position(|&g| g == heavy).unwrap();
                let moved = sims[owner].migrate_out(local_from);
                if moved.is_empty() {
                    continue; // backlog was all in flight — nothing to move
                }
                let n_moved = moved.len();
                let local_to = rosters[cold_n].iter().position(|&g| g == heavy).unwrap();
                let (program_cycles, handoff_cycles, blocked_cycles) = sims[cold_n].migrate_in(
                    local_to,
                    moved,
                    t,
                    mig.handoff_cy_per_req,
                    &mut recs[cold_n],
                );
                migrations.push(FleetMigration {
                    tenant: models[heavy].net.name.clone(),
                    from_node: owner,
                    to_node: cold_n,
                    t,
                    moved: n_moved,
                    program_cycles,
                    handoff_cycles,
                    blocked_cycles,
                    streamed: scfg.stream_weights,
                });
                owner = cold_n;
            }
        }
        if fleet_auto {
            // fleet replica autoscaler: total heavy backlog over the
            // active set, PR 6 Pressure hysteresis, grow toward the
            // fastest inactive replica / shrink off the slowest active
            let depth: usize = (0..n)
                .filter(|&k| alive[k] && active[k])
                .map(|k| sims[k].tenant_backlog_at(heavy_local[k].unwrap(), t))
                .sum();
            apressure.record(0, t, depth);
            apressure.age_out(0, t);
            if t >= acooldown && apressure.sustained_hi(0, t, acfg.hi_depth) {
                let cand = (0..n)
                    .filter(|&k| alive[k] && !active[k])
                    .min_by_key(|&k| (svc[k], k));
                if let Some(k) = cand {
                    active[k] = true;
                    apressure.clear(0);
                    acooldown = t + acfg.cooldown_cy;
                    // re-water-fill every pending heavy request over the
                    // grown active set; each re-splice pays the full
                    // migration price, including shares landing back
                    // where they were (a conservative rebalance barrier)
                    let mut moved_all: Vec<u64> = Vec::new();
                    for src in 0..n {
                        if alive[src] && active[src] && src != k {
                            moved_all.append(&mut sims[src].migrate_out(heavy_local[src].unwrap()));
                        }
                    }
                    moved_all.sort_unstable();
                    let moved_n = moved_all.len();
                    let pool: Vec<usize> = (0..n).filter(|&q| alive[q] && active[q]).collect();
                    let mut busy = vec![t; n];
                    let mut per: Vec<Vec<u64>> = vec![Vec::new(); n];
                    for &a in &moved_all {
                        let mut best = pool[0];
                        for &c in &pool[1..] {
                            if busy[c].max(a) + svc[c] < busy[best].max(a) + svc[best] {
                                best = c;
                            }
                        }
                        busy[best] = busy[best].max(a) + svc[best];
                        per[best].push(a);
                    }
                    for (q, share) in per.iter_mut().enumerate() {
                        if !share.is_empty() {
                            let share = std::mem::take(share);
                            sims[q].migrate_in(
                                heavy_local[q].unwrap(),
                                share,
                                t,
                                mig.handoff_cy_per_req,
                                &mut recs[q],
                            );
                        }
                    }
                    replica_scales.push(ReplicaScale {
                        t,
                        grow: true,
                        node: k,
                        moved: moved_n,
                        active_after: pool.len(),
                    });
                }
            } else if t >= acooldown && apressure.sustained_lo(0, t, acfg.lo_depth) {
                let act: Vec<usize> = (0..n).filter(|&k| alive[k] && active[k]).collect();
                if act.len() > 1 {
                    // retire the slowest active replica (ties: higher id)
                    let k = *act.iter().max_by_key(|&&k| (svc[k], k)).unwrap();
                    active[k] = false;
                    apressure.clear(0);
                    acooldown = t + acfg.cooldown_cy;
                    let moved = sims[k].migrate_out(heavy_local[k].unwrap());
                    let moved_n = moved.len();
                    if moved_n > 0 {
                        // the retiree's pending lands on the fastest
                        // remaining replica
                        let rest: Vec<usize> =
                            act.iter().copied().filter(|&q| q != k).collect();
                        let dst = *rest.iter().min_by_key(|&&q| (svc[q], q)).unwrap();
                        sims[dst].migrate_in(
                            heavy_local[dst].unwrap(),
                            moved,
                            t,
                            mig.handoff_cy_per_req,
                            &mut recs[dst],
                        );
                    }
                    replica_scales.push(ReplicaScale {
                        t,
                        grow: false,
                        node: k,
                        moved: moved_n,
                        active_after: act.len() - 1,
                    });
                }
            }
        }
    }

    // a node still down at the end of the run is down to the horizon
    for d in 0..n {
        if let Some(s) = down_since[d] {
            downtime[d] += duration_cy.saturating_sub(s.min(duration_cy));
        }
    }

    // --- fold ----------------------------------------------------------
    let mut nodes: Vec<NodeReport> = Vec::with_capacity(n);
    for ((ix, sim), rec) in sims.into_iter().enumerate().zip(recs.iter_mut()) {
        nodes.push(NodeReport {
            node: ix,
            arrays: node_arrays[ix],
            report: sim.into_report(rec),
        });
    }
    Ok(FleetReport {
        router: fcfg.router,
        nodes_n: n,
        seed: scfg.seed,
        cycle_ns,
        nodes,
        migrations,
        replica_scales,
        faults: if chaos {
            Some(FleetFaultOutcome {
                events: fault_log,
                failovers,
                retried,
                lost_in_crash: lost,
                downtime_cy: downtime,
                arrays_lost,
                horizon_cy: duration_cy,
            })
        } else {
            None
        },
    })
}

/// The capacity-weighted least-loaded survivor (w = 0): where the
/// migration controller re-homes its heavy-tenant tracking when the
/// owner dies without a pending stream to follow.
fn least_loaded_survivor(
    alive: &[bool],
    node_arrays: &[usize],
    sims: &[NodeSim],
    t: u64,
) -> Option<usize> {
    let mut best: Option<(usize, u64)> = None;
    for (k, &a) in alive.iter().enumerate() {
        if !a {
            continue;
        }
        let b = sims[k].backlog_at(t) as u64;
        let better = match best {
            None => true,
            Some((bk, bb)) => {
                (b as u128) * node_arrays[bk] as u128 < (bb as u128) * node_arrays[k] as u128
            }
        };
        if better {
            best = Some((k, b));
        }
    }
    best.map(|(k, _)| k)
}

#[cfg(test)]
mod tests {
    use super::super::{bottleneck_fleet, mnv2_bottleneck_pair, simulate};
    use super::*;

    #[test]
    fn ring_assignment_is_pinned() {
        // the ring is part of the routing contract: these assignments are
        // frozen (recomputed independently from the FNV-1a definition)
        let r4 = hash_ring(4);
        assert_eq!(ring_assign(&r4, "mobilenetv2"), 2);
        assert_eq!(ring_assign(&r4, "bottleneck"), 3);
        for i in 0..8 {
            assert_eq!(ring_assign(&r4, &format!("bn-{i}")), 3, "bn-{i}");
        }
        let r1 = hash_ring(1);
        for name in ["mobilenetv2", "bottleneck", "bn-0"] {
            assert_eq!(ring_assign(&r1, name), 0);
        }
        let r2 = hash_ring(2);
        assert_eq!(ring_assign(&r2, "mobilenetv2"), 1);
        assert_eq!(ring_assign(&r2, "bottleneck"), 1);
        // ring size and determinism
        assert_eq!(r4.len(), 4 * VNODES);
        assert_eq!(hash_ring(4), r4);
    }

    #[test]
    fn survivor_rings_rebuild_deterministically() {
        // removing a node leaves exactly the full ring minus its points
        // (original-id keys), so re-adding it restores the original
        // assignment bit-for-bit
        let full = hash_ring(4);
        let survivors = hash_ring_of(&[0, 1, 3]);
        let expect: Vec<(u64, usize)> =
            full.iter().copied().filter(|&(_, ix)| ix != 2).collect();
        assert_eq!(survivors, expect);
        assert_eq!(hash_ring_of(&[0, 1, 2, 3]), full);
        // seed-stable across rebuilds
        assert_eq!(hash_ring_of(&[0, 1, 3]), survivors);
        // a tenant on a survivor keeps its owner; one on the dead node
        // fails over deterministically and returns home on re-add
        assert_eq!(ring_assign(&full, "bottleneck"), 3);
        assert_eq!(ring_assign(&survivors, "bottleneck"), 3);
        assert_eq!(ring_assign(&full, "mobilenetv2"), 2);
        let failover = ring_assign(&survivors, "mobilenetv2");
        assert_ne!(failover, 2);
        assert_eq!(ring_assign(&survivors, "mobilenetv2"), failover);
        assert_eq!(ring_assign(&hash_ring_of(&[0, 1, 2, 3]), "mobilenetv2"), 2);
        // order of the id list never matters
        assert_eq!(hash_ring_of(&[3, 0, 1]), survivors);
    }

    #[test]
    fn node_arrays_parser_names_the_offending_entry() {
        assert_eq!(parse_node_arrays("32,24,16", 3).unwrap(), vec![32, 24, 16]);
        assert_eq!(parse_node_arrays(" 8 , 8 ", 2).unwrap(), vec![8, 8]);
        let e = parse_node_arrays("32,24", 3).unwrap_err();
        assert!(
            e.contains("2 array counts") && e.contains("--nodes says 3"),
            "{e}"
        );
        let e = parse_node_arrays("32,x,16", 3).unwrap_err();
        assert!(e.contains("entry 2 of 3") && e.contains("`x`"), "{e}");
        let e = parse_node_arrays("32,0,16", 3).unwrap_err();
        assert!(e.contains("entry 2 of 3") && e.contains("`0`"), "{e}");
        let e = parse_node_arrays("32,,16", 3).unwrap_err();
        assert!(e.contains("entry 2 of 3"), "{e}");
    }

    #[test]
    fn least_loaded_assign_is_capacity_aware() {
        // heaviest first to the big node; the rest water-fill the small
        // node once the big one carries the hot tenant
        assert_eq!(least_loaded_assign(&[100, 10, 10], &[64, 12]), vec![0, 1, 1]);
        // equal caps, equal loads: ties break to the lower node id in
        // descending-load order
        assert_eq!(least_loaded_assign(&[5, 5], &[32, 32]), vec![0, 1]);
        // one node takes everything
        assert_eq!(least_loaded_assign(&[7, 3], &[64]), vec![0, 0]);
    }

    #[test]
    fn two_node_fleet_conserves_arrivals_under_every_router() {
        let pm = PowerModel::paper();
        let models = bottleneck_fleet(3, 200.0);
        let scfg = ServeConfig {
            duration_s: 0.02,
            ..ServeConfig::default()
        };
        let solo = simulate(&models, &scfg, &pm).unwrap();
        let offered: u64 = solo.tenants.iter().map(|t| t.arrivals).sum();
        assert!(offered > 0);
        for router in [
            RouterPolicy::Hash,
            RouterPolicy::LeastLoaded,
            RouterPolicy::Replica,
        ] {
            let fcfg = FleetConfig::new(2, router);
            let rep = simulate_fleet(&models, &scfg, &fcfg, &pm).unwrap();
            assert_eq!(rep.nodes.len(), 2, "{}", router.label());
            // sharding loses no offered load…
            assert_eq!(rep.total_arrivals(), offered, "{}", router.label());
            // …and every arrival is accounted for
            assert_eq!(
                rep.total_served() + rep.total_dropped() + rep.total_rejected(),
                rep.total_arrivals(),
                "{}",
                router.label()
            );
            // no fault plan: no chaos ledger, no replica resizes
            assert!(rep.faults.is_none(), "{}", router.label());
            assert!(rep.replica_scales.is_empty(), "{}", router.label());
            // byte-determinism of the rendered artifacts
            let again = simulate_fleet(&models, &scfg, &fcfg, &pm).unwrap();
            assert_eq!(
                rep.render_table(),
                again.render_table(),
                "{}",
                router.label()
            );
            assert_eq!(
                rep.to_json().to_string_pretty(),
                again.to_json().to_string_pretty(),
                "{}",
                router.label()
            );
        }
    }

    #[test]
    fn single_node_fleet_matches_the_single_cluster_path() {
        let pm = PowerModel::paper();
        let models = mnv2_bottleneck_pair(120.0);
        let scfg = ServeConfig {
            duration_s: 0.02,
            ..ServeConfig::default()
        };
        let solo = simulate(&models, &scfg, &pm).unwrap();
        for router in [
            RouterPolicy::Hash,
            RouterPolicy::LeastLoaded,
            RouterPolicy::Replica,
        ] {
            let rep = simulate_fleet(&models, &scfg, &FleetConfig::new(1, router), &pm).unwrap();
            assert!(rep.migrations.is_empty());
            assert_eq!(
                rep.nodes[0].report.render_table(),
                solo.render_table(),
                "{}",
                router.label()
            );
            assert_eq!(
                rep.nodes[0].report.to_json().to_string_pretty(),
                solo.to_json().to_string_pretty(),
                "{}",
                router.label()
            );
        }
    }

    #[test]
    fn fleet_rejects_bad_configs() {
        let pm = PowerModel::paper();
        let models = bottleneck_fleet(2, 50.0);
        let scfg = ServeConfig {
            duration_s: 0.005,
            ..ServeConfig::default()
        };
        assert!(simulate_fleet(&models, &scfg, &FleetConfig::new(0, RouterPolicy::Hash), &pm)
            .is_err());
        let mut fc = FleetConfig::new(2, RouterPolicy::Hash);
        fc.node_arrays = vec![64]; // wrong length
        assert!(simulate_fleet(&models, &scfg, &fc, &pm).is_err());
        fc.node_arrays = vec![64, 0]; // empty node
        assert!(simulate_fleet(&models, &scfg, &fc, &pm).is_err());
        // autoscaling a multi-node fleet needs the replica router
        let auto_cfg = ServeConfig {
            autoscale: true,
            ..scfg.clone()
        };
        assert!(simulate_fleet(
            &models,
            &auto_cfg,
            &FleetConfig::new(2, RouterPolicy::Hash),
            &pm
        )
        .is_err());
        assert!(simulate_fleet(
            &models,
            &auto_cfg,
            &FleetConfig::new(2, RouterPolicy::LeastLoaded),
            &pm
        )
        .is_err());
        // an invalid fault plan is rejected up front
        let mut fc = FleetConfig::new(2, RouterPolicy::Hash);
        fc.faults = FaultPlan::parse("crash@node7:1e6").unwrap();
        assert!(simulate_fleet(&models, &scfg, &fc, &pm).is_err());
    }
}
