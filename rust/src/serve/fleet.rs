//! Fleet-scale sharding: N heterogeneous IMC clusters behind a routing
//! front-end, under one deterministic event loop.
//!
//! Each node is a complete single-cluster simulator — its own array
//! count ([`FleetConfig::node_arrays`]), `ResourceTimeline` pool, plan
//! cache, and `EventQueue` — embodied by `serve::NodeSim`, the factored
//! setup/step/report of `serve::simulate_traced`. The fleet loop holds N
//! of them and repeatedly steps **the node whose earliest stored event
//! instant is globally smallest, ties toward the lower node id**. That
//! is the whole ordering contract, and it is weaker than it looks:
//! stored instants are lower bounds, so a node may dispatch *later* than
//! another node's pending event. This is harmless — nodes share no
//! resources, so each node's dispatch table is a function of its own
//! routed arrival stream alone and is invariant under interleaving. The
//! global order only pins *when* the migration controller samples
//! backlogs, which makes migrations (and therefore everything) a pure
//! function of the seed: two runs with the same seed and flags produce
//! byte-identical fleet reports, per-node tables, and traces.
//!
//! ## Router policies
//!
//! Routing is per *tenant* (a model and its arrival stream), decided up
//! front from the globally generated seeded streams — the same
//! `seed + (i+1)·φ` per-tenant seeds as a single-cluster run, so the
//! offered load is identical no matter how it is sharded:
//!
//! - **`hash`** — consistent hashing: FNV-1a over 32 virtual points per
//!   node; a tenant lives on the first ring point at or after its name's
//!   hash. Stateless and minimally disruptive as nodes come and go, but
//!   load-blind: a hot tenant pins its whole stream to one node.
//! - **`least-loaded`** — offered-load-aware placement (heaviest tenant
//!   first, each to the node minimizing projected load per array), plus
//!   an *online* migration controller: the heaviest tenant holds standby
//!   replicas on every node, and when its owner's backlog sustains above
//!   `hot_factor × coldest + hot_margin` over a pressure window
//!   (`serve::autoscale::Pressure`, the PR 6 hysteresis machinery), its
//!   pending stream migrates to the coldest node for the migration price
//!   below.
//! - **`replica`** — the heaviest tenant is resident on *every* node and
//!   its stream is split per-arrival to the node with the earliest
//!   projected finish (a virtual-finish-time water-fill over probed
//!   single-request service cycles); all other tenants route by the hash
//!   ring.
//!
//! ## Migration cost accounting
//!
//! A cross-node move charges exactly what the PR 6 autoscaler's
//! `apply_scale` charges an in-pool slice move — PCM reprogramming of
//! every array the tenant's resident plan (first pass) touches,
//! serialized on the *destination's* `RES_PROG` port and chained after
//! whatever already occupies the destination arrays — **plus** a trace
//! hand-off charge on the destination's DMA port
//! ([`FleetMigrationConfig::handoff_cy_per_req`] per moved request),
//! since the pending stream's state has to cross nodes. Programming
//! energy lands on the tenant's destination-node ledger. With
//! `--stream-weights` the whole tail rides the overlap path and the
//! tenant's dispatch floor stays put; otherwise the floor moves past it
//! (`blocked_cycles`). Every migration is reported in
//! [`FleetReport::migrations`] with its independently recomputable
//! price — `tests/fleet_regression.rs` re-derives `program_cycles` from
//! the placement and `ImaArrayPool::program_cycles_by_array`.
//!
//! `--nodes 1` (any router) degenerates to a single node owning every
//! tenant in global order with its original streams, no standby copies
//! and no migration controller — pinned bit-identical to the pre-fleet
//! single-cluster path on dispatch tables, serve JSON, and trace bytes.

use crate::arch::{PowerModel, SystemConfig};
use crate::coordinator::{BatchConfig, PlanCache};
use crate::net::Network;
use crate::util::json::{obj, Json};
use crate::util::table::{f, Table};

use super::autoscale::Pressure;
use super::metrics::LogHistogram;
use super::tenancy::place_tenants;
use super::trace::TraceRecorder;
use super::{traffic, ModelTraffic, NodeSim, ServeConfig, ServeReport};

/// Virtual ring points per node — enough that a 4-node ring's arcs are
/// reasonably even without making ring construction measurable.
const VNODES: usize = 32;

/// How the front-end assigns tenants (and their arrival streams) to
/// nodes. See the module docs for the semantics of each policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouterPolicy {
    /// Consistent hashing by tenant name over a virtual-node ring.
    Hash,
    /// Offered-load-aware placement plus online hot-spot migration.
    LeastLoaded,
    /// Heaviest tenant replicated on all nodes, stream split
    /// per-arrival; everything else hash-routed.
    Replica,
}

impl RouterPolicy {
    pub fn parse(s: &str) -> Result<RouterPolicy, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "hash" | "consistent-hash" => Ok(RouterPolicy::Hash),
            "least-loaded" | "ll" => Ok(RouterPolicy::LeastLoaded),
            "replica" => Ok(RouterPolicy::Replica),
            other => Err(format!(
                "unknown router `{other}` (hash|least-loaded|replica)"
            )),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            RouterPolicy::Hash => "hash",
            RouterPolicy::LeastLoaded => "least-loaded",
            RouterPolicy::Replica => "replica",
        }
    }
}

/// Knobs of the least-loaded router's online migration controller. The
/// pressure window/cooldown defaults mirror `AutoscaleConfig` so the
/// two controllers breathe at the same rate.
#[derive(Clone, Copy, Debug)]
pub struct FleetMigrationConfig {
    /// Migrate when `owner backlog ≥ hot_factor × coldest + hot_margin`…
    pub hot_factor: u64,
    /// …with the additive margin keeping tiny backlogs from thrashing.
    pub hot_margin: u64,
    /// The imbalance must sustain for a full window (cycles).
    pub window_cy: u64,
    /// Minimum spacing between migrations (cycles).
    pub cooldown_cy: u64,
    /// Hand-off DMA charge per moved pending request (cycles).
    pub handoff_cy_per_req: u64,
}

impl Default for FleetMigrationConfig {
    fn default() -> Self {
        FleetMigrationConfig {
            hot_factor: 2,
            hot_margin: 8,
            window_cy: 1_000_000,
            cooldown_cy: 3_000_000,
            handoff_cy_per_req: 512,
        }
    }
}

/// Fleet topology and routing configuration; per-node serving knobs
/// (policy, window, seed, …) come from the shared [`ServeConfig`].
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Number of nodes (≥ 1).
    pub nodes: usize,
    pub router: RouterPolicy,
    /// Per-node array counts (heterogeneous fleet). Empty = every node
    /// gets the shared `ServeConfig::n_arrays`.
    pub node_arrays: Vec<usize>,
    pub migration: FleetMigrationConfig,
}

impl FleetConfig {
    pub fn new(nodes: usize, router: RouterPolicy) -> FleetConfig {
        FleetConfig {
            nodes,
            router,
            node_arrays: Vec::new(),
            migration: FleetMigrationConfig::default(),
        }
    }
}

/// One executed cross-node migration, with its independently
/// recomputable price (see the module docs).
#[derive(Clone, Debug)]
pub struct FleetMigration {
    pub tenant: String,
    pub from_node: usize,
    pub to_node: usize,
    /// Fleet-clock instant the move was decided and charged (cycles).
    pub t: u64,
    /// Pending requests handed off.
    pub moved: usize,
    /// PCM reprogramming on the destination (sum over touched arrays).
    pub program_cycles: u64,
    /// DMA hand-off charge (`moved × handoff_cy_per_req`).
    pub handoff_cycles: u64,
    /// How far past `t` the tenant's dispatch floor moved (0 when the
    /// price streamed under compute).
    pub blocked_cycles: u64,
    pub streamed: bool,
}

/// One node's slice of the fleet: its id, pool size, and complete
/// single-cluster [`ServeReport`].
#[derive(Clone, Debug)]
pub struct NodeReport {
    pub node: usize,
    pub arrays: usize,
    pub report: ServeReport,
}

/// The fleet run's outcome: per-node reports plus the migration log.
/// Aggregates (arrival conservation, merged latency percentiles) are
/// derived, never stored, so they cannot drift from the per-node truth.
#[derive(Clone, Debug)]
pub struct FleetReport {
    pub router: RouterPolicy,
    pub nodes_n: usize,
    pub seed: u64,
    pub cycle_ns: f64,
    pub nodes: Vec<NodeReport>,
    pub migrations: Vec<FleetMigration>,
}

impl FleetReport {
    /// Offered load summed over every node's tenant ledger. Migration
    /// moves a request's ledger entry with it, so this equals the
    /// globally generated arrival count exactly.
    pub fn total_arrivals(&self) -> u64 {
        self.nodes
            .iter()
            .flat_map(|n| n.report.tenants.iter())
            .map(|t| t.arrivals)
            .sum()
    }

    pub fn total_served(&self) -> u64 {
        self.nodes.iter().map(|n| n.report.total_served()).sum()
    }

    pub fn total_dropped(&self) -> u64 {
        self.nodes.iter().map(|n| n.report.total_dropped()).sum()
    }

    pub fn total_rejected(&self) -> u64 {
        self.nodes.iter().map(|n| n.report.total_rejected()).sum()
    }

    /// Fleet makespan: the last node to drain.
    pub fn makespan_cycles(&self) -> u64 {
        self.nodes
            .iter()
            .map(|n| n.report.makespan_cycles)
            .max()
            .unwrap_or(0)
    }

    /// End-to-end latency over *all* served requests fleet-wide: the
    /// per-tenant histograms merged bin-wise ([`LogHistogram::merge`]),
    /// exactly what one histogram over the union would report.
    pub fn merged_latency(&self) -> LogHistogram {
        let mut h = LogHistogram::new();
        for n in &self.nodes {
            for t in &n.report.tenants {
                h.merge(&t.latency);
            }
        }
        h
    }

    /// Fleet throughput over the fleet makespan, inferences/s.
    pub fn inferences_per_s(&self) -> f64 {
        let makespan_s = self.makespan_cycles() as f64 * self.cycle_ns * 1e-9;
        if makespan_s > 0.0 {
            self.total_served() as f64 / makespan_s
        } else {
            0.0
        }
    }

    fn ms(&self, cy: u64) -> f64 {
        cy as f64 * self.cycle_ns * 1e-6
    }

    /// The fleet summary table the CLI prints above the per-node
    /// tables: one row per node plus the fleet totals and the migration
    /// log. Byte-identical across runs with the same seed.
    pub fn render_table(&self) -> String {
        let merged = self.merged_latency();
        let (p50, p95, p99) = merged.percentiles();
        let title = format!(
            "fleet — {} nodes, {} router, seed {:#x}, p50/p95/p99 {}/{}/{} ms",
            self.nodes_n,
            self.router.label(),
            self.seed,
            f(self.ms(p50), 3),
            f(self.ms(p95), 3),
            f(self.ms(p99), 3),
        );
        let mut t = Table::new(
            &title,
            &[
                "node", "arrays", "tenants", "arrivals", "served", "dropped", "rejected",
                "p95 ms", "util",
            ],
        );
        for nr in &self.nodes {
            let mut h = LogHistogram::new();
            for ten in &nr.report.tenants {
                h.merge(&ten.latency);
            }
            let node_arrivals: u64 = nr.report.tenants.iter().map(|s| s.arrivals).sum();
            t.row([
                nr.node.to_string(),
                nr.arrays.to_string(),
                nr.report.tenants.len().to_string(),
                node_arrivals.to_string(),
                nr.report.total_served().to_string(),
                nr.report.total_dropped().to_string(),
                nr.report.total_rejected().to_string(),
                f(self.ms(h.quantile(0.95)), 3),
                format!("{:.0}%", nr.report.utilization() * 100.0),
            ]);
        }
        let mut out = t.render();
        out.push_str(&format!(
            "fleet totals: {} arrivals, {} served, {} dropped, {} rejected, {:.1} inf/s\n",
            self.total_arrivals(),
            self.total_served(),
            self.total_dropped(),
            self.total_rejected(),
            self.inferences_per_s(),
        ));
        if !self.migrations.is_empty() {
            out.push_str(&format!("migrations: {}\n", self.migrations.len()));
            for m in &self.migrations {
                out.push_str(&format!(
                    "  {} node{} -> node{} @{}: {} reqs, {} prog cy, {} handoff cy, {} blocked{}\n",
                    m.tenant,
                    m.from_node,
                    m.to_node,
                    m.t,
                    m.moved,
                    m.program_cycles,
                    m.handoff_cycles,
                    m.blocked_cycles,
                    if m.streamed { " (streamed)" } else { "" },
                ));
            }
        }
        out
    }

    /// Machine-readable fleet report: the aggregates, the migration
    /// log, and every node's full single-cluster JSON under `nodes[]`.
    pub fn to_json(&self) -> Json {
        let merged = self.merged_latency();
        let (p50, p95, p99) = merged.percentiles();
        let migrations: Vec<Json> = self
            .migrations
            .iter()
            .map(|m| {
                obj([
                    ("tenant", m.tenant.as_str().into()),
                    ("from_node", m.from_node.into()),
                    ("to_node", m.to_node.into()),
                    ("t_cycles", (m.t as f64).into()),
                    ("moved", m.moved.into()),
                    ("program_cycles", (m.program_cycles as f64).into()),
                    ("handoff_cycles", (m.handoff_cycles as f64).into()),
                    ("blocked_cycles", (m.blocked_cycles as f64).into()),
                    ("streamed", m.streamed.into()),
                ])
            })
            .collect();
        let nodes: Vec<Json> = self
            .nodes
            .iter()
            .map(|nr| {
                obj([
                    ("node", nr.node.into()),
                    ("arrays", nr.arrays.into()),
                    ("report", nr.report.to_json()),
                ])
            })
            .collect();
        obj([
            ("router", self.router.label().into()),
            ("nodes_n", self.nodes_n.into()),
            ("seed", format!("{:#x}", self.seed).into()),
            (
                "fleet",
                obj([
                    ("arrivals", (self.total_arrivals() as f64).into()),
                    ("served", (self.total_served() as f64).into()),
                    ("dropped", (self.total_dropped() as f64).into()),
                    ("rejected", (self.total_rejected() as f64).into()),
                    ("p50_ms", self.ms(p50).into()),
                    ("p95_ms", self.ms(p95).into()),
                    ("p99_ms", self.ms(p99).into()),
                    ("makespan_cycles", (self.makespan_cycles() as f64).into()),
                    ("inf_per_s", self.inferences_per_s().into()),
                    ("migrations", Json::Arr(migrations)),
                ]),
            ),
            ("nodes", Json::Arr(nodes)),
        ])
    }
}

/// FNV-1a 64-bit — the same hash `Network::fingerprint` uses, hand
/// rolled here over a string key.
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in s.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_0001_b3);
    }
    h
}

/// The consistent-hash ring: `VNODES` points per node keyed
/// `node{ix}#{v}`, sorted by (hash, node) so collisions (astronomically
/// unlikely) still order deterministically.
fn hash_ring(n: usize) -> Vec<(u64, usize)> {
    let mut pts: Vec<(u64, usize)> = (0..n)
        .flat_map(|ix| (0..VNODES).map(move |v| (fnv1a(&format!("node{ix}#{v}")), ix)))
        .collect();
    pts.sort_unstable();
    pts
}

/// Ring lookup: the first point at or clockwise of the name's hash
/// (wrapping to the ring's first point).
fn ring_assign(pts: &[(u64, usize)], name: &str) -> usize {
    let h = fnv1a(name);
    let ix = pts.partition_point(|&(ph, _)| ph < h);
    if ix == pts.len() {
        pts[0].1
    } else {
        pts[ix].1
    }
}

/// Offered-load-aware placement: tenants in descending arrival count
/// (ties toward the lower tenant index), each to the node minimizing
/// projected load per array — `(load + w) / cap` compared by
/// cross-multiplication so the decision is exact integer arithmetic
/// (strict inequality keeps the lower node id on ties).
fn least_loaded_assign(arrival_counts: &[usize], caps: &[usize]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..arrival_counts.len()).collect();
    order.sort_by(|&a, &b| {
        arrival_counts[b]
            .cmp(&arrival_counts[a])
            .then(a.cmp(&b))
    });
    let mut load = vec![0u64; caps.len()];
    let mut owner = vec![0usize; arrival_counts.len()];
    for ti in order {
        let w = arrival_counts[ti] as u64;
        let mut best = 0usize;
        for cand in 1..caps.len() {
            if (load[cand] + w) as u128 * caps[best] as u128
                < (load[best] + w) as u128 * caps[cand] as u128
            {
                best = cand;
            }
        }
        load[best] += w;
        owner[ti] = best;
    }
    owner
}

/// [`simulate_fleet_traced`] with tracing off on every node.
pub fn simulate_fleet(
    models: &[ModelTraffic],
    scfg: &ServeConfig,
    fcfg: &FleetConfig,
    pm: &PowerModel,
) -> Result<FleetReport, String> {
    let mut recs: Vec<TraceRecorder> = (0..fcfg.nodes).map(|_| TraceRecorder::Off).collect();
    simulate_fleet_traced(models, scfg, fcfg, pm, &mut recs)
}

/// Run the fleet to completion: route the globally generated arrival
/// streams to nodes, step the per-node simulators under the global
/// min-event order (see the module docs), and run the migration
/// controller for the least-loaded router. `recs` holds one trace
/// recorder per node ([`TraceRecorder::Off`] for no trace); per-node
/// traces are as bit-identical to untraced runs as single-cluster ones.
pub fn simulate_fleet_traced(
    models: &[ModelTraffic],
    scfg: &ServeConfig,
    fcfg: &FleetConfig,
    pm: &PowerModel,
    recs: &mut [TraceRecorder],
) -> Result<FleetReport, String> {
    let n = fcfg.nodes;
    if n == 0 {
        return Err("a fleet needs at least one node".into());
    }
    if models.is_empty() {
        return Err("no models to serve".into());
    }
    if recs.len() != n {
        return Err(format!("{} trace recorders for {n} nodes", recs.len()));
    }
    if n > 1 && scfg.autoscale {
        return Err(
            "in-node autoscaling and cross-node migration both own the arrays; \
             --autoscale is limited to --nodes 1"
                .into(),
        );
    }
    if !fcfg.node_arrays.is_empty() && fcfg.node_arrays.len() != n {
        return Err(format!(
            "--node-arrays lists {} nodes, --nodes says {n}",
            fcfg.node_arrays.len()
        ));
    }
    let node_arrays: Vec<usize> = if fcfg.node_arrays.is_empty() {
        vec![scfg.n_arrays; n]
    } else {
        fcfg.node_arrays.clone()
    };
    for (ix, &na) in node_arrays.iter().enumerate() {
        if na == 0 {
            return Err(format!("node {ix} has no arrays"));
        }
        if scfg.headroom >= na {
            return Err(format!(
                "headroom {} leaves node {ix} no arrays to carve (node has {na})",
                scfg.headroom
            ));
        }
    }

    // the globally generated seeded streams — identical offered load to
    // a single-cluster run, however it is sharded (the per-tenant seed
    // depends only on the global tenant index; cycle_ns is frequency-
    // derived and frequency does not vary with the array count)
    let cfg_global = SystemConfig::scaled_up(scfg.n_arrays);
    let cycle_ns = cfg_global.freq.cycle_ns();
    let duration_cy = (scfg.duration_s * 1e9 / cycle_ns) as u64;
    let arrivals: Vec<Vec<u64>> = models
        .iter()
        .enumerate()
        .map(|(i, m)| {
            let seed_i = scfg
                .seed
                .wrapping_add((i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            traffic::arrivals(&m.traffic, seed_i, duration_cy, cycle_ns)
        })
        .collect();
    // the heaviest tenant by offered load (first on ties) — the one the
    // replica and migration machinery revolves around
    let mut heavy = 0usize;
    for (i, a) in arrivals.iter().enumerate() {
        if a.len() > arrivals[heavy].len() {
            heavy = i;
        }
    }

    // --- route: one owner per tenant ---------------------------------
    let ring = hash_ring(n);
    let owner_of: Vec<usize> = match fcfg.router {
        RouterPolicy::Hash | RouterPolicy::Replica => models
            .iter()
            .map(|m| ring_assign(&ring, &m.net.name))
            .collect(),
        RouterPolicy::LeastLoaded => {
            let counts: Vec<usize> = arrivals.iter().map(|a| a.len()).collect();
            least_loaded_assign(&counts, &node_arrays)
        }
    };

    // per-node rosters, ascending global tenant index; the heavy tenant
    // gets standby copies wherever the migration controller (least-
    // loaded) or the per-arrival splitter (replica) may need it, and a
    // node with no resident tenant gets a standby copy so its pool is
    // still a valid (if idle) placement
    let mut rosters: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (gi, &ow) in owner_of.iter().enumerate() {
        rosters[ow].push(gi);
    }
    let everywhere = n > 1
        && (fcfg.router == RouterPolicy::LeastLoaded || fcfg.router == RouterPolicy::Replica);
    for r in rosters.iter_mut() {
        if everywhere && !r.contains(&heavy) {
            r.push(heavy);
            r.sort_unstable();
        }
        if r.is_empty() {
            r.push(heavy);
        }
    }

    // --- per-node configs ---------------------------------------------
    let scfgs: Vec<ServeConfig> = node_arrays
        .iter()
        .map(|&na| ServeConfig {
            n_arrays: na,
            ..scfg.clone()
        })
        .collect();
    let cfgs: Vec<SystemConfig> = node_arrays
        .iter()
        .map(|&na| SystemConfig::scaled_up(na))
        .collect();
    let mut caches: Vec<PlanCache> = (0..n)
        .map(|_| PlanCache::with_capacity(scfg.plan_cache_cap))
        .collect();

    // --- replica split of the heavy stream ----------------------------
    // probe each node's single-request service cycles for the heavy
    // tenant; placement and batch cost are interned in the node's plan
    // cache, so the probe warms exactly what NodeSim::new recomputes and
    // never perturbs the node's own run
    let mut split: Vec<Vec<u64>> = vec![Vec::new(); n];
    if fcfg.router == RouterPolicy::Replica && n > 1 {
        let mut svc = vec![0u64; n];
        for ix in 0..n {
            let nets: Vec<&Network> = rosters[ix].iter().map(|&gi| &models[gi].net).collect();
            let tenancy = place_tenants(
                &nets,
                cfgs[ix].xbar_rows,
                node_arrays[ix] - scfg.headroom,
                scfg.rotate,
                &mut caches[ix],
            )?;
            let local = rosters[ix].iter().position(|&gi| gi == heavy).unwrap();
            let rep = caches[ix].get_or_batch(
                &models[heavy].net,
                scfg.strategy,
                &cfgs[ix],
                pm,
                &tenancy.tenants[local].plan,
                BatchConfig {
                    batch: 1,
                    pipeline: scfg.pipeline,
                    charge_dma: scfg.charge_dma,
                    stream_weights: scfg.stream_weights,
                },
            );
            svc[ix] = rep.cycles;
        }
        // earliest-projected-finish water-fill, arrival order, ties to
        // the lower node id
        let mut busy = vec![0u64; n];
        for &a in &arrivals[heavy] {
            let mut best = 0usize;
            for cand in 1..n {
                if busy[cand].max(a) + svc[cand] < busy[best].max(a) + svc[best] {
                    best = cand;
                }
            }
            busy[best] = busy[best].max(a) + svc[best];
            split[best].push(a);
        }
    }

    // --- per-node model lists: routed streams as replayable traces ----
    let replica_split = fcfg.router == RouterPolicy::Replica && n > 1;
    let node_models: Vec<Vec<ModelTraffic>> = rosters
        .iter()
        .enumerate()
        .map(|(ix, roster)| {
            roster
                .iter()
                .map(|&gi| {
                    let stream = if gi == heavy && replica_split {
                        split[ix].clone()
                    } else if owner_of[gi] == ix {
                        arrivals[gi].clone()
                    } else {
                        Vec::new() // standby copy: resident, no stream
                    };
                    ModelTraffic {
                        net: models[gi].net.clone(),
                        traffic: traffic::TrafficModel::Trace {
                            arrivals_cy: stream,
                        },
                        weight: models[gi].weight,
                    }
                })
                .collect()
        })
        .collect();

    // --- build the node simulators ------------------------------------
    let mut sims: Vec<NodeSim> = Vec::with_capacity(n);
    for (((m, sc), cf), ca) in node_models
        .iter()
        .zip(scfgs.iter())
        .zip(cfgs.iter())
        .zip(caches.iter_mut())
    {
        sims.push(NodeSim::new(m, sc, pm, cf, ca)?);
    }

    // --- the global event loop ----------------------------------------
    let mig = &fcfg.migration;
    let migrate_on = n > 1 && fcfg.router == RouterPolicy::LeastLoaded;
    let mut pressure = Pressure::new(1, mig.window_cy);
    let mut owner = owner_of[heavy];
    let mut cooldown_until = 0u64;
    let mut migrations: Vec<FleetMigration> = Vec::new();
    loop {
        let mut next: Option<(u64, usize)> = None;
        for (j, s) in sims.iter_mut().enumerate() {
            if let Some(t) = s.next_event() {
                if next.map_or(true, |(bt, _)| t < bt) {
                    next = Some((t, j));
                }
            }
        }
        let Some((_, j)) = next else { break };
        let stepped = sims[j].step(&mut recs[j]);
        if !migrate_on {
            continue;
        }
        let Some(t) = stepped else { continue };
        // hot-spot detector: the heavy tenant's owner vs the coldest
        // other node, sampled at every fleet dispatch
        let hot = sims[owner].backlog_at(t) as u64;
        let mut cold = (u64::MAX, usize::MAX);
        for (k, s) in sims.iter().enumerate() {
            if k != owner {
                let b = s.backlog_at(t) as u64;
                if (b, k) < cold {
                    cold = (b, k);
                }
            }
        }
        let (cold_b, cold_n) = cold;
        if hot >= mig.hot_factor.saturating_mul(cold_b).saturating_add(mig.hot_margin) {
            pressure.record(0, t, 1);
        } else {
            pressure.clear(0);
        }
        pressure.age_out(0, t);
        if t >= cooldown_until && pressure.sustained_hi(0, t, 1) {
            pressure.clear(0);
            cooldown_until = t + mig.cooldown_cy;
            let local_from = rosters[owner].iter().position(|&g| g == heavy).unwrap();
            let moved = sims[owner].migrate_out(local_from);
            if moved.is_empty() {
                continue; // backlog was all in flight — nothing to move
            }
            let n_moved = moved.len();
            let local_to = rosters[cold_n].iter().position(|&g| g == heavy).unwrap();
            let (program_cycles, handoff_cycles, blocked_cycles) = sims[cold_n].migrate_in(
                local_to,
                moved,
                t,
                mig.handoff_cy_per_req,
                &mut recs[cold_n],
            );
            migrations.push(FleetMigration {
                tenant: models[heavy].net.name.clone(),
                from_node: owner,
                to_node: cold_n,
                t,
                moved: n_moved,
                program_cycles,
                handoff_cycles,
                blocked_cycles,
                streamed: scfg.stream_weights,
            });
            owner = cold_n;
        }
    }

    // --- fold ----------------------------------------------------------
    let mut nodes: Vec<NodeReport> = Vec::with_capacity(n);
    for ((ix, sim), rec) in sims.into_iter().enumerate().zip(recs.iter_mut()) {
        nodes.push(NodeReport {
            node: ix,
            arrays: node_arrays[ix],
            report: sim.into_report(rec),
        });
    }
    Ok(FleetReport {
        router: fcfg.router,
        nodes_n: n,
        seed: scfg.seed,
        cycle_ns,
        nodes,
        migrations,
    })
}

#[cfg(test)]
mod tests {
    use super::super::{bottleneck_fleet, mnv2_bottleneck_pair, simulate};
    use super::*;

    #[test]
    fn ring_assignment_is_pinned() {
        // the ring is part of the routing contract: these assignments are
        // frozen (recomputed independently from the FNV-1a definition)
        let r4 = hash_ring(4);
        assert_eq!(ring_assign(&r4, "mobilenetv2"), 2);
        assert_eq!(ring_assign(&r4, "bottleneck"), 3);
        for i in 0..8 {
            assert_eq!(ring_assign(&r4, &format!("bn-{i}")), 3, "bn-{i}");
        }
        let r1 = hash_ring(1);
        for name in ["mobilenetv2", "bottleneck", "bn-0"] {
            assert_eq!(ring_assign(&r1, name), 0);
        }
        let r2 = hash_ring(2);
        assert_eq!(ring_assign(&r2, "mobilenetv2"), 1);
        assert_eq!(ring_assign(&r2, "bottleneck"), 1);
        // ring size and determinism
        assert_eq!(r4.len(), 4 * VNODES);
        assert_eq!(hash_ring(4), r4);
    }

    #[test]
    fn least_loaded_assign_is_capacity_aware() {
        // heaviest first to the big node; the rest water-fill the small
        // node once the big one carries the hot tenant
        assert_eq!(least_loaded_assign(&[100, 10, 10], &[64, 12]), vec![0, 1, 1]);
        // equal caps, equal loads: ties break to the lower node id in
        // descending-load order
        assert_eq!(least_loaded_assign(&[5, 5], &[32, 32]), vec![0, 1]);
        // one node takes everything
        assert_eq!(least_loaded_assign(&[7, 3], &[64]), vec![0, 0]);
    }

    #[test]
    fn two_node_fleet_conserves_arrivals_under_every_router() {
        let pm = PowerModel::paper();
        let models = bottleneck_fleet(3, 200.0);
        let scfg = ServeConfig {
            duration_s: 0.02,
            ..ServeConfig::default()
        };
        let solo = simulate(&models, &scfg, &pm).unwrap();
        let offered: u64 = solo.tenants.iter().map(|t| t.arrivals).sum();
        assert!(offered > 0);
        for router in [
            RouterPolicy::Hash,
            RouterPolicy::LeastLoaded,
            RouterPolicy::Replica,
        ] {
            let fcfg = FleetConfig::new(2, router);
            let rep = simulate_fleet(&models, &scfg, &fcfg, &pm).unwrap();
            assert_eq!(rep.nodes.len(), 2, "{}", router.label());
            // sharding loses no offered load…
            assert_eq!(rep.total_arrivals(), offered, "{}", router.label());
            // …and every arrival is accounted for
            assert_eq!(
                rep.total_served() + rep.total_dropped() + rep.total_rejected(),
                rep.total_arrivals(),
                "{}",
                router.label()
            );
            // byte-determinism of the rendered artifacts
            let again = simulate_fleet(&models, &scfg, &fcfg, &pm).unwrap();
            assert_eq!(
                rep.render_table(),
                again.render_table(),
                "{}",
                router.label()
            );
            assert_eq!(
                rep.to_json().to_string_pretty(),
                again.to_json().to_string_pretty(),
                "{}",
                router.label()
            );
        }
    }

    #[test]
    fn single_node_fleet_matches_the_single_cluster_path() {
        let pm = PowerModel::paper();
        let models = mnv2_bottleneck_pair(120.0);
        let scfg = ServeConfig {
            duration_s: 0.02,
            ..ServeConfig::default()
        };
        let solo = simulate(&models, &scfg, &pm).unwrap();
        for router in [
            RouterPolicy::Hash,
            RouterPolicy::LeastLoaded,
            RouterPolicy::Replica,
        ] {
            let rep = simulate_fleet(&models, &scfg, &FleetConfig::new(1, router), &pm).unwrap();
            assert!(rep.migrations.is_empty());
            assert_eq!(
                rep.nodes[0].report.render_table(),
                solo.render_table(),
                "{}",
                router.label()
            );
            assert_eq!(
                rep.nodes[0].report.to_json().to_string_pretty(),
                solo.to_json().to_string_pretty(),
                "{}",
                router.label()
            );
        }
    }

    #[test]
    fn fleet_rejects_bad_configs() {
        let pm = PowerModel::paper();
        let models = bottleneck_fleet(2, 50.0);
        let scfg = ServeConfig {
            duration_s: 0.005,
            ..ServeConfig::default()
        };
        assert!(simulate_fleet(&models, &scfg, &FleetConfig::new(0, RouterPolicy::Hash), &pm)
            .is_err());
        let mut fc = FleetConfig::new(2, RouterPolicy::Hash);
        fc.node_arrays = vec![64]; // wrong length
        assert!(simulate_fleet(&models, &scfg, &fc, &pm).is_err());
        fc.node_arrays = vec![64, 0]; // empty node
        assert!(simulate_fleet(&models, &scfg, &fc, &pm).is_err());
        let auto_cfg = ServeConfig {
            autoscale: true,
            ..scfg.clone()
        };
        assert!(simulate_fleet(
            &models,
            &auto_cfg,
            &FleetConfig::new(2, RouterPolicy::Hash),
            &pm
        )
        .is_err());
    }
}
