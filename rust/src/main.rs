//! `imcc` — CLI for the heterogeneous in-memory computing cluster.
//!
//! Every figure/table of the paper regenerates from a subcommand; `all`
//! writes the full machine-readable report set used by EXPERIMENTS.md.

use imcc::arch::{ExecModel, FreqPoint, PowerModel, SystemConfig};
use imcc::report;
use imcc::util::cli::Args;
use imcc::util::json::{obj, Json};

const USAGE: &str = "\
imcc — heterogeneous in-memory computing cluster (Garofalo et al. 2022 reproduction)

USAGE: imcc <command> [options]

commands (one per paper exhibit):
  area                    Fig. 6b   cluster area breakdown
  roofline                Fig. 7    IMA roofline (3 panels x 5 bus widths)
  bottleneck              Fig. 9/10 Bottleneck case study, all five mappings
  tilepack                Alg. 1    TILE&PACK of MobileNetV2 onto crossbars
  e2e                     Fig. 12   end-to-end MobileNetV2 on the scaled-up system
  table1                  Table I   comparison with the state of the art
  ablate                  DESIGN.md §8 ablations (exec model, C_job, bus, L1/DMA, PCM programming)
  fig13                   Fig. 13   four IMC computing models
  scaleup                 multi-array serving: pool-size × batch sweep, or one
                          point with --arrays N --batch B
  serve                   event-driven multi-model serving: open-loop traffic
                          into one pool, dynamic batching, latency percentiles
                          (--sweep for the rate × policy table; --nodes N
                          for a routed fleet of independent clusters)
  bench-timeline          long-horizon timeline perf harness: multi-tenant
                          serve at several horizons, pruned vs --no-prune,
                          wall-clock + deterministic counters; exits non-zero
                          on any dispatch divergence or counter regression
  infer [--tiny]          functional MobileNetV2 inference (bit-exact vs the
                          JAX golden logits when artifacts are present)
  all [--json FILE]       run everything; optionally dump JSON

options:
  --freq-mhz {500|250}    operating point            (default 500)
  --bus BITS              IMA data-interface width   (default 128)
  --sequential            sequential IMA execution   (default pipelined)
  --artifacts DIR         artifacts directory        (default ./artifacts)
  --noise SIGMA           PCM conductance noise for `infer` (default 0)
  --arrays N              `scaleup`/`serve`: crossbar arrays in the pool
  --batch N               `scaleup`: batched requests per serving cycle;
                          `infer`: serve N back-to-back requests
  --no-pipeline           `scaleup`/`serve`: disable request pipelining
  --models A,B            `serve`: comma list (mobilenetv2|bottleneck)
  --rate R                `serve`: Poisson arrivals per second per model (50)
  --policy P              `serve`: arbitration fifo|wrr|sjf    (default fifo)
  --duration D            `serve`: arrival horizon in seconds  (default 0.25)
  --seed S                `serve`: traffic seed                (default 0xc0ffee00)
  --max-batch B           `serve`: admission window width      (default 8)
  --max-wait-us W         `serve`: admission window wait cap   (default 200)
  --traffic T             `serve`: poisson|bursty              (default poisson)
  --deadline-ms D         `serve`: abandon after D ms waiting  (default off)
  --weights A,B           `serve`: WRR weights per model       (default 1,1)
  --no-overlap            `serve`: serialize batches on the pool (the PR 2
                          model; default is per-resource backfilled dispatch)
  --no-backfill           `serve`: conservative envelope reservations (the
                          PR 3 model; default backfills batches into idle
                          gaps of committed reservations)
  --no-prune              `serve`: keep the full committed interval history
                          instead of folding intervals behind the watermark
                          (dispatch tables are bit-identical either way;
                          only counters move; `bench-timeline` always runs
                          both modes and rejects the flag)
  --event-queue Q         `serve`: next-event structure, calendar|heap
                          (default calendar; heap is the pre-calendar
                          behavior — dispatch tables, serve JSON, and
                          trace bytes are bit-identical either way;
                          `bench-timeline` always runs both and rejects
                          the flag)
  --no-gap-skip           `serve`: disable the timeline's gap-search fast
                          paths (append-at-tail, no-usable-gap); dispatch
                          decisions are identical either way — only the
                          `probes` counter moves. `bench-timeline` runs
                          both modes and rejects the flag
  --stream-weights        `serve`/`scaleup`: stream staged PCM reprogramming
                          under the previous pass's compute tail
  --slo-p95 CY            `serve`: p95 latency budget in cycles; arrivals
                          predicted to blow it are refused at the front
                          door instead of queueing (default off). JSON
                          gains `rejected` totals and per-tenant `slo_p95`
  --no-admission          `serve`: keep the --slo-p95 budget as a config
                          echo but never refuse a request at the door
  --autoscale             `serve`: online pool resizing — sustained backlog
                          grows a tenant's array slice out of the free run
                          (sustained idle shrinks it), re-planning through
                          the plan cache and charging PCM reprogramming of
                          the moved arrays (streamed with --stream-weights);
                          JSON gains the `scale_events` decision trace
  --no-autoscale          `serve`: pin the resizing controller off (the
                          controlled-vs-uncontrolled baseline switch)
  --headroom N            `serve`: hold N arrays back from the initial
                          carve for the autoscaler to hand out (default 0)
  --nodes N               `serve`: shard across N independent nodes behind
                          a routing front-end (default 1 = the single-
                          cluster path, bit-identical to omitting the
                          flag; --router is accepted and ignored at N=1)
  --router P              `serve`: fleet routing with --nodes N > 1,
                          hash|least-loaded|replica (default hash);
                          least-loaded also arms the cross-node tenant
                          migration controller
  --node-arrays A,B,..    `serve`: per-node pool sizes for a heterogeneous
                          fleet (comma list of length N; default --arrays
                          everywhere). Traces export per node as
                          FILE-node<i>.json
  --faults SPEC           `serve`: deterministic fault plan for a fleet
                          (--nodes N > 1) — comma list of
                          crash@nodeN:T[..T2] | drain@nodeN:T[..T2] |
                          update@nodeN:T..T2 | degrade@nodeN:T..T2xF |
                          arrayfail@nodeN:TxK, instants in cycles (5e6
                          ok). Queued work fails over to survivors;
                          crashes lose in-flight batches (lost_in_crash)
  --fault-seed S          `serve`: draw a seeded random crash/recover
                          plan instead (MTBF = half the horizon); the
                          drawn plan is echoed for replay via --faults
  --tenants N             `bench-timeline`: fleet size          (default 4)
  --trace [FILE]          `serve`: record a deterministic execution trace
                          and export it as Chrome trace_event JSON (open
                          at ui.perfetto.dev or chrome://tracing; default
                          file BENCH_trace.json) plus a summary line.
                          Tracing never perturbs the run — tables, serve
                          JSON, and counters are bit-identical on or off
  --trace-limit N         `serve`: cap recorded trace events at N; past it
                          the oldest events are dropped and counted in the
                          export's `truncated_events` (default 1048576)
  --json [FILE]           `scaleup`/`serve`/`bench-timeline`: also write a
                          machine-readable bench baseline (default
                          BENCH_scaleup.json / BENCH_serve.json /
                          BENCH_timeline.json)
  --sweep                 `serve`: rate × policy percentile table over the
                          default model pair; honors only --arrays --rate
                          --policy --duration --seed --no-overlap
                          --no-backfill --json (--trace is accepted but
                          sweeps skip the export)
";

fn config_from(args: &Args) -> SystemConfig {
    let mut cfg = SystemConfig::paper();
    if args.opt_parse("freq-mhz", 500u32) == 250 {
        cfg = cfg.with_freq(FreqPoint::LOW);
    }
    cfg = cfg.with_bus_bits(args.opt_parse("bus", 128usize));
    if args.flag("sequential") {
        cfg = cfg.with_exec(ExecModel::Sequential);
    }
    cfg
}

fn parse_seed(s: &str) -> Result<u64, String> {
    let r = if let Some(hex) = s.strip_prefix("0x") {
        u64::from_str_radix(hex, 16)
    } else {
        s.parse::<u64>()
    };
    r.map_err(|_| format!("bad seed `{s}`"))
}

/// `--json FILE` names the output; bare `--json` picks `default`; absent
/// means no baseline file.
fn json_out(args: &Args, default: &str) -> Option<String> {
    match args.opt("json") {
        Some(p) => Some(p.to_string()),
        None if args.flag("json") => Some(default.to_string()),
        None => None,
    }
}

fn write_json(path: &str, doc: &Json) -> Result<(), String> {
    std::fs::write(path, doc.to_string_pretty()).map_err(|e| format!("write {path}: {e}"))?;
    println!("wrote {path}");
    Ok(())
}

/// `imcc serve --sweep`: the rate × policy percentile table, honoring the
/// serve flags that apply to a sweep (`--arrays --rate --policy
/// --duration --seed --no-overlap --no-backfill --json`).
fn run_serve_sweep(args: &Args, pm: &PowerModel) -> Result<(), String> {
    use imcc::serve::{Policy, DEFAULT_SEED};

    if args.flag("overlap") && args.flag("no-overlap") {
        return Err("--overlap and --no-overlap are mutually exclusive".into());
    }
    if args.flag("backfill") && args.flag("no-backfill") {
        return Err("--backfill and --no-backfill are mutually exclusive".into());
    }
    if args.flag("stream-weights") {
        return Err(
            "--stream-weights is not supported with --sweep (the default \
             model pair is fully resident; nothing reprograms)"
                .into(),
        );
    }
    let overlap = !args.flag("no-overlap");
    let backfill = !args.flag("no-backfill");
    let arrays: usize = args.opt_parse("arrays", 64usize);
    let duration_s: f64 = args.opt_parse("duration", 0.25);
    let seed = match args.opt("seed") {
        None => DEFAULT_SEED,
        Some(s) => parse_seed(s)?,
    };
    let rates: Vec<f64> = match args.opt("rate") {
        None => report::serving::DEFAULT_RATES.to_vec(),
        Some(_) => vec![args.opt_parse("rate", 50.0)],
    };
    let policies: Vec<Policy> = match args.opt("policy") {
        None => report::serving::DEFAULT_POLICIES.to_vec(),
        Some(p) => vec![Policy::parse(p)?],
    };
    if args.opt("trace").is_some() || args.flag("trace") {
        println!(
            "note: --sweep skips trace export; every point still runs the \
             no-op recorder path (use `serve --trace` for a single run)"
        );
    }
    let rep = report::serving::generate_sweep(
        pm,
        arrays,
        &rates,
        &policies,
        duration_s,
        seed,
        overlap,
        backfill,
    );
    rep.print();
    if let Some(path) = json_out(args, "BENCH_serve.json") {
        let doc = obj([("bench", "serve_sweep".into()), ("points", rep.data)]);
        write_json(&path, &doc)?;
    }
    Ok(())
}

/// `imcc serve`: one serving simulation, per-model percentile table out.
fn run_serve(args: &Args, pm: &PowerModel) -> Result<(), String> {
    use imcc::serve::{
        self, BatchWindow, ModelTraffic, Policy, ServeConfig, TrafficModel, DEFAULT_SEED,
    };

    let models_arg = args.opt("models").unwrap_or("mobilenetv2,bottleneck");
    let rate: f64 = args.opt_parse("rate", 50.0);
    let policy = Policy::parse(args.opt("policy").unwrap_or("fifo"))?;
    let duration_s: f64 = args.opt_parse("duration", 0.25);
    let arrays: usize = args.opt_parse("arrays", 64usize);
    let max_batch: usize = args.opt_parse("max-batch", 8usize);
    let max_wait_us: f64 = args.opt_parse("max-wait-us", 200.0);
    let deadline_ms: f64 = args.opt_parse("deadline-ms", 0.0);
    let traffic_kind = args.opt("traffic").unwrap_or("poisson");
    let seed = match args.opt("seed") {
        None => DEFAULT_SEED,
        Some(s) => parse_seed(s)?,
    };
    let weights: Vec<u64> = match args.opt("weights") {
        None => Vec::new(),
        Some(w) => w
            .split(',')
            .map(|x| match x.trim().parse::<u64>() {
                Ok(0) | Err(_) => Err(format!("bad weight `{x}` (integer ≥ 1)")),
                Ok(v) => Ok(v),
            })
            .collect::<Result<_, _>>()?,
    };

    // wall-clock → cycle conversion from the same config the simulator
    // will run under, so the two can never drift
    let cycle_ns = SystemConfig::scaled_up(arrays).freq.cycle_ns();
    let mut models = Vec::new();
    for (i, name) in models_arg.split(',').enumerate() {
        let net = serve::model_by_name(name)?;
        let traffic = match traffic_kind {
            "poisson" => TrafficModel::Poisson { rate_per_s: rate },
            "bursty" => TrafficModel::Bursty {
                rate_per_s: rate,
                burst: 4.0,
                dwell_s: 0.01,
            },
            other => return Err(format!("unknown traffic `{other}` (poisson|bursty)")),
        };
        let weight = weights.get(i).copied().unwrap_or(1);
        models.push(ModelTraffic {
            net,
            traffic,
            weight,
        });
    }

    if args.flag("overlap") && args.flag("no-overlap") {
        return Err("--overlap and --no-overlap are mutually exclusive".into());
    }
    if args.flag("backfill") && args.flag("no-backfill") {
        return Err("--backfill and --no-backfill are mutually exclusive".into());
    }
    if args.flag("prune") && args.flag("no-prune") {
        return Err("--prune and --no-prune are mutually exclusive".into());
    }
    if args.flag("autoscale") && args.flag("no-autoscale") {
        return Err("--autoscale and --no-autoscale are mutually exclusive".into());
    }
    if args.flag("gap-skip") && args.flag("no-gap-skip") {
        return Err("--gap-skip and --no-gap-skip are mutually exclusive".into());
    }
    let event_queue = match args.opt("event-queue") {
        None => imcc::serve::EventQueueKind::default(),
        Some(s) => imcc::serve::EventQueueKind::parse(s)
            .ok_or_else(|| format!("unknown event queue `{s}` (calendar|heap)"))?,
    };
    let scfg = ServeConfig {
        n_arrays: arrays,
        policy,
        window: BatchWindow {
            max_batch,
            max_wait_cy: (max_wait_us * 1e3 / cycle_ns) as u64,
        },
        pipeline: !args.flag("no-pipeline"),
        overlap: !args.flag("no-overlap"),
        backfill: !args.flag("no-backfill"),
        stream_weights: args.flag("stream-weights"),
        prune: !args.flag("no-prune"),
        event_queue,
        gap_skip: !args.flag("no-gap-skip"),
        seed,
        duration_s,
        deadline_cy: (deadline_ms * 1e6 / cycle_ns) as u64,
        slo_p95_cy: args.opt_parse("slo-p95", 0u64),
        admission: !args.flag("no-admission"),
        autoscale: args.flag("autoscale"),
        headroom: args.opt_parse("headroom", 0usize),
        ..ServeConfig::default()
    };
    // trace export mirrors --json: `--trace FILE` names it, bare
    // `--trace` picks the default, absent = the zero-overhead recorder
    let trace_path = match args.opt("trace") {
        Some(p) => Some(p.to_string()),
        None if args.flag("trace") => Some("BENCH_trace.json".to_string()),
        None => None,
    };
    let trace_limit: usize =
        args.opt_parse("trace-limit", imcc::serve::trace::DEFAULT_TRACE_LIMIT);
    let nodes: usize = args.opt_parse("nodes", 1usize);
    if nodes == 0 {
        return Err("--nodes needs at least one node".into());
    }
    if nodes > 1 {
        return run_serve_fleet(args, pm, &models, &scfg, nodes, trace_path, trace_limit);
    }
    // `--nodes 1` (with any --router) is the pinned single-cluster path
    // below, bit-identical to omitting the flag; per-node sizing only
    // makes sense for a fleet
    if args.opt("node-arrays").is_some() {
        return Err("--node-arrays needs --nodes N > 1 (use --arrays for one node)".into());
    }
    if args.opt("faults").is_some() || args.opt("fault-seed").is_some() {
        return Err(
            "--faults/--fault-seed inject node failures into a fleet; they need --nodes N > 1"
                .into(),
        );
    }
    let mut rec = if trace_path.is_some() {
        serve::TraceRecorder::on(trace_limit)
    } else {
        serve::TraceRecorder::Off
    };
    let mut cache = imcc::coordinator::PlanCache::with_capacity(scfg.plan_cache_cap);
    let rep = serve::simulate_traced(&models, &scfg, pm, &mut cache, &mut rec)?;
    print!("{}", rep.render_table());
    print!("{}", rep.render_breakdown());
    let makespan_s = rep.makespan_cycles as f64 * rep.cycle_ns * 1e-9;
    println!(
        "{} served / {} dropped / {} rejected over {:.1} ms makespan — {:.1} inf/s aggregate",
        rep.total_served(),
        rep.total_dropped(),
        rep.total_rejected(),
        makespan_s * 1e3,
        rep.inferences_per_s(),
    );
    let c = rep.counters;
    println!(
        "counters: {} steps, {} validations, {} probe steps, {} live / {} peak / {} pruned \
         interval nodes, {} evq pushes ({} stale pops)",
        c.steps,
        c.validations,
        c.probes,
        c.live_intervals,
        c.peak_live_intervals,
        c.pruned_intervals,
        c.evq_pushes,
        c.evq_stale
    );
    if let Some(path) = trace_path {
        let tr = rec.finish().expect("recorder was on");
        print!("{}", tr.render_summary());
        write_json(&path, &imcc::serve::trace::chrome_trace(&rep, &tr))?;
    }
    if let Some(path) = json_out(args, "BENCH_serve.json") {
        write_json(&path, &rep.to_json())?;
    }
    Ok(())
}

/// `imcc serve --nodes N` with N > 1: the fleet path — route the global
/// arrival streams across N independent nodes, run them under one
/// deterministic event loop, and print the fleet summary table above
/// every node's single-cluster table.
fn run_serve_fleet(
    args: &Args,
    pm: &PowerModel,
    models: &[imcc::serve::ModelTraffic],
    scfg: &imcc::serve::ServeConfig,
    nodes: usize,
    trace_path: Option<String>,
    trace_limit: usize,
) -> Result<(), String> {
    use imcc::serve::{self, FleetConfig, RouterPolicy};

    let router = RouterPolicy::parse(args.opt("router").unwrap_or("hash"))?;
    let mut fcfg = FleetConfig::new(nodes, router);
    if let Some(s) = args.opt("node-arrays") {
        fcfg.node_arrays = serve::parse_node_arrays(s, nodes)?;
    }
    match (args.opt("faults"), args.opt("fault-seed")) {
        (Some(_), Some(_)) => {
            return Err(
                "--faults and --fault-seed are mutually exclusive: one names the plan, \
                 the other draws it"
                    .into(),
            );
        }
        (Some(spec), None) => fcfg.faults = serve::FaultPlan::parse(spec)?,
        (None, Some(s)) => {
            // a seeded crash/recover plan over the arrival horizon with
            // MTBF = horizon/2 — roughly one crash per node; echo the
            // drawn plan so the run can be replayed with --faults
            let fseed = parse_seed(s)?;
            let cycle_ns = SystemConfig::scaled_up(scfg.n_arrays).freq.cycle_ns();
            let horizon_cy = (scfg.duration_s * 1e9 / cycle_ns) as u64;
            fcfg.faults = serve::FaultPlan::seeded(fseed, nodes, horizon_cy, horizon_cy / 2);
            println!("fault plan (seed {fseed:#x}): {}", fcfg.faults.describe());
        }
        (None, None) => {}
    }
    let mut recs: Vec<serve::TraceRecorder> = (0..nodes)
        .map(|_| {
            if trace_path.is_some() {
                serve::TraceRecorder::on(trace_limit)
            } else {
                serve::TraceRecorder::Off
            }
        })
        .collect();
    let rep = serve::simulate_fleet_traced(models, scfg, &fcfg, pm, &mut recs)?;
    print!("{}", rep.render_table());
    for nr in &rep.nodes {
        print!("{}", nr.report.render_table());
    }
    if let Some(path) = trace_path {
        for (nr, rec) in rep.nodes.iter().zip(recs.into_iter()) {
            let tr = rec.finish().expect("recorder was on");
            let node_path = node_trace_path(&path, nr.node);
            write_json(&node_path, &imcc::serve::trace::chrome_trace(&nr.report, &tr))?;
        }
    }
    if let Some(path) = json_out(args, "BENCH_serve.json") {
        write_json(&path, &rep.to_json())?;
    }
    Ok(())
}

/// Per-node trace filenames: `trace.json` → `trace-node2.json` (the
/// suffix lands before the extension so the files sort as a family).
fn node_trace_path(path: &str, ix: usize) -> String {
    match path.rfind('.') {
        Some(dot) if dot > 0 => format!("{}-node{}{}", &path[..dot], ix, &path[dot..]),
        _ => format!("{path}-node{ix}"),
    }
}

/// `imcc bench-timeline`: the long-horizon timeline perf harness —
/// multi-tenant serve at several horizons, pruned vs unpruned, wall-clock
/// and deterministic counters; errors (non-zero exit) on any dispatch
/// divergence or counter regression.
fn run_bench_timeline(args: &Args, pm: &PowerModel) -> Result<(), String> {
    use imcc::serve::DEFAULT_SEED;

    if args.flag("prune") || args.flag("no-prune") {
        return Err(
            "bench-timeline always runs pruned and unpruned side by side; drop \
             --prune/--no-prune (use `serve --no-prune` for a single mode)"
                .into(),
        );
    }
    if args.opt("event-queue").is_some() || args.flag("event-queue") {
        return Err(
            "bench-timeline always runs the calendar and heap queues side by side; drop \
             --event-queue (use `serve --event-queue heap` for a single mode)"
                .into(),
        );
    }
    if args.flag("gap-skip") || args.flag("no-gap-skip") {
        return Err(
            "bench-timeline always runs the gap-skip fast paths on and off side by side; \
             drop --gap-skip/--no-gap-skip (use `serve --no-gap-skip` for a single mode)"
                .into(),
        );
    }
    let tenants: usize = args.opt_parse("tenants", 4usize);
    let rate: f64 = args.opt_parse("rate", 150.0);
    let duration_s: f64 = args.opt_parse("duration", 0.25);
    let seed = match args.opt("seed") {
        None => DEFAULT_SEED,
        Some(s) => parse_seed(s)?,
    };
    let rep = report::bench_timeline::generate(pm, tenants, rate, duration_s, seed)?;
    rep.print();
    if let Some(path) = json_out(args, "BENCH_timeline.json") {
        write_json(&path, &rep.data)?;
    }
    Ok(())
}

/// `imcc scaleup`: the pool-size × batch sweep, or one point with
/// `--arrays`/`--batch`; `--stream-weights` and `--json` apply to both.
fn run_scaleup(args: &Args, pm: &PowerModel) -> Result<(), String> {
    let pipeline = !args.flag("no-pipeline");
    let stream = args.flag("stream-weights");
    match (args.opt("arrays"), args.opt("batch")) {
        (None, None) => {
            let rep = report::scaleup::generate_sweep(
                pm,
                report::scaleup::DEFAULT_ARRAYS,
                report::scaleup::DEFAULT_BATCHES,
                pipeline,
                stream,
            );
            rep.print();
            if let Some(path) = json_out(args, "BENCH_scaleup.json") {
                let doc = obj([("bench", "scaleup".into()), ("points", rep.data)]);
                write_json(&path, &doc)?;
            }
        }
        _ => {
            let arrays: usize = args.opt_parse("arrays", 34usize);
            let batch: usize = args.opt_parse("batch", 1usize);
            let rep = report::scaleup::run_point(pm, arrays, batch, pipeline, stream)?;
            let mode = match (rep.pipelined, stream) {
                (true, true) => "pipelined, streamed",
                (true, false) => "pipelined",
                (false, true) => "strict, streamed",
                (false, false) => "strict",
            };
            println!(
                "scale-up: {} on {arrays} arrays, batch {batch} ({mode}) — \
                 {} passes, {} cycles ({} reprogramming), {:.1} inf/s, \
                 {:.2}x vs sequential, bottleneck `{}`",
                rep.network,
                rep.n_passes,
                rep.cycles,
                rep.reprogram_cycles,
                rep.inferences_per_s(),
                rep.speedup_vs_sequential(),
                rep.bottleneck_layer
            );
            if let Some(path) = json_out(args, "BENCH_scaleup.json") {
                let doc = obj([
                    ("bench", "scaleup_point".into()),
                    ("arrays", arrays.into()),
                    ("batch", batch.into()),
                    ("pipelined", rep.pipelined.into()),
                    ("stream_weights", stream.into()),
                    ("passes", rep.n_passes.into()),
                    ("cycles", (rep.cycles as f64).into()),
                    ("reprogram_cycles", (rep.reprogram_cycles as f64).into()),
                    ("dma_cycles", (rep.dma_cycles as f64).into()),
                    ("inf_per_s", rep.inferences_per_s().into()),
                    ("speedup_vs_sequential", rep.speedup_vs_sequential().into()),
                    ("bottleneck", rep.bottleneck_layer.clone().into()),
                ]);
                write_json(&path, &doc)?;
            }
        }
    }
    Ok(())
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let pm = PowerModel::paper();
    let cfg = config_from(&args);

    let Some(cmd) = args.subcommand.clone() else {
        eprint!("{USAGE}");
        std::process::exit(2);
    };

    match cmd.as_str() {
        "area" => report::fig6_area::generate(&cfg).print(),
        "roofline" => report::fig7_roofline::generate().print(),
        "bottleneck" => {
            report::fig9_bottleneck::generate(&cfg, &pm).print();
            if args.flag("breakdown") {
                report::fig10_breakdown::generate(&cfg, &pm).print();
            }
        }
        "tilepack" => {
            let net = imcc::net::mobilenetv2::mobilenet_v2(224);
            let tiles = imcc::tilepack::tile_network(&net, 256);
            let p = imcc::tilepack::pack(&tiles, 256, args.flag("rotate"));
            println!(
                "TILE&PACK: {} tiles from {} layers -> {} crossbars (paper: 34)",
                tiles.len(),
                net.layers.len(),
                p.n_bins()
            );
            for (i, u) in p.utilizations().iter().enumerate() {
                println!("  IMA {i:>2}: {:>5.1}% utilized", u * 100.0);
            }
        }
        "e2e" => report::fig12_e2e::generate(&pm).print(),
        "ablate" => report::ablations::generate(&pm).print(),
        "table1" => report::table1::generate(&pm).print(),
        "fig13" => report::fig13_models::generate(&pm).print(),
        "scaleup" => {
            if let Err(e) = run_scaleup(&args, &pm) {
                eprintln!("scale-up failed: {e}");
                std::process::exit(1);
            }
        }
        "serve" => {
            let run = if args.flag("sweep") {
                run_serve_sweep(&args, &pm)
            } else {
                run_serve(&args, &pm)
            };
            if let Err(e) = run {
                eprintln!("serve failed: {e}");
                std::process::exit(1);
            }
        }
        "bench-timeline" => {
            if let Err(e) = run_bench_timeline(&args, &pm) {
                eprintln!("bench-timeline failed: {e}");
                std::process::exit(1);
            }
        }
        "infer" => {
            let dir = args.opt("artifacts").unwrap_or("artifacts").to_string();
            let tiny = args.flag("tiny");
            let sigma: f64 = args.opt_parse("noise", 0.0);
            match imcc::runtime::functional::run_manifest_inference(&dir, tiny, sigma) {
                Ok(summary) => println!("{summary}"),
                Err(e) => {
                    eprintln!("inference failed: {e}");
                    std::process::exit(1);
                }
            }
            let batch: usize = args.opt_parse("batch", 0usize);
            if batch > 0 {
                // serving loop: weights stay programmed, N back-to-back requests
                let m = imcc::runtime::Manifest::load(&dir, tiny).unwrap();
                let mut rt = imcc::runtime::Runtime::load(&dir).unwrap();
                imcc::runtime::functional::program_network(&mut rt, &m, sigma).unwrap();
                let per = imcc::runtime::functional::serve_batch(&rt, &m, batch).unwrap();
                println!(
                    "serving: {batch} requests, {:.1} ms/inference amortized -> {:.1} inf/s host",
                    per * 1e3,
                    1.0 / per
                );
            }
        }
        "all" => {
            let reports = vec![
                report::fig6_area::generate(&cfg),
                report::fig7_roofline::generate(),
                report::fig9_bottleneck::generate(&cfg, &pm),
                report::fig10_breakdown::generate(&cfg, &pm),
                report::fig12_e2e::generate(&pm),
                report::ablations::generate(&pm),
                report::table1::generate(&pm),
                report::fig13_models::generate(&pm),
                report::scaleup::generate(&pm),
                report::serving::generate(&pm),
                report::serving::generate_controlled(&pm),
                report::serving::generate_fleet(&pm),
                report::serving::generate_faults(&pm),
            ];
            let mut all = Vec::new();
            for r in &reports {
                r.print();
                println!();
                all.push(obj([
                    ("title", r.title.as_str().into()),
                    ("data", r.data.clone()),
                ]));
            }
            if let Some(path) = args.opt("json") {
                let doc = Json::Arr(all).to_string_pretty();
                std::fs::write(path, doc).expect("write json");
                println!("wrote {path}");
            }
        }
        "help" | "--help" => print!("{USAGE}"),
        other => {
            eprintln!("unknown command `{other}`\n{USAGE}");
            std::process::exit(2);
        }
    }
}
