//! `imcc` — CLI for the heterogeneous in-memory computing cluster.
//!
//! Every figure/table of the paper regenerates from a subcommand; `all`
//! writes the full machine-readable report set used by EXPERIMENTS.md.

use imcc::arch::{ExecModel, FreqPoint, PowerModel, SystemConfig};
use imcc::report;
use imcc::util::cli::Args;
use imcc::util::json::{obj, Json};

const USAGE: &str = "\
imcc — heterogeneous in-memory computing cluster (Garofalo et al. 2022 reproduction)

USAGE: imcc <command> [options]

commands (one per paper exhibit):
  area                    Fig. 6b   cluster area breakdown
  roofline                Fig. 7    IMA roofline (3 panels x 5 bus widths)
  bottleneck              Fig. 9/10 Bottleneck case study, all five mappings
  tilepack                Alg. 1    TILE&PACK of MobileNetV2 onto crossbars
  e2e                     Fig. 12   end-to-end MobileNetV2 on the scaled-up system
  table1                  Table I   comparison with the state of the art
  ablate                  DESIGN.md §8 ablations (exec model, C_job, bus, L1/DMA, PCM programming)
  fig13                   Fig. 13   four IMC computing models
  scaleup                 multi-array serving: pool-size × batch sweep, or one
                          point with --arrays N --batch B
  infer [--tiny]          functional MobileNetV2 inference (bit-exact vs the
                          JAX golden logits when artifacts are present)
  all [--json FILE]       run everything; optionally dump JSON

options:
  --freq-mhz {500|250}    operating point            (default 500)
  --bus BITS              IMA data-interface width   (default 128)
  --sequential            sequential IMA execution   (default pipelined)
  --artifacts DIR         artifacts directory        (default ./artifacts)
  --noise SIGMA           PCM conductance noise for `infer` (default 0)
  --arrays N              `scaleup`: crossbar arrays in the pool
  --batch N               `scaleup`: batched requests per serving cycle;
                          `infer`: serve N back-to-back requests
  --no-pipeline           `scaleup`: disable request pipelining
";

fn config_from(args: &Args) -> SystemConfig {
    let mut cfg = SystemConfig::paper();
    if args.opt_parse("freq-mhz", 500u32) == 250 {
        cfg = cfg.with_freq(FreqPoint::LOW);
    }
    cfg = cfg.with_bus_bits(args.opt_parse("bus", 128usize));
    if args.flag("sequential") {
        cfg = cfg.with_exec(ExecModel::Sequential);
    }
    cfg
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let pm = PowerModel::paper();
    let cfg = config_from(&args);

    let Some(cmd) = args.subcommand.clone() else {
        eprint!("{USAGE}");
        std::process::exit(2);
    };

    match cmd.as_str() {
        "area" => report::fig6_area::generate(&cfg).print(),
        "roofline" => report::fig7_roofline::generate().print(),
        "bottleneck" => {
            report::fig9_bottleneck::generate(&cfg, &pm).print();
            if args.flag("breakdown") {
                report::fig10_breakdown::generate(&cfg, &pm).print();
            }
        }
        "tilepack" => {
            let net = imcc::net::mobilenetv2::mobilenet_v2(224);
            let tiles = imcc::tilepack::tile_network(&net, 256);
            let p = imcc::tilepack::pack(&tiles, 256, args.flag("rotate"));
            println!(
                "TILE&PACK: {} tiles from {} layers -> {} crossbars (paper: 34)",
                tiles.len(),
                net.layers.len(),
                p.n_bins()
            );
            for (i, u) in p.utilizations().iter().enumerate() {
                println!("  IMA {i:>2}: {:>5.1}% utilized", u * 100.0);
            }
        }
        "e2e" => report::fig12_e2e::generate(&pm).print(),
        "ablate" => report::ablations::generate(&pm).print(),
        "table1" => report::table1::generate(&pm).print(),
        "fig13" => report::fig13_models::generate(&pm).print(),
        "scaleup" => match (args.opt("arrays"), args.opt("batch")) {
            (None, None) => report::scaleup::generate_sweep(
                &pm,
                report::scaleup::DEFAULT_ARRAYS,
                report::scaleup::DEFAULT_BATCHES,
                !args.flag("no-pipeline"),
            )
            .print(),
            _ => {
                let arrays: usize = args.opt_parse("arrays", 34usize);
                let batch: usize = args.opt_parse("batch", 1usize);
                let pipeline = !args.flag("no-pipeline");
                match report::scaleup::run_point(&pm, arrays, batch, pipeline) {
                    Ok(rep) => {
                        println!(
                            "scale-up: {} on {arrays} arrays, batch {batch} ({}) — \
                             {} passes, {} cycles ({} reprogramming), {:.1} inf/s, \
                             {:.2}x vs sequential, bottleneck `{}`",
                            rep.network,
                            if rep.pipelined { "pipelined" } else { "strict" },
                            rep.n_passes,
                            rep.cycles,
                            rep.reprogram_cycles,
                            rep.inferences_per_s(),
                            rep.speedup_vs_sequential(),
                            rep.bottleneck_layer
                        );
                    }
                    Err(e) => {
                        eprintln!("scale-up failed: {e}");
                        std::process::exit(1);
                    }
                }
            }
        },
        "infer" => {
            let dir = args.opt("artifacts").unwrap_or("artifacts").to_string();
            let tiny = args.flag("tiny");
            let sigma: f64 = args.opt_parse("noise", 0.0);
            match imcc::runtime::functional::run_manifest_inference(&dir, tiny, sigma) {
                Ok(summary) => println!("{summary}"),
                Err(e) => {
                    eprintln!("inference failed: {e}");
                    std::process::exit(1);
                }
            }
            let batch: usize = args.opt_parse("batch", 0usize);
            if batch > 0 {
                // serving loop: weights stay programmed, N back-to-back requests
                let m = imcc::runtime::Manifest::load(&dir, tiny).unwrap();
                let mut rt = imcc::runtime::Runtime::load(&dir).unwrap();
                imcc::runtime::functional::program_network(&mut rt, &m, sigma).unwrap();
                let per = imcc::runtime::functional::serve_batch(&rt, &m, batch).unwrap();
                println!(
                    "serving: {batch} requests, {:.1} ms/inference amortized -> {:.1} inf/s host",
                    per * 1e3,
                    1.0 / per
                );
            }
        }
        "all" => {
            let reports = vec![
                report::fig6_area::generate(&cfg),
                report::fig7_roofline::generate(),
                report::fig9_bottleneck::generate(&cfg, &pm),
                report::fig10_breakdown::generate(&cfg, &pm),
                report::fig12_e2e::generate(&pm),
                report::ablations::generate(&pm),
                report::table1::generate(&pm),
                report::fig13_models::generate(&pm),
                report::scaleup::generate(&pm),
            ];
            let mut all = Vec::new();
            for r in &reports {
                r.print();
                println!();
                all.push(obj([
                    ("title", r.title.as_str().into()),
                    ("data", r.data.clone()),
                ]));
            }
            if let Some(path) = args.opt("json") {
                let doc = Json::Arr(all).to_string_pretty();
                std::fs::write(path, doc).expect("write json");
                println!("wrote {path}");
            }
        }
        "help" | "--help" => print!("{USAGE}"),
        other => {
            eprintln!("unknown command `{other}`\n{USAGE}");
            std::process::exit(2);
        }
    }
}
