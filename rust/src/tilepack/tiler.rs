//! Tiling half of TILE&PACK (paper Alg. 1, lines 3–22): split every
//! IMA-mapped weight matrix (rows = K²·Cin, cols = Cout) into tiles of at
//! most S×S (S = 256), *without* merging across layers ("we do not allow
//! tiling to fill unfilled IMA locations" — each tile is a whole rectangle
//! of one layer), and drop zero-sized remainders.

use crate::net::{LayerKind, Network};

/// One weight tile destined for a crossbar.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Tile {
    /// Index of the source layer in the network.
    pub layer: usize,
    pub name: String,
    /// Row/col offset inside the layer's weight matrix.
    pub row0: usize,
    pub col0: usize,
    /// Tile size (rows ≤ S, cols ≤ S).
    pub rows: usize,
    pub cols: usize,
}

impl Tile {
    pub fn devices(&self) -> usize {
        self.rows * self.cols
    }
}

/// Tile every IMA-mapped layer. The paper's §VI mapping puts *convolutional*
/// layers (point-wise + conv1 + conv_last) on the crossbars — its 34 IMAs
/// hold 2.23 M devices, which fits MobileNetV2's ~2.1 M conv weights but not
/// the additional 1.28 M-weight classifier; depth-wise goes to the digital
/// accelerator and the FC runs on the cores.
pub fn tile_network(net: &Network, s: usize) -> Vec<Tile> {
    let mut tiles = Vec::new();
    for (li, l) in net.layers.iter().enumerate() {
        if !matches!(l.kind, LayerKind::Conv) {
            continue;
        }
        let rows = l.xbar_map_rows();
        let cols = l.cout;
        tiles.extend(tile_matrix(li, &l.name, rows, cols, s));
    }
    tiles
}

/// Alg. 1 inner loops: full S×S tiles + row remainder + col remainder +
/// corner, skipping empty ones.
pub fn tile_matrix(layer: usize, name: &str, rows: usize, cols: usize, s: usize) -> Vec<Tile> {
    let mut out = Vec::new();
    let n_h = rows / s;
    let h_rem = rows % s;
    let n_w = cols / s;
    let w_rem = cols % s;

    let mut push = |i: usize, j: usize, r0: usize, c0: usize, r: usize, c: usize| {
        if r > 0 && c > 0 {
            out.push(Tile {
                layer,
                name: format!("{name}_tile{i}_{j}"),
                row0: r0,
                col0: c0,
                rows: r,
                cols: c,
            });
        }
    };

    for i in 0..n_h {
        for j in 0..n_w {
            push(i, j, i * s, j * s, s, s);
        }
    }
    for j in 0..n_w {
        push(n_h, j, n_h * s, j * s, h_rem, s);
    }
    for i in 0..n_h {
        push(i, n_w, i * s, n_w * s, s, w_rem);
    }
    push(n_h, n_w, n_h * s, n_w * s, h_rem, w_rem);
    out
}

/// Coverage check: tiles of one matrix must partition it exactly.
pub fn check_partition(tiles: &[Tile], rows: usize, cols: usize) -> Result<(), String> {
    let total: usize = tiles.iter().map(|t| t.devices()).sum();
    if total != rows * cols {
        return Err(format!("area {total} != {}", rows * cols));
    }
    for (i, a) in tiles.iter().enumerate() {
        if a.row0 + a.rows > rows || a.col0 + a.cols > cols {
            return Err(format!("tile {i} out of matrix bounds"));
        }
        for b in &tiles[i + 1..] {
            let overlap_r = a.row0 < b.row0 + b.rows && b.row0 < a.row0 + a.rows;
            let overlap_c = a.col0 < b.col0 + b.cols && b.col0 < a.col0 + a.cols;
            if overlap_r && overlap_c {
                return Err(format!("tiles overlap: {a:?} vs {b:?}"));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::mobilenetv2::mobilenet_v2;
    use crate::util::prop;

    #[test]
    fn small_matrix_single_tile() {
        let t = tile_matrix(0, "conv1", 27, 32, 256);
        assert_eq!(t.len(), 1);
        assert_eq!((t[0].rows, t[0].cols), (27, 32));
    }

    #[test]
    fn exact_multiple_no_remainders() {
        let t = tile_matrix(0, "fc", 512, 512, 256);
        assert_eq!(t.len(), 4);
        assert!(t.iter().all(|x| x.rows == 256 && x.cols == 256));
        check_partition(&t, 512, 512).unwrap();
    }

    #[test]
    fn ragged_both_dims() {
        // 1280×1000 → 5 row groups (4 more the 5th is 1280%256=0 → exactly 5)
        let t = tile_matrix(0, "fc", 1280, 1000, 256);
        check_partition(&t, 1280, 1000).unwrap();
        // 5 full row bands × (3 full cols + 232 remainder) = 20 tiles
        assert_eq!(t.len(), 20);
        assert!(t.iter().any(|x| x.cols == 1000 % 256));
    }

    #[test]
    fn partition_property() {
        prop::check("tiler_partition", 200, |rng| {
            let rows = rng.range_i64(1, 2000) as usize;
            let cols = rng.range_i64(1, 2000) as usize;
            let s = rng.range_i64(16, 512) as usize;
            let t = tile_matrix(0, "m", rows, cols, s);
            check_partition(&t, rows, cols).unwrap_or_else(|e| panic!("{e}"));
            assert!(t.iter().all(|x| x.rows <= s && x.cols <= s));
        });
    }

    #[test]
    fn mobilenet_total_devices_match_weights() {
        let net = mobilenet_v2(224);
        let tiles = tile_network(&net, 256);
        let tile_devices: usize = tiles.iter().map(|t| t.devices()).sum();
        let conv_weights: usize = net
            .layers
            .iter()
            .filter(|l| l.kind == LayerKind::Conv)
            .map(|l| l.n_weights())
            .sum();
        // tiling introduces no padding *inside* tiles — device count equals
        // the true weight count (padding appears only as unfilled bin area)
        assert_eq!(tile_devices, conv_weights);
        // the dominant tile population should be well under 256² each
        assert!(tiles.iter().all(|t| t.rows <= 256 && t.cols <= 256));
    }
}
