//! Packing half of TILE&PACK (paper Alg. 1, lines 23–24): BINBESTFIT +
//! MAXRECTSBSSF — offline packing of all tiles onto the minimum number of
//! S×S crossbar bins, choosing for each tile the bin where its BSSF score
//! is globally best (rectpack's behavior), opening a new bin when none fits.

use super::maxrects::{MaxRectsBin, Rect};
use super::tiler::Tile;

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Placement {
    pub tile: Tile,
    pub bin: usize,
    pub pos: Rect,
}

#[derive(Debug, Default)]
pub struct Packing {
    pub bins: Vec<MaxRectsBin>,
    pub placements: Vec<Placement>,
}

impl Packing {
    pub fn n_bins(&self) -> usize {
        self.bins.len()
    }

    pub fn utilizations(&self) -> Vec<f64> {
        self.bins.iter().map(|b| b.utilization()).collect()
    }

    pub fn total_devices(&self) -> usize {
        self.bins.iter().map(|b| b.used_area()).sum()
    }

    /// Lower bound on bins for this tile set (area bound).
    pub fn area_lower_bound(tiles: &[Tile], s: usize) -> usize {
        let area: usize = tiles.iter().map(|t| t.devices()).sum();
        area.div_ceil(s * s)
    }
}

/// Pack tiles onto S×S bins. `rotate` allows 90° tile rotation (a crossbar
/// can host a transposed tile by swapping DAC/ADC roles only in principle —
/// the paper's mapping does not rotate, so the default is false; the
/// ablation in `report::experiments` quantifies what rotation would save).
pub fn pack(tiles: &[Tile], s: usize, rotate: bool) -> Packing {
    // offline heuristic: sort by area descending (rectpack default)
    let mut order: Vec<usize> = (0..tiles.len()).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(tiles[i].devices()));

    let mut packing = Packing::default();
    for &ti in &order {
        let t = &tiles[ti];
        // best existing bin by BSSF score
        let mut best: Option<(usize, (usize, usize))> = None;
        for (bi, bin) in packing.bins.iter().enumerate() {
            if let Some((score, _)) = bin.score(t.rows, t.cols) {
                if best.map(|(_, s0)| score < s0).unwrap_or(true) {
                    best = Some((bi, score));
                }
            }
        }
        let bi = match best {
            Some((bi, _)) => bi,
            None => {
                packing.bins.push(MaxRectsBin::new(s, s, rotate));
                packing.bins.len() - 1
            }
        };
        let pos = packing.bins[bi]
            .insert(t.rows, t.cols, ti)
            .expect("fresh bin must fit a tile ≤ S×S");
        packing.placements.push(Placement {
            tile: t.clone(),
            bin: bi,
            pos,
        });
    }
    packing
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::mobilenetv2::mobilenet_v2;
    use crate::tilepack::tiler::{tile_matrix, tile_network};
    use crate::util::prop;

    #[test]
    fn single_tile_single_bin() {
        let tiles = tile_matrix(0, "m", 100, 100, 256);
        let p = pack(&tiles, 256, false);
        assert_eq!(p.n_bins(), 1);
        assert!((p.utilizations()[0] - 10_000.0 / 65_536.0).abs() < 1e-9);
    }

    #[test]
    fn every_tile_placed_exactly_once() {
        let tiles = tile_matrix(0, "m", 1280, 1000, 256);
        let p = pack(&tiles, 256, false);
        assert_eq!(p.placements.len(), tiles.len());
        let devices: usize = tiles.iter().map(|t| t.devices()).sum();
        assert_eq!(p.total_devices(), devices);
        for b in &p.bins {
            b.check_invariants().unwrap();
        }
    }

    #[test]
    fn never_below_area_lower_bound() {
        prop::check("packer_lower_bound", 60, |rng| {
            let n = rng.range_i64(1, 30) as usize;
            let tiles: Vec<Tile> = (0..n)
                .flat_map(|i| {
                    tile_matrix(
                        i,
                        &format!("m{i}"),
                        rng.range_i64(1, 700) as usize,
                        rng.range_i64(1, 700) as usize,
                        256,
                    )
                })
                .collect();
            let p = pack(&tiles, 256, false);
            let lb = Packing::area_lower_bound(&tiles, 256);
            assert!(p.n_bins() >= lb);
            // sanity upper bound: BSSF should stay within 2× of area bound
            assert!(p.n_bins() <= 2 * lb + 1, "{} vs lb {lb}", p.n_bins());
            for b in &p.bins {
                b.check_invariants().unwrap_or_else(|e| panic!("{e}"));
            }
        });
    }

    #[test]
    fn mobilenet_packs_to_about_34_crossbars() {
        // paper Fig. 12b: 34 crossbars, most at 100 %, last < 84 %
        let net = mobilenet_v2(224);
        let tiles = tile_network(&net, 256);
        let p = pack(&tiles, 256, false);
        let lb = Packing::area_lower_bound(&tiles, 256);
        assert!(lb >= 32, "area lower bound {lb}");
        assert!(
            (33..=38).contains(&p.n_bins()),
            "got {} bins (paper: 34)",
            p.n_bins()
        );
        // most bins nearly full
        let mut utils = p.utilizations();
        utils.sort_by(|a, b| b.partial_cmp(a).unwrap());
        assert!(utils[p.n_bins() / 2] > 0.9, "median util {}", utils[p.n_bins() / 2]);
    }
}
