//! MaxRects bin packing with Best-Short-Side-Fit scoring (Jylänki 2010,
//! "A thousand ways to pack the bin") — the algorithm behind the paper's
//! `rectpack.MaxRectsBssf`.
//!
//! Invariants (property-tested):
//!  * placed rectangles never overlap,
//!  * placed rectangles stay inside the bin,
//!  * free-rectangle list covers exactly the unoccupied area (checked by
//!    area accounting).

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Rect {
    pub x: usize,
    pub y: usize,
    pub w: usize,
    pub h: usize,
}

impl Rect {
    pub fn new(x: usize, y: usize, w: usize, h: usize) -> Rect {
        Rect { x, y, w, h }
    }

    pub fn area(&self) -> usize {
        self.w * self.h
    }

    pub fn contains(&self, other: &Rect) -> bool {
        other.x >= self.x
            && other.y >= self.y
            && other.x + other.w <= self.x + self.w
            && other.y + other.h <= self.y + self.h
    }

    pub fn intersects(&self, other: &Rect) -> bool {
        self.x < other.x + other.w
            && other.x < self.x + self.w
            && self.y < other.y + other.h
            && other.y < self.y + self.h
    }
}

/// One crossbar-sized bin.
#[derive(Clone, Debug)]
pub struct MaxRectsBin {
    pub width: usize,
    pub height: usize,
    pub allow_rotate: bool,
    free: Vec<Rect>,
    pub placed: Vec<(Rect, usize)>, // (position, tile id)
}

/// BSSF score: (short-side leftover, long-side leftover) — smaller is better.
type Score = (usize, usize);

impl MaxRectsBin {
    pub fn new(width: usize, height: usize, allow_rotate: bool) -> Self {
        MaxRectsBin {
            width,
            height,
            allow_rotate,
            free: vec![Rect::new(0, 0, width, height)],
            placed: Vec::new(),
        }
    }

    pub fn used_area(&self) -> usize {
        self.placed.iter().map(|(r, _)| r.area()).sum()
    }

    pub fn utilization(&self) -> f64 {
        self.used_area() as f64 / (self.width * self.height) as f64
    }

    /// Best BSSF score achievable for a (w, h) tile, if it fits.
    pub fn score(&self, w: usize, h: usize) -> Option<(Score, Rect)> {
        let mut best: Option<(Score, Rect)> = None;
        for f in &self.free {
            for (tw, th) in self.orientations(w, h) {
                if tw <= f.w && th <= f.h {
                    let short = (f.w - tw).min(f.h - th);
                    let long = (f.w - tw).max(f.h - th);
                    let cand = ((short, long), Rect::new(f.x, f.y, tw, th));
                    if best.map(|(s, _)| cand.0 < s).unwrap_or(true) {
                        best = Some(cand);
                    }
                }
            }
        }
        best
    }

    fn orientations(&self, w: usize, h: usize) -> Vec<(usize, usize)> {
        if self.allow_rotate && w != h {
            vec![(w, h), (h, w)]
        } else {
            vec![(w, h)]
        }
    }

    /// Place a tile at its best position. Returns the placement or None.
    pub fn insert(&mut self, w: usize, h: usize, id: usize) -> Option<Rect> {
        let (_, pos) = self.score(w, h)?;
        self.place(pos, id);
        Some(pos)
    }

    fn place(&mut self, node: Rect, id: usize) {
        // split every free rect that intersects the placed node
        let mut i = 0;
        while i < self.free.len() {
            if self.free[i].intersects(&node) {
                let f = self.free.swap_remove(i);
                self.split(f, &node);
            } else {
                i += 1;
            }
        }
        self.prune();
        self.placed.push((node, id));
    }

    /// MaxRects split: the free rect minus the used node produces up to four
    /// maximal free rects.
    fn split(&mut self, f: Rect, used: &Rect) {
        // left
        if used.x > f.x {
            self.free.push(Rect::new(f.x, f.y, used.x - f.x, f.h));
        }
        // right
        if used.x + used.w < f.x + f.w {
            self.free.push(Rect::new(
                used.x + used.w,
                f.y,
                f.x + f.w - (used.x + used.w),
                f.h,
            ));
        }
        // bottom (below used, smaller y)
        if used.y > f.y {
            self.free.push(Rect::new(f.x, f.y, f.w, used.y - f.y));
        }
        // top
        if used.y + used.h < f.y + f.h {
            self.free.push(Rect::new(
                f.x,
                used.y + used.h,
                f.w,
                f.y + f.h - (used.y + used.h),
            ));
        }
    }

    /// Remove free rects fully contained in another (keep only maximal).
    fn prune(&mut self) {
        let mut i = 0;
        while i < self.free.len() {
            let mut removed = false;
            for j in 0..self.free.len() {
                if i != j && self.free[j].contains(&self.free[i]) {
                    self.free.swap_remove(i);
                    removed = true;
                    break;
                }
            }
            if !removed {
                i += 1;
            }
        }
    }

    /// Check the no-overlap / in-bounds invariants (used by tests).
    pub fn check_invariants(&self) -> Result<(), String> {
        let bin = Rect::new(0, 0, self.width, self.height);
        for (i, (a, _)) in self.placed.iter().enumerate() {
            if !bin.contains(a) {
                return Err(format!("tile {i} out of bounds: {a:?}"));
            }
            for (b, _) in &self.placed[i + 1..] {
                if a.intersects(b) {
                    return Err(format!("overlap: {a:?} vs {b:?}"));
                }
            }
            for f in &self.free {
                if f.intersects(a) {
                    return Err(format!("free rect {f:?} overlaps placed {a:?}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn single_insert_at_origin() {
        let mut b = MaxRectsBin::new(256, 256, false);
        let p = b.insert(100, 50, 0).unwrap();
        assert_eq!(p, Rect::new(0, 0, 100, 50));
        b.check_invariants().unwrap();
    }

    #[test]
    fn fills_bin_exactly_with_quarters() {
        let mut b = MaxRectsBin::new(256, 256, false);
        for i in 0..4 {
            assert!(b.insert(128, 128, i).is_some(), "quarter {i}");
        }
        assert_eq!(b.used_area(), 256 * 256);
        assert!(b.insert(1, 1, 99).is_none());
        b.check_invariants().unwrap();
    }

    #[test]
    fn rejects_oversized() {
        let mut b = MaxRectsBin::new(256, 256, false);
        assert!(b.insert(257, 10, 0).is_none());
        assert!(b.insert(10, 300, 0).is_none());
    }

    #[test]
    fn rotation_rescues_tall_tiles() {
        let mut b = MaxRectsBin::new(256, 64, true);
        // 64×200 only fits rotated
        assert!(b.insert(64, 200, 0).is_some());
        let mut b2 = MaxRectsBin::new(256, 64, false);
        assert!(b2.insert(64, 200, 0).is_none());
    }

    #[test]
    fn bssf_prefers_tight_fit() {
        let mut b = MaxRectsBin::new(100, 100, false);
        b.insert(100, 40, 0); // leaves a 100×60 strip
        // a 100×60 tile should exactly fill the strip
        let p = b.insert(100, 60, 1).unwrap();
        assert_eq!(p, Rect::new(0, 40, 100, 60));
        assert_eq!(b.used_area(), 100 * 100);
    }

    #[test]
    fn random_insertions_keep_invariants() {
        prop::check("maxrects_invariants", 120, |rng| {
            let mut b = MaxRectsBin::new(256, 256, rng.below(2) == 0);
            let n = rng.range_i64(1, 40) as usize;
            for id in 0..n {
                let w = rng.range_i64(1, 256) as usize;
                let h = rng.range_i64(1, 256) as usize;
                let _ = b.insert(w, h, id);
            }
            b.check_invariants()
                .unwrap_or_else(|e| panic!("invariant: {e}"));
            assert!(b.used_area() <= 256 * 256);
        });
    }

    #[test]
    fn deterministic_for_fixed_seed() {
        // same SplitMix64 seed → bit-identical placements, run after run —
        // the property the plan cache and golden schedules rely on
        use crate::util::rng::SplitMix64;
        let run = || {
            let mut rng = SplitMix64::new(0xD0_0DCAFE);
            let mut b = MaxRectsBin::new(256, 256, false);
            for id in 0..60 {
                let w = rng.range_i64(1, 200) as usize;
                let h = rng.range_i64(1, 200) as usize;
                let _ = b.insert(w, h, id);
            }
            b.placed.clone()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b);
        assert!(!a.is_empty());
    }

    #[test]
    fn many_small_tiles_reach_high_utilization() {
        let mut b = MaxRectsBin::new(256, 256, false);
        let mut id = 0;
        while b.insert(32, 32, id).is_some() {
            id += 1;
        }
        assert_eq!(id, 64); // 8×8 grid of 32×32 tiles fills it exactly
        assert!((b.utilization() - 1.0).abs() < 1e-9);
    }
}
