//! TILE&PACK (paper Alg. 1 + Fig. 12b): tile every conv/fc weight matrix to
//! crossbar-sized rectangles, then pack the tiles onto the minimum number of
//! 256×256 IMA crossbars with MaxRects-BSSF bin packing (the paper uses the
//! `rectpack` Python library; `maxrects` is a from-scratch implementation of
//! the same algorithm, Jylänki 2010). [`placement`] lifts the packing to
//! whole-network pool placement — resident when the pool holds every
//! weight, staged (multi-pass, reprogramming) when it does not.

pub mod maxrects;
pub mod packer;
pub mod placement;
pub mod tiler;

pub use maxrects::{MaxRectsBin, Rect};
pub use packer::{pack, Packing};
pub use placement::{place_network, place_staged, PoolPlacement, StagedPlacement};
pub use tiler::{tile_network, Tile};
