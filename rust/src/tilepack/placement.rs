//! Whole-network placement across a multi-array IMA pool (the §VI scale-up
//! generalized): TILE&PACK every conv/fc weight matrix onto at most
//! `n_arrays` crossbars, pin the weights on-chip, and report per-array
//! occupancy (the Fig. 12b view, extended to arbitrary pool sizes).
//!
//! Two regimes:
//!
//! * **Resident** — the whole network packs into the pool (MobileNetV2 needs
//!   ~34 arrays); weights are programmed once at boot and every request runs
//!   allocation-free.
//! * **Staged** — the pool is smaller than the weight footprint; the network
//!   is split into consecutive *passes* whose tiles each fit, and serving
//!   reprograms the pool between passes (the paper deems this infeasible at
//!   interactive rates — §VI — and the scheduler charges the full PCM
//!   program-and-verify cost so the report shows exactly why).

use crate::net::{LayerKind, Network};

use super::packer::{pack, Packing, Placement};
use super::tiler::{tile_network, Tile};

/// A network packed onto one pool-sized set of arrays.
#[derive(Clone, Debug, PartialEq)]
pub struct PoolPlacement {
    /// Crossbar side (rows = cols = `s`).
    pub s: usize,
    /// Arrays actually used (≤ the pool size it was placed for).
    pub arrays_used: usize,
    /// Every tile's (array, position) assignment.
    pub placements: Vec<Placement>,
    /// Per-array utilization in [0, 1].
    pub occupancy: Vec<f64>,
    /// For each network layer: the sorted arrays hosting at least one of
    /// its tiles (empty for layers not mapped to the pool).
    pub layer_arrays: Vec<Vec<usize>>,
    /// For each network layer: how many tiles it was split into.
    pub layer_tiles: Vec<usize>,
}

impl PoolPlacement {
    fn from_packing(net: &Network, s: usize, tiles: &[Tile], packing: Packing) -> PoolPlacement {
        let mut layer_arrays: Vec<Vec<usize>> = vec![Vec::new(); net.layers.len()];
        let mut layer_tiles = vec![0usize; net.layers.len()];
        for t in tiles {
            layer_tiles[t.layer] += 1;
        }
        for p in &packing.placements {
            let la = &mut layer_arrays[p.tile.layer];
            if !la.contains(&p.bin) {
                la.push(p.bin);
            }
        }
        for la in layer_arrays.iter_mut() {
            la.sort_unstable();
        }
        PoolPlacement {
            s,
            arrays_used: packing.n_bins(),
            occupancy: packing.utilizations(),
            placements: packing.placements,
            layer_arrays,
            layer_tiles,
        }
    }

    /// Total devices occupied across the pool.
    pub fn devices_used(&self) -> usize {
        self.placements.iter().map(|p| p.tile.devices()).sum()
    }

    /// Rows that PCM program-and-verify must write to program this
    /// placement (each placed tile programs `rows` word-lines).
    pub fn program_rows(&self) -> u64 {
        self.placements.iter().map(|p| p.tile.rows as u64).sum()
    }

    /// Placement invariants (tested): every tiled layer is placed exactly
    /// once per tile, per-array utilization stays within [0, 1], and array
    /// indices stay inside `arrays_used`.
    pub fn check_invariants(&self, net: &Network) -> Result<(), String> {
        let mut placed = vec![0usize; net.layers.len()];
        for p in &self.placements {
            if p.bin >= self.arrays_used {
                return Err(format!("tile on array {} >= {}", p.bin, self.arrays_used));
            }
            placed[p.tile.layer] += 1;
        }
        for (li, (&want, &got)) in self.layer_tiles.iter().zip(placed.iter()).enumerate() {
            if want != got {
                return Err(format!(
                    "layer {li} `{}`: {got} of {want} tiles placed",
                    net.layers[li].name
                ));
            }
        }
        for (a, &u) in self.occupancy.iter().enumerate() {
            if !(0.0..=1.0).contains(&u) {
                return Err(format!("array {a} utilization {u} outside [0,1]"));
            }
        }
        Ok(())
    }
}

/// Place the whole network onto a pool of `n_arrays` crossbars. Errors when
/// the weights do not fit (use [`place_staged`] for small pools).
pub fn place_network(
    net: &Network,
    s: usize,
    n_arrays: usize,
    rotate: bool,
) -> Result<PoolPlacement, String> {
    let tiles = tile_network(net, s);
    let packing = pack(&tiles, s, rotate);
    if packing.n_bins() > n_arrays {
        return Err(format!(
            "network `{}` needs {} arrays but the pool has {n_arrays} \
             (weights do not fit on-chip; staged placement required)",
            net.name,
            packing.n_bins()
        ));
    }
    Ok(PoolPlacement::from_packing(net, s, &tiles, packing))
}

/// A network split into consecutive passes, each resident in the pool.
#[derive(Clone, Debug, PartialEq)]
pub struct StagedPlacement {
    pub n_arrays: usize,
    /// [`crate::net::Network::fingerprint`] of the network this placement
    /// was made for — the scheduler refuses plans for a different geometry.
    pub net_fingerprint: u64,
    pub passes: Vec<PoolPlacement>,
    /// For each pass: the half-open network layer index range it executes
    /// (covers *all* layers — non-conv layers ride with the preceding pass).
    pub pass_ranges: Vec<(usize, usize)>,
}

impl StagedPlacement {
    pub fn n_passes(&self) -> usize {
        self.passes.len()
    }

    /// Resident placements never reprogram on the request path.
    pub fn is_resident(&self) -> bool {
        self.passes.len() <= 1
    }

    /// PCM rows rewritten per serving cycle through all passes (zero when
    /// resident — boot-time programming is off the request path).
    pub fn reprogram_rows_per_cycle(&self) -> u64 {
        if self.is_resident() {
            0
        } else {
            self.passes.iter().map(|p| p.program_rows()).sum()
        }
    }
}

/// Greedily split the network into consecutive passes whose TILE&PACK each
/// fits `n_arrays`. Errors only if a single layer alone exceeds the pool.
pub fn place_staged(
    net: &Network,
    s: usize,
    n_arrays: usize,
    rotate: bool,
) -> Result<StagedPlacement, String> {
    // fast path: everything fits
    if let Ok(p) = place_network(net, s, n_arrays, rotate) {
        return Ok(StagedPlacement {
            n_arrays,
            net_fingerprint: net.fingerprint(),
            passes: vec![p],
            pass_ranges: vec![(0, net.layers.len())],
        });
    }

    let conv_idx: Vec<usize> = net
        .layers
        .iter()
        .enumerate()
        .filter(|(_, l)| l.kind == LayerKind::Conv)
        .map(|(i, _)| i)
        .collect();

    // `keep[i]`: conv layer i stays in the trial pass; everything else is
    // masked to a non-tiled kind so tile_network skips it while `layer`
    // ids still refer to the full network
    let sub_net = |keep: &[bool]| -> Network {
        Network {
            name: net.name.clone(),
            layers: net
                .layers
                .iter()
                .enumerate()
                .map(|(i, l)| {
                    let mut l = l.clone();
                    if l.kind == LayerKind::Conv && !keep[i] {
                        l.kind = LayerKind::Add;
                    }
                    l
                })
                .collect(),
        }
    };
    let mask_of = |layers: &[usize]| -> Vec<bool> {
        let mut keep = vec![false; net.layers.len()];
        for &i in layers {
            keep[i] = true;
        }
        keep
    };

    let single_layer_err = |ci: usize| {
        format!(
            "layer `{}` alone exceeds a {n_arrays}-array pool",
            net.layers[ci].name
        )
    };

    let mut passes = Vec::new();
    let mut pass_first_conv = Vec::new();
    let mut group: Vec<usize> = Vec::new();
    // the last successful packing of `group` — reused when the pass closes
    // instead of re-running MaxRects on the identical layer set
    let mut group_placed: Option<PoolPlacement> = None;
    for &ci in &conv_idx {
        let mut attempt = group.clone();
        attempt.push(ci);
        match place_network(&sub_net(&mask_of(&attempt)), s, n_arrays, rotate) {
            Ok(p) => {
                group = attempt;
                group_placed = Some(p);
            }
            Err(_) => {
                let placed = group_placed.take().ok_or_else(|| single_layer_err(ci))?;
                passes.push(placed);
                pass_first_conv.push(group[0]);
                let p = place_network(&sub_net(&mask_of(&[ci])), s, n_arrays, rotate)
                    .map_err(|_| single_layer_err(ci))?;
                group = vec![ci];
                group_placed = Some(p);
            }
        }
    }
    if let Some(placed) = group_placed {
        passes.push(placed);
        pass_first_conv.push(group[0]);
    }

    // layer ranges: pass p runs from its first conv layer (or 0 for the
    // first pass) up to the next pass's first conv layer
    let mut pass_ranges = Vec::with_capacity(passes.len());
    for (p, _) in passes.iter().enumerate() {
        let start = if p == 0 { 0 } else { pass_first_conv[p] };
        let end = if p + 1 < passes.len() {
            pass_first_conv[p + 1]
        } else {
            net.layers.len()
        };
        pass_ranges.push((start, end));
    }

    Ok(StagedPlacement {
        n_arrays,
        net_fingerprint: net.fingerprint(),
        passes,
        pass_ranges,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::bottleneck::bottleneck;
    use crate::net::mobilenetv2::mobilenet_v2;

    #[test]
    fn mobilenet_resident_on_34_arrays() {
        let net = mobilenet_v2(224);
        let p = place_network(&net, 256, 40, false).unwrap();
        assert!((33..=38).contains(&p.arrays_used), "{}", p.arrays_used);
        p.check_invariants(&net).unwrap();
        // every layer placed exactly once per tile; conv layers host arrays
        for (li, l) in net.layers.iter().enumerate() {
            if l.kind == crate::net::LayerKind::Conv {
                assert!(!p.layer_arrays[li].is_empty(), "{}", l.name);
            } else {
                assert!(p.layer_arrays[li].is_empty(), "{}", l.name);
            }
        }
        // occupancy ≤ 1.0 everywhere, and devices match the tiling
        let conv_weights: usize = net
            .layers
            .iter()
            .filter(|l| l.kind == crate::net::LayerKind::Conv)
            .map(|l| l.n_weights())
            .sum();
        assert_eq!(p.devices_used(), conv_weights);
    }

    #[test]
    fn mobilenet_does_not_fit_8_arrays_resident() {
        let net = mobilenet_v2(224);
        assert!(place_network(&net, 256, 8, false).is_err());
    }

    #[test]
    fn bottleneck_expand_and_project_on_disjoint_arrays() {
        let net = bottleneck();
        let p = place_network(&net, 256, 8, false).unwrap();
        p.check_invariants(&net).unwrap();
        let exp = &p.layer_arrays[0];
        let proj = &p.layer_arrays[2];
        assert!(!exp.is_empty() && !proj.is_empty());
        assert!(
            exp.iter().all(|a| !proj.contains(a)),
            "expand {exp:?} vs project {proj:?}"
        );
    }

    #[test]
    fn staged_placement_covers_every_layer_once() {
        let net = mobilenet_v2(224);
        let st = place_staged(&net, 256, 8, false).unwrap();
        assert!(st.n_passes() > 1, "{}", st.n_passes());
        assert!(!st.is_resident());
        // ranges tile [0, len) without gaps or overlap
        let mut cursor = 0usize;
        for &(a, b) in &st.pass_ranges {
            assert_eq!(a, cursor);
            assert!(b > a);
            cursor = b;
        }
        assert_eq!(cursor, net.layers.len());
        // each pass fits the pool and places its conv layers in-range
        for (p, &(_, b)) in st.passes.iter().zip(st.pass_ranges.iter()) {
            assert!(p.arrays_used <= 8);
            for pl in &p.placements {
                // the first pass may start before its first conv layer
                assert!(pl.tile.layer < b, "tile layer {} vs range end {b}", pl.tile.layer);
            }
        }
        assert!(st.reprogram_rows_per_cycle() > 0);
    }

    #[test]
    fn staged_is_deterministic() {
        let net = mobilenet_v2(224);
        let a = place_staged(&net, 256, 8, false).unwrap();
        let b = place_staged(&net, 256, 8, false).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn resident_staged_has_one_pass_and_no_reprogram() {
        let net = bottleneck();
        let st = place_staged(&net, 256, 8, false).unwrap();
        assert_eq!(st.n_passes(), 1);
        assert!(st.is_resident());
        assert_eq!(st.reprogram_rows_per_cycle(), 0);
    }
}
