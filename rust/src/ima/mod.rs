//! The analog In-Memory Accelerator subsystem (paper §III-A, §IV-B).
//!
//! * [`crossbar`] — PCM device-array state: programming (iterative
//!   program-and-verify), conductance readout, the noise model;
//! * [`mapping`]  — how layers become crossbar jobs: point-wise/standard
//!   convolutions via virtual im2col, depth-wise via diagonal C_job blocks;
//! * [`subsys`]   — the timing model: job phase demands, sequential vs
//!   pipelined schedules, per-layer cost/energy;
//! * [`pool`]     — the multi-array scale-up: N crossbars with weights
//!   pinned on-chip, pool occupancy, PCM (re)programming cost.

pub mod crossbar;
pub mod mapping;
pub mod pool;
pub mod subsys;

pub use mapping::{ConvMap, DwMap, JobShape};
pub use pool::ImaArrayPool;
pub use subsys::{ImaSubsystem, LayerCost};
