//! Multi-array IMA pool (the §VI scale-up, generalized beyond 34 arrays).
//!
//! The paper's scaled-up system statically muxes N crossbars into one IMA
//! subsystem — one array computes at a time, but every array holds its
//! weights permanently. [`ImaArrayPool`] models the pool-level quantities
//! the batch scheduler needs on top of the single-array timing model in
//! [`super::subsys`]: device capacity, placement fit, per-array occupancy,
//! and the PCM program-and-verify cost of (re)programming a placement —
//! 20–30× the MVM latency *per row* (§VI), which is why staged serving on
//! an undersized pool is catastrophically slow and the paper insists on
//! weights resident on-chip.

use std::collections::BTreeMap;

use crate::arch::{PowerModel, SystemConfig};
use crate::tilepack::PoolPlacement;

use super::subsys::ImaSubsystem;

pub struct ImaArrayPool<'a> {
    pub cfg: &'a SystemConfig,
    pub pm: &'a PowerModel,
    /// Arrays in the pool (mirrors `cfg.n_crossbars`).
    pub n_arrays: usize,
}

impl<'a> ImaArrayPool<'a> {
    pub fn new(cfg: &'a SystemConfig, pm: &'a PowerModel) -> Self {
        ImaArrayPool {
            cfg,
            pm,
            n_arrays: cfg.n_crossbars,
        }
    }

    /// The shared single-array timing model (arrays are identical; the
    /// static mux serializes compute, so per-layer costs come from here).
    pub fn subsystem(&self) -> ImaSubsystem<'a> {
        ImaSubsystem::new(self.cfg, self.pm)
    }

    /// Total PCM device capacity of the pool.
    pub fn capacity_devices(&self) -> usize {
        self.cfg.xbar_rows * self.cfg.xbar_cols * self.n_arrays
    }

    /// Does a placement fit this pool?
    pub fn fits(&self, p: &PoolPlacement) -> bool {
        p.arrays_used <= self.n_arrays
    }

    /// Pool-wide occupancy: fraction of *all* pool devices holding weights
    /// (unused arrays count as empty — the Fig. 12b denominator).
    pub fn pool_occupancy(&self, p: &PoolPlacement) -> f64 {
        if self.n_arrays == 0 {
            return 0.0;
        }
        p.devices_used() as f64 / self.capacity_devices() as f64
    }

    /// Cycles to program (or reprogram) every tile of a placement: per-row
    /// program-and-verify at `pcm_program_row_factor` × the MVM latency.
    pub fn program_cycles(&self, p: &PoolPlacement) -> u64 {
        let per_row = self.cfg.ima_mvm_ns * self.cfg.pcm_program_row_factor;
        let cy_per_row = (per_row / self.cfg.freq.cycle_ns()).ceil() as u64;
        p.program_rows() * cy_per_row
    }

    /// [`Self::program_cycles`] split by hosting array (keys are the
    /// placement's array indices, ascending). The values sum exactly to
    /// `program_cycles` — weight-update streaming reorders these chunks
    /// onto per-array timelines without changing the total programming
    /// work.
    pub fn program_cycles_by_array(&self, p: &PoolPlacement) -> BTreeMap<usize, u64> {
        let per_row = self.cfg.ima_mvm_ns * self.cfg.pcm_program_row_factor;
        let cy_per_row = (per_row / self.cfg.freq.cycle_ns()).ceil() as u64;
        let mut out: BTreeMap<usize, u64> = BTreeMap::new();
        for pl in &p.placements {
            *out.entry(pl.bin).or_insert(0) += pl.tile.rows as u64 * cy_per_row;
        }
        out
    }

    /// First-order energy of (re)programming a placement: each row holds
    /// the analog macro for `pcm_program_row_factor` MVM-latency intervals
    /// (write pulses + verify reads) with that tile's columns active — the
    /// single-word-line job energy scaled by the iteration count. Keeps the
    /// batch reports' energy consistent with their reprogramming cycles.
    pub fn program_energy_j(&self, p: &PoolPlacement) -> f64 {
        p.placements
            .iter()
            .map(|pl| {
                self.cfg.pcm_program_row_factor
                    * pl.tile.rows as f64
                    * self.pm.ima_job_energy_j(self.cfg, 1, pl.tile.cols)
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::mobilenetv2::mobilenet_v2;
    use crate::tilepack::place_network;

    #[test]
    fn capacity_and_fit() {
        let cfg = SystemConfig::scaled_up(34);
        let pm = PowerModel::paper();
        let pool = ImaArrayPool::new(&cfg, &pm);
        assert_eq!(pool.n_arrays, 34);
        assert_eq!(pool.capacity_devices(), 34 * 65536);

        let net = mobilenet_v2(224);
        let p = place_network(&net, 256, 40, false).unwrap();
        assert!(pool.fits(&p) == (p.arrays_used <= 34));
        let occ = pool.pool_occupancy(&p);
        assert!((0.5..=1.0).contains(&occ), "{occ}");
    }

    #[test]
    fn per_array_programming_sums_to_total() {
        let cfg = SystemConfig::scaled_up(34);
        let pm = PowerModel::paper();
        let pool = ImaArrayPool::new(&cfg, &pm);
        let net = mobilenet_v2(224);
        let p = place_network(&net, 256, 40, false).unwrap();
        let by_array = pool.program_cycles_by_array(&p);
        assert!(!by_array.is_empty());
        assert!(by_array.keys().all(|&a| a < p.arrays_used));
        assert_eq!(by_array.values().sum::<u64>(), pool.program_cycles(&p));
    }

    #[test]
    fn programming_dwarfs_inference() {
        // §VI: programming all of MNv2's rows takes far longer than the
        // 10 ms inference — the argument for weights resident on-chip
        let cfg = SystemConfig::scaled_up(34);
        let pm = PowerModel::paper();
        let pool = ImaArrayPool::new(&cfg, &pm);
        let net = mobilenet_v2(224);
        let p = place_network(&net, 256, 40, false).unwrap();
        let prog_s = pool.program_cycles(&p) as f64 * cfg.freq.cycle_ns() * 1e-9;
        assert!(prog_s > 10e-3, "programming {prog_s} s");
    }
}
