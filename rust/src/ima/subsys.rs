//! IMA subsystem timing/energy model (paper §IV-B, §V-B).
//!
//! Turns a layer mapping into phase demands per job, schedules the job
//! stream under the configured execution model, and accounts energy. The
//! roofline study (Fig. 7) and every layer cost in Figs. 9/10/12 come from
//! here.

use crate::arch::{EnergyAccount, ExecModel, PowerModel, SystemConfig};
use crate::sim::pipeline::{schedule_pipelined, schedule_sequential, steady_state_pipelined, JobPhases, Schedule};

use super::mapping::{ConvMap, DwMap, JobShape};

/// Cost of running one layer (or one layer's job stream) on the IMA.
#[derive(Clone, Debug, Default)]
pub struct LayerCost {
    pub cycles: u64,
    pub n_jobs: usize,
    pub useful_macs: u64,
    pub devices_active: usize,
    pub energy: EnergyAccount,
}

impl LayerCost {
    pub fn time_s(&self, cfg: &SystemConfig) -> f64 {
        self.cycles as f64 * cfg.freq.cycle_ns() * 1e-9
    }
}

pub struct ImaSubsystem<'a> {
    pub cfg: &'a SystemConfig,
    pub pm: &'a PowerModel,
}

impl<'a> ImaSubsystem<'a> {
    pub fn new(cfg: &'a SystemConfig, pm: &'a PowerModel) -> Self {
        ImaSubsystem { cfg, pm }
    }

    /// Phase demands of one job (cycles at the cluster clock).
    pub fn phases(&self, j: &JobShape, dw_style: bool) -> JobPhases {
        let c = self.cfg;
        let bus = c.bus_bytes();
        let setup = c.streamer_setup_cy;
        JobPhases {
            stream_in: setup + (j.in_bytes.div_ceil(bus)) as u64,
            compute: c.ima_compute_cy(),
            stream_out: setup + (j.out_bytes.div_ceil(bus)) as u64,
            issue: if dw_style {
                // diagonal dw jobs: cores rewrite source strides per job
                c.ima_dw_job_cfg_cy
            } else {
                c.ima_trigger_cy + c.ima_job_issue_cy
            },
        }
    }

    fn schedule(&self, phases: JobPhases, n: u64, dw_style: bool) -> Schedule {
        match (self.cfg.ima_exec, dw_style) {
            // the diagonal dw job stream cannot be hardware-pipelined
            (ExecModel::Sequential, _) | (_, true) => schedule_sequential((0..n).map(|_| phases)),
            (ExecModel::Pipelined, false) => steady_state_pipelined(n, phases),
        }
    }

    /// Exact (non-closed-form) pipelined schedule — used by tests to verify
    /// the steady-state estimate and by heterogeneous job streams.
    pub fn schedule_exact(&self, jobs: Vec<JobPhases>) -> Schedule {
        match self.cfg.ima_exec {
            ExecModel::Sequential => schedule_sequential(jobs),
            ExecModel::Pipelined => schedule_pipelined(jobs),
        }
    }

    fn account(&self, sched: &Schedule, job: &JobShape, n_jobs: u64, cfg_cy: u64) -> LayerCost {
        let mut e = EnergyAccount::default();
        let wall = sched.makespan + cfg_cy;
        e.wall_cy = wall;
        e.ima_digital_active_cy = sched.port_busy + sched.xbar_busy;
        // streams occupy the TCDM at full port duty while active
        e.tcdm_duty_millicycles = sched.port_busy * 1000;
        // one core orchestrates (issue/config), the others are clock-gated
        e.core_active_cy = cfg_cy + n_jobs * 2;
        e.core_idle_cy = wall * self.cfg.n_cores as u64 - e.core_active_cy;
        e.ima_analog_j = n_jobs as f64 * self.pm.ima_job_energy_j(self.cfg, job.rows_used, job.cols_used);
        LayerCost {
            cycles: wall,
            n_jobs: n_jobs as usize,
            useful_macs: job.useful_macs * n_jobs,
            devices_active: job.devices,
            energy: e,
        }
    }

    /// Cost of a conv/fc layer mapped as `map` (all tiles, all pixels).
    /// Digital accumulation/requant for row-split layers is *not* included
    /// here — the coordinator adds the cores' share.
    pub fn conv_layer_cost(&self, map: &ConvMap) -> LayerCost {
        let mut total = LayerCost::default();
        let cfg_cy = self.cfg.ima_layer_cfg_cy;
        let mut first = true;
        for (job, pixels) in map.tile_jobs() {
            let phases = self.phases(&job, false);
            let sched = self.schedule(phases, pixels as u64, false);
            let c = self.account(&sched, &job, pixels as u64, if first { cfg_cy } else { 0 });
            total.cycles += c.cycles;
            total.n_jobs += c.n_jobs;
            total.useful_macs += c.useful_macs;
            total.devices_active += job.devices;
            total.energy.add(&c.energy);
            first = false;
        }
        total
    }

    /// Cost of a depth-wise layer mapped on the IMA with `c_job` channels.
    pub fn dw_layer_cost(&self, map: &DwMap) -> LayerCost {
        let job = map.job();
        let phases = self.phases(&job, true);
        let sched = self.schedule(phases, map.n_jobs() as u64, true);
        let mut c = self.account(&sched, &job, map.n_jobs() as u64, self.cfg.ima_layer_cfg_cy);
        c.devices_active = map.devices_total();
        c
    }

    /// Achieved throughput in ops/s for a job stream (2 ops per useful MAC
    /// — the paper charges only true MACs, padding contributes nothing).
    pub fn achieved_ops_per_s(&self, cost: &LayerCost) -> f64 {
        if cost.cycles == 0 {
            return 0.0;
        }
        2.0 * cost.useful_macs as f64 / (cost.cycles as f64 * self.cfg.freq.cycle_ns() * 1e-9)
    }

    /// One roofline point (Fig. 7): a synthetic c×c point-wise layer.
    /// Returns (operational intensity ops/B, achieved GOPS, roof GOPS).
    pub fn roofline_point(&self, c_channels: usize, pixels: usize) -> (f64, f64, f64) {
        let l = crate::net::workload::synthetic_pointwise(c_channels, pixels);
        let map = ConvMap::new(&l, self.cfg.xbar_rows);
        let cost = self.conv_layer_cost(&map);
        let job = map.job(0, 0);
        let ops = 2.0 * job.useful_macs as f64;
        let bytes = (job.in_bytes + job.out_bytes) as f64;
        let intensity = ops / bytes;
        let achieved = self.achieved_ops_per_s(&cost) / 1e9;
        // diagonal compute roof: ops per 130 ns at this utilization
        let roof = ops / (self.cfg.ima_mvm_ns * 1e-9) / 1e9;
        (intensity, achieved, roof)
    }

    /// Peak bandwidth of the IMA data interface (GB/s).
    pub fn bus_bandwidth_gbps(&self) -> f64 {
        self.cfg.bus_bytes() as f64 * self.cfg.freq.freq_hz() / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::FreqPoint;
    use crate::net::Layer;

    fn sys<'a>(cfg: &'a SystemConfig, pm: &'a PowerModel) -> ImaSubsystem<'a> {
        ImaSubsystem::new(cfg, pm)
    }

    #[test]
    fn peak_958_gops_at_250mhz_pipelined_128bit() {
        // paper §V-B: "a peak of 958 GOPS at 250 MHz, only 10 % less than
        // the theoretical peak performance at the compute roof"
        let cfg = SystemConfig::paper().with_freq(FreqPoint::LOW);
        let pm = PowerModel::paper();
        let ima = sys(&cfg, &pm);
        let (_, achieved, roof) = ima.roofline_point(256, 65536);
        assert!((roof - 1008.0).abs() < 1.0, "roof {roof}");
        assert!(
            (900.0..1000.0).contains(&achieved),
            "achieved {achieved} (paper: 958)"
        );
    }

    #[test]
    fn sequential_at_500mhz_loses_a_third_to_streams() {
        // Fig. 7a: in the sequential model 8–40 % of cycles are stream
        // phases; at full utilization / 128-bit the gap is ~1/3
        let cfg = SystemConfig::paper().with_exec(ExecModel::Sequential);
        let pm = PowerModel::paper();
        let ima = sys(&cfg, &pm);
        let (_, achieved, roof) = ima.roofline_point(256, 4096);
        let frac = achieved / roof;
        assert!((0.45..0.80).contains(&frac), "seq/roof = {frac}");
    }

    #[test]
    fn bus_32bit_is_memory_bound_at_500mhz() {
        // Fig. 7a: "only with a 32-bit wide bus we are memory bound"
        let pm = PowerModel::paper();
        let narrow = SystemConfig::paper().with_bus_bits(32);
        let wide = SystemConfig::paper().with_bus_bits(128);
        let a32 = sys(&narrow, &pm).roofline_point(256, 4096).1;
        let a128 = sys(&wide, &pm).roofline_point(256, 4096).1;
        assert!(a128 > a32 * 1.5, "128-bit {a128} vs 32-bit {a32}");
    }

    #[test]
    fn bus_beyond_128_does_not_help_at_250mhz() {
        // Fig. 7c: optimal configuration is 128-bit; wider buys nothing
        let pm = PowerModel::paper();
        let b128 = SystemConfig::paper()
            .with_freq(FreqPoint::LOW)
            .with_bus_bits(128);
        let b512 = SystemConfig::paper()
            .with_freq(FreqPoint::LOW)
            .with_bus_bits(512);
        let a128 = sys(&b128, &pm).roofline_point(256, 8192).1;
        let a512 = sys(&b512, &pm).roofline_point(256, 8192).1;
        assert!((a512 - a128).abs() / a128 < 0.05, "{a128} vs {a512}");
    }

    #[test]
    fn pipelined_beats_sequential_everywhere() {
        let pm = PowerModel::paper();
        for bus in [32, 64, 128, 256] {
            for c in [64, 128, 256] {
                let p = SystemConfig::paper().with_bus_bits(bus);
                let s = p.clone().with_exec(ExecModel::Sequential);
                let ap = sys(&p, &pm).roofline_point(c, 2048).1;
                let as_ = sys(&s, &pm).roofline_point(c, 2048).1;
                assert!(ap >= as_, "bus {bus} c {c}: {ap} < {as_}");
            }
        }
    }

    #[test]
    fn conv_layer_cost_scales_with_tiles() {
        let cfg = SystemConfig::paper();
        let pm = PowerModel::paper();
        let ima = sys(&cfg, &pm);
        let small = ConvMap::new(&Layer::conv("a", 16, 16, 128, 256), 256);
        let big = ConvMap::new(&Layer::conv("b", 16, 16, 128, 768), 256);
        let cs = ima.conv_layer_cost(&small);
        let cb = ima.conv_layer_cost(&big);
        assert_eq!(cb.n_jobs, 3 * cs.n_jobs);
        assert!(cb.cycles > 2 * cs.cycles);
        assert!(cb.energy.ima_analog_j > 2.0 * cs.energy.ima_analog_j);
    }

    #[test]
    fn dw_on_ima_is_inefficient() {
        // the Fig. 9 story: dw on the IMA wastes devices and time
        let cfg = SystemConfig::paper();
        let pm = PowerModel::paper();
        let ima = sys(&cfg, &pm);
        let net = crate::net::bottleneck::bottleneck();
        let dw8 = ima.dw_layer_cost(&DwMap::new(&net.layers[1], 8));
        let dw16 = ima.dw_layer_cost(&DwMap::new(&net.layers[1], 16));
        // c_job16 halves the job count → roughly halves the time
        assert!(dw8.cycles > dw16.cycles);
        let ratio = dw8.cycles as f64 / dw16.cycles as f64;
        assert!((1.6..2.2).contains(&ratio), "{ratio}");
        // and both are far slower than the pw layers of the same block
        let pw = ima.conv_layer_cost(&ConvMap::new(&net.layers[0], 256));
        assert!(dw16.cycles > 5 * pw.cycles);
    }

    #[test]
    fn analog_energy_tracks_utilization() {
        let cfg = SystemConfig::paper();
        let pm = PowerModel::paper();
        let ima = sys(&cfg, &pm);
        let full = ConvMap::new(&Layer::conv("f", 8, 8, 256, 256), 256);
        let tiny = ConvMap::new(&Layer::conv("t", 8, 8, 32, 32), 256);
        let cf = ima.conv_layer_cost(&full);
        let ct = ima.conv_layer_cost(&tiny);
        let per_job_full = cf.energy.ima_analog_j / cf.n_jobs as f64;
        let per_job_tiny = ct.energy.ima_analog_j / ct.n_jobs as f64;
        assert!(per_job_full > 2.0 * per_job_tiny);
    }
}
