//! Layer → crossbar-job mapping (paper §V-C, Fig. 3a & Fig. 8).
//!
//! * Standard / point-wise convolutions: the streamer's virtual IM2COL maps
//!   a K×K×Cin input volume on the word-lines (rows = K²·Cin) and Cout
//!   filters across bit-lines; one *job* = one output pixel × one column
//!   tile. Layers exceeding the array split into row tiles (digital partial
//!   accumulation on the cores) and column tiles.
//! * Depth-wise: diagonal block mapping with `c_job` channels per job —
//!   rows = K²·c_job, cols = c_job, jobs = pixels × C/c_job, devices =
//!   K²·C·c_job (paper's N_xbar formula).

use crate::net::Layer;

/// Shape of one crossbar job: what streams in/out and what's active.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct JobShape {
    /// Bytes streamed in (input activations for the mapped rows).
    pub in_bytes: usize,
    /// Bytes streamed out (8-bit ADC outputs, or 4× for raw int32 partials).
    pub out_bytes: usize,
    /// Active word-lines / bit-lines — drive analog energy.
    pub rows_used: usize,
    pub cols_used: usize,
    /// Active devices (rows_used × cols_used).
    pub devices: usize,
    /// MACs this job performs (true MACs, excluding padding zeros).
    pub useful_macs: u64,
}

/// Mapping of a conv/fc layer onto (row tiles × col tiles) of S×S crossbars.
#[derive(Clone, Debug)]
pub struct ConvMap {
    pub rows: usize,
    pub cols: usize,
    pub n_row_tiles: usize,
    pub n_col_tiles: usize,
    pub pixels: usize,
    pub s: usize,
}

impl ConvMap {
    pub fn new(l: &Layer, s: usize) -> ConvMap {
        let rows = l.xbar_map_rows();
        let cols = l.cout;
        ConvMap {
            rows,
            cols,
            n_row_tiles: rows.div_ceil(s),
            n_col_tiles: cols.div_ceil(s),
            pixels: l.out_pixels(),
            s,
        }
    }

    /// Total jobs for the layer: every output pixel visits every tile.
    pub fn n_jobs(&self) -> usize {
        self.pixels * self.n_row_tiles * self.n_col_tiles
    }

    /// Whether partial sums need digital accumulation on the cores.
    pub fn row_split(&self) -> bool {
        self.n_row_tiles > 1
    }

    /// Job shape for tile (rt, ct).
    pub fn job(&self, rt: usize, ct: usize) -> JobShape {
        let rows_used = (self.rows - rt * self.s).min(self.s);
        let cols_used = (self.cols - ct * self.s).min(self.s);
        let raw = self.row_split();
        JobShape {
            in_bytes: rows_used,
            // raw partials leave as int32 (4 B), fused ADC output as int8
            out_bytes: cols_used * if raw { 4 } else { 1 },
            rows_used,
            cols_used,
            devices: rows_used * cols_used,
            useful_macs: (rows_used * cols_used) as u64,
        }
    }

    /// All tile job shapes with their multiplicity (pixels each).
    pub fn tile_jobs(&self) -> Vec<(JobShape, usize)> {
        let mut out = Vec::new();
        for rt in 0..self.n_row_tiles {
            for ct in 0..self.n_col_tiles {
                out.push((self.job(rt, ct), self.pixels));
            }
        }
        out
    }

    /// Crossbar devices the mapping occupies (no intra-tile padding).
    pub fn devices_total(&self) -> usize {
        self.rows * self.cols
    }
}

/// Depth-wise on the IMA with `c_job` channels per job (the paper's two
/// analyzed configurations: 8 and 16).
#[derive(Clone, Debug)]
pub struct DwMap {
    pub c: usize,
    pub c_job: usize,
    pub k: usize,
    pub pixels: usize,
}

impl DwMap {
    pub fn new(l: &Layer, c_job: usize) -> DwMap {
        DwMap {
            c: l.cout,
            c_job,
            k: l.k,
            pixels: l.out_pixels(),
        }
    }

    pub fn jobs_per_pixel(&self) -> usize {
        self.c.div_ceil(self.c_job)
    }

    pub fn n_jobs(&self) -> usize {
        self.pixels * self.jobs_per_pixel()
    }

    /// Devices occupied: N_xbar = K² · C · C_job (paper §V-C).
    pub fn devices_total(&self) -> usize {
        self.k * self.k * self.c * self.c_job
    }

    pub fn job(&self) -> JobShape {
        let rows_used = self.k * self.k * self.c_job;
        JobShape {
            in_bytes: rows_used,
            out_bytes: self.c_job,
            rows_used,
            cols_used: self.c_job,
            devices: rows_used * self.c_job,
            useful_macs: (self.k * self.k * self.c_job) as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::bottleneck;
    use crate::net::Layer;

    #[test]
    fn pointwise_single_tile() {
        let l = Layer::conv("pw", 16, 16, 128, 256);
        let m = ConvMap::new(&l, 256);
        assert_eq!((m.n_row_tiles, m.n_col_tiles), (1, 1));
        assert_eq!(m.n_jobs(), 256);
        let j = m.job(0, 0);
        assert_eq!(j.in_bytes, 128);
        assert_eq!(j.out_bytes, 256);
        assert!(!m.row_split());
    }

    #[test]
    fn expand_layer_col_tiles() {
        let l = Layer::conv("exp", 16, 16, 128, 768);
        let m = ConvMap::new(&l, 256);
        assert_eq!((m.n_row_tiles, m.n_col_tiles), (1, 3));
        assert_eq!(m.n_jobs(), 256 * 3);
    }

    #[test]
    fn project_layer_row_split_outputs_raw_partials() {
        let l = Layer::conv("proj", 16, 16, 768, 128);
        let m = ConvMap::new(&l, 256);
        assert_eq!((m.n_row_tiles, m.n_col_tiles), (3, 1));
        assert!(m.row_split());
        let j = m.job(0, 0);
        assert_eq!(j.out_bytes, 128 * 4); // int32 partials
    }

    #[test]
    fn ragged_edge_tiles() {
        let l = Layer::conv("cl", 7, 7, 320, 1280);
        let m = ConvMap::new(&l, 256);
        assert_eq!((m.n_row_tiles, m.n_col_tiles), (2, 5));
        let edge = m.job(1, 0);
        assert_eq!(edge.in_bytes, 320 - 256);
        assert_eq!(m.n_jobs(), 49 * 10);
    }

    #[test]
    fn conv1_virtual_im2col_rows() {
        let l = Layer::conv("conv1", 224, 224, 3, 32).with_k(3, 2, 1);
        let m = ConvMap::new(&l, 256);
        assert_eq!(m.rows, 27);
        assert_eq!(m.n_jobs(), 112 * 112);
    }

    #[test]
    fn dw_map_matches_paper_device_formula() {
        let net = bottleneck::bottleneck();
        let dw = &net.layers[1];
        for c_job in [8, 16] {
            let m = DwMap::new(dw, c_job);
            assert_eq!(m.devices_total(), 9 * 768 * c_job);
            assert_eq!(m.n_jobs(), 256 * 768 / c_job);
            let j = m.job();
            assert_eq!(j.in_bytes, 9 * c_job);
            assert_eq!(j.out_bytes, c_job);
        }
    }

    #[test]
    fn dw_useful_fraction_is_one_over_cjob() {
        let net = bottleneck::bottleneck();
        let m = DwMap::new(&net.layers[1], 16);
        let j = m.job();
        // diagonal mapping: only 1/c_job of the block is true weights
        assert_eq!(j.useful_macs as usize * m.c_job, j.devices);
    }
}
