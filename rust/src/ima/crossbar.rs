//! PCM crossbar device array: programming, readout, noise (paper §III-A, §VI).
//!
//! Functional MVM numerics live in the AOT artifacts (L1 Pallas kernel); this
//! model owns the *state* view the coordinator needs: which cells hold which
//! conductance, how long programming takes (iterative program-and-verify,
//! 20–30× the MVM latency per row, §VI), and the conductance-error model used
//! by the noise ablation (weights are perturbed host-side and flow through
//! the same artifacts — DESIGN.md §3).

use crate::arch::SystemConfig;
use crate::util::rng::SplitMix64;

/// One 256×256 PCM crossbar's programmed state.
#[derive(Clone)]
pub struct Crossbar {
    pub rows: usize,
    pub cols: usize,
    /// Target 4-bit weights; `None` = unprogrammed (conductance ~0).
    cells: Vec<Option<i8>>,
    /// Rows that have been touched (programming is row-wise, §VI).
    rows_programmed: Vec<bool>,
}

impl Crossbar {
    pub fn new(rows: usize, cols: usize) -> Self {
        Crossbar {
            rows,
            cols,
            cells: vec![None; rows * cols],
            rows_programmed: vec![false; rows],
        }
    }

    pub fn program_tile(&mut self, row0: usize, col0: usize, tile: &[i8], trows: usize, tcols: usize) {
        assert!(row0 + trows <= self.rows && col0 + tcols <= self.cols);
        for r in 0..trows {
            for c in 0..tcols {
                let w = tile[r * tcols + c];
                debug_assert!((-8..=7).contains(&w), "int4 range");
                self.cells[(row0 + r) * self.cols + col0 + c] = Some(w);
            }
            self.rows_programmed[row0 + r] = true;
        }
    }

    pub fn read_cell(&self, r: usize, c: usize) -> Option<i8> {
        self.cells[r * self.cols + c]
    }

    pub fn programmed_cells(&self) -> usize {
        self.cells.iter().filter(|c| c.is_some()).count()
    }

    pub fn programmed_rows(&self) -> usize {
        self.rows_programmed.iter().filter(|&&b| b).count()
    }

    pub fn utilization(&self) -> f64 {
        self.programmed_cells() as f64 / (self.rows * self.cols) as f64
    }

    /// Programming time for the rows touched so far (s): row-wise iterative
    /// program-and-verify at `pcm_program_row_factor` × the MVM latency.
    pub fn programming_time_s(&self, cfg: &SystemConfig) -> f64 {
        self.programmed_rows() as f64 * cfg.pcm_program_row_factor * cfg.ima_mvm_ns * 1e-9
    }

    /// Extract the weights of a region as int8 values (unprogrammed = 0),
    /// with optional conductance noise: w' = round(w + N(0, σ·|w_max|)),
    /// clipped to int4 — the perturbed weights feed the same MVM artifacts.
    pub fn read_region_noisy(
        &self,
        row0: usize,
        col0: usize,
        trows: usize,
        tcols: usize,
        sigma: f64,
        rng: &mut SplitMix64,
    ) -> Vec<i8> {
        let mut out = Vec::with_capacity(trows * tcols);
        for r in 0..trows {
            for c in 0..tcols {
                let w = self.cells[(row0 + r) * self.cols + col0 + c].unwrap_or(0) as f64;
                let noisy = if sigma > 0.0 {
                    (w + rng.next_gauss() * sigma * 8.0).round()
                } else {
                    w
                };
                out.push(noisy.clamp(-8.0, 7.0) as i8);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn program_and_read_back() {
        let mut xb = Crossbar::new(256, 256);
        let tile = vec![3i8; 4 * 5];
        xb.program_tile(10, 20, &tile, 4, 5);
        assert_eq!(xb.read_cell(10, 20), Some(3));
        assert_eq!(xb.read_cell(13, 24), Some(3));
        assert_eq!(xb.read_cell(14, 24), None);
        assert_eq!(xb.programmed_cells(), 20);
        assert_eq!(xb.programmed_rows(), 4);
    }

    #[test]
    fn programming_time_magnitude() {
        // full 256-row crossbar at 25×130 ns/row ≈ 0.83 ms — "considerably
        // larger than an MVM" (paper §VI), i.e. ~6400 MVMs' worth
        let mut xb = Crossbar::new(256, 256);
        let tile = vec![1i8; 256 * 256];
        xb.program_tile(0, 0, &tile, 256, 256);
        let cfg = SystemConfig::paper();
        let t = xb.programming_time_s(&cfg);
        assert!((0.5e-3..1.5e-3).contains(&t), "{t}");
        let mvms_equiv = t / (cfg.ima_mvm_ns * 1e-9);
        assert!(mvms_equiv > 1000.0);
    }

    #[test]
    fn noiseless_read_is_exact() {
        let mut xb = Crossbar::new(16, 16);
        let tile: Vec<i8> = (0..16).map(|i| (i % 16) as i8 - 8).collect();
        xb.program_tile(0, 0, &tile, 1, 16);
        let mut rng = SplitMix64::new(1);
        let got = xb.read_region_noisy(0, 0, 1, 16, 0.0, &mut rng);
        assert_eq!(got, tile);
    }

    #[test]
    fn noisy_read_stays_int4_and_perturbs() {
        let mut xb = Crossbar::new(16, 16);
        let tile = vec![5i8; 16 * 16];
        xb.program_tile(0, 0, &tile, 16, 16);
        let mut rng = SplitMix64::new(2);
        let got = xb.read_region_noisy(0, 0, 16, 16, 0.1, &mut rng);
        assert!(got.iter().all(|&w| (-8..=7).contains(&w)));
        assert!(got.iter().any(|&w| w != 5), "σ=0.1 must perturb something");
    }
}
