//! The dedicated depth-wise digital accelerator (paper §IV-C, Figs. 4/5).
//!
//! Weight-stationary 3×3 engine: 16 channels per block, a 3×3×16 weight
//! buffer, a 4×3×16 sliding window buffer, a 36-multiplier MAC network
//! (3×3×4 per cycle), ReLU + shift&clip epilogue. The LD/MAC/ST pipeline
//! processes one output pixel (16 channels) per 4-cycle inner loop during
//! the steady state → 36 MAC/cycle peak, 29.7 MAC/cycle average on real
//! layers once preload/prime overheads are charged.
//!
//! [`datapath`] is the cycle-exact schedule of Fig. 5b; functional numerics
//! live in the `dw3x3` Pallas artifacts.

pub mod datapath;

pub use datapath::{dw_layer_cost, DwAccCost};
