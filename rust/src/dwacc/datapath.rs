//! Cycle-exact schedule of the depth-wise engine (paper Fig. 5).
//!
//! Per 16-channel block:
//!   * weight preload: 3×3×16 bytes through the 16 B/cycle port (9 cycles);
//!   * per output column: window-buffer prime (3×3 pixels × 16 ch = 9 beats)
//!     then the LD/MAC/ST inner loop:
//!       - stride 1: the window slides one row → LD 3 pixels (3 cycles),
//!         MAC 4 cycles (4 channels each), ST overlapped in cycle 4 →
//!         4 cycles per output pixel;
//!       - stride 2: the window slides two rows → LD 6 pixels dominates →
//!         6 cycles per output pixel.
//!
//! Peak = 36 MAC/cycle (3×3×4 multipliers); the paper's quoted *average* of
//! 29.7 MAC/cycle emerges from the prime/preload overheads and the stride-2
//! layers (see `average_rate_matches_paper`).

use crate::arch::{EnergyAccount, PowerModel, SystemConfig};
use crate::net::Layer;

pub const CH_BLOCK: usize = 16;

#[derive(Clone, Debug, Default)]
pub struct DwAccCost {
    pub cycles: u64,
    pub macs: u64,
    pub energy: EnergyAccount,
}

impl DwAccCost {
    pub fn macs_per_cycle(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.macs as f64 / self.cycles as f64
        }
    }
}

/// Cycles for one 16-channel block of an `hout`×`wout` output tile.
fn block_cycles(hout: usize, wout: usize, stride: usize, setup_cy: u64) -> u64 {
    let preload = 9u64; // 3×3×16 B at 16 B/cycle
    let prime = 9u64; // first 3×3 window × 16 ch
    let per_pixel = match stride {
        1 => 4u64,
        2 => 6u64,
        _ => 2 + 2 * stride as u64, // generalization (unused by MNv2)
    };
    setup_cy + preload + wout as u64 * (prime + hout as u64 * per_pixel)
}

/// Full-layer cost on the dedicated accelerator.
pub fn dw_layer_cost(l: &Layer, cfg: &SystemConfig, pm: &PowerModel) -> DwAccCost {
    assert_eq!(l.k, 3, "the engine targets 3×3 depth-wise kernels");
    let blocks = l.cout.div_ceil(CH_BLOCK) as u64;
    let cycles = blocks * block_cycles(l.hout(), l.wout(), l.stride, cfg.dw_setup_cy);
    let macs = l.macs();

    let mut e = EnergyAccount::default();
    e.wall_cy = cycles;
    e.dw_active_cy = cycles;
    // LD dominates the port: ~1 beat/cycle through the shared HWPE port
    e.tcdm_duty_millicycles = cycles * 800;
    // one core triggers then sleeps; others gated
    e.core_active_cy = cfg.ima_layer_cfg_cy / 2;
    e.core_idle_cy = cycles * cfg.n_cores as u64;
    let _ = pm;
    DwAccCost { cycles, macs, energy: e }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::mobilenetv2::mobilenet_v2;
    use crate::net::{Layer, LayerKind};

    fn cost(l: &Layer) -> DwAccCost {
        let cfg = SystemConfig::paper();
        let pm = PowerModel::paper();
        dw_layer_cost(l, &cfg, &pm)
    }

    #[test]
    fn steady_state_rate_approaches_36() {
        // huge stride-1 layer: prime/preload amortize away
        let l = Layer::dw("big", 512, 512, 16, 1);
        let c = cost(&l);
        let r = c.macs_per_cycle();
        assert!((34.0..36.0).contains(&r), "{r}");
    }

    #[test]
    fn stride2_rate_is_two_thirds() {
        let l = Layer::dw("s2", 512, 512, 16, 2);
        let r = cost(&l).macs_per_cycle();
        assert!((22.0..24.5).contains(&r), "{r}");
    }

    #[test]
    fn average_rate_matches_paper() {
        // paper §IV-C: "an average performance of 29.7 MAC/cycle" — measured
        // over the depth-wise layers the system actually runs (MobileNetV2)
        let net = mobilenet_v2(224);
        let mut macs = 0u64;
        let mut cycles = 0u64;
        for l in net.layers.iter().filter(|l| l.kind == LayerKind::Dw) {
            let c = cost(l);
            macs += c.macs;
            cycles += c.cycles;
        }
        let avg = macs as f64 / cycles as f64;
        assert!(
            (27.0..33.0).contains(&avg),
            "average {avg} MAC/cycle (paper: 29.7)"
        );
    }

    #[test]
    fn speedup_vs_single_core_software_about_26x() {
        // paper §IV-C: 26× over a pure (single-core) software implementation
        let cfg = SystemConfig::paper();
        let l = Layer::dw("bneck", 16, 16, 768, 1);
        let acc = cost(&l);
        let sw_cy = l.macs() as f64 / cfg.sw_dw_macs_per_cycle_1core;
        let speedup = sw_cy / acc.cycles as f64;
        assert!((20.0..32.0).contains(&speedup), "{speedup}");
    }

    #[test]
    fn channel_blocks_round_up() {
        let l24 = Layer::dw("c24", 32, 32, 24, 1);
        let l32 = Layer::dw("c32", 32, 32, 32, 1);
        // 24 channels still needs 2 blocks
        assert_eq!(cost(&l24).cycles, cost(&l32).cycles);
    }

    #[test]
    fn energy_account_is_populated() {
        let l = Layer::dw("e", 64, 64, 64, 1);
        let c = cost(&l);
        assert_eq!(c.energy.dw_active_cy, c.cycles);
        assert!(c.energy.tcdm_duty_millicycles > 0);
    }
}
