//! Cluster simulation substrates.
//!
//! The engines (`ima`, `dwacc`, `cores`) produce *phase demands* (stream
//! bytes, compute latencies); this module turns them into cycle-accurate
//! schedules and activity ledgers:
//!
//! * [`pipeline`] — resource-constrained list scheduler for the IMA's
//!   three-phase jobs (the sequential/pipelined execution models of Fig. 3);
//! * [`tcdm`] — banked-memory contention model for the logarithmic
//!   interconnect;
//! * [`event_unit`] — synchronization/wake-up costs;
//! * [`dma`] — L2↔TCDM transfer model (double-buffering analysis).

pub mod dma;
pub mod event_unit;
pub mod pipeline;
pub mod tcdm;
