//! Three-phase job pipeline scheduler (paper Fig. 3b).
//!
//! Each IMA job is STREAM-IN → COMPUTE → STREAM-OUT. The two stream phases
//! contend for the single HWPE data port (the streamer's source and sink are
//! *dynamically multiplexed*, §IV-A); COMPUTE owns the crossbar. The
//! sequential model serializes everything; the pipelined model lets phases of
//! *different* jobs overlap subject to those two resources — exactly what the
//! added pipeline registers buy (§IV-B).
//!
//! This is an exact greedy list schedule (jobs issue in order, each phase
//! starts as soon as its predecessor phase and its resource allow), which is
//! how the engine FSM behaves.

/// One job's phase durations in cycles.
#[derive(Clone, Copy, Debug)]
pub struct JobPhases {
    pub stream_in: u64,
    pub compute: u64,
    pub stream_out: u64,
    /// Cycles the controlling core spends issuing this job (occupies
    /// neither port nor crossbar but delays the *next* issue).
    pub issue: u64,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct Schedule {
    /// Total makespan in cycles.
    pub makespan: u64,
    /// Cycles the data port was busy (TCDM side activity for energy).
    pub port_busy: u64,
    /// Cycles the crossbar was computing (analog active time).
    pub xbar_busy: u64,
}

/// Sequential model: phases of each job strictly in order, no overlap.
pub fn schedule_sequential<I: IntoIterator<Item = JobPhases>>(jobs: I) -> Schedule {
    let mut t = 0u64;
    let mut port = 0u64;
    let mut xbar = 0u64;
    for j in jobs {
        t += j.issue + j.stream_in + j.compute + j.stream_out;
        port += j.stream_in + j.stream_out;
        xbar += j.compute;
    }
    Schedule {
        makespan: t,
        port_busy: port,
        xbar_busy: xbar,
    }
}

/// Pipelined model, implementing the paper's engine-FSM policy (§IV-B):
/// during the compute phase of job *i*, the streamer first fetches the
/// inputs of job *i+1*, then drains the results of job *i-1* — i.e. the
/// port service order is IN₀, IN₁, OUT₀, IN₂, OUT₁, … The extra pipeline
/// registers allow exactly one job of look-ahead on each side.
pub fn schedule_pipelined(jobs: Vec<JobPhases>) -> Schedule {
    let n = jobs.len();
    if n == 0 {
        return Schedule::default();
    }
    let mut port_free = 0u64;
    let mut issue_done = vec![0u64; n];
    let mut acc = 0u64;
    for (i, j) in jobs.iter().enumerate() {
        acc += j.issue;
        issue_done[i] = acc;
    }
    let mut in_end = vec![0u64; n];
    let mut comp_start = vec![0u64; n];
    let mut comp_end = vec![0u64; n];
    let mut port_busy = 0u64;
    let mut xbar_busy = 0u64;
    let mut makespan = 0u64;

    // IN_0
    let in0_start = issue_done[0].max(port_free);
    in_end[0] = in0_start + jobs[0].stream_in;
    port_free = in_end[0];
    port_busy += jobs[0].stream_in;
    comp_start[0] = in_end[0];
    comp_end[0] = comp_start[0] + jobs[0].compute;
    xbar_busy += jobs[0].compute;

    for i in 1..n {
        // IN_i: port free, issue done, and the input pipeline register is
        // free once COMP_{i-1} has latched its operands (= comp start).
        let in_start = port_free.max(issue_done[i]).max(comp_start[i - 1]);
        in_end[i] = in_start + jobs[i].stream_in;
        port_free = in_end[i];
        port_busy += jobs[i].stream_in;

        // OUT_{i-1}: after its compute, in FSM order after IN_i.
        let out_start = port_free.max(comp_end[i - 1]);
        let out_end = out_start + jobs[i - 1].stream_out;
        port_free = out_end;
        port_busy += jobs[i - 1].stream_out;
        makespan = makespan.max(out_end);

        comp_start[i] = in_end[i].max(comp_end[i - 1]);
        comp_end[i] = comp_start[i] + jobs[i].compute;
        xbar_busy += jobs[i].compute;
    }
    // final OUT
    let out_start = port_free.max(comp_end[n - 1]);
    let out_end = out_start + jobs[n - 1].stream_out;
    port_busy += jobs[n - 1].stream_out;
    makespan = makespan.max(out_end).max(comp_end[n - 1]);

    Schedule {
        makespan,
        port_busy,
        xbar_busy,
    }
}

/// Closed-form steady-state estimate for `n` identical pipelined jobs —
/// used by the roofline sweeps where exact scheduling of millions of jobs
/// would be wasteful. Exact for the uniform-job case (see property test).
pub fn steady_state_pipelined(n: u64, j: JobPhases) -> Schedule {
    if n == 0 {
        return Schedule::default();
    }
    let stage = (j.stream_in + j.stream_out)
        .max(j.compute)
        .max(j.issue);
    // fill + (n-1) steady iterations + drain
    let fill = j.issue + j.stream_in + j.compute + j.stream_out;
    Schedule {
        makespan: fill + (n - 1) * stage,
        port_busy: n * (j.stream_in + j.stream_out),
        xbar_busy: n * j.compute,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn uni(n: u64, ji: JobPhases) -> Vec<JobPhases> {
        (0..n).map(|_| ji).collect()
    }

    #[test]
    fn sequential_sums_everything() {
        let j = JobPhases {
            stream_in: 10,
            compute: 65,
            stream_out: 12,
            issue: 3,
        };
        let s = schedule_sequential(uni(4, j));
        assert_eq!(s.makespan, 4 * (10 + 65 + 12 + 3));
        assert_eq!(s.xbar_busy, 4 * 65);
        assert_eq!(s.port_busy, 4 * 22);
    }

    #[test]
    fn pipelined_compute_bound_hits_compute_rate() {
        // compute 65 dominates port (10+12): steady state = 65/job
        let j = JobPhases {
            stream_in: 10,
            compute: 65,
            stream_out: 12,
            issue: 1,
        };
        let n = 1000;
        let s = schedule_pipelined(uni(n, j));
        let per_job = s.makespan as f64 / n as f64;
        assert!((per_job - 65.0).abs() < 0.2, "{per_job}");
    }

    #[test]
    fn pipelined_memory_bound_hits_port_rate() {
        // port 40+40 dominates compute 33: steady state = 80/job
        let j = JobPhases {
            stream_in: 40,
            compute: 33,
            stream_out: 40,
            issue: 1,
        };
        let n = 500;
        let s = schedule_pipelined(uni(n, j));
        let per_job = s.makespan as f64 / n as f64;
        assert!((per_job - 80.0).abs() < 0.5, "{per_job}");
    }

    #[test]
    fn pipelined_never_slower_than_sequential() {
        prop::check("pipe_le_seq", 200, |rng| {
            let n = rng.range_i64(1, 40) as u64;
            let jobs: Vec<JobPhases> = (0..n)
                .map(|_| JobPhases {
                    stream_in: rng.range_i64(0, 100) as u64,
                    compute: rng.range_i64(1, 100) as u64,
                    stream_out: rng.range_i64(0, 100) as u64,
                    issue: rng.range_i64(0, 10) as u64,
                })
                .collect();
            let seq = schedule_sequential(jobs.clone());
            let pipe = schedule_pipelined(jobs.clone());
            assert!(pipe.makespan <= seq.makespan, "{pipe:?} vs {seq:?}");
            assert_eq!(pipe.xbar_busy, seq.xbar_busy);
            assert_eq!(pipe.port_busy, seq.port_busy);
            // lower bounds: resources can't be beaten
            let port_total: u64 = jobs.iter().map(|j| j.stream_in + j.stream_out).sum();
            let xbar_total: u64 = jobs.iter().map(|j| j.compute).sum();
            assert!(pipe.makespan >= port_total.max(xbar_total));
        });
    }

    #[test]
    fn steady_state_matches_exact_for_uniform_jobs() {
        prop::check("steady_state_exact", 200, |rng| {
            let j = JobPhases {
                stream_in: rng.range_i64(0, 60) as u64,
                compute: rng.range_i64(1, 90) as u64,
                stream_out: rng.range_i64(0, 60) as u64,
                issue: rng.range_i64(0, 5) as u64,
            };
            let n = rng.range_i64(1, 200) as u64;
            let exact = schedule_pipelined(uni(n, j));
            let est = steady_state_pipelined(n, j);
            // The closed form is exact when one stage strictly dominates;
            // otherwise it can differ by at most one pipeline fill.
            let fill = j.issue + j.stream_in + j.compute + j.stream_out;
            let diff = est.makespan.abs_diff(exact.makespan);
            assert!(diff <= fill, "diff {diff} > fill {fill} ({j:?}, n={n})");
            assert_eq!(est.xbar_busy, exact.xbar_busy);
            assert_eq!(est.port_busy, exact.port_busy);
        });
    }

    #[test]
    fn empty_job_stream() {
        assert_eq!(schedule_pipelined(Vec::new()).makespan, 0);
        assert_eq!(steady_state_pipelined(0, JobPhases { stream_in: 1, compute: 1, stream_out: 1, issue: 0 }).makespan, 0);
    }
}
