//! Hardware Synchronization Unit model (paper §III-B): barriers, HWPE
//! end-of-computation events, clock-gated sleep/wake costs.

#[derive(Clone, Copy, Debug)]
pub struct EventUnit {
    /// Cycles for a full 8-core barrier (enter → all gated → release).
    pub barrier_cy: u64,
    /// Cycles from an HWPE end-of-computation event to the waiting core
    /// resuming execution (clock-ungate + pipeline refill).
    pub wakeup_cy: u64,
    /// Cycles for a core to enter the clock-gated wait state.
    pub sleep_cy: u64,
}

impl EventUnit {
    pub fn paper() -> Self {
        // "low-overhead and fine-grained parallelism" — single-digit to
        // low-double-digit cycles in the PULP cluster event unit.
        EventUnit {
            barrier_cy: 12,
            wakeup_cy: 8,
            sleep_cy: 2,
        }
    }

    /// Total synchronization cost of offloading one accelerator job batch:
    /// core programs the HWPE, sleeps, is woken at end of computation.
    pub fn offload_roundtrip_cy(&self) -> u64 {
        self.sleep_cy + self.wakeup_cy
    }

    /// Cost of a parallel section over `n_chunks` of work distributed on
    /// `n_cores`: one dispatch barrier + one join barrier; returns the
    /// overhead cycles to add to the parallel work itself.
    pub fn parallel_section_overhead_cy(&self, n_chunks: usize, n_cores: usize) -> u64 {
        let waves = n_chunks.div_ceil(n_cores.max(1)) as u64;
        2 * self.barrier_cy + waves.saturating_sub(1) * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_is_small() {
        let eu = EventUnit::paper();
        assert!(eu.offload_roundtrip_cy() <= 16);
    }

    #[test]
    fn parallel_overhead_grows_with_waves() {
        let eu = EventUnit::paper();
        let one = eu.parallel_section_overhead_cy(8, 8);
        let many = eu.parallel_section_overhead_cy(64, 8);
        assert!(many > one);
        assert_eq!(one, 2 * eu.barrier_cy);
    }
}
