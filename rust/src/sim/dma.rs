//! Cluster DMA model (paper §III-B): TCDM ↔ L2 transfers.
//!
//! The paper's end-to-end study keeps all activations resident in L1 and
//! argues (§VI) that double buffering hides L2 traffic; this model lets us
//! *check* that claim as an ablation instead of assuming it.

#[derive(Clone, Copy, Debug)]
pub struct DmaModel {
    /// AXI beat width towards L2, bytes per cluster cycle.
    pub bytes_per_cycle: usize,
    /// One-off programming + arbitration latency per transfer.
    pub setup_cy: u64,
}

impl DmaModel {
    pub fn paper() -> Self {
        DmaModel {
            bytes_per_cycle: 8,
            setup_cy: 30,
        }
    }

    pub fn transfer_cy(&self, bytes: usize) -> u64 {
        if bytes == 0 {
            return 0;
        }
        self.setup_cy + (bytes.div_ceil(self.bytes_per_cycle)) as u64
    }

    /// Double-buffering check: can a transfer of `bytes` hide behind
    /// `compute_cy` cycles of engine work?
    pub fn hides_behind(&self, bytes: usize, compute_cy: u64) -> bool {
        self.transfer_cy(bytes) <= compute_cy
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_cost() {
        let d = DmaModel::paper();
        assert_eq!(d.transfer_cy(0), 0);
        assert_eq!(d.transfer_cy(8), 30 + 1);
        assert_eq!(d.transfer_cy(1024), 30 + 128);
    }

    #[test]
    fn double_buffering_typical_layer() {
        // a 56x56x24 activation (75 kB) vs ~1 M compute cycles: hidden
        let d = DmaModel::paper();
        assert!(d.hides_behind(56 * 56 * 24, 1_000_000));
        assert!(!d.hides_behind(1 << 20, 1000));
    }
}
