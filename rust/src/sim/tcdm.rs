//! TCDM banking/contention model (paper §III-B).
//!
//! 512 kB over 32 word-interleaved banks behind a 1-cycle logarithmic
//! interconnect. Conflicts arise when multiple masters hit the same bank in
//! the same cycle; the LIC serializes them (round-robin). Two access
//! regimes matter here:
//!
//! * **streamer bursts** (HWPE): contiguous word-aligned streams walk the
//!   interleaving — zero self-conflict; conflict only against other masters;
//! * **parallel cores** (PULP-NN): effectively random bank picks each cycle —
//!   modeled with the classic random-access acceptance probability.

#[derive(Clone, Copy, Debug)]
pub struct TcdmModel {
    pub banks: usize,
    pub word_bytes: usize,
}

impl TcdmModel {
    pub fn paper() -> Self {
        TcdmModel {
            banks: 32,
            word_bytes: 4,
        }
    }

    /// Expected fraction of requests served per cycle when `n` masters each
    /// issue one random-bank request per cycle:
    /// `E[served]/n = B/n * (1 - (1 - 1/B)^n)`.
    pub fn random_access_efficiency(&self, n_masters: usize) -> f64 {
        if n_masters == 0 {
            return 1.0;
        }
        let b = self.banks as f64;
        let n = n_masters as f64;
        (b / n) * (1.0 - (1.0 - 1.0 / b).powf(n))
    }

    /// Effective slowdown factor (>= 1) for `n` cores doing load-heavy
    /// kernels; PULP-NN throughput constants in `arch::params` are quoted
    /// *with* this effect at n=8, so engines use it only for what-if sweeps.
    pub fn core_contention_slowdown(&self, n_masters: usize) -> f64 {
        1.0 / self.random_access_efficiency(n_masters)
    }

    /// Cycles to stream `bytes` through a port of `port_bytes`/cycle with
    /// the streamer walking interleaved banks (self-conflict-free), plus
    /// an extra per-transfer realigner cost when the base is misaligned.
    pub fn stream_cycles(&self, bytes: usize, port_bytes: usize, misaligned: bool) -> u64 {
        if bytes == 0 {
            return 0;
        }
        let beats = bytes.div_ceil(port_bytes) as u64;
        beats + if misaligned { 1 } else { 0 }
    }

    /// Contention factor between one streaming HWPE port and `n_cores`
    /// actively accessing cores: the streamer claims `port_bytes /
    /// word_bytes` banks per cycle out of `banks`.
    pub fn stream_vs_cores_factor(&self, port_bytes: usize, n_cores_active: usize) -> f64 {
        if n_cores_active == 0 {
            return 1.0;
        }
        let stream_banks = (port_bytes / self.word_bytes).max(1) as f64;
        let p_hit = stream_banks / self.banks as f64; // core hits a stream bank
        1.0 + p_hit * n_cores_active as f64 / self.banks as f64 * self.banks as f64 / stream_banks
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn single_master_no_contention() {
        let t = TcdmModel::paper();
        assert!((t.random_access_efficiency(1) - 1.0).abs() < 1e-12);
        assert!((t.core_contention_slowdown(1) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn eight_cores_on_32_banks_mild_contention() {
        let t = TcdmModel::paper();
        let eff = t.random_access_efficiency(8);
        // classic result: ~89 % acceptance for 8 masters on 32 banks
        assert!((0.85..0.93).contains(&eff), "{eff}");
    }

    #[test]
    fn efficiency_monotonic_in_masters() {
        let t = TcdmModel::paper();
        prop::check("tcdm_monotone", 64, |rng| {
            let a = rng.range_i64(1, 63) as usize;
            let b = a + rng.range_i64(1, 16) as usize;
            assert!(
                t.random_access_efficiency(a) >= t.random_access_efficiency(b) - 1e-12
            );
        });
    }

    #[test]
    fn stream_cycles_exact_beats() {
        let t = TcdmModel::paper();
        assert_eq!(t.stream_cycles(256, 16, false), 16);
        assert_eq!(t.stream_cycles(257, 16, false), 17);
        assert_eq!(t.stream_cycles(0, 16, false), 0);
        assert_eq!(t.stream_cycles(16, 16, true), 2);
    }

    #[test]
    fn stream_contention_bounded() {
        let t = TcdmModel::paper();
        let f = t.stream_vs_cores_factor(16, 8);
        assert!(f >= 1.0 && f < 1.5, "{f}");
        assert_eq!(t.stream_vs_cores_factor(16, 0), 1.0);
    }
}
