//! MobileNetV2 builder (Sandler et al. 2018), width 1.0, 224×224 — the
//! paper's end-to-end workload (§VI). Mirrors `python/compile/netspec.py`
//! exactly; the integration test `tests/integration_manifest.rs` asserts the
//! two never drift.

use super::layer::{Layer, LayerKind, Network};

/// Inverted-residual settings: (expansion t, out channels c, repeats n,
/// first-block stride s).
pub const MNV2_BLOCKS: [(usize, usize, usize, usize); 7] = [
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
];

pub fn mobilenet_v2(resolution: usize) -> Network {
    let mut layers: Vec<Layer> = Vec::new();
    let mut h = resolution;
    let mut cin = 3usize;

    layers.push(
        Layer::conv("conv1", h, h, cin, 32)
            .with_k(3, 2, 1)
            .with_relu(),
    );
    h = layers.last().unwrap().hout();
    cin = 32;

    for (bi, (t, ch, n, s)) in MNV2_BLOCKS.iter().enumerate() {
        for i in 0..*n {
            let stride = if i == 0 { *s } else { 1 };
            let prefix = format!("bneck{}_{}", bi + 1, i);
            let block_in_idx = layers.len() - 1;
            let hid = cin * t;
            if *t != 1 {
                layers.push(
                    Layer::conv(&format!("{prefix}_exp"), h, h, cin, hid).with_relu(),
                );
            }
            layers.push(Layer::dw(&format!("{prefix}_dw"), h, h, hid, stride));
            h = layers.last().unwrap().hout();
            layers.push(Layer::conv(&format!("{prefix}_proj"), h, h, hid, *ch));
            if stride == 1 && cin == *ch {
                layers.push(Layer::add(&format!("{prefix}_add"), h, h, *ch, block_in_idx));
            }
            cin = *ch;
        }
    }

    layers.push(Layer::conv("conv_last", h, h, cin, 1280).with_relu());
    layers.push(Layer {
        name: "pool".into(),
        kind: LayerKind::Pool,
        hin: h,
        win: h,
        cin: 1280,
        cout: 1280,
        k: 1,
        stride: 1,
        pad: 0,
        relu: false,
        residual_from: None,
        shift: 0,
    });
    layers.push(Layer {
        name: "fc".into(),
        kind: LayerKind::Fc,
        hin: 1,
        win: 1,
        cin: 1280,
        cout: 1000,
        k: 1,
        stride: 1,
        pad: 0,
        relu: false,
        residual_from: None,
        shift: 0,
    });

    let net = Network {
        name: "mobilenetv2".into(),
        layers,
    };
    debug_assert!(net.validate().is_ok(), "{:?}", net.validate());
    net
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anatomy() {
        let net = mobilenet_v2(224);
        net.validate().unwrap();
        assert_eq!(net.layers[0].hout(), 112);
        let dws = net
            .layers
            .iter()
            .filter(|l| l.kind == LayerKind::Dw)
            .count();
        assert_eq!(dws, 17);
        let adds = net
            .layers
            .iter()
            .filter(|l| l.kind == LayerKind::Add)
            .count();
        assert_eq!(adds, 10);
        assert_eq!(net.layers.last().unwrap().cout, 1000);
    }

    #[test]
    fn macs_match_the_literature() {
        let net = mobilenet_v2(224);
        let m = net.total_macs();
        assert!(
            (280_000_000..330_000_000).contains(&m),
            "MobileNetV2 ≈ 300 MMAC, got {m}"
        );
    }

    #[test]
    fn conv_weight_volume_drives_tilepack() {
        let net = mobilenet_v2(224);
        // conv weights only — TILE&PACK maps the convolutional layers on
        // crossbars (the paper's 34 IMAs = 2.23 M devices fit exactly the
        // ~2.1 M conv weights + fragmentation; the 1.28 M-weight classifier
        // is not crossbar-resident and runs on the cores in §VI)
        let conv_w: usize = net
            .layers
            .iter()
            .filter(|l| l.kind == LayerKind::Conv)
            .map(|l| l.n_weights())
            .sum();
        assert!((2_000_000..2_300_000).contains(&conv_w), "{conv_w}");
        // whole model incl. classifier ≈ 3.4 M params (the literature value)
        assert!((3_300_000..3_600_000).contains(&net.total_weights()));
    }

    #[test]
    fn final_stage_resolution_is_7x7() {
        let net = mobilenet_v2(224);
        let conv_last = net
            .layers
            .iter()
            .find(|l| l.name == "conv_last")
            .unwrap();
        assert_eq!(conv_last.hin, 7);
    }
}
