//! Layer and network descriptors (mirrors `python/compile/netspec.py`).

/// Layer kinds — the paper's workload taxonomy (§V-C): dense MVM-shaped
/// layers go to the IMA, depth-wise to the digital accelerator, the rest to
/// the cores.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    /// Standard/point-wise convolution (IMA via virtual im2col).
    Conv,
    /// 3×3 depth-wise convolution.
    Dw,
    /// int8 saturating residual add (`residual_from` points at the source).
    Add,
    /// Global average pool.
    Pool,
    /// Fully connected (IMA, rows = Cin).
    Fc,
}

#[derive(Clone, Debug)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    pub hin: usize,
    pub win: usize,
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    pub relu: bool,
    pub residual_from: Option<usize>,
    /// Requantization shift (filled from the manifest for functional runs;
    /// irrelevant to timing).
    pub shift: i32,
}

impl Layer {
    pub fn conv(name: &str, hin: usize, win: usize, cin: usize, cout: usize) -> Layer {
        Layer {
            name: name.into(),
            kind: LayerKind::Conv,
            hin,
            win,
            cin,
            cout,
            k: 1,
            stride: 1,
            pad: 0,
            relu: false,
            residual_from: None,
            shift: 0,
        }
    }

    pub fn with_k(mut self, k: usize, stride: usize, pad: usize) -> Layer {
        self.k = k;
        self.stride = stride;
        self.pad = pad;
        self
    }

    pub fn with_relu(mut self) -> Layer {
        self.relu = true;
        self
    }

    pub fn dw(name: &str, hin: usize, win: usize, c: usize, stride: usize) -> Layer {
        Layer {
            name: name.into(),
            kind: LayerKind::Dw,
            hin,
            win,
            cin: c,
            cout: c,
            k: 3,
            stride,
            pad: 1,
            relu: true,
            residual_from: None,
            shift: 0,
        }
    }

    pub fn add(name: &str, h: usize, w: usize, c: usize, from: usize) -> Layer {
        Layer {
            name: name.into(),
            kind: LayerKind::Add,
            hin: h,
            win: w,
            cin: c,
            cout: c,
            k: 1,
            stride: 1,
            pad: 0,
            relu: false,
            residual_from: Some(from),
            shift: 0,
        }
    }

    pub fn hout(&self) -> usize {
        match self.kind {
            LayerKind::Add => self.hin,
            LayerKind::Pool | LayerKind::Fc => 1,
            _ => (self.hin + 2 * self.pad - self.k) / self.stride + 1,
        }
    }

    pub fn wout(&self) -> usize {
        match self.kind {
            LayerKind::Add => self.win,
            LayerKind::Pool | LayerKind::Fc => 1,
            _ => (self.win + 2 * self.pad - self.k) / self.stride + 1,
        }
    }

    /// Output pixels (spatial).
    pub fn out_pixels(&self) -> usize {
        self.hout() * self.wout()
    }

    /// Crossbar mapping rows (virtual-im2col depth) for IMA-mapped layers.
    pub fn xbar_map_rows(&self) -> usize {
        self.k * self.k * self.cin
    }

    /// MAC count (paper convention: 1 MAC = 2 ops).
    pub fn macs(&self) -> u64 {
        match self.kind {
            LayerKind::Conv | LayerKind::Fc => {
                (self.out_pixels() * self.k * self.k * self.cin * self.cout) as u64
            }
            LayerKind::Dw => (self.out_pixels() * 9 * self.cout) as u64,
            _ => 0,
        }
    }

    /// Total op count including non-MAC layers (adds/pools count 1 op/elem).
    pub fn ops(&self) -> u64 {
        match self.kind {
            LayerKind::Add => (self.out_pixels() * self.cout) as u64,
            LayerKind::Pool => (self.hin * self.win * self.cin) as u64,
            _ => 2 * self.macs(),
        }
    }

    /// Weight element count in the serialized layout.
    pub fn n_weights(&self) -> usize {
        match self.kind {
            LayerKind::Conv | LayerKind::Fc => self.k * self.k * self.cin * self.cout,
            LayerKind::Dw => 9 * self.cin,
            _ => 0,
        }
    }

    pub fn in_bytes(&self) -> usize {
        self.hin * self.win * self.cin
    }

    pub fn out_bytes(&self) -> usize {
        self.out_pixels() * self.cout
    }
}

/// A network is a flat layer list; residual edges are indices into it.
#[derive(Clone, Debug, Default)]
pub struct Network {
    pub name: String,
    pub layers: Vec<Layer>,
}

impl Network {
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Geometry fingerprint (FNV-1a over layer kinds + shapes, names
    /// excluded, order-sensitive). Placements and cached plans key on it so
    /// a plan can never silently be applied to a different network.
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut mix = |v: u64| {
            for b in v.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x1000_0000_01b3);
            }
        };
        mix(self.layers.len() as u64);
        for l in &self.layers {
            mix(l.kind as u64);
            for v in [l.hin, l.win, l.cin, l.cout, l.k, l.stride, l.pad] {
                mix(v as u64);
            }
            mix(l.residual_from.map(|v| v as u64 + 1).unwrap_or(0));
        }
        h
    }

    pub fn total_ops(&self) -> u64 {
        self.layers.iter().map(|l| l.ops()).sum()
    }

    pub fn total_weights(&self) -> usize {
        self.layers.iter().map(|l| l.n_weights()).sum()
    }

    /// Validate residual links and inter-layer shape consistency.
    pub fn validate(&self) -> Result<(), String> {
        let mut prev_out: Option<(usize, usize, usize)> = None;
        for (i, l) in self.layers.iter().enumerate() {
            if let Some((h, w, c)) = prev_out {
                if l.kind != LayerKind::Fc && (l.hin, l.win, l.cin) != (h, w, c) {
                    return Err(format!(
                        "layer {i} `{}` input {:?} != previous output {:?}",
                        l.name,
                        (l.hin, l.win, l.cin),
                        (h, w, c)
                    ));
                }
                if l.kind == LayerKind::Fc && l.cin != h * w * c {
                    return Err(format!("fc layer {i} cin {} != {}", l.cin, h * w * c));
                }
            }
            if let Some(src) = l.residual_from {
                if src >= i {
                    return Err(format!("layer {i} residual_from {src} is not earlier"));
                }
                let s = &self.layers[src];
                if (s.hout(), s.wout(), s.cout) != (l.hin, l.win, l.cin) {
                    return Err(format!(
                        "layer {i} `{}` residual source shape mismatch",
                        l.name
                    ));
                }
            }
            prev_out = Some((l.hout(), l.wout(), l.cout));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_shape_algebra() {
        let l = Layer::conv("c", 224, 224, 3, 32).with_k(3, 2, 1);
        assert_eq!(l.hout(), 112);
        assert_eq!(l.xbar_map_rows(), 27);
        assert_eq!(l.macs(), 112 * 112 * 27 * 32);
        assert_eq!(l.n_weights(), 27 * 32);
    }

    #[test]
    fn dw_shape_algebra() {
        let l = Layer::dw("d", 56, 56, 144, 2);
        assert_eq!(l.hout(), 28);
        assert_eq!(l.macs(), 28 * 28 * 9 * 144);
        assert_eq!(l.n_weights(), 9 * 144);
    }

    #[test]
    fn validate_catches_shape_break() {
        let mut n = Network {
            name: "x".into(),
            layers: vec![
                Layer::conv("a", 8, 8, 3, 16),
                Layer::conv("b", 8, 8, 99, 16), // wrong cin
            ],
        };
        assert!(n.validate().is_err());
        n.layers[1].cin = 16;
        assert!(n.validate().is_ok());
    }

    #[test]
    fn validate_catches_bad_residual() {
        let n = Network {
            name: "x".into(),
            layers: vec![
                Layer::conv("a", 8, 8, 16, 16),
                Layer::add("r", 8, 8, 16, 1), // self-reference (not earlier)
            ],
        };
        assert!(n.validate().is_err());
    }
}
