//! DNN descriptors: layer shapes, the full MobileNetV2, the case-study
//! Bottleneck, and synthetic workload generators for the roofline sweeps.
//!
//! The Rust network builder is independent of the Python `netspec.py` (the
//! timing model must not require artifacts); `runtime::manifest` loads the
//! Python-serialized network for functional inference, and an integration
//! test asserts the two agree layer-by-layer.

pub mod bottleneck;
pub mod layer;
pub mod mobilenetv2;
pub mod workload;

pub use layer::{Layer, LayerKind, Network};
