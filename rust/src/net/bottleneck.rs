//! The case-study Bottleneck layer (paper §V-C, Fig. 8).
//!
//! Fig. 8 is not machine-readable; DESIGN.md §5 derives the unique
//! MobileNetV2-style configuration consistent with the paper's quoted
//! numbers: Cin = Cout = 128, expansion 6 (hidden = 768), 16×16 spatial,
//! stride 1, with residual — it reproduces the +25 %/+54 % (cjob 8/16)
//! crossbar-device increases and fits the 512 kB TCDM without tiling.

use super::layer::{Layer, Network};

pub const C: usize = 128;
pub const HID: usize = 768;
pub const HW: usize = 16;

/// The four-layer Bottleneck: pw-expand → dw 3×3 → pw-project → residual.
pub fn bottleneck() -> Network {
    let net = Network {
        name: "bottleneck".into(),
        layers: vec![
            Layer::conv("bneck_exp", HW, HW, C, HID).with_relu(),
            Layer::dw("bneck_dw", HW, HW, HID, 1),
            Layer::conv("bneck_proj", HW, HW, HID, C),
            // residual adds the block *input*; in this standalone network the
            // source index -... we model it as adding layer 0's input, which
            // `coordinator` special-cases via `residual_from == usize::MAX`.
            Layer {
                residual_from: Some(usize::MAX),
                ..Layer::add("bneck_add", HW, HW, C, 0)
            },
        ],
    };
    net
}

/// TCDM footprint of the whole block (activations + dw weights), bytes.
pub fn tcdm_footprint_bytes() -> usize {
    let input = HW * HW * C;
    let hidden = HW * HW * HID;
    let output = HW * HW * C;
    let dw_w = 9 * HID;
    // input + one hidden (expand out) + one hidden (dw out) + output
    input + 2 * hidden + output + dw_w
}

/// Crossbar devices for the depth-wise layer mapped on the IMA with
/// `c_job` channels per job (paper: N_xbar = K² · C · C_job).
pub fn dw_ima_devices(c_job: usize) -> usize {
    9 * HID * c_job
}

/// True weight count of the block (pw + dw).
pub fn weight_count() -> usize {
    2 * C * HID + 9 * HID
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits_512kb_tcdm() {
        // paper: "all the weights and activations fit the on-cluster TCDM
        // (512 kB), without requiring any activation data tiling"
        assert!(tcdm_footprint_bytes() <= 512 * 1024, "{}", tcdm_footprint_bytes());
        // and it is a tight fit (the paper chose it as the largest such)
        assert!(tcdm_footprint_bytes() > 350 * 1024);
    }

    #[test]
    fn device_increase_matches_paper() {
        let w = weight_count() as f64;
        let dw_w = (9 * HID) as f64;
        let inc8 = (dw_ima_devices(8) as f64 - dw_w) / w;
        let inc16 = (dw_ima_devices(16) as f64 - dw_w) / w;
        assert!((inc8 - 0.25).abs() < 0.04, "cjob8 +{:.0}%", inc8 * 100.0);
        assert!((inc16 - 0.54).abs() < 0.04, "cjob16 +{:.0}%", inc16 * 100.0);
    }

    #[test]
    fn dense_dw_mapping_is_infeasible() {
        // paper: mapping the dw densely would need ~23× the real weights
        let dense = 9 * HID * HID + 2 * C * HID;
        let ratio = dense as f64 / weight_count() as f64;
        assert!(ratio > 20.0, "{ratio}");
    }

    #[test]
    fn macs_split() {
        let net = bottleneck();
        let pw: u64 = net.layers[0].macs() + net.layers[2].macs();
        let dw: u64 = net.layers[1].macs();
        assert_eq!(pw, 2 * (HW * HW * C * HID) as u64);
        assert_eq!(dw, (HW * HW * 9 * HID) as u64);
        // pw dominates ~28:1 — the Amdahl setup of Fig. 10
        assert!(pw / dw > 25);
    }
}
