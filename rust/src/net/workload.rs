//! Synthetic workload generators (paper §V-B: "synthetic point-wise layers
//! with different utilization rates of the IMC array, from 5 % to 100 %").

use super::layer::{Layer, Network};

/// A square point-wise layer with `c` in/out channels over `pixels` output
/// pixels — crossbar utilization = c²/256².
pub fn synthetic_pointwise(c: usize, pixels: usize) -> Layer {
    let side = (pixels as f64).sqrt().ceil() as usize;
    Layer::conv(&format!("synth_pw_{c}"), side, side, c, c)
}

/// The Fig. 7 sweep: utilization rates 5 %..100 % of a 256×256 crossbar
/// (side = 256·sqrt(u)), serialized as equal-channel pw layers.
pub fn utilization_sweep(xbar_side: usize) -> Vec<(f64, Layer)> {
    let utils: [f64; 11] = [0.05, 0.10, 0.20, 0.30, 0.40, 0.50, 0.60, 0.70, 0.80, 0.90, 1.00];
    utils
        .iter()
        .map(|&u| {
            let c = ((xbar_side as f64) * u.sqrt()).round().max(1.0) as usize;
            (u, synthetic_pointwise(c, 1024))
        })
        .collect()
}

/// The §V-B peak-performance workload: a full-utilization 256-in/256-out
/// point-wise layer.
pub fn peak_workload(pixels: usize) -> Network {
    Network {
        name: "peak_pw_256".into(),
        layers: vec![synthetic_pointwise(256, pixels)],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_covers_5_to_100_percent() {
        let sweep = utilization_sweep(256);
        assert_eq!(sweep.len(), 11);
        let (u0, l0) = &sweep[0];
        assert!((*u0 - 0.05).abs() < 1e-9);
        // 256 * sqrt(0.05) ≈ 57 channels
        assert!((50..65).contains(&l0.cin), "{}", l0.cin);
        let (ul, ll) = sweep.last().unwrap();
        assert_eq!(*ul, 1.0);
        assert_eq!(ll.cin, 256);
    }

    #[test]
    fn utilization_is_c_squared() {
        for (u, l) in utilization_sweep(256) {
            let real = (l.cin * l.cout) as f64 / (256.0 * 256.0);
            assert!((real - u).abs() < 0.02, "u={u} real={real}");
        }
    }

    #[test]
    fn peak_workload_saturates_crossbar() {
        let n = peak_workload(1024);
        assert_eq!(n.layers[0].xbar_map_rows(), 256);
        assert_eq!(n.layers[0].cout, 256);
    }
}
