//! `imcc bench-timeline` — the long-horizon timeline performance harness.
//!
//! Serves a multi-tenant bottleneck fleet at several arrival horizons
//! (the largest is 10× the base — the long-horizon acceptance point),
//! once with watermark pruning and once with `--no-prune`, and reports
//! *both* measurements the perf trajectory needs:
//!
//! * **deterministic counters** (`ServeCounters`: event-loop steps,
//!   candidate validations, gap-search probe steps, live/pruned interval
//!   nodes) — reproducible under the fixed seed, so CI can gate on them
//!   without flaking;
//! * **wall clock** per simulation — the human-facing number, recorded in
//!   `BENCH_timeline.json` but never gated on.
//!
//! The harness hard-fails (the CLI exits non-zero) if the pruned and
//! unpruned dispatch tables diverge anywhere, or if, at the longest
//! horizon, pruning does not strictly reduce both the probe work and the
//! live-interval footprint — the two regressions this PR's tentpole
//! exists to prevent.

use std::time::Instant;

use crate::arch::PowerModel;
use crate::coordinator::PlanCache;
use crate::serve::{bottleneck_fleet, simulate_with_cache, ServeConfig, ServeReport};
use crate::util::json::{obj, Json};
use crate::util::table::{f, Table};

use super::Report;

/// Horizon multipliers over the base duration; the last entry is the
/// ≥ 10× long-horizon point the acceptance criteria pin.
pub const DEFAULT_MULTIPLIERS: &[u64] = &[1, 4, 10];

/// The dispatch table and every aggregate derived from it must be
/// bit-identical between the pruned and unpruned runs.
fn check_identical(pruned: &ServeReport, unpruned: &ServeReport) -> Result<(), String> {
    if pruned.render_table() != unpruned.render_table() {
        return Err("pruned and unpruned dispatch tables diverge".into());
    }
    if pruned.makespan_cycles != unpruned.makespan_cycles
        || pruned.busy_cycles != unpruned.busy_cycles
        || pruned.peak_backlog != unpruned.peak_backlog
    {
        return Err(format!(
            "pruned/unpruned aggregates diverge: makespan {} vs {}, busy {} vs {}, \
             peak backlog {} vs {}",
            pruned.makespan_cycles,
            unpruned.makespan_cycles,
            pruned.busy_cycles,
            unpruned.busy_cycles,
            pruned.peak_backlog,
            unpruned.peak_backlog
        ));
    }
    Ok(())
}

/// Run the sweep: `n_tenants` bottleneck tenants at `rate` req/s each,
/// horizons `base_duration_s × DEFAULT_MULTIPLIERS`, pruned vs unpruned.
pub fn generate(
    pm: &PowerModel,
    n_tenants: usize,
    rate: f64,
    base_duration_s: f64,
    seed: u64,
) -> Result<Report, String> {
    let models = bottleneck_fleet(n_tenants, rate);
    let n_arrays = 6 * n_tenants.max(1);
    let title = format!(
        "Timeline perf — {n_tenants} tenants, {rate} req/s each, {n_arrays} arrays, \
         seed {seed:#x}, pruned vs --no-prune"
    );
    let mut t = Table::new(
        &title,
        &[
            "horizon s",
            "mode",
            "wall ms",
            "makespan cy",
            "served",
            "steps",
            "probes",
            "live iv",
            "peak iv",
            "pruned iv",
        ],
    );
    let mut points = Vec::new();
    // one cache for the whole sweep: placement runs once, batch profiles
    // intern across every (duration, mode) point
    let mut cache = PlanCache::with_capacity(32);

    for &mult in DEFAULT_MULTIPLIERS {
        let duration_s = base_duration_s * mult as f64;
        let mut reports: Vec<(bool, ServeReport, f64)> = Vec::new();
        for prune in [true, false] {
            let scfg = ServeConfig {
                n_arrays,
                prune,
                seed,
                duration_s,
                ..ServeConfig::default()
            };
            let t0 = Instant::now();
            let rep = simulate_with_cache(&models, &scfg, pm, &mut cache)?;
            let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
            reports.push((prune, rep, wall_ms));
        }
        let (_, pruned_rep, _) = &reports[0];
        let (_, unpruned_rep, _) = &reports[1];
        check_identical(pruned_rep, unpruned_rep)
            .map_err(|e| format!("horizon {duration_s} s: {e}"))?;
        if mult == *DEFAULT_MULTIPLIERS.last().unwrap() {
            let (p, u) = (pruned_rep.counters, unpruned_rep.counters);
            if p.probes >= u.probes {
                return Err(format!(
                    "long horizon: pruned probe work {} is not below unpruned {}",
                    p.probes,
                    u.probes
                ));
            }
            if p.live_intervals >= u.live_intervals {
                return Err(format!(
                    "long horizon: pruned live intervals {} not below unpruned {}",
                    p.live_intervals,
                    u.live_intervals
                ));
            }
        }
        for (prune, rep, wall_ms) in &reports {
            let c = rep.counters;
            let mode = if *prune { "pruned" } else { "no-prune" };
            t.row([
                f(duration_s, 2),
                mode.into(),
                f(*wall_ms, 2),
                rep.makespan_cycles.to_string(),
                rep.total_served().to_string(),
                c.steps.to_string(),
                c.probes.to_string(),
                c.live_intervals.to_string(),
                c.peak_live_intervals.to_string(),
                c.pruned_intervals.to_string(),
            ]);
            points.push(obj([
                ("duration_s", duration_s.into()),
                ("prune", (*prune).into()),
                ("wall_ms", (*wall_ms).into()),
                ("makespan_cycles", (rep.makespan_cycles as f64).into()),
                ("served", (rep.total_served() as f64).into()),
                ("steps", (c.steps as f64).into()),
                ("validations", (c.validations as f64).into()),
                ("probes", (c.probes as f64).into()),
                ("live_intervals", (c.live_intervals as f64).into()),
                ("peak_live_intervals", (c.peak_live_intervals as f64).into()),
                ("pruned_intervals", (c.pruned_intervals as f64).into()),
                ("watermark", (c.watermark as f64).into()),
            ]));
        }
    }

    let mut text = t.render();
    text.push_str(
        "identical dispatch tables pruned vs unpruned at every horizon (hard-checked); \
         probe work and live-interval footprint strictly smaller pruned at the longest \
         horizon. Counters are deterministic under the seed; wall clock is informative \
         only.\n",
    );

    Ok(Report {
        title: "bench-timeline".into(),
        text,
        data: obj([
            ("bench", "timeline".into()),
            ("tenants", n_tenants.into()),
            ("rate_per_s", rate.into()),
            ("arrays", n_arrays.into()),
            ("seed", format!("{seed:#x}").into()),
            ("base_duration_s", base_duration_s.into()),
            ("points", Json::Arr(points)),
        ]),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::DEFAULT_SEED;

    #[test]
    fn harness_passes_and_emits_all_points() {
        let pm = PowerModel::paper();
        // short base horizon keeps the test quick; the 10× point still
        // exercises the long-horizon checks
        let rep = generate(&pm, 2, 200.0, 0.01, DEFAULT_SEED).unwrap();
        let points = rep.data.req("points").as_arr().unwrap();
        assert_eq!(points.len(), 2 * DEFAULT_MULTIPLIERS.len());
        for p in points {
            assert!(p.req("wall_ms").as_f64().unwrap() >= 0.0);
            assert!(p.req("steps").as_f64().unwrap() > 0.0);
            assert!(p.req("makespan_cycles").as_f64().unwrap() > 0.0);
        }
        // the JSON payload round-trips through the writer
        let text = rep.data.to_string_pretty();
        assert_eq!(Json::parse(&text).unwrap(), rep.data);
    }
}
