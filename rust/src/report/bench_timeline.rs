//! `imcc bench-timeline` — the long-horizon timeline performance harness.
//!
//! Serves a multi-tenant bottleneck fleet at several arrival horizons
//! (the largest is 10× the base — the long-horizon acceptance point)
//! and reports *both* measurements the perf trajectory needs:
//!
//! * **deterministic counters** (`ServeCounters`: event-loop steps,
//!   candidate validations, gap-search probe steps, live/pruned interval
//!   nodes, event-queue pushes/pops/stale revalidations) — reproducible
//!   under the fixed seed, so CI can gate on them without flaking;
//! * **wall clock** per simulation — the human-facing number, recorded in
//!   `BENCH_timeline.json` but never gated on.
//!
//! Three side-by-side comparisons run per sweep, every one gated on
//! counters and bit-identity, never on wall clock:
//!
//! * **pruned vs `--no-prune`** at every horizon — dispatch tables must
//!   be bit-identical, and at the longest horizon pruning must strictly
//!   cut both probe work and the live-interval footprint;
//! * **calendar vs heap event queue** at every horizon — the full serve
//!   JSON (counters included) must be bit-identical, since both queues
//!   realize the same total order; the per-mode structural step counts
//!   (`evq_steps` — the only mode-dependent tally, deliberately absent
//!   from serve JSON) are recorded here for the trajectory;
//! * **gap-skip fast paths on vs off** at the longest horizon —
//!   dispatch tables and makespan must be bit-identical, and the fast
//!   paths must strictly cut `probes`.
//!
//! The harness hard-fails (the CLI exits non-zero) on any divergence or
//! on either strict-cut gate, so `imcc bench-timeline` in CI is the
//! regression tripwire for all three mechanisms.

use std::time::Instant;

use crate::arch::PowerModel;
use crate::coordinator::PlanCache;
use crate::serve::{
    bottleneck_fleet, simulate_with_cache, EventQueueKind, ServeConfig, ServeReport,
};
use crate::util::json::{obj, Json};
use crate::util::table::{f, Table};

use super::Report;

/// Horizon multipliers over the base duration; the last entry is the
/// ≥ 10× long-horizon point the acceptance criteria pin.
pub const DEFAULT_MULTIPLIERS: &[u64] = &[1, 4, 10];

/// The dispatch table and every aggregate derived from it must be
/// bit-identical between two runs of one workload.
fn check_identical(a: &ServeReport, b: &ServeReport, what: &str) -> Result<(), String> {
    if a.render_table() != b.render_table() {
        return Err(format!("{what}: dispatch tables diverge"));
    }
    if a.makespan_cycles != b.makespan_cycles
        || a.busy_cycles != b.busy_cycles
        || a.peak_backlog != b.peak_backlog
    {
        return Err(format!(
            "{what}: aggregates diverge: makespan {} vs {}, busy {} vs {}, \
             peak backlog {} vs {}",
            a.makespan_cycles,
            b.makespan_cycles,
            a.busy_cycles,
            b.busy_cycles,
            a.peak_backlog,
            b.peak_backlog
        ));
    }
    Ok(())
}

/// Run the sweep: `n_tenants` bottleneck tenants at `rate` req/s each,
/// horizons `base_duration_s × DEFAULT_MULTIPLIERS`; pruned vs unpruned,
/// calendar vs heap, and (at the longest horizon) gap-skip on vs off.
pub fn generate(
    pm: &PowerModel,
    n_tenants: usize,
    rate: f64,
    base_duration_s: f64,
    seed: u64,
) -> Result<Report, String> {
    let models = bottleneck_fleet(n_tenants, rate);
    let n_arrays = 6 * n_tenants.max(1);
    let title = format!(
        "Timeline perf — {n_tenants} tenants, {rate} req/s each, {n_arrays} arrays, \
         seed {seed:#x}; pruned vs --no-prune, calendar vs heap, gap-skip on/off"
    );
    let mut t = Table::new(
        &title,
        &[
            "horizon s",
            "mode",
            "wall ms",
            "makespan cy",
            "served",
            "steps",
            "probes",
            "live iv",
            "peak iv",
            "pruned iv",
            "evq push",
            "evq stale",
            "evq steps",
        ],
    );
    let mut points = Vec::new();
    let mut evq_points = Vec::new();
    let mut gap_skip_point = None;
    // one cache for the whole sweep: placement runs once, batch profiles
    // intern across every (duration, mode) point
    let mut cache = PlanCache::with_capacity(32);

    let run = |scfg: &ServeConfig, cache: &mut PlanCache| -> Result<(ServeReport, f64), String> {
        let t0 = Instant::now();
        let rep = simulate_with_cache(&models, scfg, pm, cache)?;
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        Ok((rep, wall_ms))
    };
    let emit_row = |t: &mut Table, duration_s: f64, mode: &str, rep: &ServeReport, wall: f64| {
        let c = rep.counters;
        t.row([
            f(duration_s, 2),
            mode.into(),
            f(wall, 2),
            rep.makespan_cycles.to_string(),
            rep.total_served().to_string(),
            c.steps.to_string(),
            c.probes.to_string(),
            c.live_intervals.to_string(),
            c.peak_live_intervals.to_string(),
            c.pruned_intervals.to_string(),
            c.evq_pushes.to_string(),
            c.evq_stale.to_string(),
            rep.evq_steps.to_string(),
        ]);
    };

    let last_mult = *DEFAULT_MULTIPLIERS.last().unwrap();
    for &mult in DEFAULT_MULTIPLIERS {
        let duration_s = base_duration_s * mult as f64;
        let base = ServeConfig { n_arrays, seed, duration_s, ..ServeConfig::default() };

        let (pruned_rep, pruned_wall) = run(&base, &mut cache)?;
        let (unpruned_rep, unpruned_wall) =
            run(&ServeConfig { prune: false, ..base.clone() }, &mut cache)?;
        check_identical(&pruned_rep, &unpruned_rep, "pruned vs unpruned")
            .map_err(|e| format!("horizon {duration_s} s: {e}"))?;

        // calendar vs heap: same order realized by a different structure,
        // so the *entire* serve JSON — counters included — must match
        let (heap_rep, heap_wall) =
            run(&ServeConfig { event_queue: EventQueueKind::Heap, ..base.clone() }, &mut cache)?;
        check_identical(&pruned_rep, &heap_rep, "calendar vs heap")
            .map_err(|e| format!("horizon {duration_s} s: {e}"))?;
        if pruned_rep.to_json() != heap_rep.to_json() {
            return Err(format!(
                "horizon {duration_s} s: serve JSON diverges between --event-queue \
                 calendar and heap"
            ));
        }

        if mult == last_mult {
            let (p, u) = (pruned_rep.counters, unpruned_rep.counters);
            if p.probes >= u.probes {
                return Err(format!(
                    "long horizon: pruned probe work {} is not below unpruned {}",
                    p.probes, u.probes
                ));
            }
            if p.live_intervals >= u.live_intervals {
                return Err(format!(
                    "long horizon: pruned live intervals {} not below unpruned {}",
                    p.live_intervals, u.live_intervals
                ));
            }

            // gap-skip off: identical dispatch, strictly more probe work
            let (slow_rep, slow_wall) =
                run(&ServeConfig { gap_skip: false, ..base.clone() }, &mut cache)?;
            check_identical(&pruned_rep, &slow_rep, "gap-skip on vs off")
                .map_err(|e| format!("horizon {duration_s} s: {e}"))?;
            if pruned_rep.counters.probes >= slow_rep.counters.probes {
                return Err(format!(
                    "long horizon: gap-skip probes {} not strictly below --no-gap-skip {}",
                    pruned_rep.counters.probes, slow_rep.counters.probes
                ));
            }
            emit_row(&mut t, duration_s, "no-gap-skip", &slow_rep, slow_wall);
            gap_skip_point = Some(obj([
                ("duration_s", duration_s.into()),
                ("makespan_cycles", (slow_rep.makespan_cycles as f64).into()),
                ("probes_on", (pruned_rep.counters.probes as f64).into()),
                ("probes_off", (slow_rep.counters.probes as f64).into()),
            ]));
        }

        for (mode, rep, wall) in
            [("pruned", &pruned_rep, pruned_wall), ("no-prune", &unpruned_rep, unpruned_wall)]
        {
            let c = rep.counters;
            emit_row(&mut t, duration_s, mode, rep, wall);
            points.push(obj([
                ("duration_s", duration_s.into()),
                ("prune", (mode == "pruned").into()),
                ("wall_ms", wall.into()),
                ("makespan_cycles", (rep.makespan_cycles as f64).into()),
                ("served", (rep.total_served() as f64).into()),
                ("steps", (c.steps as f64).into()),
                ("validations", (c.validations as f64).into()),
                ("probes", (c.probes as f64).into()),
                ("live_intervals", (c.live_intervals as f64).into()),
                ("peak_live_intervals", (c.peak_live_intervals as f64).into()),
                ("pruned_intervals", (c.pruned_intervals as f64).into()),
                ("watermark", (c.watermark as f64).into()),
                ("evq_pushes", (c.evq_pushes as f64).into()),
                ("evq_pops", (c.evq_pops as f64).into()),
                ("evq_stale", (c.evq_stale as f64).into()),
            ]));
        }
        emit_row(&mut t, duration_s, "heap", &heap_rep, heap_wall);
        let c = pruned_rep.counters;
        evq_points.push(obj([
            ("duration_s", duration_s.into()),
            // mode-independent traffic (hard-checked identical above)
            ("pushes", (c.evq_pushes as f64).into()),
            ("pops", (c.evq_pops as f64).into()),
            ("stale", (c.evq_stale as f64).into()),
            // per-mode structural work + informative wall clock
            ("calendar_steps", (pruned_rep.evq_steps as f64).into()),
            ("heap_steps", (heap_rep.evq_steps as f64).into()),
            ("calendar_wall_ms", pruned_wall.into()),
            ("heap_wall_ms", heap_wall.into()),
        ]));
    }

    let mut text = t.render();
    text.push_str(
        "hard-checked at every horizon: dispatch tables identical pruned vs unpruned, \
         and full serve JSON identical calendar vs heap event queue. At the longest \
         horizon pruning strictly cuts probe work and live intervals, and the gap-skip \
         fast paths strictly cut probes at identical dispatch. Counters are \
         deterministic under the seed; wall clock is informative only.\n",
    );

    Ok(Report {
        title: "bench-timeline".into(),
        text,
        data: obj([
            ("bench", "timeline".into()),
            ("tenants", n_tenants.into()),
            ("rate_per_s", rate.into()),
            ("arrays", n_arrays.into()),
            ("seed", format!("{seed:#x}").into()),
            ("base_duration_s", base_duration_s.into()),
            ("points", Json::Arr(points)),
            ("event_queue", Json::Arr(evq_points)),
            ("gap_skip", gap_skip_point.expect("the longest horizon always runs")),
        ]),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::DEFAULT_SEED;

    #[test]
    fn harness_passes_and_emits_all_points() {
        let pm = PowerModel::paper();
        // short base horizon keeps the test quick; the 10× point still
        // exercises the long-horizon checks
        let rep = generate(&pm, 2, 200.0, 0.01, DEFAULT_SEED).unwrap();
        let points = rep.data.req("points").as_arr().unwrap();
        assert_eq!(points.len(), 2 * DEFAULT_MULTIPLIERS.len());
        for p in points {
            assert!(p.req("wall_ms").as_f64().unwrap() >= 0.0);
            assert!(p.req("steps").as_f64().unwrap() > 0.0);
            assert!(p.req("makespan_cycles").as_f64().unwrap() > 0.0);
            assert!(p.req("evq_pushes").as_f64().unwrap() > 0.0);
            assert!(
                p.req("evq_pops").as_f64().unwrap() <= p.req("evq_pushes").as_f64().unwrap(),
                "every pop extracts something previously pushed"
            );
        }
        // one heap-vs-calendar record per horizon, with the
        // mode-independent traffic and both modes' structural steps
        let evq = rep.data.req("event_queue").as_arr().unwrap();
        assert_eq!(evq.len(), DEFAULT_MULTIPLIERS.len());
        for e in evq {
            assert!(e.req("pushes").as_f64().unwrap() > 0.0);
            assert!(e.req("calendar_steps").as_f64().unwrap() > 0.0);
            assert!(e.req("heap_steps").as_f64().unwrap() > 0.0);
        }
        // the gap-skip gate ran at the longest horizon and cut probes
        let gs = rep.data.req("gap_skip");
        assert!(
            gs.req("probes_on").as_f64().unwrap() < gs.req("probes_off").as_f64().unwrap(),
            "generate() must have hard-failed instead"
        );
        // the JSON payload round-trips through the writer
        let text = rep.data.to_string_pretty();
        assert_eq!(Json::parse(&text).unwrap(), rep.data);
    }
}
