//! Fig. 12: end-to-end MobileNetV2 on the scaled-up (34-crossbar) system.
//!
//! (a) per-layer latency/energy/efficiency; (b) the TILE&PACK mapping;
//! (c) latency+energy breakdown of the conv2d and Bottleneck layers.
//! Paper totals: 10.1 ms, 482 µJ, 99 inf/s.

use crate::arch::{PowerModel, SystemConfig};
use crate::coordinator::{run_network, Engine, RunReport, Strategy};
use crate::net::mobilenetv2::mobilenet_v2;
use crate::tilepack::{pack, tile_network, Packing};
use crate::util::json::{obj, Json};
use crate::util::table::{f, Table};
use crate::util::units;

use super::Report;

/// The §VI system: 34 crossbars (or whatever TILE&PACK needs).
pub fn e2e_config() -> (SystemConfig, Packing) {
    let net = mobilenet_v2(224);
    let tiles = tile_network(&net, 256);
    let packing = pack(&tiles, 256, false);
    let cfg = SystemConfig::scaled_up(packing.n_bins());
    (cfg, packing)
}

pub fn run(cfg: &SystemConfig, pm: &PowerModel) -> RunReport {
    run_network(&mobilenet_v2(224), Strategy::ImaDw, cfg, pm)
}

pub fn generate(pm: &PowerModel) -> Report {
    let (cfg, packing) = e2e_config();
    let rep = run(&cfg, pm);

    // ---- (a) per-layer table -------------------------------------------
    let mut t = Table::new(
        "Fig. 12a — MobileNetV2 end-to-end, per layer",
        &["layer", "engine", "latency", "energy", "GMAC/s/W"],
    );
    let mut layer_rows = Vec::new();
    for l in &rep.layers {
        let time_s = l.cycles as f64 * cfg.freq.cycle_ns() * 1e-9;
        let gmacs_w = if l.energy_j > 0.0 {
            l.macs as f64 / time_s / 1e9 / (l.energy_j / time_s)
        } else {
            0.0
        };
        t.row([
            l.name.clone(),
            format!("{:?}", l.engine),
            units::fmt_time(time_s),
            units::fmt_energy(l.energy_j),
            f(gmacs_w, 1),
        ]);
        layer_rows.push(obj([
            ("name", l.name.clone().into()),
            ("engine", format!("{:?}", l.engine).into()),
            ("latency_s", time_s.into()),
            ("energy_j", l.energy_j.into()),
            ("gmacs_per_w", gmacs_w.into()),
        ]));
    }
    let mut text = t.render();

    // ---- totals ---------------------------------------------------------
    text.push_str(&format!(
        "\nTOTAL: {} | {} | {:.0} inf/s  (paper: 10.1 ms, 482 µJ, 99 inf/s)\n",
        units::fmt_time(rep.time_s),
        units::fmt_energy(rep.energy_j),
        rep.inferences_per_s()
    ));

    // ---- (b) tile&pack --------------------------------------------------
    let utils = packing.utilizations();
    let full = utils.iter().filter(|u| **u > 0.99).count();
    text.push_str(&format!(
        "Fig. 12b — TILE&PACK: {} crossbars (paper: 34), {} at 100% utilization, last at {:.0}%\n",
        packing.n_bins(),
        full,
        utils.iter().cloned().fold(f64::INFINITY, f64::min) * 100.0
    ));

    // ---- (c) engine breakdown -------------------------------------------
    let bd = rep.engine_breakdown();
    let total_cy = rep.cycles.max(1);
    text.push_str("Fig. 12c — cycle breakdown: ");
    for (e, cy) in &bd {
        text.push_str(&format!("{:?} {:.1}%  ", e, 100.0 * *cy as f64 / total_cy as f64));
    }
    text.push('\n');

    // ---- (c) per-block latency+energy (conv2d + every Bottleneck) ---------
    let mut blocks: Vec<(String, u64, f64)> = Vec::new();
    for l in &rep.layers {
        let block = l
            .name
            .rsplit_once('_')
            .map(|(pre, _)| pre.to_string())
            .unwrap_or_else(|| l.name.clone());
        match blocks.last_mut() {
            Some((b, cy, e)) if *b == block => {
                *cy += l.cycles;
                *e += l.energy_j;
            }
            _ => blocks.push((block, l.cycles, l.energy_j)),
        }
    }
    let mut tb = Table::new(
        "Fig. 12c — latency/energy by block",
        &["block", "latency", "energy", "% time"],
    );
    for (b, cy, e) in &blocks {
        tb.row([
            b.clone(),
            units::fmt_time(*cy as f64 * cfg.freq.cycle_ns() * 1e-9),
            units::fmt_energy(*e),
            f(100.0 * *cy as f64 / total_cy as f64, 1),
        ]);
    }
    text.push_str(&tb.render());

    let ima_cy = bd.iter().find(|(e, _)| *e == Engine::Ima).unwrap().1;
    Report {
        title: "fig12_e2e".into(),
        text,
        data: obj([
            ("total_time_s", rep.time_s.into()),
            ("total_energy_j", rep.energy_j.into()),
            ("inf_per_s", rep.inferences_per_s().into()),
            ("n_crossbars", packing.n_bins().into()),
            ("min_bin_utilization", utils.iter().cloned().fold(f64::INFINITY, f64::min).into()),
            ("ima_cycle_share", (ima_cy as f64 / total_cy as f64).into()),
            ("layers", Json::Arr(layer_rows)),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e2e_totals_near_paper() {
        // paper §VI: 10.1 ms, 482 µJ — the headline end-to-end claim
        let pm = PowerModel::paper();
        let r = generate(&pm);
        let t = r.data.req("total_time_s").as_f64().unwrap();
        let e = r.data.req("total_energy_j").as_f64().unwrap();
        assert!((5e-3..20e-3).contains(&t), "{t} s (paper: 10.1 ms)");
        assert!((250e-6..900e-6).contains(&e), "{e} J (paper: 482 µJ)");
    }

    #[test]
    fn crossbar_count_near_34() {
        let pm = PowerModel::paper();
        let r = generate(&pm);
        let n = r.data.req("n_crossbars").as_usize().unwrap();
        assert!((33..=38).contains(&n), "{n}");
    }
}
