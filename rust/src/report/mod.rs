//! Figure/table generators — one module per paper exhibit, each printing the
//! same rows/series the paper reports and returning machine-readable JSON.
//!
//! | module         | exhibit |
//! |----------------|---------|
//! | `fig6_area`    | Fig. 6b  area breakdown |
//! | `fig7_roofline`| Fig. 7a-c IMA roofline |
//! | `fig9_bottleneck`| Fig. 9a-c Bottleneck perf/eff/area-eff |
//! | `fig10_breakdown`| Fig. 10 normalized perf + layer breakdown |
//! | `fig12_e2e`    | Fig. 12a/c end-to-end MobileNetV2 + Alg.1/Fig.12b |
//! | `table1`       | Table I SoA comparison |
//! | `fig13_models` | Fig. 13 four computing models |
//! | `scaleup`      | pool-size × batch sweep (the Fig. 12b/13 story, serving regime) |
//! | `serving`      | multi-model latency percentiles vs offered load, per policy; plus controlled-vs-uncontrolled shed/latency curves (admission + autoscale) |
//! | `bench_timeline` | long-horizon timeline perf: pruned vs unpruned counters + wall clock |

pub mod ablations;
pub mod bench_timeline;
pub mod fig10_breakdown;
pub mod fig12_e2e;
pub mod fig13_models;
pub mod fig6_area;
pub mod fig7_roofline;
pub mod fig9_bottleneck;
pub mod scaleup;
pub mod serving;
pub mod table1;

use crate::util::json::Json;

/// Every report renders text for the terminal and JSON for EXPERIMENTS.md.
pub struct Report {
    pub title: String,
    pub text: String,
    pub data: Json,
}

impl Report {
    pub fn print(&self) {
        println!("{}", self.text);
    }
}
