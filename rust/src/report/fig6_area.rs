//! Fig. 6b: area breakdown of the heterogeneous cluster.

use crate::arch::{AreaModel, SystemConfig};
use crate::util::json::{obj, Json};
use crate::util::table::{f, Table};

use super::Report;

pub fn generate(cfg: &SystemConfig) -> Report {
    let area = AreaModel::for_config(cfg);
    let mut t = Table::new(
        &format!(
            "Fig. 6b — area breakdown (GF 22FDX, {} crossbar{})",
            cfg.n_crossbars,
            if cfg.n_crossbars > 1 { "s" } else { "" }
        ),
        &["component", "mm^2", "%"],
    );
    let mut rows = Vec::new();
    for (name, mm2, pct) in area.breakdown() {
        t.row([name.to_string(), f(mm2, 3), f(pct, 1)]);
        rows.push(obj([
            ("component", name.into()),
            ("mm2", mm2.into()),
            ("pct", pct.into()),
        ]));
    }
    t.row(["TOTAL".into(), f(area.total(), 3), "100.0".into()]);
    Report {
        title: "fig6b_area".into(),
        text: t.render(),
        data: obj([
            ("total_mm2", area.total().into()),
            ("breakdown", Json::Arr(rows)),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_paper_config() {
        let r = generate(&SystemConfig::paper());
        assert!(r.text.contains("IMA subsystem"));
        assert!(r.text.contains("2.500"));
        assert!(r.data.req("total_mm2").as_f64().unwrap() > 2.4);
    }

    #[test]
    fn scaled_up_grows_ima_share() {
        let r = generate(&SystemConfig::scaled_up(34));
        let total = r.data.req("total_mm2").as_f64().unwrap();
        assert!((26.0..32.0).contains(&total), "{total}");
    }
}
