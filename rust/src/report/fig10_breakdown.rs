//! Fig. 10: normalized performance vs CORES (left: pure point-wise;
//! right: whole Bottleneck) with the per-layer execution breakdown that
//! visualizes Amdahl's effect moving between mappings.

use crate::arch::{PowerModel, SystemConfig};
use crate::coordinator::{run_network, Strategy};
use crate::net::bottleneck::bottleneck;
use crate::net::{Layer, Network};
use crate::util::json::{obj, Json};
use crate::util::table::{f, Table};

use super::Report;

/// A pure point-wise workload (the left panel).
fn pointwise_only() -> Network {
    let net = bottleneck();
    Network {
        name: "pointwise_only".into(),
        layers: vec![
            Layer { residual_from: None, ..net.layers[0].clone() },
            net.layers[2].clone(),
        ],
    }
}

pub fn generate(cfg: &SystemConfig, pm: &PowerModel) -> Report {
    let pw_net = pointwise_only();
    let full = bottleneck();

    // left panel: point-wise speedup IMA vs CORES
    let pw_cores = run_network(&pw_net, Strategy::Cores, cfg, pm);
    let pw_ima = run_network(&pw_net, Strategy::ImaDw, cfg, pm);
    let pw_speedup = pw_cores.cycles as f64 / pw_ima.cycles as f64;

    // right panel: per-layer breakdown under each mapping
    let mut t = Table::new(
        "Fig. 10 (right) — Bottleneck execution breakdown (cycles)",
        &["mapping", "pw_exp", "dw", "pw_proj", "residual", "total", "norm perf"],
    );
    let cores = run_network(&full, Strategy::Cores, cfg, pm);
    let mut rows = Vec::new();
    for s in Strategy::paper_lineup() {
        let r = run_network(&full, s, cfg, pm);
        let cy: Vec<u64> = r.layers.iter().map(|l| l.cycles).collect();
        let norm = cores.cycles as f64 / r.cycles as f64;
        t.row([
            s.label(),
            cy[0].to_string(),
            cy[1].to_string(),
            cy[2].to_string(),
            cy[3].to_string(),
            r.cycles.to_string(),
            f(norm, 2),
        ]);
        rows.push(obj([
            ("mapping", s.label().into()),
            ("pw_exp_cy", (cy[0] as i64).into()),
            ("dw_cy", (cy[1] as i64).into()),
            ("pw_proj_cy", (cy[2] as i64).into()),
            ("residual_cy", (cy[3] as i64).into()),
            ("norm_perf", norm.into()),
        ]));
    }
    let mut text = format!(
        "Fig. 10 (left) — point-wise only: IMA = {pw_speedup:.1}x CORES\n\n"
    );
    text.push_str(&t.render());
    Report {
        title: "fig10_breakdown".into(),
        text,
        data: obj([
            ("pointwise_speedup", pw_speedup.into()),
            ("breakdown", Json::Arr(rows)),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pointwise_speedup_is_large() {
        // left panel: the IMA shines on dense MVM layers (tens of ×)
        let cfg = SystemConfig::paper();
        let pm = PowerModel::paper();
        let r = generate(&cfg, &pm);
        let s = r.data.req("pointwise_speedup").as_f64().unwrap();
        assert!((10.0..60.0).contains(&s), "{s}");
    }

    #[test]
    fn dw_dominates_ima_only_rows() {
        let cfg = SystemConfig::paper();
        let pm = PowerModel::paper();
        let r = generate(&cfg, &pm);
        let rows = r.data.req("breakdown").as_arr().unwrap();
        let c16 = rows
            .iter()
            .find(|x| x.req("mapping").as_str() == Some("IMA_cjob16"))
            .unwrap();
        assert!(
            c16.req("dw_cy").as_i64().unwrap()
                > 3 * c16.req("pw_exp_cy").as_i64().unwrap()
        );
    }
}
