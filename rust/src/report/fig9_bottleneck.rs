//! Fig. 9: the Bottleneck case study — performance (GOPS), energy efficiency
//! (TOPS/W) and area-utilization efficiency (GOPS/mm²) of the five mappings.

use crate::arch::{PowerModel, SystemConfig};
use crate::coordinator::{run_network, RunReport, Strategy};
use crate::net::bottleneck::bottleneck;
use crate::util::json::{obj, Json};
use crate::util::table::{f, Table};

use super::Report;

pub fn run_all(cfg: &SystemConfig, pm: &PowerModel) -> Vec<RunReport> {
    let net = bottleneck();
    Strategy::paper_lineup()
        .into_iter()
        .map(|s| run_network(&net, s, cfg, pm))
        .collect()
}

pub fn generate(cfg: &SystemConfig, pm: &PowerModel) -> Report {
    let reports = run_all(cfg, pm);
    let cores_ref = &reports[0];

    let mut t = Table::new(
        "Fig. 9 — Bottleneck (16x16x128, exp 6) @500 MHz, 128-bit, pipelined",
        &[
            "mapping", "cycles", "time", "GOPS", "vs CORES", "TOPS/W", "vs CORES",
            "GOPS/mm^2", "vs CORES",
        ],
    );
    let mut rows = Vec::new();
    for r in &reports {
        let perf_x = cores_ref.cycles as f64 / r.cycles as f64;
        let eff_x = r.tops_per_w() / cores_ref.tops_per_w();
        let area_x = r.gops_per_mm2(cfg) / cores_ref.gops_per_mm2(cfg);
        t.row([
            r.strategy.label(),
            r.cycles.to_string(),
            crate::util::units::fmt_time(r.time_s),
            f(r.gops(), 1),
            format!("{perf_x:.2}x"),
            f(r.tops_per_w(), 3),
            format!("{eff_x:.2}x"),
            f(r.gops_per_mm2(cfg), 1),
            format!("{area_x:.2}x"),
        ]);
        rows.push(obj([
            ("mapping", r.strategy.label().into()),
            ("cycles", (r.cycles as i64).into()),
            ("gops", r.gops().into()),
            ("tops_per_w", r.tops_per_w().into()),
            ("gops_per_mm2", r.gops_per_mm2(cfg).into()),
            ("perf_vs_cores", perf_x.into()),
            ("eff_vs_cores", eff_x.into()),
            ("area_eff_vs_cores", area_x.into()),
        ]));
    }
    let mut text = t.render();
    text.push_str(
        "paper:   IMA_cjob8 1.23x | IMA_cjob16 2.27x | HYBRID 4.6x | IMA+DW 11.5x (perf)\n\
         paper:   HYBRID 3.4x | IMA+DW 9.2x (energy eff) | IMA+DW 10.2x (area eff)\n",
    );
    Report {
        title: "fig9_bottleneck".into(),
        text,
        data: Json::Arr(rows),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_mappings_reported() {
        let cfg = SystemConfig::paper();
        let pm = PowerModel::paper();
        let r = generate(&cfg, &pm);
        for label in ["CORES", "IMA_cjob8", "IMA_cjob16", "HYBRID", "IMA+DW"] {
            assert!(r.text.contains(label), "{label}");
        }
        assert_eq!(r.data.as_arr().unwrap().len(), 5);
    }
}
