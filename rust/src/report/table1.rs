//! Table I: comparison with the state of the art.

use crate::arch::{AreaModel, FreqPoint, PowerModel, SystemConfig};
use crate::baselines::{all_baselines, BaselineRow};
use crate::ima::ImaSubsystem;
use crate::util::json::{obj, Json};
use crate::util::table::{f, Table};

use super::fig12_e2e;
use super::Report;

/// "This work" row, fully measured by the simulator.
pub fn this_work(pm: &PowerModel) -> BaselineRow {
    let (cfg, packing) = fig12_e2e::e2e_config();
    let rep = fig12_e2e::run(&cfg, pm);
    let area = AreaModel::for_config(&cfg).total();

    // peak: 8b×4b MVMs on one crossbar, pipelined, 250 MHz (the §V-B point)
    let peak_cfg = SystemConfig::paper().with_freq(FreqPoint::LOW);
    let ima = ImaSubsystem::new(&peak_cfg, pm);
    let (_, peak_gops, _) = ima.roofline_point(256, 65536);
    // peak efficiency: analog + streaming power at that operating point
    let full_job = pm.ima_job_energy_j(&peak_cfg, 256, 256);
    let job_time = 140e-9; // steady-state pipelined job
    let digital_w = (pm.ima_digital_active_w + pm.tcdm_active_w * 0.9 + pm.infra_w)
        * peak_cfg.freq.power_factor();
    let peak_w = full_job / job_time + digital_w;
    let peak_eff = peak_gops * 1e9 / peak_w / 1e12;

    let imc_label: &'static str =
        Box::leak(format!("{}x PCM", packing.n_bins()).into_boxed_str());
    BaselineRow {
        name: "This work",
        tech_nm: 22,
        area_mm2: area,
        cores: "8x RV32IMC Xpulp",
        analog_imc: imc_label,
        array_rows: Some(256),
        array_cols: Some(256),
        digital_acc: "Depth-wise",
        peak_tops: peak_gops / 1e3,
        peak_tops_precision: "8b-4b",
        peak_tops_per_w: peak_eff,
        mnv2_inf_per_s: Some(rep.inferences_per_s()),
        mnv2_energy_mj: Some(rep.energy_j * 1e3),
    }
}

fn fmt_opt(v: Option<f64>, prec: usize) -> String {
    v.map(|x| f(x, prec)).unwrap_or_else(|| "n/a".into())
}

pub fn generate(pm: &PowerModel) -> Report {
    let mut rows: Vec<BaselineRow> = all_baselines().iter().map(|b| b.row()).collect();
    rows.push(this_work(pm));

    let mut t = Table::new(
        "Table I — comparison with the state of the art",
        &[
            "", "tech", "area mm^2", "cores", "analog IMC", "rows", "cols",
            "digital acc", "peak TOPS", "peak TOPS/W", "MNv2 inf/s", "MNv2 mJ",
        ],
    );
    let mut data = Vec::new();
    for r in &rows {
        t.row([
            r.name.to_string(),
            format!("{}nm", r.tech_nm),
            f(r.area_mm2, 1),
            r.cores.to_string(),
            r.analog_imc.to_string(),
            r.array_rows.map(|v| v.to_string()).unwrap_or("-".into()),
            r.array_cols.map(|v| v.to_string()).unwrap_or("-".into()),
            r.digital_acc.to_string(),
            format!("{} ({})", f(r.peak_tops, 3), r.peak_tops_precision),
            f(r.peak_tops_per_w, 2),
            fmt_opt(r.mnv2_inf_per_s, 1),
            fmt_opt(r.mnv2_energy_mj, 3),
        ]);
        data.push(obj([
            ("name", r.name.into()),
            ("tech_nm", (r.tech_nm as i64).into()),
            ("area_mm2", r.area_mm2.into()),
            ("peak_tops", r.peak_tops.into()),
            ("peak_tops_per_w", r.peak_tops_per_w.into()),
            (
                "mnv2_inf_per_s",
                r.mnv2_inf_per_s.map(Json::from).unwrap_or(Json::Null),
            ),
            (
                "mnv2_energy_mj",
                r.mnv2_energy_mj.map(Json::from).unwrap_or(Json::Null),
            ),
        ]));
    }
    let mut text = t.render();
    text.push_str(
        "paper (This work): ~30 mm^2, 0.958 TOPS peak, 6.39 TOPS/W peak, 99 inf/s, 0.482 mJ\n",
    );
    Report {
        title: "table1".into(),
        text,
        data: Json::Arr(data),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn this_work_matches_paper_aggregates() {
        let pm = PowerModel::paper();
        let tw = this_work(&pm);
        assert!((0.90..1.01).contains(&tw.peak_tops), "{}", tw.peak_tops);
        assert!((4.5..8.0).contains(&tw.peak_tops_per_w), "{} (paper 6.39)", tw.peak_tops_per_w);
        // packing lands at 33 crossbars → ~26 mm² (paper: 34 → "~30 mm²")
        assert!((24.0..32.0).contains(&tw.area_mm2), "{}", tw.area_mm2);
        let inf = tw.mnv2_inf_per_s.unwrap();
        assert!((50.0..200.0).contains(&inf), "{inf} (paper 99)");
    }

    #[test]
    fn latency_gaps_vs_baselines_hold() {
        // paper: 10× vs Vega, two orders of magnitude vs [6]
        let pm = PowerModel::paper();
        let r = generate(&pm);
        let rows = r.data.as_arr().unwrap();
        let get = |name: &str| {
            rows.iter()
                .find(|x| x.req("name").as_str() == Some(name))
                .unwrap()
                .req("mnv2_inf_per_s")
                .as_f64()
        };
        let this = get("This work").unwrap();
        let vega = get("Vega [9]").unwrap();
        let jia = get("Jia [6] (IMA+MCU)").unwrap();
        assert!(this / vega > 5.0, "vs Vega {:.1}x (paper 10x)", this / vega);
        assert!(this / jia > 50.0, "vs Jia {:.0}x (paper ~430x)", this / jia);
    }
}
