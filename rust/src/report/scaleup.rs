//! Scale-up sweep: MobileNetV2 across pool sizes and batch depths — the
//! Fig. 12b/13 story extended to the serving regime. For each array count
//! the sweep reports whether the weights are resident (one pass) or staged
//! (reprogramming on the request path), per-array occupancy, and batched
//! throughput; the batch column shows what request pipelining buys once the
//! weights are pinned on-chip.

use crate::arch::{PowerModel, SystemConfig};
use crate::coordinator::{run_batched, BatchConfig, PlanCache, Strategy};
use crate::ima::ImaArrayPool;
use crate::net::mobilenetv2::mobilenet_v2;
use crate::util::json::{obj, Json};
use crate::util::table::{f, Table};

use super::Report;

pub const DEFAULT_ARRAYS: &[usize] = &[8, 16, 40, 64];
pub const DEFAULT_BATCHES: &[usize] = &[1, 2, 4, 8];

/// One (arrays, batch) sweep point, as the CLI runs it.
pub fn run_point(
    pm: &PowerModel,
    arrays: usize,
    batch: usize,
    pipeline: bool,
    stream_weights: bool,
) -> Result<crate::coordinator::BatchReport, String> {
    let net = mobilenet_v2(224);
    let cfg = SystemConfig::scaled_up(arrays);
    let mut cache = PlanCache::new();
    let plan = cache.get_or_place(&net, cfg.xbar_rows, arrays, false)?;
    Ok(run_batched(
        &net,
        Strategy::ImaDw,
        &cfg,
        pm,
        &plan,
        BatchConfig {
            batch,
            pipeline,
            stream_weights,
            ..BatchConfig::default()
        },
    ))
}

pub fn generate(pm: &PowerModel) -> Report {
    generate_sweep(pm, DEFAULT_ARRAYS, DEFAULT_BATCHES, true, false)
}

pub fn generate_sweep(
    pm: &PowerModel,
    arrays_list: &[usize],
    batches: &[usize],
    pipeline: bool,
    stream_weights: bool,
) -> Report {
    let net = mobilenet_v2(224);
    let mut cache = PlanCache::new();

    let mode = match (pipeline, stream_weights) {
        (true, true) => "pipelined, streamed",
        (true, false) => "pipelined",
        (false, true) => "strict serving, streamed",
        (false, false) => "strict serving",
    };
    let title = format!("Scale-up — MobileNetV2 across pool sizes and batch depths ({mode})");
    let mut t = Table::new(
        &title,
        &[
            "arrays", "passes", "occupancy", "batch", "inf/s", "speedup", "bottleneck",
        ],
    );
    let mut points = Vec::new();

    for &arrays in arrays_list {
        let cfg = SystemConfig::scaled_up(arrays);
        let pool = ImaArrayPool::new(&cfg, pm);
        let plan = match cache.get_or_place(&net, cfg.xbar_rows, arrays, false) {
            Ok(p) => p,
            Err(e) => {
                t.row([
                    arrays.to_string(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    e,
                ]);
                continue;
            }
        };
        let occ: f64 = plan
            .passes
            .iter()
            .map(|p| pool.pool_occupancy(p))
            .fold(0.0, f64::max);
        for &batch in batches {
            let rep = run_batched(
                &net,
                Strategy::ImaDw,
                &cfg,
                pm,
                &plan,
                BatchConfig {
                    batch,
                    pipeline,
                    stream_weights,
                    ..BatchConfig::default()
                },
            );
            t.row([
                arrays.to_string(),
                rep.n_passes.to_string(),
                format!("{:.0}%", occ * 100.0),
                batch.to_string(),
                f(rep.inferences_per_s(), 1),
                format!("{:.2}x", rep.speedup_vs_sequential()),
                rep.bottleneck_layer.clone(),
            ]);
            points.push(obj([
                ("arrays", arrays.into()),
                ("passes", rep.n_passes.into()),
                ("occupancy", occ.into()),
                ("batch", batch.into()),
                ("stream_weights", stream_weights.into()),
                ("inf_per_s", rep.inferences_per_s().into()),
                ("speedup_vs_sequential", rep.speedup_vs_sequential().into()),
                ("reprogram_cycles", (rep.reprogram_cycles as f64).into()),
            ]));
        }
    }

    let mut text = t.render();
    text.push_str(
        "resident pools (passes = 1) serve allocation-free from the plan cache; \
         staged pools pay PCM reprogramming per pass — the §VI argument for \
         holding all weights on-chip, measured.\n",
    );

    Report {
        title: "scaleup".into(),
        text,
        data: Json::Arr(points),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batching_improves_resident_throughput() {
        let pm = PowerModel::paper();
        let b1 = run_point(&pm, 40, 1, true, false).unwrap();
        let b4 = run_point(&pm, 40, 4, true, false).unwrap();
        assert_eq!(b1.n_passes, 1);
        assert!(
            b4.inferences_per_s() > b1.inferences_per_s(),
            "{} vs {}",
            b4.inferences_per_s(),
            b1.inferences_per_s()
        );
    }

    #[test]
    fn staged_8_array_pool_completes_and_amortizes() {
        let pm = PowerModel::paper();
        let b1 = run_point(&pm, 8, 1, true, false).unwrap();
        let b4 = run_point(&pm, 8, 4, true, false).unwrap();
        assert!(b1.n_passes > 1);
        assert!(b1.reprogram_cycles > 0);
        // batch-major serving amortizes reprogramming across the batch
        assert!(b4.inferences_per_s() > b1.inferences_per_s());
        // and staged serving is far slower than resident serving (the
        // reprogramming tax is ~4x the inference itself at batch 1)
        let resident = run_point(&pm, 40, 1, true, false).unwrap();
        assert!(resident.inferences_per_s() > 3.0 * b1.inferences_per_s());
    }

    #[test]
    fn streamed_point_beats_blocking_staged() {
        let pm = PowerModel::paper();
        let block = run_point(&pm, 8, 4, true, false).unwrap();
        let stream = run_point(&pm, 8, 4, true, true).unwrap();
        assert!(stream.inferences_per_s() > block.inferences_per_s());
        // the win is pure overlap: programming work is unchanged
        assert_eq!(stream.reprogram_cycles, block.reprogram_cycles);
    }

    #[test]
    fn sweep_generates() {
        let pm = PowerModel::paper();
        let r = generate_sweep(&pm, &[8, 40], &[1, 4], true, false);
        let pts = r.data.as_arr().unwrap();
        assert_eq!(pts.len(), 4);
        // 40 arrays hold all of MNv2's conv weights: resident, one pass
        let resident: Vec<_> = pts
            .iter()
            .filter(|p| p.req("arrays").as_usize().unwrap() == 40)
            .collect();
        assert!(resident.iter().all(|p| p.req("passes").as_usize().unwrap() == 1));
    }
}
