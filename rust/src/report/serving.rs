//! Serving sweep: latency percentiles vs offered load, per arbitration
//! policy — the system-level "does the array speedup survive real
//! traffic?" table.
//!
//! Two models share one pool (MobileNetV2 + the Bottleneck case study,
//! both weights-resident) under seeded Poisson arrivals. Each row is one
//! (policy, offered rate, model) point: the latency a user actually sees
//! (p50/p95/p99, queueing included), pool utilization, and drops. The
//! sweep makes the serving story quantitative: percentiles stay flat while
//! the pool has headroom, then the heavy model's tail explodes first as
//! load crosses saturation — and the policies split exactly where the
//! paper's §VI argument predicts (SJF keeps the small model fast by
//! starving the big one; WRR shares; FIFO lets the heavy model drag both).

use crate::arch::{PowerModel, SystemConfig};
use crate::coordinator::PlanCache;
use crate::net::mobilenetv2::mobilenet_v2;
use crate::serve::{
    dispatch_label, mnv2_bottleneck_pair, simulate_fleet, simulate_traced, simulate_with_cache,
    FaultPlan, FleetConfig, ModelTraffic, Policy, RouterPolicy, ServeConfig, TraceRecorder,
    TrafficModel, DEFAULT_SEED,
};
use crate::util::json::{obj, Json};
use crate::util::table::{f, Table};

use super::Report;

pub const DEFAULT_RATES: &[f64] = &[25.0, 50.0, 100.0, 200.0];
pub const DEFAULT_POLICIES: &[Policy] = &[Policy::Fifo, Policy::Wrr, Policy::Sjf];

pub fn generate(pm: &PowerModel) -> Report {
    generate_sweep(pm, 64, DEFAULT_RATES, DEFAULT_POLICIES, 0.25, DEFAULT_SEED, true, true)
}

#[allow(clippy::too_many_arguments)]
pub fn generate_sweep(
    pm: &PowerModel,
    n_arrays: usize,
    rates: &[f64],
    policies: &[Policy],
    duration_s: f64,
    seed: u64,
    overlap: bool,
    backfill: bool,
) -> Report {
    let dispatch = dispatch_label(overlap, backfill);
    let title = format!(
        "Serving — latency percentiles vs offered load ({n_arrays} arrays, \
         {duration_s} s Poisson horizon/model, seed {seed:#x}, {dispatch} dispatch)"
    );
    let mut t = Table::new(
        &title,
        &[
            "policy", "rate/s", "model", "served", "p50 ms", "p95 ms", "p99 ms", "peak q",
            "util",
        ],
    );
    let mut points = Vec::new();
    // one cache across every sweep point: the (network, pool) keys repeat,
    // so TILE&PACK runs once per model, not once per (policy, rate)
    let mut cache = PlanCache::with_capacity(32);

    for &policy in policies {
        for &rate in rates {
            let scfg = ServeConfig {
                n_arrays,
                policy,
                overlap,
                backfill,
                seed,
                duration_s,
                ..ServeConfig::default()
            };
            // sweeps never export a trace, but they deliberately run the
            // explicit no-op recorder path — the same call `serve --trace`
            // takes, minus the buffer
            let rep = match simulate_traced(
                &mnv2_bottleneck_pair(rate),
                &scfg,
                pm,
                &mut cache,
                &mut TraceRecorder::Off,
            ) {
                Ok(r) => r,
                Err(e) => {
                    t.row([
                        policy.label().into(),
                        f(rate, 0),
                        e,
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                    ]);
                    continue;
                }
            };
            let util = rep.utilization();
            for s in &rep.tenants {
                let (p50, p95, p99) = s.latency.percentiles();
                let ms = |cy: u64| cy as f64 * rep.cycle_ns * 1e-6;
                t.row([
                    policy.label().into(),
                    f(rate, 0),
                    s.name.to_string(),
                    s.served.to_string(),
                    f(ms(p50), 2),
                    f(ms(p95), 2),
                    f(ms(p99), 2),
                    s.peak_queue.to_string(),
                    format!("{:.0}%", util * 100.0),
                ]);
                points.push(obj([
                    ("policy", policy.label().into()),
                    ("rate_per_s", rate.into()),
                    ("model", s.name.as_ref().into()),
                    ("arrivals", (s.arrivals as f64).into()),
                    ("served", (s.served as f64).into()),
                    ("dropped", (s.dropped as f64).into()),
                    ("p50_ms", ms(p50).into()),
                    ("p95_ms", ms(p95).into()),
                    ("p99_ms", ms(p99).into()),
                    // where the p95 tail lives, phase by phase (queue wait,
                    // resource stall, service) — the decomposition's sweep
                    // view
                    ("p95_queue_ms", ms(s.breakdown.queue_wait.quantile(0.95)).into()),
                    ("p95_stall_ms", ms(s.breakdown.resource_stall.quantile(0.95)).into()),
                    ("p95_service_ms", ms(s.breakdown.service.quantile(0.95)).into()),
                    ("peak_queue", s.peak_queue.into()),
                    ("utilization", util.into()),
                    ("overlap", rep.overlap.into()),
                    ("backfill", rep.backfill.into()),
                    ("inf_per_s", rep.inferences_per_s().into()),
                ]));
            }
        }
    }

    let mut text = t.render();
    text.push_str(
        "open-loop Poisson per model, both models weights-resident in one pool, \
         per-resource interval dispatch (disjoint slices run concurrently, \
         backfilled batches slot into committed idle gaps); \
         latencies include queueing (p50/p95/p99 from the log histogram). \
         Past saturation FIFO couples the models, WRR shares the pool, SJF \
         shields the light model by starving the heavy one.\n",
    );

    Report {
        title: "serving".into(),
        text,
        data: Json::Arr(points),
    }
}

/// Controlled-vs-uncontrolled shed/latency curves: an overloaded staged
/// MobileNetV2 tenant under Poisson and MMPP-2 arrivals, once
/// uncontrolled (lazy deadline drops only) and once with the SLO
/// controller (`--slo-p95` admission + `--autoscale` pool resizing). The
/// scenario is deliberately tight — the pool holds back half its arrays
/// as headroom, so the tenant starts staged and the controller can buy
/// real capacity by growing it — and self-calibrating: the deadline and
/// the p95 budget derive from an uncontrolled no-deadline probe of the
/// same traffic, so the comparison lands in the interesting regime on
/// any cost model.
pub fn generate_controlled(pm: &PowerModel) -> Report {
    generate_controlled_sweep(pm, 16, 8, 4_000.0, 0.1, DEFAULT_SEED)
}

pub fn generate_controlled_sweep(
    pm: &PowerModel,
    n_arrays: usize,
    headroom: usize,
    rate_per_s: f64,
    duration_s: f64,
    seed: u64,
) -> Report {
    let title = format!(
        "Serving under control — shed + latency, admission/autoscale vs uncontrolled \
         ({n_arrays} arrays, {headroom} headroom, {rate_per_s}/s per tenant, \
         {duration_s} s horizon, seed {seed:#x})"
    );
    let mut t = Table::new(
        &title,
        &[
            "traffic", "controller", "model", "arrivals", "served", "dropped", "rejected",
            "shed %", "p95 ms", "scale ev",
        ],
    );
    let mut points = Vec::new();
    let mut cache = PlanCache::with_capacity(64);

    let traffics: [(&str, TrafficModel); 2] = [
        ("poisson", TrafficModel::Poisson { rate_per_s }),
        (
            "mmpp2",
            TrafficModel::Bursty {
                rate_per_s,
                burst: 4.0,
                dwell_s: 0.01,
            },
        ),
    ];
    for (tname, traffic) in traffics {
        let models = vec![ModelTraffic {
            net: mobilenet_v2(224),
            traffic,
            weight: 1,
        }];
        let base = ServeConfig {
            n_arrays,
            headroom,
            seed,
            duration_s,
            ..ServeConfig::default()
        };
        // probe: uncontrolled, no deadline — its p95 anchors the budget
        // and the deadline so both arms shed in the interesting regime
        let probe = match simulate_with_cache(&models, &base, pm, &mut cache) {
            Ok(r) => r,
            Err(e) => {
                t.row([
                    tname.into(),
                    e,
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]);
                continue;
            }
        };
        let p95_probe = probe
            .tenants
            .iter()
            .map(|s| s.latency.quantile(0.95))
            .max()
            .unwrap_or(0)
            .max(2);
        let deadline_cy = p95_probe / 2;
        let slo_p95_cy = p95_probe; // generous: staged tenants stay admittable
        for (label, controlled) in [("off", false), ("on", true)] {
            let scfg = ServeConfig {
                deadline_cy,
                slo_p95_cy: if controlled { slo_p95_cy } else { 0 },
                autoscale: controlled,
                ..base.clone()
            };
            let rep = match simulate_with_cache(&models, &scfg, pm, &mut cache) {
                Ok(r) => r,
                Err(e) => {
                    t.row([
                        tname.into(),
                        label.into(),
                        e,
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                    ]);
                    continue;
                }
            };
            for s in &rep.tenants {
                let (_, p95, _) = s.latency.percentiles();
                let shed = s.dropped + s.rejected;
                let shed_pct = if s.arrivals == 0 {
                    0.0
                } else {
                    shed as f64 / s.arrivals as f64 * 100.0
                };
                t.row([
                    tname.into(),
                    label.into(),
                    s.name.to_string(),
                    s.arrivals.to_string(),
                    s.served.to_string(),
                    s.dropped.to_string(),
                    s.rejected.to_string(),
                    f(shed_pct, 1),
                    f(p95 as f64 * rep.cycle_ns * 1e-6, 3),
                    rep.scale_events.len().to_string(),
                ]);
                points.push(obj([
                    ("traffic", tname.into()),
                    ("controlled", controlled.into()),
                    ("model", s.name.as_ref().into()),
                    ("arrivals", (s.arrivals as f64).into()),
                    ("served", (s.served as f64).into()),
                    ("dropped", (s.dropped as f64).into()),
                    ("rejected", (s.rejected as f64).into()),
                    ("shed_rate", (shed_pct / 100.0).into()),
                    ("p95_ms", (p95 as f64 * rep.cycle_ns * 1e-6).into()),
                    // the controller's footprint in the decomposition: how
                    // long requests stalled behind its migrations (0 for
                    // the uncontrolled arm by construction)
                    (
                        "p95_migration_ms",
                        (s.breakdown.migration_stall.quantile(0.95) as f64 * rep.cycle_ns * 1e-6)
                            .into(),
                    ),
                    ("slo_p95_cy", (rep.slo_p95_cy as f64).into()),
                    ("deadline_cy", (deadline_cy as f64).into()),
                    ("scale_events", rep.scale_events.len().into()),
                ]));
            }
        }
    }

    let mut text = t.render();
    text.push_str(
        "uncontrolled = lazy deadline drops only; controlled = front-door \
         admission against the p95 budget plus online pool resizing (the \
         staged tenant grows into the headroom once its backlog sustains). \
         Deadline and budget are calibrated from an uncontrolled \
         no-deadline probe of the same traffic (deadline = p95/2, \
         budget = p95).\n",
    );

    Report {
        title: "serving-controlled".into(),
        text,
        data: Json::Arr(points),
    }
}

/// Router comparison on a heterogeneous fleet: one hot MobileNetV2
/// tenant across four nodes of unequal pool size, once per routing
/// policy. The scenario is deliberately skewed — the consistent-hash
/// ring happens to pin the tenant to the smallest node, where it cannot
/// sit resident and every request pays staged PCM reprogramming — so
/// the table shows exactly what load-aware routing buys: least-loaded
/// places by capacity (and can migrate mid-run), replica water-fills
/// the stream across every node by projected finish time.
pub fn generate_fleet(pm: &PowerModel) -> Report {
    generate_fleet_sweep(pm, 4, &[64, 32, 12, 64], 600.0, 0.03, DEFAULT_SEED)
}

pub fn generate_fleet_sweep(
    pm: &PowerModel,
    nodes: usize,
    node_arrays: &[usize],
    hot_rate: f64,
    duration_s: f64,
    seed: u64,
) -> Report {
    let title = format!(
        "Fleet routing — hot MobileNetV2 ({hot_rate}/s) over {nodes} nodes \
         {node_arrays:?}, {duration_s} s horizon, seed {seed:#x}"
    );
    let mut t = Table::new(
        &title,
        &[
            "router", "arrivals", "served", "dropped", "rejected", "p50 ms", "p95 ms",
            "p99 ms", "inf/s", "migr",
        ],
    );
    let mut points = Vec::new();

    let models = vec![ModelTraffic {
        net: mobilenet_v2(224),
        traffic: TrafficModel::Poisson {
            rate_per_s: hot_rate,
        },
        weight: 1,
    }];
    let scfg = ServeConfig {
        // the fallback size when --node-arrays is empty; overridden per
        // node here, but it still seeds the wall-clock conversion
        n_arrays: node_arrays.iter().copied().max().unwrap_or(64),
        seed,
        duration_s,
        ..ServeConfig::default()
    };

    for router in [
        RouterPolicy::Hash,
        RouterPolicy::LeastLoaded,
        RouterPolicy::Replica,
    ] {
        let mut fcfg = FleetConfig::new(nodes, router);
        fcfg.node_arrays = node_arrays.to_vec();
        let rep = match simulate_fleet(&models, &scfg, &fcfg, pm) {
            Ok(r) => r,
            Err(e) => {
                t.row([
                    router.label().into(),
                    e,
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]);
                continue;
            }
        };
        let merged = rep.merged_latency();
        let (p50, p95, p99) = merged.percentiles();
        let ms = |cy: u64| cy as f64 * rep.cycle_ns * 1e-6;
        t.row([
            router.label().into(),
            rep.total_arrivals().to_string(),
            rep.total_served().to_string(),
            rep.total_dropped().to_string(),
            rep.total_rejected().to_string(),
            f(ms(p50), 2),
            f(ms(p95), 2),
            f(ms(p99), 2),
            f(rep.inferences_per_s(), 1),
            rep.migrations.len().to_string(),
        ]);
        points.push(obj([
            ("router", router.label().into()),
            ("nodes", nodes.into()),
            ("arrivals", (rep.total_arrivals() as f64).into()),
            ("served", (rep.total_served() as f64).into()),
            ("dropped", (rep.total_dropped() as f64).into()),
            ("rejected", (rep.total_rejected() as f64).into()),
            ("p50_ms", ms(p50).into()),
            ("p95_ms", ms(p95).into()),
            ("p99_ms", ms(p99).into()),
            ("inf_per_s", rep.inferences_per_s().into()),
            ("migrations", rep.migrations.len().into()),
            (
                "node_arrays",
                Json::Arr(rep.nodes.iter().map(|n| n.arrays.into()).collect()),
            ),
            (
                "node_served",
                Json::Arr(
                    rep.nodes
                        .iter()
                        .map(|n| (n.report.total_served() as f64).into())
                        .collect(),
                ),
            ),
        ]));
    }

    let mut text = t.render();
    text.push_str(
        "one globally generated arrival set, three routings of it: hash pins \
         tenants by consistent ring position (here the hot tenant lands on \
         the smallest node, staged), least-loaded assigns by projected load \
         over capacity and migrates the tenant off a sustained-hot node \
         (PCM reprogramming priced on the destination), replica spreads the \
         stream across all nodes by earliest projected finish.\n",
    );

    Report {
        title: "serving-fleet".into(),
        text,
        data: Json::Arr(points),
    }
}

/// Availability vs MTBF: the same heterogeneous fleet under seeded
/// crash/recover plans of decreasing mean-time-between-failures, next
/// to its healthy baseline. Each row is one full fleet run; the sweep
/// quantifies what the self-healing layer costs — availability falls
/// with MTBF while the extended conservation law
/// (`served + dropped + rejected + lost_in_crash == offered`) pins
/// every request, and the degraded p95 sits next to the healthy one.
pub fn generate_faults(pm: &PowerModel) -> Report {
    generate_faults_sweep(pm, 3, &[32, 24, 16], 300.0, 0.03, DEFAULT_SEED, &[1.0, 0.5, 0.25])
}

#[allow(clippy::too_many_arguments)]
pub fn generate_faults_sweep(
    pm: &PowerModel,
    nodes: usize,
    node_arrays: &[usize],
    hot_rate: f64,
    duration_s: f64,
    seed: u64,
    mtbf_fracs: &[f64],
) -> Report {
    let title = format!(
        "Fleet under faults — availability vs MTBF (MobileNetV2 {hot_rate}/s over \
         {nodes} nodes {node_arrays:?}, {duration_s} s horizon, seed {seed:#x})"
    );
    let mut t = Table::new(
        &title,
        &[
            "mtbf/horizon", "events", "failovers", "retried", "lost", "served", "avail",
            "p95 ms",
        ],
    );
    let mut points = Vec::new();

    let models = vec![ModelTraffic {
        net: mobilenet_v2(224),
        traffic: TrafficModel::Poisson {
            rate_per_s: hot_rate,
        },
        weight: 1,
    }];
    let scfg = ServeConfig {
        n_arrays: node_arrays.iter().copied().max().unwrap_or(64),
        seed,
        duration_s,
        ..ServeConfig::default()
    };
    let cycle_ns = SystemConfig::scaled_up(scfg.n_arrays).freq.cycle_ns();
    let horizon_cy = (duration_s * 1e9 / cycle_ns) as u64;

    // the healthy baseline first (label ∞), then MTBF = frac × horizon
    let mut arms: Vec<(String, FaultPlan)> = vec![("inf".to_string(), FaultPlan::none())];
    for &frac in mtbf_fracs {
        let mtbf_cy = ((horizon_cy as f64 * frac) as u64).max(1);
        arms.push((
            format!("{frac}"),
            FaultPlan::seeded(seed, nodes, horizon_cy, mtbf_cy),
        ));
    }
    let mut healthy_p95_ms = 0.0;
    for (label, plan) in arms {
        let mut fcfg = FleetConfig::new(nodes, RouterPolicy::Hash);
        fcfg.node_arrays = node_arrays.to_vec();
        fcfg.faults = plan;
        let rep = match simulate_fleet(&models, &scfg, &fcfg, pm) {
            Ok(r) => r,
            Err(e) => {
                t.row([
                    label,
                    e,
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]);
                continue;
            }
        };
        let merged = rep.merged_latency();
        let (_, p95, _) = merged.percentiles();
        let p95_ms = p95 as f64 * rep.cycle_ns * 1e-6;
        if label == "inf" {
            healthy_p95_ms = p95_ms;
        }
        // a drawn plan can come up empty at a long MTBF: that run IS the
        // healthy fleet and reports no chaos ledger
        let (events, failovers, retried, lost, avail) = match &rep.faults {
            Some(fo) => (
                fo.events.len(),
                fo.failovers.len(),
                fo.retried,
                fo.lost_in_crash,
                fo.availability(),
            ),
            None => (0, 0, 0, 0, 1.0),
        };
        t.row([
            label.clone(),
            events.to_string(),
            failovers.to_string(),
            retried.to_string(),
            lost.to_string(),
            rep.total_served().to_string(),
            f(avail, 4),
            f(p95_ms, 2),
        ]);
        points.push(obj([
            ("mtbf_over_horizon", label.as_str().into()),
            ("fault_events", events.into()),
            ("failovers", failovers.into()),
            ("retried", (retried as f64).into()),
            ("lost_in_crash", (lost as f64).into()),
            ("arrivals", (rep.total_arrivals() as f64).into()),
            ("served", (rep.total_served() as f64).into()),
            ("dropped", (rep.total_dropped() as f64).into()),
            ("rejected", (rep.total_rejected() as f64).into()),
            ("availability", avail.into()),
            ("p95_ms", p95_ms.into()),
            ("p95_healthy_ms", healthy_p95_ms.into()),
        ]));
    }

    let mut text = t.render();
    text.push_str(
        "seeded crash/recover plans (node 0 spared as the survivor anchor); \
         queued work fails over to ring survivors at the migration price and \
         parks for the home node when recovery is near, in-flight batches \
         are lost at the crash instant. Conservation extends to \
         served + dropped + rejected + lost == offered on every row.\n",
    );

    Report {
        title: "serving-faults".into(),
        text,
        data: Json::Arr(points),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_generates_all_points() {
        let pm = PowerModel::paper();
        let r = generate_sweep(
            &pm,
            64,
            &[50.0],
            &[Policy::Fifo, Policy::Sjf],
            0.05,
            0xAB,
            true,
            true,
        );
        let pts = r.data.as_arr().unwrap();
        // 2 policies × 1 rate × 2 models
        assert_eq!(pts.len(), 4);
        for p in pts {
            assert!(p.req("p99_ms").as_f64().unwrap() >= p.req("p50_ms").as_f64().unwrap());
            let u = p.req("utilization").as_f64().unwrap();
            assert!((0.0..=1.0).contains(&u), "{u}");
            // decomposition view: every phase tail present and sane
            for k in ["p95_queue_ms", "p95_stall_ms", "p95_service_ms"] {
                assert!(p.req(k).as_f64().unwrap() >= 0.0, "{k}");
            }
            if p.req("served").as_f64().unwrap() > 0.0 {
                assert!(
                    p.req("p95_service_ms").as_f64().unwrap() > 0.0,
                    "served requests spend real service time"
                );
            }
        }
    }

    #[test]
    fn controlled_sweep_conserves_and_labels_every_point() {
        let pm = PowerModel::paper();
        let r = generate_controlled_sweep(&pm, 16, 8, 3_000.0, 0.05, 0xAB);
        let pts = r.data.as_arr().unwrap();
        // 2 traffics × 2 arms × 1 tenant
        assert_eq!(pts.len(), 4);
        let mut uncontrolled = 0;
        for p in pts {
            let arrivals = p.req("arrivals").as_f64().unwrap();
            let accounted = p.req("served").as_f64().unwrap()
                + p.req("dropped").as_f64().unwrap()
                + p.req("rejected").as_f64().unwrap();
            assert_eq!(arrivals, accounted, "admission must conserve arrivals");
            let shed = p.req("shed_rate").as_f64().unwrap();
            assert!((0.0..=1.0).contains(&shed), "{shed}");
            assert!(p.req("p95_migration_ms").as_f64().unwrap() >= 0.0);
            if *p.req("controlled") == Json::Bool(false) {
                uncontrolled += 1;
                // the uncontrolled arm never refuses at the front door and
                // never migrates — any nonzero here means the off switch leaks
                assert_eq!(p.req("rejected").as_f64().unwrap(), 0.0);
                assert_eq!(p.req("scale_events").as_f64().unwrap(), 0.0);
                assert_eq!(p.req("slo_p95_cy").as_f64().unwrap(), 0.0);
                assert_eq!(
                    p.req("p95_migration_ms").as_f64().unwrap(),
                    0.0,
                    "no migrations, no migration stall"
                );
            } else {
                assert!(p.req("slo_p95_cy").as_f64().unwrap() > 0.0);
            }
        }
        assert_eq!(uncontrolled, 2, "both arms present for both traffics");
        assert!(r.text.contains("rejected"));
    }

    #[test]
    fn fleet_sweep_covers_every_router_and_conserves() {
        let pm = PowerModel::paper();
        let r = generate_fleet_sweep(&pm, 2, &[32, 16], 300.0, 0.02, 0xAB);
        let pts = r.data.as_arr().unwrap();
        assert_eq!(pts.len(), 3, "one point per router");
        for p in pts {
            let arrivals = p.req("arrivals").as_f64().unwrap();
            let accounted = p.req("served").as_f64().unwrap()
                + p.req("dropped").as_f64().unwrap()
                + p.req("rejected").as_f64().unwrap();
            assert_eq!(arrivals, accounted, "routing must conserve arrivals");
            assert!(p.req("p99_ms").as_f64().unwrap() >= p.req("p50_ms").as_f64().unwrap());
            let node_served = p.req("node_served").as_arr().unwrap();
            assert_eq!(node_served.len(), 2);
            let sum: f64 = node_served.iter().map(|v| v.as_f64().unwrap()).sum();
            assert_eq!(sum, p.req("served").as_f64().unwrap());
        }
        // all three policies route the same offered load
        let a0 = pts[0].req("arrivals").as_f64().unwrap();
        assert!(pts.iter().all(|p| p.req("arrivals").as_f64().unwrap() == a0));
    }

    #[test]
    fn fault_sweep_extends_conservation_and_prices_downtime() {
        let pm = PowerModel::paper();
        let r = generate_faults_sweep(&pm, 3, &[16, 12, 8], 200.0, 0.02, 0xAB, &[0.25]);
        let pts = r.data.as_arr().unwrap();
        assert_eq!(pts.len(), 2, "healthy baseline + one MTBF arm");
        let offered = pts[0].req("arrivals").as_f64().unwrap();
        assert!(offered > 0.0);
        for p in pts {
            // the extended law: every request served, shed, or lost
            let accounted = p.req("served").as_f64().unwrap()
                + p.req("dropped").as_f64().unwrap()
                + p.req("rejected").as_f64().unwrap()
                + p.req("lost_in_crash").as_f64().unwrap();
            let lost = p.req("lost_in_crash").as_f64().unwrap();
            assert_eq!(p.req("arrivals").as_f64().unwrap(), accounted - lost);
            assert_eq!(accounted, offered, "offered load is router-invariant");
            let avail = p.req("availability").as_f64().unwrap();
            assert!((0.0..=1.0).contains(&avail), "{avail}");
            if p.req("fault_events").as_f64().unwrap() > 0.0 {
                assert!(avail < 1.0, "a fired crash must cost availability");
            }
        }
        // the healthy baseline is clean
        assert_eq!(pts[0].req("fault_events").as_f64().unwrap(), 0.0);
        assert_eq!(pts[0].req("availability").as_f64().unwrap(), 1.0);
        assert_eq!(pts[0].req("lost_in_crash").as_f64().unwrap(), 0.0);
        // determinism: the sweep is a pure function of its arguments
        let again = generate_faults_sweep(&pm, 3, &[16, 12, 8], 200.0, 0.02, 0xAB, &[0.25]);
        assert_eq!(r.text, again.text);
    }

    #[test]
    fn overload_inflates_the_tail() {
        let pm = PowerModel::paper();
        let r = generate_sweep(&pm, 64, &[25.0, 800.0], &[Policy::Fifo], 0.05, 0xAB, true, true);
        let pts = r.data.as_arr().unwrap();
        let p99_of = |rate: f64| -> f64 {
            pts.iter()
                .filter(|p| {
                    p.req("rate_per_s").as_f64().unwrap() == rate
                        && p.req("model").as_str().unwrap().contains("mobilenet")
                })
                .map(|p| p.req("p99_ms").as_f64().unwrap())
                .fold(0.0, f64::max)
        };
        assert!(
            p99_of(800.0) > 2.0 * p99_of(25.0),
            "{} vs {}",
            p99_of(800.0),
            p99_of(25.0)
        );
    }
}
