//! Serving sweep: latency percentiles vs offered load, per arbitration
//! policy — the system-level "does the array speedup survive real
//! traffic?" table.
//!
//! Two models share one pool (MobileNetV2 + the Bottleneck case study,
//! both weights-resident) under seeded Poisson arrivals. Each row is one
//! (policy, offered rate, model) point: the latency a user actually sees
//! (p50/p95/p99, queueing included), pool utilization, and drops. The
//! sweep makes the serving story quantitative: percentiles stay flat while
//! the pool has headroom, then the heavy model's tail explodes first as
//! load crosses saturation — and the policies split exactly where the
//! paper's §VI argument predicts (SJF keeps the small model fast by
//! starving the big one; WRR shares; FIFO lets the heavy model drag both).

use crate::arch::PowerModel;
use crate::coordinator::PlanCache;
use crate::serve::{
    dispatch_label, mnv2_bottleneck_pair, simulate_with_cache, Policy, ServeConfig, DEFAULT_SEED,
};
use crate::util::json::{obj, Json};
use crate::util::table::{f, Table};

use super::Report;

pub const DEFAULT_RATES: &[f64] = &[25.0, 50.0, 100.0, 200.0];
pub const DEFAULT_POLICIES: &[Policy] = &[Policy::Fifo, Policy::Wrr, Policy::Sjf];

pub fn generate(pm: &PowerModel) -> Report {
    generate_sweep(pm, 64, DEFAULT_RATES, DEFAULT_POLICIES, 0.25, DEFAULT_SEED, true, true)
}

#[allow(clippy::too_many_arguments)]
pub fn generate_sweep(
    pm: &PowerModel,
    n_arrays: usize,
    rates: &[f64],
    policies: &[Policy],
    duration_s: f64,
    seed: u64,
    overlap: bool,
    backfill: bool,
) -> Report {
    let dispatch = dispatch_label(overlap, backfill);
    let title = format!(
        "Serving — latency percentiles vs offered load ({n_arrays} arrays, \
         {duration_s} s Poisson horizon/model, seed {seed:#x}, {dispatch} dispatch)"
    );
    let mut t = Table::new(
        &title,
        &[
            "policy", "rate/s", "model", "served", "p50 ms", "p95 ms", "p99 ms", "peak q",
            "util",
        ],
    );
    let mut points = Vec::new();
    // one cache across every sweep point: the (network, pool) keys repeat,
    // so TILE&PACK runs once per model, not once per (policy, rate)
    let mut cache = PlanCache::with_capacity(32);

    for &policy in policies {
        for &rate in rates {
            let scfg = ServeConfig {
                n_arrays,
                policy,
                overlap,
                backfill,
                seed,
                duration_s,
                ..ServeConfig::default()
            };
            let rep = match simulate_with_cache(&mnv2_bottleneck_pair(rate), &scfg, pm, &mut cache)
            {
                Ok(r) => r,
                Err(e) => {
                    t.row([
                        policy.label().into(),
                        f(rate, 0),
                        e,
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                    ]);
                    continue;
                }
            };
            let util = rep.utilization();
            for s in &rep.tenants {
                let (p50, p95, p99) = s.latency.percentiles();
                let ms = |cy: u64| cy as f64 * rep.cycle_ns * 1e-6;
                t.row([
                    policy.label().into(),
                    f(rate, 0),
                    s.name.to_string(),
                    s.served.to_string(),
                    f(ms(p50), 2),
                    f(ms(p95), 2),
                    f(ms(p99), 2),
                    s.peak_queue.to_string(),
                    format!("{:.0}%", util * 100.0),
                ]);
                points.push(obj([
                    ("policy", policy.label().into()),
                    ("rate_per_s", rate.into()),
                    ("model", s.name.as_ref().into()),
                    ("arrivals", (s.arrivals as f64).into()),
                    ("served", (s.served as f64).into()),
                    ("dropped", (s.dropped as f64).into()),
                    ("p50_ms", ms(p50).into()),
                    ("p95_ms", ms(p95).into()),
                    ("p99_ms", ms(p99).into()),
                    ("peak_queue", s.peak_queue.into()),
                    ("utilization", util.into()),
                    ("overlap", rep.overlap.into()),
                    ("backfill", rep.backfill.into()),
                    ("inf_per_s", rep.inferences_per_s().into()),
                ]));
            }
        }
    }

    let mut text = t.render();
    text.push_str(
        "open-loop Poisson per model, both models weights-resident in one pool, \
         per-resource interval dispatch (disjoint slices run concurrently, \
         backfilled batches slot into committed idle gaps); \
         latencies include queueing (p50/p95/p99 from the log histogram). \
         Past saturation FIFO couples the models, WRR shares the pool, SJF \
         shields the light model by starving the heavy one.\n",
    );

    Report {
        title: "serving".into(),
        text,
        data: Json::Arr(points),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_generates_all_points() {
        let pm = PowerModel::paper();
        let r = generate_sweep(
            &pm,
            64,
            &[50.0],
            &[Policy::Fifo, Policy::Sjf],
            0.05,
            0xAB,
            true,
            true,
        );
        let pts = r.data.as_arr().unwrap();
        // 2 policies × 1 rate × 2 models
        assert_eq!(pts.len(), 4);
        for p in pts {
            assert!(p.req("p99_ms").as_f64().unwrap() >= p.req("p50_ms").as_f64().unwrap());
            let u = p.req("utilization").as_f64().unwrap();
            assert!((0.0..=1.0).contains(&u), "{u}");
        }
    }

    #[test]
    fn overload_inflates_the_tail() {
        let pm = PowerModel::paper();
        let r = generate_sweep(&pm, 64, &[25.0, 800.0], &[Policy::Fifo], 0.05, 0xAB, true, true);
        let pts = r.data.as_arr().unwrap();
        let p99_of = |rate: f64| -> f64 {
            pts.iter()
                .filter(|p| {
                    p.req("rate_per_s").as_f64().unwrap() == rate
                        && p.req("model").as_str().unwrap().contains("mobilenet")
                })
                .map(|p| p.req("p99_ms").as_f64().unwrap())
                .fold(0.0, f64::max)
        };
        assert!(
            p99_of(800.0) > 2.0 * p99_of(25.0),
            "{} vs {}",
            p99_of(800.0),
            p99_of(25.0)
        );
    }
}
