//! Fig. 7: roofline of the IMA subsystem.
//!
//! Three panels — (a) sequential @500 MHz, (b) sequential @250 MHz,
//! (c) pipelined @250 MHz — each sweeping the IMA bus width 32→512 bit over
//! crossbar utilizations 5→100 %. The compute roof is the diagonal
//! `perf = ops/130 ns ∝ intensity²`; bandwidth lines cap the memory-bound
//! region; the paper's reading: 64-bit suffices at 500 MHz sequential,
//! 128-bit is optimal at 250 MHz pipelined where the roof is reached
//! (958 GOPS peak).

use crate::arch::{ExecModel, FreqPoint, PowerModel, SystemConfig};
use crate::ima::ImaSubsystem;
use crate::util::json::{obj, Json};
use crate::util::table::{f, Table};

use super::Report;

pub const BUS_WIDTHS: [usize; 5] = [32, 64, 128, 256, 512];

pub struct Panel {
    pub label: &'static str,
    pub freq: FreqPoint,
    pub exec: ExecModel,
}

pub fn panels() -> Vec<Panel> {
    vec![
        Panel {
            label: "(a) sequential @500MHz",
            freq: FreqPoint::HIGH,
            exec: ExecModel::Sequential,
        },
        Panel {
            label: "(b) sequential @250MHz",
            freq: FreqPoint::LOW,
            exec: ExecModel::Sequential,
        },
        Panel {
            label: "(c) pipelined @250MHz",
            freq: FreqPoint::LOW,
            exec: ExecModel::Pipelined,
        },
    ]
}

pub fn generate() -> Report {
    let pm = PowerModel::paper();
    let mut text = String::new();
    let mut data_panels = Vec::new();

    for panel in panels() {
        let mut t = Table::new(
            &format!("Fig. 7 {} — GOPS by (utilization, bus width)", panel.label),
            &["util %", "intensity", "roof", "32b", "64b", "128b", "256b", "512b"],
        );
        let mut series = Vec::new();
        for (u, layer) in crate::net::workload::utilization_sweep(256) {
            let mut row = vec![f(u * 100.0, 0)];
            let mut per_bus = Vec::new();
            let mut intensity = 0.0;
            let mut roof = 0.0;
            for bus in BUS_WIDTHS {
                let cfg = SystemConfig::paper()
                    .with_freq(panel.freq)
                    .with_exec(panel.exec)
                    .with_bus_bits(bus);
                let ima = ImaSubsystem::new(&cfg, &pm);
                let (i, achieved, r) = ima.roofline_point(layer.cin, 2048);
                intensity = i;
                roof = r;
                per_bus.push((bus, achieved));
            }
            row.insert(1, f(intensity, 1));
            row.insert(2, f(roof, 1));
            for (_, a) in &per_bus {
                row.push(f(*a, 1));
            }
            t.row(row);
            series.push(obj([
                ("utilization", u.into()),
                ("intensity_ops_per_byte", intensity.into()),
                ("roof_gops", roof.into()),
                (
                    "achieved_gops",
                    Json::Arr(
                        per_bus
                            .iter()
                            .map(|(b, a)| obj([("bus_bits", (*b).into()), ("gops", (*a).into())]))
                            .collect(),
                    ),
                ),
            ]));
        }
        text.push_str(&t.render());
        text.push('\n');
        data_panels.push(obj([
            ("panel", panel.label.into()),
            ("points", Json::Arr(series)),
        ]));
    }

    // the §V-B peak claim
    let cfg = SystemConfig::paper().with_freq(FreqPoint::LOW);
    let ima = ImaSubsystem::new(&cfg, &pm);
    let (_, peak, roof) = ima.roofline_point(256, 65536);
    text.push_str(&format!(
        "peak (pipelined, 128-bit, 250 MHz, 100% util): {peak:.0} GOPS \
         ({:.1}% of the {roof:.0} GOPS compute roof; paper: 958, >90%)\n",
        100.0 * peak / roof
    ));

    Report {
        title: "fig7_roofline".into(),
        text,
        data: obj([
            ("panels", Json::Arr(data_panels)),
            ("peak_gops", peak.into()),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_three_panels_render() {
        let r = generate();
        assert!(r.text.contains("(a) sequential @500MHz"));
        assert!(r.text.contains("(c) pipelined @250MHz"));
        let peak = r.data.req("peak_gops").as_f64().unwrap();
        assert!((900.0..1000.0).contains(&peak), "{peak}");
    }

    #[test]
    fn memory_bound_only_at_32bit_500mhz() {
        // Fig. 7a reading: "only with a 32-bit wide bus we are memory bound
        // and a 64-bit wide data interface is sufficient" — i.e. at 500 MHz
        // the 64-bit bandwidth *line* already crosses above the compute roof
        // at full utilization, while the 32-bit line does not.
        let pm = PowerModel::paper();
        for (bus, sufficient) in [(32usize, false), (64, true), (128, true)] {
            let cfg = SystemConfig::paper().with_bus_bits(bus);
            let ima = ImaSubsystem::new(&cfg, &pm);
            let (intensity, _, roof) = ima.roofline_point(256, 2048);
            let bw_line_gops = ima.bus_bandwidth_gbps() * intensity;
            assert_eq!(
                bw_line_gops >= roof,
                sufficient,
                "bus {bus}: bw line {bw_line_gops:.0} vs roof {roof:.0}"
            );
        }
    }
}
