//! Ablations beyond the paper's figures (DESIGN.md §8) — quantifying the
//! design choices the paper asserts:
//!
//! * sequential vs pipelined IMA on the *end-to-end* network (Fig. 7 only
//!   shows synthetic layers);
//! * C_job sweep for depth-wise-on-IMA (the paper reports only 8 and 16);
//! * IMA bus-width sweep end-to-end (the paper fixes 128-bit);
//! * L1 residency + DMA double-buffering check (§VI *assumes* activations
//!   fit L1 and DMA hides; we verify per layer);
//! * PCM programming one-time cost (§VI quotes 20–30× MVM latency per row).

use crate::arch::{ExecModel, PowerModel, SystemConfig};
use crate::coordinator::{run_network, Strategy};
use crate::ima::{DwMap, ImaSubsystem};
use crate::net::mobilenetv2::mobilenet_v2;
use crate::net::{bottleneck, LayerKind};
use crate::util::json::{obj, Json};
use crate::util::table::{f, Table};

use super::Report;

pub fn generate(pm: &PowerModel) -> Report {
    let mut text = String::new();
    let mut data = Vec::new();

    // ---- 1. sequential vs pipelined, end to end --------------------------
    let net = mobilenet_v2(224);
    let n_xbars = {
        let tiles = crate::tilepack::tile_network(&net, 256);
        crate::tilepack::pack(&tiles, 256, false).n_bins()
    };
    let mut t = Table::new(
        "ablation 1 — IMA execution model, end-to-end MobileNetV2",
        &["exec model", "latency", "energy", "inf/s"],
    );
    let mut seq_pipe = Vec::new();
    for exec in [ExecModel::Sequential, ExecModel::Pipelined] {
        let cfg = SystemConfig::scaled_up(n_xbars).with_exec(exec);
        let r = run_network(&net, Strategy::ImaDw, &cfg, pm);
        t.row([
            format!("{exec:?}"),
            crate::util::units::fmt_time(r.time_s),
            crate::util::units::fmt_energy(r.energy_j),
            f(r.inferences_per_s(), 1),
        ]);
        seq_pipe.push(obj([
            ("exec", format!("{exec:?}").into()),
            ("time_s", r.time_s.into()),
            ("energy_j", r.energy_j.into()),
        ]));
    }
    text.push_str(&t.render());
    data.push(("exec_model", Json::Arr(seq_pipe)));

    // ---- 2. C_job sweep ---------------------------------------------------
    let bn = bottleneck::bottleneck();
    let cfg = SystemConfig::paper();
    let ima = ImaSubsystem::new(&cfg, pm);
    let mut t = Table::new(
        "ablation 2 — depth-wise-on-IMA C_job sweep (case-study dw layer)",
        &["C_job", "jobs", "devices", "cycles", "MAC/cycle"],
    );
    let mut cjob_rows = Vec::new();
    for c_job in [1usize, 2, 4, 8, 16, 32, 64] {
        let map = DwMap::new(&bn.layers[1], c_job);
        let cost = ima.dw_layer_cost(&map);
        let rate = cost.useful_macs as f64 / cost.cycles as f64;
        t.row([
            c_job.to_string(),
            map.n_jobs().to_string(),
            map.devices_total().to_string(),
            cost.cycles.to_string(),
            f(rate, 2),
        ]);
        cjob_rows.push(obj([
            ("c_job", c_job.into()),
            ("devices", map.devices_total().into()),
            ("cycles", (cost.cycles as i64).into()),
        ]));
    }
    text.push_str(&t.render());
    text.push_str(
        "reading: doubling C_job halves time but doubles wasted devices — the\n\
         paper's 8/16 sit at the knee; even C_job=64 stays far from the DW\n\
         accelerator's 29.7 MAC/cycle.\n\n",
    );
    data.push(("cjob_sweep", Json::Arr(cjob_rows)));

    // ---- 3. bus-width sweep end-to-end -------------------------------------
    let mut t = Table::new(
        "ablation 3 — IMA bus width, end-to-end MobileNetV2 (pipelined)",
        &["bus", "latency", "vs 128-bit"],
    );
    let base = {
        let cfg = SystemConfig::scaled_up(n_xbars).with_bus_bits(128);
        run_network(&net, Strategy::ImaDw, &cfg, pm).time_s
    };
    let mut bus_rows = Vec::new();
    for bus in [32usize, 64, 128, 256, 512] {
        let cfg = SystemConfig::scaled_up(n_xbars).with_bus_bits(bus);
        let r = run_network(&net, Strategy::ImaDw, &cfg, pm);
        t.row([
            format!("{bus}b"),
            crate::util::units::fmt_time(r.time_s),
            format!("{:+.1}%", 100.0 * (r.time_s - base) / base),
        ]);
        bus_rows.push(obj([("bus", bus.into()), ("time_s", r.time_s.into())]));
    }
    text.push_str(&t.render());
    data.push(("bus_sweep", Json::Arr(bus_rows)));

    // ---- 4. L1 residency + DMA double-buffering (the L1 planner) ----------
    let cfg = SystemConfig::scaled_up(n_xbars);
    let lp = crate::coordinator::l1_plan(&net, Strategy::ImaDw, &cfg, pm);
    let e2e = run_network(&net, Strategy::ImaDw, &cfg, pm);
    let exposed = lp.total_exposed_dma_cy();
    text.push_str(&format!(
        "ablation 4 — L1 residency (planner): {} of {} layers need spatial \
         tiling against the 512 kB TCDM (peak working set {} kB); \
         double-buffered DMA hides all transfers except the stride-2 \
         depth-wise layers, exposing {} cycles = {:.1}% of the inference → \
         the paper's \"resident in L1\" §VI assumption is near-free, not \
         free.\n\n",
        lp.layers_tiled(),
        net.layers.len(),
        lp.peak_working_set() / 1024,
        exposed,
        100.0 * exposed as f64 / e2e.cycles as f64
    ));
    data.push(("l1_layers_tiled", Json::Num(lp.layers_tiled() as f64)));
    data.push(("l1_exposed_dma_cy", Json::Num(exposed as f64)));

    // ---- 5. PCM programming one-time cost ----------------------------------
    let rows_programmed: usize = net
        .layers
        .iter()
        .filter(|l| l.kind == LayerKind::Conv)
        .map(|l| l.xbar_map_rows().min(256) * l.cout.div_ceil(256)
            + l.xbar_map_rows().saturating_sub(256))
        .sum();
    let prog_s = rows_programmed as f64 * cfg.pcm_program_row_factor * cfg.ima_mvm_ns * 1e-9;
    text.push_str(&format!(
        "ablation 5 — PCM programming: ~{rows_programmed} crossbar rows, \
         {:.1} ms one-time program-and-verify (≈{:.0}× one inference) — why \
         §VI rules out inference-time reprogramming.\n",
        prog_s * 1e3,
        prog_s / 10.1e-3
    ));
    data.push(("pcm_program_s", Json::Num(prog_s)));

    Report {
        title: "ablations".into(),
        text,
        data: Json::Obj(data.into_iter().map(|(k, v)| (k.to_string(), v)).collect()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipelined_beats_sequential_e2e() {
        let pm = PowerModel::paper();
        let r = generate(&pm);
        let arr = r.data.req("exec_model").as_arr().unwrap();
        let seq = arr[0].req("time_s").as_f64().unwrap();
        let pipe = arr[1].req("time_s").as_f64().unwrap();
        assert!(pipe < seq);
        // sequential costs tens of percent end to end
        assert!(seq / pipe > 1.1, "{}", seq / pipe);
    }

    #[test]
    fn cjob_monotonic_in_devices_and_speed() {
        let pm = PowerModel::paper();
        let r = generate(&pm);
        let rows = r.data.req("cjob_sweep").as_arr().unwrap();
        for w in rows.windows(2) {
            assert!(w[1].req("devices").as_i64() > w[0].req("devices").as_i64());
            assert!(w[1].req("cycles").as_i64() < w[0].req("cycles").as_i64());
        }
    }

    #[test]
    fn bus_width_knee_at_128() {
        let pm = PowerModel::paper();
        let r = generate(&pm);
        let rows = r.data.req("bus_sweep").as_arr().unwrap();
        let t = |i: usize| rows[i].req("time_s").as_f64().unwrap();
        // 32b noticeably worse than 128b; 512b no better than 128b
        assert!(t(0) > t(2) * 1.05, "32b {} vs 128b {}", t(0), t(2));
        assert!((t(4) - t(2)).abs() / t(2) < 0.02);
    }

    #[test]
    fn programming_dwarfs_inference() {
        let pm = PowerModel::paper();
        let r = generate(&pm);
        let prog = r.data.req("pcm_program_s").as_f64().unwrap();
        assert!(prog > 10.1e-3, "{prog}");
    }
}
