//! Fig. 13: MobileNetV2 performance across the four IMC computing models —
//! IMA+DIG.ACC (not deployable), IMA+MCU, SW+IMA, SW+IMA+DIG.ACC (this work).

use crate::arch::PowerModel;
use crate::baselines::{AnalogNets, JiaMcu};
use crate::coordinator::{run_network, Strategy};
use crate::net::mobilenetv2::mobilenet_v2;
use crate::util::json::{obj, Json};
use crate::util::table::{f, Table};

use super::fig12_e2e;
use super::Report;

pub fn generate(pm: &PowerModel) -> Report {
    let (cfg, _) = fig12_e2e::e2e_config();
    let net = mobilenet_v2(224);

    // SW+IMA: the [8]-class system — pw on IMA, dw + rest in software
    let sw_ima = run_network(&net, Strategy::Hybrid, &cfg, pm);
    // SW+IMA+DIG.ACC: this work
    let full = run_network(&net, Strategy::ImaDw, &cfg, pm);
    // IMA+MCU: [6]-class
    let mcu = JiaMcu::default();
    let mcu_inf_s = 1.0 / mcu.mnv2_time_s();
    // IMA+DIG.ACC: [7]/[31]-class — not deployable
    let blockers = AnalogNets.mnv2_blockers();

    let mut t = Table::new(
        "Fig. 13 — MobileNetV2 on four IMC computing models",
        &["model", "example", "inf/s", "note"],
    );
    t.row([
        "IMA+DIG.ACC".into(),
        "[7],[31]".into(),
        "n/a".into(),
        "not deployable (no programmable cores)".into(),
    ]);
    t.row([
        "IMA+MCU".into(),
        "[6]".into(),
        f(mcu_inf_s, 2),
        "single tiny core bottleneck".into(),
    ]);
    t.row([
        "SW+IMA".into(),
        "[8]".into(),
        f(sw_ima.inferences_per_s(), 1),
        "dw in software limits".into(),
    ]);
    t.row([
        "SW+IMA+DIG.ACC".into(),
        "this work".into(),
        f(full.inferences_per_s(), 1),
        "paper: 99 inf/s".into(),
    ]);

    let mut text = t.render();
    text.push_str(&format!("IMA+DIG.ACC blockers: {}\n", blockers.join("; ")));

    Report {
        title: "fig13_models".into(),
        text,
        data: obj([
            ("ima_mcu_inf_s", mcu_inf_s.into()),
            ("sw_ima_inf_s", sw_ima.inferences_per_s().into()),
            ("this_work_inf_s", full.inferences_per_s().into()),
            ("ima_digacc_deployable", false.into()),
            (
                "blockers",
                Json::Arr(blockers.into_iter().map(Json::Str).collect()),
            ),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_of_the_four_models() {
        let pm = PowerModel::paper();
        let r = generate(&pm);
        let mcu = r.data.req("ima_mcu_inf_s").as_f64().unwrap();
        let sw_ima = r.data.req("sw_ima_inf_s").as_f64().unwrap();
        let this = r.data.req("this_work_inf_s").as_f64().unwrap();
        assert!(this > sw_ima && sw_ima > mcu, "{this} > {sw_ima} > {mcu}");
        // paper: this work ≈ 99 inf/s, SW+IMA noticeably slower, IMA+MCU
        // two orders of magnitude down
        assert!(this / mcu > 100.0, "{:.0}x", this / mcu);
        assert!(this / sw_ima > 1.5, "{:.1}x", this / sw_ima);
    }
}
