//! Golden-vector comparison helpers (checksums shared with qnn.py).

/// Order-independent checksum: sum of elements as i64 + 31·count.
/// Must match `python/compile/qnn.py::checksum_i64`.
pub fn checksum_i8(x: &[i8]) -> i64 {
    x.iter().map(|&v| v as i64).sum::<i64>() + 31 * x.len() as i64
}

pub fn checksum_i32(x: &[i32]) -> i64 {
    x.iter().map(|&v| v as i64).sum::<i64>() + 31 * x.len() as i64
}

/// First index where two slices differ (diagnostics).
pub fn first_mismatch<T: PartialEq>(a: &[T], b: &[T]) -> Option<usize> {
    if a.len() != b.len() {
        return Some(a.len().min(b.len()));
    }
    a.iter().zip(b.iter()).position(|(x, y)| x != y)
}

/// Load a little-endian int8 binary file.
pub fn load_i8(path: &str) -> std::io::Result<Vec<i8>> {
    Ok(std::fs::read(path)?.iter().map(|&b| b as i8).collect())
}

pub fn load_i32(path: &str) -> std::io::Result<Vec<i32>> {
    Ok(std::fs::read(path)?
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checksum_matches_python_formula() {
        // qnn.py: sum + 31*size; see test_checksum_matches_rust_formula
        assert_eq!(checksum_i32(&[1, -2, 3]), (1 - 2 + 3) + 31 * 3);
        assert_eq!(checksum_i8(&[]), 0);
    }

    #[test]
    fn mismatch_detection() {
        assert_eq!(first_mismatch(&[1, 2, 3], &[1, 9, 3]), Some(1));
        assert_eq!(first_mismatch(&[1, 2], &[1, 2]), None);
        assert_eq!(first_mismatch(&[1], &[1, 2]), Some(1));
    }
}
