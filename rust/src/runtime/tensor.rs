//! HWC int8 tensors + the host-side data movement the cluster cores do:
//! im2col gather (the streamer's virtual IM2COL, done by the host here),
//! zero-padded tile extraction for the dw engine, chunking.

#[derive(Clone, Debug, PartialEq)]
pub struct TensorI8 {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<i8>,
}

impl TensorI8 {
    pub fn zeros(h: usize, w: usize, c: usize) -> TensorI8 {
        TensorI8 {
            h,
            w,
            c,
            data: vec![0; h * w * c],
        }
    }

    pub fn from_vec(h: usize, w: usize, c: usize, data: Vec<i8>) -> TensorI8 {
        assert_eq!(data.len(), h * w * c);
        TensorI8 { h, w, c, data }
    }

    #[inline]
    pub fn at(&self, y: usize, x: usize, ch: usize) -> i8 {
        self.data[(y * self.w + x) * self.c + ch]
    }

    /// Signed-coordinate read with zero padding outside the tensor.
    #[inline]
    pub fn at_padded(&self, y: isize, x: isize, ch: usize) -> i8 {
        if y < 0 || x < 0 || y >= self.h as isize || x >= self.w as isize {
            0
        } else {
            self.at(y as usize, x as usize, ch)
        }
    }

    /// im2col row for output pixel (oy, ox): crossbar row ordering
    /// r = (ki*k + kj)*Cin + ci (must match ref.im2col / functional.rs).
    /// Writes `k*k*c` values into `out`.
    pub fn im2col_row(&self, oy: usize, ox: usize, k: usize, stride: usize, pad: usize, out: &mut [i8]) {
        debug_assert_eq!(out.len(), k * k * self.c);
        let mut idx = 0;
        let oy = (oy * stride) as isize - pad as isize;
        let ox = (ox * stride) as isize - pad as isize;
        for ki in 0..k as isize {
            for kj in 0..k as isize {
                let y = oy + ki;
                let x = ox + kj;
                if y >= 0 && x >= 0 && y < self.h as isize && x < self.w as isize {
                    let base = ((y as usize) * self.w + x as usize) * self.c;
                    out[idx..idx + self.c].copy_from_slice(&self.data[base..base + self.c]);
                } else {
                    out[idx..idx + self.c].fill(0);
                }
                idx += self.c;
            }
        }
    }

    /// Extract a zero-padded spatial tile of one 16-channel block for the
    /// dw engine: input window origin (in padded coordinates with pad=1)
    /// at (y0, x0), side `side`, channels [c0, c0+16).
    pub fn dw_tile(&self, y0: isize, x0: isize, side: usize, c0: usize, cb: usize) -> Vec<i8> {
        let mut out = vec![0i8; side * side * cb];
        for ty in 0..side {
            for tx in 0..side {
                let sy = y0 + ty as isize;
                let sx = x0 + tx as isize;
                if sy < 0 || sx < 0 || sy >= self.h as isize || sx >= self.w as isize {
                    continue; // stays zero
                }
                let src = ((sy as usize) * self.w + sx as usize) * self.c + c0;
                let dst = (ty * side + tx) * cb;
                let n = cb.min(self.c.saturating_sub(c0));
                out[dst..dst + n].copy_from_slice(&self.data[src..src + n]);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq_tensor(h: usize, w: usize, c: usize) -> TensorI8 {
        let data: Vec<i8> = (0..h * w * c).map(|i| (i % 127) as i8).collect();
        TensorI8::from_vec(h, w, c, data)
    }

    #[test]
    fn im2col_row_k1_is_identity() {
        let t = seq_tensor(4, 4, 3);
        let mut out = vec![0; 3];
        t.im2col_row(2, 1, 1, 1, 0, &mut out);
        assert_eq!(out, vec![t.at(2, 1, 0), t.at(2, 1, 1), t.at(2, 1, 2)]);
    }

    #[test]
    fn im2col_row_k3_ordering() {
        let t = seq_tensor(5, 5, 2);
        let mut out = vec![0; 18];
        t.im2col_row(1, 1, 3, 1, 1, &mut out);
        // r = (ki*3 + kj)*2 + ci; window origin (0,0)
        for ki in 0..3 {
            for kj in 0..3 {
                for ci in 0..2 {
                    let r = (ki * 3 + kj) * 2 + ci;
                    assert_eq!(out[r], t.at(ki, kj, ci), "ki {ki} kj {kj} ci {ci}");
                }
            }
        }
    }

    #[test]
    fn im2col_zero_pads_borders() {
        let t = seq_tensor(4, 4, 1);
        let mut out = vec![99; 9];
        t.im2col_row(0, 0, 3, 1, 1, &mut out);
        // top-left window: first row and column padded
        assert_eq!(out[0], 0);
        assert_eq!(out[1], 0);
        assert_eq!(out[3], 0);
        assert_eq!(out[4], t.at(0, 0, 0));
    }

    #[test]
    fn im2col_stride2() {
        let t = seq_tensor(8, 8, 1);
        let mut out = vec![0; 9];
        t.im2col_row(1, 2, 3, 2, 1, &mut out);
        // window origin = (1*2-1, 2*2-1) = (1, 3)
        assert_eq!(out[0], t.at(1, 3, 0));
        assert_eq!(out[8], t.at(3, 5, 0));
    }

    #[test]
    fn dw_tile_extraction_with_halo() {
        let t = seq_tensor(16, 16, 32);
        // tile at origin (-1,-1) (pad=1), block 1 (channels 16..32)
        let tile = t.dw_tile(-1, -1, 18, 16, 16);
        assert_eq!(tile.len(), 18 * 18 * 16);
        // (0,0) of the tile is padding
        assert_eq!(tile[0], 0);
        // (1,1,ch0) of the tile is t(0,0,16)
        assert_eq!(tile[(18 + 1) * 16], t.at(0, 0, 16));
    }

    #[test]
    fn dw_tile_partial_channel_block_zero_fills() {
        let t = seq_tensor(4, 4, 24); // 24 channels: second block is half
        let tile = t.dw_tile(0, 0, 4, 16, 16);
        // channels 8..16 of the block (i.e. 24..32) must be zero
        for c in 8..16 {
            assert_eq!(tile[c], 0, "padded channel {c}");
        }
        assert_eq!(tile[0], t.at(0, 0, 16));
    }
}
