//! Functional end-to-end inference through the job backend.
//!
//! Replays the manifest network layer by layer, issuing the same job stream
//! the timing model accounts (DESIGN.md §4):
//!
//! * conv/fc — host gathers the virtual-IM2COL rows (the streamer's job),
//!   crossbar tiles are programmed once as device buffers, 16-pixel MVM
//!   jobs run per (row-tile × col-tile); row-split layers accumulate int32
//!   partials on the host (the cores' job) and requantize via the `requant`
//!   artifact;
//! * dw — 16-channel × 16×16-output engine tiles through `dw3x3_s{1,2}`;
//! * add — saturating `residual` chunks;
//! * pool — host integer math (cores), matching `ref.avgpool_ref` exactly;
//! * fc — raw partials summed to int32 logits (no requant, like the golden).
//!
//! Every layer's output checksum is compared against the manifest golden;
//! the final logits must match bit-exactly.

use crate::bail;
use crate::net::LayerKind;
use crate::util::error::{Context, Result};
use crate::util::rng::SplitMix64;

use super::client::{Runtime, DW_CB, DW_TILE, PIXELS, PIXELS_BATCH, RESIDUAL_CHUNK, XBAR};
use super::golden::{checksum_i32, checksum_i8};
use super::manifest::Manifest;
use super::tensor::TensorI8;

#[derive(Debug)]
pub struct InferenceResult {
    pub logits: Vec<i32>,
    pub argmax: usize,
    pub backend_calls: u64,
    pub programmed_tiles: usize,
    pub wall: std::time::Duration,
    /// (layer name, ours, golden) for every layer — all must match.
    pub checksums: Vec<(String, i64, i64)>,
}

impl InferenceResult {
    pub fn all_match(&self) -> bool {
        self.checksums.iter().all(|(_, a, b)| a == b)
    }

    pub fn first_divergent_layer(&self) -> Option<&str> {
        self.checksums
            .iter()
            .find(|(_, a, b)| a != b)
            .map(|(n, _, _)| n.as_str())
    }
}

/// Program every conv/fc crossbar tile of the network (done once, like the
/// PCM programming flow in §VI). `sigma > 0` adds Gaussian conductance noise
/// to the stored weights (the accuracy ablation).
pub fn program_network(rt: &mut Runtime, m: &Manifest, sigma: f64) -> Result<()> {
    for (li, ml) in m.layers.iter().enumerate() {
        let l = &ml.layer;
        if !matches!(l.kind, LayerKind::Conv | LayerKind::Fc) {
            continue;
        }
        let rows = l.k * l.k * l.cin;
        let cols = l.cout;
        let w = m.layer_weights(li);
        assert_eq!(w.len(), rows * cols, "{}", l.name);
        let n_rt = rows.div_ceil(XBAR);
        let n_ct = cols.div_ceil(XBAR);
        for rt_i in 0..n_rt {
            for ct_i in 0..n_ct {
                let r0 = rt_i * XBAR;
                let c0 = ct_i * XBAR;
                let r_used = (rows - r0).min(XBAR);
                let c_used = (cols - c0).min(XBAR);
                let mut tile = vec![0i8; XBAR * XBAR];
                for r in 0..r_used {
                    let src = (r0 + r) * cols + c0;
                    tile[r * XBAR..r * XBAR + c_used].copy_from_slice(&w[src..src + c_used]);
                }
                if sigma > 0.0 {
                    let mut rng = SplitMix64::new(
                        (m.seed as u64) ^ ((li as u64) << 32) ^ ((rt_i as u64) << 16) ^ ct_i as u64,
                    );
                    for v in tile.iter_mut() {
                        if *v != 0 {
                            let noisy = (*v as f64 + rng.next_gauss() * sigma * 8.0).round();
                            *v = noisy.clamp(-8.0, 7.0) as i8;
                        }
                    }
                }
                rt.program_weight_tile((li, rt_i, ct_i), &tile)?;
            }
        }
    }
    Ok(())
}

/// Run one conv/fc layer. Returns the HWC output tensor (for fc, the int32
/// logits are returned separately).
fn run_conv(
    rt: &Runtime,
    li: usize,
    l: &crate::net::Layer,
    input: &TensorI8,
) -> Result<(TensorI8, Option<Vec<i32>>)> {
    let rows = l.k * l.k * l.cin;
    let cols = l.cout;
    let n_rt = rows.div_ceil(XBAR);
    let n_ct = cols.div_ceil(XBAR);
    let hout = l.hout();
    let wout = l.wout();
    let pixels = hout * wout;
    let mut out = TensorI8::zeros(hout, wout, cols);
    let mut fc_logits: Option<Vec<i32>> = None;

    let mut im2col = vec![0i8; rows];
    let mut chunk_rows = vec![vec![0i8; PIXELS_BATCH * XBAR]; n_rt];

    let mut px = 0usize;
    while px < pixels {
        // prefer the 128-pixel batched artifact; fall back to 16 at the tail
        let batch = if pixels - px >= PIXELS_BATCH {
            PIXELS_BATCH
        } else {
            PIXELS
        };
        let n_px = batch.min(pixels - px);
        // gather the im2col rows of this pixel chunk, split by row tile
        for cr in chunk_rows.iter_mut() {
            cr[..batch * XBAR].fill(0);
        }
        for p in 0..n_px {
            let oy = (px + p) / wout;
            let ox = (px + p) % wout;
            input.im2col_row(oy, ox, l.k, l.stride, l.pad, &mut im2col);
            for (rt_i, cr) in chunk_rows.iter_mut().enumerate() {
                let r0 = rt_i * XBAR;
                let r_used = (rows - r0).min(XBAR);
                cr[p * XBAR..p * XBAR + r_used].copy_from_slice(&im2col[r0..r0 + r_used]);
            }
        }

        if n_rt == 1 && l.kind != LayerKind::Fc {
            // fused-ADC path: one job batch per column tile
            for ct_i in 0..n_ct {
                let y = rt.mvm(
                    (li, 0, ct_i),
                    &chunk_rows[0][..batch * XBAR],
                    l.shift,
                    l.relu,
                    batch,
                )?;
                let c0 = ct_i * XBAR;
                let c_used = (cols - c0).min(XBAR);
                for p in 0..n_px {
                    let dst = (px + p) * cols + c0;
                    out.data[dst..dst + c_used]
                        .copy_from_slice(&y[p * XBAR..p * XBAR + c_used]);
                }
            }
        } else {
            // row-split: raw int32 partials, host accumulation (cores),
            // digital requant — or raw logits for the classifier
            for ct_i in 0..n_ct {
                let c0 = ct_i * XBAR;
                let c_used = (cols - c0).min(XBAR);
                let mut acc = vec![0i32; batch * XBAR];
                for (rt_i, cr) in chunk_rows.iter().enumerate() {
                    let part = rt.mvm_raw((li, rt_i, ct_i), &cr[..batch * XBAR], batch)?;
                    for (a, p) in acc.iter_mut().zip(part.iter()) {
                        *a += *p;
                    }
                }
                if l.kind == LayerKind::Fc {
                    let logits = fc_logits.get_or_insert_with(|| vec![0i32; cols]);
                    for c in 0..c_used {
                        logits[c0 + c] = acc[c]; // single pixel (row 0)
                    }
                } else {
                    let y = rt.requant(&acc, l.shift, l.relu, batch)?;
                    for p in 0..n_px {
                        let dst = (px + p) * cols + c0;
                        out.data[dst..dst + c_used]
                            .copy_from_slice(&y[p * XBAR..p * XBAR + c_used]);
                    }
                }
            }
        }
        px += n_px;
    }
    Ok((out, fc_logits))
}

/// Run one depth-wise layer through the engine tiles.
fn run_dw(rt: &Runtime, w: &[i8], l: &crate::net::Layer, input: &TensorI8) -> Result<TensorI8> {
    assert_eq!(l.k, 3);
    let hout = l.hout();
    let wout = l.wout();
    let c = l.cout;
    let mut out = TensorI8::zeros(hout, wout, c);
    let side = (DW_TILE - 1) * l.stride + 3;
    let n_cb = c.div_ceil(DW_CB);
    let n_ty = hout.div_ceil(DW_TILE);
    let n_tx = wout.div_ceil(DW_TILE);

    for cb in 0..n_cb {
        let c0 = cb * DW_CB;
        // weight block [3,3,16] with zero-fill beyond c
        let mut wb = vec![0i8; 9 * DW_CB];
        for kk in 0..9 {
            let n = DW_CB.min(c - c0);
            wb[kk * DW_CB..kk * DW_CB + n]
                .copy_from_slice(&w[kk * c + c0..kk * c + c0 + n]);
        }
        for ty in 0..n_ty {
            for tx in 0..n_tx {
                let y0 = (ty * DW_TILE * l.stride) as isize - l.pad as isize;
                let x0 = (tx * DW_TILE * l.stride) as isize - l.pad as isize;
                let xt = input.dw_tile(y0, x0, side, c0, DW_CB);
                let yt = rt.dw_tile(&xt, &wb, l.shift, l.relu, l.stride)?;
                let ny = DW_TILE.min(hout - ty * DW_TILE);
                let nx = DW_TILE.min(wout - tx * DW_TILE);
                let nc = DW_CB.min(c - c0);
                for dy in 0..ny {
                    for dx in 0..nx {
                        let src = (dy * DW_TILE + dx) * DW_CB;
                        let dst = ((ty * DW_TILE + dy) * wout + tx * DW_TILE + dx) * c + c0;
                        out.data[dst..dst + nc].copy_from_slice(&yt[src..src + nc]);
                    }
                }
            }
        }
    }
    Ok(out)
}

/// Run one conv/fc layer through the backend job stream. Public so the
/// batched property tests can pit the exact orchestration path (tiling,
/// padding, chunked 16/128-pixel batching, row-split accumulation) against
/// an independent host reference — no artifacts required.
pub fn run_conv_layer(
    rt: &Runtime,
    li: usize,
    l: &crate::net::Layer,
    input: &TensorI8,
) -> Result<(TensorI8, Option<Vec<i32>>)> {
    run_conv(rt, li, l, input)
}

fn run_residual(rt: &Runtime, a: &TensorI8, b: &TensorI8) -> Result<TensorI8> {
    assert_eq!(a.data.len(), b.data.len());
    let n = a.data.len();
    let mut out = TensorI8::zeros(a.h, a.w, a.c);
    let mut pa = vec![0i8; RESIDUAL_CHUNK];
    let mut pb = vec![0i8; RESIDUAL_CHUNK];
    let mut i = 0;
    while i < n {
        let len = RESIDUAL_CHUNK.min(n - i);
        pa[..len].copy_from_slice(&a.data[i..i + len]);
        pb[..len].copy_from_slice(&b.data[i..i + len]);
        pa[len..].fill(0);
        pb[len..].fill(0);
        let y = rt.residual(&pa, &pb)?;
        out.data[i..i + len].copy_from_slice(&y[..len]);
        i += len;
    }
    Ok(out)
}

/// Global average pool — host integer math matching `ref.avgpool_ref`.
fn run_pool(input: &TensorI8) -> TensorI8 {
    let area = (input.h * input.w) as i64;
    let mut out = TensorI8::zeros(1, 1, input.c);
    for ch in 0..input.c {
        let mut s: i64 = 0;
        for y in 0..input.h {
            for x in 0..input.w {
                s += input.at(y, x, ch) as i64;
            }
        }
        let q = (s + area / 2).div_euclid(area);
        out.data[ch] = q.clamp(-128, 127) as i8;
    }
    out
}

/// Full inference of a manifest network. Weights must be programmed first.
pub fn run_inference(rt: &Runtime, m: &Manifest) -> Result<InferenceResult> {
    let t0 = std::time::Instant::now();
    let calls0 = rt.calls.get();
    let (h, w, c) = m.input_shape;
    let mut acts: Vec<TensorI8> = Vec::with_capacity(m.layers.len());
    let mut cur = TensorI8::from_vec(h, w, c, m.input.clone());
    let mut logits: Option<Vec<i32>> = None;
    let mut checksums = Vec::new();

    for (li, ml) in m.layers.iter().enumerate() {
        let l = &ml.layer;
        let (out, sum) = match l.kind {
            LayerKind::Conv => {
                let (y, _) = run_conv(rt, li, l, &cur)?;
                let s = checksum_i8(&y.data);
                (Some(y), s)
            }
            LayerKind::Fc => {
                // flatten input to a 1×1×cin "pixel"
                let flat = TensorI8::from_vec(1, 1, cur.data.len(), cur.data.clone());
                let (_, lg) = run_conv(rt, li, l, &flat)?;
                let lg = lg.context("fc must produce logits")?;
                let s = checksum_i32(&lg);
                logits = Some(lg);
                (None, s)
            }
            LayerKind::Dw => {
                let y = run_dw(rt, m.layer_weights(li), l, &cur)?;
                let s = checksum_i8(&y.data);
                (Some(y), s)
            }
            LayerKind::Add => {
                let src = &acts[l.residual_from.expect("add needs source")];
                let y = run_residual(rt, &cur, src)?;
                let s = checksum_i8(&y.data);
                (Some(y), s)
            }
            LayerKind::Pool => {
                let y = run_pool(&cur);
                let s = checksum_i8(&y.data);
                (Some(y), s)
            }
        };
        checksums.push((l.name.clone(), sum, ml.out_checksum));
        if let Some(y) = out {
            acts.push(y.clone());
            cur = y;
        }
    }

    let logits = logits.context("network has no fc layer")?;
    let argmax = logits
        .iter()
        .enumerate()
        .max_by_key(|(_, v)| **v)
        .map(|(i, _)| i)
        .unwrap();
    Ok(InferenceResult {
        logits,
        argmax,
        backend_calls: rt.calls.get() - calls0,
        programmed_tiles: rt.programmed_tiles(),
        wall: t0.elapsed(),
        checksums,
    })
}

/// Serve a batch of `n` inference requests (weights stay programmed — the
/// request loop the coordinator runs in deployment). Returns amortized
/// seconds per inference.
pub fn serve_batch(rt: &Runtime, m: &Manifest, n: usize) -> Result<f64> {
    let t0 = std::time::Instant::now();
    for _ in 0..n {
        let res = run_inference(rt, m)?;
        std::hint::black_box(res.argmax);
    }
    Ok(t0.elapsed().as_secs_f64() / n.max(1) as f64)
}

/// CLI entry: load, program, run, verify against golden. Returns a summary.
pub fn run_manifest_inference(dir: &str, tiny: bool, sigma: f64) -> Result<String> {
    let m = Manifest::load(dir, tiny)?;
    let mut rt = Runtime::load(dir)?;
    program_network(&mut rt, &m, sigma)?;
    let res = run_inference(&rt, &m)?;

    let mut s = format!(
        "network {} ({} layers, {:.1} MMAC) — {} backend job calls, {} crossbar tiles programmed, {:.2}s wall\n",
        m.network_name,
        m.layers.len(),
        m.to_network().total_macs() as f64 / 1e6,
        res.backend_calls,
        res.programmed_tiles,
        res.wall.as_secs_f64()
    );
    if sigma == 0.0 {
        if !res.all_match() {
            bail!(
                "layer checksum divergence at `{}` — numeric contract broken\n{s}",
                res.first_divergent_layer().unwrap()
            );
        }
        if res.logits != m.golden_logits {
            bail!("logits differ from JAX golden ({s})");
        }
        s.push_str(&format!(
            "bit-exact vs JAX golden: all {} layer checksums match, argmax = {} (golden {})\n",
            res.checksums.len(),
            res.argmax,
            m.golden_argmax
        ));
    } else {
        // noise study: report logit divergence instead of asserting
        let l2: f64 = res
            .logits
            .iter()
            .zip(m.golden_logits.iter())
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        s.push_str(&format!(
            "conductance noise σ={sigma}: argmax {} (clean {}), logit L2 drift {:.1}\n",
            res.argmax, m.golden_argmax, l2
        ));
    }
    Ok(s)
}
