//! Manifest loading: the Python→Rust network contract.
//!
//! `artifacts/manifest.json` (written by aot.py) carries the layer list with
//! shapes, shifts, weight offsets and golden checksums; `weights.bin` the
//! int4 weights; `golden/` the input and logits. This module parses it into
//! the same `net::Network` the timing model uses, plus the runtime extras.

use crate::net::{Layer, LayerKind, Network};
use crate::util::error::{Context, Result};
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ManifestLayer {
    pub layer: Layer,
    pub weight_offset: usize,
    pub weight_len: usize,
    pub out_checksum: i64,
}

pub struct Manifest {
    pub network_name: String,
    pub seed: i64,
    pub layers: Vec<ManifestLayer>,
    pub weights: Vec<i8>,
    pub input_shape: (usize, usize, usize),
    pub input: Vec<i8>,
    pub golden_logits: Vec<i32>,
    pub golden_argmax: usize,
}

fn kind_of(s: &str) -> LayerKind {
    match s {
        "conv" => LayerKind::Conv,
        "dw" => LayerKind::Dw,
        "add" => LayerKind::Add,
        "pool" => LayerKind::Pool,
        "fc" => LayerKind::Fc,
        other => panic!("unknown layer kind `{other}` in manifest"),
    }
}

impl Manifest {
    /// `tiny = true` loads manifest_tiny.json (fast integration tests).
    pub fn load(dir: &str, tiny: bool) -> Result<Manifest> {
        let mpath = if tiny {
            format!("{dir}/manifest_tiny.json")
        } else {
            format!("{dir}/manifest.json")
        };
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {mpath} (run `make artifacts`)"))?;
        let j = Json::parse(&text).context("parsing manifest")?;

        let weights_file = j.req("weights_file").as_str().unwrap().to_string();
        let wbytes = std::fs::read(format!("{dir}/{weights_file}"))?;
        let weights: Vec<i8> = wbytes.iter().map(|&b| b as i8).collect();

        let mut layers = Vec::new();
        for lj in j.req("layers").as_arr().unwrap() {
            let kind = kind_of(lj.req("kind").as_str().unwrap());
            let layer = Layer {
                name: lj.req("name").as_str().unwrap().to_string(),
                kind,
                hin: lj.req("hin").as_usize().unwrap(),
                win: lj.req("win").as_usize().unwrap(),
                cin: lj.req("cin").as_usize().unwrap(),
                cout: lj.req("cout").as_usize().unwrap(),
                k: lj.req("k").as_usize().unwrap(),
                stride: lj.req("stride").as_usize().unwrap(),
                pad: lj.req("pad").as_usize().unwrap(),
                relu: lj.req("relu").as_i64().unwrap() != 0,
                residual_from: match lj.req("residual_from").as_i64().unwrap() {
                    -1 => None,
                    v => Some(v as usize),
                },
                shift: lj.req("shift").as_i64().unwrap() as i32,
            };
            // shape algebra cross-check: python hout/wout vs rust
            assert_eq!(
                layer.hout(),
                lj.req("hout").as_usize().unwrap(),
                "hout mismatch on {}",
                layer.name
            );
            assert_eq!(layer.macs(), lj.req("macs").as_i64().unwrap() as u64);
            layers.push(ManifestLayer {
                layer,
                weight_offset: lj.req("weight_offset").as_usize().unwrap(),
                weight_len: lj.req("weight_len").as_usize().unwrap(),
                out_checksum: lj.req("out_checksum").as_i64().unwrap(),
            });
        }

        let ishape = j.req("input").req("shape").as_arr().unwrap();
        let input_shape = (
            ishape[0].as_usize().unwrap(),
            ishape[1].as_usize().unwrap(),
            ishape[2].as_usize().unwrap(),
        );
        let input_file = j.req("input").req("file").as_str().unwrap();
        let ibytes = std::fs::read(format!("{dir}/{input_file}"))?;
        let input: Vec<i8> = ibytes.iter().map(|&b| b as i8).collect();

        let logits_file = j.req("logits").req("file").as_str().unwrap();
        let lbytes = std::fs::read(format!("{dir}/{logits_file}"))?;
        let golden_logits: Vec<i32> = lbytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        assert_eq!(
            golden_logits.len(),
            j.req("logits").req("len").as_usize().unwrap()
        );

        Ok(Manifest {
            network_name: j.req("network").as_str().unwrap().to_string(),
            seed: j.req("seed").as_i64().unwrap(),
            layers,
            weights,
            input_shape,
            input,
            golden_logits,
            golden_argmax: j.req("logits").req("argmax").as_usize().unwrap(),
        })
    }

    /// Weights of layer `idx` (serialized layout: crossbar [K²Cin, Cout]
    /// row-major for conv/fc, [3,3,C] for dw).
    pub fn layer_weights(&self, idx: usize) -> &[i8] {
        let ml = &self.layers[idx];
        &self.weights[ml.weight_offset..ml.weight_offset + ml.weight_len]
    }

    /// View as a plain `Network` (for cross-checks against the builder).
    pub fn to_network(&self) -> Network {
        Network {
            name: self.network_name.clone(),
            layers: self.layers.iter().map(|m| m.layer.clone()).collect(),
        }
    }
}
