//! Functional runtime: the numeric half of the coordinator.
//!
//! Performs end-to-end quantized inference by issuing exactly the job
//! stream the timing model accounts: crossbar MVM jobs in 16/128-pixel
//! chunks, depth-wise engine tiles, residual chunks. The host code plays
//! the cluster cores' role (im2col gather, int32 partial accumulation,
//! pooling); the per-job tensor math runs in [`client::Runtime`] — a native
//! integer backend implementing the AOT ABI's numeric contract (the
//! original PJRT/`xla` client is unavailable offline; see client.rs).
//! Python never runs here.
//!
//! Bit-exactness against the JAX golden vectors (same seed, same numeric
//! contract) is asserted per layer via checksums and on the final logits
//! whenever the artifacts are present (`make artifacts`); the contract
//! itself is property-tested artifact-free.

pub mod client;
pub mod functional;
pub mod golden;
pub mod manifest;
pub mod tensor;

pub use client::Runtime;
pub use manifest::Manifest;
