//! PJRT runtime: the functional half of the coordinator.
//!
//! Loads the AOT artifacts (`artifacts/*.hlo.txt`, HLO *text* — see
//! DESIGN.md / aot.py for why not serialized protos), compiles them once on
//! the PJRT CPU client, and performs end-to-end quantized inference by
//! issuing exactly the job stream the timing model accounts: crossbar MVM
//! jobs in 16-pixel chunks, depth-wise engine tiles, residual chunks. The
//! host code plays the cluster cores' role (im2col gather, int32 partial
//! accumulation, pooling); all tensor math runs inside PJRT executables.
//! Python never runs here.
//!
//! Bit-exactness against the JAX golden vectors (same seed, same numeric
//! contract) is asserted per layer via checksums and on the final logits.

pub mod client;
pub mod functional;
pub mod golden;
pub mod manifest;
pub mod tensor;

pub use client::Runtime;
pub use manifest::Manifest;
