//! Job-level execution backend: compile-once artifact registry + typed job
//! calls, implemented as a **native integer backend**.
//!
//! The original runtime compiled AOT-lowered Pallas kernels through the PJRT
//! C API (`xla` crate). That crate is unavailable in the offline build
//! environment, so this module implements the *same numeric contract*
//! (DESIGN.md §4) directly in Rust, behind the same API. The shapes are the
//! AOT ABI fixed in `python/compile/aot.py`:
//!
//!   imc_mvm      (x i8[16,256], w i8[256,256], shift i32[1], relu i32[1]) -> i8[16,256]
//!   imc_mvm_raw  (x i8[16,256], w i8[256,256])                            -> i32[16,256]
//!   requant      (acc i32[16,256], shift, relu)                           -> i8[16,256]
//!   residual     (a i8[4096], b i8[4096])                                 -> i8[4096]
//!   dw3x3_s1     (x i8[18,18,16], w i8[3,3,16], shift, relu)              -> i8[16,16,16]
//!   dw3x3_s2     (x i8[33,33,16], w i8[3,3,16], shift, relu)              -> i8[16,16,16]
//!   bottleneck   (x i8[16,16,128], w1, wd, w2, shifts i32[3])             -> i8[16,16,128]
//!
//! (each MVM/requant entry also exists as a 128-pixel `_b128` batch — the
//! batched path the multi-array scheduler issues).
//!
//! Weight tiles are programmed once per layer tile and cached — the
//! analogous operation to programming the PCM crossbar, which the paper also
//! performs once, off the inference path. The golden-vector integration
//! tests (vs the JAX reference, `make artifacts`) gate on artifact presence;
//! the contract itself is exercised artifact-free by `tests/prop_*.rs`.

use std::collections::HashMap;

use crate::util::error::Result;

pub const PIXELS: usize = 16;
pub const PIXELS_BATCH: usize = 128;
pub const XBAR: usize = 256;
pub const DW_TILE: usize = 16;
pub const DW_CB: usize = 16;
pub const RESIDUAL_CHUNK: usize = 4096;

/// The shared requantization rule: round-half-up shift, optional relu,
/// int8 clip. Must match `python/compile/qnn.py` and `tests/prop_*`.
#[inline]
pub fn requant_val(acc: i64, shift: i32, relu: bool) -> i8 {
    let mut v = if shift > 0 {
        (acc + (1i64 << (shift - 1))) >> shift
    } else {
        acc
    };
    if relu {
        v = v.max(0);
    }
    v.clamp(-128, 127) as i8
}

pub struct Runtime {
    /// Artifact directory the runtime was opened on (golden vectors and
    /// manifests resolve against it; the native backend itself needs none).
    pub artifacts_dir: String,
    /// Programmed weight tiles (the "PCM crossbars"), 256×256 each.
    weight_cache: HashMap<(usize, usize, usize), Vec<i8>>,
    /// Backend job calls issued (the request-path cost driver).
    pub calls: std::cell::Cell<u64>,
}

impl Runtime {
    /// Open the backend on an artifact directory. The native backend
    /// compiles nothing, so this always succeeds; golden files under `dir`
    /// are read lazily by the tests/examples that need them.
    pub fn load(dir: &str) -> Result<Runtime> {
        Ok(Runtime {
            artifacts_dir: dir.to_string(),
            weight_cache: HashMap::new(),
            calls: std::cell::Cell::new(0),
        })
    }

    /// Program a padded 256×256 weight tile once; later jobs reuse it
    /// (PCM programming happens once, §VI).
    pub fn program_weight_tile(
        &mut self,
        key: (usize, usize, usize),
        w_padded: &[i8],
    ) -> Result<()> {
        if self.weight_cache.contains_key(&key) {
            return Ok(());
        }
        assert_eq!(w_padded.len(), XBAR * XBAR);
        self.weight_cache.insert(key, w_padded.to_vec());
        Ok(())
    }

    pub fn programmed_tiles(&self) -> usize {
        self.weight_cache.len()
    }

    fn weights(&self, key: (usize, usize, usize)) -> Result<&[i8]> {
        match self.weight_cache.get(&key) {
            Some(w) => Ok(w),
            None => crate::bail!("weight tile {key:?} was never programmed"),
        }
    }

    fn check_pixels(&self, pixels: usize) -> Result<()> {
        if pixels != PIXELS && pixels != PIXELS_BATCH {
            crate::bail!("unsupported pixel batch {pixels}");
        }
        Ok(())
    }

    /// Raw int32 MVM partials of a pixel batch against a programmed tile —
    /// shared kernel of the fused and row-split paths.
    fn mvm_acc(&self, w: &[i8], x: &[i8], pixels: usize) -> Vec<i32> {
        assert_eq!(x.len(), pixels * XBAR);
        let mut acc = vec![0i32; pixels * XBAR];
        for p in 0..pixels {
            let xrow = &x[p * XBAR..(p + 1) * XBAR];
            let arow = &mut acc[p * XBAR..(p + 1) * XBAR];
            for (r, &xv) in xrow.iter().enumerate() {
                if xv == 0 {
                    continue;
                }
                let xv = xv as i32;
                let wrow = &w[r * XBAR..(r + 1) * XBAR];
                for (a, &wv) in arow.iter_mut().zip(wrow.iter()) {
                    *a += xv * wv as i32;
                }
            }
        }
        acc
    }

    /// Fused-ADC crossbar job batch against a programmed tile.
    /// `x` is [pixels, 256] i8 with pixels = 16 or 128 (the batched variant
    /// amortizes the per-call overhead on large layers — §Perf).
    pub fn mvm(
        &self,
        key: (usize, usize, usize),
        x: &[i8],
        shift: i32,
        relu: bool,
        pixels: usize,
    ) -> Result<Vec<i8>> {
        self.check_pixels(pixels)?;
        self.calls.set(self.calls.get() + 1);
        let w = self.weights(key)?;
        let acc = self.mvm_acc(w, x, pixels);
        Ok(acc
            .iter()
            .map(|&a| requant_val(a as i64, shift, relu))
            .collect())
    }

    /// Raw-partial crossbar job batch (row-split layers): int32 out.
    pub fn mvm_raw(
        &self,
        key: (usize, usize, usize),
        x: &[i8],
        pixels: usize,
    ) -> Result<Vec<i32>> {
        self.check_pixels(pixels)?;
        self.calls.set(self.calls.get() + 1);
        let w = self.weights(key)?;
        Ok(self.mvm_acc(w, x, pixels))
    }

    /// Digital requantization of accumulated partials.
    pub fn requant(&self, acc: &[i32], shift: i32, relu: bool, pixels: usize) -> Result<Vec<i8>> {
        self.check_pixels(pixels)?;
        assert_eq!(acc.len(), pixels * XBAR);
        self.calls.set(self.calls.get() + 1);
        Ok(acc
            .iter()
            .map(|&a| requant_val(a as i64, shift, relu))
            .collect())
    }

    /// One depth-wise engine tile (stride 1 or 2): 16×16 output pixels of a
    /// 16-channel block. `x` is [side, side, 16] HWC, `w` is [3, 3, 16].
    pub fn dw_tile(
        &self,
        x: &[i8],
        w: &[i8],
        shift: i32,
        relu: bool,
        stride: usize,
    ) -> Result<Vec<i8>> {
        let side = match stride {
            1 => DW_TILE + 2,
            2 => 2 * DW_TILE + 1,
            s => crate::bail!("dw stride {s} unsupported by the engine"),
        };
        assert_eq!(x.len(), side * side * DW_CB);
        assert_eq!(w.len(), 9 * DW_CB);
        self.calls.set(self.calls.get() + 1);
        let mut out = vec![0i8; DW_TILE * DW_TILE * DW_CB];
        for ty in 0..DW_TILE {
            for tx in 0..DW_TILE {
                for ch in 0..DW_CB {
                    let mut acc: i64 = 0;
                    for ki in 0..3 {
                        for kj in 0..3 {
                            let sy = ty * stride + ki;
                            let sx = tx * stride + kj;
                            acc += x[(sy * side + sx) * DW_CB + ch] as i64
                                * w[(ki * 3 + kj) * DW_CB + ch] as i64;
                        }
                    }
                    out[(ty * DW_TILE + tx) * DW_CB + ch] = requant_val(acc, shift, relu);
                }
            }
        }
        Ok(out)
    }

    /// One saturating int8 residual chunk.
    pub fn residual(&self, a: &[i8], b: &[i8]) -> Result<Vec<i8>> {
        assert_eq!(a.len(), RESIDUAL_CHUNK);
        assert_eq!(b.len(), RESIDUAL_CHUNK);
        self.calls.set(self.calls.get() + 1);
        Ok(a.iter()
            .zip(b.iter())
            .map(|(&x, &y)| x.saturating_add(y))
            .collect())
    }

    /// The fused L2 Bottleneck artifact (16×16×128 case study):
    /// pw-expand (relu) → 3×3 dw s1 (relu) → pw-project → saturating
    /// residual with the block input. `shifts` requantize the three layers.
    pub fn bottleneck(
        &self,
        x: &[i8],
        w1: &[i8],
        wd: &[i8],
        w2: &[i8],
        shifts: &[i32; 3],
    ) -> Result<Vec<i8>> {
        const HW: usize = 16;
        const C: usize = 128;
        const HID: usize = 768;
        assert_eq!(x.len(), HW * HW * C);
        assert_eq!(w1.len(), C * HID);
        assert_eq!(wd.len(), 9 * HID);
        assert_eq!(w2.len(), HID * C);
        self.calls.set(self.calls.get() + 1);

        // pw expand: [256 px, 128] · [128, 768] → relu i8
        let mut y1 = vec![0i8; HW * HW * HID];
        for p in 0..HW * HW {
            for c in 0..HID {
                let mut acc: i64 = 0;
                for r in 0..C {
                    acc += x[p * C + r] as i64 * w1[r * HID + c] as i64;
                }
                y1[p * HID + c] = requant_val(acc, shifts[0], true);
            }
        }

        // dw 3×3 stride 1 pad 1, relu
        let mut yd = vec![0i8; HW * HW * HID];
        for oy in 0..HW {
            for ox in 0..HW {
                for c in 0..HID {
                    let mut acc: i64 = 0;
                    for ki in 0..3usize {
                        for kj in 0..3usize {
                            let sy = oy as isize + ki as isize - 1;
                            let sx = ox as isize + kj as isize - 1;
                            if sy < 0 || sx < 0 || sy >= HW as isize || sx >= HW as isize {
                                continue;
                            }
                            acc += y1[(sy as usize * HW + sx as usize) * HID + c] as i64
                                * wd[(ki * 3 + kj) * HID + c] as i64;
                        }
                    }
                    yd[(oy * HW + ox) * HID + c] = requant_val(acc, shifts[1], true);
                }
            }
        }

        // pw project (no relu) + saturating residual with the input
        let mut out = vec![0i8; HW * HW * C];
        for p in 0..HW * HW {
            for c in 0..C {
                let mut acc: i64 = 0;
                for r in 0..HID {
                    acc += yd[p * HID + r] as i64 * w2[r * C + c] as i64;
                }
                let v = requant_val(acc, shifts[2], false);
                out[p * C + c] = v.saturating_add(x[p * C + c]);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn requant_contract() {
        assert_eq!(requant_val(1000, 3, false), 125); // (1000 + 4) >> 3
        assert_eq!(requant_val(-1000, 3, false), -125);
        assert_eq!(requant_val(100_000, 3, false), 127);
        assert_eq!(requant_val(-100_000, 3, false), -128);
        assert_eq!(requant_val(-1000, 3, true), 0);
        assert_eq!(requant_val(-5, 0, false), -5); // shift 0 passes through
    }

    #[test]
    fn identity_tile_mvm_roundtrips() {
        let mut rt = Runtime::load("unused").unwrap();
        let mut w = vec![0i8; XBAR * XBAR];
        for i in 0..XBAR {
            w[i * XBAR + i] = 1;
        }
        rt.program_weight_tile((0, 0, 0), &w).unwrap();
        let mut x = vec![0i8; PIXELS * XBAR];
        for (i, v) in x.iter_mut().enumerate() {
            *v = ((i * 7) % 251) as i8;
        }
        let y = rt.mvm((0, 0, 0), &x, 0, false, PIXELS).unwrap();
        assert_eq!(y, x);
        let r = rt.mvm_raw((0, 0, 0), &x, PIXELS).unwrap();
        assert!(r.iter().zip(x.iter()).all(|(a, b)| *a == *b as i32));
        assert_eq!(rt.calls.get(), 2);
    }

    #[test]
    fn unprogrammed_tile_is_an_error() {
        let rt = Runtime::load("unused").unwrap();
        let x = vec![0i8; PIXELS * XBAR];
        assert!(rt.mvm((1, 2, 3), &x, 0, false, PIXELS).is_err());
    }

    #[test]
    fn unsupported_batch_is_an_error() {
        let mut rt = Runtime::load("unused").unwrap();
        rt.program_weight_tile((0, 0, 0), &vec![0i8; XBAR * XBAR])
            .unwrap();
        let x = vec![0i8; 32 * XBAR];
        assert!(rt.mvm((0, 0, 0), &x, 0, false, 32).is_err());
    }

    #[test]
    fn residual_saturates() {
        let rt = Runtime::load("unused").unwrap();
        let a = vec![100i8; RESIDUAL_CHUNK];
        let b = vec![100i8; RESIDUAL_CHUNK];
        let y = rt.residual(&a, &b).unwrap();
        assert!(y.iter().all(|&v| v == 127));
    }
}
