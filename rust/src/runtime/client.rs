//! PJRT client wrapper: compile-once artifact registry + typed job calls.
//!
//! Shapes are the AOT ABI fixed in `python/compile/aot.py`:
//!   imc_mvm      (x i8[16,256], w i8[256,256], shift i32[1], relu i32[1]) -> i8[16,256]
//!   imc_mvm_raw  (x i8[16,256], w i8[256,256])                            -> i32[16,256]
//!   requant      (acc i32[16,256], shift, relu)                           -> i8[16,256]
//!   residual     (a i8[4096], b i8[4096])                                 -> i8[4096]
//!   dw3x3_s1     (x i8[18,18,16], w i8[3,3,16], shift, relu)              -> i8[16,16,16]
//!   dw3x3_s2     (x i8[33,33,16], w i8[3,3,16], shift, relu)              -> i8[16,16,16]
//!   bottleneck   (x i8[16,16,128], w1, wd, w2, shifts i32[3])             -> i8[16,16,128]
//!
//! Weight tiles are serialized once per layer tile and cached as literals —
//! the analogous operation to programming the PCM crossbar, which the paper
//! also performs once, off the inference path.

use std::collections::HashMap;

use anyhow::{Context, Result};
use xla::{ElementType, Literal, PjRtClient, PjRtLoadedExecutable};

pub const PIXELS: usize = 16;
pub const PIXELS_BATCH: usize = 128;
pub const XBAR: usize = 256;
pub const DW_TILE: usize = 16;
pub const DW_CB: usize = 16;
pub const RESIDUAL_CHUNK: usize = 4096;

pub struct Runtime {
    pub client: PjRtClient,
    exes: HashMap<&'static str, PjRtLoadedExecutable>,
    /// Cached weight literals (the "programmed crossbars"). Kept as host
    /// literals: the tfrt CPU client rejects re-used device buffers in
    /// `execute_b` (it donates inputs), so jobs go through `execute` and
    /// the weight transfer cost stays on the PJRT side of the fence.
    weight_cache: HashMap<(usize, usize, usize), Literal>,
    pub calls: std::cell::Cell<u64>,
}

fn lit_i8(dims: &[usize], data: &[i8]) -> Result<Literal> {
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len()) };
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::S8,
        dims,
        bytes,
    )?)
}

fn lit_i32(dims: &[usize], data: &[i32]) -> Result<Literal> {
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::S32,
        dims,
        bytes,
    )?)
}

impl Runtime {
    /// Load and compile every artifact in `dir`.
    pub fn load(dir: &str) -> Result<Runtime> {
        let client = PjRtClient::cpu().context("PJRT CPU client")?;
        let mut exes = HashMap::new();
        for name in [
            "imc_mvm",
            "imc_mvm_raw",
            "imc_mvm_b128",
            "imc_mvm_raw_b128",
            "requant",
            "requant_b128",
            "residual",
            "dw3x3_s1",
            "dw3x3_s2",
            "bottleneck",
        ] {
            let path = format!("{dir}/{name}.hlo.txt");
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("loading {path} (run `make artifacts`)"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            exes.insert(name, exe);
        }
        Ok(Runtime {
            client,
            exes,
            weight_cache: HashMap::new(),
            calls: std::cell::Cell::new(0),
        })
    }

    fn exe(&self, name: &str) -> &PjRtLoadedExecutable {
        &self.exes[name]
    }

    fn run1(&self, name: &str, args: &[Literal]) -> Result<Literal> {
        self.calls.set(self.calls.get() + 1);
        let result = self.exe(name).execute::<Literal>(args)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple1()?)
    }

    /// Upload a padded 256×256 weight tile once; later calls reuse the
    /// device buffer (PCM programming happens once, §VI).
    pub fn program_weight_tile(
        &mut self,
        key: (usize, usize, usize),
        w_padded: &[i8],
    ) -> Result<()> {
        if self.weight_cache.contains_key(&key) {
            return Ok(());
        }
        assert_eq!(w_padded.len(), XBAR * XBAR);
        let lit = lit_i8(&[XBAR, XBAR], w_padded)?;
        self.weight_cache.insert(key, lit);
        Ok(())
    }

    pub fn programmed_tiles(&self) -> usize {
        self.weight_cache.len()
    }

    fn run1_with_weights(
        &self,
        name: &str,
        key: (usize, usize, usize),
        others: Vec<Literal>,
        w_pos: usize,
    ) -> Result<Literal> {
        self.calls.set(self.calls.get() + 1);
        let w = &self.weight_cache[&key];
        let mut ordered: Vec<&Literal> = Vec::with_capacity(others.len() + 1);
        for (i, lit) in others.iter().enumerate() {
            if i == w_pos {
                ordered.push(w);
            }
            ordered.push(lit);
        }
        if w_pos >= others.len() {
            ordered.push(w);
        }
        let out = self.exe(name).execute::<&Literal>(&ordered)?[0][0].to_literal_sync()?;
        Ok(out.to_tuple1()?)
    }

    /// Fused-ADC crossbar job batch against a programmed tile.
    /// `x` is [pixels, 256] i8 with pixels = 16 or 128 (the batched variant
    /// amortizes the per-call overhead on large layers — §Perf).
    pub fn mvm(
        &self,
        key: (usize, usize, usize),
        x: &[i8],
        shift: i32,
        relu: bool,
        pixels: usize,
    ) -> Result<Vec<i8>> {
        let name = match pixels {
            PIXELS => "imc_mvm",
            PIXELS_BATCH => "imc_mvm_b128",
            p => anyhow::bail!("unsupported pixel batch {p}"),
        };
        let args = vec![
            lit_i8(&[pixels, XBAR], x)?,
            lit_i32(&[1], &[shift])?,
            lit_i32(&[1], &[relu as i32])?,
        ];
        let out = self.run1_with_weights(name, key, args, 1)?;
        Ok(out.to_vec::<i8>()?)
    }

    /// Raw-partial crossbar job batch (row-split layers): int32 out.
    pub fn mvm_raw(
        &self,
        key: (usize, usize, usize),
        x: &[i8],
        pixels: usize,
    ) -> Result<Vec<i32>> {
        let name = match pixels {
            PIXELS => "imc_mvm_raw",
            PIXELS_BATCH => "imc_mvm_raw_b128",
            p => anyhow::bail!("unsupported pixel batch {p}"),
        };
        let args = vec![lit_i8(&[pixels, XBAR], x)?];
        let out = self.run1_with_weights(name, key, args, 1)?;
        Ok(out.to_vec::<i32>()?)
    }

    /// Digital requantization of accumulated partials.
    pub fn requant(&self, acc: &[i32], shift: i32, relu: bool, pixels: usize) -> Result<Vec<i8>> {
        let name = match pixels {
            PIXELS => "requant",
            PIXELS_BATCH => "requant_b128",
            p => anyhow::bail!("unsupported pixel batch {p}"),
        };
        let out = self.run1(
            name,
            &[
                lit_i32(&[pixels, XBAR], acc)?,
                lit_i32(&[1], &[shift])?,
                lit_i32(&[1], &[relu as i32])?,
            ],
        )?;
        Ok(out.to_vec::<i8>()?)
    }

    /// One depth-wise engine tile (stride 1 or 2).
    pub fn dw_tile(
        &self,
        x: &[i8],
        w: &[i8],
        shift: i32,
        relu: bool,
        stride: usize,
    ) -> Result<Vec<i8>> {
        let (name, side) = match stride {
            1 => ("dw3x3_s1", DW_TILE + 2),
            2 => ("dw3x3_s2", 2 * DW_TILE + 1),
            s => anyhow::bail!("dw stride {s} unsupported by the engine"),
        };
        assert_eq!(x.len(), side * side * DW_CB);
        let out = self.run1(
            name,
            &[
                lit_i8(&[side, side, DW_CB], x)?,
                lit_i8(&[3, 3, DW_CB], w)?,
                lit_i32(&[1], &[shift])?,
                lit_i32(&[1], &[relu as i32])?,
            ],
        )?;
        Ok(out.to_vec::<i8>()?)
    }

    /// One residual chunk.
    pub fn residual(&self, a: &[i8], b: &[i8]) -> Result<Vec<i8>> {
        assert_eq!(a.len(), RESIDUAL_CHUNK);
        let out = self.run1(
            "residual",
            &[lit_i8(&[RESIDUAL_CHUNK], a)?, lit_i8(&[RESIDUAL_CHUNK], b)?],
        )?;
        Ok(out.to_vec::<i8>()?)
    }

    /// The fused L2 Bottleneck artifact (16×16×128 case study).
    pub fn bottleneck(
        &self,
        x: &[i8],
        w1: &[i8],
        wd: &[i8],
        w2: &[i8],
        shifts: &[i32; 3],
    ) -> Result<Vec<i8>> {
        let out = self.run1(
            "bottleneck",
            &[
                lit_i8(&[16, 16, 128], x)?,
                lit_i8(&[128, 768], w1)?,
                lit_i8(&[3, 3, 768], wd)?,
                lit_i8(&[768, 128], w2)?,
                lit_i32(&[3], shifts)?,
            ],
        )?;
        Ok(out.to_vec::<i8>()?)
    }
}
