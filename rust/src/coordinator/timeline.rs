//! Per-resource busy timelines: the contention vocabulary shared by the
//! batch scheduler and the serving arbiter.
//!
//! PR 2's serving loop modeled the whole pool as one opaque server — a
//! dispatched batch held "the pool" for its full makespan, so two tenants
//! on *disjoint* array slices could never overlap, and a staged tenant's
//! PCM reprogramming stalled everyone. This module replaces that scalar
//! clock with explicit resources:
//!
//! * the 8-core complex ([`RES_CORES`]),
//! * the depth-wise accelerator ([`RES_DWACC`]),
//! * the shared IMA mux that serializes IMA jobs without a pool placement
//!   ([`RES_IMA_MUX`]),
//! * the L2/DMA port that carries staged cut-boundary activations
//!   ([`RES_DMA`]),
//! * the PCM program-and-verify port that serializes all reprogramming
//!   ([`RES_PROG`]),
//! * and every crossbar array as its own resource ([`RES_ARRAY0`]` + i`).
//!
//! [`run_batched`](super::scheduler::run_batched) already schedules over
//! these resources internally; what it now *emits* is a
//! [`ReservationProfile`] — for each resource the batch touches, the
//! offsets (relative to batch start) of its first occupancy and final
//! release, plus the cycles actually held. The serving loop keeps one
//! [`ResourceTimeline`] of scalar next-free times over the whole pool and
//! dispatches a tenant's batch at the earliest instant every required
//! resource is free — so tenants on disjoint slices genuinely overlap
//! while contended shared resources (cores, DW accelerator, mux, DMA)
//! still serialize correctly.
//!
//! The envelope model is deliberately conservative: within a batch a
//! resource is considered held from its first use to its last release, so
//! a later batch may not backfill into idle gaps of an earlier batch's
//! envelope. That keeps the timeline a scalar per resource (exact event
//! jumps, no interval sets) and makes overlap claims safe: the reported
//! makespan is an upper bound on what a cleverer arbiter could do, and is
//! still strictly below the serialized sum whenever envelopes are
//! disjoint.

use std::collections::BTreeMap;

/// The RISC-V core complex (one shared resource).
pub const RES_CORES: usize = 0;
/// The depth-wise accelerator.
pub const RES_DWACC: usize = 1;
/// Shared IMA mux: serializes IMA jobs that have no pool placement.
pub const RES_IMA_MUX: usize = 2;
/// The cluster L2/DMA port (staged cut-boundary spills/refills).
pub const RES_DMA: usize = 3;
/// The PCM program-and-verify port: all reprogramming — within a batch
/// and across tenants — serializes here.
pub const RES_PROG: usize = 4;
/// First crossbar array; array `i` is resource `RES_ARRAY0 + i`.
pub const RES_ARRAY0: usize = 5;

/// Human-readable name of a resource id (pool-absolute array indices).
pub fn res_label(res: usize) -> String {
    match res {
        RES_CORES => "cores".into(),
        RES_DWACC => "dw_acc".into(),
        RES_IMA_MUX => "ima_mux".into(),
        RES_DMA => "dma".into(),
        RES_PROG => "pcm_prog".into(),
        a => format!("array{}", a - RES_ARRAY0),
    }
}

/// One resource's envelope within a scheduled batch. All offsets are
/// cycles relative to the batch's start instant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ResourceSpan {
    /// Resource id (`RES_*`; arrays are plan-local, i.e. relative to the
    /// tenant's slice base).
    pub res: usize,
    /// Offset of the first cycle the batch occupies this resource.
    pub first_use: u64,
    /// Offset of the cycle the batch finally releases this resource.
    pub last_release: u64,
    /// Cycles the resource is actually held (≤ `last_release - first_use`).
    pub busy: u64,
}

/// The per-resource reservation profile of one scheduled batch: which
/// resources it needs, when (relative to its start), and for how long.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ReservationProfile {
    /// Spans sorted by resource id (one entry per touched resource).
    pub spans: Vec<ResourceSpan>,
    /// Batch makespan: the offset at which the whole batch has drained.
    pub len: u64,
}

impl ReservationProfile {
    /// The span for `res`, if the batch touches it.
    pub fn span(&self, res: usize) -> Option<&ResourceSpan> {
        self.spans.iter().find(|s| s.res == res)
    }

    /// Total busy cycles across all resources (for conservation checks:
    /// every span's `busy` must fit inside the batch makespan).
    pub fn total_busy(&self) -> u64 {
        self.spans.iter().map(|s| s.busy).sum()
    }
}

/// Accumulates per-resource occupancy while a schedule is being built,
/// then freezes into a [`ReservationProfile`].
#[derive(Debug, Default)]
pub struct ProfileBuilder {
    /// res → (first_use, last_release, busy)
    spans: BTreeMap<usize, (u64, u64, u64)>,
}

impl ProfileBuilder {
    pub fn new() -> ProfileBuilder {
        ProfileBuilder::default()
    }

    /// Record that `res` is held over `[start, finish)`.
    pub fn occupy(&mut self, res: usize, start: u64, finish: u64) {
        debug_assert!(finish >= start);
        let e = self.spans.entry(res).or_insert((start, finish, 0));
        e.0 = e.0.min(start);
        e.1 = e.1.max(finish);
        e.2 += finish - start;
    }

    /// Freeze into a profile with batch makespan `len`.
    pub fn build(self, len: u64) -> ReservationProfile {
        ReservationProfile {
            spans: self
                .spans
                .into_iter()
                .map(|(res, (first_use, last_release, busy))| ResourceSpan {
                    res,
                    first_use,
                    last_release,
                    busy,
                })
                .collect(),
            len,
        }
    }
}

/// Scalar next-free times over every resource of one pool, plus cumulative
/// busy cycles for the utilization breakdown. Array ids are pool-absolute;
/// profiles carry slice-local array ids, so every operation takes the
/// tenant's `array_base` and relocates `RES_ARRAY0 + a` to
/// `RES_ARRAY0 + array_base + a` (shared resources map to themselves).
#[derive(Clone, Debug, Default)]
pub struct ResourceTimeline {
    free: BTreeMap<usize, u64>,
    busy: BTreeMap<usize, u64>,
}

impl ResourceTimeline {
    pub fn new() -> ResourceTimeline {
        ResourceTimeline::default()
    }

    fn map_res(res: usize, array_base: usize) -> usize {
        if res >= RES_ARRAY0 {
            res + array_base
        } else {
            res
        }
    }

    /// When `res` (pool-absolute) next becomes free.
    pub fn free_at(&self, res: usize) -> u64 {
        *self.free.get(&res).unwrap_or(&0)
    }

    /// Cycles `res` (pool-absolute) has been held so far.
    pub fn busy_cycles(&self, res: usize) -> u64 {
        *self.busy.get(&res).unwrap_or(&0)
    }

    /// Cumulative busy cycles per pool-absolute resource id.
    pub fn busy_map(&self) -> &BTreeMap<usize, u64> {
        &self.busy
    }

    /// Earliest instant ≥ `not_before` at which a batch with this profile
    /// can start: every resource it needs must be free by the offset the
    /// batch first touches it.
    pub fn earliest_start(
        &self,
        prof: &ReservationProfile,
        array_base: usize,
        not_before: u64,
    ) -> u64 {
        let mut t = not_before;
        for s in &prof.spans {
            let free = self.free_at(Self::map_res(s.res, array_base));
            t = t.max(free.saturating_sub(s.first_use));
        }
        t
    }

    /// Commit a batch dispatched at `t`: push each touched resource's
    /// next-free time to the batch's release offset and accumulate busy
    /// cycles. Callers must have chosen `t ≥ earliest_start(..)`.
    pub fn commit(&mut self, t: u64, prof: &ReservationProfile, array_base: usize) {
        for s in &prof.spans {
            let res = Self::map_res(s.res, array_base);
            let release = t + s.last_release;
            let e = self.free.entry(res).or_insert(0);
            *e = (*e).max(release);
            *self.busy.entry(res).or_insert(0) += s.busy;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prof(spans: &[(usize, u64, u64, u64)], len: u64) -> ReservationProfile {
        ReservationProfile {
            spans: spans
                .iter()
                .map(|&(res, first_use, last_release, busy)| ResourceSpan {
                    res,
                    first_use,
                    last_release,
                    busy,
                })
                .collect(),
            len,
        }
    }

    #[test]
    fn disjoint_profiles_overlap_fully() {
        let mut tl = ResourceTimeline::new();
        let a = prof(&[(RES_ARRAY0, 0, 100, 100)], 100);
        let b = prof(&[(RES_ARRAY0 + 1, 0, 80, 80)], 80);
        let ta = tl.earliest_start(&a, 0, 0);
        tl.commit(ta, &a, 0);
        let tb = tl.earliest_start(&b, 0, 0);
        assert_eq!((ta, tb), (0, 0), "disjoint resources must not serialize");
        tl.commit(tb, &b, 0);
        assert_eq!(tl.free_at(RES_ARRAY0), 100);
        assert_eq!(tl.free_at(RES_ARRAY0 + 1), 80);
    }

    #[test]
    fn shared_resource_serializes_on_its_span_only() {
        let mut tl = ResourceTimeline::new();
        // batch A holds cores over [90, 100) of a 100-cycle batch
        let a = prof(&[(RES_ARRAY0, 0, 100, 100), (RES_CORES, 90, 100, 10)], 100);
        // batch B needs cores at offset 50 of an 80-cycle batch
        let b = prof(&[(RES_ARRAY0 + 1, 0, 80, 80), (RES_CORES, 50, 60, 10)], 80);
        tl.commit(0, &a, 0);
        // B may start at 50: its cores use (offset 50) then lands at 100
        assert_eq!(tl.earliest_start(&b, 0, 0), 50);
    }

    #[test]
    fn array_base_relocates_slices() {
        let mut tl = ResourceTimeline::new();
        let p = prof(&[(RES_ARRAY0, 0, 10, 10)], 10);
        tl.commit(0, &p, 0);
        // same plan-local array in a slice based at 4 is a different
        // physical array — no contention
        assert_eq!(tl.earliest_start(&p, 4, 0), 0);
        tl.commit(0, &p, 4);
        assert_eq!(tl.free_at(RES_ARRAY0 + 4), 10);
        // but the same slice contends with itself
        assert_eq!(tl.earliest_start(&p, 0, 0), 10);
    }

    #[test]
    fn earliest_start_respects_not_before_and_first_use() {
        let mut tl = ResourceTimeline::new();
        let a = prof(&[(RES_DWACC, 0, 40, 40)], 40);
        tl.commit(0, &a, 0);
        // a batch that first touches the DW accelerator at offset 30 may
        // start at 10 (so its use begins exactly at 40)
        let b = prof(&[(RES_DWACC, 30, 50, 20)], 60);
        assert_eq!(tl.earliest_start(&b, 0, 0), 10);
        assert_eq!(tl.earliest_start(&b, 0, 25), 25);
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(res_label(RES_CORES), "cores");
        assert_eq!(res_label(RES_DWACC), "dw_acc");
        assert_eq!(res_label(RES_IMA_MUX), "ima_mux");
        assert_eq!(res_label(RES_DMA), "dma");
        assert_eq!(res_label(RES_PROG), "pcm_prog");
        assert_eq!(res_label(RES_ARRAY0 + 7), "array7");
    }

    #[test]
    fn builder_merges_occupancy_into_envelopes() {
        let mut b = ProfileBuilder::new();
        b.occupy(RES_CORES, 10, 20);
        b.occupy(RES_CORES, 40, 45);
        b.occupy(RES_ARRAY0 + 2, 0, 5);
        let p = b.build(50);
        assert_eq!(p.len, 50);
        let c = p.span(RES_CORES).unwrap();
        assert_eq!((c.first_use, c.last_release, c.busy), (10, 45, 15));
        let a = p.span(RES_ARRAY0 + 2).unwrap();
        assert_eq!((a.first_use, a.last_release, a.busy), (0, 5, 5));
        assert_eq!(p.total_busy(), 20);
    }
}
