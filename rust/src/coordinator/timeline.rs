//! Per-resource busy **interval timelines**: the contention vocabulary
//! shared by the batch scheduler and the serving arbiter.
//!
//! PR 2's serving loop modeled the whole pool as one opaque server; PR 3
//! replaced that with explicit resources, but reserved each batch as one
//! conservative busy *envelope* per resource (first use → last release),
//! so a later batch could never slot into an earlier batch's idle gaps.
//! This module keeps the full story: every resource's occupancy is a
//! sorted, merged set of `[start, end)` busy intervals ([`IntervalSet`]),
//! and the pool timeline can *backfill* — place a batch into the idle
//! gaps of already-committed batches whenever every busy interval of its
//! profile fits.
//!
//! The resources:
//!
//! * each RISC-V core of the 8-core complex ([`RES_CORE0`]` + c`) — a
//!   core-mapped layer occupies the prefix `core0..coresₖ` its parallel
//!   section engages, so small ancillary layers of different tenants can
//!   share the complex (the serving arbiter rotates each tenant's core
//!   affinity, see [`ResMap`]);
//! * the depth-wise accelerator ([`RES_DWACC`]);
//! * the shared IMA mux that serializes IMA jobs without a pool placement
//!   ([`RES_IMA_MUX`]);
//! * the L2/DMA port that carries staged cut-boundary activations
//!   ([`RES_DMA`]);
//! * the PCM program-and-verify port that serializes all reprogramming
//!   ([`RES_PROG`]);
//! * and every crossbar array as its own resource ([`RES_ARRAY0`]` + i`).
//!
//! [`run_batched`](super::scheduler::run_batched) emits a
//! [`ReservationProfile`]: for each resource the batch touches, the merged
//! busy intervals (offsets relative to batch start) plus the envelope
//! summary (`first_use`/`last_release`/`busy`). The serving loop keeps one
//! [`ResourceTimeline`] over the whole pool and dispatches a tenant's
//! batch at the earliest instant its profile fits:
//!
//! * in **backfill** mode ([`ResourceTimeline::backfilling`]) the search
//!   is an interval intersection — a batch may start while an earlier
//!   batch is still draining, as long as none of their busy intervals on
//!   any shared resource overlap;
//! * in **envelope** mode ([`ResourceTimeline::envelope`]) the search
//!   reproduces the PR 3 scalar next-free-time model bit-identically
//!   (`--no-backfill` in the serving CLI): each resource is considered
//!   held from its first use to its last release, which makes the
//!   reported makespan an upper bound on what the backfilling arbiter
//!   achieves — the conservation the regression and property suites pin
//!   (`tests/backfill_regression.rs`, `tests/prop_backfill.rs`).
//!
//! Long-horizon hygiene: committed intervals that end at or before a
//! **watermark** — the oldest instant any future dispatch could probe
//! (the serving loop threads the minimum over its tenants' next
//! admission instants) — can be folded away with
//! [`ResourceTimeline::prune_before`], bounding the gap search to the
//! live window. Pruning is invisible to dispatch decisions: every future
//! probe `[t+a, t+b)` has `t ≥ watermark`, so a pruned interval could
//! never have conflicted again (`tests/prop_prune.rs` and the CI pruning
//! smoke pin bit-identity against `--no-prune`). The cumulative busy
//! tallies and scalar next-free frontiers survive pruning, so the
//! utilization breakdown is unchanged. Storage is dense *and
//! struct-of-arrays*: each [`IntervalSet`] keeps its interval starts and
//! ends in two parallel `u64` vectors, so the conflict probe's binary
//! search walks one contiguous `ends[]` array (half the bytes of the
//! old `(start, end)` pair layout) and per-resource state lives in
//! `Vec`s indexed by the pool-absolute resource id. [`TimelineStats`]
//! counts the search work deterministically (binary-search halving
//! steps, live/pruned interval nodes) so perf regressions pin on
//! counters instead of wall clock.
//!
//! **Gap-skip fast paths** (the long-horizon dispatch accelerator,
//! [`ResourceTimeline::set_gap_skip`], `--no-gap-skip` in the serving
//! CLI): the backfill search carries two O(1) short-circuits per span
//! interval, both *exact* — they change how much work the search does
//! (the `probes` counter), never where a batch lands:
//!
//! * **append-at-tail** — a probe starting at or past the resource's
//!   last committed release (`t + a ≥ set.end()`) cannot conflict, so
//!   the binary search is skipped outright. This is the common case of
//!   steady-state serving, where each tenant's next batch lands after
//!   its previous one.
//! * **no-usable-gap** — every [`IntervalSet`] maintains an upper bound
//!   on its largest *internal* idle gap ([`IntervalSet::max_internal_gap`],
//!   monotone under inserts, conservative under pruning). A probe
//!   interval strictly longer than that bound which overlaps the
//!   committed window (`t + a < set.end()` and `t + b > set.start()`)
//!   provably conflicts and provably fits no committed gap, so the
//!   search jumps straight to the append-at-tail placement
//!   (`t = set.end() - a`) instead of crawling conflict by conflict.
//!   The invariant: a conflict-free placement inside `[start, end)`
//!   would have to sit wholly inside one internal gap, whose width the
//!   bound dominates — contradiction — and any candidate before the
//!   jump target satisfies the same three conditions, so no feasible
//!   start is skipped.
//!
//! With the fast paths off the search reproduces the PR 5 probe
//! accounting exactly; dispatch decisions are bit-identical either way
//! (pinned by `tests/prop_evq.rs` and the timeline unit suite), and the
//! win is expressed purely in the deterministic `probes` counter.

use std::cell::Cell;
use std::collections::BTreeMap;

/// Cores in the complex; core `c` is resource `RES_CORE0 + c`.
pub const N_CORES: usize = 8;
/// First per-core resource (the complex is eight resources, not one).
pub const RES_CORE0: usize = 0;
/// The depth-wise accelerator.
pub const RES_DWACC: usize = 8;
/// Shared IMA mux: serializes IMA jobs that have no pool placement.
pub const RES_IMA_MUX: usize = 9;
/// The cluster L2/DMA port (staged cut-boundary spills/refills).
pub const RES_DMA: usize = 10;
/// The PCM program-and-verify port: all reprogramming — within a batch
/// and across tenants — serializes here.
pub const RES_PROG: usize = 11;
/// First crossbar array; array `i` is resource `RES_ARRAY0 + i`.
pub const RES_ARRAY0: usize = 12;

/// Human-readable name of a resource id (pool-absolute array indices).
pub fn res_label(res: usize) -> String {
    match res {
        c if c < N_CORES => format!("core{c}"),
        RES_DWACC => "dw_acc".into(),
        RES_IMA_MUX => "ima_mux".into(),
        RES_DMA => "dma".into(),
        RES_PROG => "pcm_prog".into(),
        a => format!("array{}", a - RES_ARRAY0),
    }
}

/// A sorted, merged, non-adjacent set of `[start, end)` busy intervals —
/// the canonical representation every profile span and committed timeline
/// carries. Inserting an interval merges it with any overlapping or
/// adjacent neighbors, so the invariants (sorted, pairwise disjoint,
/// non-adjacent, non-empty) hold by construction.
///
/// Storage is struct-of-arrays: `starts[]` and `ends[]` are parallel
/// `u64` vectors, so the conflict probe's `partition_point` walks one
/// contiguous array of ends instead of striding over `(start, end)`
/// pairs. The set also maintains [`max_internal_gap`](Self::max_internal_gap),
/// an upper bound on its widest internal idle gap, which the gap-skip
/// fast path of [`ResourceTimeline::earliest_start`] consults. Equality
/// compares the interval content only, never the gap bound (two sets
/// built by different insert orders may carry different — equally valid
/// — bounds).
#[derive(Clone, Debug, Default, Eq)]
pub struct IntervalSet {
    starts: Vec<u64>,
    ends: Vec<u64>,
    /// Upper bound on the widest internal idle gap: monotone under
    /// inserts (appends record the gap they close over; merges and
    /// mid-inserts only shrink or destroy gaps) and left untouched by
    /// pruning (removed gaps leave the bound conservative). Never an
    /// underestimate, so the fast path never skips a usable gap.
    max_gap: u64,
}

impl PartialEq for IntervalSet {
    fn eq(&self, other: &Self) -> bool {
        self.starts == other.starts && self.ends == other.ends
    }
}

impl IntervalSet {
    pub fn new() -> IntervalSet {
        IntervalSet::default()
    }

    /// The canonical interval list, materialized as pairs.
    pub fn to_vec(&self) -> Vec<(u64, u64)> {
        self.iter().collect()
    }

    /// The canonical intervals, in order, as `(start, end)` pairs.
    pub fn iter(&self) -> impl Iterator<Item = (u64, u64)> + '_ {
        self.starts.iter().copied().zip(self.ends.iter().copied())
    }

    pub fn is_empty(&self) -> bool {
        self.starts.is_empty()
    }

    /// Stored interval nodes.
    pub fn len(&self) -> usize {
        self.starts.len()
    }

    /// Total covered time (sum of interval lengths).
    pub fn total(&self) -> u64 {
        self.iter().map(|(a, b)| b - a).sum()
    }

    /// First covered instant (0 when empty).
    pub fn start(&self) -> u64 {
        self.starts.first().copied().unwrap_or(0)
    }

    /// One past the last covered instant (0 when empty).
    pub fn end(&self) -> u64 {
        self.ends.last().copied().unwrap_or(0)
    }

    /// Upper bound on the widest idle gap strictly *between* stored
    /// intervals (never the open space before the first or after the
    /// last). A probe interval longer than this bound cannot fit any
    /// internal gap — the exactness the gap-skip fast path rests on.
    pub fn max_internal_gap(&self) -> u64 {
        self.max_gap
    }

    /// Does `[start, end)` intersect any stored interval?
    pub fn overlaps(&self, start: u64, end: u64) -> bool {
        self.first_conflict_end(start, end).is_some()
    }

    /// End of the earliest stored interval intersecting `[start, end)` —
    /// the instant a conflicting probe must be pushed past.
    pub fn first_conflict_end(&self, start: u64, end: u64) -> Option<u64> {
        if start >= end {
            return None;
        }
        let i = self.ends.partition_point(|&b| b <= start);
        if i < self.starts.len() && self.starts[i] < end {
            Some(self.ends[i])
        } else {
            None
        }
    }

    /// Insert `[start, end)`, merging overlapping or adjacent intervals
    /// (empty intervals are ignored). Inserts that land at or beyond the
    /// last stored interval — the common case for committed schedules,
    /// whose occupancies arrive in nondecreasing order per resource —
    /// append or extend the tail in O(1) amortized; only an insert that
    /// begins strictly before the tail pays the general merge.
    pub fn insert(&mut self, start: u64, end: u64) {
        if start >= end {
            return;
        }
        match self.ends.last().copied() {
            None => {
                self.starts.push(start);
                self.ends.push(end);
                return;
            }
            Some(le) => {
                if start > le {
                    // a strict append opens a new internal gap [le, start)
                    self.max_gap = self.max_gap.max(start - le);
                    self.starts.push(start);
                    self.ends.push(end);
                    return;
                }
                let ls = *self.starts.last().unwrap();
                if start >= ls {
                    // overlaps or touches the tail interval only
                    *self.ends.last_mut().unwrap() = le.max(end);
                    return;
                }
            }
        }
        // lo: first interval whose end touches `start`; hi: one past the
        // last interval whose start touches `end` — everything in
        // `lo..hi` fuses with the newcomer. A mid-insert splits an
        // existing gap (both halves stay under the bound) and a fuse only
        // shrinks its neighbors, so `max_gap` stays a bound — except a
        // plain insert *before the first interval*, which turns open
        // space into a brand-new internal gap the bound must absorb.
        let lo = self.ends.partition_point(|&b| b < start);
        let hi = self.starts.partition_point(|&a| a <= end);
        if lo == hi {
            if lo == 0 {
                self.max_gap = self.max_gap.max(self.starts[0] - end);
            }
            self.starts.insert(lo, start);
            self.ends.insert(lo, end);
            return;
        }
        let s = start.min(self.starts[lo]);
        let e = end.max(self.ends[hi - 1]);
        self.starts.splice(lo..hi, std::iter::once(s));
        self.ends.splice(lo..hi, std::iter::once(e));
    }

    /// Drop every interval that ends at or before `watermark`; an
    /// interval straddling the watermark stays whole. Returns how many
    /// nodes were removed. The gap bound is left as is — gaps that fell
    /// behind the watermark can no longer be probed, so a conservative
    /// bound stays sound.
    pub fn prune_before(&mut self, watermark: u64) -> usize {
        let k = self.ends.partition_point(|&b| b <= watermark);
        if k > 0 {
            self.starts.drain(..k);
            self.ends.drain(..k);
        }
        k
    }

    /// Panic unless the canonical invariants hold: entries non-empty,
    /// sorted, pairwise disjoint, non-adjacent, and the gap bound
    /// dominating every internal gap (used by the property suite;
    /// `insert` maintains them by construction).
    pub fn check_invariants(&self) {
        assert_eq!(self.starts.len(), self.ends.len(), "SoA arrays must stay parallel");
        for (a, b) in self.iter() {
            assert!(a < b, "empty interval in {:?}", self.to_vec());
        }
        for i in 1..self.starts.len() {
            assert!(
                self.ends[i - 1] < self.starts[i],
                "intervals must stay sorted, disjoint and non-adjacent: {:?}",
                self.to_vec()
            );
            assert!(
                self.starts[i] - self.ends[i - 1] <= self.max_gap,
                "gap bound {} underestimates gap [{}, {})",
                self.max_gap,
                self.ends[i - 1],
                self.starts[i]
            );
        }
    }
}

/// One resource's occupancy within a scheduled batch. All offsets are
/// cycles relative to the batch's start instant.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ResourceSpan {
    /// Resource id (`RES_*`; arrays are plan-local, i.e. relative to the
    /// tenant's slice base; cores are logical, relative to the tenant's
    /// core affinity).
    pub res: usize,
    /// Offset of the first cycle the batch occupies this resource.
    pub first_use: u64,
    /// Offset of the cycle the batch finally releases this resource.
    pub last_release: u64,
    /// Cycles the resource is actually held (≤ `last_release - first_use`).
    pub busy: u64,
    /// The merged busy intervals themselves, sorted and non-adjacent —
    /// `first_use`/`last_release` bracket them and `busy` is their total.
    /// This is what the backfilling arbiter intersects against the pool.
    pub intervals: Vec<(u64, u64)>,
}

/// The per-resource reservation profile of one scheduled batch: which
/// resources it needs, when (relative to its start), and for how long.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ReservationProfile {
    /// Spans sorted by resource id (one entry per touched resource).
    pub spans: Vec<ResourceSpan>,
    /// Batch makespan: the offset at which the whole batch has drained.
    pub len: u64,
}

impl ReservationProfile {
    /// The span for `res`, if the batch touches it.
    pub fn span(&self, res: usize) -> Option<&ResourceSpan> {
        self.spans.iter().find(|s| s.res == res)
    }

    /// Total busy cycles across all resources (for conservation checks:
    /// every span's `busy` must fit inside the batch makespan).
    pub fn total_busy(&self) -> u64 {
        self.spans.iter().map(|s| s.busy).sum()
    }

    /// The intervals [`ResourceTimeline::commit`] records for this profile
    /// (offsets relative to the dispatch instant): every merged busy
    /// interval in backfill mode, the first-use→last-release envelope
    /// otherwise. Resource ids stay profile-local — callers relocate them
    /// through the tenant's [`ResMap`]. The serve tracer replays exactly
    /// this to build its per-resource occupancy tracks, so traced
    /// occupancy merges to the committed timeline by construction.
    pub fn committed_spans(&self, backfill: bool) -> impl Iterator<Item = (usize, u64, u64)> + '_ {
        self.spans.iter().flat_map(move |s| span_committed(s, backfill))
    }
}

/// One span's committed intervals (see
/// [`ReservationProfile::committed_spans`]): each merged busy interval
/// when backfilling, the envelope once otherwise.
fn span_committed(s: &ResourceSpan, backfill: bool) -> Box<dyn Iterator<Item = (usize, u64, u64)> + '_> {
    if backfill {
        Box::new(s.intervals.iter().map(move |&(a, b)| (s.res, a, b)))
    } else {
        Box::new(std::iter::once((s.res, s.first_use, s.last_release)))
    }
}

/// Accumulates per-resource occupancy while a schedule is being built,
/// then freezes into a [`ReservationProfile`]. Occupancies of one
/// resource must not overlap each other (the scheduler serializes every
/// resource internally); adjacent occupancies merge into one interval.
#[derive(Debug, Default)]
pub struct ProfileBuilder {
    /// res → (busy intervals, accumulated busy cycles)
    spans: BTreeMap<usize, (IntervalSet, u64)>,
}

impl ProfileBuilder {
    pub fn new() -> ProfileBuilder {
        ProfileBuilder::default()
    }

    /// Record that `res` is held over `[start, finish)`.
    pub fn occupy(&mut self, res: usize, start: u64, finish: u64) {
        debug_assert!(finish >= start);
        let e = self.spans.entry(res).or_default();
        e.0.insert(start, finish);
        e.1 += finish - start;
    }

    /// Freeze into a profile with batch makespan `len`.
    pub fn build(self, len: u64) -> ReservationProfile {
        ReservationProfile {
            spans: self
                .spans
                .into_iter()
                .map(|(res, (set, busy))| ResourceSpan {
                    res,
                    first_use: set.start(),
                    last_release: set.end(),
                    busy,
                    intervals: set.to_vec(),
                })
                .collect(),
            len,
        }
    }
}

/// Relocation of a profile's slice-local resource ids onto the pool:
/// arrays shift by `array_base` (a tenant's slice starts there), per-core
/// resources rotate by `core_base` modulo [`N_CORES`] (so tenants whose
/// small core sections engage fewer than eight cores land on disjoint
/// physical cores), and the shared engines map to themselves. The
/// envelope arbiter always uses `core_base = 0` — rotation is a backfill
/// refinement, and with every core engaged it is a no-op permutation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ResMap {
    pub array_base: usize,
    pub core_base: usize,
}

impl ResMap {
    /// Array relocation only (core affinity 0) — the PR 3 mapping.
    pub fn arrays(array_base: usize) -> ResMap {
        ResMap { array_base, core_base: 0 }
    }

    /// Pool-absolute resource id for a profile-local one.
    pub fn map(&self, res: usize) -> usize {
        if res >= RES_ARRAY0 {
            res + self.array_base
        } else if res < N_CORES {
            (res + self.core_base) % N_CORES
        } else {
            res
        }
    }
}

/// Deterministic work/occupancy counters of one [`ResourceTimeline`] —
/// what the perf trajectory pins on (counters, not wall clock, so the
/// regression checks are not flaky).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TimelineStats {
    /// Gap-search probe work: binary-search halving steps spent inside
    /// [`ResourceTimeline::earliest_start`] (envelope mode counts one
    /// step per span frontier check). Shrinking the committed sets —
    /// pruning — shrinks this at identical dispatch decisions.
    pub probes: u64,
    /// Interval nodes currently stored across all resources.
    pub live_nodes: u64,
    /// High-water mark of `live_nodes` over the run.
    pub peak_live_nodes: u64,
    /// Interval nodes folded into the watermark so far.
    pub pruned_nodes: u64,
    /// Everything ending at or before this instant has been folded away.
    pub watermark: u64,
}

/// Binary-search halving steps over a sorted set of `n > 0` nodes — the
/// deterministic unit [`TimelineStats::probes`] counts (`partition_point`
/// always runs the full halving sequence, so the count is a pure
/// function of the set size).
fn search_steps(n: usize) -> u64 {
    (usize::BITS - n.leading_zeros()) as u64
}

/// Committed occupancy over every resource of one pool, plus cumulative
/// busy cycles for the utilization breakdown. Array ids are pool-absolute;
/// profiles carry slice-local ids, so every operation takes the tenant's
/// [`ResMap`] and relocates arrays/cores onto the pool. Per-resource
/// state is dense (`Vec`s indexed by resource id), grown on demand —
/// [`with_resources`](ResourceTimeline::with_resources) preallocates a
/// whole pool.
///
/// Two dispatch disciplines share the structure:
///
/// * [`backfilling`](ResourceTimeline::backfilling) — `earliest_start`
///   intersects the profile's busy intervals against the committed
///   interval sets and may place a batch inside idle gaps of
///   already-committed batches;
/// * [`envelope`](ResourceTimeline::envelope) — `earliest_start` uses
///   scalar next-free times (the committed envelope), bit-identical to
///   the PR 3 arbiter; on any one timeline state the envelope answer is
///   never earlier than the backfilled one.
///
/// Long-horizon runs call [`prune_before`](ResourceTimeline::prune_before)
/// with the oldest instant any future dispatch could probe; everything
/// committed wholly before it folds into the pruned tally and the gap
/// search walks only the live window.
#[derive(Clone, Debug)]
pub struct ResourceTimeline {
    backfill: bool,
    /// Gap-search fast paths (append-at-tail and no-usable-gap) — on by
    /// default; `--no-gap-skip` reproduces the PR 5 probe accounting.
    gap_skip: bool,
    /// Committed busy intervals per pool-absolute resource id.
    busy_iv: Vec<IntervalSet>,
    /// Scalar next-free time per resource (max committed release).
    free: Vec<u64>,
    /// Cumulative busy cycles per resource.
    busy: Vec<u64>,
    /// Everything ending at or before this has been folded away.
    watermark: u64,
    /// Interval nodes currently stored across all resources.
    live_nodes: usize,
    peak_live_nodes: usize,
    pruned_nodes: u64,
    /// Gap-search probe steps; a `Cell` because `earliest_start` is a
    /// read-only query of the committed state.
    probes: Cell<u64>,
}

impl ResourceTimeline {
    pub fn new(backfill: bool) -> ResourceTimeline {
        ResourceTimeline::with_resources(backfill, 0)
    }

    /// A timeline preallocated for resource ids `0..n_res` (committing a
    /// higher id still works — storage grows on demand).
    pub fn with_resources(backfill: bool, n_res: usize) -> ResourceTimeline {
        ResourceTimeline {
            backfill,
            gap_skip: true,
            busy_iv: vec![IntervalSet::new(); n_res],
            free: vec![0; n_res],
            busy: vec![0; n_res],
            watermark: 0,
            live_nodes: 0,
            peak_live_nodes: 0,
            pruned_nodes: 0,
            probes: Cell::new(0),
        }
    }

    fn grow(&mut self, res: usize) {
        if res >= self.busy_iv.len() {
            self.busy_iv.resize_with(res + 1, IntervalSet::new);
            self.free.resize(res + 1, 0);
            self.busy.resize(res + 1, 0);
        }
    }

    /// Interval-intersection dispatch: batches may slot into idle gaps.
    pub fn backfilling() -> ResourceTimeline {
        ResourceTimeline::new(true)
    }

    /// Conservative envelope dispatch (the PR 3 model, `--no-backfill`).
    pub fn envelope() -> ResourceTimeline {
        ResourceTimeline::new(false)
    }

    pub fn is_backfilling(&self) -> bool {
        self.backfill
    }

    /// Enable or disable the gap-search fast paths. Dispatch decisions
    /// are identical either way — only the `probes` counter moves — so
    /// this is a pure perf off-switch (`--no-gap-skip`).
    pub fn set_gap_skip(&mut self, on: bool) {
        self.gap_skip = on;
    }

    pub fn is_gap_skipping(&self) -> bool {
        self.gap_skip
    }

    /// When `res` (pool-absolute) next becomes free of *all* committed
    /// work — the envelope frontier, maintained in both modes and never
    /// affected by pruning.
    pub fn free_at(&self, res: usize) -> u64 {
        self.free.get(res).copied().unwrap_or(0)
    }

    /// Cycles `res` (pool-absolute) has been held so far (pruning never
    /// forgets busy tallies).
    pub fn busy_cycles(&self, res: usize) -> u64 {
        self.busy.get(res).copied().unwrap_or(0)
    }

    /// Cumulative busy cycles per pool-absolute resource id, ascending;
    /// resources never committed are skipped.
    pub fn busy_per_resource(&self) -> impl Iterator<Item = (usize, u64)> + '_ {
        self.busy.iter().copied().enumerate().filter(|&(_, b)| b > 0)
    }

    /// Committed busy intervals of `res` (pool-absolute), canonical form
    /// (intervals older than the watermark may have been pruned away).
    pub fn intervals(&self, res: usize) -> Vec<(u64, u64)> {
        self.busy_iv.get(res).map_or_else(Vec::new, |s| s.to_vec())
    }

    /// Does `[start, end)` intersect committed (unpruned) work on `res`?
    pub fn overlaps(&self, res: usize, start: u64, end: u64) -> bool {
        self.busy_iv.get(res).is_some_and(|s| s.overlaps(start, end))
    }

    /// Deterministic work/occupancy counters (see [`TimelineStats`]).
    pub fn stats(&self) -> TimelineStats {
        TimelineStats {
            probes: self.probes.get(),
            live_nodes: self.live_nodes as u64,
            peak_live_nodes: self.peak_live_nodes as u64,
            pruned_nodes: self.pruned_nodes,
            watermark: self.watermark,
        }
    }

    /// Fold every committed interval that ends at or before `watermark`
    /// into the pruned tally, bounding the gap search to the live window.
    ///
    /// Sound whenever no future `earliest_start`/`commit` touches an
    /// instant before `watermark`: a probe `[t+a, t+b)` with
    /// `t ≥ watermark` cannot intersect an interval ending at or before
    /// it, so pruning never changes a dispatch decision — only how much
    /// committed history the search walks. The serving loop passes the
    /// minimum over its tenants' next admission instants, which
    /// lower-bounds every future `not_before`. Watermarks are monotone;
    /// calls that do not advance it are free.
    pub fn prune_before(&mut self, watermark: u64) {
        if watermark <= self.watermark {
            return;
        }
        self.watermark = watermark;
        for set in &mut self.busy_iv {
            let dropped = set.prune_before(watermark);
            self.live_nodes -= dropped;
            self.pruned_nodes += dropped as u64;
        }
    }

    /// Earliest instant ≥ `not_before` at which a batch with this profile
    /// can start. Envelope mode: every needed resource must be free of all
    /// committed work by the offset the batch first touches it. Backfill
    /// mode: every busy interval of the profile must avoid every committed
    /// interval — the search jumps the candidate past the earliest
    /// conflict until a feasible placement (possibly inside gaps) is
    /// found, so the result is never later than the envelope answer.
    pub fn earliest_start(&self, prof: &ReservationProfile, map: ResMap, not_before: u64) -> u64 {
        self.earliest_start_blocked(prof, map, not_before).0
    }

    /// [`earliest_start`](Self::earliest_start) plus attribution: the
    /// pool-absolute id of the resource that last pushed the start past
    /// `not_before` (`None` when the profile fits at the floor — nothing
    /// stalled it). Envelope mode: the resource whose frontier set the
    /// final start (ties keep the earlier claimant). Backfill mode: the
    /// resource whose committed interval forced the final jump of the gap
    /// search. Probe accounting is byte-identical to the unattributed
    /// query — `earliest_start` delegates here — so tracing the blocker
    /// cannot perturb the counters the perf gates pin.
    pub fn earliest_start_blocked(
        &self,
        prof: &ReservationProfile,
        map: ResMap,
        not_before: u64,
    ) -> (u64, Option<usize>) {
        let mut steps: u64 = 0;
        let mut blocker: Option<usize> = None;
        let found = if !self.backfill {
            let mut t = not_before;
            for s in &prof.spans {
                steps += 1;
                let res = map.map(s.res);
                let cand = self.free_at(res).saturating_sub(s.first_use);
                if cand > t {
                    t = cand;
                    blocker = Some(res);
                }
            }
            t
        } else {
            let mut t = not_before;
            'search: loop {
                for s in &prof.spans {
                    let res = map.map(s.res);
                    let Some(set) = self.busy_iv.get(res) else {
                        continue;
                    };
                    if set.is_empty() {
                        continue;
                    }
                    let cost = search_steps(set.len());
                    let (set_start, set_end) = (set.start(), set.end());
                    let gap = set.max_internal_gap();
                    for &(a, b) in &s.intervals {
                        if self.gap_skip {
                            if t + a >= set_end {
                                // append-at-tail: the probe begins at or
                                // past the last committed release — no
                                // stored interval can conflict
                                steps += 1;
                                continue;
                            }
                            if b - a > gap && t + b > set_start {
                                // no usable gap: the probe overhangs the
                                // committed window yet is wider than any
                                // internal gap, so a conflict is certain
                                // and the only feasible placement is the
                                // tail — jump there in one step
                                steps += 1;
                                t = set_end - a;
                                blocker = Some(res);
                                continue 'search;
                            }
                        }
                        steps += cost;
                        if let Some(end) = set.first_conflict_end(t + a, t + b) {
                            // the conflicting interval ends past t + a, so
                            // this strictly advances t — termination
                            // follows from the finite committed set
                            t = end - a;
                            blocker = Some(res);
                            continue 'search;
                        }
                    }
                }
                break t;
            }
        };
        self.probes.set(self.probes.get() + steps);
        (found, blocker)
    }

    /// Committed (unpruned) busy-interval sets per pool-absolute resource
    /// id, skipping never-touched resources — the final-occupancy snapshot
    /// the serve tracer captures at drain for its span-conservation
    /// invariant.
    pub fn committed_intervals(&self) -> impl Iterator<Item = (usize, &IntervalSet)> + '_ {
        self.busy_iv.iter().enumerate().filter(|(_, s)| !s.is_empty())
    }

    /// Commit a batch dispatched at `t`. Backfill mode records each busy
    /// interval; envelope mode records the whole first-use→last-release
    /// envelope (exactly what the PR 3 arbiter reserved). Both push the
    /// scalar next-free frontier and accumulate busy cycles. Callers must
    /// have chosen `t ≥ earliest_start(..)`, and must not commit behind
    /// the pruning watermark (such intervals would be invisible).
    pub fn commit(&mut self, t: u64, prof: &ReservationProfile, map: ResMap) {
        debug_assert!(
            t >= self.watermark,
            "commit at {t} behind the pruning watermark {}",
            self.watermark
        );
        for s in &prof.spans {
            let res = map.map(s.res);
            self.grow(res);
            let before = self.busy_iv[res].len();
            if self.backfill {
                for &(a, b) in &s.intervals {
                    debug_assert!(
                        !self.busy_iv[res].overlaps(t + a, t + b),
                        "double-booked res {res} over [{}, {})",
                        t + a,
                        t + b
                    );
                    self.busy_iv[res].insert(t + a, t + b);
                }
            } else {
                self.busy_iv[res].insert(t + s.first_use, t + s.last_release);
            }
            self.live_nodes += self.busy_iv[res].len();
            self.live_nodes -= before;
            let release = t + s.last_release;
            self.free[res] = self.free[res].max(release);
            self.busy[res] += s.busy;
        }
        self.peak_live_nodes = self.peak_live_nodes.max(self.live_nodes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Profile from (res, disjoint sorted occupancy list) pairs.
    fn prof(spans: &[(usize, &[(u64, u64)])], len: u64) -> ReservationProfile {
        let mut b = ProfileBuilder::new();
        for &(res, ivs) in spans {
            for &(s, e) in ivs {
                b.occupy(res, s, e);
            }
        }
        b.build(len)
    }

    #[test]
    fn interval_set_merges_overlap_and_adjacency() {
        let mut s = IntervalSet::new();
        s.insert(10, 20);
        s.insert(30, 40);
        assert_eq!(s.to_vec(), &[(10, 20), (30, 40)]);
        s.insert(20, 25); // adjacent to [10, 20)
        assert_eq!(s.to_vec(), &[(10, 25), (30, 40)]);
        s.insert(24, 31); // bridges both
        assert_eq!(s.to_vec(), &[(10, 40)]);
        s.insert(5, 5); // empty: ignored
        assert_eq!(s.to_vec(), &[(10, 40)]);
        s.insert(0, 2);
        assert_eq!(s.to_vec(), &[(0, 2), (10, 40)]);
        s.check_invariants();
        assert_eq!(s.total(), 32);
        assert_eq!((s.start(), s.end()), (0, 40));
    }

    #[test]
    fn interval_set_conflict_probes() {
        let mut s = IntervalSet::new();
        s.insert(10, 20);
        s.insert(40, 50);
        assert!(!s.overlaps(0, 10), "touching ends do not conflict");
        assert!(!s.overlaps(20, 40), "the gap is free");
        assert!(s.overlaps(15, 16));
        assert!(s.overlaps(5, 45));
        assert_eq!(s.first_conflict_end(5, 45), Some(20), "earliest conflict");
        assert_eq!(s.first_conflict_end(25, 45), Some(50));
        assert_eq!(s.first_conflict_end(20, 40), None);
        assert_eq!(s.first_conflict_end(7, 7), None, "empty probe");
    }

    #[test]
    fn disjoint_profiles_overlap_fully() {
        for mut tl in [ResourceTimeline::backfilling(), ResourceTimeline::envelope()] {
            let a = prof(&[(RES_ARRAY0, &[(0, 100)])], 100);
            let b = prof(&[(RES_ARRAY0 + 1, &[(0, 80)])], 80);
            let ta = tl.earliest_start(&a, ResMap::default(), 0);
            tl.commit(ta, &a, ResMap::default());
            let tb = tl.earliest_start(&b, ResMap::default(), 0);
            assert_eq!((ta, tb), (0, 0), "disjoint resources must not serialize");
            tl.commit(tb, &b, ResMap::default());
            assert_eq!(tl.free_at(RES_ARRAY0), 100);
            assert_eq!(tl.free_at(RES_ARRAY0 + 1), 80);
        }
    }

    #[test]
    fn envelope_serializes_on_the_span_backfill_finds_the_gap() {
        // batch A holds array0 over [0, 100) and core0 over [90, 100);
        // batch B needs array1 over [0, 80) and core0 over [50, 60)
        let a = prof(&[(RES_ARRAY0, &[(0, 100)]), (RES_CORE0, &[(90, 100)])], 100);
        let b = prof(&[(RES_ARRAY0 + 1, &[(0, 80)]), (RES_CORE0, &[(50, 60)])], 80);
        // envelope: core0 is "held" over [90, 100), so B may start at 50
        // (its core use, offset 50, then lands exactly at the release)
        let mut env = ResourceTimeline::envelope();
        env.commit(0, &a, ResMap::default());
        assert_eq!(env.earliest_start(&b, ResMap::default(), 0), 50);
        // backfill: B's core interval [50, 60) fits before A's [90, 100)
        let mut bf = ResourceTimeline::backfilling();
        bf.commit(0, &a, ResMap::default());
        assert_eq!(bf.earliest_start(&b, ResMap::default(), 0), 0);
        bf.commit(0, &b, ResMap::default());
        assert_eq!(bf.intervals(RES_CORE0), &[(50, 60), (90, 100)]);
    }

    #[test]
    fn backfill_jumps_conflicts_to_the_first_fitting_gap() {
        let mut tl = ResourceTimeline::backfilling();
        let held = prof(&[(RES_DWACC, &[(0, 10), (20, 30)])], 30);
        tl.commit(0, &held, ResMap::default());
        // a 5-cycle accelerator job fits the [10, 20) gap
        let short = prof(&[(RES_DWACC, &[(0, 5)])], 5);
        assert_eq!(tl.earliest_start(&short, ResMap::default(), 0), 10);
        // respecting not_before inside the gap
        assert_eq!(tl.earliest_start(&short, ResMap::default(), 12), 12);
        // a 15-cycle job cannot: it lands past the second interval
        let long = prof(&[(RES_DWACC, &[(0, 15)])], 15);
        assert_eq!(tl.earliest_start(&long, ResMap::default(), 0), 30);
    }

    #[test]
    fn backfill_never_later_than_envelope_on_one_state() {
        // same committed content, same probe: the backfilled answer can
        // only be earlier (busy intervals are subsets of envelopes)
        let committed = prof(&[(RES_CORE0, &[(5, 10), (90, 100)]), (RES_DMA, &[(0, 40)])], 100);
        let probe = prof(&[(RES_CORE0, &[(0, 6)]), (RES_DMA, &[(50, 60)])], 60);
        let mut bf = ResourceTimeline::backfilling();
        let mut env = ResourceTimeline::envelope();
        bf.commit(0, &committed, ResMap::default());
        env.commit(0, &committed, ResMap::default());
        let t_bf = bf.earliest_start(&probe, ResMap::default(), 0);
        let t_env = env.earliest_start(&probe, ResMap::default(), 0);
        assert!(t_bf <= t_env, "{t_bf} > {t_env}");
        assert_eq!(t_env, 100, "envelope waits out core0's last release");
        assert_eq!(t_bf, 10, "backfill slots between core0's intervals");
    }

    #[test]
    fn res_map_relocates_arrays_and_rotates_cores() {
        let m = ResMap { array_base: 4, core_base: 4 };
        assert_eq!(m.map(RES_ARRAY0), RES_ARRAY0 + 4);
        assert_eq!(m.map(RES_CORE0), RES_CORE0 + 4);
        assert_eq!(m.map(RES_CORE0 + 6), RES_CORE0 + 2, "cores wrap mod 8");
        assert_eq!(m.map(RES_DWACC), RES_DWACC);
        assert_eq!(m.map(RES_PROG), RES_PROG);
        assert_eq!(ResMap::arrays(3).map(RES_CORE0 + 5), RES_CORE0 + 5);
    }

    #[test]
    fn array_base_relocates_slices() {
        let mut tl = ResourceTimeline::backfilling();
        let p = prof(&[(RES_ARRAY0, &[(0, 10)])], 10);
        tl.commit(0, &p, ResMap::arrays(0));
        // same plan-local array in a slice based at 4 is a different
        // physical array — no contention
        assert_eq!(tl.earliest_start(&p, ResMap::arrays(4), 0), 0);
        tl.commit(0, &p, ResMap::arrays(4));
        assert_eq!(tl.free_at(RES_ARRAY0 + 4), 10);
        // but the same slice contends with itself
        assert_eq!(tl.earliest_start(&p, ResMap::arrays(0), 0), 10);
    }

    #[test]
    fn core_rotation_lets_small_sections_share_the_complex() {
        // two tenants whose parallel sections engage two cores each: with
        // rotated affinity they land on disjoint physical cores
        let p = prof(&[(RES_CORE0, &[(0, 50)]), (RES_CORE0 + 1, &[(0, 50)])], 50);
        let mut tl = ResourceTimeline::backfilling();
        let a = ResMap::default();
        let b = ResMap { array_base: 0, core_base: 4 };
        tl.commit(tl.earliest_start(&p, a, 0), &p, a);
        assert_eq!(tl.earliest_start(&p, b, 0), 0, "disjoint cores overlap");
        tl.commit(0, &p, b);
        assert_eq!(tl.busy_cycles(RES_CORE0 + 4), 50);
        // a third tenant colliding with the first waits
        assert_eq!(tl.earliest_start(&p, a, 0), 50);
    }

    #[test]
    fn earliest_start_respects_not_before_and_first_use() {
        for mk in [ResourceTimeline::backfilling, ResourceTimeline::envelope] {
            let mut tl = mk();
            let a = prof(&[(RES_DWACC, &[(0, 40)])], 40);
            tl.commit(0, &a, ResMap::default());
            // a batch that first touches the DW accelerator at offset 30
            // may start at 10 (so its use begins exactly at 40)
            let b = prof(&[(RES_DWACC, &[(30, 50)])], 60);
            assert_eq!(tl.earliest_start(&b, ResMap::default(), 0), 10);
            assert_eq!(tl.earliest_start(&b, ResMap::default(), 25), 25);
        }
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(res_label(RES_CORE0), "core0");
        assert_eq!(res_label(RES_CORE0 + 7), "core7");
        assert_eq!(res_label(RES_DWACC), "dw_acc");
        assert_eq!(res_label(RES_IMA_MUX), "ima_mux");
        assert_eq!(res_label(RES_DMA), "dma");
        assert_eq!(res_label(RES_PROG), "pcm_prog");
        assert_eq!(res_label(RES_ARRAY0 + 7), "array7");
    }

    #[test]
    fn builder_merges_occupancy_into_canonical_spans() {
        let mut b = ProfileBuilder::new();
        b.occupy(RES_CORE0, 10, 20);
        b.occupy(RES_CORE0, 40, 45);
        b.occupy(RES_ARRAY0 + 2, 0, 5);
        b.occupy(RES_ARRAY0 + 2, 5, 9); // adjacent: merges
        let p = b.build(50);
        assert_eq!(p.len, 50);
        let c = p.span(RES_CORE0).unwrap();
        assert_eq!((c.first_use, c.last_release, c.busy), (10, 45, 15));
        assert_eq!(c.intervals, vec![(10, 20), (40, 45)]);
        let a = p.span(RES_ARRAY0 + 2).unwrap();
        assert_eq!((a.first_use, a.last_release, a.busy), (0, 9, 9));
        assert_eq!(a.intervals, vec![(0, 9)]);
        assert_eq!(p.total_busy(), 24);
    }

    #[test]
    fn insert_append_fast_path_keeps_canonical_form() {
        // nondecreasing inserts hit the O(1) tail path in every flavor:
        // disjoint append, adjacency, overlap, nesting
        let mut t = IntervalSet::new();
        t.insert(0, 5);
        t.insert(5, 9); // adjacent: fuses with the tail
        t.insert(7, 12); // overlapping: extends the tail
        t.insert(3, 4); // nested in the tail: bounds unchanged
        assert_eq!(t.to_vec(), &[(0, 12)]);
        t.insert(20, 30); // strictly past the tail: appended
        t.insert(1, 2); // before the tail: general path, still nested
        assert_eq!(t.to_vec(), &[(0, 12), (20, 30)]);
        t.check_invariants();
        let mut s = IntervalSet::new();
        for i in 0..100u64 {
            s.insert(i * 10, i * 10 + 5);
        }
        assert_eq!(s.len(), 100);
        s.check_invariants();
    }

    #[test]
    fn interval_set_prunes_only_the_dead_prefix() {
        let mut s = IntervalSet::new();
        s.insert(0, 10);
        s.insert(20, 30);
        s.insert(40, 50);
        assert_eq!(s.prune_before(25), 1, "only [0, 10) is fully dead");
        // [20, 30) straddles the watermark and stays whole
        assert_eq!(s.to_vec(), &[(20, 30), (40, 50)]);
        assert_eq!(s.prune_before(30), 1);
        assert_eq!(s.prune_before(30), 0, "idempotent at the same watermark");
        assert_eq!(s.to_vec(), &[(40, 50)]);
        s.check_invariants();
    }

    #[test]
    fn gap_bound_survives_prune_then_front_insert_then_fuse() {
        // the composed sequence the PR 8 review flagged: pruning drops
        // the prefix (bound untouched), a front insert then lands
        // *before* the new first interval (opening a brand-new internal
        // gap the bound must absorb), and a later fuse closes it again —
        // the bound must dominate every live gap at every step
        let mut s = IntervalSet::new();
        s.insert(0, 10);
        s.insert(12, 20); // gap 2 — the pre-prune bound stays tiny
        s.check_invariants();
        assert_eq!(s.max_internal_gap(), 2);
        assert_eq!(s.prune_before(20), 2, "the whole prefix is dead");
        // append into the emptied set: no internal gap yet, bound untouched
        s.insert(200, 210);
        s.check_invariants();
        // front insert before [200,210): opens internal gap [60, 200) —
        // 140 wide, far above the stale bound of 2; without the lo == 0
        // record the fast path would skip it
        s.insert(50, 60);
        s.check_invariants();
        assert!(s.max_internal_gap() >= 140, "front-insert gap must be absorbed");
        // fuse across the gap: the bound stays conservative, never under
        s.insert(60, 200);
        s.check_invariants();
        assert_eq!(s.to_vec(), &[(50, 210)]);
        // a fresh append re-records its own gap on top
        s.insert(215, 220);
        s.check_invariants();
    }

    #[test]
    fn gap_bound_survives_repeated_prune_front_insert_cycles() {
        // iterate the prune → front-insert cycle with shrinking offsets:
        // each round's front insert opens a different gap width and
        // check_invariants asserts the bound dominates after every step
        let mut s = IntervalSet::new();
        for round in 1..=8u64 {
            let base = round * 1_000;
            s.insert(base + 500, base + 510);
            s.check_invariants();
            s.prune_before(base);
            // front insert with a round-dependent gap to the survivor
            s.insert(base + 100, base + 100 + round);
            s.check_invariants();
            // fuse the two into one, then append the next round's seed
            s.insert(base + 100 + round, base + 500);
            s.check_invariants();
        }
    }

    #[test]
    fn front_insert_gap_is_never_skipped_by_the_fast_path() {
        // end-to-end: a timeline whose only usable gap was created by the
        // prune → front-insert sequence must still be found by
        // earliest_start (the no-usable-gap fast path consults the bound;
        // an underestimate would skip the real gap)
        let mut tl = ResourceTimeline::backfilling();
        tl.commit(0, &prof(&[(RES_DMA, &[(0, 10), (200, 210)])], 210), ResMap::default());
        tl.prune_before(10);
        // front-insert ahead of [200, 210): internal gap [40, 200)
        tl.commit(0, &prof(&[(RES_DMA, &[(30, 40)])], 40), ResMap::default());
        let probe = prof(&[(RES_DMA, &[(0, 100)])], 100);
        assert_eq!(
            tl.earliest_start(&probe, ResMap::default(), 40),
            40,
            "the gap opened by the front insert must be usable"
        );
    }

    #[test]
    fn pruning_is_invisible_to_future_probes() {
        // two identical timelines, one pruned at the oldest future probe:
        // every earliest_start at or past the watermark must agree, and
        // the envelope frontier / busy tallies must survive the fold
        let committed = prof(&[(RES_DWACC, &[(0, 10), (20, 30), (50, 60)])], 60);
        let mut a = ResourceTimeline::backfilling();
        let mut b = ResourceTimeline::backfilling();
        a.commit(0, &committed, ResMap::default());
        b.commit(0, &committed, ResMap::default());
        b.prune_before(40);
        assert_eq!(b.stats().pruned_nodes, 2);
        assert_eq!(b.stats().watermark, 40);
        assert!(b.stats().live_nodes < a.stats().live_nodes);
        let probe = prof(&[(RES_DWACC, &[(0, 15)])], 15);
        for nb in [40u64, 45, 55, 100] {
            assert_eq!(
                a.earliest_start(&probe, ResMap::default(), nb),
                b.earliest_start(&probe, ResMap::default(), nb),
                "not_before {nb}"
            );
        }
        assert_eq!(b.free_at(RES_DWACC), 60, "frontier survives pruning");
        assert_eq!(b.busy_cycles(RES_DWACC), 30, "busy tally survives pruning");
        assert_eq!(b.intervals(RES_DWACC), &[(50, 60)]);
    }

    #[test]
    fn stats_count_probes_and_live_nodes_deterministically() {
        let mut tl = ResourceTimeline::with_resources(true, RES_ARRAY0 + 4);
        let p = prof(&[(RES_CORE0, &[(0, 10)])], 10);
        assert_eq!(tl.stats(), TimelineStats::default());
        let _ = tl.earliest_start(&p, ResMap::default(), 0);
        assert_eq!(tl.stats().probes, 0, "empty committed sets cost nothing");
        tl.commit(0, &p, ResMap::default());
        assert_eq!(tl.stats().live_nodes, 1);
        assert_eq!(tl.stats().peak_live_nodes, 1);
        let _ = tl.earliest_start(&p, ResMap::default(), 0);
        let probes_once = tl.stats().probes;
        assert!(probes_once > 0);
        let _ = tl.earliest_start(&p, ResMap::default(), 0);
        assert_eq!(tl.stats().probes, 2 * probes_once, "probe cost is deterministic");
    }

    #[test]
    fn live_node_accounting_tracks_merges() {
        let mut tl = ResourceTimeline::backfilling();
        let a = prof(&[(RES_DMA, &[(0, 10)])], 10);
        let b = prof(&[(RES_DMA, &[(10, 20)])], 20);
        tl.commit(0, &a, ResMap::default());
        assert_eq!(tl.stats().live_nodes, 1);
        tl.commit(0, &b, ResMap::default());
        // adjacent intervals fuse: still one node
        assert_eq!(tl.stats().live_nodes, 1);
        assert_eq!(tl.stats().peak_live_nodes, 1);
        assert_eq!(tl.intervals(RES_DMA), &[(0, 20)]);
        tl.prune_before(20);
        assert_eq!(tl.stats().live_nodes, 0);
        assert_eq!(tl.stats().pruned_nodes, 1);
        assert_eq!(tl.busy_cycles(RES_DMA), 20);
    }

    #[test]
    fn blocked_query_attributes_the_binding_resource() {
        // backfill: the DW accelerator's committed interval forces the jump
        let mut bf = ResourceTimeline::backfilling();
        let held = prof(&[(RES_DWACC, &[(0, 40)]), (RES_DMA, &[(0, 10)])], 40);
        bf.commit(0, &held, ResMap::default());
        let probe = prof(&[(RES_DWACC, &[(0, 15)]), (RES_DMA, &[(20, 30)])], 30);
        let (t, blk) = bf.earliest_start_blocked(&probe, ResMap::default(), 0);
        assert_eq!((t, blk), (40, Some(RES_DWACC)));
        // fits at the floor: nothing to blame
        let (t, blk) = bf.earliest_start_blocked(&probe, ResMap::default(), 40);
        assert_eq!((t, blk), (40, None));
        // envelope: the frontier that set the final start wins
        let mut env = ResourceTimeline::envelope();
        env.commit(0, &held, ResMap::default());
        let (t, blk) = env.earliest_start_blocked(&probe, ResMap::default(), 0);
        assert_eq!((t, blk), (40, Some(RES_DWACC)));
        // attribution delegates: the unattributed answer and the probe
        // count are identical
        let plain = ResourceTimeline::backfilling();
        let mut a = plain.clone();
        let mut b = plain;
        a.commit(0, &held, ResMap::default());
        b.commit(0, &held, ResMap::default());
        assert_eq!(
            a.earliest_start(&probe, ResMap::default(), 0),
            b.earliest_start_blocked(&probe, ResMap::default(), 0).0
        );
        assert_eq!(a.stats().probes, b.stats().probes, "probe accounting must match");
    }

    #[test]
    fn committed_spans_match_commit_in_both_modes() {
        let p = prof(&[(RES_DWACC, &[(0, 10), (20, 30)]), (RES_DMA, &[(5, 15)])], 30);
        for backfill in [true, false] {
            let mut tl = ResourceTimeline::new(backfill);
            tl.commit(100, &p, ResMap::default());
            // replaying committed_spans at the same dispatch offset must
            // reproduce the committed sets exactly
            let mut replay: BTreeMap<usize, IntervalSet> = BTreeMap::new();
            for (res, a, b) in p.committed_spans(backfill) {
                replay.entry(res).or_default().insert(100 + a, 100 + b);
            }
            for (res, ivs) in tl.committed_intervals() {
                assert_eq!(&replay[&res], ivs, "res {res}, backfill {backfill}");
            }
            assert_eq!(replay.len(), tl.committed_intervals().count());
        }
    }

    #[test]
    fn busy_per_resource_skips_untouched_ids() {
        let mut tl = ResourceTimeline::with_resources(true, RES_ARRAY0 + 8);
        let p = prof(&[(RES_ARRAY0 + 2, &[(0, 10)]), (RES_DWACC, &[(0, 4)])], 10);
        tl.commit(0, &p, ResMap::default());
        let got: Vec<(usize, u64)> = tl.busy_per_resource().collect();
        assert_eq!(got, vec![(RES_DWACC, 4), (RES_ARRAY0 + 2, 10)]);
    }

    #[test]
    fn max_internal_gap_is_a_monotone_upper_bound() {
        let mut s = IntervalSet::new();
        assert_eq!(s.max_internal_gap(), 0);
        s.insert(0, 10);
        assert_eq!(s.max_internal_gap(), 0, "one interval has no internal gap");
        s.insert(30, 40); // opens gap [10, 30)
        assert_eq!(s.max_internal_gap(), 20);
        s.insert(15, 18); // splits the gap: bound stays conservative
        assert_eq!(s.max_internal_gap(), 20);
        s.check_invariants();
        s.insert(10, 30); // fills everything: a stale bound stays sound
        assert_eq!(s.to_vec(), vec![(0, 40)]);
        s.check_invariants();
        // pruning keeps the bound (conservative is sound)
        let mut t = IntervalSet::new();
        t.insert(0, 5);
        t.insert(100, 110);
        t.insert(120, 130);
        assert_eq!(t.max_internal_gap(), 95);
        t.prune_before(110);
        assert_eq!(t.max_internal_gap(), 95, "prune never lowers the bound");
        t.check_invariants();
        // a backfill landing *before* the first interval turns open space
        // into a brand-new internal gap the bound must absorb
        let mut u = IntervalSet::new();
        u.insert(100, 200);
        assert_eq!(u.max_internal_gap(), 0);
        u.insert(0, 10);
        assert_eq!(u.max_internal_gap(), 90, "front insert opens gap [10, 100)");
        u.check_invariants();
        // ...while a front insert that fuses with the head opens none
        let mut v = IntervalSet::new();
        v.insert(100, 200);
        v.insert(50, 100);
        assert_eq!(v.to_vec(), vec![(50, 200)]);
        assert_eq!(v.max_internal_gap(), 0);
        v.check_invariants();
    }

    #[test]
    fn gap_skip_never_changes_the_dispatch_answer() {
        // a committed landscape with tail appends, a wide dead gap, and a
        // narrow usable gap; probes of every width must agree fast/slow
        let committed = prof(
            &[
                (RES_DWACC, &[(0, 10), (12, 30), (35, 60)]),
                (RES_DMA, &[(5, 50)]),
                (RES_CORE0, &[(0, 3), (90, 100)]),
            ],
            100,
        );
        let probes = [
            prof(&[(RES_DWACC, &[(0, 2)])], 2),
            prof(&[(RES_DWACC, &[(0, 5)])], 5),
            prof(&[(RES_DWACC, &[(0, 40)])], 40),
            prof(&[(RES_DWACC, &[(0, 4)]), (RES_DMA, &[(1, 3)])], 4),
            prof(&[(RES_CORE0, &[(0, 50)]), (RES_DMA, &[(10, 20)])], 50),
        ];
        let mut fast = ResourceTimeline::backfilling();
        let mut slow = ResourceTimeline::backfilling();
        slow.set_gap_skip(false);
        fast.commit(0, &committed, ResMap::default());
        slow.commit(0, &committed, ResMap::default());
        for p in &probes {
            for nb in [0u64, 7, 31, 61, 200] {
                let (tf, bf) = fast.earliest_start_blocked(p, ResMap::default(), nb);
                let (ts, bs) = slow.earliest_start_blocked(p, ResMap::default(), nb);
                assert_eq!(tf, ts, "start diverged at not_before {nb}");
                assert_eq!(bf, bs, "blocker diverged at not_before {nb}");
            }
        }
        assert!(
            fast.stats().probes <= slow.stats().probes,
            "fast paths must never add probe work: {} > {}",
            fast.stats().probes,
            slow.stats().probes
        );
    }

    #[test]
    fn gap_skip_cuts_probe_work_on_append_heavy_timelines() {
        // the serving common case: monotone tail appends, probes past the
        // frontier — the O(1) path must beat the binary-search accounting
        let mut fast = ResourceTimeline::backfilling();
        let mut slow = ResourceTimeline::backfilling();
        slow.set_gap_skip(false);
        let job = prof(&[(RES_DWACC, &[(0, 8)])], 10);
        let mut t = 0;
        for _ in 0..64 {
            for tl in [&mut fast, &mut slow] {
                let got = tl.earliest_start(&job, ResMap::default(), t);
                assert_eq!(got, t, "appends at the frontier are conflict-free");
                tl.commit(got, &job, ResMap::default());
            }
            t += 10;
        }
        assert_eq!(fast.intervals(RES_DWACC), slow.intervals(RES_DWACC));
        assert!(
            fast.stats().probes < slow.stats().probes,
            "append fast path must strictly cut probes: {} !< {}",
            fast.stats().probes,
            slow.stats().probes
        );
    }
}
