//! Batched multi-array scheduler: the serving loop of the scaled-up system.
//!
//! The per-request execution model stays the paper's (§VI: layer-to-layer
//! sequential, activations in L1); what this module adds is the *request*
//! dimension. A batch of B inferences flows through the layer chain, and
//! with pipelining enabled, request r+1 may occupy a resource as soon as
//! request r has released it — so while request r computes on the arrays
//! that host layer k+1, request r+1 computes on the (disjoint) arrays of
//! layer k, with double-buffered activations decoupling the two. This is an
//! exact greedy list schedule over explicit resources:
//!
//! * each pool array is a resource — conv layers occupy exactly the arrays
//!   TILE&PACK placed their tiles on (two layers sharing an array cannot
//!   overlap, which the schedule enforces by construction);
//! * the DW accelerator is a single resource; the core complex is eight
//!   per-core resources — a core-mapped layer occupies the prefix
//!   `core0..cores_used` its parallel section engages (every core layer
//!   includes core 0, so core layers still serialize pairwise exactly as
//!   a fused complex would, and the schedule is unchanged);
//! * IMA-mapped layers without a placement (e.g. dw-on-IMA under the
//!   `IMA_cjob` strategies) serialize on one shared virtual IMA resource;
//! * activations between consecutive layers are double-buffered: layer k
//!   of request r additionally waits until request r−2 has consumed the
//!   k/k+1 boundary buffer (at most two live activations per boundary).
//!
//! With pipelining disabled and a resident plan, the batch degenerates to
//! B back-to-back inferences and the totals are bit-identical to B
//! sequential runs — the regression tests pin both properties.
//!
//! Staged (undersized-pool) plans execute batch-major: every pass runs the
//! whole batch before the pool reprograms for the next pass, so the
//! enormous PCM cost amortizes over B (a truly sequential request would
//! reprogram every pass itself — `sequential_cycles` is that baseline) —
//! the report then shows exactly how far off-chip weights are from
//! interactive serving (§VI's argument).
//!
//! Staged passes additionally charge the L2 activation traffic at every
//! cut boundary: the activation feeding the next pass's first layer must
//! spill to L2 while the pool reprograms and refill into L1 afterwards
//! (one DMA spill + one refill per request per cut, serialized at the
//! pass barrier on the single cluster DMA). Resident plans never touch L2
//! on the request path, matching the paper's all-activations-in-L1 model.
//!
//! Two extensions ride on the same resource machinery:
//!
//! * every batch emits a [`ReservationProfile`] — per resource, the merged
//!   busy intervals (plus the first-use/last-release envelope summary) —
//!   so the serving arbiter can overlap batches of different tenants and
//!   backfill later batches into committed idle gaps (see
//!   [`super::timeline`]);
//! * with [`BatchConfig::stream_weights`] set, staged plans *stream* their
//!   PCM updates: pass k+1's program-and-verify runs array by array on the
//!   single programming port, each array starting the moment pass k's
//!   compute releases it, and pass k+1's layers start as soon as their own
//!   arrays are programmed (plus their request's boundary refill). The
//!   cut-boundary DMA likewise overlaps programming on its own port.
//!   Programming work, DMA work, and energy are identical to the blocking
//!   schedule — only the makespan shrinks. With the flag off the schedule
//!   is bit-identical to the original barrier model.

use crate::arch::{EnergyAccount, PowerModel, SystemConfig};
use crate::ima::ImaArrayPool;
use crate::net::Network;
use crate::sim::dma::DmaModel;
use crate::tilepack::StagedPlacement;

use super::timeline::{
    ProfileBuilder, ReservationProfile, N_CORES, RES_ARRAY0, RES_CORE0, RES_DMA, RES_DWACC,
    RES_IMA_MUX, RES_PROG,
};
use super::{Engine, Executor, Strategy};

/// Batch execution knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchConfig {
    pub batch: usize,
    /// Overlap requests across layer resources (double-buffered
    /// activations); disabled = strict back-to-back serving.
    pub pipeline: bool,
    /// Charge the L2 spill/refill of cut-boundary activations between
    /// staged passes (no effect on resident plans). On by default;
    /// disabling it reproduces the pre-DMA accounting for ablations.
    pub charge_dma: bool,
    /// Stream staged PCM updates: overlap a pass's compute tail with the
    /// next pass's reprogramming on arrays the running pass has released
    /// (no effect on resident plans). Off by default — the blocking
    /// barrier schedule stays bit-identical to the PR 1/2 model.
    pub stream_weights: bool,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig {
            batch: 1,
            pipeline: true,
            charge_dma: true,
            stream_weights: false,
        }
    }
}

/// Outcome of serving one batch.
#[derive(Clone, Debug)]
pub struct BatchReport {
    pub network: String,
    pub strategy: Strategy,
    pub batch: usize,
    pub pipelined: bool,
    pub n_passes: usize,
    /// Total cycles to drain the batch (incl. reprogramming for staged).
    pub cycles: u64,
    /// Of which: PCM reprogramming (zero for resident plans).
    pub reprogram_cycles: u64,
    /// Of which: L2 spill/refill of cut-boundary activations between
    /// staged passes (zero for resident plans; DMA energy is negligible
    /// next to PCM programming and is not accounted).
    pub dma_cycles: u64,
    pub time_s: f64,
    /// Total energy: request work plus (for staged plans) the PCM
    /// program-and-verify energy matching `reprogram_cycles`.
    pub energy_j: f64,
    /// Of which: PCM reprogramming (zero for resident plans).
    pub reprogram_energy_j: f64,
    /// One request's layer work executed alone (no reprogramming).
    pub per_request_cycles: u64,
    /// The honest sequential baseline: B requests served one at a time,
    /// each paying the full per-pass reprogramming and its own boundary
    /// activation spill/refill itself (equals `per_request_cycles * batch`
    /// for resident plans).
    pub sequential_cycles: u64,
    /// Name of the layer whose resources bound the pipeline.
    pub bottleneck_layer: String,
    /// Per-resource reservation profile of this batch — merged busy
    /// intervals plus the envelope summary (offsets relative to dispatch;
    /// array/core ids are plan-local) — what the serving arbiter
    /// intersects against its pool timeline.
    pub profile: ReservationProfile,
}

impl BatchReport {
    /// The plan runs staged passes — weights are reprogrammed between
    /// passes instead of being fully resident in the tenant's slice.
    /// Rides in the execution trace's batch spans, since staged batches
    /// are the ones whose occupancy includes the programming port.
    pub fn staged(&self) -> bool {
        self.n_passes > 1
    }

    pub fn inferences_per_s(&self) -> f64 {
        if self.time_s > 0.0 {
            self.batch as f64 / self.time_s
        } else {
            0.0
        }
    }

    /// Batch speedup over B strictly sequential requests (each paying its
    /// own reprogramming on staged pools).
    pub fn speedup_vs_sequential(&self) -> f64 {
        if self.cycles == 0 {
            return 1.0;
        }
        self.sequential_cycles as f64 / self.cycles as f64
    }
}

/// Serve a batch of `cfgb.batch` requests of `net` under `strategy` on the
/// pool described by `cfg`/`plan`. The plan must come from the plan cache
/// (or `place_staged`) for the same network.
pub fn run_batched(
    net: &Network,
    strategy: Strategy,
    cfg: &SystemConfig,
    pm: &PowerModel,
    plan: &StagedPlacement,
    cfgb: BatchConfig,
) -> BatchReport {
    assert!(cfgb.batch > 0, "batch must be ≥ 1");
    assert_eq!(
        plan.net_fingerprint,
        net.fingerprint(),
        "plan was placed for a different network geometry"
    );
    assert_eq!(
        plan.pass_ranges.last().map(|&(_, b)| b),
        Some(net.layers.len()),
        "plan does not cover this network"
    );
    let ex = Executor::new(cfg, pm, strategy);
    let pool = ImaArrayPool::new(cfg, pm);

    // per-layer (cycles, energy, engine, cores engaged), computed once —
    // requests are identical and the engine choice feeds the resource
    // mapping
    let costs: Vec<(u64, EnergyAccount, Engine, usize)> = net
        .layers
        .iter()
        .map(|l| {
            let (rep, acc) = ex.layer(l);
            (rep.cycles, acc, rep.engine, rep.cores_used)
        })
        .collect();
    let per_request_cycles: u64 = costs.iter().map(|(cy, _, _, _)| *cy).sum();
    let per_request_energy: f64 = {
        let mut acc = EnergyAccount::default();
        for (_, e, _, _) in &costs {
            acc.add(e);
        }
        acc.total_j(pm, cfg)
    };

    // resources each layer occupies (within its pass); core layers hold
    // the per-core prefix their parallel section engages — every core
    // layer includes core 0, so the intra-batch schedule is identical to
    // the fused-complex model
    let layer_resources = |pass: &crate::tilepack::PoolPlacement,
                           range: (usize, usize)|
     -> Vec<Vec<usize>> {
        let mut out = Vec::new();
        for li in range.0..range.1 {
            let res = match costs[li].2 {
                Engine::Cores => {
                    let k = costs[li].3.clamp(1, N_CORES);
                    (0..k).map(|c| RES_CORE0 + c).collect()
                }
                Engine::DwAcc => vec![RES_DWACC],
                Engine::Ima => {
                    let arrays = &pass.layer_arrays[li];
                    if arrays.is_empty() {
                        vec![RES_IMA_MUX]
                    } else {
                        arrays.iter().map(|a| RES_ARRAY0 + a).collect()
                    }
                }
            };
            out.push(res);
        }
        out
    };

    let (reprogram_per_pass, reprogram_energy_j): (Vec<u64>, f64) = if plan.is_resident() {
        (vec![0; plan.passes.len()], 0.0)
    } else {
        (
            plan.passes.iter().map(|p| pool.program_cycles(p)).collect(),
            plan.passes.iter().map(|p| pool.program_energy_j(p)).sum(),
        )
    };

    // per-cut L2 activation traffic: the tensor feeding the next pass's
    // first layer spills to L2 and refills into L1 (one transfer each way
    // per request, serialized at the pass barrier on the cluster DMA)
    let dma = DmaModel::paper();
    let boundary_dma_cy: Vec<u64> = plan
        .pass_ranges
        .windows(2)
        .map(|w| {
            if cfgb.charge_dma {
                2 * dma.transfer_cy(net.layers[w[1].0].in_bytes())
            } else {
                0
            }
        })
        .collect();

    // greedy list schedule, batch-major across passes
    let mut reprogram_cycles: u64 = 0;
    let mut dma_cycles: u64 = 0;
    // dense scratch over the plan's resource ids — the schedule loops are
    // the hottest code in the crate, so no per-(request, layer) map ops.
    // Programming chunks and layer arrays both stay below arrays_used.
    let n_layers_total = net.layers.len();
    let n_res = RES_ARRAY0 + plan.passes.iter().map(|p| p.arrays_used).max().unwrap_or(0);
    let mut busy_cy: Vec<u64> = vec![0; n_res];
    let mut touched: Vec<bool> = vec![false; n_res];
    // busy cycles layer `li` contributed on resource `res`, at
    // `res * n_layers_total + li` (the bottleneck attribution)
    let mut layer_contrib: Vec<u64> = vec![0; n_res * n_layers_total];
    let mut builder = ProfileBuilder::new();

    let streamed = cfgb.stream_weights && !plan.is_resident();
    let cycles: u64 = if streamed {
        // ---- streamed weight updates ---------------------------------
        // Pass k+1's PCM programming runs array by array on the single
        // program-and-verify port, each chunk starting the moment pass
        // k's compute releases that array; pass k+1's layers start once
        // their own arrays are programmed and their request's boundary
        // activation has refilled (DMA overlaps programming on its own
        // port). Resource state therefore persists across passes.
        let mut res_free: Vec<u64> = vec![0; n_res];
        let mut prog_free: u64 = 0; // the programming port
        let mut dma_free: u64 = 0; // the cluster DMA port
        let mut req_end: Vec<u64> = vec![0; cfgb.batch];
        let mut makespan: u64 = 0;

        for (pi, (pass, &range)) in plan.passes.iter().zip(plan.pass_ranges.iter()).enumerate() {
            let chunks = pool.program_cycles_by_array(pass);
            for (&a, &cy) in &chunks {
                let res = RES_ARRAY0 + a;
                let start = prog_free.max(res_free[res]);
                let finish = start + cy;
                builder.occupy(res, start, finish);
                builder.occupy(RES_PROG, start, finish);
                res_free[res] = finish;
                prog_free = finish;
            }
            reprogram_cycles += reprogram_per_pass[pi];

            let res_of = layer_resources(pass, range);
            let n_layers = range.1 - range.0;
            let mut finish_prev: Vec<u64> = vec![0; n_layers];
            let mut finish_prev2: Vec<u64> = vec![0; n_layers];
            let mut prev_request_end: u64 = 0;
            for end in req_end.iter_mut() {
                let mut t = *end;
                if pi > 0 {
                    // spill once the request drains from the previous
                    // pass, refill before this one — one DMA transaction
                    let cy = boundary_dma_cy[pi - 1];
                    if cy > 0 {
                        let start = dma_free.max(*end);
                        let finish = start + cy;
                        builder.occupy(RES_DMA, start, finish);
                        dma_free = finish;
                        dma_cycles += cy;
                        t = finish;
                    }
                }
                if !cfgb.pipeline {
                    t = t.max(prev_request_end);
                }
                let mut finish_cur: Vec<u64> = vec![0; n_layers];
                for (k, li) in (range.0..range.1).enumerate() {
                    let cy = costs[li].0;
                    let mut start = t;
                    for &res in &res_of[k] {
                        start = start.max(res_free[res]);
                    }
                    if k + 1 < n_layers {
                        start = start.max(finish_prev2[k + 1]);
                    }
                    let finish = start + cy;
                    for &res in &res_of[k] {
                        builder.occupy(res, start, finish);
                        res_free[res] = finish;
                        busy_cy[res] += cy;
                        touched[res] = true;
                        layer_contrib[res * n_layers_total + li] += cy;
                    }
                    finish_cur[k] = finish;
                    t = finish;
                }
                prev_request_end = t;
                *end = t;
                makespan = makespan.max(t);
                finish_prev2 = std::mem::replace(&mut finish_prev, finish_cur);
            }
        }
        // compute on a programmed array always outlasts its programming
        // under the IMA strategies; the max guards strategies that leave
        // programmed arrays idle
        makespan.max(prog_free).max(dma_free)
    } else {
        // ---- blocking barrier schedule (bit-identical to PR 1/2) -----
        let mut now: u64 = 0; // global clock across passes
        let mut res_free: Vec<u64> = vec![0; n_res];
        for (pi, (pass, &range)) in plan.passes.iter().zip(plan.pass_ranges.iter()).enumerate() {
            // crossing a cut: every request's boundary activation spills
            // to L2 and refills into L1 around the reprogramming barrier
            if pi > 0 {
                let cy = boundary_dma_cy[pi - 1].saturating_mul(cfgb.batch as u64);
                if cy > 0 {
                    builder.occupy(RES_DMA, now, now + cy);
                }
                now += cy;
                dma_cycles += cy;
            }
            // staged pools rewrite their weights before every pass; the
            // per-array program-and-verify chunks serialize inside the
            // barrier (profile attribution only — `now` jumps the total)
            if reprogram_per_pass[pi] > 0 {
                let chunks = pool.program_cycles_by_array(pass);
                let mut t0 = now;
                for (&a, &cy) in &chunks {
                    builder.occupy(RES_ARRAY0 + a, t0, t0 + cy);
                    t0 += cy;
                }
                debug_assert_eq!(t0, now + reprogram_per_pass[pi]);
                builder.occupy(RES_PROG, now, now + reprogram_per_pass[pi]);
            }
            now += reprogram_per_pass[pi];
            reprogram_cycles += reprogram_per_pass[pi];

            let res_of = layer_resources(pass, range);
            let n_layers = range.1 - range.0;
            // every resource opens the pass free at the barrier
            res_free.fill(now);
            // per-layer finish times of the previous two requests — the
            // double-buffer backpressure (request r's layer k may not
            // start until request r−2 has consumed the k/k+1 boundary
            // buffer)
            let mut finish_prev: Vec<u64> = vec![now; n_layers];
            let mut finish_prev2: Vec<u64> = vec![now; n_layers];
            let mut pass_end = now;
            let mut prev_request_end = now;
            for _r in 0..cfgb.batch {
                let mut finish_cur: Vec<u64> = vec![now; n_layers];
                let mut t = now; // this request's position in the chain
                if !cfgb.pipeline {
                    // strict serving: wait for the previous request
                    t = t.max(prev_request_end);
                }
                for (k, li) in (range.0..range.1).enumerate() {
                    let cy = costs[li].0;
                    let mut start = t;
                    for &res in &res_of[k] {
                        start = start.max(res_free[res]);
                    }
                    // buffer slot at the output boundary frees once
                    // request r−2 has finished the consuming layer k+1
                    if k + 1 < n_layers {
                        start = start.max(finish_prev2[k + 1]);
                    }
                    let finish = start + cy;
                    for &res in &res_of[k] {
                        builder.occupy(res, start, finish);
                        res_free[res] = finish;
                        busy_cy[res] += cy;
                        touched[res] = true;
                        layer_contrib[res * n_layers_total + li] += cy;
                    }
                    finish_cur[k] = finish;
                    t = finish;
                }
                prev_request_end = t;
                pass_end = pass_end.max(t);
                finish_prev2 = std::mem::replace(&mut finish_prev, finish_cur);
            }
            now = pass_end;
        }
        now
    };

    // pipeline bottleneck: the busiest resource, attributed to the layer
    // that contributed the most busy time on it (deterministic: ascending
    // scan with ties falling to the later entry — the same winner the
    // old BTreeMap + max_by_key tie-break produced)
    let mut bottleneck_layer = String::from("none");
    let mut best_res: Option<usize> = None;
    let mut best_busy: u64 = 0;
    for res in 0..n_res {
        if touched[res] && busy_cy[res] >= best_busy {
            best_res = Some(res);
            best_busy = busy_cy[res];
        }
    }
    if let Some(res) = best_res {
        let mut top_li: Option<usize> = None;
        let mut top_cy: u64 = 0;
        for li in 0..n_layers_total {
            let cy = layer_contrib[res * n_layers_total + li];
            if cy > 0 && cy >= top_cy {
                top_li = Some(li);
                top_cy = cy;
            }
        }
        if let Some(li) = top_li {
            bottleneck_layer = net.layers[li].name.clone();
        }
    }

    let time_s = cycles as f64 * cfg.freq.cycle_ns() * 1e-9;
    // a truly sequential request reprograms every pass itself and pays its
    // own boundary spill/refill; batch-major serving pays reprogramming
    // once per batch (reprogram_cycles is one serving cycle) but DMA per
    // request — activations are per-request state and never amortize
    let per_request_dma: u64 = boundary_dma_cy.iter().sum();
    let sequential_cycles = (per_request_cycles + reprogram_cycles + per_request_dma)
        .saturating_mul(cfgb.batch as u64);
    BatchReport {
        network: net.name.clone(),
        strategy,
        batch: cfgb.batch,
        pipelined: cfgb.pipeline,
        n_passes: plan.n_passes(),
        cycles,
        reprogram_cycles,
        dma_cycles,
        time_s,
        energy_j: per_request_energy * cfgb.batch as f64 + reprogram_energy_j,
        reprogram_energy_j,
        per_request_cycles,
        sequential_cycles,
        bottleneck_layer,
        profile: builder.build(cycles),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::plan_cache::PlanCache;
    use crate::coordinator::run_network;
    use crate::net::bottleneck::bottleneck;

    fn setup() -> (SystemConfig, PowerModel) {
        (SystemConfig::scaled_up(8), PowerModel::paper())
    }

    #[test]
    fn batch_one_pipelined_equals_one_sequential_run() {
        let (cfg, pm) = setup();
        let net = bottleneck();
        let mut cache = PlanCache::new();
        let plan = cache.get_or_place(&net, 256, 8, false).unwrap();
        let one = run_batched(
            &net,
            Strategy::ImaDw,
            &cfg,
            &pm,
            &plan,
            BatchConfig {
                batch: 1,
                pipeline: true,
                ..BatchConfig::default()
            },
        );
        let seq = run_network(&net, Strategy::ImaDw, &cfg, &pm);
        assert_eq!(one.cycles, seq.cycles);
        assert_eq!(one.per_request_cycles, seq.cycles);
        assert!((one.energy_j - seq.energy_j).abs() < 1e-12);
    }

    #[test]
    fn pipelined_batch_overlaps_disjoint_resources() {
        let (cfg, pm) = setup();
        let net = bottleneck();
        let mut cache = PlanCache::new();
        let plan = cache.get_or_place(&net, 256, 8, false).unwrap();
        let b = BatchConfig {
            batch: 4,
            pipeline: true,
            ..BatchConfig::default()
        };
        let piped = run_batched(&net, Strategy::ImaDw, &cfg, &pm, &plan, b);
        let strict = run_batched(
            &net,
            Strategy::ImaDw,
            &cfg,
            &pm,
            &plan,
            BatchConfig {
                batch: 4,
                pipeline: false,
                ..BatchConfig::default()
            },
        );
        assert!(piped.cycles < strict.cycles, "{} vs {}", piped.cycles, strict.cycles);
        assert!(piped.speedup_vs_sequential() > 1.0);
        assert!(piped.inferences_per_s() > strict.inferences_per_s());
        // lower bound: the bottleneck resource cannot be beaten
        assert!(piped.cycles >= piped.per_request_cycles);
    }

    #[test]
    fn profile_intervals_are_consistent() {
        // resident plan: spans stay inside the makespan, interval sets are
        // canonical and account exactly for the busy cycles, and no DMA
        // resource appears
        let (cfg, pm) = setup();
        let net = bottleneck();
        let mut cache = PlanCache::new();
        let plan = cache.get_or_place(&net, 256, 8, false).unwrap();
        let rep = run_batched(
            &net,
            Strategy::ImaDw,
            &cfg,
            &pm,
            &plan,
            BatchConfig {
                batch: 4,
                ..BatchConfig::default()
            },
        );
        let prof = &rep.profile;
        assert_eq!(prof.len, rep.cycles);
        assert!(!prof.spans.is_empty());
        for s in &prof.spans {
            assert!(s.first_use <= s.last_release);
            assert!(
                s.last_release <= prof.len,
                "res {} released at {} > len {}",
                s.res,
                s.last_release,
                prof.len
            );
            assert!(s.busy <= s.last_release - s.first_use);
            // interval lists are sorted, disjoint, non-adjacent, bracket
            // the envelope, and sum exactly to the busy cycles
            assert!(!s.intervals.is_empty());
            for w in s.intervals.windows(2) {
                assert!(w[0].1 < w[1].0, "res {}: {:?}", s.res, s.intervals);
            }
            assert_eq!(s.intervals.first().unwrap().0, s.first_use);
            assert_eq!(s.intervals.last().unwrap().1, s.last_release);
            let total: u64 = s.intervals.iter().map(|&(a, b)| b - a).sum();
            assert_eq!(total, s.busy, "res {}", s.res);
        }
        assert!(prof.span(RES_DMA).is_none(), "resident plans never touch L2");
        assert!(prof.span(RES_PROG).is_none(), "resident plans never reprogram");
        assert!(prof.span(RES_DWACC).is_some());
        // the residual/pool sections engage the whole complex: all eight
        // per-core resources appear, and core 0 dominates every other
        // core's envelope (the fused-complex equivalence precondition)
        let c0 = prof.span(RES_CORE0).expect("core layers reserve core 0");
        for c in 1..N_CORES {
            if let Some(s) = prof.span(RES_CORE0 + c) {
                assert!(s.first_use >= c0.first_use, "core{c}");
                assert!(s.last_release <= c0.last_release, "core{c}");
            }
        }
        assert!(prof.span(RES_CORE0 + 7).is_some(), "bottleneck adds fill 8 cores");
    }

    #[test]
    fn staged_profiles_reserve_the_programming_port() {
        // a staged batch's profile must carry the PCM programming port so
        // two staged tenants cannot reprogram concurrently cross-tenant
        let (cfg, pm) = setup();
        let net = crate::net::mobilenetv2::mobilenet_v2(224);
        let mut cache = PlanCache::new();
        let plan = cache.get_or_place(&net, 256, 8, false).unwrap();
        for stream_weights in [false, true] {
            let rep = run_batched(
                &net,
                Strategy::ImaDw,
                &cfg,
                &pm,
                &plan,
                BatchConfig {
                    batch: 2,
                    stream_weights,
                    ..BatchConfig::default()
                },
            );
            let prog = rep.profile.span(RES_PROG).expect("staged batches program");
            assert_eq!(prog.busy, rep.reprogram_cycles, "stream {stream_weights}");
            assert!(rep.profile.span(RES_DMA).is_some());
        }
    }

    #[test]
    fn streamed_weight_updates_beat_the_barrier() {
        let (cfg, pm) = setup();
        let net = crate::net::mobilenetv2::mobilenet_v2(224);
        let mut cache = PlanCache::new();
        let plan = cache.get_or_place(&net, 256, 8, false).unwrap();
        assert!(plan.n_passes() > 1, "8 arrays must stage MNv2");
        for batch in [1usize, 4] {
            let block = run_batched(
                &net,
                Strategy::ImaDw,
                &cfg,
                &pm,
                &plan,
                BatchConfig {
                    batch,
                    ..BatchConfig::default()
                },
            );
            let stream = run_batched(
                &net,
                Strategy::ImaDw,
                &cfg,
                &pm,
                &plan,
                BatchConfig {
                    batch,
                    stream_weights: true,
                    ..BatchConfig::default()
                },
            );
            // identical work, identical energy — only the makespan moves
            assert_eq!(stream.reprogram_cycles, block.reprogram_cycles);
            assert_eq!(stream.dma_cycles, block.dma_cycles);
            assert_eq!(stream.sequential_cycles, block.sequential_cycles);
            assert!((stream.energy_j - block.energy_j).abs() < 1e-12);
            assert!(
                stream.cycles < block.cycles,
                "batch {batch}: {} !< {}",
                stream.cycles,
                block.cycles
            );
            // programming still serializes on one port: the makespan can
            // beat neither the programming work nor a lone request
            assert!(stream.cycles >= stream.reprogram_cycles);
            assert!(stream.cycles >= stream.per_request_cycles);
        }
    }

    #[test]
    fn stream_flag_is_inert_on_resident_plans() {
        let (cfg, pm) = setup();
        let net = bottleneck();
        let mut cache = PlanCache::new();
        let plan = cache.get_or_place(&net, 256, 8, false).unwrap();
        let base = BatchConfig {
            batch: 4,
            ..BatchConfig::default()
        };
        let a = run_batched(&net, Strategy::ImaDw, &cfg, &pm, &plan, base);
        let b = run_batched(
            &net,
            Strategy::ImaDw,
            &cfg,
            &pm,
            &plan,
            BatchConfig {
                stream_weights: true,
                ..base
            },
        );
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.profile, b.profile);
    }
}
