//! Run-level metrics: the quantities every paper figure reports.

use crate::arch::{AreaModel, EnergyAccount, PowerModel, SystemConfig};
use crate::util::units;

use super::{Engine, Strategy};

/// Per-layer outcome.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub name: String,
    pub engine: Engine,
    pub cycles: u64,
    pub energy_j: f64,
    pub macs: u64,
    pub ops: u64,
    /// PCM devices this layer occupies (0 when not IMA-mapped).
    pub devices: usize,
    /// Cores the layer's parallel section engages (0 when the layer does
    /// not run on the core complex) — the batch scheduler reserves the
    /// per-core resource prefix `core0..cores_used`.
    pub cores_used: usize,
}

/// Whole-run outcome for one (network, strategy) pair.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub network: String,
    pub strategy: Strategy,
    pub cycles: u64,
    pub time_s: f64,
    pub energy_j: f64,
    pub ops: u64,
    pub devices_used: usize,
    pub layers: Vec<LayerReport>,
}

impl RunReport {
    pub fn from_parts(
        network: &str,
        strategy: Strategy,
        cfg: &SystemConfig,
        pm: &PowerModel,
        layers: Vec<LayerReport>,
        accounts: &EnergyAccount,
    ) -> RunReport {
        let cycles: u64 = layers.iter().map(|l| l.cycles).sum();
        let ops: u64 = layers.iter().map(|l| l.ops).sum();
        let devices_used: usize = layers.iter().map(|l| l.devices).sum();
        let time_s = cycles as f64 * cfg.freq.cycle_ns() * 1e-9;
        RunReport {
            network: network.into(),
            strategy,
            cycles,
            time_s,
            energy_j: accounts.total_j(pm, cfg),
            ops,
            devices_used,
            layers,
        }
    }

    pub fn gops(&self) -> f64 {
        units::gops(self.ops, self.time_s)
    }

    pub fn tops_per_w(&self) -> f64 {
        units::tops_per_w(self.ops, self.energy_j)
    }

    /// Area charged to the run: the non-IMA cluster plus the effective PCM
    /// area of the mapped devices (padding included) — Fig. 9c convention,
    /// see DESIGN.md §5 / EXPERIMENTS.md for the deviation discussion.
    pub fn area_mm2(&self, cfg: &SystemConfig) -> f64 {
        let base = AreaModel::paper();
        let non_ima = base.total() - base.ima_subsystem;
        let pcm = base.effective_pcm_mm2(cfg, self.devices_used);
        non_ima + pcm + if self.devices_used > 0 { 0.10 } else { 0.0 }
    }

    pub fn gops_per_mm2(&self, cfg: &SystemConfig) -> f64 {
        self.gops() / self.area_mm2(cfg)
    }

    pub fn inferences_per_s(&self) -> f64 {
        if self.time_s > 0.0 {
            1.0 / self.time_s
        } else {
            0.0
        }
    }

    /// Cycles spent per engine (the Fig. 10 breakdown).
    pub fn engine_breakdown(&self) -> Vec<(Engine, u64)> {
        let mut ima = 0;
        let mut dw = 0;
        let mut cores = 0;
        for l in &self.layers {
            match l.engine {
                Engine::Ima => ima += l.cycles,
                Engine::DwAcc => dw += l.cycles,
                Engine::Cores => cores += l.cycles,
            }
        }
        vec![(Engine::Ima, ima), (Engine::DwAcc, dw), (Engine::Cores, cores)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy_layer(cycles: u64, engine: Engine) -> LayerReport {
        LayerReport {
            name: "l".into(),
            engine,
            cycles,
            energy_j: 1e-6,
            macs: 1000,
            ops: 2000,
            devices: 0,
            cores_used: 0,
        }
    }

    #[test]
    fn aggregates() {
        let cfg = SystemConfig::paper();
        let pm = PowerModel::paper();
        let mut acc = EnergyAccount::default();
        acc.wall_cy = 300;
        let r = RunReport::from_parts(
            "net",
            Strategy::Cores,
            &cfg,
            &pm,
            vec![dummy_layer(100, Engine::Cores), dummy_layer(200, Engine::Ima)],
            &acc,
        );
        assert_eq!(r.cycles, 300);
        assert_eq!(r.ops, 4000);
        assert!((r.time_s - 300.0 * 2e-9).abs() < 1e-15);
        let bd = r.engine_breakdown();
        assert_eq!(bd[0].1, 200); // IMA
        assert_eq!(bd[2].1, 100); // cores
    }

    #[test]
    fn area_includes_pcm_only_when_mapped() {
        let cfg = SystemConfig::paper();
        let pm = PowerModel::paper();
        let acc = EnergyAccount::default();
        let mut l = dummy_layer(10, Engine::Ima);
        l.devices = 65536;
        let with = RunReport::from_parts("n", Strategy::ImaDw, &cfg, &pm, vec![l], &acc);
        let without = RunReport::from_parts(
            "n",
            Strategy::Cores,
            &cfg,
            &pm,
            vec![dummy_layer(10, Engine::Cores)],
            &acc,
        );
        assert!(with.area_mm2(&cfg) > without.area_mm2(&cfg) + 0.7);
    }
}
