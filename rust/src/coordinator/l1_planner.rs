//! L1 (TCDM) memory planner — makes the paper's §VI residency assumption
//! executable instead of assumed.
//!
//! The paper runs layer-to-layer inference "with the additional condition
//! that all the input activations reside in the L1 memory" and argues that
//! double buffering and activation tiling hide the L2 traffic when they
//! don't fit. This planner:
//!
//! * allocates each layer's working set (input + output + dw weights for
//!   the accelerator + residual source kept alive) against the 512 kB TCDM;
//! * when a layer overflows, derives the spatial tiling factor that fits
//!   and the DMA schedule (double-buffered halves);
//! * verifies, per tile, that the transfer hides behind the engine time —
//!   producing the latency *penalty* (usually zero) instead of a hope.

use crate::arch::{PowerModel, SystemConfig};
use crate::net::{LayerKind, Network};
use crate::sim::dma::DmaModel;

use super::{Executor, Strategy};

/// Plan for one layer.
#[derive(Clone, Debug)]
pub struct LayerPlan {
    pub name: String,
    /// Full working set in bytes (in + out + weights resident in L1).
    pub working_set: usize,
    /// 1 = fully resident; >1 = spatial tiling factor applied.
    pub tiles: usize,
    /// DMA cycles that could NOT be hidden behind compute (adds latency).
    pub exposed_dma_cy: u64,
}

#[derive(Clone, Debug, Default)]
pub struct L1Plan {
    pub layers: Vec<LayerPlan>,
    pub l1_bytes: usize,
}

impl L1Plan {
    pub fn layers_tiled(&self) -> usize {
        self.layers.iter().filter(|l| l.tiles > 1).count()
    }

    pub fn total_exposed_dma_cy(&self) -> u64 {
        self.layers.iter().map(|l| l.exposed_dma_cy).sum()
    }

    pub fn peak_working_set(&self) -> usize {
        self.layers.iter().map(|l| l.working_set).max().unwrap_or(0)
    }
}

/// Residual liveness: bytes of earlier outputs that must stay in L1 while
/// the block body executes.
fn residual_live_bytes(net: &Network, idx: usize) -> usize {
    net.layers
        .iter()
        .enumerate()
        .skip(idx + 1)
        .filter_map(|(_, l)| {
            l.residual_from.and_then(|src| {
                // `src`'s output is alive through layers (src, add]
                if src <= idx {
                    let s = &net.layers[src];
                    Some(s.out_pixels() * s.cout)
                } else {
                    None
                }
            })
        })
        .max()
        .unwrap_or(0)
}

/// Build the plan for a network under a strategy.
pub fn plan(net: &Network, strategy: Strategy, cfg: &SystemConfig, pm: &PowerModel) -> L1Plan {
    let l1 = cfg.tcdm_kb * 1024;
    let dma = DmaModel::paper();
    let ex = Executor::new(cfg, pm, strategy);
    let mut out = L1Plan {
        layers: Vec::new(),
        l1_bytes: l1,
    };

    for (i, l) in net.layers.iter().enumerate() {
        let dw_w = if l.kind == LayerKind::Dw { l.n_weights() } else { 0 };
        let live = residual_live_bytes(net, i);
        let ws = l.in_bytes() + l.out_bytes() + dw_w + live;

        // fully resident (no DMA at all) when the plain working set fits;
        // otherwise tile so that double-buffered halves fit (2 tile-inputs
        // + 2 tile-outputs staged while weights/live tensors stay put)
        let mut tiles = 1usize;
        if ws > l1 {
            tiles = 2;
            while tiles < 64 {
                let staged = 2 * (l.in_bytes() + l.out_bytes()) / tiles + dw_w + live;
                if staged <= l1 {
                    break;
                }
                tiles *= 2;
            }
        }

        // can each tile's DMA hide behind its share of compute?
        let (rep, _) = ex.layer(l);
        let per_tile_cy = rep.cycles / tiles as u64;
        let per_tile_bytes = (l.in_bytes() + l.out_bytes()) / tiles;
        let dma_cy = dma.transfer_cy(per_tile_bytes);
        let exposed = if tiles == 1 {
            0
        } else {
            (dma_cy.saturating_sub(per_tile_cy)) * tiles as u64
        };

        out.layers.push(LayerPlan {
            name: l.name.clone(),
            working_set: ws,
            tiles,
            exposed_dma_cy: exposed,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::mobilenetv2::mobilenet_v2;

    #[test]
    fn mnv2_plan_validates_the_papers_assumption() {
        let cfg = SystemConfig::scaled_up(33);
        let pm = PowerModel::paper();
        let net = mobilenet_v2(224);
        let p = plan(&net, Strategy::ImaDw, &cfg, &pm);
        assert_eq!(p.layers.len(), net.layers.len());
        // early layers need tiling…
        assert!(p.layers_tiled() >= 8, "{}", p.layers_tiled());
        // …and double-buffered DMA hides *almost* everything: only the
        // stride-2 dw layers (4× read:write on the fast accelerator)
        // expose transfers, totalling <2 % of the 5.4 M-cycle inference —
        // a sharper statement than the paper's blanket §VI assumption.
        let exposed = p.total_exposed_dma_cy();
        assert!(exposed > 0, "stride-2 dw should expose some DMA");
        assert!(
            (exposed as f64) < 0.02 * 5_440_000.0,
            "exposed {exposed} cycles"
        );
    }

    #[test]
    fn bottleneck_fits_untiled() {
        // the case-study block was *chosen* to fit 512 kB — the planner
        // must agree (paper §V-C)
        let cfg = SystemConfig::paper();
        let pm = PowerModel::paper();
        let net = crate::net::bottleneck::bottleneck();
        let p = plan(&net, Strategy::ImaDw, &cfg, &pm);
        assert_eq!(p.layers_tiled(), 0, "{:#?}", p.layers);
        assert!(p.peak_working_set() <= 512 * 1024);
    }

    #[test]
    fn residual_liveness_counted() {
        let net = mobilenet_v2(224);
        // inside bneck2_1 (which has an add), the block input must be live
        let idx = net
            .layers
            .iter()
            .position(|l| l.name == "bneck2_1_dw")
            .unwrap();
        assert!(residual_live_bytes(&net, idx) > 0);
        // conv1 has no residual crossing it
        assert_eq!(residual_live_bytes(&net, 0), 0);
    }
}
